//! Regenerate every table and figure of the paper's evaluation and write
//! the markdown to `EXPERIMENTS_GENERATED.md`.
//!
//! ```bash
//! cargo run --release --example paper_figures                 # default scale (300 convs)
//! cargo run --release --example paper_figures -- --paper      # full scale (1000 convs)
//! cargo run --release --example paper_figures -- --quick      # smoke scale (80 convs)
//! ```

use fastswitch::coordinator::priority::Pattern;
use fastswitch::exp;
use fastswitch::exp::runner::Scale;
use fastswitch::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let scale = if args.flag("paper") {
        Scale::paper()
    } else if args.flag("quick") {
        Scale::quick()
    } else {
        Scale::default()
    };
    let freqs = [0.01, 0.02, 0.04, 0.08];
    eprintln!(
        "regenerating all figures at {} conversations (this runs ~40 simulations)...",
        scale.conversations
    );

    let mut reports = Vec::new();
    let t0 = std::time::Instant::now();

    eprintln!("[1/11] fig1 latency breakdown");
    reports.push(exp::fig1::run(&scale));
    eprintln!("[2/11] fig2 waiting fractions");
    reports.push(exp::fig2::run(&scale));
    eprintln!("[3/11] fig3 granularity timeline");
    reports.push(exp::fig3::run());
    eprintln!("[4/11] fig4 workload distributions");
    reports.push(exp::fig4::run(&scale));
    eprintln!("[5/11] fig6 asynchrony degrees + fig8(a-d) tail latency ladders");
    reports.push(exp::fig6::run());
    for testbed in ["llama8b", "qwen32b"] {
        for pat in [Pattern::Markov, Pattern::Random] {
            reports.push(exp::fig8::run_latency(testbed, pat, &scale));
        }
    }
    eprintln!("[6/11] fig8(e-f) throughput sweeps");
    for testbed in ["llama8b", "qwen32b"] {
        reports.push(exp::fig8::run_throughput(
            testbed,
            Pattern::Markov,
            &freqs,
            &scale,
        ));
    }
    eprintln!("[7/11] fig9 call-stack overhead");
    reports.push(exp::fig9::run(&freqs, &scale));
    eprintln!("[8/11] fig10 context-switch overhead");
    reports.push(exp::fig10::run(&freqs, &scale));
    eprintln!("[9/11] fig11 block-group size sensitivity");
    reports.push(exp::fig11::run(&[64, 256, 1000, 2000, 3000], &[0.02, 0.04], &scale));
    eprintln!("[10/11] fig12 token-generation efficiency");
    reports.push(exp::fig12::run(&scale));
    eprintln!("[11/11] fig13 CPU memory sensitivity + table1 swap volume");
    reports.push(exp::fig13::run(&[2, 8, 20, 40, 60, 80], &scale));
    reports.push(exp::table1::run(&scale));

    let mut md = format!(
        "# Generated paper figures (scale: {} conversations, seed {})\n\n",
        scale.conversations, scale.seed
    );
    for r in &reports {
        println!("{}", r.render());
        md.push_str(&r.markdown());
    }
    std::fs::write("EXPERIMENTS_GENERATED.md", md).expect("write");
    eprintln!(
        "done in {:.1}s — wrote EXPERIMENTS_GENERATED.md",
        t0.elapsed().as_secs_f64()
    );
}
