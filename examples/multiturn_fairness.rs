//! Multi-turn fairness scenario: the workload the paper's introduction
//! motivates — many concurrent multi-turn conversations with frequent
//! priority adjustments, where the serving system must keep *tail* SLOs
//! tight for everyone rather than letting a few requests hog the GPU.
//!
//! Demonstrates:
//! 1. how tail TTFT degrades with priority-update frequency on the vLLM
//!    baseline (fairness costs context switches),
//! 2. how much of that cost each FastSwitch optimization removes,
//! 3. the Random-vs-Markov pattern effect (§5.1.1: Random is harsher —
//!    it breaks block-group continuity and CPU-copy reuse).
//!
//! ```bash
//! cargo run --release --example multiturn_fairness
//! ```

use fastswitch::config::{EngineConfig, Preset};
use fastswitch::coordinator::priority::Pattern;
use fastswitch::exp::runner::{run_sim, Scale};

fn main() {
    let scale = Scale {
        conversations: 200,
        ..Scale::default()
    };
    println!("Multi-turn fairness under priority churn (LLaMA-8B/A10 testbed)\n");

    // 1. Fairness tax on the baseline: sweep the update frequency.
    println!("-- vLLM baseline: tail TTFT vs priority-update frequency --");
    for freq in [0.005, 0.02, 0.08] {
        let mut cfg = EngineConfig::vllm_baseline();
        cfg.scheduler.priority_update_freq = freq;
        let out = run_sim(cfg, Preset::llama8b_a10(), Pattern::Markov, &scale);
        let ttft = out.recorder.ttft();
        println!(
            "  freq {freq:<6} P99 TTFT {:.3}s  preemptions {:>5}  swap-stall {:>7.1}s",
            ttft.p(99.0),
            out.recorder.preemptions,
            out.recorder.stall_breakdown().1 as f64 / 1e9,
        );
    }

    // 2. What each optimization buys back at high frequency.
    println!("\n-- ablation at freq 0.04 (Markov) --");
    let mut base_p99 = 0.0;
    for mut cfg in EngineConfig::ablation_ladder() {
        cfg.scheduler.priority_update_freq = 0.04;
        let label = cfg.label.clone();
        let out = run_sim(cfg, Preset::llama8b_a10(), Pattern::Markov, &scale);
        let p99 = out.recorder.ttft().p(99.0);
        if label == "vllm" {
            base_p99 = p99;
        }
        println!(
            "  {label:<16} P99 TTFT {:.3}s ({:.2}x)  granularity {:>5.1} blk/call  reused {:>6} blocks",
            p99,
            base_p99 / p99,
            out.swap_stats.avg_granularity(),
            out.reuse_blocks_reused,
        );
    }

    // 3. Pattern effect on full FastSwitch.
    println!("\n-- FastSwitch: Markov vs Random pattern (freq 0.04) --");
    for pat in [Pattern::Markov, Pattern::Random] {
        let mut cfg = EngineConfig::fastswitch();
        cfg.scheduler.priority_update_freq = 0.04;
        let out = run_sim(cfg, Preset::llama8b_a10(), pat, &scale);
        let ttft = out.recorder.ttft();
        println!(
            "  {pat:?}: P99 TTFT {:.3}s, conflicts {}, reuse {:>6} blocks, swap volume {} blocks",
            ttft.p(99.0),
            out.swap_stats.conflicts,
            out.reuse_blocks_reused,
            out.reuse_blocks_transferred,
        );
    }
    println!("\n(paper §5.1.1: Random disrupts block-group continuity and reuse, Markov retains it)");
}
