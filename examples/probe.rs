use fastswitch::config::{EngineConfig, Preset};
use fastswitch::coordinator::priority::Pattern;
use fastswitch::exp::runner::{run_sim, Scale};

fn main() {
    for rate in [0.3, 0.5] {
        let scale = Scale { conversations: 150, request_rate: rate, ..Scale::default() };
        println!("--- qwen32b @ {rate} req/s ---");
        for cfg0 in [EngineConfig::vllm_baseline(), EngineConfig::with_dbg_reuse(), EngineConfig::fastswitch()] {
            let mut cfg = cfg0.clone();
            cfg.scheduler.priority_update_freq = 0.02;
            let out = run_sim(cfg, Preset::qwen32b_a100(), Pattern::Markov, &scale);
            let ttft = out.recorder.ttft();
            let tbt = out.recorder.tbt();
            println!(
                "{:<16} P99TTFT={:8.2}s P99.9TBT={:7.2}s tput={:6.1} recompute={:6} contam={:7}",
                out.label, ttft.p(99.0), tbt.p(99.9), out.throughput(),
                out.recorder.recompute_preemptions, out.contaminated,
            );
        }
    }
}
