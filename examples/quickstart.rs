//! Quickstart: simulate FastSwitch vs the vLLM baseline on the paper's
//! LLaMA-8B/A10 testbed and print the tail-latency comparison.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fastswitch::config::{EngineConfig, Preset};
use fastswitch::coordinator::priority::Pattern;
use fastswitch::exp::runner::{run_sim, Scale};

fn main() {
    // The paper's LLaMA-8B setting: priority updates at frequency 0.04
    // (every 25 iterations), Markov context-switching pattern.
    let scale = Scale {
        conversations: 200,
        request_rate: 1.0,
        seed: 42,
        ..Scale::default()
    };

    println!("FastSwitch quickstart — LLaMA-8B on A10 (simulated testbed)");
    println!(
        "{} conversations, Poisson {} req/s\n",
        scale.conversations, scale.request_rate
    );

    let mut rows = Vec::new();
    for mut cfg in [EngineConfig::vllm_baseline(), EngineConfig::fastswitch()] {
        cfg.scheduler.priority_update_freq = 0.04;
        let label = cfg.label.clone();
        let out = run_sim(cfg, Preset::llama8b_a10(), Pattern::Markov, &scale);
        let ttft = out.recorder.ttft();
        let tbt = out.recorder.tbt();
        let (inf, swap, _) = out.recorder.stall_breakdown();
        println!(
            "{label:<12} P95 TTFT {:.3}s  P99 TTFT {:.3}s  P99.9 TBT {:.3}s  \
             throughput {:.1} tok/s  swap-stall {:.1}s / inference {:.1}s",
            ttft.p(95.0),
            ttft.p(99.0),
            tbt.p(99.9),
            out.throughput(),
            swap as f64 / 1e9,
            inf as f64 / 1e9,
        );
        rows.push((label, ttft.p(99.0), tbt.p(99.9)));
    }
    println!(
        "\nFastSwitch speedup: P99 TTFT {:.2}x, P99.9 TBT {:.2}x",
        rows[0].1 / rows[1].1,
        rows[0].2 / rows[1].2
    );
    println!("(paper: 1.4–5.8x TTFT, up to 11.2x TBT across testbeds)");
}
