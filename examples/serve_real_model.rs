//! End-to-end real-model serving driver — the proof that all layers
//! compose: Pallas kernels → JAX model → HLO text → PJRT → the Rust
//! coordinator serving batched requests with priority preemption and
//! physical KV swapping, reporting wall-clock latency and throughput.
//!
//! ```bash
//! make artifacts                              # once (python AOT path)
//! cargo run --release --example serve_real_model
//! ```

use std::path::Path;

use fastswitch::config::Granularity;
use fastswitch::runtime::PjrtModel;
use fastswitch::server::{RealEngine, RealEngineConfig, RealRequestSpec};
use fastswitch::util::rng::Rng;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let model = PjrtModel::load(dir).expect("load artifacts");
    println!(
        "loaded model on {}: {} layers, d_model {}, {} KV blocks x {} tokens, decode variants {:?}",
        model.platform(),
        model.meta.n_layers,
        model.meta.d_model,
        model.meta.num_blocks,
        model.meta.block_size,
        model.meta.decode_batch_sizes,
    );
    let vocab = model.meta.vocab;

    let mut eng = RealEngine::new(
        model,
        RealEngineConfig {
            granularity: Granularity::BlockGroup { init_group_blocks: 8 },
            copy_workers: 4,
            cpu_slots: 512,
            max_batch: 8,
        },
    );

    // A mixed batch: varied prompts, generation budgets, and priorities —
    // low-priority requests will be preempted (physically swapped out)
    // when high-priority ones need the batch/KV space.
    let mut rng = Rng::new(7);
    let n = 12;
    for i in 0..n {
        let plen = rng.usize(16, 120);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.usize(1, vocab) as i32).collect();
        eng.submit(RealRequestSpec {
            prompt,
            max_new_tokens: rng.usize(8, 40),
            priority: (i % 3) as i64,
        });
    }

    let out = eng.run().expect("serve");
    println!("\n== end-to-end real serving (PJRT CPU) ==");
    println!("requests      : {}", out.completions.len());
    println!("tokens        : {}", out.tokens);
    println!("decode iters  : {}", out.decode_iters);
    println!("wall time     : {:.2}s", out.wall_s);
    println!("throughput    : {:.1} tok/s", out.throughput_tok_s);
    println!(
        "TTFT P50/P95/P99 : {:.3}/{:.3}/{:.3} s",
        out.ttft_s.p(50.0),
        out.ttft_s.p(95.0),
        out.ttft_s.p(99.0)
    );
    println!(
        "TBT  P50/P95/P99 : {:.4}/{:.4}/{:.4} s",
        out.tbt_s.p(50.0),
        out.tbt_s.p(95.0),
        out.tbt_s.p(99.0)
    );
    println!(
        "preemptions   : {} ({} blocks physically swapped)",
        out.preemptions, out.swapped_blocks
    );
    for (id, toks) in out.completions.iter().take(3) {
        println!(
            "request {id}: {} tokens -> {:?}...",
            toks.len(),
            &toks[..toks.len().min(8)]
        );
    }
}
