"""Kernel-vs-oracle correctness: paged_attention (the CORE signal).

Hypothesis sweeps shapes/GQA ratios/context lengths; every case asserts
allclose against the pure-jnp oracle in compile.kernels.ref.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import paged_attention
from compile.kernels.ref import ref_paged_attention

SET = dict(deadline=None, max_examples=12, print_blob=True)


def make_case(rng, B, H, KH, D, NB, BS, MAXB, ctx_lens):
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((NB, BS, KH, D)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((NB, BS, KH, D)), jnp.float32)
    # Distinct blocks per request so cross-request contamination would be
    # caught by the oracle comparison.
    perm = rng.permutation(NB)
    bt = jnp.asarray(perm[: B * MAXB].reshape(B, MAXB), jnp.int32)
    cl = jnp.asarray(ctx_lens, jnp.int32)
    return q, kc, vc, bt, cl


def check(B, H, KH, D, NB, BS, MAXB, ctx_lens, seed=0):
    rng = np.random.default_rng(seed)
    q, kc, vc, bt, cl = make_case(rng, B, H, KH, D, NB, BS, MAXB, ctx_lens)
    out = paged_attention(q, kc, vc, bt, cl, block_size=BS)
    ref = ref_paged_attention(q, kc, vc, bt, cl, block_size=BS)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


@settings(**SET)
@given(
    B=st.integers(1, 5),
    KH=st.integers(1, 4),
    G=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([8, 16, 32, 64]),
    BS=st.sampled_from([4, 8, 16]),
    data=st.data(),
)
def test_paged_attention_matches_ref(B, KH, G, D, BS, data):
    H = KH * G
    MAXB = 6
    NB = B * MAXB + 1
    max_ctx = MAXB * BS
    ctx = [data.draw(st.integers(1, max_ctx)) for _ in range(B)]
    check(B, H, KH, D, NB, BS, MAXB, ctx, seed=data.draw(st.integers(0, 2**16)))


def test_single_token_context():
    """ctx=1: the query attends only to its own freshly written KV."""
    check(2, 2, 2, 8, 16, 8, 4, [1, 1])


def test_exact_block_boundaries():
    """Context lengths at exact multiples of the block size."""
    BS = 8
    check(3, 4, 2, 16, 32, BS, 6, [BS, 2 * BS, 6 * BS])


def test_one_past_block_boundary():
    BS = 8
    check(2, 2, 1, 8, 24, BS, 6, [BS + 1, 5 * BS + 1])


def test_full_table():
    """Every block-table slot in use."""
    check(1, 4, 4, 16, 9, 4, 8, [32])


def test_shared_blocks_between_requests():
    """Two requests legitimately sharing the same physical blocks (prefix
    sharing) must read identical KV."""
    rng = np.random.default_rng(7)
    B, H, KH, D, NB, BS, MAXB = 2, 2, 2, 8, 16, 8, 4
    q1 = rng.standard_normal((1, H, D)).astype(np.float32)
    q = jnp.asarray(np.concatenate([q1, q1]), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((NB, BS, KH, D)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((NB, BS, KH, D)), jnp.float32)
    bt = jnp.asarray([[3, 5, 0, 0], [3, 5, 0, 0]], jnp.int32)
    cl = jnp.asarray([13, 13], jnp.int32)
    out = paged_attention(q, kc, vc, bt, cl, block_size=BS)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]), rtol=1e-6)


def test_stale_table_entries_ignored():
    """Entries past ceil(ctx/BS) must not affect the result."""
    rng = np.random.default_rng(11)
    B, H, KH, D, NB, BS, MAXB = 1, 2, 2, 8, 16, 8, 4
    q, kc, vc, bt, cl = make_case(rng, B, H, KH, D, NB, BS, MAXB, [10])
    out1 = paged_attention(q, kc, vc, bt, cl, block_size=BS)
    bt2 = np.asarray(bt).copy()
    bt2[0, 2:] = 0  # clobber stale entries
    out2 = paged_attention(q, kc, vc, jnp.asarray(bt2), cl, block_size=BS)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_softmax_scale_invariance_shift():
    """Shifting all K by a constant along D changes scores but the kernel's
    online softmax must stay finite and match the oracle (numerical
    robustness with large score magnitudes)."""
    rng = np.random.default_rng(13)
    q, kc, vc, bt, cl = make_case(rng, 2, 2, 2, 8, 16, 8, 4, [9, 17])
    kc = kc * 30.0  # large magnitudes
    out = paged_attention(q, kc, vc, bt, cl, block_size=8)
    ref = ref_paged_attention(q, kc, vc, bt, cl, block_size=8)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)
