"""L2 model correctness: the paged decode/prefill paths must reproduce an
ordinary dense causal transformer token-for-token."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import ModelConfig
from compile.model import (
    decode_step,
    dense_forward,
    init_params,
    param_spec,
    prefill_chunk,
)

CFG = ModelConfig(
    vocab=128, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, max_seq=64, num_blocks=16, block_size=8, max_blocks_per_seq=8,
    prefill_chunk=8,
)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def empty_caches():
    shape = (CFG.n_layers, CFG.num_blocks, CFG.block_size, CFG.n_kv_heads,
             CFG.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def random_tokens(rng, n):
    return jnp.asarray(rng.integers(1, CFG.vocab, n), jnp.int32)


def test_param_spec_shapes(params):
    for arr, (name, shape) in zip(params, param_spec(CFG)):
        assert tuple(arr.shape) == tuple(shape), name


def test_prefill_then_decode_matches_dense(params):
    """Chunked prefill + step-by-step decode == dense forward (greedy)."""
    rng = np.random.default_rng(2)
    S = 20
    tokens = random_tokens(rng, S)
    dense_logits = dense_forward(CFG, params, tokens)
    kc, vc = empty_caches()
    bt = jnp.asarray([3, 5, 7, 2, 4, 6, 8, 9], jnp.int32)

    _, kc, vc = prefill_chunk(CFG, params, kc, vc, tokens[:8], 0, 8, bt)
    nt, kc, vc = prefill_chunk(CFG, params, kc, vc, tokens[8:16], 8, 8, bt)
    assert int(nt) == int(jnp.argmax(dense_logits[15]))

    B = 2
    btab = jnp.zeros((B, 8), jnp.int32).at[0].set(bt)
    for pos in range(16, S):
        tok = jnp.asarray([int(tokens[pos]), 0], jnp.int32)
        positions = jnp.asarray([pos, 0], jnp.int32)
        cl = jnp.asarray([pos + 1, 0], jnp.int32)
        nxt, kc, vc = decode_step(CFG, params, kc, vc, tok, positions, btab, cl)
        assert int(nxt[0]) == int(jnp.argmax(dense_logits[pos])), pos


def test_partial_final_chunk(params):
    """Prompt not a multiple of the chunk size: final chunk padded."""
    rng = np.random.default_rng(5)
    S = 11
    tokens = random_tokens(rng, S)
    dense_logits = dense_forward(CFG, params, tokens)
    kc, vc = empty_caches()
    bt = jnp.asarray([3, 5, 7, 2, 4, 6, 8, 9], jnp.int32)
    _, kc, vc = prefill_chunk(CFG, params, kc, vc, tokens[:8], 0, 8, bt)
    padded = jnp.concatenate([tokens[8:], jnp.zeros(5, jnp.int32)])
    nt, kc, vc = prefill_chunk(CFG, params, kc, vc, padded, 8, 3, bt)
    assert int(nt) == int(jnp.argmax(dense_logits[S - 1]))


def test_batched_decode_request_isolation(params):
    """Two requests decoding in the same batch produce exactly what each
    would produce alone."""
    rng = np.random.default_rng(8)
    S = 10
    toks_a, toks_b = random_tokens(rng, S), random_tokens(rng, S)
    la = dense_forward(CFG, params, toks_a)
    lb = dense_forward(CFG, params, toks_b)

    kc, vc = empty_caches()
    bt_a = jnp.asarray([1, 2, 0, 0, 0, 0, 0, 0], jnp.int32)
    bt_b = jnp.asarray([3, 4, 0, 0, 0, 0, 0, 0], jnp.int32)
    pad = jnp.concatenate([toks_a[8:], jnp.zeros(6, jnp.int32)])
    _, kc, vc = prefill_chunk(CFG, params, kc, vc, toks_a[:8], 0, 8, bt_a)
    _, kc, vc = prefill_chunk(CFG, params, kc, vc, pad, 8, 2, bt_a)
    pad = jnp.concatenate([toks_b[8:], jnp.zeros(6, jnp.int32)])
    _, kc, vc = prefill_chunk(CFG, params, kc, vc, toks_b[:8], 0, 8, bt_b)
    _, kc, vc = prefill_chunk(CFG, params, kc, vc, pad, 8, 2, bt_b)

    btab = jnp.stack([bt_a, bt_b])
    tok = jnp.asarray([int(jnp.argmax(la[S - 1])), int(jnp.argmax(lb[S - 1]))],
                      jnp.int32)
    positions = jnp.asarray([S, S], jnp.int32)
    cl = jnp.asarray([S + 1, S + 1], jnp.int32)
    nxt, kc, vc = decode_step(CFG, params, kc, vc, tok, positions, btab, cl)

    # Compare against dense continuation of each request independently.
    ext_a = jnp.concatenate([toks_a, tok[:1]])
    ext_b = jnp.concatenate([toks_b, tok[1:]])
    assert int(nxt[0]) == int(jnp.argmax(dense_forward(CFG, params, ext_a)[S]))
    assert int(nxt[1]) == int(jnp.argmax(dense_forward(CFG, params, ext_b)[S]))


def test_inactive_slots_do_not_corrupt_cache(params):
    """A padded (inactive) slot must only ever write the null block 0."""
    rng = np.random.default_rng(9)
    kc, vc = empty_caches()
    bt = jnp.asarray([3, 5, 0, 0, 0, 0, 0, 0], jnp.int32)
    toks = random_tokens(rng, 8)
    _, kc, vc = prefill_chunk(CFG, params, kc, vc, toks, 0, 8, bt)
    snapshot_k = np.asarray(kc)

    btab = jnp.zeros((2, 8), jnp.int32).at[0].set(bt)
    tok = jnp.asarray([int(toks[0]), 77], jnp.int32)  # slot 1 inactive
    positions = jnp.asarray([8, 50], jnp.int32)
    cl = jnp.asarray([9, 0], jnp.int32)
    _, kc, vc = decode_step(CFG, params, kc, vc, tok, positions, btab, cl)
    after_k = np.asarray(kc)
    # Only block 3 (slot 0's write, position 8 -> block idx 1 -> bt[1]=5)
    # and the null block 0 may change.
    changed = {
        b for b in range(CFG.num_blocks)
        if not np.array_equal(snapshot_k[:, b], after_k[:, b])
    }
    assert changed <= {0, 5}, changed


def test_multi_turn_prefix_reuse(params):
    """Turn 2's prefill on top of turn 1's cached KV matches a dense run
    over the concatenated conversation."""
    rng = np.random.default_rng(12)
    t1, t2 = random_tokens(rng, 8), random_tokens(rng, 8)
    conv = jnp.concatenate([t1, t2])
    dense_logits = dense_forward(CFG, params, conv)
    kc, vc = empty_caches()
    bt = jnp.asarray([2, 6, 0, 0, 0, 0, 0, 0], jnp.int32)
    _, kc, vc = prefill_chunk(CFG, params, kc, vc, t1, 0, 8, bt)
    nt, kc, vc = prefill_chunk(CFG, params, kc, vc, t2, 8, 8, bt)
    assert int(nt) == int(jnp.argmax(dense_logits[15]))
