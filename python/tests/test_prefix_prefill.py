"""Kernel-vs-oracle correctness: prefix_prefill (multi-turn prefill)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import prefix_prefill
from compile.kernels.ref import ref_prefix_prefill

SET = dict(deadline=None, max_examples=10, print_blob=True)


def make_case(rng, T, H, KH, D, NB, BS, MAXB):
    q = jnp.asarray(rng.standard_normal((T, H, D)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((T, KH, D)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((T, KH, D)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((NB, BS, KH, D)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((NB, BS, KH, D)), jnp.float32)
    bt = jnp.asarray(rng.permutation(NB)[:MAXB], jnp.int32)
    return q, kn, vn, kc, vc, bt


def check(T, H, KH, D, NB, BS, MAXB, pfx, ta, seed=0, rtol=3e-5):
    rng = np.random.default_rng(seed)
    q, kn, vn, kc, vc, bt = make_case(rng, T, H, KH, D, NB, BS, MAXB)
    out = prefix_prefill(q, kn, vn, kc, vc, bt, pfx, ta, block_size=BS)
    ref = ref_prefix_prefill(q, kn, vn, kc, vc, bt, pfx, ta, block_size=BS)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=rtol, atol=rtol)


@settings(**SET)
@given(
    T=st.sampled_from([4, 8, 16]),
    KH=st.integers(1, 4),
    G=st.sampled_from([1, 2]),
    D=st.sampled_from([8, 16, 32]),
    BS=st.sampled_from([4, 8]),
    data=st.data(),
)
def test_prefix_prefill_matches_ref(T, KH, G, D, BS, data):
    H = KH * G
    MAXB = 8
    NB = MAXB + 2
    pfx = data.draw(st.integers(0, (MAXB - 2) * BS))
    ta = data.draw(st.integers(1, T))
    check(T, H, KH, D, NB, BS, MAXB, pfx, ta, seed=data.draw(st.integers(0, 2**16)))


def test_no_prefix_pure_causal():
    """prefix_len = 0 degenerates to plain causal self-attention."""
    check(16, 4, 2, 16, 10, 8, 8, pfx=0, ta=16)


def test_single_new_token_equals_decode_shape():
    """ta = 1: the turn's first decode-like step through the prefill path."""
    check(8, 2, 2, 8, 10, 8, 8, pfx=24, ta=1)


def test_prefix_at_block_boundary():
    check(8, 2, 2, 8, 10, 8, 8, pfx=16, ta=8)


def test_prefix_mid_block():
    check(8, 2, 2, 8, 10, 8, 8, pfx=13, ta=5)


def test_padded_rows_zeroed():
    rng = np.random.default_rng(3)
    q, kn, vn, kc, vc, bt = make_case(rng, 8, 2, 2, 8, 10, 8, 8)
    out = prefix_prefill(q, kn, vn, kc, vc, bt, 5, 3, block_size=8)
    assert np.allclose(np.asarray(out[3:]), 0.0)


def test_padding_rows_do_not_leak_into_valid_rows():
    """Changing padded-row inputs must not change valid-row outputs."""
    rng = np.random.default_rng(4)
    q, kn, vn, kc, vc, bt = make_case(rng, 8, 2, 2, 8, 10, 8, 8)
    out1 = prefix_prefill(q, kn, vn, kc, vc, bt, 9, 4, block_size=8)
    q2 = np.asarray(q).copy()
    kn2 = np.asarray(kn).copy()
    q2[4:] = 99.0
    kn2[4:] = -99.0
    out2 = prefix_prefill(
        jnp.asarray(q2), jnp.asarray(kn2), vn, kc, vc, bt, 9, 4, block_size=8
    )
    np.testing.assert_allclose(np.asarray(out1[:4]), np.asarray(out2[:4]), rtol=1e-6)
