"""AOT pipeline sanity: HLO text artifacts, params manifest, meta file."""

import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest

from compile import aot
from compile.config import DEFAULT, ModelConfig
from compile.model import param_spec

TINY = ModelConfig(
    vocab=64, d_model=32, n_layers=1, n_heads=2, n_kv_heads=2, head_dim=16,
    d_ff=64, max_seq=32, num_blocks=8, block_size=8, max_blocks_per_seq=4,
    prefill_chunk=8, decode_batch_sizes=(1, 2),
)


def entry_param_count(text):
    """Count parameters of the ENTRY computation only (fused
    subcomputations declare their own `parameter(N)` lines)."""
    entry = text[text.index("ENTRY") :]
    entry = entry[: entry.index("\n}")]
    return entry.count("parameter(")


def test_decode_hlo_text_is_parseable_hlo(tmp_path):
    text = aot.lower_decode(TINY, 2)
    assert "ENTRY" in text and "HloModule" in text
    # params + 2 caches + 4 dynamic operands
    n_inputs = len(param_spec(TINY)) + 2 + 4
    assert entry_param_count(text) == n_inputs


def test_prefill_hlo_text_is_parseable_hlo():
    text = aot.lower_prefill(TINY)
    assert "ENTRY" in text and "HloModule" in text
    n_inputs = len(param_spec(TINY)) + 2 + 4
    assert entry_param_count(text) == n_inputs


def test_params_bin_size(tmp_path):
    n = aot.write_params(TINY, str(tmp_path), seed=0)
    expect = sum(int(np.prod(s)) for _, s in param_spec(TINY)) * 4
    assert n == expect


def test_params_bin_deterministic(tmp_path):
    aot.write_params(TINY, str(tmp_path), seed=3)
    a = (tmp_path / "params.bin").read_bytes()
    aot.write_params(TINY, str(tmp_path), seed=3)
    b = (tmp_path / "params.bin").read_bytes()
    assert a == b


def test_meta_roundtrip(tmp_path):
    aot.write_meta(TINY, str(tmp_path))
    lines = (tmp_path / "model_meta.txt").read_text().splitlines()
    assert lines[0] == "fastswitch-model-meta v1"
    kv = dict(
        line.split(" ", 1) for line in lines[1:] if not line.startswith("tensor")
    )
    assert int(kv["vocab"]) == TINY.vocab
    assert int(kv["block_size"]) == TINY.block_size
    assert kv["decode_batch_sizes"] == "1,2"
    tensors = [line.split() for line in lines if line.startswith("tensor")]
    assert len(tensors) == len(param_spec(TINY))
    for (_, name, dims), (sname, sshape) in zip(tensors, param_spec(TINY)):
        assert name == sname
        assert tuple(int(d) for d in dims.split("x")) == tuple(sshape)


@pytest.mark.skipif(
    not os.path.exists(Path(__file__).resolve().parents[2] / "artifacts" / ".stamp"),
    reason="run `make artifacts` first",
)
def test_shipped_artifacts_consistent():
    root = Path(__file__).resolve().parents[2] / "artifacts"
    cfg = DEFAULT
    for b in cfg.decode_batch_sizes:
        text = (root / f"decode_b{b}.hlo.txt").read_text()
        assert "ENTRY" in text
    expect = sum(int(np.prod(s)) for _, s in param_spec(cfg)) * 4
    assert (root / "params.bin").stat().st_size == expect
