"""L2: paged-KV transformer for real execution on the serving path.

A decoder-only transformer whose attention reads/writes a vLLM-style paged
KV cache through the L1 Pallas kernels. Two entry points, both AOT-lowered
to HLO text by :mod:`compile.aot` and executed from the Rust runtime:

- :func:`decode_step` — one token per running request (the decode
  iteration of continuous batching).
- :func:`prefill_chunk` — one chunk of a single request's prompt, with
  prefix reuse (previous turns' KV already in the cache).

Contracts with the Rust coordinator (rust/src/runtime/):

- Block 0 of the paged cache is the reserved *null block*: padded batch
  slots and padded block-table entries point at it, so scatters from
  inactive slots land there harmlessly. The Rust allocator never hands
  out block 0 in real mode.
- ``context_lens[b]`` counts tokens *including* the one being decoded;
  inactive slots have ``context_lens[b] == 0`` and ``token_ids[b] == 0``.
- The caches are carried functionally: each call returns the updated
  caches, which the runtime feeds to the next call (kept device-resident
  as PJRT buffers on the Rust side).

Weights are an explicit, ordered list of arrays (see param_spec) so the
Rust side can stream them from ``artifacts/params.bin`` without pytree
guesswork.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .kernels import paged_attention, prefix_prefill


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list — the params.bin layout contract."""
    spec = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos_embed", (cfg.max_seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.n_heads * cfg.head_dim)),
            (p + "wk", (cfg.d_model, cfg.n_kv_heads * cfg.head_dim)),
            (p + "wv", (cfg.d_model, cfg.n_kv_heads * cfg.head_dim)),
            (p + "wo", (cfg.n_heads * cfg.head_dim, cfg.d_model)),
            (p + "ln2", (cfg.d_model,)),
            (p + "w_in", (cfg.d_model, cfg.d_ff)),
            (p + "w_gate", (cfg.d_model, cfg.d_ff)),
            (p + "w_out", (cfg.d_ff, cfg.d_model)),
        ]
    spec += [
        ("ln_f", (cfg.d_model,)),
        ("unembed", (cfg.d_model, cfg.vocab)),
    ]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0):
    """Random (but well-scaled) weights as the ordered list of arrays."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in param_spec(cfg):
        if name.endswith(("ln1", "ln2", "ln_f")):
            arr = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else cfg.d_model
            arr = rng.standard_normal(shape).astype(np.float32) / np.sqrt(fan_in)
        params.append(jnp.asarray(arr))
    return params


def params_by_name(cfg: ModelConfig, params):
    return dict(zip([n for n, _ in param_spec(cfg)], params))


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def _rmsnorm(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _mlp(x, w_in, w_gate, w_out):
    return (jax.nn.silu(x @ w_gate) * (x @ w_in)) @ w_out


def _scatter_kv(cache_l, blk_ids, offsets, kv):
    """Write per-row KV vectors into the paged cache.

    cache_l: [NB, BS, KH, D]; blk_ids/offsets: [R] int32; kv: [R, KH, D].
    Rows whose block id is 0 target the null block (padding contract).
    """
    return cache_l.at[blk_ids, offsets].set(kv)


# --------------------------------------------------------------------------
# Decode step
# --------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params, k_cache, v_cache, token_ids,
                positions, block_tables, context_lens):
    """One decode iteration for a (padded) batch.

    k_cache/v_cache: [L, NB, BS, KH, D]
    token_ids:       [B] int32
    positions:       [B] int32 (0-based position of the token being decoded)
    block_tables:    [B, MAXB] int32
    context_lens:    [B] int32 (includes the current token; 0 = inactive)
    returns (next_token_ids [B] int32, k_cache, v_cache)
    """
    P = params_by_name(cfg, params)
    B = token_ids.shape[0]
    BS = cfg.block_size
    H, KH, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    active = context_lens > 0
    safe_pos = jnp.where(active, positions, 0)
    x = P["embed"][token_ids] + P["pos_embed"][safe_pos]  # [B, d]

    rows = jnp.arange(B)
    blk_ids = jnp.where(active, block_tables[rows, safe_pos // BS], 0)
    offsets = safe_pos % BS
    # The kernel needs ctx >= 1 even on padded slots (they attend into the
    # null block and their output is discarded).
    kernel_cl = jnp.maximum(context_lens, 1)

    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = _rmsnorm(x, P[p + "ln1"])
        q = (h @ P[p + "wq"]).reshape(B, H, D)
        k = (h @ P[p + "wk"]).reshape(B, KH, D)
        v = (h @ P[p + "wv"]).reshape(B, KH, D)
        k_cache = k_cache.at[i].set(_scatter_kv(k_cache[i], blk_ids, offsets, k))
        v_cache = v_cache.at[i].set(_scatter_kv(v_cache[i], blk_ids, offsets, v))
        attn = paged_attention(
            q, k_cache[i], v_cache[i], block_tables, kernel_cl, block_size=BS
        )
        x = x + attn.reshape(B, H * D) @ P[p + "wo"]
        x = x + _mlp(_rmsnorm(x, P[p + "ln2"]), P[p + "w_in"], P[p + "w_gate"],
                     P[p + "w_out"])

    logits = _rmsnorm(x, P["ln_f"]) @ P["unembed"]  # [B, vocab]
    next_ids = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    next_ids = jnp.where(active, next_ids, 0)
    return next_ids, k_cache, v_cache


# --------------------------------------------------------------------------
# Prefill (chunked, with prefix reuse)
# --------------------------------------------------------------------------

def prefill_chunk(cfg: ModelConfig, params, k_cache, v_cache, token_ids,
                  prefix_len, t_actual, block_table):
    """Prefill one chunk of one request's prompt on top of a reused prefix.

    k_cache/v_cache: [L, NB, BS, KH, D]
    token_ids:   [T] int32 (rows >= t_actual are padding)
    prefix_len:  scalar int32 — tokens already in the cache (previous turns
                 and/or previously prefilled chunks of this prompt)
    t_actual:    scalar int32 — valid tokens in this chunk (>= 1)
    block_table: [MAXB] int32
    returns (next_token_id scalar int32, k_cache, v_cache)

    The returned token is the greedy continuation after the chunk's last
    valid token — only meaningful for the prompt's final chunk.
    """
    P = params_by_name(cfg, params)
    T = token_ids.shape[0]
    BS = cfg.block_size
    H, KH, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    idx = jnp.arange(T)
    valid = idx < t_actual
    positions = prefix_len + idx
    safe_pos = jnp.where(valid, positions, 0)
    x = P["embed"][token_ids] + P["pos_embed"][safe_pos]  # [T, d]

    blk_ids = jnp.where(valid, block_table[safe_pos // BS], 0)
    offsets = safe_pos % BS

    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = _rmsnorm(x, P[p + "ln1"])
        q = (h @ P[p + "wq"]).reshape(T, H, D)
        k = (h @ P[p + "wk"]).reshape(T, KH, D)
        v = (h @ P[p + "wv"]).reshape(T, KH, D)
        k_cache = k_cache.at[i].set(_scatter_kv(k_cache[i], blk_ids, offsets, k))
        v_cache = v_cache.at[i].set(_scatter_kv(v_cache[i], blk_ids, offsets, v))
        attn = prefix_prefill(
            q, k, v, k_cache[i], v_cache[i], block_table, prefix_len, t_actual,
            block_size=BS,
        )
        x = x + attn.reshape(T, H * D) @ P[p + "wo"]
        x = x + _mlp(_rmsnorm(x, P[p + "ln2"]), P[p + "w_in"], P[p + "w_gate"],
                     P[p + "w_out"])

    last = _rmsnorm(x[t_actual - 1], P["ln_f"])
    logits = last @ P["unembed"]
    return jnp.argmax(logits).astype(jnp.int32), k_cache, v_cache


# --------------------------------------------------------------------------
# Dense reference (for tests): same model, ordinary causal attention
# --------------------------------------------------------------------------

def dense_forward(cfg: ModelConfig, params, token_ids):
    """Run the model densely over a full sequence; returns logits of every
    position. Used by tests to validate the paged decode/prefill paths
    end-to-end."""
    P = params_by_name(cfg, params)
    S = token_ids.shape[0]
    H, KH, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KH
    x = P["embed"][token_ids] + P["pos_embed"][jnp.arange(S)]
    mask = jnp.tril(jnp.ones((S, S), bool))
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = _rmsnorm(x, P[p + "ln1"])
        q = (h @ P[p + "wq"]).reshape(S, KH, G, D)
        k = (h @ P[p + "wk"]).reshape(S, KH, D)
        v = (h @ P[p + "wv"]).reshape(S, KH, D)
        s = jnp.einsum("tkgd,skd->tkgs", q, k) / (D**0.5)
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("tkgs,skd->tkgd", pr, v).reshape(S, H * D)
        x = x + attn @ P[p + "wo"]
        x = x + _mlp(_rmsnorm(x, P[p + "ln2"]), P[p + "w_in"], P[p + "w_gate"],
                     P[p + "w_out"])
    return _rmsnorm(x, P["ln_f"]) @ P["unembed"]
