"""AOT pipeline: lower the L2 model to HLO *text* artifacts for Rust.

Python runs ONCE, at build time (`make artifacts`); the Rust binary is
self-contained afterwards. Interchange format is HLO text, NOT
``.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Emitted artifacts (``artifacts/``):

- ``decode_b{B}.hlo.txt``  — one decode iteration at batch size B, for
  each B in ``cfg.decode_batch_sizes``. The runtime picks the smallest
  variant that fits the scheduled batch and pads.
- ``prefill_t{T}.hlo.txt`` — one prefill chunk (T tokens) with prefix
  reuse for a single request.
- ``params.bin``           — raw little-endian f32 weights, in
  ``model.param_spec`` order.
- ``model_meta.txt``       — line-based config + tensor manifest parsed
  by ``rust/src/runtime/meta.rs``.

Input convention of every HLO entry computation: the flattened jit
arguments in order — params[0..N), k_cache, v_cache, then the per-call
dynamic operands. Outputs are lowered with ``return_tuple=True``.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import DEFAULT, ModelConfig
from .model import decode_step, init_params, param_spec, prefill_chunk


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _cache_struct(cfg: ModelConfig):
    shape = (cfg.n_layers, cfg.num_blocks, cfg.block_size, cfg.n_kv_heads,
             cfg.head_dim)
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _param_structs(cfg: ModelConfig):
    return [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_spec(cfg)]


def lower_decode(cfg: ModelConfig, batch: int) -> str:
    fn = functools.partial(decode_step, cfg)
    i32 = jnp.int32
    lowered = jax.jit(fn).lower(
        _param_structs(cfg),
        _cache_struct(cfg),
        _cache_struct(cfg),
        jax.ShapeDtypeStruct((batch,), i32),  # token_ids
        jax.ShapeDtypeStruct((batch,), i32),  # positions
        jax.ShapeDtypeStruct((batch, cfg.max_blocks_per_seq), i32),
        jax.ShapeDtypeStruct((batch,), i32),  # context_lens
    )
    return to_hlo_text(lowered)


def lower_prefill(cfg: ModelConfig) -> str:
    fn = functools.partial(prefill_chunk, cfg)
    i32 = jnp.int32
    lowered = jax.jit(fn).lower(
        _param_structs(cfg),
        _cache_struct(cfg),
        _cache_struct(cfg),
        jax.ShapeDtypeStruct((cfg.prefill_chunk,), i32),  # token_ids
        jax.ShapeDtypeStruct((), i32),  # prefix_len
        jax.ShapeDtypeStruct((), i32),  # t_actual
        jax.ShapeDtypeStruct((cfg.max_blocks_per_seq,), i32),
    )
    return to_hlo_text(lowered)


def write_params(cfg: ModelConfig, out_dir: str, seed: int) -> int:
    params = init_params(cfg, seed=seed)
    path = os.path.join(out_dir, "params.bin")
    with open(path, "wb") as f:
        for arr in params:
            f.write(np.asarray(arr, dtype="<f4").tobytes())
    return os.path.getsize(path)


def write_meta(cfg: ModelConfig, out_dir: str) -> None:
    lines = ["fastswitch-model-meta v1"]
    for key in ("vocab", "d_model", "n_layers", "n_heads", "n_kv_heads",
                "head_dim", "d_ff", "max_seq", "num_blocks", "block_size",
                "max_blocks_per_seq", "prefill_chunk"):
        lines.append(f"{key} {getattr(cfg, key)}")
    lines.append(
        "decode_batch_sizes " + ",".join(str(b) for b in cfg.decode_batch_sizes)
    )
    for name, shape in param_spec(cfg):
        lines.append("tensor " + name + " " + "x".join(str(d) for d in shape))
    with open(os.path.join(out_dir, "model_meta.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def write_golden(cfg: ModelConfig, out_dir: str, seed: int, n_decode: int = 20) -> None:
    """Golden transcript for the Rust runtime parity test: prefill a fixed
    prompt through the same decode/prefill functions that were lowered,
    then decode greedily. The Rust integration test must reproduce every
    token through PJRT."""
    import numpy as np

    from .model import decode_step, init_params, prefill_chunk

    params = init_params(cfg, seed=seed)
    rng = np.random.default_rng(1234)
    prompt = rng.integers(1, cfg.vocab, cfg.prefill_chunk + 7).astype(np.int32)

    shape = (cfg.n_layers, cfg.num_blocks, cfg.block_size, cfg.n_kv_heads,
             cfg.head_dim)
    kc = jnp.zeros(shape, jnp.float32)
    vc = jnp.zeros(shape, jnp.float32)
    # Block table: blocks 1.. (block 0 reserved).
    bt = jnp.asarray(
        [i + 1 for i in range(cfg.max_blocks_per_seq)], jnp.int32
    )

    # Chunked prefill.
    T = cfg.prefill_chunk
    pos = 0
    next_tok = None
    while pos < len(prompt):
        chunk = prompt[pos : pos + T]
        ta = len(chunk)
        padded = np.zeros(T, np.int32)
        padded[:ta] = chunk
        next_tok, kc, vc = prefill_chunk(
            cfg, params, kc, vc, jnp.asarray(padded), pos, ta, bt
        )
        pos += ta

    out_tokens = [int(next_tok)]
    ctx = len(prompt) + 1
    btab = jnp.zeros((1, cfg.max_blocks_per_seq), jnp.int32).at[0].set(bt)
    for _ in range(n_decode - 1):
        tok = jnp.asarray([out_tokens[-1]], jnp.int32)
        positions = jnp.asarray([ctx - 1], jnp.int32)
        cl = jnp.asarray([ctx], jnp.int32)
        nxt, kc, vc = decode_step(cfg, params, kc, vc, tok, positions, btab, cl)
        out_tokens.append(int(nxt[0]))
        ctx += 1

    with open(os.path.join(out_dir, "golden.txt"), "w") as f:
        f.write("prompt " + ",".join(str(t) for t in prompt) + "\n")
        f.write("continuation " + ",".join(str(t) for t in out_tokens) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = DEFAULT
    os.makedirs(args.out_dir, exist_ok=True)

    for b in cfg.decode_batch_sizes:
        text = lower_decode(cfg, b)
        path = os.path.join(args.out_dir, f"decode_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    text = lower_prefill(cfg)
    path = os.path.join(args.out_dir, f"prefill_t{cfg.prefill_chunk}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")

    n = write_params(cfg, args.out_dir, args.seed)
    print(f"wrote params.bin ({n} bytes)")
    write_meta(cfg, args.out_dir)
    print("wrote model_meta.txt")
    write_golden(cfg, args.out_dir, args.seed)
    print("wrote golden.txt")


if __name__ == "__main__":
    main()
