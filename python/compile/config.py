"""Model / cache configuration shared by the L1 kernels, L2 model and AOT.

The Rust coordinator reads the same values from ``artifacts/model_meta.txt``
(emitted by :mod:`compile.aot`), so this file is the single source of truth
for the real-execution model.

The model is intentionally small: the paper's SLO dynamics come from the
scheduler / swap subsystem, not model quality (see DESIGN.md, hardware
substitution table). Sizes are chosen so a full end-to-end serve run on the
CPU PJRT backend finishes in seconds, while exercising exactly the same
paged-KV data path a large model would.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """Configuration of the paged-KV transformer used for real execution."""

    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    max_seq: int = 1024

    # Paged KV cache geometry (mirrors vLLM: block_size tokens per block).
    num_blocks: int = 256
    block_size: int = 16
    # Max blocks per sequence = max_seq / block_size.
    max_blocks_per_seq: int = 64

    # AOT-compiled shape variants.
    decode_batch_sizes: tuple = (1, 4, 8)
    prefill_chunk: int = 64

    def __post_init__(self):
        assert self.d_model == self.n_heads * self.head_dim
        assert self.n_heads % self.n_kv_heads == 0
        assert self.max_seq == self.max_blocks_per_seq * self.block_size
        assert self.max_seq <= self.num_blocks * self.block_size


DEFAULT = ModelConfig()
