"""L1 Pallas kernel: prefill-with-prefix for multi-turn conversations.

The paper integrates lightllm's triton "prefill with prefix" kernel so a
new conversation turn attends over the previous turns' KV (already resident
in the paged cache — possibly just swapped back in from CPU) without
recomputing it. This is the TPU/Pallas rethink of that kernel: a single
program per request streams the paged prefix KV block-by-block (online
softmax, same as the decode kernel) and then applies the causally-masked
new-token block in one MXU-shaped contraction.

VMEM footprint per program: one KV block pair + the new-token tile +
accumulators ≈ (2·BS·KH·D + 3·T·KH·D + T·KH·G·D) · 4 B; with the default
T=64 geometry ≈ 330 KB « 16 MB, leaving room to scale T or D.

interpret=True: see paged_attention.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _prefix_prefill_kernel(
    q_ref,  # [T, H, D]
    kn_ref,  # [T, KH, D]  new-token keys
    vn_ref,  # [T, KH, D]
    bt_ref,  # [MAXB] int32
    pfx_ref,  # [1] int32   prefix length
    ta_ref,  # [1] int32   actual new-token count
    k_ref,  # [NB, BS, KH, D] paged prefix cache
    v_ref,  # [NB, BS, KH, D]
    o_ref,  # [T, H, D]
    *,
    block_size: int,
    n_kv_heads: int,
):
    T, H, D = q_ref.shape
    KH = n_kv_heads
    G = H // KH
    BS = block_size
    scale = 1.0 / (D**0.5)

    q = q_ref[...].reshape(T, KH, G, D).astype(jnp.float32)
    pfx = pfx_ref[0]
    ta = ta_ref[0]

    # ---- Stage 1: stream the paged prefix, online softmax over all T
    # queries at once (no causal mask: every new token sees the whole
    # prefix).
    def body(i, carry):
        m, l, acc = carry  # [T,KH,G], [T,KH,G], [T,KH,G,D]
        blk = bt_ref[i]
        k = pl.load(k_ref, (pl.dslice(blk, 1),))[0].astype(jnp.float32)  # [BS,KH,D]
        v = pl.load(v_ref, (pl.dslice(blk, 1),))[0].astype(jnp.float32)
        s = jnp.einsum("tkgd,skd->tkgs", q, k) * scale  # [T,KH,G,BS]
        pos = i * BS + jnp.arange(BS)
        s = jnp.where((pos < pfx)[None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("tkgs,skd->tkgd", p, v)
        return m_new, l_new, acc_new

    n_pfx_blocks = (pfx + BS - 1) // BS
    m0 = jnp.full((T, KH, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((T, KH, G), jnp.float32)
    acc0 = jnp.zeros((T, KH, G, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_pfx_blocks, body, (m0, l0, acc0))

    # ---- Stage 2: new-token self-attention block with causal mask,
    # merged into the same online softmax state.
    kn = kn_ref[...].astype(jnp.float32)  # [T,KH,D]
    vn = vn_ref[...].astype(jnp.float32)
    s = jnp.einsum("tkgd,skd->tkgs", q, kn) * scale  # [T,KH,G,T]
    t_idx = jnp.arange(T)
    causal = t_idx[None, :] <= t_idx[:, None]  # key j visible to query i
    valid = t_idx[None, :] < ta
    s = jnp.where((causal & valid)[:, None, None, :], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    corr = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l = l * corr + p.sum(axis=-1)
    acc = acc * corr[..., None] + jnp.einsum("tkgs,skd->tkgd", p, vn)

    out = acc / l[..., None]  # [T,KH,G,D]
    out = jnp.where((t_idx < ta)[:, None, None, None], out, 0.0)
    o_ref[...] = out.reshape(T, H, D).astype(o_ref.dtype)


def prefix_prefill(
    q, k_new, v_new, k_cache, v_cache, block_table, prefix_len, t_actual, *, block_size
):
    """Prefill-with-prefix attention for one request.

    Shapes match :func:`compile.kernels.ref.ref_prefix_prefill`;
    ``prefix_len`` / ``t_actual`` are scalar int32 arrays (traced).
    """
    T, H, D = q.shape
    NB, BS, KH, _ = k_cache.shape
    assert BS == block_size
    MAXB = block_table.shape[0]

    kernel = functools.partial(
        _prefix_prefill_kernel, block_size=block_size, n_kv_heads=KH
    )
    pfx = jnp.asarray(prefix_len, jnp.int32).reshape(1)
    ta = jnp.asarray(t_actual, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((T, H, D), lambda i: (0, 0, 0)),
            pl.BlockSpec((T, KH, D), lambda i: (0, 0, 0)),
            pl.BlockSpec((T, KH, D), lambda i: (0, 0, 0)),
            pl.BlockSpec((MAXB,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((NB, BS, KH, D), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((NB, BS, KH, D), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((T, H, D), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, H, D), q.dtype),
        interpret=True,
    )(q, k_new, v_new, block_table, pfx, ta, k_cache, v_cache)
