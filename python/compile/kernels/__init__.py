"""L1: Pallas kernels for the serving hot-spots.

- paged_attention: decode-time attention over a vLLM-style block table.
- prefix_prefill: prefill-with-prefix for multi-turn conversations (the
  lightllm kernel the paper integrates), rethought for Pallas/TPU.
- ref: pure-jnp oracles used by the pytest suite.
"""

from .paged_attention import paged_attention
from .prefix_prefill import prefix_prefill

__all__ = ["paged_attention", "prefix_prefill"]
