"""L1 Pallas kernel: decode-time paged attention over a vLLM block table.

One query token per request; KV lives in a paged cache indexed through a
per-request block table. This is the serving hot-spot: every decode
iteration of every running request goes through this kernel.

TPU adaptation of the paper's CUDA data path (DESIGN.md
§Hardware-Adaptation): instead of one CUDA thread block per (request,
kv-split) with shared-memory staging, we run a Pallas grid over requests;
each program streams the request's KV blocks HBM→VMEM and maintains an
online-softmax accumulator in registers/VMEM. The q·kᵀ and p·v contractions
are shaped to land on the MXU ([BS, D] x [D, G·KH] tiles). VMEM footprint
per program = one KV block pair + accumulator:
    2·BS·KH·D·4B + KH·G·D·4B ≈ 2·16·4·64·4 + 4·1·64·4 ≈ 33 KB  « 16 MB.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; the interpret path lowers to plain HLO, which is what the
Rust runtime executes (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_attention_kernel(
    q_ref,  # [1, H, D]
    bt_ref,  # [1, MAXB] int32
    cl_ref,  # [1] int32
    k_ref,  # [NB, BS, KH, D] (full cache)
    v_ref,  # [NB, BS, KH, D]
    o_ref,  # [1, H, D]
    *,
    block_size: int,
    n_kv_heads: int,
):
    H, D = q_ref.shape[1], q_ref.shape[2]
    KH = n_kv_heads
    G = H // KH
    BS = block_size
    scale = 1.0 / (D**0.5)
    max_blocks = bt_ref.shape[1]

    q = q_ref[0].reshape(KH, G, D).astype(jnp.float32)
    ctx = cl_ref[0]

    def body(i, carry):
        m, l, acc = carry  # [KH,G], [KH,G], [KH,G,D]
        blk = bt_ref[0, i]
        # HBM→VMEM stage of one KV block (dynamic gather through the block
        # table — the Pallas analogue of vLLM's per-block pointer chase).
        k = pl.load(k_ref, (pl.dslice(blk, 1),))[0].astype(jnp.float32)  # [BS,KH,D]
        v = pl.load(v_ref, (pl.dslice(blk, 1),))[0].astype(jnp.float32)
        # MXU contraction: scores[KH,G,BS]
        s = jnp.einsum("kgd,skd->kgs", q, k) * scale
        # Mask token slots beyond the context length.
        pos = i * BS + jnp.arange(BS)
        s = jnp.where((pos < ctx)[None, None, :], s, NEG_INF)
        # Online softmax update.
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])  # [KH,G,BS]
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("kgs,skd->kgd", p, v)
        return m_new, l_new, acc_new

    # Only walk blocks that actually hold context; later block-table
    # entries may be stale/null.
    n_blocks = (ctx + BS - 1) // BS
    m0 = jnp.full((KH, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((KH, G), jnp.float32)
    acc0 = jnp.zeros((KH, G, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    del max_blocks
    out = acc / l[..., None]
    o_ref[0] = out.reshape(H, D).astype(o_ref.dtype)


def paged_attention(q, k_cache, v_cache, block_tables, context_lens, *, block_size):
    """Paged attention for a batch of single-token (decode) queries.

    Shapes match :func:`compile.kernels.ref.ref_paged_attention`.
    """
    B, H, D = q.shape
    NB, BS, KH, _ = k_cache.shape
    assert BS == block_size
    MAXB = block_tables.shape[1]

    kernel = functools.partial(
        _paged_attention_kernel, block_size=block_size, n_kv_heads=KH
    )
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, MAXB), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            # Full-cache residency: the block table's indirection is dynamic,
            # so the cache cannot be tiled by the grid; on real TPU this is
            # the HBM-resident operand that pl.load streams per-block.
            pl.BlockSpec((NB, BS, KH, D), lambda b: (0, 0, 0, 0)),
            pl.BlockSpec((NB, BS, KH, D), lambda b: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=True,
    )(q, block_tables, context_lens, k_cache, v_cache)
