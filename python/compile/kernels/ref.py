"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks the kernels against:
dense, gather-based attention with no paging tricks, written for clarity
rather than speed.
"""

import jax.numpy as jnp


def gather_paged_kv(cache, block_table, ctx_len, block_size):
    """Gather a request's KV from the paged cache into a dense array.

    cache:        [num_blocks, block_size, n_kv_heads, head_dim]
    block_table:  [max_blocks_per_seq] int32 (entries past the context are
                  arbitrary — typically 0, the reserved null block)
    ctx_len:      python int — number of valid tokens
    returns       [ctx_len, n_kv_heads, head_dim]
    """
    n_blocks = (ctx_len + block_size - 1) // block_size
    parts = [cache[block_table[i]] for i in range(n_blocks)]
    dense = jnp.concatenate(parts, axis=0) if parts else cache[:0, 0]
    return dense[:ctx_len]


def ref_paged_attention(q, k_cache, v_cache, block_tables, context_lens, *, block_size):
    """Decode-time paged attention, one query token per request.

    q:            [B, n_heads, head_dim]
    k_cache:      [num_blocks, block_size, n_kv_heads, head_dim]
    v_cache:      same shape as k_cache
    block_tables: [B, max_blocks_per_seq] int32
    context_lens: [B] int32 (>=1; the query token's own KV is already in
                  the cache, mirroring the vLLM decode contract)
    returns       [B, n_heads, head_dim]
    """
    B, H, D = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    scale = 1.0 / (D**0.5)
    outs = []
    for b in range(B):
        ctx = int(context_lens[b])
        k = gather_paged_kv(k_cache, block_tables[b], ctx, block_size)  # [ctx, KH, D]
        v = gather_paged_kv(v_cache, block_tables[b], ctx, block_size)
        # GQA: head h attends with kv head h // G
        qh = q[b].reshape(KH, G, D)
        scores = jnp.einsum("kgd,tkd->kgt", qh, k) * scale  # [KH, G, ctx]
        p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
        p = p / p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("kgt,tkd->kgd", p, v)
        outs.append(o.reshape(H, D))
    return jnp.stack(outs)


def ref_prefix_prefill(
    q, k_new, v_new, k_cache, v_cache, block_table, prefix_len, t_actual, *, block_size
):
    """Prefill-with-prefix attention for a single request.

    q:          [T, n_heads, head_dim]   — new-token queries (rows >= t_actual
                are padding; their output is unspecified and zeroed here)
    k_new:      [T, n_kv_heads, head_dim] — new-token keys
    v_new:      [T, n_kv_heads, head_dim]
    k_cache:    paged prefix KV, [num_blocks, block_size, KH, D]
    block_table:[max_blocks_per_seq] int32
    prefix_len: python int — reused prefix length (tokens already in cache)
    t_actual:   python int — number of valid new tokens (<= T)
    returns     [T, n_heads, head_dim] (rows >= t_actual zeroed)
    """
    T, H, D = q.shape
    KH = k_new.shape[1]
    G = H // KH
    scale = 1.0 / (D**0.5)

    kp = gather_paged_kv(k_cache, block_table, prefix_len, block_size)  # [P, KH, D]
    vp = gather_paged_kv(v_cache, block_table, prefix_len, block_size)
    k_all = jnp.concatenate([kp, k_new[:t_actual]], axis=0)  # [P+t, KH, D]
    v_all = jnp.concatenate([vp, v_new[:t_actual]], axis=0)

    qh = q.reshape(T, KH, G, D)
    scores = jnp.einsum("tkgd,skd->tkgs", qh, k_all) * scale  # [T, KH, G, P+t]
    # Causal mask in the new-token suffix: query i sees the whole prefix
    # plus new tokens 0..i.
    t_idx = jnp.arange(T)[:, None]
    s_idx = jnp.arange(prefix_len + t_actual)[None, :]
    mask = s_idx <= (prefix_len + t_idx)  # [T, P+t]
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("tkgs,skd->tkgd", p, v_all).reshape(T, H, D)
    valid = (jnp.arange(T) < t_actual)[:, None, None]
    return jnp.where(valid, o, 0.0)
