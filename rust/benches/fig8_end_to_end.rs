//! Bench: Fig. 8 end-to-end — the headline ladder + throughput sweep,
//! timed. `cargo bench --bench fig8_end_to_end`.
use fastswitch::coordinator::priority::Pattern;
use fastswitch::exp::{self, runner::Scale};
use fastswitch::util::bench::{bench, section};

fn main() {
    let scale = Scale::quick();
    section("fig8(a-d): tail-latency ablation ladder (llama8b, Markov)");
    let mut rep = None;
    bench("ladder of 4 sims", 0, 1, || {
        rep = Some(exp::fig8::run_latency("llama8b", Pattern::Markov, &scale));
    });
    println!("{}", rep.unwrap().render());

    section("fig8(e-f): throughput sweep");
    let mut rep = None;
    bench("throughput sweep (2 freqs x 2 systems)", 0, 1, || {
        rep = Some(exp::fig8::run_throughput(
            "llama8b",
            Pattern::Markov,
            &[0.02, 0.08],
            &scale,
        ));
    });
    println!("{}", rep.unwrap().render());
}
