//! Hot-path microbenchmarks for the L3 coordinator — the §Perf targets:
//! allocator churn, swap-op segment building, swap-manager submission,
//! scheduler admission, and a full engine iteration. The paper's budget
//! (Fig. 9) is scheduler work < 1% of a ~30 ms iteration, i.e. well
//! under 300 µs per iteration for everything here combined.
use fastswitch::block::{buddy::BlockGroupAllocator, fixed::FixedBlockAllocator, KvAllocator};
use fastswitch::config::{
    DispatchMode, GpuSpec, Granularity, ModelSpec, SwapCostConfig, SwapMode,
};
use fastswitch::coordinator::request::ReqState;
use fastswitch::coordinator::scheduler::{schedule, Candidate, IterBudget};
use fastswitch::sim::link::{Direction, PcieLink};
use fastswitch::swap::engine::{BlockMove, SegmentBuilder};
use fastswitch::swap::manager::SwapManager;
use fastswitch::util::bench::{bench, black_box, section};
use fastswitch::util::rng::Rng;

fn bench_allocators() {
    section("allocators (1556-block A10 space, churn mix)");
    bench("fixed: alloc+release 32 blocks", 10, 2000, || {
        let mut a = FixedBlockAllocator::new(1556);
        for r in 0..8 {
            black_box(a.allocate(r, 32));
        }
        for r in 0..8 {
            black_box(a.release(r));
        }
    });
    bench("buddy: alloc+release 32 blocks", 10, 2000, || {
        let mut a = BlockGroupAllocator::new(1556, 60, 1);
        for r in 0..8 {
            black_box(a.allocate(r, 32));
        }
        for r in 0..8 {
            black_box(a.release(r));
        }
    });
    bench("buddy: churned steady-state step", 5, 200, || {
        let mut a = BlockGroupAllocator::new(1556, 60, 1);
        let mut rng = Rng::new(3);
        let mut live: Vec<u64> = Vec::new();
        let mut next = 0u64;
        for _ in 0..500 {
            if !live.is_empty() && rng.chance(0.45) {
                let i = rng.usize(0, live.len());
                a.release(live.swap_remove(i));
            } else if a.allocate(next, rng.usize(4, 40)).is_some() {
                live.push(next);
                next += 1;
            }
        }
        black_box(live.len());
    });
}

fn bench_segments() {
    section("segment building (63-block preemption, 32 layers)");
    let model = ModelSpec::llama8b();
    let moves: Vec<BlockMove> = (0..63)
        .map(|i| BlockMove { logical: i, gpu: 10 + i, cpu: 100 + i })
        .collect();
    let fixed = SegmentBuilder::new(model.clone(), Granularity::FixedBlock);
    let group = SegmentBuilder::new(
        model,
        Granularity::BlockGroup { init_group_blocks: 60 },
    );
    bench("fixed (2016 segments)", 10, 5000, || {
        black_box(fixed.build(1, Direction::Out, &moves));
    });
    bench("block-group (32 segments)", 10, 5000, || {
        black_box(group.build(1, Direction::Out, &moves));
    });
}

fn bench_swap_manager() {
    section("swap manager submission");
    let model = ModelSpec::llama8b();
    let group = SegmentBuilder::new(
        model,
        Granularity::BlockGroup { init_group_blocks: 60 },
    );
    let moves: Vec<BlockMove> = (0..63)
        .map(|i| BlockMove { logical: i, gpu: 10 + i, cpu: 100 + i })
        .collect();
    bench("submit_swap_out (coalesced, threadpool)", 10, 2000, || {
        let mut m = SwapManager::new(
            SwapMode::Adaptive,
            DispatchMode::ThreadPool { workers: 4 },
            &SwapCostConfig::default(),
            PcieLink::new(GpuSpec::a10()),
        );
        let op = group.build(1, Direction::Out, &moves);
        black_box(m.submit_swap_out(op, 0));
    });
}

fn bench_conflict_detection() {
    // Per-iteration admission cost: detect_conflict(new_blocks) against a
    // pile of in-flight swap-outs. The linear-scan version was
    // O(inflight × blocks × new_blocks); the hashed version must stay
    // well under the 300 µs scheduler budget even with hundreds of fresh
    // blocks.
    section("conflict detection (8 in-flight 63-block ops)");
    let model = ModelSpec::llama8b();
    let group = SegmentBuilder::new(
        model,
        Granularity::BlockGroup { init_group_blocks: 60 },
    );
    let mut m = SwapManager::new(
        SwapMode::Async,
        DispatchMode::ThreadPool { workers: 4 },
        &SwapCostConfig::default(),
        PcieLink::new(GpuSpec::a10()),
    );
    for r in 0..8u64 {
        let moves: Vec<BlockMove> = (0..63)
            .map(|i| BlockMove {
                logical: i,
                gpu: 1000 * r as u32 + i,
                cpu: 100 + i,
            })
            .collect();
        m.submit_swap_out(group.build(r, Direction::Out, &moves), 0);
    }
    // Fresh allocations that never conflict (worst case: full scan).
    let clean: Vec<u32> = (50_000..50_256).collect();
    bench("detect_conflict: 256 clean new blocks", 10, 5000, || {
        black_box(m.detect_conflict(&clean, 0));
    });
    // One conflicting block buried at the end.
    let mut dirty = clean.clone();
    dirty.push(1000 * 7 + 31);
    bench("detect_conflict: 257 blocks, 1 conflict", 10, 5000, || {
        black_box(m.detect_conflict(&dirty, 0));
    });
}

fn bench_scheduler() {
    section("scheduler admission (256 candidates)");
    let cands: Vec<Candidate> = (0..256)
        .map(|i| Candidate {
            id: i,
            priority: (i % 8) as i64,
            turn_arrival: i,
            state: if i % 3 == 0 {
                ReqState::Running
            } else if i % 3 == 1 {
                ReqState::SwappedOut
            } else {
                ReqState::Queued
            },
            blocks_held: if i % 3 == 0 { 60 } else { 0 },
            blocks_needed: 30,
            prefill_remaining: if i % 3 == 2 { 512 } else { 0 },
        })
        .collect();
    bench("schedule() 256 candidates", 10, 5000, || {
        black_box(schedule(&cands, 1556, 32, IterBudget::chunked(544, 512)));
    });
}

fn bench_scheduler_scale() {
    // The PR-10 sublinearity sweep: per-epoch cost of the sort-based
    // oracle vs the incremental candidate index at growing queue
    // depths, with a fixed 32-entry churn per epoch (what a priority
    // update actually dirties). The sort line should grow roughly
    // n log n; the incremental line should stay near-flat.
    use fastswitch::coordinator::queue::{CandidateIndex, EpochScratch};
    section("scheduler scale sweep (32-entry churn per epoch, both paths)");
    for &depth in &[100usize, 1_000, 10_000, 100_000] {
        let mut rng = Rng::new(0x5CA1E ^ depth as u64);
        let mut cands: Vec<Candidate> = (0..depth as u64)
            .map(|id| {
                let running = rng.chance(0.05);
                Candidate {
                    id,
                    priority: rng.usize(0, 8) as i64,
                    turn_arrival: rng.next_u64() % 1_000_000,
                    state: if running {
                        ReqState::Running
                    } else {
                        ReqState::SwappedOut
                    },
                    blocks_held: if running { rng.usize(4, 16) } else { 0 },
                    blocks_needed: if running { rng.usize(0, 2) } else { rng.usize(1, 16) },
                    prefill_remaining: 0,
                }
            })
            .collect();
        let mut index = CandidateIndex::new(1_024);
        for &c in &cands {
            index.upsert(c);
        }
        let mut scratch = EpochScratch::default();
        let iters = (400_000 / depth).clamp(4, 400) as u32;
        bench(&format!("incremental walk, depth {depth}"), 2, iters, || {
            for _ in 0..32 {
                let i = rng.usize(0, depth);
                cands[i].priority = rng.usize(0, 8) as i64;
                index.upsert(cands[i]);
            }
            index.schedule_into(1_024, 64, IterBudget::chunked(256, 64), &mut scratch);
            black_box(scratch.sched.admitted());
        });
        bench(&format!("sort oracle, depth {depth}"), 2, iters, || {
            black_box(schedule(&cands, 1_024, 64, IterBudget::chunked(256, 64)).admitted());
        });
    }
}

fn bench_engine_iteration() {
    section("end-to-end engine (quick sim, wall time per virtual iteration)");
    use fastswitch::config::{EngineConfig, Preset};
    use fastswitch::coordinator::priority::Pattern;
    use fastswitch::exp::runner::{run_sim, Scale};
    let scale = Scale { conversations: 40, ..Scale::quick() };
    let mut iters = 0u64;
    let mut cfgs = vec![EngineConfig::vllm_baseline(), EngineConfig::fastswitch()];
    for cfg in cfgs.drain(..) {
        let label = format!("full sim 40 convs ({})", cfg.label);
        let mut c = cfg;
        c.scheduler.priority_update_freq = 0.04;
        let res = bench(&label, 0, 3, || {
            let out = run_sim(c.clone(), Preset::llama8b_a10(), Pattern::Markov, &scale);
            iters = out.iterations;
            black_box(out.recorder.total_tokens);
        });
        println!(
            "  -> {:.2} µs wall per virtual iteration ({} iterations)",
            res.mean_ns / 1e3 / iters as f64,
            iters
        );
    }
}

fn main() {
    bench_allocators();
    bench_segments();
    bench_swap_manager();
    bench_conflict_detection();
    bench_scheduler();
    bench_scheduler_scale();
    bench_engine_iteration();
}
