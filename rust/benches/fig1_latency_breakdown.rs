//! Bench: regenerate Fig. 1 (latency breakdown across percentiles) and
//! time the run. `cargo bench --bench fig1_latency_breakdown`.
use fastswitch::exp::{self, runner::Scale};
use fastswitch::util::bench::{bench, section};

fn main() {
    section("fig1: latency breakdown (vLLM baseline)");
    let mut last = None;
    bench("fig1 quick-scale sim", 0, 3, || {
        last = Some(exp::fig1::run(&Scale::quick()));
    });
    println!("{}", last.unwrap().render());
}
