//! Bench: chunked-prefill showdown — monolithic vs token-budget chunked
//! admission across chunk sizes on a long-prompt multi-tenant workload,
//! timed. `cargo bench --bench chunked_prefill`.
use fastswitch::exp::{self, runner::Scale};
use fastswitch::util::bench::{bench, section};

fn main() {
    let scale = Scale::quick();
    section(&format!(
        "chunked prefill showdown (chunks {:?}, {} tenants, heavy share {})",
        exp::chunked_prefill::CHUNKS,
        exp::chunked_prefill::N_TENANTS,
        exp::chunked_prefill::HEAVY_SHARE,
    ));
    let mut rep = None;
    bench("monolithic + 3 chunk sizes x 1 sim each", 0, 1, || {
        rep = Some(exp::chunked_prefill::run(&scale));
    });
    println!("{}", rep.unwrap().render());
}
