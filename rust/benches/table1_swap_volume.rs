//! Bench: Table 1 — swap-out volume, traditional vs KV Cache Reuse.
use fastswitch::exp::{self, runner::Scale};
use fastswitch::util::bench::{bench, section};

fn main() {
    section("table1: swap-out volume microbenchmark");
    let mut rep = None;
    bench("table1 (2 sims)", 0, 1, || {
        rep = Some(exp::table1::run(&Scale::quick()));
    });
    println!("{}", rep.unwrap().render());
}
