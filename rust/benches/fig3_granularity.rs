//! Bench: Fig. 3 microbenchmark — fixed-block vs block-group preemption
//! cost at several preemption sizes.
use fastswitch::exp;
use fastswitch::util::bench::{bench, section};

fn main() {
    section("fig3: preemption granularity timeline");
    for blocks in [16, 63, 128, 256] {
        let mut rep = None;
        bench(&format!("build+simulate preemption of {blocks} blocks"), 1, 20, || {
            rep = Some(exp::fig3::run_with_blocks(blocks));
        });
        println!("{}", rep.unwrap().render());
    }
}
