//! Cluster scaling bench: router + replica cost as the fleet grows.
//!
//! Measures (a) wall time per cluster run as replica count scales with a
//! proportionally scaled arrival rate (weak scaling — the router's own
//! overhead must stay negligible next to the engines), and (b) the
//! placement policies head-to-head at a fixed fleet size.
use fastswitch::cluster::{ClusterConfig, PlacementKind, DEFAULT_SPILL_THRESHOLD};
use fastswitch::config::{EngineConfig, Preset};
use fastswitch::coordinator::priority::Pattern;
use fastswitch::exp::runner::{run_cluster_with, Scale, WorkloadSpec};
use fastswitch::util::bench::{bench, black_box, section};

fn run_once(replicas: usize, placement: PlacementKind, conversations: usize) -> (u64, f64, f64) {
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.priority_update_freq = 0.04;
    let scale = Scale {
        conversations,
        request_rate: replicas as f64, // weak scaling: ~1 conv/s per replica
        seed: 42,
        max_iters: 2_000_000,
        charge_sched_overhead: false,
    };
    let spec = WorkloadSpec {
        tenants: 4,
        heavy_share: 0.4,
        ..WorkloadSpec::default()
    };
    let out = run_cluster_with(
        cfg,
        Preset::llama8b_a10(),
        Pattern::Markov,
        ClusterConfig {
            replicas,
            placement,
            parallel: false,
        },
        &scale,
        &spec,
    );
    (out.total_tokens(), out.throughput(), out.affinity_hit_rate())
}

fn main() {
    section("cluster weak scaling (kv_affinity, 30 convs/replica)");
    for replicas in [1usize, 2, 4] {
        let label = format!("cluster {replicas} replicas, {} convs", 30 * replicas);
        let mut tokens = 0u64;
        let mut tput = 0.0;
        bench(&label, 0, 3, || {
            let (t, p, _) = run_once(
                replicas,
                PlacementKind::KvAffinity {
                    spill_threshold: DEFAULT_SPILL_THRESHOLD,
                },
                30 * replicas,
            );
            tokens = t;
            tput = p;
            black_box(t);
        });
        println!("  -> {tokens} tokens, {tput:.1} tok/s aggregate virtual throughput");
    }

    section("placement policies head-to-head (3 replicas, 90 convs)");
    for placement in [
        PlacementKind::RoundRobin,
        PlacementKind::LeastLoaded,
        PlacementKind::KvAffinity {
            spill_threshold: DEFAULT_SPILL_THRESHOLD,
        },
    ] {
        let mut stats = (0u64, 0.0, 0.0);
        bench(&format!("placement {}", placement.label()), 0, 3, || {
            stats = run_once(3, placement, 90);
            black_box(stats.0);
        });
        println!(
            "  -> {:.1} tok/s, affinity hit rate {:.3}",
            stats.1, stats.2
        );
    }
}
