//! Bench: fairness showdown — trace vs VTC vs SLO-aware priorities on a
//! skewed multi-tenant bursty workload, timed.
//! `cargo bench --bench fairness_showdown`.
use fastswitch::exp::{self, runner::Scale};
use fastswitch::util::bench::{bench, section};

fn main() {
    let scale = Scale::quick();
    section(&format!(
        "fairness showdown ({} tenants, heavy share {}, burst {}x)",
        exp::fairness_showdown::N_TENANTS,
        exp::fairness_showdown::HEAVY_SHARE,
        exp::fairness_showdown::BURST,
    ));
    let mut rep = None;
    bench("3 policies x 1 sim each", 0, 1, || {
        rep = Some(exp::fairness_showdown::run(&scale));
    });
    println!("{}", rep.unwrap().render());
}
