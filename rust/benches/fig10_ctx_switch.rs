//! Bench: Fig. 10 — context-switch overhead, fixed vs block groups.
use fastswitch::exp::{self, runner::Scale};
use fastswitch::util::bench::{bench, section};

fn main() {
    section("fig10: context-switch overhead across frequencies");
    let mut rep = None;
    bench("fig10 (2 freqs x 2 systems)", 0, 1, || {
        rep = Some(exp::fig10::run(&[0.02, 0.08], &Scale::quick()));
    });
    println!("{}", rep.unwrap().render());
}
