//! Bench: Fig. 12 — token-generation efficiency with/without the
//! Multithreading Swap Manager.
use fastswitch::exp::{self, runner::Scale};
use fastswitch::util::bench::{bench, section};

fn main() {
    section("fig12: token-generation efficiency (MTSM on/off)");
    let mut rep = None;
    bench("fig12 (2 sims)", 0, 1, || {
        rep = Some(exp::fig12::run(&Scale::quick()));
    });
    println!("{}", rep.unwrap().render());
}
