//! Bench: lookahead prefetch ladder — demand-only (depth 0) vs depths
//! 1/2/4 on the bursty multi-tenant mix, timed.
//! `cargo bench --bench prefetch_depth`.
use fastswitch::exp::{self, runner::Scale};
use fastswitch::util::bench::{bench, section};

fn main() {
    let scale = Scale::quick();
    section(&format!(
        "prefetch depth ladder (depths {:?}, {} tenants, heavy share {}, {}x bursts)",
        exp::prefetch::DEPTHS,
        exp::prefetch::N_TENANTS,
        exp::prefetch::HEAVY_SHARE,
        exp::prefetch::BURST,
    ));
    let mut rep = None;
    bench("4 depths x 1 sim each", 0, 1, || {
        rep = Some(exp::prefetch::run(&scale));
    });
    println!("{}", rep.unwrap().render());
}
