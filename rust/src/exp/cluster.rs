//! Cluster placement showdown — the experiment the cluster front-end
//! exists for: round-robin vs least-loaded vs KV-affinity placement of a
//! skewed multi-tenant ShareGPT workload with bursty MMPP arrivals on a
//! multi-replica cluster, compared on aggregate per-tenant tail
//! TTFT/TBT, cluster-wide Jain fairness, KV-locality preservation
//! (`affinity hit rate`, re-transferred context blocks), and swap
//! volume.
//!
//! Expected shape: round-robin scatters a conversation's turns across
//! replicas, so nearly every later turn re-prefills its whole history on
//! a cold replica — the §3.3 reuse win is destroyed and swap/prefill
//! volume balloons. Least-loaded balances better but is equally
//! locality-blind. KV-affinity keeps later turns where the CPU KV copy
//! lives (spilling only under load imbalance), so re-transferred blocks
//! collapse while tail latency stays competitive.
//!
//! `fastswitch exp cluster` or `cargo bench --bench cluster_scaling`.

use super::runner::{run_cluster_with, Scale, WorkloadSpec};
use super::{f2, f3, Report};
use crate::cluster::{ClusterConfig, ClusterOutcome, PlacementKind, DEFAULT_SPILL_THRESHOLD};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;
use crate::fairness::PolicyKind;

/// ≥ 2 replicas so placement is a real decision.
pub const REPLICAS: usize = 3;
/// Tenant mix: one heavy abuser issuing half the traffic, five light
/// tenants splitting the rest; arrivals in 4× bursts (MMPP).
pub const N_TENANTS: usize = 6;
pub const HEAVY_SHARE: f64 = 0.5;
pub const BURST: f64 = 4.0;

/// The three placement policies under comparison.
pub fn policies() -> [PlacementKind; 3] {
    [
        PlacementKind::RoundRobin,
        PlacementKind::LeastLoaded,
        PlacementKind::KvAffinity {
            spill_threshold: DEFAULT_SPILL_THRESHOLD,
        },
    ]
}

pub fn run_policy(placement: PlacementKind, scale: &Scale) -> ClusterOutcome {
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.priority_update_freq = 0.04;
    // Each replica runs its own online fairness policy; the report
    // checks the *aggregate* Jain index across all of them.
    cfg.fairness.policy = PolicyKind::Vtc;
    let spec = WorkloadSpec {
        tenants: N_TENANTS,
        heavy_share: HEAVY_SHARE,
        burst: Some(BURST),
        ..WorkloadSpec::default()
    };
    // Scale the arrival rate with the fleet so each replica sees
    // single-engine-like pressure.
    let scale = Scale {
        request_rate: scale.request_rate * REPLICAS as f64,
        ..scale.clone()
    };
    run_cluster_with(
        cfg,
        Preset::llama8b_a10(),
        Pattern::Markov,
        ClusterConfig {
            replicas: REPLICAS,
            placement,
            parallel: false,
        },
        &scale,
        &spec,
    )
}

pub fn run(scale: &Scale) -> Report {
    let mut rep = Report::new(
        "cluster",
        &format!(
            "placement showdown on {REPLICAS} replicas: round_robin vs least_loaded vs \
             kv_affinity, {N_TENANTS} tenants (tenant 0 heavy, {}% of traffic), {BURST}x bursts",
            (HEAVY_SHARE * 100.0) as u32,
        ),
        &[
            "placement",
            "tenant",
            "P50 TTFT s",
            "P99 TTFT s",
            "P99 TBT s",
            "tok share",
            "jain",
            "affinity",
            "migr blocks",
            "swap blocks",
        ],
    );
    for placement in policies() {
        let out = run_policy(placement, scale);
        let ttft = out.ttft_by_tenant();
        let tbt = out.tbt_by_tenant();
        for &(tenant, share) in &out.token_shares() {
            let tt = ttft.iter().find(|&&(t, _)| t == tenant).map(|(_, p)| p);
            let tb = tbt.iter().find(|&&(t, _)| t == tenant).map(|(_, p)| p);
            rep.row(vec![
                placement.label().into(),
                if tenant == 0 {
                    "0 (heavy)".into()
                } else {
                    tenant.to_string()
                },
                tt.map(|p| f3(p.p(50.0))).unwrap_or_else(|| "-".into()),
                tt.map(|p| f3(p.p(99.0))).unwrap_or_else(|| "-".into()),
                tb.map(|p| f3(p.p(99.0))).unwrap_or_else(|| "-".into()),
                f3(share),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
            ]);
        }
        let all_ttft = out.ttft();
        let all_tbt = out.tbt();
        rep.row(vec![
            placement.label().into(),
            "all".into(),
            f3(all_ttft.p(50.0)),
            f3(all_ttft.p(99.0)),
            f3(all_tbt.p(99.0)),
            "1.000".into(),
            f3(out.jain_fairness()),
            f2(out.affinity_hit_rate()),
            out.retransferred_blocks_on_migration.to_string(),
            out.swap_blocks_total().to_string(),
        ]);
    }
    rep.note(
        "affinity = fraction of later-turn placements kept on the replica holding the \
         conversation's CPU KV copy; migr blocks = CPU-resident context blocks thrown \
         away by migrations (reuse the target replica must rebuild)",
    );
    rep.note(
        "jain = Jain fairness index over cluster-wide per-tenant token counts \
         (aggregated across all replicas); swap blocks = PCIe KV traffic summed over replicas",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Scale {
        Scale {
            conversations: 36,
            ..Scale::quick()
        }
    }

    #[test]
    fn showdown_reports_all_policies_and_aggregates() {
        let rep = run(&quick());
        let placements: std::collections::HashSet<&str> =
            rep.rows.iter().map(|r| r[0].as_str()).collect();
        assert_eq!(
            placements,
            ["round_robin", "least_loaded", "kv_affinity"]
                .into_iter()
                .collect()
        );
        assert!(rep.rows.iter().any(|r| r[1] == "0 (heavy)"));
        assert!(rep.rows.iter().any(|r| r[1] == "all"));
    }

    #[test]
    fn kv_affinity_retransfers_strictly_less_than_round_robin() {
        // The acceptance bar: on a multi-turn workload, locality-blind
        // rotation must pay for its migrations in re-prefilled context
        // blocks, and KV-affinity must strictly undercut it.
        let scale = quick();
        let rr = run_policy(PlacementKind::RoundRobin, &scale);
        let aff = run_policy(
            PlacementKind::KvAffinity {
                spill_threshold: DEFAULT_SPILL_THRESHOLD,
            },
            &scale,
        );
        assert!(
            rr.retransferred_blocks_on_migration > 0,
            "round_robin on {REPLICAS} replicas must force re-prefills"
        );
        assert!(
            aff.retransferred_blocks_on_migration < rr.retransferred_blocks_on_migration,
            "kv_affinity {} !< round_robin {}",
            aff.retransferred_blocks_on_migration,
            rr.retransferred_blocks_on_migration
        );
        assert!(aff.affinity_hit_rate() > rr.affinity_hit_rate());
    }
}
