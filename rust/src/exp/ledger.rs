//! The per-PR performance ledger — the canonical cross-PR measurement
//! matrix, regenerated into a schema-stable `BENCH_PR<N>.json` at the
//! repo root so every performance delta shows up as a reviewable diff.
//!
//! The matrix sections (schema in [`crate::obs::ledger`]):
//!
//! - **hotpath** — ns/op micro-measurements of the L3 hot operations
//!   (RNG draw, reservoir insert, trace emit on/off, percentile merge);
//! - **scheduler_epoch** — mean wall-ns per priority-update epoch by
//!   pipeline stage, from the [`crate::obs::EpochProfiler`];
//! - **sched_scale** — scheduler epoch ns/op at queue depths 10²–10⁵
//!   for the sort-based oracle vs the incremental
//!   [`crate::coordinator::queue::CandidateIndex`], asserting
//!   byte-identical schedules while timing (the ratio must grow with
//!   depth — that is the sublinearity claim);
//! - **throughput** — end-to-end tokens/s at 1 and 3 replicas on the
//!   bursty 6-tenant churn mix;
//! - **parallel** — wall-clock of the 3-replica churn run under the
//!   deterministic executor vs the threaded (`--parallel`) executor,
//!   with the resulting speedup;
//! - **policies** — p50/p99 TTFT+TBT, stall shares, preemption counts
//!   and swap volume per preemption policy on the same mix.
//!
//! Wall-clock numbers here are measurements, not determinism pins — the
//! virtual-time e2e pins live in `rust/tests/`.
//!
//! `fastswitch exp ledger [--ledger-out PATH]`.

use std::hint::black_box;
use std::time::Instant;

use super::preemption::{self, BURST, FREQ, HEAVY_SHARE, N_TENANTS, POLICIES};
use super::runner::{
    at_freq, run_cluster_with, run_sim_with, sched_overhead_share, swap_stall_share,
    Scale, WorkloadSpec,
};
use super::{f2, f3, Report};
use crate::cluster::ClusterConfig;
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;
use crate::fairness::PolicyKind;
use crate::coordinator::queue::{CandidateIndex, EpochScratch};
use crate::coordinator::request::ReqState;
use crate::coordinator::scheduler::{schedule, Candidate, IterBudget};
use crate::obs::ledger::{
    EpochCost, HotpathRow, Ledger, LedgerConfig, ParallelRow, PolicyRow, SchedScaleRow,
    ThroughputRow, LEDGER_SCHEMA,
};
use crate::obs::{Reservoir, Stage, TraceEvent, TraceSink};
use crate::util::rng::Rng;
use crate::util::stats::Percentiles;

/// The PR this tree's ledger is stamped with.
pub const PR: u32 = 10;

/// The churn mix every section measures under — identical to the
/// preemption showdown's (6 tenants, bursty arrivals, VTC, hard
/// priority churn).
fn churn_spec() -> WorkloadSpec {
    WorkloadSpec {
        tenants: N_TENANTS,
        heavy_share: HEAVY_SHARE,
        burst: Some(BURST),
        ..WorkloadSpec::default()
    }
}

fn churn_cfg() -> EngineConfig {
    let mut cfg = at_freq(EngineConfig::fastswitch(), FREQ);
    cfg.fairness.policy = PolicyKind::Vtc;
    cfg
}

/// Time `iters` calls of `f` and report the mean ns/op.
fn measure(name: &str, iters: u64, mut f: impl FnMut()) -> HotpathRow {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    HotpathRow {
        name: name.into(),
        ns_per_op: t0.elapsed().as_nanos() as f64 / iters as f64,
    }
}

fn hotpath_rows() -> Vec<HotpathRow> {
    let mut rng = Rng::new(0xBE7C);
    let mut res = Reservoir::default();
    let mut x = 0.0f64;
    let off = TraceSink::off();
    let on = TraceSink::on();
    let parts: Vec<Percentiles> = (0..4)
        .map(|k| Percentiles::from((0..256).map(|i| (i * 4 + k) as f64).collect()))
        .collect();
    vec![
        measure("rng_next_u64", 1_000_000, || {
            black_box(rng.next_u64());
        }),
        measure("reservoir_add", 1_000_000, || {
            res.add(black_box(x));
            x += 1.0;
        }),
        // The default-off cost every engine iteration pays per would-be
        // event — must stay indistinguishable from zero.
        measure("trace_emit_off", 1_000_000, || {
            off.emit(0, TraceEvent::Epoch { epoch: 0 });
        }),
        measure("trace_emit_on", 100_000, || {
            on.emit(0, TraceEvent::Epoch { epoch: 0 });
        }),
        // Cross-replica percentile aggregation (exercises the
        // exact-capacity merge preallocation).
        measure("percentiles_merge_4x256", 2_000, || {
            black_box(Percentiles::merged(parts.clone()).p(99.0));
        }),
    ]
}

/// Queue depths the scheduler-scale sweep measures at. Anything below
/// the default conversation count is a quick run (the CI smoke and the
/// unit test), which stops at the 10³ cell so it stays fast; the full
/// run sweeps to the 100k-deep queue the sublinearity claim is about.
fn sched_scale_depths(scale: &Scale) -> &'static [usize] {
    if scale.conversations < Scale::default().conversations {
        &[100, 1_000]
    } else {
        &[100, 1_000, 10_000, 100_000]
    }
}

/// A plausible parked-fleet candidate: a thin resident slice on top of
/// a deep swapped-out backlog — the regime where the sort-based oracle
/// pays O(n log n) per epoch for an O(admitted) decision.
fn synth_candidate(id: u64, rng: &mut Rng) -> Candidate {
    let (state, blocks_held, blocks_needed, prefill_remaining) = match rng.usize(0, 16) {
        0 => (ReqState::Running, rng.usize(4, 16), rng.usize(0, 2), 0u32),
        1 => (ReqState::Prefilling, rng.usize(1, 8), rng.usize(0, 2), 96),
        2 => (ReqState::Queued, 0, rng.usize(1, 16), rng.usize(64, 512) as u32),
        _ => (ReqState::SwappedOut, 0, rng.usize(1, 16), 0),
    };
    Candidate {
        id,
        priority: rng.usize(0, 8) as i64,
        turn_arrival: rng.next_u64() % 1_000_000,
        state,
        blocks_held,
        blocks_needed,
        prefill_remaining,
    }
}

/// Time one scheduling epoch over a synthetic `depth`-deep population
/// for both scheduler paths, asserting byte-identical schedules while
/// the clock runs. The churn per epoch is fixed (32 re-keys — what a
/// priority-update epoch actually dirties), so the incremental cost
/// should stay flat as `depth` grows while the sort cost keeps
/// climbing.
fn sched_scale_row(depth: usize) -> SchedScaleRow {
    const TOTAL_BLOCKS: usize = 1_024;
    const MAX_BATCH: usize = 64;
    const CHURN: usize = 32;
    let budget = IterBudget::chunked(256, 64);
    let mut rng = Rng::new(0x5CA1E ^ depth as u64);
    let mut cands: Vec<Candidate> = (0..depth as u64)
        .map(|id| synth_candidate(id, &mut rng))
        .collect();
    let mut index = CandidateIndex::new(TOTAL_BLOCKS);
    for &c in &cands {
        index.upsert(c);
    }
    let mut scratch = EpochScratch::default();
    // Fewer timing epochs at the deep end keep the sweep bounded; the
    // per-epoch work there is large enough to time reliably anyway.
    let epochs = (1_000_000 / depth).clamp(8, 512);
    let mut sort_ns = 0u128;
    let mut incremental_ns = 0u128;
    let mut touched = Vec::with_capacity(CHURN);
    for _ in 0..epochs {
        // Identical churn feeds both paths.
        touched.clear();
        for _ in 0..CHURN {
            let i = rng.usize(0, depth);
            cands[i].priority = rng.usize(0, 8) as i64;
            touched.push(i);
        }
        let t_inc = Instant::now();
        for &i in &touched {
            index.upsert(cands[i]);
        }
        index.schedule_into(TOTAL_BLOCKS, MAX_BATCH, budget, &mut scratch);
        incremental_ns += t_inc.elapsed().as_nanos();
        let t_sort = Instant::now();
        let oracle = schedule(&cands, TOTAL_BLOCKS, MAX_BATCH, budget);
        sort_ns += t_sort.elapsed().as_nanos();
        assert_eq!(
            scratch.sched, oracle,
            "incremental scheduler diverged from the sort oracle at depth {depth}"
        );
    }
    let sort_ns_per_epoch = sort_ns as f64 / epochs as f64;
    let incremental_ns_per_epoch = incremental_ns as f64 / epochs as f64;
    SchedScaleRow {
        depth,
        sort_ns_per_epoch,
        incremental_ns_per_epoch,
        ratio: sort_ns_per_epoch / incremental_ns_per_epoch.max(1.0),
    }
}

fn sched_scale_rows(scale: &Scale) -> Vec<SchedScaleRow> {
    sched_scale_depths(scale)
        .iter()
        .map(|&d| sched_scale_row(d))
        .collect()
}

/// Measure the full matrix at `scale`.
pub fn build(scale: &Scale) -> Ledger {
    // One profiled single-engine run covers both the per-stage epoch
    // costs and the 1-replica throughput point.
    let mut cfg = churn_cfg();
    cfg.obs.profile = true;
    cfg.label = "ledger_profiled".into();
    let spec = churn_spec();
    let profiled =
        run_sim_with(cfg, Preset::llama8b_a10(), Pattern::Markov, scale, &spec);
    let prof = &profiled.recorder.profiler;
    let scheduler_epoch = EpochCost {
        admission_ns_mean: prof.mean_ns(Stage::Admission),
        preemption_ns_mean: prof.mean_ns(Stage::Preemption),
        prefetch_ns_mean: prof.mean_ns(Stage::Prefetch),
        execution_ns_mean: prof.mean_ns(Stage::Execution),
        total_ns_mean: prof.total_mean_ns(),
    };
    let t_det = Instant::now();
    let cluster = run_cluster_with(
        churn_cfg(),
        Preset::llama8b_a10(),
        Pattern::Markov,
        ClusterConfig {
            replicas: 3,
            ..ClusterConfig::default()
        },
        scale,
        &spec,
    );
    let deterministic_wall_s = t_det.elapsed().as_secs_f64();
    let throughput = vec![
        ThroughputRow {
            replicas: 1,
            tokens_per_s: profiled.throughput(),
        },
        ThroughputRow {
            replicas: 3,
            tokens_per_s: cluster.throughput(),
        },
    ];

    // Same workload, same seed, threaded executor: one OS thread per
    // replica plus the router. Virtual-time totals agree with the
    // deterministic run (the actor e2e suite pins that); this row is
    // the wall-clock delta only.
    let t_par = Instant::now();
    let par = run_cluster_with(
        churn_cfg(),
        Preset::llama8b_a10(),
        Pattern::Markov,
        ClusterConfig {
            replicas: 3,
            parallel: true,
            ..ClusterConfig::default()
        },
        scale,
        &spec,
    );
    let parallel_wall_s = t_par.elapsed().as_secs_f64();
    assert_eq!(
        par.finished_conversations() + par.rejected_conversations(),
        cluster.finished_conversations() + cluster.rejected_conversations(),
        "threaded executor lost or duplicated conversations"
    );
    let parallel = ParallelRow {
        replicas: 3,
        deterministic_wall_s,
        parallel_wall_s,
        speedup: deterministic_wall_s / parallel_wall_s.max(1e-9),
    };

    let policies = POLICIES
        .iter()
        .map(|&kind| {
            let out = preemption::run_policy(kind, scale);
            let ttft = out.recorder.ttft();
            let tbt = out.recorder.tbt();
            PolicyRow {
                policy: out.label.clone(),
                ttft_p50_s: ttft.p(50.0),
                ttft_p99_s: ttft.p(99.0),
                tbt_p50_s: tbt.p(50.0),
                tbt_p99_s: tbt.p(99.0),
                swap_stall_share: swap_stall_share(&out),
                sched_overhead_share: sched_overhead_share(&out),
                preemptions: out.recorder.preemptions,
                partial_evictions: out.recorder.partial_evictions,
                swap_gb: out.swap_stats.total_bytes as f64 / 1e9,
                tokens_per_s: out.throughput(),
            }
        })
        .collect();

    Ledger {
        pr: PR,
        config: LedgerConfig {
            conversations: scale.conversations,
            seed: scale.seed,
            tenants: N_TENANTS,
            heavy_share: HEAVY_SHARE,
            burst: BURST,
            priority_update_freq: FREQ,
        },
        hotpath: hotpath_rows(),
        scheduler_epoch,
        sched_scale: sched_scale_rows(scale),
        throughput,
        parallel,
        policies,
    }
}

/// Measure the matrix, write `out_path`, and return the summary report.
pub fn run(scale: &Scale, out_path: &str) -> Report {
    let ledger = build(scale);
    let json = ledger.to_json();
    let mut rep = Report::new(
        "ledger",
        &format!("per-PR perf ledger (PR {PR}, schema {LEDGER_SCHEMA})"),
        &["section", "metric", "value"],
    );
    for h in &ledger.hotpath {
        rep.row(vec!["hotpath".into(), h.name.clone(), f2(h.ns_per_op)]);
    }
    rep.row(vec![
        "epoch".into(),
        "total_ns_mean".into(),
        f2(ledger.scheduler_epoch.total_ns_mean),
    ]);
    for s in &ledger.sched_scale {
        rep.row(vec![
            "sched_scale".into(),
            format!("depth {} sort/incremental", s.depth),
            f2(s.ratio),
        ]);
    }
    for t in &ledger.throughput {
        rep.row(vec![
            "throughput".into(),
            format!("{}x tok/s", t.replicas),
            f2(t.tokens_per_s),
        ]);
    }
    let p = &ledger.parallel;
    rep.row(vec![
        "parallel".into(),
        format!("{}x deterministic wall s", p.replicas),
        f3(p.deterministic_wall_s),
    ]);
    rep.row(vec![
        "parallel".into(),
        format!("{}x threaded wall s", p.replicas),
        f3(p.parallel_wall_s),
    ]);
    rep.row(vec!["parallel".into(), "speedup".into(), f2(p.speedup)]);
    for p in &ledger.policies {
        rep.row(vec![
            "policy".into(),
            format!("{} ttft_p99_s", p.policy),
            f3(p.ttft_p99_s),
        ]);
        rep.row(vec![
            "policy".into(),
            format!("{} tok/s", p.policy),
            f2(p.tokens_per_s),
        ]);
    }
    match std::fs::write(out_path, &json) {
        Ok(()) => rep.note(format!("wrote {out_path} ({} bytes)", json.len())),
        Err(e) => rep.note(format!("FAILED to write {out_path}: {e}")),
    }
    rep.note(
        "wall-clock sections (hotpath, scheduler_epoch, parallel) vary by host; \
         the virtual-time sections (throughput, policies) are deterministic per seed",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_produces_the_full_matrix() {
        let scale = Scale {
            conversations: 12,
            ..Scale::quick()
        };
        let l = build(&scale);
        assert_eq!(l.pr, PR);
        assert_eq!(l.policies.len(), POLICIES.len());
        for (row, kind) in l.policies.iter().zip(POLICIES) {
            assert_eq!(row.policy, kind.label());
        }
        assert_eq!(l.throughput.len(), 2);
        assert_eq!(l.throughput[0].replicas, 1);
        assert_eq!(l.throughput[1].replicas, 3);
        assert!(l.throughput[0].tokens_per_s > 0.0);
        assert_eq!(l.parallel.replicas, 3);
        assert!(l.parallel.deterministic_wall_s > 0.0);
        assert!(l.parallel.parallel_wall_s > 0.0);
        assert!(l.parallel.speedup.is_finite() && l.parallel.speedup > 0.0);
        assert!(!l.hotpath.is_empty());
        assert!(l.hotpath.iter().all(|h| h.ns_per_op.is_finite()));
        // Quick scale sweeps the 10² and 10³ cells; the row itself
        // asserts byte-identity between the two scheduler paths. No
        // ratio floor here — debug-build timings are too noisy for
        // that; the release-mode BENCH run is where the claim is held.
        assert_eq!(l.sched_scale.len(), 2);
        assert!(l.sched_scale.windows(2).all(|w| w[0].depth < w[1].depth));
        for s in &l.sched_scale {
            assert!(s.sort_ns_per_epoch > 0.0);
            assert!(s.incremental_ns_per_epoch > 0.0);
            assert!(s.ratio.is_finite() && s.ratio > 0.0);
        }
        let j = l.to_json();
        assert!(j.contains(LEDGER_SCHEMA));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }
}
