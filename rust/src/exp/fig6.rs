//! Fig. 6 — comparison of varying degrees of asynchronous preemption.
//!
//! The paper's three regimes for a swap occurring alongside inference:
//! (a) fully sequential — copies AND their dispatch block the iteration
//!     (vLLM: sync swap, GIL dispatch);
//! (b) asynchronous execution only — DMA overlaps, but dispatch still
//!     serializes on the main thread (the FastServe-style middle ground);
//! (c) fully asynchronous — dispatch offloaded to worker threads too
//!     (FastSwitch §3.2).
//!
//! We reproduce it as a measurable ablation: one 63-block swap-in
//! submitted at the start of a 30 ms decode iteration; the figure's
//! quantity is how much the iteration lengthens under each regime.

use super::{f2, Report};
use crate::config::{
    DispatchMode, GpuSpec, Granularity, ModelSpec, SwapCostConfig, SwapMode,
};
use crate::sim::clock::Ns;
use crate::sim::link::{Direction, PcieLink};
use crate::swap::engine::{BlockMove, SegmentBuilder};
use crate::swap::manager::{SwapInDecision, SwapManager};

pub fn run() -> Report {
    let model = ModelSpec::llama8b();
    let iter_ns: Ns = 30_000_000; // one decode iteration
    let blocks = 63u32;

    let mut rep = Report::new(
        "fig6",
        "Degrees of asynchronous preemption (63-block swap-in during a 30 ms iteration)",
        &["regime", "dispatch on main thread ms", "iteration stall ms", "iteration total ms"],
    );

    let cases = [
        (
            "(a) fully sequential (vLLM)",
            SwapMode::Sync,
            DispatchMode::Gil,
            Granularity::FixedBlock,
        ),
        (
            "(b) async execution, sync dispatch",
            SwapMode::Async,
            DispatchMode::Gil,
            Granularity::FixedBlock,
        ),
        (
            "(c) fully async (FastSwitch)",
            SwapMode::Async,
            DispatchMode::ThreadPool { workers: 4 },
            Granularity::BlockGroup { init_group_blocks: 60 },
        ),
    ];
    for (name, mode, dispatch, gran) in cases {
        let cost = SwapCostConfig::default();
        let mut mgr = SwapManager::new(mode, dispatch, &cost, PcieLink::new(GpuSpec::a10()));
        let builder = SegmentBuilder::new(model.clone(), gran);
        let moves: Vec<BlockMove> = (0..blocks)
            .map(|i| BlockMove { logical: i, gpu: 10 + i, cpu: 100 + i })
            .collect();
        let op = builder.build(1, Direction::In, &moves);
        let decision = mgr.submit_swap_in(op, 0, iter_ns, 8, 2048.0);
        // Main-thread dispatch blocks the iteration even in regime (b).
        let main_thread = mgr.stats.main_thread_dispatch_ns;
        let stall = match decision {
            SwapInDecision::Sync { done } => done,
            SwapInDecision::Async => main_thread,
        };
        rep.row(vec![
            name.into(),
            f2(main_thread as f64 / 1e6),
            f2(stall as f64 / 1e6),
            f2((iter_ns + stall) as f64 / 1e6),
        ]);
    }
    rep.note(
        "paper: (a) serializes everything; (b) still pays the dispatch stage; \
         (c) overlaps both stages",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asynchrony_degrees_match_paper_fig6() {
        let rep = run();
        let total = |i: usize| -> f64 { rep.num(i, 3) };
        // The paper's key observation: regime (b) barely improves on (a)
        // because the dispatch stage — not DMA execution — is the
        // bottleneck at vLLM granularity (Challenge #1/#2).
        assert!(total(0) >= total(1), "(b) can't be worse than (a)");
        assert!(
            (total(0) - total(1)) / total(0) < 0.10,
            "(b) ≈ (a): dispatch dominates ({} vs {})",
            total(0),
            total(1)
        );
        // Only regime (c) actually overlaps the context switch.
        assert!(total(1) > 1.5 * total(2), "(c) must beat (b) decisively");
        assert!(total(2) < 30.5, "fully async ≈ bare iteration: {}", total(2));
        // (b) still pays the full dispatch stage on the main thread.
        let dispatch_b = rep.num(1, 1);
        assert!(dispatch_b > 30.0, "GIL dispatch of 2016 calls is heavy");
    }
}
