use fastswitch::config::{EngineConfig, Preset};
use fastswitch::coordinator::priority::Pattern;
use fastswitch::exp::runner::{run_sim, Scale};

fn main() {
    let scale = Scale { conversations: 150, ..Scale::default() };
    for cfg0 in [EngineConfig::with_dbg_reuse(), EngineConfig::fastswitch()] {
        let mut cfg = cfg0.clone();
        cfg.scheduler.priority_update_freq = 0.04;
        let out = run_sim(cfg, Preset::llama8b_a10(), Pattern::Markov, &scale);
        let (inf, swap, sched) = out.recorder.stall_breakdown();
        let eff = out.recorder.token_gen_efficiency(5);
        println!(
            "{:<16} inf={:.1}s swap={:.3}s sched={:.3}s tput={:.1} p1eff={:.1} \
             p50eff={:.1} sync_in={} async_in={} swapouts={}",
            out.label,
            inf as f64 / 1e9,
            swap as f64 / 1e9,
            sched as f64 / 1e9,
            out.throughput(),
            eff.p(1.0),
            eff.p(50.0),
            out.swap_stats.sync_swap_ins,
            out.swap_stats.async_swap_ins,
            out.swap_stats.swap_out_ops,
        );
    }
}
