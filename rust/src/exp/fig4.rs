//! Fig. 4 — ShareGPT conversation turns & length distributions.
//!
//! Regenerates the workload statistics the paper reports: 78 %
//! multi-turn, 5.5 turns/conversation average, heavy-tailed lengths.

use super::runner::Scale;
use super::{f2, pct, Report};
use crate::util::stats::Histogram;
use crate::workload::sharegpt::{generate, stats, ShareGptConfig};

pub fn run(scale: &Scale) -> Report {
    let convs = generate(&ShareGptConfig::default(), scale.conversations.max(1000), scale.seed);
    let s = stats(&convs);

    let mut rep = Report::new(
        "fig4",
        "ShareGPT-like workload distribution",
        &["statistic", "value", "paper"],
    );
    rep.row(vec![
        "mean turns/conversation".into(),
        f2(s.mean_turns),
        "5.5".into(),
    ]);
    rep.row(vec![
        "multi-turn fraction".into(),
        pct(s.multi_turn_fraction),
        "78%".into(),
    ]);
    rep.row(vec![
        "mean prompt tokens/turn".into(),
        f2(s.mean_prompt),
        "(heavy-tailed)".into(),
    ]);
    rep.row(vec![
        "mean response tokens/turn".into(),
        f2(s.mean_response),
        "(responses > prompts)".into(),
    ]);
    rep.row(vec![
        "P95 conversation tokens".into(),
        f2(s.p95_conv_tokens),
        "-".into(),
    ]);

    // Turn-count histogram (panel 1 of the figure).
    let mut h = Histogram::new(1.0, 21.0, 20);
    for c in &convs {
        h.add(c.turns.len() as f64);
    }
    for (center, frac) in h.normalized().iter().take(10) {
        rep.row(vec![
            format!("P(turns = {})", *center as u32),
            pct(*frac),
            "-".into(),
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn statistics_match_paper() {
        let rep = run(&Scale::quick());
        let turns = rep.num(0, 1);
        assert!((turns - 5.5).abs() < 0.5);
        let multi = rep.num(1, 1);
        assert!((multi - 78.0).abs() < 6.0);
    }
}
