//! Prefetch showdown — demand-only swap-ins (depth 0) vs the lookahead
//! context-switch prefetcher at depths 1/2/4, on the bursty multi-tenant
//! mix under VTC priorities.
//!
//! Expected shape: with prefetching off, every re-admission (preempted
//! request regaining priority, or a multi-turn conversation returning
//! from think time) pays its swap-in on the critical path — either a
//! synchronous stall or several iterations of held-but-idle blocks.
//! With depth > 0 the engine projects the next epochs' admissions
//! (`scheduler::predict_admission` + the pending-turn horizon) and
//! issues those swap-ins early as *background* PCIe traffic, strictly
//! under the I/O budget, so predicted re-admissions land with zero
//! synchronous swap-in stall. Deeper lookahead converts more stall into
//! background I/O but speculates further, so wasted (canceled) bytes
//! can grow with depth.
//!
//! `fastswitch exp prefetch` or `cargo bench --bench prefetch_depth`.

use super::runner::{run_sim_with, Scale, WorkloadSpec};
use super::{f2, f3, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::engine::ServeOutcome;
use crate::coordinator::priority::Pattern;
use crate::fairness::PolicyKind;
use crate::sim::clock::to_secs;

/// Lookahead depths swept by `run` (epochs; 0 = prefetch off).
pub const DEPTHS: [u64; 4] = [0, 1, 2, 4];
/// Tenant mix matching the cluster/fairness showdowns: one heavy tenant
/// issuing half the traffic, bursty MMPP arrivals.
pub const N_TENANTS: usize = 6;
pub const HEAVY_SHARE: f64 = 0.5;
pub const BURST: f64 = 4.0;

/// Run one depth variant on the shared seed/workload.
pub fn run_depth(depth: u64, scale: &Scale) -> ServeOutcome {
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.priority_update_freq = 0.04;
    cfg.fairness.policy = PolicyKind::Vtc;
    cfg.prefetch.depth = depth;
    cfg.label = format!("prefetch/{depth}");
    let spec = WorkloadSpec {
        tenants: N_TENANTS,
        heavy_share: HEAVY_SHARE,
        burst: Some(BURST),
        ..WorkloadSpec::default()
    };
    run_sim_with(cfg, Preset::llama8b_a10(), Pattern::Markov, scale, &spec)
}

pub fn run(scale: &Scale) -> Report {
    let mut rep = Report::new(
        "prefetch",
        &format!(
            "lookahead swap-in prefetch: off vs depth 1/2/4, {N_TENANTS} tenants \
             ({}% heavy), {BURST}x bursts under VTC",
            (HEAVY_SHARE * 100.0) as u32,
        ),
        &[
            "depth",
            "TTFT P50 s",
            "TTFT P99 s",
            "TBT P99 s",
            "sync swap-ins",
            "swap stall s",
            "hit rate",
            "recovered ms",
            "wasted MB",
        ],
    );
    for depth in DEPTHS {
        let out = run_depth(depth, scale);
        let ttft = out.recorder.ttft();
        let tbt = out.recorder.tbt();
        let (_, swap_stall, _) = out.recorder.stall_breakdown();
        rep.row(vec![
            depth.to_string(),
            f3(ttft.p(50.0)),
            f3(ttft.p(99.0)),
            f3(tbt.p(99.0)),
            out.swap_stats.sync_swap_ins.to_string(),
            f2(to_secs(swap_stall)),
            f2(out.swap_stats.prefetch_hit_rate()),
            f2(out.swap_stats.prefetch_recovered_ns as f64 / 1e6),
            f2(out.swap_stats.prefetch_wasted_bytes as f64 / 1e6),
        ]);
    }
    rep.note(
        "hit rate = re-admissions served by a landed/in-flight prefetch over all KV \
         re-materializations; recovered = demand transfer time moved off the critical \
         path; wasted = PCIe bytes spent on canceled (mispredicted) prefetches",
    );
    rep.note(
        "prefetch traffic is background I/O: issued only on an idle inbound DMA engine \
         and capped by the [prefetch] io_budget token bucket, so demand swap volume and \
         the dispatch/sync stall buckets are untouched by speculation",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Scale {
        Scale {
            conversations: 30,
            ..Scale::quick()
        }
    }

    #[test]
    fn lookahead_prefetches_and_recovers_stall_on_the_bursty_mix() {
        let off = run_depth(0, &quick());
        let on = run_depth(2, &quick());
        // Same workload drains either way.
        assert_eq!(
            off.recorder.finished_conversations + off.recorder.rejected_conversations,
            30
        );
        assert_eq!(
            on.recorder.finished_conversations + on.recorder.rejected_conversations,
            30
        );
        assert_eq!(off.swap_stats.prefetch_ops, 0, "depth 0 must not speculate");
        assert!(
            on.swap_stats.prefetch_hits > 0,
            "lookahead must land hits on a multi-turn bursty mix"
        );
        assert!(on.swap_stats.prefetch_hit_rate() > 0.0);
        assert!(on.swap_stats.prefetch_recovered_ns > 0);
    }

    #[test]
    fn report_covers_every_depth() {
        let rep = run(&quick());
        assert_eq!(rep.rows.len(), DEPTHS.len());
        for (row, depth) in rep.rows.iter().zip(DEPTHS) {
            assert_eq!(row[0], depth.to_string());
        }
    }
}
