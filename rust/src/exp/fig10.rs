//! Fig. 10 — Context-switching overhead across priority-update
//! frequencies: Dynamic Block Group Manager vs vLLM fixed blocks.
//!
//! Paper: the coarse-grained allocator shows up to 3.11× context-switch
//! speedup across frequencies (ratio of context-switch overhead to
//! end-to-end latency).

use super::runner::{at_freq, run_sim, swap_stall_share, Scale};
use super::{fx, pct, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;

pub fn run(freqs: &[f64], scale: &Scale) -> Report {
    let mut rep = Report::new(
        "fig10",
        "Context-switch overhead share & DBG speedup vs frequency",
        &["freq", "vllm ctx share", "dbg ctx share", "ctx-switch speedup"],
    );
    for &f in freqs {
        let base = at_freq(EngineConfig::vllm_baseline(), f);
        let dbg = at_freq(EngineConfig::with_dbg(), f);
        let ob = run_sim(base, Preset::llama8b_a10(), Pattern::Markov, scale);
        let od = run_sim(dbg, Preset::llama8b_a10(), Pattern::Markov, scale);
        let (sb, sd) = (swap_stall_share(&ob), swap_stall_share(&od));
        // Speedup in absolute context-switch stall time.
        let (_, swap_b, _) = ob.recorder.stall_breakdown();
        let (_, swap_d, _) = od.recorder.stall_breakdown();
        rep.row(vec![
            format!("{f:.3}"),
            pct(sb),
            pct(sd),
            fx(swap_b as f64 / swap_d.max(1) as f64),
        ]);
    }
    rep.note("paper: up to 3.11x context-switch speedup from coarse granularity alone");
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dbg_reduces_context_switch_overhead() {
        let rep = run(&[0.04], &Scale::quick());
        let spd = rep.num(0, 3);
        assert!(spd > 1.5, "DBG ctx-switch speedup only {spd}x");
    }
}
