//! Fig. 2 — In most iterations only a small share of requests wait on
//! KV transfers; global priority updates hit the tail.
//!
//! Paper setup: LLaMA-8B/A10, Markov, freq 0.02, 500 multi-turn convs.

use super::runner::{run_sim, Scale};
use super::{pct, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;
use crate::util::stats::Percentiles;

pub fn run(scale: &Scale) -> Report {
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.priority_update_freq = 0.02;
    let out = run_sim(cfg, Preset::llama8b_a10(), Pattern::Markov, scale);

    let fracs = out.recorder.waiting_on_swap_fractions();
    let p = Percentiles::from(fracs.clone());
    let zero_share =
        fracs.iter().filter(|&&f| f == 0.0).count() as f64 / fracs.len().max(1) as f64;

    let mut rep = Report::new(
        "fig2",
        "Share of batch waiting on KV transfers per iteration",
        &["statistic", "value"],
    );
    rep.row(vec!["iterations with zero waiters".into(), pct(zero_share)]);
    for q in [50.0, 90.0, 99.0, 99.9] {
        rep.row(vec![format!("P{q} waiting fraction"), pct(p.p(q))]);
    }
    rep.note(
        "paper: most iterations have few/no waiters; tails spike after global priority updates",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn most_iterations_have_no_waiters() {
        let rep = run(&Scale::quick());
        let zero = rep.num(0, 1);
        assert!(zero > 50.0, "zero-waiter share {zero}% too low");
    }
}
