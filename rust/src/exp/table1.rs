//! Table 1 — Swap-out volume microbenchmark: traditional swap-out vs
//! optimized swap-out with KV Cache Reuse.
//!
//! Paper numbers: blocks 122 030 → 58 187 (−53 %), operations
//! 13 076 → 10 713, latency 15.5 s → 6.7 s.

use super::runner::{at_freq, run_sim, Scale};
use super::{pct, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;
use crate::sim::clock::to_secs;

pub fn run(scale: &Scale) -> Report {
    let freq = 0.04;
    let trad = at_freq(EngineConfig::with_dbg(), freq); // DBG on, reuse off
    let opt = at_freq(EngineConfig::with_dbg_reuse(), freq);

    let ot = run_sim(trad, Preset::llama8b_a10(), Pattern::Markov, scale);
    let oo = run_sim(opt, Preset::llama8b_a10(), Pattern::Markov, scale);

    let mut rep = Report::new(
        "table1",
        "Swap-out volume: traditional vs KV Cache Reuse",
        &["metric", "traditional", "with reuse", "reduction"],
    );
    let (bt, bo) = (ot.reuse_blocks_transferred, oo.reuse_blocks_transferred);
    rep.row(vec![
        "num blocks swapped out".into(),
        bt.to_string(),
        bo.to_string(),
        pct(1.0 - bo as f64 / bt.max(1) as f64),
    ]);
    let (ct, co) = (ot.swap_stats.total_calls, oo.swap_stats.total_calls);
    rep.row(vec![
        "num DMA operations".into(),
        ct.to_string(),
        co.to_string(),
        pct(1.0 - co as f64 / ct.max(1) as f64),
    ]);
    let (_, st, _) = ot.recorder.stall_breakdown();
    let (_, so, _) = oo.recorder.stall_breakdown();
    rep.row(vec![
        "swap stall latency (s)".into(),
        format!("{:.2}", to_secs(st)),
        format!("{:.2}", to_secs(so)),
        pct(1.0 - so as f64 / st.max(1) as f64),
    ]);
    rep.note("paper: blocks 122030 -> 58187 (-53%), ops 13076 -> 10713, latency 15.5s -> 6.7s");
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_halves_swap_out_volume() {
        let rep = run(&Scale::quick());
        let red = rep.num(0, 3);
        assert!(red > 25.0, "block reduction only {red}% (paper: 53%)");
    }
}
