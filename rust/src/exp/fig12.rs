//! Fig. 12 — Token-generation efficiency with vs without the
//! Multithreading Swap Manager.
//!
//! Paper method: split the run into fixed 5-iteration windows, compute
//! tokens/second within each, compare percentiles. Baseline = all other
//! optimizations on, swap manager off. Paper: +21.8 % at P99, +12.6 % at
//! P99.9 (higher is better — note these are efficiency percentiles, so
//! low percentiles are the stall-hit windows).

use super::runner::{at_freq, run_sim, Scale};
use super::{f2, pct, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;

pub fn run(scale: &Scale) -> Report {
    let freq = 0.04;
    // Everything but MTSM vs the full system.
    let base = at_freq(EngineConfig::with_dbg_reuse(), freq);
    let full = at_freq(EngineConfig::fastswitch(), freq);

    let ob = run_sim(base, Preset::llama8b_a10(), Pattern::Markov, scale);
    let of = run_sim(full, Preset::llama8b_a10(), Pattern::Markov, scale);
    let eb = ob.recorder.token_gen_efficiency(5);
    let ef = of.recorder.token_gen_efficiency(5);

    let mut rep = Report::new(
        "fig12",
        "Token-generation efficiency per 5-iteration window (tok/s)",
        &["percentile", "no-MTSM", "FastSwitch", "gain"],
    );
    // Low percentiles of efficiency = the windows hurt by stalls — that's
    // where MTSM helps (the paper plots efficiency across percentiles).
    for q in [1.0, 5.0, 10.0, 25.0, 50.0, 90.0] {
        let (b, f) = (eb.p(q), ef.p(q));
        rep.row(vec![
            format!("P{q}"),
            f2(b),
            f2(f),
            pct(f / b - 1.0),
        ]);
    }
    rep.note(
        "paper: +21.8% @P99 / +12.6% @P99.9 of their (inverted) percentile axis — \
         i.e. the stall-dominated windows improve most",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mtsm_improves_stall_windows() {
        let rep = run(&Scale::quick());
        // Mean gain over the stall-hit (low) percentiles must be positive.
        let gains: Vec<f64> = (0..3).map(|row| rep.num(row, 3)).collect();
        let mean = gains.iter().sum::<f64>() / gains.len() as f64;
        assert!(mean > 0.0, "MTSM should lift stall windows: {gains:?}");
    }
}
