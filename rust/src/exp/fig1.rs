//! Fig. 1 — Latency breakdown across percentiles (motivation).
//!
//! Paper setup: LLaMA-8B on A10, vLLM, 1 000 multi-turn ShareGPT convs,
//! 1 req/s, priority updates every 100 iterations. Finding: P99 total
//! iteration latency ≈ 1.6× P50, with swap stall ≈ 59.9 % of P99;
//! P99.9 ≈ 2× inference time.

use super::runner::{run_sim, Scale};
use super::{f2, pct, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;
use crate::util::stats::Percentiles;

pub fn run(scale: &Scale) -> Report {
    let mut cfg = EngineConfig::vllm_baseline();
    cfg.scheduler.priority_update_freq = 0.01; // every 100 iterations
    let out = run_sim(cfg, Preset::llama8b_a10(), Pattern::Markov, scale);

    // Per-iteration (total, swap) samples; normalize to mean inference.
    let samples = out.recorder.iteration_latency_samples();
    let infs: Vec<f64> = out
        .recorder
        .iterations
        .iter()
        .filter(|s| s.inference_ns > 0)
        .map(|s| s.inference_ns as f64)
        .collect();
    let inf_mean = infs.iter().sum::<f64>() / infs.len().max(1) as f64;
    let totals = Percentiles::from(samples.iter().map(|(t, _)| *t).collect());

    let mut rep = Report::new(
        "fig1",
        "Latency breakdown across percentiles (vLLM baseline, LLaMA-8B/A10)",
        &["percentile", "total/inf", "swap share", "sched share"],
    );
    for p in [50.0, 95.0, 99.0, 99.9] {
        let cut = totals.p(p);
        // Average swap share among iterations at/above this percentile.
        let above: Vec<&(f64, f64)> =
            samples.iter().filter(|(t, _)| *t >= cut).collect();
        let swap_share = above.iter().map(|(t, s)| s / t).sum::<f64>()
            / above.len().max(1) as f64;
        let sched: f64 = out
            .recorder
            .iterations
            .iter()
            .map(|s| s.sched_overhead_ns as f64)
            .sum::<f64>()
            / samples.len().max(1) as f64;
        rep.row(vec![
            format!("P{p}"),
            f2(cut / inf_mean),
            pct(swap_share),
            pct(sched / cut),
        ]);
    }
    let p99_over_p50 = totals.p(99.0) / totals.p(50.0);
    rep.note(format!(
        "P99/P50 = {:.2} (paper ≈ 1.6); paper swap share at P99 ≈ 59.9%",
        p99_over_p50
    ));
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let rep = run(&Scale::quick());
        assert_eq!(rep.rows.len(), 4);
        // Tail totals exceed median (heavy-tailed swap stalls).
        let (p50, p99) = (rep.num(0, 1), rep.num(2, 1));
        assert!(p99 > p50, "tail must exceed median: {p50} vs {p99}");
    }
}
