//! Fig. 9 — Scheduler call-stack overhead vs priority-update frequency.
//!
//! The paper instruments FastSwitch's own scheduling code and shows it
//! stays under 1 % of end-to-end time even at high frequency. We measure
//! the same thing for real: the engine charges the wall-clock time of its
//! scheduling phases (arrival handling, priority updates, admission,
//! allocation, swap planning) to the virtual clock.

use super::runner::{at_freq, run_sim, sched_overhead_share, Scale};
use super::{pct, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;

pub fn run(freqs: &[f64], scale: &Scale) -> Report {
    let mut rep = Report::new(
        "fig9",
        "Call-stack (scheduler) overhead share of end-to-end time",
        &["freq", "vllm", "vllm+dbg", "vllm+dbg+reuse", "fastswitch"],
    );
    let mut scale = scale.clone();
    scale.charge_sched_overhead = true;
    for &f in freqs {
        let mut cells = vec![format!("{f:.3}")];
        for cfg in EngineConfig::ablation_ladder() {
            let out = run_sim(
                at_freq(cfg, f),
                Preset::llama8b_a10(),
                Pattern::Markov,
                &scale,
            );
            cells.push(pct(sched_overhead_share(&out)));
        }
        rep.row(cells);
    }
    rep.note("paper: overhead grows with frequency but stays < 1% of end-to-end time");
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_under_one_percent() {
        let rep = run(&[0.02], &Scale::quick());
        for col in 1..rep.headers.len() {
            let v = rep.num(0, col);
            assert!(v < 1.0, "call-stack overhead {v}% exceeds the paper's 1%");
        }
    }
}
