//! Fig. 8 — The headline end-to-end comparison.
//!
//! (a)–(d): P95/P99/P99.9 TTFT and P99.9 TBT for the incremental
//! ablation (vLLM → +DBG → +DBG+Reuse → FastSwitch), for LLaMA-8B/A10
//! (freq 0.04) and Qwen-32B/A100 (freq 0.02), under Markov and Random
//! patterns. Paper speedups: LLaMA-8B 4.3–5.8× P95 TTFT … 2.0–2.7×
//! P99.9 TBT; Qwen-32B 1.4–1.7× … 3.6–11.2×.
//!
//! (e)–(f): end-to-end throughput across priority-update frequencies
//! (up to 1.334× / 1.444×).

use super::runner::{at_freq, run_ladder, run_sim, Scale};
use super::{f2, f3, fx, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;

/// Testbed settings: (preset, priority-update frequency, offered load).
///
/// Frequencies follow the paper (0.04 LLaMA-8B, 0.02 Qwen-32B). The
/// offered load is scaled to each testbed's serving capacity so both
/// operate in the paper's contended-but-not-collapsed regime: the
/// Qwen-32B/A100 testbed holds half the KV blocks and decodes ~3× fewer
/// tokens/s than LLaMA-8B/A10, so an open-loop 1 req/s (which the A10
/// testbed sustains) drives it into unbounded-backlog collapse where CPU
/// swap space exhausts and every system degenerates to recompute
/// thrashing — a regime outside the paper's evaluation.
fn preset_freq(name: &str) -> (Preset, f64, f64) {
    match name {
        "llama8b" => (Preset::llama8b_a10(), 0.04, 1.0),
        "qwen32b" => (Preset::qwen32b_a100(), 0.02, 0.4),
        _ => panic!("unknown testbed"),
    }
}

/// Panels (a)–(d): latency ladder for one testbed + pattern.
pub fn run_latency(testbed: &str, pattern: Pattern, scale: &Scale) -> Report {
    let (preset, freq, rate) = preset_freq(testbed);
    let mut scale = scale.clone();
    scale.request_rate = rate;
    let outs = run_ladder(&preset, pattern, freq, &scale);
    let base_ttft = outs[0].recorder.ttft();
    let base_tbt = outs[0].recorder.tbt();

    let mut rep = Report::new(
        "fig8-latency",
        &format!("Tail latency, {testbed}, {pattern:?}, freq {freq}"),
        &[
            "config", "P95 TTFT s", "P99 TTFT s", "P99.9 TTFT s", "P99.9 TBT s",
            "P95 TTFT spd", "P99 TTFT spd", "P99.9 TTFT spd", "P99.9 TBT spd",
        ],
    );
    for out in &outs {
        let ttft = out.recorder.ttft();
        let tbt = out.recorder.tbt();
        rep.row(vec![
            out.label.clone(),
            f3(ttft.p(95.0)),
            f3(ttft.p(99.0)),
            f3(ttft.p(99.9)),
            f3(tbt.p(99.9)),
            fx(base_ttft.p(95.0) / ttft.p(95.0)),
            fx(base_ttft.p(99.0) / ttft.p(99.0)),
            fx(base_ttft.p(99.9) / ttft.p(99.9)),
            fx(base_tbt.p(99.9) / tbt.p(99.9)),
        ]);
    }
    rep.note("paper: each added optimization lowers tail latency; FastSwitch wins every column");
    rep
}

/// Panels (e)–(f): throughput vs priority-update frequency.
pub fn run_throughput(testbed: &str, pattern: Pattern, freqs: &[f64], scale: &Scale) -> Report {
    let (preset, _, rate) = preset_freq(testbed);
    let mut scale = scale.clone();
    scale.request_rate = rate;
    let scale = &scale;
    let mut rep = Report::new(
        "fig8-throughput",
        &format!("Throughput vs priority-update frequency, {testbed}, {pattern:?}"),
        &["freq", "vllm tok/s", "fastswitch tok/s", "speedup"],
    );
    for &f in freqs {
        let base = at_freq(EngineConfig::vllm_baseline(), f);
        let fast = at_freq(EngineConfig::fastswitch(), f);
        let ob = run_sim(base, preset.clone(), pattern, scale);
        let of = run_sim(fast, preset.clone(), pattern, scale);
        rep.row(vec![
            f3(f),
            f2(ob.throughput()),
            f2(of.throughput()),
            fx(of.throughput() / ob.throughput()),
        ]);
    }
    rep.note("paper: up to 1.334x (LLaMA-8B) / 1.444x (Qwen-32B) at high frequency");
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fastswitch_wins_tail_latency_llama() {
        let rep = run_latency("llama8b", Pattern::Markov, &Scale::quick());
        assert_eq!(rep.rows.len(), 4);
        let last = rep.rows.len() - 1;
        assert!(rep.num(last, 5) > 1.0, "P95 TTFT speedup {}", rep.rows[last][5]);
        assert!(rep.num(last, 8) > 1.0, "P99.9 TBT speedup {}", rep.rows[last][8]);
    }

    #[test]
    fn throughput_improves_at_high_frequency() {
        let rep = run_throughput(
            "llama8b",
            Pattern::Markov,
            &[0.04],
            &Scale::quick(),
        );
        assert!(rep.num(0, 3) > 1.0, "speedup {}", rep.rows[0][3]);
    }
}
