//! Fairness showdown — the experiment the fairness subsystem exists
//! for: trace vs VTC vs SLO-aware priorities on a skewed multi-tenant
//! workload (one heavy abuser vs many light tenants) with bursty
//! arrivals, compared on per-tenant tail TTFT/TBT and token shares.
//!
//! Expected shape: under the offline trace, priorities ignore tenants,
//! so the heavy tenant's demand share (~50 %) becomes its service share
//! and light tenants eat the queueing tail. VTC pushes shares toward
//! max-min fairness (heavy throttled while everyone is backlogged);
//! SLO-aware additionally compresses the light tenants' tail TTFT by
//! boosting whoever is missing targets.
//!
//! `fastswitch exp fairness` or `cargo bench --bench fairness_showdown`.

use super::runner::{run_sim_with, Scale, WorkloadSpec};
use super::{f2, f3, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::engine::ServeOutcome;
use crate::coordinator::priority::Pattern;
use crate::fairness::PolicyKind;

/// Tenant mix: one heavy abuser issuing half the traffic, five light
/// tenants splitting the rest; arrivals in 4× bursts.
pub const N_TENANTS: usize = 6;
pub const HEAVY_SHARE: f64 = 0.5;
pub const BURST: f64 = 4.0;

fn run_policy(kind: PolicyKind, scale: &Scale) -> ServeOutcome {
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.priority_update_freq = 0.04;
    cfg.fairness.policy = kind;
    cfg.label = kind.label().to_string();
    let spec = WorkloadSpec {
        tenants: N_TENANTS,
        heavy_share: HEAVY_SHARE,
        burst: Some(BURST),
        ..WorkloadSpec::default()
    };
    run_sim_with(cfg, Preset::llama8b_a10(), Pattern::Markov, scale, &spec)
}

pub fn run(scale: &Scale) -> Report {
    let mut rep = Report::new(
        "fairness-showdown",
        &format!(
            "trace vs VTC vs SLO-aware, {} tenants (tenant 0 heavy, {}% of traffic), {}x bursts",
            N_TENANTS,
            (HEAVY_SHARE * 100.0) as u32,
            BURST
        ),
        &[
            "policy",
            "tenant",
            "P50 TTFT s",
            "P99 TTFT s",
            "P99 TBT s",
            "tok share",
            "maxmin",
            "jain",
        ],
    );
    for kind in [PolicyKind::Trace, PolicyKind::Vtc, PolicyKind::SloAware] {
        let out = run_policy(kind, scale);
        let ttft = out.recorder.ttft_by_tenant();
        let tbt = out.recorder.tbt_by_tenant();
        let shares = out.recorder.token_shares();
        for &(tenant, share) in &shares {
            let tt = ttft.iter().find(|&&(t, _)| t == tenant).map(|(_, p)| p);
            let tb = tbt.iter().find(|&&(t, _)| t == tenant).map(|(_, p)| p);
            rep.row(vec![
                out.label.clone(),
                if tenant == 0 {
                    "0 (heavy)".into()
                } else {
                    tenant.to_string()
                },
                tt.map(|p| f3(p.p(50.0))).unwrap_or_else(|| "-".into()),
                tt.map(|p| f3(p.p(99.0))).unwrap_or_else(|| "-".into()),
                tb.map(|p| f3(p.p(99.0))).unwrap_or_else(|| "-".into()),
                f3(share),
                String::new(),
                String::new(),
            ]);
        }
        rep.row(vec![
            out.label.clone(),
            "all".into(),
            f3(out.recorder.ttft().p(50.0)),
            f3(out.recorder.ttft().p(99.0)),
            f3(out.recorder.tbt().p(99.0)),
            "1.000".into(),
            f2(out.recorder.max_min_share_ratio()),
            f3(out.recorder.jain_fairness()),
        ]);
    }
    rep.note(
        "trace priorities are tenant-blind; VTC equalizes token shares while tenants are \
         backlogged; SLO-aware also boosts tenants missing TTFT/TBT targets",
    );
    rep.note(
        "maxmin = max/min per-tenant token share; jain = Jain fairness index \
         over token counts",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn showdown_reports_all_policies_and_tenants() {
        let rep = run(&Scale {
            conversations: 40,
            ..Scale::quick()
        });
        // 3 policies × (per-tenant rows + one "all" summary row each).
        let policies: std::collections::HashSet<&str> = rep
            .rows
            .iter()
            .map(|r| r[0].as_str())
            .collect();
        assert_eq!(
            policies,
            ["trace", "vtc", "slo-aware"].into_iter().collect()
        );
        assert!(rep.rows.iter().any(|r| r[1] == "0 (heavy)"));
        assert!(rep.rows.iter().any(|r| r[1] == "all"));
        assert_eq!(rep.rows.len() % 3, 0);
    }
}
