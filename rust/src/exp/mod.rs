//! Experiment harness: one module per paper figure/table (see the
//! experiment index in DESIGN.md).
//!
//! Every experiment returns a [`Report`] — the same rows/series the paper
//! plots — and is runnable via `fastswitch exp <id>` or
//! `examples/paper_figures`. Absolute numbers come from the calibrated
//! simulation testbed; the *shape* (who wins, by what factor, where the
//! knees are) is what reproduces the paper.

pub mod chunked_prefill;
pub mod cluster;
pub mod fairness_showdown;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig6;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod gauntlet;
pub mod ledger;
pub mod locality;
pub mod preemption;
pub mod prefetch;
pub mod runner;
pub mod table1;

use std::fmt::Write as _;

/// A printable experiment result (one table / figure's series).
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, headers: &[&str]) -> Self {
        Report {
            id: id.into(),
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} — {} ==", self.id, self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &w));
        let _ = writeln!(out, "{}", "-".repeat(w.iter().sum::<usize>() + 2 * w.len()));
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &w));
        }
        for n in &self.notes {
            let _ = writeln!(out, "  note: {n}");
        }
        out
    }

    /// Numeric value of cell (`row`, `col`), stripping the `%` / `x`
    /// suffixes the format helpers append — the one parser every
    /// experiment test used to hand-roll.
    pub fn num(&self, row: usize, col: usize) -> f64 {
        self.rows[row][col]
            .trim_end_matches('%')
            .trim_end_matches('x')
            .parse()
            .unwrap_or_else(|_| panic!("non-numeric cell ({row},{col}): {:?}", self.rows[row][col]))
    }

    /// Render as a markdown table (for EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {} — {}\n", self.id, self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "\n*{n}*");
        }
        out.push('\n');
        out
    }
}

/// Format helpers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn fx(x: f64) -> String {
    format!("{x:.2}x")
}

pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_render_and_markdown() {
        let mut r = Report::new("figX", "demo", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        r.note("hello");
        let t = r.render();
        assert!(t.contains("figX") && t.contains("hello"));
        let m = r.markdown();
        assert!(m.contains("| a | b |") && m.contains("| 1 | 2 |"));
    }

    #[test]
    fn num_strips_format_suffixes() {
        let mut r = Report::new("x", "y", &["a", "b", "c"]);
        r.row(vec!["1.5".into(), "42.0%".into(), "3.11x".into()]);
        assert_eq!(r.num(0, 0), 1.5);
        assert_eq!(r.num(0, 1), 42.0);
        assert_eq!(r.num(0, 2), 3.11);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut r = Report::new("x", "y", &["a", "b"]);
        r.row(vec!["1".into()]);
    }
}
