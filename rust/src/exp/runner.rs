//! Shared simulation runner for all experiments.

use crate::cluster::{ClusterConfig, ClusterOutcome, ClusterRouter};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::engine::{ServeOutcome, ServingEngine};
use crate::coordinator::priority::Pattern;
use crate::workload::sharegpt::{generate, Conversation, ShareGptConfig};
use crate::workload::tenants::{assign_tenants, TenantMix};
use crate::workload::{ArrivalTrace, ScenarioWorkload};

/// Experiment scale knobs (defaults keep each figure seconds-scale; the
/// paper's full scale is `conversations = 1000`).
#[derive(Clone, Debug)]
pub struct Scale {
    pub conversations: usize,
    pub request_rate: f64,
    pub seed: u64,
    pub max_iters: u64,
    /// Charge real wall-clock scheduler overhead to the virtual clock
    /// (needed by Fig. 9; off elsewhere for determinism).
    pub charge_sched_overhead: bool,
}

impl Default for Scale {
    fn default() -> Self {
        Scale {
            conversations: 300,
            request_rate: 1.0,
            seed: 42,
            max_iters: 2_000_000,
            charge_sched_overhead: false,
        }
    }
}

impl Scale {
    pub fn paper() -> Self {
        Scale {
            conversations: 1000,
            ..Default::default()
        }
    }

    pub fn quick() -> Self {
        Scale {
            conversations: 80,
            ..Default::default()
        }
    }
}

/// Workload shape beyond the scale knobs: tenant split and arrival
/// pattern. The default reproduces the classic single-tenant Poisson
/// workload bit-for-bit.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Number of tenants; 1 = the classic single-tenant workload.
    pub tenants: usize,
    /// Fraction of conversations issued by tenant 0 (used when
    /// `tenants > 1`).
    pub heavy_share: f64,
    /// `Some(burst_factor)` switches arrivals from Poisson to the on/off
    /// bursty pattern at the same long-run rate.
    pub burst: Option<f64>,
    /// Override the ShareGPT generator shape (`None` = paper defaults) —
    /// e.g. the long-prompt mixes of the chunked-prefill experiments.
    pub sharegpt: Option<ShareGptConfig>,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            tenants: 1,
            heavy_share: 1.0,
            burst: None,
            sharegpt: None,
        }
    }
}

/// Generate the conversation set + arrival trace for a (scale, spec).
pub fn build_workload(scale: &Scale, spec: &WorkloadSpec) -> (Vec<Conversation>, ArrivalTrace) {
    let wl = spec.sharegpt.clone().unwrap_or_default();
    let mut convs = generate(&wl, scale.conversations, scale.seed);
    if spec.tenants > 1 {
        assign_tenants(
            &mut convs,
            &TenantMix::skewed(spec.tenants, spec.heavy_share),
            scale.seed ^ 0x7E,
        );
    }
    let arrivals = match spec.burst {
        Some(b) => ArrivalTrace::bursty(&convs, scale.request_rate, b, scale.seed ^ 0x5EED),
        None => ArrivalTrace::poisson(&convs, scale.request_rate, scale.seed ^ 0x5EED),
    };
    (convs, arrivals)
}

/// Run one simulation over a shaped workload.
pub fn run_sim_with(
    cfg: EngineConfig,
    preset: Preset,
    pattern: Pattern,
    scale: &Scale,
    spec: &WorkloadSpec,
) -> ServeOutcome {
    let (convs, arrivals) = build_workload(scale, spec);
    let mut engine = ServingEngine::new(cfg, preset, pattern, convs, arrivals, scale.seed);
    engine.charge_sched_overhead = scale.charge_sched_overhead;
    engine.run(scale.max_iters)
}

/// Run one cluster simulation: the shaped workload dispatched across
/// `cluster.replicas` independent engine replicas by the configured
/// placement policy.
pub fn run_cluster_with(
    cfg: EngineConfig,
    preset: Preset,
    pattern: Pattern,
    cluster: ClusterConfig,
    scale: &Scale,
    spec: &WorkloadSpec,
) -> ClusterOutcome {
    let (convs, arrivals) = build_workload(scale, spec);
    let mut router = ClusterRouter::new(
        cfg,
        preset,
        pattern,
        cluster,
        convs,
        arrivals,
        scale.seed,
    );
    router.set_charge_sched_overhead(scale.charge_sched_overhead);
    router.run(scale.max_iters)
}

/// Run one simulation over a pre-built scenario workload (the gauntlet
/// scenarios carry their own conversations + arrivals; any drain plan
/// is ignored on the single-engine path — there is nowhere to migrate).
pub fn run_sim_scenario(
    cfg: EngineConfig,
    preset: Preset,
    pattern: Pattern,
    scale: &Scale,
    wl: &ScenarioWorkload,
) -> ServeOutcome {
    let mut engine = ServingEngine::new(
        cfg,
        preset,
        pattern,
        wl.conversations.clone(),
        wl.arrivals.clone(),
        scale.seed,
    );
    engine.charge_sched_overhead = scale.charge_sched_overhead;
    engine.run(scale.max_iters)
}

/// Run one cluster simulation over a pre-built scenario workload. When
/// the scenario carries a [`crate::workload::DrainPlan`] and the
/// cluster has somewhere to migrate (≥ 2 replicas), the drain event —
/// and its re-join, if the plan schedules one — is scheduled through
/// the router's deterministic work queue.
pub fn run_cluster_scenario(
    cfg: EngineConfig,
    preset: Preset,
    pattern: Pattern,
    cluster: ClusterConfig,
    scale: &Scale,
    wl: &ScenarioWorkload,
) -> ClusterOutcome {
    let mut router = ClusterRouter::new(
        cfg,
        preset,
        pattern,
        cluster,
        wl.conversations.clone(),
        wl.arrivals.clone(),
        scale.seed,
    );
    router.set_charge_sched_overhead(scale.charge_sched_overhead);
    if let Some(d) = wl.drain {
        if cluster.replicas >= 2 {
            router.set_drain(d.replica, d.at);
            if let Some(rejoin_at) = d.rejoin_at {
                router.set_rejoin(d.replica, rejoin_at);
            }
        }
    }
    router.run(scale.max_iters)
}

/// Run one simulation (classic single-tenant Poisson workload).
pub fn run_sim(
    cfg: EngineConfig,
    preset: Preset,
    pattern: Pattern,
    scale: &Scale,
) -> ServeOutcome {
    run_sim_with(cfg, preset, pattern, scale, &WorkloadSpec::default())
}

/// One-liner for the pattern every figure module used to copy: take a
/// ladder rung, set its priority-update frequency, return it.
pub fn at_freq(mut cfg: EngineConfig, freq: f64) -> EngineConfig {
    cfg.scheduler.priority_update_freq = freq;
    cfg
}

/// Swap-stall share of end-to-end (inference + swap + scheduler) time —
/// the "context-switch overhead" quantity of Figs. 10/13.
pub fn swap_stall_share(out: &ServeOutcome) -> f64 {
    let (inf, swap, sched) = out.recorder.stall_breakdown();
    swap as f64 / (inf + swap + sched).max(1) as f64
}

/// Scheduler-overhead share of end-to-end time (Fig. 9's quantity).
pub fn sched_overhead_share(out: &ServeOutcome) -> f64 {
    let (inf, swap, sched) = out.recorder.stall_breakdown();
    sched as f64 / (inf + swap + sched).max(1) as f64
}

/// Run the ablation ladder (vllm → +dbg → +reuse → fastswitch) at a
/// given priority-update frequency.
pub fn run_ladder(
    preset: &Preset,
    pattern: Pattern,
    freq: f64,
    scale: &Scale,
) -> Vec<ServeOutcome> {
    EngineConfig::ablation_ladder()
        .into_iter()
        .map(|mut cfg| {
            cfg.scheduler.priority_update_freq = freq;
            run_sim(cfg, preset.clone(), pattern, scale)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_completes() {
        let mut cfg = EngineConfig::fastswitch();
        cfg.scheduler.priority_update_freq = 0.04;
        let out = run_sim(
            cfg,
            Preset::llama8b_a10(),
            Pattern::Markov,
            &Scale {
                conversations: 20,
                ..Scale::quick()
            },
        );
        assert_eq!(out.recorder.finished_conversations, 20);
    }
}

#[cfg(test)]
mod scale_probe {
    use super::*;

    #[test]
    #[ignore] // manual probe: cargo test --release -- --ignored scale_probe
    fn probe_300_conversations() {
        let t0 = std::time::Instant::now();
        let mut cfg = EngineConfig::vllm_baseline();
        cfg.scheduler.priority_update_freq = 0.04;
        let out = run_sim(
            cfg,
            Preset::llama8b_a10(),
            Pattern::Markov,
            &Scale::default(),
        );
        println!(
            "300 convs: {:.1}s wall, {} iters, {} tokens, span {:.0}s, preempt {}",
            t0.elapsed().as_secs_f64(),
            out.iterations,
            out.recorder.total_tokens,
            crate::sim::clock::to_secs(out.span),
            out.recorder.preemptions,
        );
    }
}
