//! Fig. 13 — Sensitivity of context-switch overhead to CPU memory size
//! for KV-cache copies.
//!
//! Paper: more CPU memory → fewer contaminated copies → more reuse →
//! lower context-switch overhead, with diminishing returns past 60 GB
//! (their testbed's sweet spot).

use super::runner::{run_sim, Scale};
use super::{f2, pct, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;

pub fn run(cpu_gb: &[u64], scale: &Scale) -> Report {
    let mut rep = Report::new(
        "fig13",
        "Context-switch overhead vs CPU swap-space size (FastSwitch)",
        &[
            "cpu GB",
            "ctx-switch share",
            "reuse fraction",
            "contaminated / swap-out",
            "recompute preempts",
        ],
    );
    for &gb in cpu_gb {
        let mut preset = Preset::llama8b_a10();
        preset.cpu_swap_bytes = gb * (1 << 30);
        let mut cfg = EngineConfig::fastswitch();
        cfg.scheduler.priority_update_freq = 0.04;
        let out = run_sim(cfg, preset, Pattern::Markov, scale);
        let (inf, swap, sched) = out.recorder.stall_breakdown();
        let moved = out.reuse_blocks_transferred + out.reuse_blocks_reused;
        rep.row(vec![
            gb.to_string(),
            pct(swap as f64 / (inf + swap + sched).max(1) as f64),
            pct(out.reuse_blocks_reused as f64 / moved.max(1) as f64),
            f2(out.contaminated as f64 / out.swap_stats.swap_out_ops.max(1) as f64),
            out.recorder.recompute_preemptions.to_string(),
        ]);
    }
    rep.note("paper: overhead falls with CPU memory, diminishing returns past 60 GB");
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_cpu_memory_never_hurts_reuse() {
        let rep = run(&[2, 60], &Scale::quick());
        let frac = |r: &Vec<String>, i: usize| -> f64 {
            r[i].trim_end_matches('%').parse().unwrap()
        };
        // Larger CPU space: more reuse, not more contamination pressure.
        assert!(
            frac(&rep.rows[1], 2) >= frac(&rep.rows[0], 2) - 1e-9,
            "reuse fraction must not fall with more memory"
        );
        let ctx_small = frac(&rep.rows[0], 1);
        let ctx_big = frac(&rep.rows[1], 1);
        assert!(
            ctx_big <= ctx_small + 0.5,
            "ctx overhead should not grow with memory: {ctx_small} -> {ctx_big}"
        );
    }
}
