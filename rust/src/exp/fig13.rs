//! Fig. 13 — Sensitivity of context-switch overhead to CPU memory size
//! for KV-cache copies.
//!
//! Paper: more CPU memory → fewer contaminated copies → more reuse →
//! lower context-switch overhead, with diminishing returns past 60 GB
//! (their testbed's sweet spot).

use super::runner::{at_freq, run_sim, swap_stall_share, Scale};
use super::{f2, pct, Report};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;

pub fn run(cpu_gb: &[u64], scale: &Scale) -> Report {
    let mut rep = Report::new(
        "fig13",
        "Context-switch overhead vs CPU swap-space size (FastSwitch)",
        &[
            "cpu GB",
            "ctx-switch share",
            "reuse fraction",
            "contaminated / swap-out",
            "recompute preempts",
        ],
    );
    for &gb in cpu_gb {
        let mut preset = Preset::llama8b_a10();
        preset.cpu_swap_bytes = gb * (1 << 30);
        let cfg = at_freq(EngineConfig::fastswitch(), 0.04);
        let out = run_sim(cfg, preset, Pattern::Markov, scale);
        let moved = out.reuse_blocks_transferred + out.reuse_blocks_reused;
        rep.row(vec![
            gb.to_string(),
            pct(swap_stall_share(&out)),
            pct(out.reuse_blocks_reused as f64 / moved.max(1) as f64),
            f2(out.contaminated as f64 / out.swap_stats.swap_out_ops.max(1) as f64),
            out.recorder.recompute_preemptions.to_string(),
        ]);
    }
    rep.note("paper: overhead falls with CPU memory, diminishing returns past 60 GB");
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_cpu_memory_never_hurts_reuse() {
        let rep = run(&[2, 60], &Scale::quick());
        // Larger CPU space: more reuse, not more contamination pressure.
        assert!(
            rep.num(1, 2) >= rep.num(0, 2) - 1e-9,
            "reuse fraction must not fall with more memory"
        );
        let ctx_small = rep.num(0, 1);
        let ctx_big = rep.num(1, 1);
        assert!(
            ctx_big <= ctx_small + 0.5,
            "ctx overhead should not grow with memory: {ctx_small} -> {ctx_big}"
        );
    }
}
