//! Fig. 3 — Timeline comparison: fixed-size-block vs dynamic-block-group
//! preemption (dispatch-dominated vs coalesced).
//!
//! Microbenchmark: swap one request's KV (~1 000 tokens) out and back,
//! measuring DMA calls, dispatch time, and end-to-end time under both
//! granularities. LLaMA-8B geometry: a 63-block preemption at fixed
//! granularity is 63 × 32 layers ≈ 2 000 dispatches of 128 KB.

use super::{f2, pct, Report};
use crate::config::{
    DispatchMode, EngineConfig, GpuSpec, Granularity, ModelSpec, SwapMode,
};
use crate::sim::link::{Direction, PcieLink};
use crate::swap::engine::{BlockMove, SegmentBuilder};
use crate::swap::manager::SwapManager;

pub fn run_with_blocks(n_blocks: u32) -> Report {
    let model = ModelSpec::llama8b();
    let mut rep = Report::new(
        "fig3",
        "Fixed-block vs dynamic-block-group preemption timeline",
        &[
            "policy", "blocks", "dma calls", "avg seg KB", "dispatch ms", "total ms",
            "dispatch share",
        ],
    );
    for (name, gran, dispatch) in [
        ("vLLM fixed", Granularity::FixedBlock, DispatchMode::Gil),
        (
            "FastSwitch group",
            Granularity::BlockGroup { init_group_blocks: 60 },
            DispatchMode::ThreadPool { workers: 4 },
        ),
    ] {
        let cost = EngineConfig::vllm_baseline().swap_cost;
        let mut mgr = SwapManager::new(
            SwapMode::Sync,
            dispatch,
            &cost,
            PcieLink::new(GpuSpec::a10()),
        );
        let builder = SegmentBuilder::new(model.clone(), gran);
        let moves: Vec<BlockMove> = (0..n_blocks)
            .map(|i| BlockMove {
                logical: i,
                gpu: 10 + i,
                cpu: 100 + i,
            })
            .collect();
        let op = builder.build(1, Direction::Out, &moves);
        let calls = op.n_calls();
        let seg_kb = op.total_bytes() as f64 / calls as f64 / 1024.0;
        let total = mgr.submit_swap_out(op, 0);
        let dispatch_ns = mgr.dispatch.dispatch_time;
        rep.row(vec![
            name.into(),
            n_blocks.to_string(),
            calls.to_string(),
            f2(seg_kb),
            f2(dispatch_ns as f64 / 1e6),
            f2(total as f64 / 1e6),
            pct(dispatch_ns as f64 / total.max(1) as f64),
        ]);
    }
    rep.note(
        "paper: dispatch is 90–95% of transmission at vLLM granularity; \
         block groups coalesce it away",
    );
    rep
}

pub fn run() -> Report {
    run_with_blocks(63) // ~1 000 tokens at block_size 16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_dominates_fixed_but_not_group() {
        let rep = run();
        let fixed_share = rep.num(0, 6);
        let group_share = rep.num(1, 6);
        assert!(fixed_share > 85.0, "fixed dispatch share {fixed_share}");
        assert!(group_share < fixed_share);
        let fixed_total = rep.num(0, 5);
        let group_total = rep.num(1, 5);
        assert!(
            group_total * 4.0 < fixed_total,
            "coalescing must win big: {group_total} vs {fixed_total}"
        );
    }

    #[test]
    fn fixed_calls_are_blocks_times_layers() {
        let rep = run_with_blocks(10);
        let calls: usize = rep.rows[0][2].parse().unwrap();
        assert_eq!(calls, 10 * 32);
    }
}
