//! Preemption-policy showdown — `swap_all` (whole-victim eviction, the
//! baseline) vs `cost_aware` (per-victim swap-vs-recompute by the
//! roofline/PCIe crossover) vs `partial_tail` (evict only the minimal
//! tail of block runs the admitted set needs), under hard priority
//! churn on the bursty multi-tenant VTC mix.
//!
//! Expected shape: `partial_tail` moves strictly fewer blocks/bytes over
//! PCIe than `swap_all` (the retained heads never cross the link) at
//! equal completion; `cost_aware` consults the swap-vs-recompute
//! crossover per victim — on the A10 testbed the coalesced round trip
//! beats roofline recompute at every servable context (the paper's
//! premise), so it tracks `swap_all` here and flips to recompute only
//! on slow or contended links (see `rust/tests/preemption_e2e.rs`).
//!
//! `fastswitch exp preemption`.

use super::runner::{at_freq, run_sim_with, Scale, WorkloadSpec};
use super::{f2, f3, Report};
use crate::config::{EngineConfig, PreemptionPolicyKind, Preset};
use crate::coordinator::engine::ServeOutcome;
use crate::coordinator::priority::Pattern;
use crate::fairness::PolicyKind;

/// The policy ladder swept by `run`.
pub const POLICIES: [PreemptionPolicyKind; 3] = [
    PreemptionPolicyKind::SwapAll,
    PreemptionPolicyKind::CostAware,
    PreemptionPolicyKind::PartialTail,
];
/// Tenant mix matching the prefetch/cluster showdowns.
pub const N_TENANTS: usize = 6;
pub const HEAVY_SHARE: f64 = 0.5;
pub const BURST: f64 = 4.0;
/// Hard churn: priorities update every 4 iterations, so membership (and
/// with it the eviction machinery) is exercised constantly.
pub const FREQ: f64 = 0.25;

/// Run one policy variant on the shared seed/workload.
pub fn run_policy(kind: PreemptionPolicyKind, scale: &Scale) -> ServeOutcome {
    let mut cfg = at_freq(EngineConfig::fastswitch(), FREQ);
    cfg.fairness.policy = PolicyKind::Vtc;
    cfg.preemption.policy = kind;
    cfg.label = kind.label().to_string();
    let spec = WorkloadSpec {
        tenants: N_TENANTS,
        heavy_share: HEAVY_SHARE,
        burst: Some(BURST),
        ..WorkloadSpec::default()
    };
    run_sim_with(cfg, Preset::llama8b_a10(), Pattern::Markov, scale, &spec)
}

pub fn run(scale: &Scale) -> Report {
    let mut rep = Report::new(
        "preemption",
        &format!(
            "preemption policies under churn (freq {FREQ}): swap_all vs cost_aware \
             vs partial_tail, {N_TENANTS} tenants, {BURST}x bursts under VTC"
        ),
        &[
            "policy",
            "preempts",
            "partial",
            "blocks kept",
            "recompute",
            "swap-out blocks",
            "swap GB",
            "TTFT P99 s",
            "TBT P99 s",
            "tok/s",
        ],
    );
    for kind in POLICIES {
        let out = run_policy(kind, scale);
        let ttft = out.recorder.ttft();
        let tbt = out.recorder.tbt();
        rep.row(vec![
            out.label.clone(),
            out.recorder.preemptions.to_string(),
            out.recorder.partial_evictions.to_string(),
            out.recorder.blocks_retained.to_string(),
            out.recorder.recompute_preemptions.to_string(),
            out.reuse_blocks_transferred.to_string(),
            f2(out.swap_stats.total_bytes as f64 / 1e9),
            f3(ttft.p(99.0)),
            f3(tbt.p(99.0)),
            f2(out.throughput()),
        ]);
    }
    rep.note(
        "partial = tail-only evictions; blocks kept = GPU-resident blocks those \
         evictions preserved (KV locality that never crossed PCIe)",
    );
    rep.note(
        "cost_aware recomputes only when the roofline prefill beats the PCIe round \
         trip; on the A10 testbed the coalesced round trip wins at every servable \
         context, so its row tracks swap_all here",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Scale {
        Scale {
            conversations: 30,
            ..Scale::quick()
        }
    }

    #[test]
    fn showdown_covers_every_policy_and_drains_the_workload() {
        let rep = run(&quick());
        assert_eq!(rep.rows.len(), POLICIES.len());
        for (row, kind) in rep.rows.iter().zip(POLICIES) {
            assert_eq!(row[0], kind.label());
        }
        // swap_all must never report partial evictions or recomputes
        // driven by the cost model.
        assert_eq!(rep.num(0, 2), 0.0, "swap_all cannot partially evict");
    }

    #[test]
    fn partial_tail_never_moves_more_than_swap_all() {
        let all = run_policy(PreemptionPolicyKind::SwapAll, &quick());
        let partial = run_policy(PreemptionPolicyKind::PartialTail, &quick());
        assert_eq!(
            all.recorder.finished_conversations + all.recorder.rejected_conversations,
            30
        );
        assert_eq!(
            partial.recorder.finished_conversations
                + partial.recorder.rejected_conversations,
            30
        );
        assert!(
            partial.reuse_blocks_transferred <= all.reuse_blocks_transferred,
            "partial {} > swap_all {} blocks moved out",
            partial.reuse_blocks_transferred,
            all.reuse_blocks_transferred
        );
    }
}
