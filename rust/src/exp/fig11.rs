//! Fig. 11 — Sensitivity: initial block-group size vs achieved swap
//! granularity, across priority-update frequencies.
//!
//! Paper: sweeping the initial group size from 64 to 3 000 tokens changes
//! achieved granularity by ≤ 15.13 % at fixed frequency — the allocator
//! is robust; GPU memory per task, not the knob, determines granularity.

use super::runner::{at_freq, run_sim, Scale};
use super::{f2, Report};
use crate::config::{EngineConfig, Granularity, Preset};
use crate::coordinator::priority::Pattern;

pub fn run(init_tokens: &[usize], freqs: &[f64], scale: &Scale) -> Report {
    let block_size = Preset::llama8b_a10().model.block_size;
    let mut headers = vec!["init tokens".to_string(), "init blocks".to_string()];
    for f in freqs {
        headers.push(format!("gran@{f:.3}"));
    }
    let mut rep = Report::new(
        "fig11",
        "Avg swap granularity (blocks/call) vs initial group size",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut extremes: Vec<f64> = Vec::new();
    for &toks in init_tokens {
        let blocks = toks.div_ceil(block_size);
        let mut cells = vec![toks.to_string(), blocks.to_string()];
        for &f in freqs {
            let mut cfg = at_freq(EngineConfig::fastswitch(), f);
            cfg.granularity = Granularity::BlockGroup {
                init_group_blocks: blocks,
            };
            let out = run_sim(cfg, Preset::llama8b_a10(), Pattern::Markov, scale);
            let g = out.swap_stats.avg_granularity();
            extremes.push(g);
            cells.push(f2(g));
        }
        rep.row(cells);
    }
    if !extremes.is_empty() {
        let min = extremes.iter().cloned().fold(f64::MAX, f64::min);
        let max = extremes.iter().cloned().fold(0.0f64, f64::max);
        rep.note(format!(
            "spread (max-min)/min = {:.2}% (paper: <= 15.13%); paper avg ~20 blocks/group",
            100.0 * (max - min) / min
        ));
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn granularity_robust_to_init_size() {
        let rep = run(&[64, 1000, 3000], &[0.04], &Scale::quick());
        let g: Vec<f64> = rep.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        let min = g.iter().cloned().fold(f64::MAX, f64::min);
        let max = g.iter().cloned().fold(0.0f64, f64::max);
        // Generous bound at quick scale; the paper reports 15 %.
        assert!(
            (max - min) / min < 0.6,
            "granularity too sensitive: {g:?}"
        );
        // Coarse in absolute terms.
        assert!(min > 2.0, "granularity should stay coarse: {g:?}");
    }
}
