//! Prefix-locality showdown — the experiment the global prefix cache
//! and prefix-aware placement exist for: agent fleets sharing a long
//! system-prompt template vs plain disjoint chat, placed by round_robin
//! vs kv_affinity vs prefix_aware on a multi-replica cluster, compared
//! on prefix hit rate, prompt tokens saved vs prefilled, cluster-wide
//! Jain fairness, tail TTFT, and later-turn KV affinity.
//!
//! Expected shape: on the disjoint workload the cache is inert (zero
//! hits) and all three policies look like the PR-8 placement showdown.
//! On the shared-template workload the cache alone already removes
//! repeated template prefills wherever two fleet members land on the
//! same replica; prefix_aware placement then routes fresh templated
//! conversations *at* the replica holding the deepest published chain,
//! concentrating reuse instead of leaving it to collision luck — hit
//! rate and saved tokens rise while fairness stays at the VTC baseline,
//! because VTC charges only the uncached suffix.
//!
//! `fastswitch exp locality`.

use super::runner::{build_workload, Scale, WorkloadSpec};
use super::{f2, f3, pct, Report};
use crate::cluster::{
    ClusterConfig, ClusterOutcome, ClusterRouter, PlacementKind, DEFAULT_SPILL_THRESHOLD,
};
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;
use crate::fairness::PolicyKind;
use crate::workload::SharedPrefix;

/// ≥ 2 replicas so routing to the chain-holder is a real decision.
pub const REPLICAS: usize = 3;
/// Six tenants = six agent fleets, each sharing one template (tenant 0
/// heavy, as in the placement showdown — fairness must survive reuse).
pub const N_TENANTS: usize = 6;
pub const HEAVY_SHARE: f64 = 0.5;
pub const BURST: f64 = 4.0;
/// Shared system-prompt template length per fleet, in tokens — 16
/// blocks at the llama8b block size of 16. Conversations with shorter
/// first prompts share the template only up to `prompt - 1` tokens (the
/// completing chunk must still emit the turn's first token).
pub const TEMPLATE_TOKENS: u32 = 256;

/// The two workload shapes under comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fleet {
    /// Every tenant is an agent fleet: all its conversations open with
    /// the tenant's shared template (`group` = tenant id).
    Shared,
    /// Plain multi-tenant chat: no conversation declares a template, so
    /// the prefix cache never matches and never publishes.
    Disjoint,
}

impl Fleet {
    pub fn label(self) -> &'static str {
        match self {
            Fleet::Shared => "shared",
            Fleet::Disjoint => "disjoint",
        }
    }
}

/// The three placement policies under comparison.
pub fn policies() -> [PlacementKind; 3] {
    [
        PlacementKind::RoundRobin,
        PlacementKind::KvAffinity {
            spill_threshold: DEFAULT_SPILL_THRESHOLD,
        },
        PlacementKind::PrefixAware {
            spill_threshold: DEFAULT_SPILL_THRESHOLD,
        },
    ]
}

/// Run one (placement, fleet) cell. Both fleets run the *same*
/// conversations and arrival trace — `Fleet::Shared` only stamps the
/// per-tenant template onto each conversation, so every difference in
/// the outcome is the cache's and the placement's doing.
pub fn run_cell(placement: PlacementKind, fleet: Fleet, scale: &Scale) -> ClusterOutcome {
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.priority_update_freq = 0.04;
    cfg.fairness.policy = PolicyKind::Vtc;
    cfg.prefix.enabled = true;
    let spec = WorkloadSpec {
        tenants: N_TENANTS,
        heavy_share: HEAVY_SHARE,
        burst: Some(BURST),
        ..WorkloadSpec::default()
    };
    let scale = Scale {
        request_rate: scale.request_rate * REPLICAS as f64,
        ..scale.clone()
    };
    let (mut convs, arrivals) = build_workload(&scale, &spec);
    if fleet == Fleet::Shared {
        for c in &mut convs {
            c.prefix = Some(SharedPrefix {
                group: c.tenant as u64,
                tokens: TEMPLATE_TOKENS,
            });
        }
    }
    let mut router = ClusterRouter::new(
        cfg,
        Preset::llama8b_a10(),
        Pattern::Markov,
        ClusterConfig {
            replicas: REPLICAS,
            placement,
            parallel: false,
        },
        convs,
        arrivals,
        scale.seed,
    );
    router.set_charge_sched_overhead(scale.charge_sched_overhead);
    router.run(scale.max_iters)
}

pub fn run(scale: &Scale) -> Report {
    let mut rep = Report::new(
        "locality",
        &format!(
            "prefix-locality showdown on {REPLICAS} replicas: shared {TEMPLATE_TOKENS}-token \
             templates vs disjoint chat x round_robin/kv_affinity/prefix_aware, \
             {N_TENANTS} tenants, {BURST}x bursts, prefix cache on",
        ),
        &[
            "placement",
            "fleet",
            "hit rate",
            "saved tok",
            "prefill tok",
            "jain",
            "P99 TTFT s",
            "affinity",
        ],
    );
    for placement in policies() {
        for fleet in [Fleet::Shared, Fleet::Disjoint] {
            let out = run_cell(placement, fleet, scale);
            let convs = out.finished_conversations() + out.rejected_conversations();
            let hit_rate = out.prefix_hits_total() as f64 / convs.max(1) as f64;
            rep.row(vec![
                placement.label().into(),
                fleet.label().into(),
                pct(hit_rate),
                out.prefix_saved_tokens_total().to_string(),
                out.prefill_tokens_total().to_string(),
                f3(out.jain_fairness()),
                f3(out.ttft().p(99.0)),
                f2(out.affinity_hit_rate()),
            ]);
        }
    }
    rep.note(
        "hit rate = fresh conversations served partly from the shared pool / all \
         conversations; saved tok = prompt tokens never prefilled (never charged by VTC); \
         prefill tok = prompt tokens actually prefilled across replicas",
    );
    rep.note(
        "disjoint rows pin the null result: no templates -> zero hits, zero saved, \
         prefix_aware degrades to kv_affinity; jain = cluster-wide per-tenant token fairness",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Scale {
        Scale {
            conversations: 24,
            ..Scale::quick()
        }
    }

    #[test]
    fn shared_fleet_hits_the_cache_and_prefills_strictly_less() {
        let scale = quick();
        let placement = PlacementKind::PrefixAware {
            spill_threshold: DEFAULT_SPILL_THRESHOLD,
        };
        let shared = run_cell(placement, Fleet::Shared, &scale);
        let disjoint = run_cell(placement, Fleet::Disjoint, &scale);
        assert!(shared.prefix_hits_total() > 0, "templated fleet never hit");
        assert_eq!(disjoint.prefix_hits_total(), 0, "disjoint chat cannot hit");
        assert_eq!(disjoint.prefix_saved_tokens_total(), 0);
        assert!(
            shared.prefill_tokens_total() < disjoint.prefill_tokens_total(),
            "shared {} !< disjoint {}",
            shared.prefill_tokens_total(),
            disjoint.prefill_tokens_total()
        );
        // Reuse must not buy throughput with fairness: both runs stay a
        // valid Jain index, and the shared run stays within 2% of the
        // no-reuse baseline.
        let (js, jd) = (shared.jain_fairness(), disjoint.jain_fairness());
        assert!(js > 0.0 && js <= 1.0 + 1e-12, "jain = {js}");
        assert!(js >= jd - 0.02, "shared jain {js} fell >2% under {jd}");
    }

    #[test]
    fn report_covers_every_cell() {
        let rep = run(&quick());
        assert_eq!(rep.rows.len(), 6, "3 placements x 2 fleets");
        let placements: std::collections::HashSet<&str> =
            rep.rows.iter().map(|r| r[0].as_str()).collect();
        assert_eq!(
            placements,
            ["round_robin", "kv_affinity", "prefix_aware"]
                .into_iter()
                .collect()
        );
        for r in &rep.rows {
            if r[1] == "disjoint" {
                assert_eq!(r[2], "0.00%", "disjoint row {} hit the cache", r[0]);
                assert_eq!(r[3], "0", "disjoint row {} saved tokens", r[0]);
            }
        }
    }
}
