//! Chunked-prefill showdown — monolithic (whole-prefill) admission vs
//! the token-budget chunked scheduler, across chunk sizes, on a
//! long-prompt multi-tenant workload under VTC priorities.
//!
//! Expected shape: under monolithic admission every long prompt runs in
//! exclusive iterations, so co-resident decodes inherit the *whole*
//! prefill latency as an inter-token gap — tail TBT spikes to the
//! prefill duration. Chunking bounds each iteration near the roofline
//! token budget, so decode gaps stay within a couple of decode
//! iterations; the price is TTFT (a long prompt now needs several
//! budget-shared iterations to complete), which shrinks as the chunk
//! grows. The table reports both sides of that trade-off, plus the
//! decode-interference stall bucket that chunking exists to shrink.
//!
//! `fastswitch exp chunked` or `cargo bench --bench chunked_prefill`.

use super::runner::{run_sim_with, Scale, WorkloadSpec};
use super::{f2, f3, Report};
use crate::config::{EngineConfig, PrefillMode, Preset};
use crate::coordinator::engine::ServeOutcome;
use crate::coordinator::priority::Pattern;
use crate::fairness::PolicyKind;
use crate::sim::clock::to_secs;
use crate::workload::ShareGptConfig;

/// Chunk sizes swept by `run` (tokens).
pub const CHUNKS: [usize; 3] = [128, 256, 512];
/// Tenant mix: one heavy tenant issuing half the long-prompt traffic.
pub const N_TENANTS: usize = 4;
pub const HEAVY_SHARE: f64 = 0.5;

/// Long-prompt variant of the ShareGPT statistics: median first prompts
/// around ~700 tokens (an agentic / document-grounded mix), follow-ups
/// and responses unchanged, so prefill work keeps interrupting a steady
/// decode population.
pub fn long_prompt_workload() -> ShareGptConfig {
    ShareGptConfig {
        mean_turns: 3.0,
        first_prompt_mu: 6.6, // median ≈ 735 tokens
        first_prompt_sigma: 0.6,
        prompt_mu: 5.0, // median ≈ 150-token follow-ups
        mean_think_s: 10.0,
        max_prompt: 2048,
        ..ShareGptConfig::default()
    }
}

/// Run one (mode, chunk) variant on the shared seed/workload.
pub fn run_variant(mode: PrefillMode, chunk: usize, scale: &Scale) -> ServeOutcome {
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.prefill_mode = mode;
    cfg.scheduler.prefill_chunk = chunk;
    cfg.fairness.policy = PolicyKind::Vtc;
    cfg.label = match mode {
        PrefillMode::Monolithic => "monolithic".to_string(),
        PrefillMode::Chunked => format!("chunked/{chunk}"),
    };
    let spec = WorkloadSpec {
        tenants: N_TENANTS,
        heavy_share: HEAVY_SHARE,
        sharegpt: Some(long_prompt_workload()),
        ..WorkloadSpec::default()
    };
    run_sim_with(cfg, Preset::llama8b_a10(), Pattern::Markov, scale, &spec)
}

pub fn run(scale: &Scale) -> Report {
    let mut rep = Report::new(
        "chunked-prefill",
        &format!(
            "monolithic vs token-budget chunked prefill, long-prompt mix, \
             {N_TENANTS} tenants under VTC"
        ),
        &[
            "mode",
            "TTFT P50 s",
            "TTFT P99 s",
            "TBT P50 s",
            "TBT P99 s",
            "interference s",
            "tok/s",
        ],
    );
    let mut variants = vec![(PrefillMode::Monolithic, CHUNKS[0])];
    variants.extend(CHUNKS.iter().map(|&c| (PrefillMode::Chunked, c)));
    for (mode, chunk) in variants {
        let out = run_variant(mode, chunk, scale);
        let ttft = out.recorder.ttft();
        let tbt = out.recorder.tbt();
        rep.row(vec![
            out.label.clone(),
            f3(ttft.p(50.0)),
            f3(ttft.p(99.0)),
            f3(tbt.p(50.0)),
            f3(tbt.p(99.0)),
            f2(to_secs(out.recorder.decode_interference_ns())),
            f2(out.throughput()),
        ]);
    }
    rep.note(
        "monolithic admission runs whole prompts in exclusive iterations: co-resident \
         decodes inherit the full prefill latency as tail TBT; chunking bounds the gap \
         at the token-budget iteration cost, paying a TTFT premium on long prompts",
    );
    rep.note(
        "interference = total virtual time decode-ready requests were \
         blocked/inflated by prefill work",
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Scale {
        Scale {
            conversations: 30,
            ..Scale::quick()
        }
    }

    #[test]
    fn chunking_cuts_tail_tbt_on_the_same_seed() {
        let mono = run_variant(PrefillMode::Monolithic, 256, &quick());
        let chunked = run_variant(PrefillMode::Chunked, 256, &quick());
        let tbt_mono = mono.recorder.tbt().p(99.0);
        let tbt_chunked = chunked.recorder.tbt().p(99.0);
        assert!(
            tbt_chunked < tbt_mono,
            "chunked p99 TBT {tbt_chunked:.3}s !< monolithic {tbt_mono:.3}s"
        );
        // Both variants must still drain the workload.
        assert_eq!(
            mono.recorder.finished_conversations + mono.recorder.rejected_conversations,
            30
        );
        assert_eq!(
            chunked.recorder.finished_conversations
                + chunked.recorder.rejected_conversations,
            30
        );
        // ... and chunking shrinks the interference bucket it targets.
        assert!(
            chunked.recorder.decode_interference_ns()
                < mono.recorder.decode_interference_ns(),
            "interference {} !< {}",
            chunked.recorder.decode_interference_ns(),
            mono.recorder.decode_interference_ns()
        );
    }

    #[test]
    fn report_covers_all_variants() {
        let rep = run(&quick());
        assert_eq!(rep.rows.len(), 1 + CHUNKS.len());
        assert_eq!(rep.rows[0][0], "monolithic");
        assert!(rep.rows.iter().any(|r| r[0] == "chunked/256"));
    }
}
