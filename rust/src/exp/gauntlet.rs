//! The scenario gauntlet — every preemption policy × every adversarial
//! workload scenario in one seeded run, with shared invariant checks
//! after each cell and a schema-stable JSON scorecard
//! (`GAUNTLET_PR<N>.json`, schema in [`crate::obs::gauntlet`]).
//!
//! The grid: rows are the [`crate::workload::ScenarioSpec`] fleet
//! (agentic tool-call loops, mega-context summarization, thundering
//! herd with a mid-run replica drain + rejoin, diurnal load wave);
//! columns are
//! the preemption ladder ([`super::preemption::POLICIES`]: `swap_all`,
//! `cost_aware`, `partial_tail`). Every cell runs the full 3-replica
//! cluster path — placement, migrations, and (thundering herd) the
//! drain event all exercise the router — under VTC fairness, hard
//! priority churn, and a depth-2 lookahead prefetcher, so every
//! subsystem the scenarios stress is live.
//!
//! After each cell, [`crate::metrics::invariants::check_cluster`]
//! audits block conservation, stall-bucket partition, served-token
//! accounting, and monotone VTC. The scorecard is written *first* (with
//! per-cell violation counts), then the run fails if any cell was
//! dirty — a CI artifact of a broken run still shows which cell broke.
//!
//! `fastswitch exp gauntlet [--gauntlet-out PATH]`.

use super::preemption::{FREQ, POLICIES};
use super::runner::{at_freq, run_cluster_scenario, Scale};
use super::{f2, f3, Report};
use crate::cluster::ClusterConfig;
use crate::config::{EngineConfig, Preset};
use crate::coordinator::priority::Pattern;
use crate::fairness::PolicyKind;
use crate::metrics::invariants::check_cluster;
use crate::obs::gauntlet::{GauntletConfig, Scorecard, ScorecardCell, GAUNTLET_SCHEMA};
use crate::workload::scenario::SCENARIO_TENANTS;
use crate::workload::{ScenarioParams, ScenarioSpec};

/// Replica fan-out every cell runs at (the thundering-herd drain needs
/// somewhere to migrate; 3 matches the ledger's cluster point).
pub const REPLICAS: usize = 3;
/// Lookahead prefetch depth — on, so the agentic scenario's think-time
/// churn exercises issue/claim/cancel in every cell.
pub const PREFETCH_DEPTH: u64 = 2;

/// The engine configuration every cell shares (only the preemption
/// policy varies): fastswitch ladder rung, VTC fairness, hard priority
/// churn, depth-2 prefetch.
fn cell_cfg(kind: crate::config::PreemptionPolicyKind) -> EngineConfig {
    let mut cfg = at_freq(EngineConfig::fastswitch(), FREQ);
    cfg.fairness.policy = PolicyKind::Vtc;
    cfg.preemption.policy = kind;
    cfg.prefetch.depth = PREFETCH_DEPTH;
    cfg.label = kind.label().to_string();
    cfg
}

/// Run the full grid and assemble the scorecard. Scenario workloads are
/// built once per scenario and reused across the policy column, so
/// every policy sees byte-identical conversations and arrivals. The
/// generator knobs (`--herd-spike`, `--think-floor`) land in `params`;
/// defaults reproduce the canonical grid.
pub fn build(scale: &Scale, params: &ScenarioParams) -> (Scorecard, Vec<String>) {
    let max_model_len = EngineConfig::fastswitch().scheduler.max_seq_len;
    let mut cells = Vec::new();
    let mut violations = Vec::new();
    for spec in ScenarioSpec::all(max_model_len) {
        let wl = spec.build_with(scale.conversations, scale.request_rate, scale.seed, params);
        let total = wl.conversations.len() as u64;
        for kind in POLICIES {
            let out = run_cluster_scenario(
                cell_cfg(kind),
                Preset::llama8b_a10(),
                Pattern::Markov,
                ClusterConfig {
                    replicas: REPLICAS,
                    ..ClusterConfig::default()
                },
                scale,
                &wl,
            );
            let cell_violations = check_cluster(&out, total, spec.expect_rejection_free());
            let ttft = out.ttft();
            let tbt = out.tbt();
            let (mut inf, mut swap, mut sched) = (0u64, 0u64, 0u64);
            let (mut hits, mut demand, mut preempts) = (0u64, 0u64, 0u64);
            for r in &out.replicas {
                let (i, s, c) = r.recorder.stall_breakdown();
                inf += i;
                swap += s;
                sched += c;
                hits += r.swap_stats.prefetch_hits + r.swap_stats.prefetch_partial_hits;
                demand += r.swap_stats.swap_in_ops;
                preempts += r.recorder.preemptions;
            }
            let wall = (inf + swap + sched).max(1) as f64;
            cells.push(ScorecardCell {
                scenario: spec.label().to_string(),
                policy: kind.label().to_string(),
                ttft_p50_s: ttft.p(50.0),
                ttft_p99_s: ttft.p(99.0),
                tbt_p50_s: tbt.p(50.0),
                tbt_p99_s: tbt.p(99.0),
                swap_stall_share: swap as f64 / wall,
                sched_overhead_share: sched as f64 / wall,
                swap_gb: out.swap_bytes_total() as f64 / 1e9,
                swap_blocks: out.swap_blocks_total(),
                jain_fairness: out.jain_fairness(),
                prefetch_hit_rate: if hits + demand == 0 {
                    0.0
                } else {
                    hits as f64 / (hits + demand) as f64
                },
                tokens_per_s: out.throughput(),
                finished: out.finished_conversations(),
                rejected: out.rejected_conversations(),
                migrations: out.migrations,
                preemptions: preempts,
                invariant_violations: cell_violations.len() as u64,
            });
            for v in cell_violations {
                violations.push(format!("{}/{}: {v}", spec.label(), kind.label()));
            }
        }
    }
    let card = Scorecard {
        pr: super::ledger::PR,
        config: GauntletConfig {
            conversations: scale.conversations,
            seed: scale.seed,
            replicas: REPLICAS,
            tenants: SCENARIO_TENANTS,
            max_model_len,
            request_rate: scale.request_rate,
            priority_update_freq: FREQ,
            herd_spike: params.herd_spike,
            agentic_think_floor: params.agentic_think_floor_s,
        },
        cells,
    };
    (card, violations)
}

/// Run the gauntlet, write the scorecard to `out_path`, and return the
/// summary report. The scorecard (with per-cell violation counts) is
/// written *before* the zero-violations assertion, so a failing run
/// still leaves the artifact showing which cell broke.
pub fn run(scale: &Scale, params: &ScenarioParams, out_path: &str) -> Report {
    let (card, violations) = build(scale, params);
    let json = card.to_json();
    let write_result = std::fs::write(out_path, &json);
    let mut rep = Report::new(
        "gauntlet",
        &format!(
            "scenario gauntlet (PR {}, schema {GAUNTLET_SCHEMA}): {} scenarios x {} \
             policies, {REPLICAS} replicas, VTC, churn freq {FREQ}",
            card.pr,
            card.cells.len() / POLICIES.len(),
            POLICIES.len()
        ),
        &[
            "scenario",
            "policy",
            "TTFT P99 s",
            "TBT P99 s",
            "swap GB",
            "jain",
            "prefetch hit",
            "migrations",
            "finished",
            "rejected",
            "violations",
        ],
    );
    for c in &card.cells {
        rep.row(vec![
            c.scenario.clone(),
            c.policy.clone(),
            f3(c.ttft_p99_s),
            f3(c.tbt_p99_s),
            f2(c.swap_gb),
            f3(c.jain_fairness),
            f3(c.prefetch_hit_rate),
            c.migrations.to_string(),
            c.finished.to_string(),
            c.rejected.to_string(),
            c.invariant_violations.to_string(),
        ]);
    }
    match write_result {
        Ok(()) => rep.note(format!("wrote {out_path} ({} bytes)", json.len())),
        Err(e) => rep.note(format!("FAILED to write {out_path}: {e}")),
    }
    rep.note(
        "thundering_herd rows include a mid-run replica drain and a pre-wave-3 \
         rejoin: migrations must be > 0 there and conversation accounting must \
         survive the full drain/rejoin cycle",
    );
    assert!(
        violations.is_empty(),
        "gauntlet invariant violations:\n{}",
        violations.join("\n")
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Scale {
        Scale {
            conversations: 12,
            request_rate: 2.0,
            ..Scale::quick()
        }
    }

    #[test]
    fn grid_covers_every_scenario_policy_pair_cleanly() {
        let (card, violations) = build(&quick(), &ScenarioParams::default());
        assert_eq!(violations, Vec::<String>::new());
        let scenarios = ScenarioSpec::all(4096).len();
        assert_eq!(card.cells.len(), scenarios * POLICIES.len());
        // Row-major: scenario outer, policy inner, in canonical order.
        for (i, cell) in card.cells.iter().enumerate() {
            assert_eq!(cell.policy, POLICIES[i % POLICIES.len()].label());
            assert_eq!(cell.invariant_violations, 0);
            assert!(cell.finished + cell.rejected == quick().conversations as u64);
        }
        // Mega-context is rejection-free by construction.
        for cell in card.cells.iter().filter(|c| c.scenario == "mega_context") {
            assert_eq!(cell.rejected, 0, "mega_context must admit everything");
        }
        // The herd's drain forces migrations in every policy column.
        for cell in card
            .cells
            .iter()
            .filter(|c| c.scenario == "thundering_herd")
        {
            assert!(cell.migrations > 0, "drain must force migrations");
        }
    }

    #[test]
    fn same_seed_rebuild_is_identical() {
        let (a, _) = build(&quick(), &ScenarioParams::default());
        let (b, _) = build(&quick(), &ScenarioParams::default());
        assert_eq!(a.to_json(), b.to_json(), "gauntlet must be deterministic");
    }
}
