//! Perf-ledger schema: the typed record behind `BENCH_PR<N>.json` and
//! its hand-rolled (dependency-free) JSON emitter.
//!
//! The ledger is the regression-visible performance trajectory: every
//! PR regenerates the canonical matrix (hotpath ops, scheduler epoch
//! cost, tokens/s at 1 and 3 replicas, per-policy tail latency + stall
//! breakdown under the bursty 6-tenant churn mix) into a schema-stable
//! JSON file at the repo root, so any perf delta shows up as a diff.
//! The matrix *runner* lives in `exp::ledger`; this module is only the
//! schema + serializer, so `obs` never depends on `exp`.

use std::fmt::Write as _;

/// Schema identifier — bump only on breaking key/type changes.
/// v2: added the `sched_scale` section (scheduler-epoch cost vs queue
/// depth, sort oracle vs incremental index).
pub const LEDGER_SCHEMA: &str = "fastswitch-ledger-v2";

/// Workload/config fingerprint the matrix was measured under.
#[derive(Clone, Debug)]
pub struct LedgerConfig {
    pub conversations: usize,
    pub seed: u64,
    pub tenants: usize,
    pub heavy_share: f64,
    pub burst: f64,
    pub priority_update_freq: f64,
}

/// One micro-benchmarked hot operation.
#[derive(Clone, Debug)]
pub struct HotpathRow {
    pub name: String,
    pub ns_per_op: f64,
}

/// Mean wall-ns per scheduler epoch, by stage (from the epoch
/// profiler).
#[derive(Clone, Debug, Default)]
pub struct EpochCost {
    pub admission_ns_mean: f64,
    pub preemption_ns_mean: f64,
    pub prefetch_ns_mean: f64,
    pub execution_ns_mean: f64,
    pub total_ns_mean: f64,
}

/// End-to-end throughput at a replica count.
#[derive(Clone, Debug)]
pub struct ThroughputRow {
    pub replicas: usize,
    pub tokens_per_s: f64,
}

/// Wall-clock comparison of the two cluster executors on the same
/// workload: the seeded deterministic scheduler vs the threaded
/// (`--parallel`) runtime. `speedup` > 1 means the threads paid off on
/// this host; the *virtual-time* workload totals agree by construction
/// (the actor e2e suite pins that), so this row is pure wall-clock.
#[derive(Clone, Debug)]
pub struct ParallelRow {
    pub replicas: usize,
    pub deterministic_wall_s: f64,
    pub parallel_wall_s: f64,
    pub speedup: f64,
}

/// Scheduler-epoch cost at one candidate-queue depth, sort-based oracle
/// vs incremental bucketed index on identical candidate churn. `ratio`
/// is `sort / incremental` (> 1 means the index wins); it must grow
/// with depth — the sublinearity evidence the CI schema check gates on.
#[derive(Clone, Debug)]
pub struct SchedScaleRow {
    pub depth: usize,
    pub sort_ns_per_epoch: f64,
    pub incremental_ns_per_epoch: f64,
    pub ratio: f64,
}

/// Tail latency + stall breakdown for one preemption policy on the
/// churn mix.
#[derive(Clone, Debug)]
pub struct PolicyRow {
    pub policy: String,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tbt_p50_s: f64,
    pub tbt_p99_s: f64,
    pub swap_stall_share: f64,
    pub sched_overhead_share: f64,
    pub preemptions: u64,
    pub partial_evictions: u64,
    pub swap_gb: f64,
    pub tokens_per_s: f64,
}

/// The full canonical matrix for one PR.
#[derive(Clone, Debug)]
pub struct Ledger {
    pub pr: u32,
    pub config: LedgerConfig,
    pub hotpath: Vec<HotpathRow>,
    pub scheduler_epoch: EpochCost,
    pub sched_scale: Vec<SchedScaleRow>,
    pub throughput: Vec<ThroughputRow>,
    pub parallel: ParallelRow,
    pub policies: Vec<PolicyRow>,
}

/// JSON number: finite floats at fixed precision, non-finite → 0.0 (a
/// `NaN` would make the file unparseable).
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "0.0".to_string()
    }
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl Ledger {
    /// Serialize to the schema-stable pretty JSON written at repo root.
    pub fn to_json(&self) -> String {
        let mut o = String::new();
        let _ = writeln!(o, "{{");
        let _ = writeln!(o, "  \"schema\": \"{LEDGER_SCHEMA}\",");
        let _ = writeln!(o, "  \"pr\": {},", self.pr);
        let c = &self.config;
        let _ = writeln!(o, "  \"config\": {{");
        let _ = writeln!(o, "    \"conversations\": {},", c.conversations);
        let _ = writeln!(o, "    \"seed\": {},", c.seed);
        let _ = writeln!(o, "    \"tenants\": {},", c.tenants);
        let _ = writeln!(o, "    \"heavy_share\": {},", num(c.heavy_share));
        let _ = writeln!(o, "    \"burst\": {},", num(c.burst));
        let _ = writeln!(
            o,
            "    \"priority_update_freq\": {}",
            num(c.priority_update_freq)
        );
        let _ = writeln!(o, "  }},");
        let _ = writeln!(o, "  \"hotpath\": [");
        for (i, h) in self.hotpath.iter().enumerate() {
            let comma = if i + 1 < self.hotpath.len() { "," } else { "" };
            let _ = writeln!(
                o,
                "    {{\"name\": \"{}\", \"ns_per_op\": {}}}{comma}",
                esc(&h.name),
                num(h.ns_per_op)
            );
        }
        let _ = writeln!(o, "  ],");
        let e = &self.scheduler_epoch;
        let _ = writeln!(o, "  \"scheduler_epoch\": {{");
        let _ = writeln!(o, "    \"admission_ns_mean\": {},", num(e.admission_ns_mean));
        let _ = writeln!(o, "    \"preemption_ns_mean\": {},", num(e.preemption_ns_mean));
        let _ = writeln!(o, "    \"prefetch_ns_mean\": {},", num(e.prefetch_ns_mean));
        let _ = writeln!(o, "    \"execution_ns_mean\": {},", num(e.execution_ns_mean));
        let _ = writeln!(o, "    \"total_ns_mean\": {}", num(e.total_ns_mean));
        let _ = writeln!(o, "  }},");
        let _ = writeln!(o, "  \"sched_scale\": [");
        for (i, s) in self.sched_scale.iter().enumerate() {
            let comma = if i + 1 < self.sched_scale.len() { "," } else { "" };
            let _ = writeln!(
                o,
                "    {{\"depth\": {}, \"sort_ns_per_epoch\": {}, \
                 \"incremental_ns_per_epoch\": {}, \"ratio\": {}}}{comma}",
                s.depth,
                num(s.sort_ns_per_epoch),
                num(s.incremental_ns_per_epoch),
                num(s.ratio)
            );
        }
        let _ = writeln!(o, "  ],");
        let _ = writeln!(o, "  \"throughput\": [");
        for (i, t) in self.throughput.iter().enumerate() {
            let comma = if i + 1 < self.throughput.len() { "," } else { "" };
            let _ = writeln!(
                o,
                "    {{\"replicas\": {}, \"tokens_per_s\": {}}}{comma}",
                t.replicas,
                num(t.tokens_per_s)
            );
        }
        let _ = writeln!(o, "  ],");
        let p = &self.parallel;
        let _ = writeln!(o, "  \"parallel\": {{");
        let _ = writeln!(o, "    \"replicas\": {},", p.replicas);
        let _ = writeln!(
            o,
            "    \"deterministic_wall_s\": {},",
            num(p.deterministic_wall_s)
        );
        let _ = writeln!(o, "    \"parallel_wall_s\": {},", num(p.parallel_wall_s));
        let _ = writeln!(o, "    \"speedup\": {}", num(p.speedup));
        let _ = writeln!(o, "  }},");
        let _ = writeln!(o, "  \"policies\": [");
        for (i, p) in self.policies.iter().enumerate() {
            let comma = if i + 1 < self.policies.len() { "," } else { "" };
            let _ = writeln!(o, "    {{");
            let _ = writeln!(o, "      \"policy\": \"{}\",", esc(&p.policy));
            let _ = writeln!(o, "      \"ttft_p50_s\": {},", num(p.ttft_p50_s));
            let _ = writeln!(o, "      \"ttft_p99_s\": {},", num(p.ttft_p99_s));
            let _ = writeln!(o, "      \"tbt_p50_s\": {},", num(p.tbt_p50_s));
            let _ = writeln!(o, "      \"tbt_p99_s\": {},", num(p.tbt_p99_s));
            let _ = writeln!(o, "      \"swap_stall_share\": {},", num(p.swap_stall_share));
            let _ = writeln!(
                o,
                "      \"sched_overhead_share\": {},",
                num(p.sched_overhead_share)
            );
            let _ = writeln!(o, "      \"preemptions\": {},", p.preemptions);
            let _ = writeln!(o, "      \"partial_evictions\": {},", p.partial_evictions);
            let _ = writeln!(o, "      \"swap_gb\": {},", num(p.swap_gb));
            let _ = writeln!(o, "      \"tokens_per_s\": {}", num(p.tokens_per_s));
            let _ = writeln!(o, "    }}{comma}");
        }
        let _ = writeln!(o, "  ]");
        o.push('}');
        o.push('\n');
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Ledger {
        Ledger {
            pr: 6,
            config: LedgerConfig {
                conversations: 24,
                seed: 42,
                tenants: 6,
                heavy_share: 0.5,
                burst: 4.0,
                priority_update_freq: 0.25,
            },
            hotpath: vec![HotpathRow { name: "rng_next_u64".into(), ns_per_op: 1.5 }],
            scheduler_epoch: EpochCost {
                admission_ns_mean: 100.0,
                preemption_ns_mean: 200.0,
                prefetch_ns_mean: 50.0,
                execution_ns_mean: 400.0,
                total_ns_mean: 750.0,
            },
            sched_scale: vec![
                SchedScaleRow {
                    depth: 100,
                    sort_ns_per_epoch: 4000.0,
                    incremental_ns_per_epoch: 2000.0,
                    ratio: 2.0,
                },
                SchedScaleRow {
                    depth: 1000,
                    sort_ns_per_epoch: 60000.0,
                    incremental_ns_per_epoch: 3000.0,
                    ratio: 20.0,
                },
            ],
            throughput: vec![
                ThroughputRow { replicas: 1, tokens_per_s: 1000.0 },
                ThroughputRow { replicas: 3, tokens_per_s: 2800.0 },
            ],
            parallel: ParallelRow {
                replicas: 3,
                deterministic_wall_s: 1.2,
                parallel_wall_s: 0.8,
                speedup: 1.5,
            },
            policies: vec![PolicyRow {
                policy: "swap_all".into(),
                ttft_p50_s: 0.1,
                ttft_p99_s: 0.9,
                tbt_p50_s: 0.03,
                tbt_p99_s: 0.2,
                swap_stall_share: 0.05,
                sched_overhead_share: 0.01,
                preemptions: 12,
                partial_evictions: 0,
                swap_gb: 1.25,
                tokens_per_s: 990.0,
            }],
        }
    }

    #[test]
    fn json_has_every_schema_key() {
        let j = sample().to_json();
        for key in [
            "\"schema\"", "\"pr\"", "\"config\"", "\"conversations\"", "\"seed\"",
            "\"tenants\"", "\"heavy_share\"", "\"burst\"", "\"priority_update_freq\"",
            "\"hotpath\"", "\"ns_per_op\"", "\"scheduler_epoch\"", "\"admission_ns_mean\"",
            "\"preemption_ns_mean\"", "\"prefetch_ns_mean\"", "\"execution_ns_mean\"",
            "\"total_ns_mean\"", "\"sched_scale\"", "\"depth\"",
            "\"sort_ns_per_epoch\"", "\"incremental_ns_per_epoch\"", "\"ratio\"",
            "\"throughput\"", "\"replicas\"", "\"tokens_per_s\"",
            "\"parallel\"", "\"deterministic_wall_s\"", "\"parallel_wall_s\"",
            "\"speedup\"",
            "\"policies\"", "\"policy\"", "\"ttft_p50_s\"", "\"ttft_p99_s\"",
            "\"tbt_p50_s\"", "\"tbt_p99_s\"", "\"swap_stall_share\"",
            "\"sched_overhead_share\"", "\"preemptions\"", "\"partial_evictions\"",
            "\"swap_gb\"",
        ] {
            assert!(j.contains(key), "missing {key} in\n{j}");
        }
        assert!(j.contains(LEDGER_SCHEMA));
    }

    #[test]
    fn json_guards_non_finite() {
        let mut l = sample();
        l.scheduler_epoch.total_ns_mean = f64::NAN;
        let j = l.to_json();
        assert!(!j.contains("NaN"), "NaN leaked into JSON:\n{j}");
        assert!(j.contains("\"total_ns_mean\": 0.0"));
    }

    #[test]
    fn json_is_structurally_balanced() {
        let j = sample().to_json();
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
    }
}
