//! Gauntlet scorecard schema: the typed record behind
//! `GAUNTLET_PR<N>.json` and its hand-rolled (dependency-free) JSON
//! emitter — the same discipline as [`super::ledger`].
//!
//! The scorecard is the regression grid of the scenario gauntlet: one
//! cell per preemption policy × workload scenario, each carrying tail
//! latency, stall shares, swap volume, fairness, prefetch efficiency,
//! and the cell's invariant-violation count (always 0 on a passing
//! run — the count is serialized so a CI artifact of a *failing* run
//! still shows which cell broke). The matrix runner lives in
//! `exp::gauntlet`; this module is only the schema + serializer, so
//! `obs` never depends on `exp`.

use std::fmt::Write as _;

/// Schema identifier — bump only on breaking key/type changes.
pub const GAUNTLET_SCHEMA: &str = "fastswitch-gauntlet-v1";

/// Workload/config fingerprint the gauntlet was run under.
#[derive(Clone, Debug)]
pub struct GauntletConfig {
    pub conversations: usize,
    pub seed: u64,
    pub replicas: usize,
    pub tenants: usize,
    pub max_model_len: usize,
    pub request_rate: f64,
    pub priority_update_freq: f64,
    /// Thundering-herd within-wave spike factor the run used
    /// (`--herd-spike`; canonical default in
    /// [`crate::workload::scenario::HERD_SPIKE`]).
    pub herd_spike: f64,
    /// Agentic think-time floor in seconds (`--think-floor`; canonical
    /// default in [`crate::workload::scenario::AGENTIC_THINK_MIN_S`]).
    pub agentic_think_floor: f64,
}

/// One policy × scenario cell of the grid.
#[derive(Clone, Debug)]
pub struct ScorecardCell {
    pub scenario: String,
    pub policy: String,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tbt_p50_s: f64,
    pub tbt_p99_s: f64,
    pub swap_stall_share: f64,
    pub sched_overhead_share: f64,
    pub swap_gb: f64,
    pub swap_blocks: u64,
    pub jain_fairness: f64,
    pub prefetch_hit_rate: f64,
    pub tokens_per_s: f64,
    pub finished: u64,
    pub rejected: u64,
    pub migrations: u64,
    pub preemptions: u64,
    pub invariant_violations: u64,
}

/// The full scorecard for one PR.
#[derive(Clone, Debug)]
pub struct Scorecard {
    pub pr: u32,
    pub config: GauntletConfig,
    pub cells: Vec<ScorecardCell>,
}

/// JSON number: finite floats at fixed precision, non-finite → 0.0 (a
/// `NaN` would make the file unparseable).
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "0.0".to_string()
    }
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl Scorecard {
    /// Sum of per-cell invariant violations (0 on a healthy run).
    pub fn total_violations(&self) -> u64 {
        self.cells.iter().map(|c| c.invariant_violations).sum()
    }

    /// Serialize to the schema-stable pretty JSON written at repo root.
    pub fn to_json(&self) -> String {
        let mut o = String::new();
        let _ = writeln!(o, "{{");
        let _ = writeln!(o, "  \"schema\": \"{GAUNTLET_SCHEMA}\",");
        let _ = writeln!(o, "  \"pr\": {},", self.pr);
        let c = &self.config;
        let _ = writeln!(o, "  \"config\": {{");
        let _ = writeln!(o, "    \"conversations\": {},", c.conversations);
        let _ = writeln!(o, "    \"seed\": {},", c.seed);
        let _ = writeln!(o, "    \"replicas\": {},", c.replicas);
        let _ = writeln!(o, "    \"tenants\": {},", c.tenants);
        let _ = writeln!(o, "    \"max_model_len\": {},", c.max_model_len);
        let _ = writeln!(o, "    \"request_rate\": {},", num(c.request_rate));
        let _ = writeln!(
            o,
            "    \"priority_update_freq\": {},",
            num(c.priority_update_freq)
        );
        let _ = writeln!(o, "    \"herd_spike\": {},", num(c.herd_spike));
        let _ = writeln!(
            o,
            "    \"agentic_think_floor\": {}",
            num(c.agentic_think_floor)
        );
        let _ = writeln!(o, "  }},");
        let _ = writeln!(o, "  \"cells\": [");
        for (i, cell) in self.cells.iter().enumerate() {
            let comma = if i + 1 < self.cells.len() { "," } else { "" };
            let _ = writeln!(o, "    {{");
            let _ = writeln!(o, "      \"scenario\": \"{}\",", esc(&cell.scenario));
            let _ = writeln!(o, "      \"policy\": \"{}\",", esc(&cell.policy));
            let _ = writeln!(o, "      \"ttft_p50_s\": {},", num(cell.ttft_p50_s));
            let _ = writeln!(o, "      \"ttft_p99_s\": {},", num(cell.ttft_p99_s));
            let _ = writeln!(o, "      \"tbt_p50_s\": {},", num(cell.tbt_p50_s));
            let _ = writeln!(o, "      \"tbt_p99_s\": {},", num(cell.tbt_p99_s));
            let _ = writeln!(
                o,
                "      \"swap_stall_share\": {},",
                num(cell.swap_stall_share)
            );
            let _ = writeln!(
                o,
                "      \"sched_overhead_share\": {},",
                num(cell.sched_overhead_share)
            );
            let _ = writeln!(o, "      \"swap_gb\": {},", num(cell.swap_gb));
            let _ = writeln!(o, "      \"swap_blocks\": {},", cell.swap_blocks);
            let _ = writeln!(o, "      \"jain_fairness\": {},", num(cell.jain_fairness));
            let _ = writeln!(
                o,
                "      \"prefetch_hit_rate\": {},",
                num(cell.prefetch_hit_rate)
            );
            let _ = writeln!(o, "      \"tokens_per_s\": {},", num(cell.tokens_per_s));
            let _ = writeln!(o, "      \"finished\": {},", cell.finished);
            let _ = writeln!(o, "      \"rejected\": {},", cell.rejected);
            let _ = writeln!(o, "      \"migrations\": {},", cell.migrations);
            let _ = writeln!(o, "      \"preemptions\": {},", cell.preemptions);
            let _ = writeln!(
                o,
                "      \"invariant_violations\": {}",
                cell.invariant_violations
            );
            let _ = writeln!(o, "    }}{comma}");
        }
        let _ = writeln!(o, "  ]");
        o.push('}');
        o.push('\n');
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Scorecard {
        Scorecard {
            pr: 7,
            config: GauntletConfig {
                conversations: 24,
                seed: 42,
                replicas: 3,
                tenants: 4,
                max_model_len: 4096,
                request_rate: 2.0,
                priority_update_freq: 0.25,
                herd_spike: 20.0,
                agentic_think_floor: 0.05,
            },
            cells: vec![
                ScorecardCell {
                    scenario: "agentic".into(),
                    policy: "swap_all".into(),
                    ttft_p50_s: 0.12,
                    ttft_p99_s: 0.8,
                    tbt_p50_s: 0.03,
                    tbt_p99_s: 0.2,
                    swap_stall_share: 0.04,
                    sched_overhead_share: 0.0,
                    swap_gb: 1.5,
                    swap_blocks: 3000,
                    jain_fairness: 0.93,
                    prefetch_hit_rate: 0.6,
                    tokens_per_s: 900.0,
                    finished: 24,
                    rejected: 0,
                    migrations: 2,
                    preemptions: 11,
                    invariant_violations: 0,
                },
                ScorecardCell {
                    scenario: "thundering_herd".into(),
                    policy: "partial_tail".into(),
                    ttft_p50_s: 0.5,
                    ttft_p99_s: 3.0,
                    tbt_p50_s: 0.05,
                    tbt_p99_s: 0.4,
                    swap_stall_share: 0.1,
                    sched_overhead_share: 0.0,
                    swap_gb: 4.0,
                    swap_blocks: 8000,
                    jain_fairness: 0.88,
                    prefetch_hit_rate: 0.3,
                    tokens_per_s: 1200.0,
                    finished: 23,
                    rejected: 1,
                    migrations: 9,
                    preemptions: 40,
                    invariant_violations: 0,
                },
            ],
        }
    }

    #[test]
    fn json_has_every_schema_key() {
        let j = sample().to_json();
        for key in [
            "\"schema\"", "\"pr\"", "\"config\"", "\"conversations\"", "\"seed\"",
            "\"replicas\"", "\"tenants\"", "\"max_model_len\"", "\"request_rate\"",
            "\"priority_update_freq\"", "\"herd_spike\"", "\"agentic_think_floor\"",
            "\"cells\"", "\"scenario\"", "\"policy\"",
            "\"ttft_p50_s\"", "\"ttft_p99_s\"", "\"tbt_p50_s\"", "\"tbt_p99_s\"",
            "\"swap_stall_share\"", "\"sched_overhead_share\"", "\"swap_gb\"",
            "\"swap_blocks\"", "\"jain_fairness\"", "\"prefetch_hit_rate\"",
            "\"tokens_per_s\"", "\"finished\"", "\"rejected\"", "\"migrations\"",
            "\"preemptions\"", "\"invariant_violations\"",
        ] {
            assert!(j.contains(key), "missing {key} in\n{j}");
        }
        assert!(j.contains(GAUNTLET_SCHEMA));
    }

    #[test]
    fn json_guards_non_finite() {
        let mut s = sample();
        s.cells[0].jain_fairness = f64::NAN;
        s.cells[0].prefetch_hit_rate = f64::INFINITY;
        let j = s.to_json();
        assert!(!j.contains("NaN") && !j.contains("inf"), "non-finite leaked:\n{j}");
        assert!(j.contains("\"jain_fairness\": 0.0"));
    }

    #[test]
    fn json_is_structurally_balanced_and_deterministic() {
        let j = sample().to_json();
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        assert_eq!(j, sample().to_json(), "serialization must be pure");
    }

    #[test]
    fn violations_sum_across_cells() {
        let mut s = sample();
        assert_eq!(s.total_violations(), 0);
        s.cells[1].invariant_violations = 3;
        assert_eq!(s.total_violations(), 3);
    }
}
