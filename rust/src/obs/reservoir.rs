//! Bounded telemetry: an O(1) fixed-array reservoir histogram (the
//! Falcon `Timer` idiom — Vitter's Algorithm R over a fixed sample
//! array) and the per-stage scheduler-epoch profiler.
//!
//! The exact percentile pipeline pushes every sample into a `Vec` and
//! sorts at read time; fine for experiments, unbounded for a serving
//! loop. The reservoir keeps a uniform random subset of fixed size, so
//! memory and per-sample cost are constant regardless of run length,
//! at the price of sampling error on tail percentiles (pinned by the
//! accuracy tests in `rust/tests/obs_e2e.rs`).

use crate::sim::clock::Ns;
use crate::util::rng::Rng;
use crate::util::stats::{Percentiles, Welford};

/// Fixed reservoir size. 1024 samples keep p50 within a few percent
/// and p99 within the pinned bound on the seeded workloads.
pub const RESERVOIR_N: usize = 1024;

/// Seed for the reservoir's private replacement stream. Constant so a
/// run's reservoir contents are a pure function of the sample sequence
/// (determinism pins depend on it); private so enabling reservoir mode
/// never perturbs any workload RNG stream.
const RESERVOIR_SEED: u64 = 0x0B5E_C0DE;

/// Fixed-size uniform reservoir (Algorithm R).
#[derive(Clone, Debug)]
pub struct Reservoir {
    samples: [f64; RESERVOIR_N],
    count: u64,
    rng: Rng,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir {
            samples: [0.0; RESERVOIR_N],
            count: 0,
            rng: Rng::new(RESERVOIR_SEED),
        }
    }
}

impl Reservoir {
    /// Record one sample: O(1), no allocation.
    pub fn add(&mut self, x: f64) {
        let seen = self.count;
        self.count += 1;
        if (seen as usize) < RESERVOIR_N {
            self.samples[seen as usize] = x;
        } else {
            // Replace a random slot with probability N / (seen + 1) —
            // keeps the retained set uniform over everything seen.
            let r = self.rng.range(0, seen + 1);
            if (r as usize) < RESERVOIR_N {
                self.samples[r as usize] = x;
            }
        }
    }

    /// Total samples observed (not retained).
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Percentile summary over the retained subset.
    pub fn percentiles(&self) -> Percentiles {
        let n = (self.count as usize).min(RESERVOIR_N);
        Percentiles::from(self.samples[..n].to_vec())
    }
}

/// Scheduler stage measured by the epoch profiler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Admission,
    Preemption,
    Prefetch,
    Execution,
}

impl Stage {
    pub const ALL: [Stage; 4] = [
        Stage::Admission,
        Stage::Preemption,
        Stage::Prefetch,
        Stage::Execution,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Preemption => "preemption",
            Stage::Prefetch => "prefetch",
            Stage::Execution => "execution",
        }
    }
}

/// Per-stage wall-clock cost per priority-update epoch.
///
/// `add` accumulates real (host) nanoseconds per stage inside the
/// current epoch; `roll` closes the epoch into per-stage Welford
/// summaries. Wall time feeds *only* this profiler — never the virtual
/// clock — so enabling it cannot move a simulation result.
#[derive(Clone, Debug, Default)]
pub struct EpochProfiler {
    pub enabled: bool,
    current: [u64; 4],
    stats: [Welford; 4],
    epochs: u64,
}

impl EpochProfiler {
    pub fn new(enabled: bool) -> Self {
        EpochProfiler {
            enabled,
            ..EpochProfiler::default()
        }
    }

    /// Charge `ns` of wall time to `stage` in the current epoch.
    #[inline]
    pub fn add(&mut self, stage: Stage, ns: Ns) {
        if self.enabled {
            self.current[stage as usize] += ns;
        }
    }

    /// Close the current epoch into the per-stage summaries.
    pub fn roll(&mut self) {
        if !self.enabled {
            return;
        }
        for (acc, stat) in self.current.iter_mut().zip(self.stats.iter_mut()) {
            stat.add(*acc as f64);
            *acc = 0;
        }
        self.epochs += 1;
    }

    /// Epochs closed so far.
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Mean wall-ns per epoch for one stage (0.0 before any roll).
    pub fn mean_ns(&self, stage: Stage) -> f64 {
        let s = &self.stats[stage as usize];
        if s.count() == 0 {
            0.0
        } else {
            s.mean()
        }
    }

    /// Mean total scheduler wall-ns per epoch across all stages.
    pub fn total_mean_ns(&self) -> f64 {
        Stage::ALL.iter().map(|&s| self.mean_ns(s)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_is_exact_below_capacity() {
        let mut r = Reservoir::default();
        for i in 0..100 {
            r.add(i as f64);
        }
        assert_eq!(r.count(), 100);
        let p = r.percentiles();
        assert_eq!(p.len(), 100);
        assert_eq!(p.min(), 0.0);
        assert_eq!(p.max(), 99.0);
    }

    #[test]
    fn reservoir_tracks_percentiles_over_capacity() {
        // 50k samples from a seeded lognormal: the reservoir's p50/p99
        // must land near the exact pipeline's.
        let mut rng = Rng::new(77);
        let mut res = Reservoir::default();
        let mut exact = Vec::with_capacity(50_000);
        for _ in 0..50_000 {
            let x = rng.lognormal(0.0, 1.0);
            res.add(x);
            exact.push(x);
        }
        assert_eq!(res.count(), 50_000);
        let e = Percentiles::from(exact);
        let p = res.percentiles();
        assert_eq!(p.len(), RESERVOIR_N);
        let rel = |a: f64, b: f64| (a - b).abs() / b;
        assert!(rel(p.p(50.0), e.p(50.0)) < 0.10, "p50 {} vs {}", p.p(50.0), e.p(50.0));
        assert!(rel(p.p(99.0), e.p(99.0)) < 0.30, "p99 {} vs {}", p.p(99.0), e.p(99.0));
    }

    #[test]
    fn reservoir_is_deterministic() {
        let feed = |r: &mut Reservoir| {
            let mut rng = Rng::new(5);
            for _ in 0..10_000 {
                r.add(rng.f64());
            }
        };
        let (mut a, mut b) = (Reservoir::default(), Reservoir::default());
        feed(&mut a);
        feed(&mut b);
        assert_eq!(a.percentiles().samples(), b.percentiles().samples());
    }

    #[test]
    fn profiler_rolls_epochs() {
        let mut p = EpochProfiler::new(true);
        p.add(Stage::Admission, 100);
        p.add(Stage::Execution, 300);
        p.roll();
        p.add(Stage::Admission, 300);
        p.roll();
        assert_eq!(p.epochs(), 2);
        assert_eq!(p.mean_ns(Stage::Admission), 200.0);
        assert_eq!(p.mean_ns(Stage::Execution), 150.0);
        assert_eq!(p.mean_ns(Stage::Prefetch), 0.0);
        assert_eq!(p.total_mean_ns(), 350.0);
    }

    #[test]
    fn disabled_profiler_stays_zero() {
        let mut p = EpochProfiler::new(false);
        p.add(Stage::Admission, 100);
        p.roll();
        assert_eq!(p.epochs(), 0);
        assert_eq!(p.total_mean_ns(), 0.0);
    }
}
