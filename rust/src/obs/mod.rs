//! Observability layer: request-lifecycle tracing, bounded-reservoir
//! telemetry, and the perf-ledger schema.
//!
//! Three layers, all default-off and all side-effect-free on the
//! simulation itself:
//!
//! - [`trace`]: a zero-cost-when-off [`TraceSink`] recording typed
//!   events for every request state transition (arrival, promotion,
//!   preemption, swap/prefetch I/O, migration, turn finish), plus the
//!   [`chrome`] exporter that renders a run for `chrome://tracing`.
//! - [`reservoir`]: O(1) fixed-array reservoir percentiles (the Falcon
//!   `Timer` idiom) and the per-stage scheduler-epoch profiler — the
//!   bounded alternative to the exact Vec-push percentile pipeline.
//! - [`ledger`]: the schema behind the per-PR `BENCH_PR<N>.json`
//!   perf trajectory (the matrix runner lives in [`crate::exp`]).
//! - [`gauntlet`]: the schema behind the per-PR `GAUNTLET_PR<N>.json`
//!   scenario-gauntlet scorecard (runner in [`crate::exp`] as well).
//!
//! The determinism contract: with [`ObsConfig::default`] (everything
//! off) no trace buffer exists, no reservoir is fed, no wall clock is
//! read, and no RNG stream is touched — every e2e pin stays
//! byte-identical.

pub mod chrome;
pub mod gauntlet;
pub mod ledger;
pub mod reservoir;
pub mod trace;

pub use reservoir::{EpochProfiler, Reservoir, Stage, RESERVOIR_N};
pub use trace::{text_dump, TraceEvent, TraceRecord, TraceSink};

/// How the [`crate::metrics::Recorder`] summarizes TTFT/TBT latency.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TelemetryMode {
    /// Keep every sample; percentiles are exact (the default — e2e
    /// pins and paper figures rely on it).
    #[default]
    Exact,
    /// Feed bounded reservoirs online; percentiles are sampled with
    /// O(1) memory per metric.
    Reservoir,
}

impl TelemetryMode {
    pub fn by_name(s: &str) -> Option<TelemetryMode> {
        match s {
            "exact" => Some(TelemetryMode::Exact),
            "reservoir" => Some(TelemetryMode::Reservoir),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TelemetryMode::Exact => "exact",
            TelemetryMode::Reservoir => "reservoir",
        }
    }
}

/// The `[obs]` config section: every knob defaults to off/exact.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ObsConfig {
    /// Record the lifecycle trace stream.
    pub trace: bool,
    /// Measure per-stage scheduler wall time per epoch.
    pub profile: bool,
    /// Latency summary mode.
    pub telemetry: TelemetryMode,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_fully_off() {
        let o = ObsConfig::default();
        assert!(!o.trace);
        assert!(!o.profile);
        assert_eq!(o.telemetry, TelemetryMode::Exact);
    }

    #[test]
    fn telemetry_mode_round_trips() {
        for m in [TelemetryMode::Exact, TelemetryMode::Reservoir] {
            assert_eq!(TelemetryMode::by_name(m.label()), Some(m));
        }
        assert_eq!(TelemetryMode::by_name("bogus"), None);
    }
}
