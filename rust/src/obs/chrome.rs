//! Chrome trace-event exporter: renders a trace stream as the JSON
//! array format `chrome://tracing` / Perfetto load directly, keyed by
//! simulation time.
//!
//! Mapping: events with a completion timestamp (swap-out, swap-in,
//! prefetch issue) become complete spans (`"ph": "X"`, `dur` =
//! `done - at`); everything else is an instant (`"ph": "i"`). `pid` is
//! the replica lane (0 for single-engine runs, replica count = router
//! lane), `tid` groups events by subsystem so the viewer stacks
//! lifecycle, swap, prefetch, routing, actor-mailbox, and prefix-cache
//! rows separately. Timestamps
//! are virtual nanoseconds rendered as microseconds (the unit the
//! viewer expects).

use super::trace::{TraceEvent, TraceRecord};
use std::fmt::Write as _;

/// Subsystem row within a process lane.
fn tid(ev: &TraceEvent) -> u32 {
    match ev {
        TraceEvent::SwapOut { .. } | TraceEvent::SwapIn { .. } => 1,
        TraceEvent::PrefetchIssue { .. }
        | TraceEvent::PrefetchClaim { .. }
        | TraceEvent::PrefetchCancel { .. } => 2,
        TraceEvent::Place { .. }
        | TraceEvent::Migrate { .. }
        | TraceEvent::MigrationEvict { .. }
        | TraceEvent::Drain { .. }
        | TraceEvent::Rejoin { .. } => 3,
        TraceEvent::MailboxDepth { .. } => 4,
        TraceEvent::PrefixHit { .. }
        | TraceEvent::PrefixInsert { .. }
        | TraceEvent::PrefixEvict { .. } => 5,
        _ => 0,
    }
}

fn push_arg(args: &mut String, key: &str, val: impl std::fmt::Display) {
    if !args.is_empty() {
        args.push(',');
    }
    let _ = write!(args, "\"{key}\":{val}");
}

/// The `args` object for one event — every payload field, numerically.
fn args_json(ev: &TraceEvent) -> String {
    let mut a = String::new();
    match ev {
        TraceEvent::Arrival { req, turn, tenant } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "turn", turn);
            push_arg(&mut a, "tenant", tenant);
        }
        TraceEvent::Epoch { epoch } => push_arg(&mut a, "epoch", epoch),
        TraceEvent::Promote { req, stall_ns } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "stall_ns", stall_ns);
        }
        TraceEvent::ChunkGrant { req, tokens } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "tokens", tokens);
        }
        TraceEvent::Preempt { req, reason, action, blocks } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "reason", format_args!("\"{reason}\""));
            push_arg(&mut a, "action", format_args!("\"{action}\""));
            push_arg(&mut a, "blocks", blocks);
        }
        TraceEvent::PartialShave { req, evicted, retained } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "evicted", evicted);
            push_arg(&mut a, "retained", retained);
        }
        TraceEvent::Recompute { req, blocks } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "blocks", blocks);
        }
        TraceEvent::SwapOut { req, blocks, bytes, sync, .. }
        | TraceEvent::SwapIn { req, blocks, bytes, sync, .. } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "blocks", blocks);
            push_arg(&mut a, "bytes", bytes);
            push_arg(&mut a, "sync", sync);
        }
        TraceEvent::PrefetchIssue { req, blocks, bytes, .. } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "blocks", blocks);
            push_arg(&mut a, "bytes", bytes);
        }
        TraceEvent::PrefetchClaim { req, ready } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "ready", ready);
        }
        TraceEvent::PrefetchCancel { req, landed } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "landed", landed);
        }
        TraceEvent::TurnFinish { req, turn, last } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "turn", turn);
            push_arg(&mut a, "last", last);
        }
        TraceEvent::Place { req, replica } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "replica", replica);
        }
        TraceEvent::Migrate { req, from, to, blocks } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "from", from);
            push_arg(&mut a, "to", to);
            push_arg(&mut a, "blocks", blocks);
        }
        TraceEvent::MigrationEvict { req, blocks } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "blocks", blocks);
        }
        TraceEvent::Drain { replica } => push_arg(&mut a, "replica", replica),
        TraceEvent::Rejoin { replica } => push_arg(&mut a, "replica", replica),
        TraceEvent::MailboxDepth { actor, depth } => {
            push_arg(&mut a, "actor", actor);
            push_arg(&mut a, "depth", depth);
        }
        TraceEvent::PrefixHit { req, blocks, tokens } => {
            push_arg(&mut a, "req", req);
            push_arg(&mut a, "blocks", blocks);
            push_arg(&mut a, "tokens", tokens);
        }
        TraceEvent::PrefixInsert { group, blocks, depth } => {
            push_arg(&mut a, "group", group);
            push_arg(&mut a, "blocks", blocks);
            push_arg(&mut a, "depth", depth);
        }
        TraceEvent::PrefixEvict { group, depth } => {
            push_arg(&mut a, "group", group);
            push_arg(&mut a, "depth", depth);
        }
    }
    a
}

/// Export one or more trace lanes as a Chrome trace-event JSON object.
///
/// Each `(pid, records)` pair is one process lane — replica index for
/// engine streams, one extra lane for the cluster router. The output is
/// loadable as-is in `chrome://tracing` or <https://ui.perfetto.dev>.
pub fn export(lanes: &[(u32, &[TraceRecord])]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for &(pid, records) in lanes {
        for r in records {
            if !first {
                out.push(',');
            }
            first = false;
            let ts = r.at as f64 / 1000.0;
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"pid\":{},\"tid\":{},\"ts\":{:.3},",
                r.ev.name(),
                pid,
                tid(&r.ev),
                ts
            );
            match r.ev.done() {
                Some(done) => {
                    let dur = done.saturating_sub(r.at) as f64 / 1000.0;
                    let _ = write!(out, "\"ph\":\"X\",\"dur\":{dur:.3},");
                }
                None => {
                    let _ = write!(out, "\"ph\":\"i\",\"s\":\"t\",");
                }
            }
            let _ = write!(out, "\"args\":{{{}}}}}", args_json(&r.ev));
        }
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<TraceRecord> {
        vec![
            TraceRecord { at: 1_000, ev: TraceEvent::Arrival { req: 1, turn: 0, tenant: 2 } },
            TraceRecord {
                at: 2_000,
                ev: TraceEvent::SwapOut { req: 1, blocks: 4, bytes: 4096, sync: false, done: 9_000 },
            },
            TraceRecord {
                at: 3_500,
                ev: TraceEvent::Preempt { req: 1, reason: "pressure", action: "partial_tail", blocks: 8 },
            },
        ]
    }

    /// Minimal structural JSON check: balanced braces/brackets outside
    /// string literals, correct top-level shape.
    fn assert_balanced(s: &str) {
        let (mut brace, mut bracket, mut in_str, mut esc) = (0i64, 0i64, false, false);
        for c in s.chars() {
            if in_str {
                if esc {
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else if c == '"' {
                    in_str = false;
                }
                continue;
            }
            match c {
                '"' => in_str = true,
                '{' => brace += 1,
                '}' => brace -= 1,
                '[' => bracket += 1,
                ']' => bracket -= 1,
                _ => {}
            }
            assert!(brace >= 0 && bracket >= 0, "early close in {s}");
        }
        assert_eq!(brace, 0, "unbalanced braces");
        assert_eq!(bracket, 0, "unbalanced brackets");
        assert!(!in_str, "unterminated string");
    }

    #[test]
    fn export_shape_and_balance() {
        let recs = sample();
        let json = export(&[(0, recs.as_slice())]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("\"displayTimeUnit\":\"ms\"}"));
        assert_balanced(&json);
        assert_eq!(json.matches("\"ph\":").count(), recs.len());
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 1, "one span event");
        assert!(json.contains("\"dur\":7.000"), "9µs - 2µs span: {json}");
        assert!(json.contains("\"reason\":\"pressure\""));
    }

    #[test]
    fn lanes_become_pids() {
        let recs = sample();
        let json = export(&[(0, recs.as_slice()), (3, recs.as_slice())]);
        assert_balanced(&json);
        assert_eq!(json.matches("\"pid\":3").count(), recs.len());
        assert_eq!(json.matches("\"ph\":").count(), 2 * recs.len());
    }

    #[test]
    fn empty_export_is_valid() {
        let json = export(&[]);
        assert_balanced(&json);
        assert!(json.contains("\"traceEvents\":[]"));
    }
}
