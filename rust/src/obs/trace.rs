//! Request-lifecycle tracing: a zero-cost-when-off [`TraceSink`]
//! recording typed events for every request state transition.
//!
//! The sink is a cloneable handle over one shared buffer, so the engine,
//! the swap manager, and (in cluster runs) the router all append to a
//! single ordered stream per replica. When tracing is off the handle
//! holds no buffer and [`TraceSink::emit`] is a branch on `None` —
//! nothing is allocated, no clock is read, no RNG is consumed, which is
//! what keeps the e2e determinism pins byte-identical with `[obs]`
//! disabled.
//!
//! Events carry their *completion* timestamp (`done`) where the
//! underlying operation has duration (swap-out, swap-in, prefetch), so
//! the Chrome exporter can render them as complete (`"ph": "X"`) spans
//! without issue/drain pairing.

use crate::memory::RequestId;
use crate::sim::clock::Ns;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

/// One typed lifecycle event. Variants mirror the taxonomy in
/// DESIGN.md §Observability; every field is plain data so the stream
/// is cheap to record and trivially deterministic to dump.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// A turn became runnable: fresh conversation arrival or a due
    /// follow-up turn entering the waiting queue.
    Arrival { req: RequestId, turn: u32, tenant: u32 },
    /// Priority-update epoch boundary crossed by the scheduler.
    Epoch { epoch: u64 },
    /// A waiting request was promoted into the running batch;
    /// `stall_ns` is the swap-in stall charged to the iteration.
    Promote { req: RequestId, stall_ns: Ns },
    /// Chunked prefill granted another chunk of `tokens` to a request.
    ChunkGrant { req: RequestId, tokens: usize },
    /// Preemption decision taken against a victim. `reason` is the
    /// selection site (`"unadmitted"`, `"pressure"`, `"sweep"`,
    /// `"turn_end"`); `action` is the planner's eviction action label;
    /// `blocks` is the victim's GPU footprint at decision time.
    Preempt {
        req: RequestId,
        reason: &'static str,
        action: &'static str,
        blocks: usize,
    },
    /// Partial-tail shave: only the tail of the victim's block runs was
    /// evicted, the head stayed GPU-resident.
    PartialShave {
        req: RequestId,
        evicted: usize,
        retained: usize,
    },
    /// Victim preempted by dropping KV for recompute (no PCIe traffic).
    Recompute { req: RequestId, blocks: usize },
    /// Swap-out submitted; completes at `done` (== submit time when
    /// `sync`).
    SwapOut {
        req: RequestId,
        blocks: usize,
        bytes: u64,
        sync: bool,
        done: Ns,
    },
    /// Swap-in submitted; completes at `done`.
    SwapIn {
        req: RequestId,
        blocks: usize,
        bytes: u64,
        sync: bool,
        done: Ns,
    },
    /// Lookahead prefetch issued on the background link lane.
    PrefetchIssue {
        req: RequestId,
        blocks: usize,
        bytes: u64,
        done: Ns,
    },
    /// A promotion claimed its prefetch (`ready` = fully landed, else
    /// the residual drain overlaps execution).
    PrefetchClaim { req: RequestId, ready: bool },
    /// A prefetch was canceled (misprediction or memory pressure);
    /// `landed` = the blocks had already arrived and were freed.
    PrefetchCancel { req: RequestId, landed: bool },
    /// A turn emitted its last token.
    TurnFinish { req: RequestId, turn: u32, last: bool },
    /// Router placed a fresh conversation on a replica.
    Place { req: RequestId, replica: u32 },
    /// Router moved a conversation's next turn to a different replica.
    Migrate {
        req: RequestId,
        from: u32,
        to: u32,
        blocks: usize,
    },
    /// Engine-side eviction of a conversation's state for migration.
    MigrationEvict { req: RequestId, blocks: usize },
    /// Router drained a replica: no further placements land on it and
    /// its conversations migrate off at their next turns.
    Drain { replica: u32 },
    /// A drained replica re-entered the placement rotation.
    Rejoin { replica: u32 },
    /// Actor-runtime mailbox depth after an enqueue: `actor` is the
    /// replica index, or the replica count for the router's own work
    /// mailbox (matching the trace-lane numbering).
    MailboxDepth { actor: u32, depth: u32 },
    /// Admission matched a fresh request against the global prefix
    /// cache: `blocks` pool blocks (= `tokens` prompt tokens) are served
    /// from the shared pool instead of being prefilled.
    PrefixHit { req: RequestId, blocks: usize, tokens: usize },
    /// Newly prefilled template blocks were published into the prefix
    /// pool: `blocks` fresh nodes, chain now `depth` blocks deep.
    PrefixInsert { group: u64, blocks: usize, depth: u32 },
    /// Memory pressure evicted the deepest unreferenced prefix-pool
    /// block (refcount 1 — never a block a live request still pins).
    PrefixEvict { group: u64, depth: u32 },
}

impl TraceEvent {
    /// Short stable name (Chrome trace `name` field).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Arrival { .. } => "Arrival",
            TraceEvent::Epoch { .. } => "Epoch",
            TraceEvent::Promote { .. } => "Promote",
            TraceEvent::ChunkGrant { .. } => "ChunkGrant",
            TraceEvent::Preempt { .. } => "Preempt",
            TraceEvent::PartialShave { .. } => "PartialShave",
            TraceEvent::Recompute { .. } => "Recompute",
            TraceEvent::SwapOut { .. } => "SwapOut",
            TraceEvent::SwapIn { .. } => "SwapIn",
            TraceEvent::PrefetchIssue { .. } => "PrefetchIssue",
            TraceEvent::PrefetchClaim { .. } => "PrefetchClaim",
            TraceEvent::PrefetchCancel { .. } => "PrefetchCancel",
            TraceEvent::TurnFinish { .. } => "TurnFinish",
            TraceEvent::Place { .. } => "Place",
            TraceEvent::Migrate { .. } => "Migrate",
            TraceEvent::MigrationEvict { .. } => "MigrationEvict",
            TraceEvent::Drain { .. } => "Drain",
            TraceEvent::Rejoin { .. } => "Rejoin",
            TraceEvent::MailboxDepth { .. } => "MailboxDepth",
            TraceEvent::PrefixHit { .. } => "PrefixHit",
            TraceEvent::PrefixInsert { .. } => "PrefixInsert",
            TraceEvent::PrefixEvict { .. } => "PrefixEvict",
        }
    }

    /// Completion time for events that span an interval.
    pub fn done(&self) -> Option<Ns> {
        match self {
            TraceEvent::SwapOut { done, .. }
            | TraceEvent::SwapIn { done, .. }
            | TraceEvent::PrefetchIssue { done, .. } => Some(*done),
            _ => None,
        }
    }
}

/// One timestamped event.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Virtual (simulation) time of emission.
    pub at: Ns,
    pub ev: TraceEvent,
}

/// Cloneable handle to a trace buffer; `None` buffer = tracing off.
///
/// The buffer is behind `Arc<Mutex<..>>` only so the handle stays
/// `Send` inside engine state — the simulation is single-threaded, so
/// the lock is never contended and emission order is deterministic.
#[derive(Clone, Debug, Default)]
pub struct TraceSink {
    buf: Option<Arc<Mutex<Vec<TraceRecord>>>>,
}

impl TraceSink {
    /// An enabled sink with a fresh shared buffer.
    pub fn on() -> Self {
        TraceSink {
            buf: Some(Arc::new(Mutex::new(Vec::new()))),
        }
    }

    /// A disabled sink (`emit` is a no-op).
    pub fn off() -> Self {
        TraceSink::default()
    }

    pub fn enabled(&self) -> bool {
        self.buf.is_some()
    }

    /// Record one event; no-op (one `None` check) when disabled.
    #[inline]
    pub fn emit(&self, at: Ns, ev: TraceEvent) {
        if let Some(buf) = &self.buf {
            buf.lock().unwrap().push(TraceRecord { at, ev });
        }
    }

    pub fn len(&self) -> usize {
        self.buf.as_ref().map_or(0, |b| b.lock().unwrap().len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take every record out of the shared buffer (emission order).
    pub fn drain(&self) -> Vec<TraceRecord> {
        match &self.buf {
            Some(buf) => std::mem::take(&mut *buf.lock().unwrap()),
            None => Vec::new(),
        }
    }
}

/// Compact line-per-event dump — the byte-identical artifact the
/// determinism tests pin (`{:?}` on plain-data enums is stable).
pub fn text_dump(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for r in records {
        let _ = writeln!(out, "{:>12} {:?}", r.at, r.ev);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_sink_records_nothing() {
        let t = TraceSink::off();
        assert!(!t.enabled());
        t.emit(5, TraceEvent::Epoch { epoch: 1 });
        assert!(t.is_empty());
        assert!(t.drain().is_empty());
    }

    #[test]
    fn clones_share_one_ordered_buffer() {
        let a = TraceSink::on();
        let b = a.clone();
        a.emit(1, TraceEvent::Epoch { epoch: 0 });
        b.emit(2, TraceEvent::TurnFinish { req: 7, turn: 0, last: true });
        a.emit(3, TraceEvent::Epoch { epoch: 1 });
        let recs = a.drain();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].at, 1);
        assert_eq!(recs[1].ev.name(), "TurnFinish");
        assert!(b.is_empty(), "drain empties the shared buffer");
    }

    #[test]
    fn text_dump_is_line_per_event_and_stable() {
        let t = TraceSink::on();
        t.emit(
            10,
            TraceEvent::SwapOut { req: 3, blocks: 4, bytes: 1024, sync: false, done: 20 },
        );
        let recs = t.drain();
        let d1 = text_dump(&recs);
        let d2 = text_dump(&recs);
        assert_eq!(d1, d2);
        assert_eq!(d1.lines().count(), 1);
        assert!(d1.contains("SwapOut"));
        assert_eq!(recs[0].ev.done(), Some(20));
    }
}
