//! Engine-level tests: whole-loop behavior on a small contended testbed
//! (moved here unchanged by the staged-pipeline refactor, plus the
//! preemption-policy coverage).

use super::{ServeOutcome, ServingEngine};
use crate::config::{EngineConfig, GpuSpec, PrefillMode, PreemptionPolicyKind, Preset};
use crate::coordinator::priority::Pattern;
use crate::workload::sharegpt::{generate, ShareGptConfig};
use crate::workload::{ArrivalTrace, Conversation};

/// Small contended testbed: LLaMA-8B timing constants but only a few
/// hundred KV blocks, so preemption pressure appears with ~10
/// conversations.
fn test_preset(gpu_blocks_target: usize) -> Preset {
    let model = crate::config::ModelSpec::llama8b();
    let mut gpu = GpuSpec::a10();
    // Shrink HBM so preset.gpu_blocks() == gpu_blocks_target.
    gpu.hbm_bytes =
        ((model.weight_bytes() + gpu_blocks_target as u64 * model.block_bytes())
            as f64
            / gpu.mem_util) as u64
            + (1 << 20);
    Preset {
        model,
        gpu,
        cpu_swap_bytes: 4096 * 4 * 1024 * 1024, // plenty
    }
}

fn small_workload(n: usize, seed: u64) -> (Vec<Conversation>, ArrivalTrace) {
    let mut cfg = ShareGptConfig::default();
    cfg.mean_turns = 3.0;
    cfg.max_prompt = 256;
    cfg.max_response = 128;
    cfg.mean_think_s = 2.0;
    let convs = generate(&cfg, n, seed);
    let tr = ArrivalTrace::poisson(&convs, 2.0, seed ^ 1);
    (convs, tr)
}

fn run_with(cfg: EngineConfig, blocks: usize, n_conv: usize, seed: u64) -> ServeOutcome {
    let (convs, tr) = small_workload(n_conv, seed);
    let mut e = ServingEngine::new(
        cfg,
        test_preset(blocks),
        Pattern::Markov,
        convs,
        tr,
        seed,
    );
    e.charge_sched_overhead = false; // determinism for tests
    e.run(200_000)
}

#[test]
fn completes_all_conversations_fastswitch() {
    let out = run_with(EngineConfig::fastswitch(), 400, 12, 1);
    assert_eq!(out.recorder.finished_conversations, 12);
    assert!(out.recorder.total_tokens > 0);
    assert!(!out.recorder.ttft().is_empty());
    assert!(!out.recorder.tbt().is_empty());
}

#[test]
fn completes_all_conversations_vllm_baseline() {
    let out = run_with(EngineConfig::vllm_baseline(), 400, 12, 1);
    assert_eq!(out.recorder.finished_conversations, 12);
}

#[test]
fn online_policies_complete_all_conversations() {
    use crate::fairness::PolicyKind;
    for kind in [PolicyKind::Vtc, PolicyKind::SloAware] {
        let mut cfg = EngineConfig::fastswitch();
        cfg.fairness.policy = kind;
        let out = run_with(cfg, 400, 12, 1);
        assert_eq!(
            out.recorder.finished_conversations, 12,
            "{kind:?} lost conversations"
        );
        assert!(out.recorder.total_tokens > 0);
    }
}

#[test]
fn contended_memory_causes_preemptions() {
    let mut cfg = EngineConfig::vllm_baseline();
    cfg.scheduler.priority_update_freq = 0.25; // churn priorities hard
    let out = run_with(cfg, 96, 16, 2);
    assert_eq!(out.recorder.finished_conversations, 16);
    assert!(
        out.recorder.preemptions + out.recorder.recompute_preemptions > 0,
        "expected preemption under contention"
    );
    assert!(out.swap_stats.swap_out_ops > 0);
}

#[test]
fn fastswitch_beats_baseline_on_stall_time() {
    let mut base = EngineConfig::vllm_baseline();
    base.scheduler.priority_update_freq = 0.25;
    let mut fast = EngineConfig::fastswitch();
    fast.scheduler.priority_update_freq = 0.25;
    let ob = run_with(base, 96, 16, 3);
    let of = run_with(fast, 96, 16, 3);
    let (_, swap_b, _) = ob.recorder.stall_breakdown();
    let (_, swap_f, _) = of.recorder.stall_breakdown();
    assert!(
        swap_f < swap_b,
        "fastswitch stall {swap_f} !< baseline {swap_b}"
    );
}

#[test]
fn reuse_reduces_swap_out_blocks() {
    let mut base = EngineConfig::with_dbg();
    base.scheduler.priority_update_freq = 0.25;
    let mut reuse = EngineConfig::with_dbg_reuse();
    reuse.scheduler.priority_update_freq = 0.25;
    let ob = run_with(base, 96, 16, 4);
    let orr = run_with(reuse, 96, 16, 4);
    assert!(orr.reuse_blocks_reused > 0, "reuse must trigger");
    assert!(
        orr.reuse_blocks_transferred < ob.reuse_blocks_transferred,
        "reuse {} !< baseline {}",
        orr.reuse_blocks_transferred,
        ob.reuse_blocks_transferred
    );
}

#[test]
fn dbg_coarser_granularity_than_fixed() {
    let mut base = EngineConfig::vllm_baseline();
    base.scheduler.priority_update_freq = 0.25;
    let mut dbg = EngineConfig::with_dbg();
    dbg.scheduler.priority_update_freq = 0.25;
    let ob = run_with(base, 96, 16, 5);
    let od = run_with(dbg, 96, 16, 5);
    assert!(ob.swap_stats.avg_granularity() < 1.5);
    assert!(
        od.swap_stats.avg_granularity() > 2.0 * ob.swap_stats.avg_granularity(),
        "dbg granularity {} vs fixed {}",
        od.swap_stats.avg_granularity(),
        ob.swap_stats.avg_granularity()
    );
}

#[test]
fn deterministic_given_seed() {
    let a = run_with(EngineConfig::fastswitch(), 128, 8, 7);
    let b = run_with(EngineConfig::fastswitch(), 128, 8, 7);
    assert_eq!(a.span, b.span);
    assert_eq!(a.recorder.total_tokens, b.recorder.total_tokens);
    assert_eq!(a.swap_stats.total_calls, b.swap_stats.total_calls);
}

#[test]
fn chunked_mode_mixes_decodes_with_prefill_chunks() {
    // Under the default chunked scheduler, prompt chunks co-run with
    // decode steps: some iterations must carry both prefill tokens
    // and a non-empty decode batch, and the decode-interference
    // bucket must be charged for them.
    let out = run_with(EngineConfig::fastswitch(), 400, 12, 1);
    let mixed = out
        .recorder
        .iterations
        .iter()
        .any(|s| s.prefill_tokens > 0 && !s.is_prefill && s.batch > 0);
    assert!(mixed, "no mixed decode+prefill iteration observed");
    assert!(out.recorder.decode_interference_ns() > 0);
    assert!(out.recorder.prefill_tokens() > 0);
}

#[test]
fn monolithic_mode_completes_and_stalls_decodes() {
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.prefill_mode = PrefillMode::Monolithic;
    let out = run_with(cfg, 400, 12, 1);
    assert_eq!(out.recorder.finished_conversations, 12);
    // Whole prompts run in exclusive iterations: no mixed ones.
    assert!(out
        .recorder
        .iterations
        .iter()
        .all(|s| s.prefill_tokens == 0 || s.batch == 0 || s.is_prefill));
}

#[test]
fn chunked_caps_prefill_per_iteration() {
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.prefill_chunk = 64;
    cfg.scheduler.max_tokens_per_iter = 96;
    let out = run_with(cfg, 400, 12, 1);
    assert_eq!(out.recorder.finished_conversations, 12);
    assert!(out
        .recorder
        .iterations
        .iter()
        .all(|s| s.prefill_tokens <= 96));
}

#[test]
fn token_budget_auto_sizes_from_roofline() {
    let (convs, tr) = small_workload(4, 1);
    let e = ServingEngine::new(
        EngineConfig::fastswitch(),
        test_preset(400),
        Pattern::Markov,
        convs,
        tr,
        1,
    );
    let b = e.token_budget();
    // max_batch (32) decode claims plus a roofline-sized chunk term.
    assert!(b > 32 && b < 4096, "budget = {b}");
}

#[test]
fn prefetch_enabled_run_completes_and_lands_hits() {
    // Multi-turn think times make pending-turn re-admissions the
    // prefetcher's bread and butter: with lookahead on, speculative
    // swap-ins must land and be claimed, and the workload must drain
    // to exactly the same token totals as the demand-only run.
    let mut cfg = EngineConfig::fastswitch();
    cfg.prefetch.depth = 2;
    let out = run_with(cfg, 400, 12, 1);
    assert_eq!(out.recorder.finished_conversations, 12);
    assert!(out.swap_stats.prefetch_ops > 0, "no speculation issued");
    assert!(out.swap_stats.prefetch_hits > 0, "no prefetch ever claimed");
    assert!(out.swap_stats.prefetch_hit_rate() > 0.0);
    assert!(out
        .recorder
        .iterations
        .iter()
        .any(|s| s.prefetch_inflight > 0));
    let base = run_with(EngineConfig::fastswitch(), 400, 12, 1);
    assert_eq!(base.swap_stats.prefetch_ops, 0, "default stays demand-only");
    assert_eq!(out.recorder.total_tokens, base.recorder.total_tokens);
}

#[test]
fn prefetch_under_contention_completes_and_cancels_safely() {
    // Hard priority churn on a tiny pool: predictions flip, landed
    // prefetches get canceled for pressure/staleness, and the final
    // allocator/CPU-space invariant checks (run by `into_outcome`)
    // must still hold with every conversation served.
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.priority_update_freq = 0.25;
    cfg.prefetch.depth = 2;
    let out = run_with(cfg, 96, 16, 2);
    assert_eq!(out.recorder.finished_conversations, 16);
    assert!(out.swap_stats.prefetch_ops > 0);
}

#[test]
fn prefetch_runs_are_deterministic() {
    let mk = || {
        let mut cfg = EngineConfig::fastswitch();
        cfg.prefetch.depth = 2;
        run_with(cfg, 128, 8, 7)
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.span, b.span);
    assert_eq!(a.recorder.total_tokens, b.recorder.total_tokens);
    assert_eq!(a.swap_stats.prefetch_ops, b.swap_stats.prefetch_ops);
    assert_eq!(a.swap_stats.prefetch_hits, b.swap_stats.prefetch_hits);
    assert_eq!(
        a.swap_stats.prefetch_wasted_bytes,
        b.swap_stats.prefetch_wasted_bytes
    );
}

#[test]
fn ttft_includes_queueing_and_swap_delays() {
    let out = run_with(EngineConfig::vllm_baseline(), 96, 16, 8);
    let ttft = out.recorder.ttft();
    // Tail must exceed median under contention.
    assert!(ttft.p(99.0) > ttft.p(50.0));
}

// ---- preemption policies (the ContextSwitchPlanner integration) ----

#[test]
fn partial_tail_run_completes_with_partial_evictions() {
    // Hard churn on a tiny pool: the deficit-driven sweep must shave
    // tails (not whole victims) at least some of the time, retain
    // blocks, and still drain the workload with the exit invariants
    // (allocator + CPU space, checked by into_outcome) intact.
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.priority_update_freq = 0.25;
    cfg.preemption.policy = PreemptionPolicyKind::PartialTail;
    let out = run_with(cfg, 96, 16, 2);
    assert_eq!(out.recorder.finished_conversations, 16);
    assert!(
        out.recorder.partial_evictions > 0,
        "contended churn must trigger partial tails"
    );
    assert!(out.recorder.blocks_retained > 0);
}

#[test]
fn partial_tail_works_under_sync_swap_and_fixed_blocks() {
    // The vLLM-baseline mechanisms (sync swap-outs → release_tail at
    // submit, fixed-block allocator, no reuse) must also carry the
    // partial path.
    let mut cfg = EngineConfig::vllm_baseline();
    cfg.scheduler.priority_update_freq = 0.25;
    cfg.preemption.policy = PreemptionPolicyKind::PartialTail;
    let out = run_with(cfg, 96, 16, 2);
    assert_eq!(out.recorder.finished_conversations, 16);
    assert!(out.recorder.partial_evictions > 0);
}

#[test]
fn cost_aware_on_the_fast_link_behaves_like_swap_all() {
    // On the A10 testbed the PCIe round trip beats roofline recompute
    // at every servable context, so cost_aware must decide SwapAll
    // everywhere — and then the run is action-for-action identical to
    // the swap_all baseline.
    let mk = |kind| {
        let mut cfg = EngineConfig::fastswitch();
        cfg.scheduler.priority_update_freq = 0.25;
        cfg.preemption.policy = kind;
        run_with(cfg, 96, 16, 2)
    };
    let cost = mk(PreemptionPolicyKind::CostAware);
    assert_eq!(
        cost.recorder.evict_recompute_decisions, 0,
        "the fast link must never pick recompute"
    );
    assert!(cost.recorder.evict_swap_decisions > 0);
    let all = mk(PreemptionPolicyKind::SwapAll);
    assert_eq!(cost.span, all.span, "identical decisions, identical run");
    assert_eq!(cost.recorder.total_tokens, all.recorder.total_tokens);
    assert_eq!(cost.swap_stats.total_bytes, all.swap_stats.total_bytes);
}

#[test]
fn cost_aware_recomputes_on_a_slow_link() {
    // Crippling PCIe 64x flips the crossover: every non-empty mid-turn
    // eviction must come out Recompute, and with ample CPU swap space
    // the recompute preemptions are exactly those decisions.
    let mut preset = test_preset(96);
    preset.gpu.pcie_bw = 0.5e9;
    let mut cfg = EngineConfig::fastswitch();
    cfg.scheduler.priority_update_freq = 0.25;
    cfg.preemption.policy = PreemptionPolicyKind::CostAware;
    let (convs, tr) = small_workload(16, 2);
    let mut e = ServingEngine::new(cfg, preset, Pattern::Markov, convs, tr, 2);
    e.charge_sched_overhead = false;
    let out = e.run(200_000);
    assert_eq!(out.recorder.finished_conversations, 16);
    assert!(out.recorder.evict_recompute_decisions > 0);
    assert_eq!(
        out.recorder.evict_swap_decisions, 0,
        "on the slow link no eviction may choose the round trip"
    );
    assert_eq!(
        out.recorder.recompute_preemptions,
        out.recorder.evict_recompute_decisions,
        "every recompute decision must execute as a recompute preemption"
    );
}

#[test]
fn policy_runs_are_deterministic() {
    for kind in [
        PreemptionPolicyKind::CostAware,
        PreemptionPolicyKind::PartialTail,
    ] {
        let mk = || {
            let mut cfg = EngineConfig::fastswitch();
            cfg.scheduler.priority_update_freq = 0.25;
            cfg.preemption.policy = kind;
            run_with(cfg, 96, 16, 7)
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.span, b.span, "{kind:?}");
        assert_eq!(a.recorder.total_tokens, b.recorder.total_tokens);
        assert_eq!(
            a.recorder.partial_evictions,
            b.recorder.partial_evictions
        );
        assert_eq!(
            a.recorder.recompute_preemptions,
            b.recorder.recompute_preemptions
        );
    }
}

#[test]
fn partial_tail_moves_fewer_blocks_than_swap_all() {
    // The point of the policy: on the same seed/workload, shaving tails
    // moves strictly fewer blocks over PCIe than whole-victim swaps.
    let mk = |kind| {
        let mut cfg = EngineConfig::fastswitch();
        cfg.scheduler.priority_update_freq = 0.25;
        cfg.preemption.policy = kind;
        run_with(cfg, 96, 16, 2)
    };
    let all = mk(PreemptionPolicyKind::SwapAll);
    let partial = mk(PreemptionPolicyKind::PartialTail);
    assert_eq!(partial.recorder.finished_conversations, 16);
    assert!(
        partial.reuse_blocks_transferred < all.reuse_blocks_transferred,
        "partial {} !< swap_all {} blocks transferred out",
        partial.reuse_blocks_transferred,
        all.reuse_blocks_transferred
    );
}
