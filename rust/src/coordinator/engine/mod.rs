//! The per-iteration serving loop (virtual time), as a staged
//! context-switch pipeline.
//!
//! Ties everything together, per Fig. 5 of the paper: the priority
//! scheduler decides admission; the Dynamic Block Group Manager (or the
//! fixed-block baseline) allocates KV; the Multithreading Swap Manager
//! executes context switches (Algorithm 1); the KV Cache Reuse Mechanism
//! minimizes swap-out volume; the roofline perf model advances the clock.
//!
//! The loop is decomposed by pipeline stage — one submodule per stage,
//! all methods on [`ServingEngine`]:
//!
//! - `admission` — arrival/turn handling, the max-model-len rejection
//!   rule, priority refresh, and the scheduler's candidate view.
//! - `preemption` — evictions (whole-victim, cost-aware recompute,
//!   partial tail), promotions (swap-ins), and turn-end context
//!   preservation. Every evict decision is delegated to the
//!   [`crate::coordinator::switch::ContextSwitchPlanner`].
//! - `prefetch` — the speculative swap-in pipeline (lookahead
//!   prediction, budgeted submission, pressure cancellation).
//! - `execution` — one mixed decode+chunked-prefill iteration: grant
//!   resolution, growth allocation, the roofline clock advance, and
//!   idle fast-forward.
//! - `migration` — the cluster front-end hooks (held turns, migration
//!   eviction, load signals).
//!
//! One deliberately *real* measurement: the scheduler's own call-stack
//! time (steps 1–8) is measured in wall-clock and charged to the virtual
//! clock — that is exactly the paper's Fig. 9 "call stack overhead", and
//! it keeps us honest about L3 hot-path cost (<1 % of end-to-end time).

mod admission;
mod execution;
mod migration;
mod preemption;
mod prefetch;
#[cfg(test)]
mod tests;

use crate::block::{buddy::BlockGroupAllocator, fixed::FixedBlockAllocator};
use crate::block::prefix::PrefixIndex;
use crate::block::KvAllocator;
use crate::config::{EngineConfig, Granularity, PrefillMode, Preset};
use crate::coordinator::priority::Pattern;
use crate::coordinator::queue::{CandidateIndex, EpochScratch};
use crate::coordinator::request::RequestTable;
use crate::coordinator::scheduler::IterBudget;
use crate::coordinator::switch::{ContextSwitchPlanner, SwitchCostModel};
use crate::fairness::policy::{build_policy, PriorityPolicy};
use crate::memory::{CpuSwapSpace, RequestId};
use crate::metrics::Recorder;
use crate::obs::{TraceRecord, TraceSink};
use crate::sim::clock::Ns;
use crate::sim::link::PcieLink;
use crate::sim::PerfModel;
use crate::swap::engine::SegmentBuilder;
use crate::swap::manager::SwapManager;
use crate::workload::{ArrivalTrace, Conversation, Turn};

/// Everything a finished simulation reports.
#[derive(Debug)]
pub struct ServeOutcome {
    pub recorder: Recorder,
    pub span: Ns,
    pub iterations: u64,
    pub swap_stats: crate::swap::manager::SwapStats,
    pub reuse_blocks_transferred: u64,
    pub reuse_blocks_reused: u64,
    pub contaminated: u64,
    pub label: String,
    /// Lifecycle trace stream (empty unless `cfg.obs.trace`).
    pub trace: Vec<TraceRecord>,
    /// GPU KV blocks still allocated when the run ended (0 for a fully
    /// drained run — every conversation finished and released its KV).
    pub gpu_blocks_used_final: usize,
    /// GPU KV blocks free at end of run.
    pub gpu_blocks_free_final: usize,
    /// Total GPU KV capacity in blocks (constant over the run).
    pub gpu_blocks_capacity: usize,
    /// CPU swap-space slots still held at end of run.
    pub cpu_blocks_used_final: usize,
    /// Total CPU swap-space capacity in block slots.
    pub cpu_blocks_capacity: usize,
    /// Final virtual-time counters per tenant when an online VTC-family
    /// fairness policy drove priorities (empty otherwise). Sorted by
    /// tenant id.
    pub vtc_counters: Vec<(u32, f64)>,
    /// KV block size in tokens (constant over the run) — lets invariant
    /// audits convert the prefix counters between blocks and tokens.
    pub block_size: usize,
    /// Prefix-pool blocks still published when the run ended (0 when the
    /// cache is disabled; the pool outlives requests by design, so a
    /// drained run with the cache on legitimately reports > 0).
    pub prefix_blocks_final: usize,
    /// Outstanding request pins on prefix-pool nodes at end of run. Must
    /// be 0 once every request finished/rejected/migrated — the dangling
    /// index-entry regression surfaces here.
    pub prefix_pinned_refs_final: u64,
}

impl ServeOutcome {
    pub fn throughput(&self) -> f64 {
        self.recorder.throughput(self.span)
    }
}

/// What [`ServingEngine::evict_for_migration`] hands the cluster router
/// when a conversation's next turn is placed on a different replica: the
/// unserved remainder plus the context the target replica must rebuild.
#[derive(Clone, Debug)]
pub struct MigratedConv {
    pub conv_id: RequestId,
    pub tenant: u32,
    /// Turns not yet served (the next turn first).
    pub remaining: Vec<Turn>,
    /// Context tokens accumulated on the source replica — the target must
    /// re-prefill all of them (its CPU holds no copy).
    pub history_tokens: u64,
    /// Valid CPU-copy blocks dropped on the source replica — the reuse
    /// the migration destroys (the router's
    /// `retransferred_blocks_on_migration` counter).
    pub cpu_copy_blocks: usize,
}

pub(crate) enum Alloc {
    Fixed(FixedBlockAllocator),
    Group(BlockGroupAllocator),
}

impl Alloc {
    pub(crate) fn as_dyn(&mut self) -> &mut dyn KvAllocator {
        match self {
            Alloc::Fixed(a) => a,
            Alloc::Group(a) => a,
        }
    }
    pub(crate) fn as_dyn_ref(&self) -> &dyn KvAllocator {
        match self {
            Alloc::Fixed(a) => a,
            Alloc::Group(a) => a,
        }
    }
}

pub struct ServingEngine {
    cfg: EngineConfig,
    preset: Preset,
    perf: PerfModel,
    alloc: Alloc,
    cpu: CpuSwapSpace,
    reuse: crate::block::reuse::KvCacheReuse,
    /// Cross-request radix prefix index (global prefix cache). Inert —
    /// never matched against, never published to — unless
    /// `cfg.prefix.enabled`.
    prefix: PrefixIndex,
    seg: SegmentBuilder,
    pub mgr: SwapManager,
    /// Source of scheduling priorities: the offline trace or an online
    /// fairness policy (VTC / SLO-aware), per `cfg.fairness`.
    policy: Box<dyn PriorityPolicy>,
    /// All evict/promote decisions (swap_all / cost_aware /
    /// partial_tail) go through this planner.
    planner: ContextSwitchPlanner,
    reqs: RequestTable,
    /// Conversations not yet arrived: (arrival, conversation), sorted desc
    /// so we pop from the back.
    future: Vec<(Ns, Conversation)>,
    /// (request, due-time) for turns waiting out think time.
    pending_turns: Vec<(RequestId, Ns)>,
    pub rec: Recorder,
    /// Lifecycle trace sink — shared with the swap manager so engine
    /// and I/O events interleave in one ordered stream. Off (no buffer)
    /// unless `cfg.obs.trace`.
    trace: TraceSink,
    now: Ns,
    iter: u64,
    epoch_iters: u64,
    last_epoch: u64,
    gpu_blocks: usize,
    block_size: usize,
    /// Per-iteration token budget (decode claims + prefill chunks);
    /// roofline-sized at init when the config says 0.
    iter_budget: u32,
    /// Wall-clock → virtual charging of scheduler overhead (Fig. 9).
    pub charge_sched_overhead: bool,
    /// Cluster mode: turn transitions are *held* for the front-end router
    /// instead of self-scheduled — `end_turn` reports the next turn via
    /// [`ServingEngine::take_released_turns`] and the router decides
    /// placement ([`ServingEngine::fire_turn`] to keep it here,
    /// [`ServingEngine::evict_for_migration`] to move it).
    pub hold_turns: bool,
    /// Next turns awaiting a router placement decision: (request, due).
    released_turns: Vec<(RequestId, Ns)>,
    /// Lookahead prefetcher: predicted re-admissions not yet submitted
    /// (drained across iterations as budget and free blocks allow).
    prefetch_queue: Vec<RequestId>,
    /// Epoch the policy projection was last rebuilt at.
    prefetch_epoch: u64,
    /// When a budget-rejected prefetch becomes submittable again — an
    /// idle engine wakes for the refill instead of sleeping past it.
    prefetch_retry_at: Option<Ns>,
    /// Requests whose context can never fit the prefetch burst budget
    /// (contexts only grow): permanently excluded, so the per-iteration
    /// due-turn scan cannot churn them through allocate/reject cycles.
    prefetch_never_fits: std::collections::HashSet<RequestId>,
    /// Partial-tail evictions whose swap-out is still draining: the
    /// source blocks stay allocated until the op completes, then
    /// `release_reaped` shrinks exactly this many tail blocks (a full
    /// eviction releases the whole table instead).
    partial_pending: std::collections::HashMap<RequestId, usize>,
    /// EMA of recent working-iteration spans (ns) — converts the epoch
    /// lookahead depth into the wall-clock horizon for pending turns.
    iter_span_ema: f64,
    /// Incremental bucketed candidate index — the default scheduler
    /// path ([`crate::coordinator::queue`]). Refreshed from the request
    /// table's dirty set each iteration; byte-identical to the
    /// sort-based oracle. Maintained only when
    /// `cfg.scheduler.incremental` (the sort path ignores it).
    index: CandidateIndex,
    /// Per-epoch scratch arena: candidate/schedule/projection buffers
    /// cleared-not-dropped between iterations.
    scratch: EpochScratch,
}

// A replica actor moves its engine onto an OS thread under the threaded
// cluster executor ([`crate::runtime::actor::threaded`]); the policy and
// planner trait objects carry `Send` supertraits for exactly this.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<ServingEngine>();
};

impl ServingEngine {
    pub fn new(
        cfg: EngineConfig,
        preset: Preset,
        pattern: Pattern,
        convs: Vec<Conversation>,
        arrivals: ArrivalTrace,
        seed: u64,
    ) -> Self {
        let gpu_blocks = preset.gpu_blocks();
        let cpu_blocks = preset.cpu_blocks();
        let block_size = preset.model.block_size;
        let alloc = match cfg.granularity {
            Granularity::FixedBlock => Alloc::Fixed(FixedBlockAllocator::new(gpu_blocks)),
            Granularity::BlockGroup { init_group_blocks } => Alloc::Group(
                BlockGroupAllocator::new(gpu_blocks, init_group_blocks, seed),
            ),
        };
        let perf = PerfModel::new(preset.model.clone(), preset.gpu.clone());
        let link = PcieLink::new(preset.gpu.clone());
        let mut mgr = SwapManager::new(cfg.swap_mode, cfg.dispatch, &cfg.swap_cost, link);
        mgr.configure_prefetch(cfg.prefetch.io_budget * preset.gpu.pcie_bw);
        let obs = cfg.obs;
        let trace = if obs.trace {
            TraceSink::on()
        } else {
            TraceSink::off()
        };
        mgr.set_trace(trace.clone());
        let seg = SegmentBuilder::new(preset.model.clone(), cfg.granularity);
        let reuse = crate::block::reuse::KvCacheReuse::new(cfg.reuse, block_size);
        let policy = build_policy(
            &cfg.fairness,
            pattern,
            cfg.scheduler.priority_levels,
            seed,
        );
        let planner = ContextSwitchPlanner::new(
            &cfg.preemption,
            SwitchCostModel::new(
                preset.model.block_bytes(),
                preset.gpu.clone(),
                perf.clone(),
            ),
        );
        let epoch_iters = (1.0 / cfg.scheduler.priority_update_freq).round().max(1.0) as u64;
        let iter_budget = if cfg.scheduler.max_tokens_per_iter == 0 {
            perf.suggest_token_budget(cfg.scheduler.max_batch)
        } else {
            cfg.scheduler.max_tokens_per_iter as u32
        };

        // Seeded with a one-request decode iteration; converges onto the
        // real cadence within a few working iterations.
        let iter_span_seed = perf.decode_iter_ns(1, 0) as f64;
        let mut future: Vec<(Ns, Conversation)> = arrivals
            .entries
            .iter()
            .map(|e| (e.arrival, convs[e.conversation as usize].clone()))
            .collect();
        future.sort_by(|a, b| b.0.cmp(&a.0)); // pop() yields earliest

        ServingEngine {
            cfg,
            preset,
            perf,
            alloc,
            cpu: CpuSwapSpace::new(cpu_blocks),
            reuse,
            prefix: PrefixIndex::new(),
            seg,
            mgr,
            policy,
            planner,
            reqs: RequestTable::default(),
            future,
            pending_turns: Vec::new(),
            rec: Recorder::with_obs(obs.telemetry, obs.profile),
            trace,
            now: 0,
            iter: 0,
            epoch_iters,
            last_epoch: u64::MAX,
            gpu_blocks,
            block_size,
            iter_budget,
            charge_sched_overhead: true,
            hold_turns: false,
            released_turns: Vec::new(),
            prefetch_queue: Vec::new(),
            prefetch_epoch: u64::MAX,
            prefetch_retry_at: None,
            prefetch_never_fits: std::collections::HashSet::new(),
            partial_pending: std::collections::HashMap::new(),
            iter_span_ema: iter_span_seed,
            index: CandidateIndex::new(gpu_blocks),
            scratch: EpochScratch::default(),
        }
    }

    pub fn now(&self) -> Ns {
        self.now
    }

    /// The resolved per-iteration token budget (after roofline
    /// auto-sizing).
    pub fn token_budget(&self) -> u32 {
        self.iter_budget
    }

    fn budget(&self) -> IterBudget {
        match self.cfg.scheduler.prefill_mode {
            PrefillMode::Monolithic => IterBudget::monolithic(),
            PrefillMode::Chunked => IterBudget::chunked(
                self.iter_budget,
                self.cfg.scheduler.prefill_chunk as u32,
            ),
        }
    }

    pub fn iterations(&self) -> u64 {
        self.iter
    }

    /// The active preemption policy's label (experiment reporting).
    pub fn preemption_policy_label(&self) -> &'static str {
        self.planner.label()
    }
}
