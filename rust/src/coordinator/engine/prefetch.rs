//! Lookahead swap-in prefetch (speculative context switching): the
//! engine projects the next priority epochs' re-admissions and issues
//! their swap-ins early as budgeted background PCIe traffic.

use super::ServingEngine;
use crate::block::KvAllocator;
use crate::coordinator::request::{KvLocation, ReqState, Request};
use crate::coordinator::scheduler::predict_admission;
use crate::memory::RequestId;
use crate::sim::clock::Ns;
use crate::swap::manager::{PrefetchCancel, PrefetchSubmit};

impl ServingEngine {
    /// Rebuild the prediction of upcoming re-admissions, once per
    /// policy epoch: (a) currently swapped-out requests the live
    /// priority policy is projected to promote within `depth` epochs
    /// ([`predict_admission`] — side-effect-free), and (b) stale landed
    /// prefetches the new projection no longer wants are canceled, their
    /// blocks returned (the CPU copy stays the valid version under the
    /// contamination rules).
    pub(super) fn rebuild_prefetch_predictions(&mut self, epoch: u64, depth: u64) {
        if self.cfg.scheduler.incremental {
            self.rebuild_predictions_incremental(epoch, depth);
        } else {
            self.rebuild_predictions_sorted(epoch, depth);
        }
        // Misprediction cleanup: a landed prefetch for a request that is
        // still parked off-GPU and no longer projected (priority flip,
        // pending turn migrated away) is canceled.
        self.cancel_stale_prefetches(depth);
    }

    /// Oracle projection path: full candidate collection +
    /// [`predict_admission`] — O(n log n) per lookahead offset.
    fn rebuild_predictions_sorted(&mut self, epoch: u64, depth: u64) {
        let cands = self.candidates();
        // One projection per candidate via `project_priorities`, which
        // leaves the policy's sequential state (the trace memo) parked
        // at the live epoch — querying `priority_of(epoch + k)` directly
        // would force every later live refresh to replay the walk from
        // epoch 0.
        let projections: std::collections::HashMap<RequestId, Vec<i64>> = cands
            .iter()
            .map(|c| {
                let tenant = self.reqs.get(c.id).tenant();
                (
                    c.id,
                    self.policy.project_priorities(c.id, tenant, epoch, depth),
                )
            })
            .collect();
        let predicted = predict_admission(
            &cands,
            self.gpu_blocks,
            self.cfg.scheduler.max_batch,
            depth,
            |id, offset| projections[&id][(offset - 1) as usize],
        );
        self.prefetch_queue = predicted;
    }

    /// Incremental projection path: the candidate index re-keys only the
    /// entries whose projected priority moved, and the projection rows
    /// live in the epoch-scratch arena (flat, row-major, binary-searched
    /// by sorted id) — no per-epoch allocation in steady state beyond
    /// the policy's own projection rows.
    fn rebuild_predictions_incremental(&mut self, epoch: u64, depth: u64) {
        self.refresh_index();
        let mut scratch = std::mem::take(&mut self.scratch);
        // The projection buffers are split out of the arena for the
        // call: the closure reads them while `predict_into` holds the
        // rest of the scratch mutably.
        let mut proj_ids = std::mem::take(&mut scratch.proj_ids);
        let mut proj = std::mem::take(&mut scratch.proj);
        proj_ids.clear();
        proj.clear();
        proj_ids.extend(self.index.ids());
        proj_ids.sort_unstable();
        for &id in proj_ids.iter() {
            let tenant = self.reqs.get(id).tenant();
            let row = self.policy.project_priorities(id, tenant, epoch, depth);
            debug_assert_eq!(row.len(), depth as usize);
            proj.extend_from_slice(&row);
        }
        self.index.predict_into(
            self.gpu_blocks,
            self.cfg.scheduler.max_batch,
            depth,
            |id, offset| {
                let i = proj_ids.binary_search(&id).expect("projected id indexed");
                proj[i * depth as usize + (offset - 1) as usize]
            },
            &mut scratch,
        );
        self.prefetch_queue.clear();
        self.prefetch_queue.extend_from_slice(&scratch.promote_out);
        scratch.proj_ids = proj_ids;
        scratch.proj = proj;
        self.scratch = scratch;
    }

    /// Shared misprediction cleanup (see
    /// [`ServingEngine::rebuild_prefetch_predictions`]).
    fn cancel_stale_prefetches(&mut self, depth: u64) {
        for id in self.mgr.prefetched_ids() {
            if self.prefetch_queue.contains(&id) || !self.reqs.contains(id) {
                continue;
            }
            let r = self.reqs.get(id);
            let parked = matches!(r.state, ReqState::SwappedOut | ReqState::WaitingTurn);
            let due_soon = self
                .pending_turns
                .iter()
                .any(|&(p, t)| p == id && t <= self.now.saturating_add(self.horizon_ns(depth)));
            if !parked || due_soon {
                continue;
            }
            if self.mgr.prefetch_ready(id, self.now) {
                if let Some(PrefetchCancel::Freed { .. }) =
                    self.mgr.cancel_prefetch(id, self.now)
                {
                    self.alloc.as_dyn().release(id);
                    // The speculative residency is gone: re-key.
                    self.reqs.touch(id);
                }
            }
        }
    }

    /// The epoch lookahead depth expressed in wall-clock nanoseconds
    /// (drives the pending-turn horizon).
    pub(super) fn horizon_ns(&self, depth: u64) -> Ns {
        (depth as f64 * self.epoch_iters as f64 * self.iter_span_ema) as Ns
    }

    /// The per-iteration prefetch pass: refresh the I/O budget, fold
    /// pending turns whose think time expires within the lookahead
    /// horizon into the prediction (their re-admission is a
    /// near-certainty — the §3.3 multi-turn workload), and submit as
    /// many speculative swap-ins as free blocks, link idleness, and the
    /// byte budget allow. Speculation never preempts and never waits:
    /// anything it cannot do right now is retried next iteration.
    pub(super) fn prefetch_pass(&mut self) {
        let depth = self.cfg.prefetch.depth;
        if depth == 0 {
            return;
        }
        self.prefetch_retry_at = None; // recomputed below if still starved
        self.mgr.refill_prefetch_budget(self.now);
        let epoch = self.iter / self.epoch_iters;
        if epoch != self.prefetch_epoch {
            self.prefetch_epoch = epoch;
            self.rebuild_prefetch_predictions(epoch, depth);
        }
        // Pending turns are re-scanned every iteration (they appear
        // mid-epoch at turn ends). The submission order is rebuilt so
        // every within-horizon due turn runs first, earliest due time
        // first, with the policy projection behind them.
        let horizon = self.horizon_ns(depth);
        let mut due: Vec<(Ns, RequestId)> = self
            .pending_turns
            .iter()
            .filter(|&&(_, t)| t <= self.now.saturating_add(horizon))
            .map(|&(id, t)| (t, id))
            .collect();
        due.sort_unstable();
        let mut ordered: Vec<RequestId> = due.into_iter().map(|(_, id)| id).collect();
        for &id in &self.prefetch_queue {
            if !ordered.contains(&id) {
                ordered.push(id);
            }
        }
        self.prefetch_queue = ordered;
        // Headroom: leave at least one growth block per admitted
        // request, so speculation never forces the grow pass into
        // preempting a real victim next iteration.
        let headroom = self
            .reqs
            .iter()
            .filter(|q| matches!(q.state, ReqState::Running | ReqState::Prefilling))
            .count();
        let mut i = 0;
        while i < self.prefetch_queue.len() {
            let id = self.prefetch_queue[i];
            if !self.reqs.contains(id)
                || self.mgr.prefetch_pending(id)
                || self.prefetch_never_fits.contains(&id)
            {
                self.prefetch_queue.remove(i);
                continue;
            }
            let r = self.reqs.get(id);
            let eligible = r.kv == KvLocation::Cpu
                && r.tokens_in_cache > 0
                && matches!(r.state, ReqState::SwappedOut | ReqState::WaitingTurn);
            if !eligible {
                self.prefetch_queue.remove(i);
                continue;
            }
            if self.mgr.swap_out_inflight(id).is_some() {
                // The CPU copy is still being written: retry after drain.
                i += 1;
                continue;
            }
            // Cheap pre-flight before touching the allocator: the op
            // moves every context block, so its bytes are exactly
            // n × block_bytes.
            let n = Request::blocks_for(r.tokens_in_cache, self.block_size);
            let bytes = n as u64 * self.preset.model.block_bytes();
            match self.mgr.prefetch_admissible(bytes, self.now) {
                PrefetchSubmit::Started => {}
                PrefetchSubmit::RejectedTooLarge => {
                    // Can never fit the burst budget (contexts only
                    // grow): exclude the request permanently so the
                    // due-turn scan cannot churn it back in.
                    self.prefetch_never_fits.insert(id);
                    self.prefetch_queue.remove(i);
                    continue;
                }
                PrefetchSubmit::RejectedBudget => {
                    // Bucket dry: wake exactly when the refill covers it.
                    self.prefetch_retry_at =
                        self.mgr.prefetch_budget_eta(bytes, self.now);
                    break;
                }
                PrefetchSubmit::RejectedBusy => {
                    break; // demand traffic owns the link: back off
                }
            }
            if self.alloc.as_dyn_ref().available_blocks() < n + headroom {
                break; // no free blocks — prefetch never preempts for space
            }
            let Some(blocks) = self.alloc.as_dyn().allocate(id, n) else {
                break;
            };
            let op = self.build_swap_in_op(id, &blocks);
            // Whether the submit sticks or the blocks bounce right back,
            // this request's residency/prefetch view changed: re-key.
            self.reqs.touch(id);
            match self.mgr.submit_prefetch(op, self.now) {
                PrefetchSubmit::Started => {
                    self.prefetch_queue.remove(i);
                }
                _ => {
                    // Pre-flight said yes, submit said no — can only be
                    // a racing state change; give the blocks back.
                    self.alloc.as_dyn().release(id);
                    break;
                }
            }
        }
    }

    /// Pressure valve: reclaim the GPU blocks of one unclaimed prefetch
    /// — demand allocation always outranks speculation, so a
    /// (mis)predicted prefetch is evicted before any real victim is
    /// preempted. Landed prefetches free immediately; an in-flight one
    /// is canceled and its short drain is waited out (still far cheaper
    /// than a preemption round-trip). Victims are picked landed-first,
    /// then lowest priority. The victim's CPU copy stays its valid KV
    /// version. Returns the time the blocks are free (≥ `now` when a
    /// drain was waited on), or `None` if there was nothing to reclaim.
    pub(super) fn cancel_one_prefetch_for_pressure(&mut self, keep: RequestId) -> Option<Ns> {
        let mut victims: Vec<(bool, i64, RequestId)> = self
            .mgr
            .prefetched_ids()
            .into_iter()
            .filter(|&v| v != keep && self.reqs.contains(v))
            .map(|v| {
                (
                    // false sorts first: landed (freeable now) preferred.
                    !self.mgr.prefetch_ready(v, self.now),
                    self.reqs.get(v).priority,
                    v,
                )
            })
            .collect();
        victims.sort_unstable();
        let &(_, _, victim) = victims.first()?;
        match self.mgr.cancel_prefetch(victim, self.now)? {
            PrefetchCancel::Freed { .. } => {
                self.alloc.as_dyn().release(victim);
                // Blocks and prefetch-pending status changed under the
                // victim's feet: re-key it in the candidate index.
                self.reqs.touch(victim);
                Some(self.now)
            }
            PrefetchCancel::Draining { done } => {
                // Account the wait like any other pressure drain so the
                // conflict bucket still explains all recorded swap stall.
                self.mgr.record_conflict(done.saturating_sub(self.now));
                let drained = self.mgr.reap_prefetch_drains(done);
                self.release_reaped(drained);
                Some(done)
            }
        }
    }
}
