//! Admission and turn handling: arrivals, think-time turn transitions,
//! the max-model-len rejection rule, priority refresh, and the
//! scheduler's candidate view of every schedulable request.

use super::ServingEngine;
use crate::block::KvAllocator;
use crate::config::PrefillMode;
use crate::coordinator::request::{KvLocation, ReqState, Request};
use crate::coordinator::scheduler::Candidate;
use crate::fairness::TenantId;
use crate::memory::RequestId;
use crate::obs::TraceEvent;
use crate::swap::manager::PrefetchCancel;

impl ServingEngine {
    /// Admission rule: a turn whose full context (plus the first-token
    /// slot) cannot fit the whole GPU KV space can never be served —
    /// reject the conversation (vLLM's max-model-len check).
    pub(super) fn reject_if_oversized(&mut self, id: RequestId) -> bool {
        let r = self.reqs.get(id);
        let worst = r.turn_total_tokens() + 1;
        if Request::blocks_for(worst, self.block_size) <= self.gpu_blocks {
            return false;
        }
        // A rejected conversation may hold speculatively prefetched GPU
        // blocks: free them now (or let an in-flight transfer drain —
        // `reap_prefetch_drains` frees the blocks then).
        match self.mgr.cancel_prefetch(id, self.now) {
            Some(PrefetchCancel::Draining { .. }) => {}
            _ => {
                self.alloc.as_dyn().release(id);
            }
        }
        self.cpu.drop_request(id);
        self.reuse.forget(id);
        self.prefix.release(id);
        let r = self.reqs.get_mut(id);
        r.state = ReqState::Finished;
        r.kv = KvLocation::None;
        self.rec.rejected_conversations += 1;
        true
    }

    /// Global prefix cache, admission side: match a fresh conversation's
    /// shared template against the index and pin the longest cached
    /// chain, so only the uncached suffix needs prefilling (and only it
    /// is VTC-charged — prefill charges are per applied chunk). Turn-0
    /// only: later turns' block positions no longer align with the
    /// template.
    fn try_prefix_match(&mut self, id: RequestId) {
        if !self.cfg.prefix.enabled {
            return;
        }
        let r = self.reqs.get(id);
        let Some(p) = r.conv.prefix else { return };
        // Cap the match one token short of the prompt: the chunk that
        // completes the (shrunk) prefill still emits the turn's first
        // token, so served outputs are byte-identical to a cache miss.
        let max_tokens = p.tokens.min(r.conv.turns[0].prompt_tokens.saturating_sub(1));
        let max_blocks = max_tokens / self.block_size as u32;
        if max_blocks == 0 {
            return;
        }
        let depth = self.prefix.acquire(id, p.group, max_blocks);
        if depth == 0 {
            return;
        }
        let tokens = depth * self.block_size as u32;
        let r = self.reqs.get_mut(id);
        r.prefix_tokens = tokens;
        r.prefill_target = r.prefill_target.saturating_sub(tokens);
        self.rec.prefix_hits += 1;
        self.rec.prefix_hit_blocks += depth as u64;
        self.rec.prefix_saved_tokens += tokens as u64;
        self.trace.emit(
            self.now,
            TraceEvent::PrefixHit {
                req: id,
                blocks: depth as usize,
                tokens: tokens as usize,
            },
        );
    }

    pub(super) fn admit_arrivals(&mut self) {
        while self.future.last().is_some_and(|(t, _)| *t <= self.now) {
            let (t, conv) = self.future.pop().unwrap();
            let id = conv.id;
            let tenant = conv.tenant;
            let r = Request::new(id, conv, t);
            self.rec.turn_arrival(id, 0, t, tenant);
            self.trace.emit(t, TraceEvent::Arrival { req: id, turn: 0, tenant });
            self.reqs.insert(r);
            if !self.reject_if_oversized(id) {
                self.try_prefix_match(id);
            }
        }
        // Turns whose think time elapsed AND whose turn-end swap-out has
        // drained (requests still in SwappingOutTurnEnd stay pending and
        // fire right after harvest transitions them).
        let mut due = Vec::new();
        let reqs = &self.reqs;
        self.pending_turns.retain(|&(id, t)| {
            if t <= self.now && reqs.get(id).state == ReqState::WaitingTurn {
                due.push((id, t));
                false
            } else {
                true
            }
        });
        for (id, t) in due {
            let r = self.reqs.get_mut(id);
            r.advance_turn(t.max(r.turn_arrival));
            let turn = r.turn as u32;
            let arr = r.turn_arrival;
            let tenant = r.tenant();
            self.rec.turn_arrival(id, turn, arr, tenant);
            self.trace.emit(arr, TraceEvent::Arrival { req: id, turn, tenant });
            // A later turn may have grown past the servable context.
            self.reject_if_oversized(id);
        }
    }

    pub(super) fn update_priorities(&mut self) {
        let epoch = self.iter / self.epoch_iters;
        if epoch == self.last_epoch {
            return;
        }
        self.last_epoch = epoch;
        self.trace.emit(self.now, TraceEvent::Epoch { epoch });
        // Fold the per-stage wall-clock accumulators into the epoch
        // statistics at the same boundary the priorities refresh on (the
        // very first epoch has accumulated nothing yet — skip it).
        if self.iter > 0 {
            self.rec.profiler.roll();
        }
        // Live (unfinished) requests and the distinct tenants backing
        // them; finished requests hold no GPU/CPU state, so their stale
        // priorities are irrelevant.
        let live: Vec<(RequestId, TenantId)> = self
            .reqs
            .iter()
            .filter(|r| r.state != ReqState::Finished)
            .map(|r| (r.id, r.tenant()))
            .collect();
        let mut active: Vec<TenantId> = live.iter().map(|&(_, t)| t).collect();
        active.sort_unstable();
        active.dedup();
        self.policy.on_schedule(epoch, &active);
        for (id, tenant) in live {
            let p = self.policy.priority_of(id, tenant, epoch);
            self.cpu.set_priority(id, p);
            // Write (and dirty) the table only when the score actually
            // moved: unchanged parked requests must stay clean so the
            // incremental index re-keys O(moved) entries per epoch, not
            // O(live).
            if self.reqs.get(id).priority != p {
                self.reqs.get_mut(id).priority = p;
            }
        }
    }

    /// Blocks to grow `r` by a prefill grant of `take` tokens. The grant
    /// that completes the prompt also emits the turn's first output
    /// token, whose KV occupies a slot too; with `take == rem == 0`
    /// (a decode-ready request) that degenerates to the next decode
    /// slot — exactly what re-admission must reserve.
    pub(super) fn prefill_blocks(&self, r: &Request, take: u32) -> usize {
        let rem = r.prefill_remaining();
        let extra = u64::from(take == rem);
        let after = r.tokens_in_cache + take as u64 + extra;
        Request::blocks_for(after, self.block_size)
            .saturating_sub(Request::blocks_for(r.tokens_in_cache, self.block_size))
    }

    /// The largest prefill grant admission must budget blocks for: one
    /// chunk (chunked mode) or the whole remaining prompt (monolithic
    /// all-or-nothing admission).
    pub(super) fn admit_take(&self, r: &Request) -> u32 {
        let rem = r.prefill_remaining();
        match self.cfg.scheduler.prefill_mode {
            PrefillMode::Monolithic => rem,
            PrefillMode::Chunked => (self.cfg.scheduler.prefill_chunk as u32).min(rem),
        }
    }

    pub(super) fn chunk_blocks(&self, r: &Request) -> usize {
        self.prefill_blocks(r, self.admit_take(r))
    }

    /// States the scheduler sees at all; everything else is parked
    /// (think time, draining turn-end swap-out) or finished. Shared by
    /// the sort-path collection and the incremental index refresh.
    pub(super) fn schedulable(state: ReqState) -> bool {
        matches!(
            state,
            ReqState::Running
                | ReqState::Prefilling
                | ReqState::SwappingIn
                | ReqState::Queued
                | ReqState::SwappedOut
                | ReqState::PartiallyResident
        )
    }

    /// The scheduler's view of one schedulable request — the single
    /// source of candidate truth for both scheduler paths: the sort
    /// path maps it over every live request, the incremental path
    /// re-evaluates it for dirty requests only.
    pub(super) fn candidate_for(&self, r: &Request) -> Candidate {
        let held = self.alloc.as_dyn_ref().table(r.id).len();
        // Off-GPU candidates normally hold no blocks (a draining
        // async swap-out's source blocks are counted conservatively
        // on top of the full re-admission ask — see `schedule`'s
        // transient-inflation note). A *prefetched* candidate is
        // the exception: its context blocks are already resident,
        // so only the remainder of the ask is fresh demand.
        let full_swap_in = |r: &Request| {
            let full = Request::blocks_for(r.tokens_in_cache, self.block_size)
                + self.chunk_blocks(r);
            if self.mgr.prefetch_pending(r.id) {
                full.saturating_sub(held)
            } else {
                full
            }
        };
        let needed = match r.state {
            ReqState::Running => {
                Request::blocks_for(r.tokens_in_cache + 1, self.block_size)
                    .saturating_sub(held)
            }
            ReqState::Prefilling => self.chunk_blocks(r),
            ReqState::SwappingIn => 0,
            ReqState::SwappedOut => full_swap_in(r),
            // Partial-tail eviction: the head is still resident,
            // so re-admission needs only the missing tail plus
            // this iteration's growth. (While the tail swap-out
            // drains, `held` still counts the draining source
            // blocks — the same conservative transient as a
            // draining full swap-out.)
            ReqState::PartiallyResident => {
                (Request::blocks_for(r.tokens_in_cache, self.block_size)
                    + self.chunk_blocks(r))
                .saturating_sub(held)
            }
            ReqState::Queued => {
                if r.kv == KvLocation::Cpu {
                    full_swap_in(r)
                } else {
                    self.chunk_blocks(r)
                }
            }
            _ => 0,
        };
        Candidate {
            id: r.id,
            priority: r.priority,
            turn_arrival: r.turn_arrival,
            // Queued-with-CPU-KV and partially-resident requests
            // behave like SwappedOut for the scheduler (need
            // promotion, not a fresh start).
            state: if (r.state == ReqState::Queued && r.kv == KvLocation::Cpu)
                || r.state == ReqState::PartiallyResident
            {
                ReqState::SwappedOut
            } else {
                r.state
            },
            blocks_held: held,
            blocks_needed: needed,
            prefill_remaining: r.prefill_remaining(),
        }
    }

    /// Sort-path candidate collection into a reusable buffer (cleared
    /// first) — the oracle's input, O(live requests) per call.
    pub(super) fn collect_candidates_into(&self, out: &mut Vec<Candidate>) {
        out.clear();
        out.extend(
            self.reqs
                .iter()
                .filter(|r| Self::schedulable(r.state))
                .map(|r| self.candidate_for(r)),
        );
    }

    pub(super) fn candidates(&self) -> Vec<Candidate> {
        let mut out = Vec::new();
        self.collect_candidates_into(&mut out);
        out
    }

    /// Sync the incremental candidate index with every request the
    /// table marked dirty since the last refresh: still-schedulable
    /// requests are re-keyed from their live state, everything else
    /// (parked, finished, migrated away) drops out of the index. Cost
    /// is O(dirty log n) — untouched entries are never revisited.
    pub(super) fn refresh_index(&mut self) {
        let mut dirty = std::mem::take(&mut self.scratch.dirty);
        self.reqs.drain_dirty_into(&mut dirty);
        for &id in dirty.iter() {
            let cand = match self.reqs.try_get(id) {
                Some(r) if Self::schedulable(r.state) => Some(self.candidate_for(r)),
                _ => None,
            };
            match cand {
                Some(c) => self.index.upsert(c),
                None => {
                    self.index.remove(id);
                }
            }
        }
        self.scratch.dirty = dirty;
    }
}
