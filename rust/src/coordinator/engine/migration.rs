//! Cluster front-end hooks (see [`crate::cluster`]): router-driven
//! arrival dispatch, held-turn placement, migration eviction, and the
//! load signals placement policies consume.

use super::{MigratedConv, ServingEngine};
use crate::block::KvAllocator;
use crate::coordinator::request::ReqState;
use crate::memory::RequestId;
use crate::obs::TraceEvent;
use crate::sim::clock::Ns;
use crate::swap::manager::PrefetchCancel;
use crate::workload::{Conversation, Turn};

impl ServingEngine {
    /// Enqueue a conversation arriving at virtual time `at` (the cluster
    /// router's dispatch path; `future` stays sorted descending so
    /// `pop()` still yields the earliest arrival).
    pub fn push_arrival(&mut self, conv: Conversation, at: Ns) {
        let idx = self.future.partition_point(|&(t, _)| t > at);
        self.future.insert(idx, (at, conv));
    }

    /// Drain the next-turn events held back by `hold_turns`: (request,
    /// due time after think time). The router must answer each with
    /// [`ServingEngine::fire_turn`] or
    /// [`ServingEngine::evict_for_migration`].
    pub fn take_released_turns(&mut self) -> Vec<(RequestId, Ns)> {
        std::mem::take(&mut self.released_turns)
    }

    /// Router kept the conversation on this replica: schedule its held
    /// next turn at `due` through the normal pending-turn path (the
    /// turn's KV context is still on this replica's CPU).
    pub fn fire_turn(&mut self, id: RequestId, due: Ns) {
        debug_assert!(self.reqs.contains(id));
        self.pending_turns.push((id, due));
    }

    /// Router moved the conversation to another replica: drop every local
    /// trace of it (GPU blocks, CPU copies, reuse state) and hand back
    /// the unserved remainder. Only valid for a conversation whose held
    /// turn has not been fired — i.e. it is waiting out think time with
    /// more turns to go. Returns `None` if the conversation meanwhile
    /// terminated here (e.g. oversize rejection).
    pub fn evict_for_migration(&mut self, id: RequestId) -> Option<MigratedConv> {
        if !self.reqs.contains(id) {
            return None;
        }
        let r = self.reqs.get(id);
        // A turn-end swap-out may still be on the wire
        // (SwappingOutTurnEnd): its content was fixed at submit, so the
        // remainder can migrate now, but the op itself keeps draining —
        // the source blocks stay allocated and visible to the conflict /
        // pressure paths until its completion event, exactly like any
        // other in-flight swap-out (`release_reaped` tolerates the
        // record being gone by then).
        if !matches!(
            r.state,
            ReqState::WaitingTurn | ReqState::SwappingOutTurnEnd
        ) || r.is_last_turn()
        {
            return None;
        }
        let history_tokens = r.turn_total_tokens();
        let remaining: Vec<Turn> = r.conv.turns[r.turn + 1..].to_vec();
        let tenant = r.tenant();
        let cpu_copy_blocks = self.cpu.valid_logical(id).len();
        let draining = self.mgr.swap_out_inflight(id).is_some();
        // A speculative prefetch may hold GPU blocks for this
        // conversation: cancel it. A landed one frees with the release
        // below; an in-flight one keeps draining and frees at reap
        // (same tolerance as the draining swap-out).
        let prefetch_draining = matches!(
            self.mgr.cancel_prefetch(id, self.now),
            Some(PrefetchCancel::Draining { .. })
        );
        if !draining && !prefetch_draining {
            self.alloc.as_dyn().release(id);
        }
        self.trace.emit(
            self.now,
            TraceEvent::MigrationEvict {
                req: id,
                blocks: cpu_copy_blocks,
            },
        );
        self.cpu.drop_request(id);
        self.reuse.forget(id);
        // Drop the prefix-pool pins too. Without this, a migrated
        // conversation's matched path stays pinned forever: the pool
        // nodes can never be evicted and `pinned_refs` dangles — the
        // thundering-herd drain regression in `prefix_e2e`.
        self.prefix.release(id);
        // Remove the record entirely: the conversation may return to this
        // replica later and re-insert under the same id; a stale Finished
        // entry would leak and be rescanned every iteration.
        let _ = self.reqs.remove(id);
        Some(MigratedConv {
            conv_id: id,
            tenant,
            remaining,
            history_tokens,
            cpu_copy_blocks,
        })
    }

    /// Does this replica still have internally schedulable work? A
    /// request parked in `WaitingTurn` whose next turn the router holds
    /// does NOT count — only the router can make it progress. In-flight
    /// swap operations DO count: an evicted conversation's draining
    /// swap-out still holds GPU source blocks that only a step can reap.
    pub fn has_pending_work(&self) -> bool {
        if !self.future.is_empty() || !self.pending_turns.is_empty() {
            return true;
        }
        if self.mgr.ongoing_in_count() > 0 || self.mgr.ongoing_out_count() > 0 {
            return true;
        }
        // A canceled prefetch still draining holds GPU blocks only a
        // step can reap. (Live unclaimed prefetches belong to requests
        // already counted below.)
        if self.mgr.prefetch_draining_count() > 0 {
            return true;
        }
        self.reqs
            .iter()
            .any(|r| !matches!(r.state, ReqState::Finished | ReqState::WaitingTurn))
    }

    /// GPU KV blocks currently allocated (placement load signal).
    pub fn gpu_blocks_in_use(&self) -> usize {
        self.alloc.as_dyn_ref().space().used_blocks()
    }

    /// Admission backlog: dispatched-but-unserved arrivals, scheduled
    /// pending turns, and requests waiting for GPU residency (placement
    /// load signal).
    pub fn backlog(&self) -> usize {
        self.future.len()
            + self.pending_turns.len()
            + self
                .reqs
                .iter()
                .filter(|r| {
                    matches!(
                        r.state,
                        ReqState::Queued
                            | ReqState::SwappedOut
                            | ReqState::PartiallyResident
                    )
                })
                .count()
    }

    /// Max decode batch (normalizes the backlog in load scores).
    pub fn max_batch(&self) -> usize {
        self.cfg.scheduler.max_batch
    }

    /// The placement load snapshot in one call — what a replica actor
    /// reports in every [`crate::runtime::actor::RouterMsg::Status`] and
    /// what the deterministic executor reads synchronously at each
    /// placement decision.
    pub fn load_snapshot(&self) -> crate::cluster::placement::ReplicaLoad {
        crate::cluster::placement::ReplicaLoad {
            blocks_in_use: self.gpu_blocks_in_use(),
            gpu_blocks: self.gpu_capacity_blocks(),
            backlog: self.backlog(),
            max_batch: self.max_batch(),
            prefix_groups: self.prefix.group_depths(),
        }
    }

    /// Testing/experiment access.
    pub fn request_state(&self, id: RequestId) -> Option<ReqState> {
        if self.reqs.contains(id) {
            Some(self.reqs.get(id).state)
        } else {
            None
        }
    }

    pub fn gpu_capacity_blocks(&self) -> usize {
        self.gpu_blocks
    }
}
