//! The execution stage: one scheduler iteration — admission, grant
//! resolution, growth allocation under pressure, the mixed
//! decode+chunked-prefill roofline advance, and idle fast-forward —
//! plus run-to-completion and outcome finalization.

use std::time::Instant;

use super::{ServeOutcome, ServingEngine};
use crate::block::KvAllocator;
use crate::config::PreemptionPolicyKind;
use crate::coordinator::request::{ReqState, Request};
use crate::coordinator::scheduler::schedule;
use crate::coordinator::switch::{ContextSwitchPlanner, VictimRank};
use crate::memory::{BlockId, RequestId};
use crate::metrics::IterationSample;
use crate::obs::{Stage, TraceEvent};
use crate::sim::clock::{to_secs, Ns};

impl ServingEngine {
    /// Advance one scheduler iteration. Returns false when all work is
    /// done.
    pub fn step(&mut self) -> bool {
        // In-flight ops gate the exit too: an evicted conversation's
        // draining swap-out (cluster migration) still holds GPU blocks
        // after its record is gone; a step must reap it. Single-engine
        // serving never hits this — live ops imply a live request.
        if self.reqs.all_finished()
            && self.future.is_empty()
            && self.mgr.next_event().is_none()
        {
            return false;
        }
        let wall0 = Instant::now();
        // Per-stage wall-clock profiling (telemetry only — never charged
        // to the virtual clock). `None` when profiling is off, so the
        // default path takes no `Instant::now` reads here.
        let mut seg_t = self.rec.profiler.enabled.then(Instant::now);
        self.admit_arrivals();
        self.harvest_async();
        self.update_priorities();

        // The scratch arena is moved out for the iteration (borrow
        // split: the schedule it holds is read while `self` is mutated)
        // and restored once the admission machinery is done with it.
        let mut scratch = std::mem::take(&mut self.scratch);
        if self.cfg.scheduler.incremental {
            self.refresh_index();
            self.index.schedule_into(
                self.gpu_blocks,
                self.cfg.scheduler.max_batch,
                self.budget(),
                &mut scratch,
            );
        } else {
            // Sort-based oracle path: still drain the dirty set so it
            // cannot grow without bound while the index sits idle.
            self.reqs.drain_dirty_into(&mut scratch.dirty);
            self.collect_candidates_into(&mut scratch.cands);
            scratch.sched = schedule(
                &scratch.cands,
                self.gpu_blocks,
                self.cfg.scheduler.max_batch,
                self.budget(),
            );
        }
        let sched = &scratch.sched;
        if let Some(t) = seg_t {
            self.rec
                .profiler
                .add(Stage::Admission, t.elapsed().as_nanos() as u64);
            seg_t = Some(Instant::now());
        }

        let mut stall: Ns = 0;

        // Preemptions first (frees blocks for promotions). The planner
        // decides per victim: swap-all / cost-aware recompute for
        // whole-victim evictions, or — under `partial_tail` — a
        // deficit-driven sweep that evicts only the minimal tails the
        // admitted set actually needs.
        if self.planner.kind() == PreemptionPolicyKind::PartialTail {
            stall += self.partial_preemption_sweep(sched);
        } else {
            for &id in &sched.preempt {
                stall += self.evict_unadmitted(id);
            }
        }

        // Estimate the iteration for the adaptive strategy.
        let running_ids: Vec<RequestId> = sched
            .keep
            .iter()
            .copied()
            .filter(|&id| self.reqs.get(id).state == ReqState::Running)
            .collect();
        // Context length includes pooled prefix blocks: they are read by
        // attention even though this request never prefilled them.
        let ctx_total: u64 = running_ids
            .iter()
            .map(|&id| {
                let r = self.reqs.get(id);
                r.tokens_in_cache + r.prefix_tokens as u64
            })
            .sum();
        let batch_now = running_ids.len();
        let avg_ctx = if batch_now > 0 {
            ctx_total as f64 / batch_now as f64
        } else {
            0.0
        };
        let iter_hint = self.perf.decode_iter_ns(batch_now.max(1), ctx_total);

        let mut new_blocks: Vec<BlockId> = Vec::new();

        // Promotions (swap-ins).
        for &id in &sched.promote {
            if let Some((s, blocks)) = self.promote(id, iter_hint, batch_now, avg_ctx) {
                stall = stall.max(s);
                new_blocks.extend(blocks);
            }
        }

        // Fresh starts (first prefill or recompute).
        for &id in &sched.start {
            self.reqs.get_mut(id).state = ReqState::Prefilling;
        }

        // Resolve the token grants against post-admission reality: a
        // grant is void if its request is mid swap-in (async promote) or
        // failed to promote; allocator pressure below can still preempt
        // a granted request, so the sets are re-filtered afterwards.
        let mut decode_set: Vec<RequestId> = Vec::new();
        let mut prefill_take: Vec<(RequestId, u32)> = Vec::new();
        for g in &sched.grants {
            let r = self.reqs.get(g.id);
            match r.state {
                ReqState::Running if g.decode > 0 => decode_set.push(g.id),
                ReqState::Prefilling if g.prefill > 0 => {
                    let take = g.prefill.min(r.prefill_remaining());
                    if take > 0 {
                        self.trace.emit(
                            self.now,
                            TraceEvent::ChunkGrant {
                                req: g.id,
                                tokens: take as usize,
                            },
                        );
                        prefill_take.push((g.id, take));
                    }
                }
                _ => {}
            }
        }
        // The schedule has been fully consumed: give the arena back so
        // the prefetch pass (and the next iteration) can reuse it.
        self.scratch = scratch;

        // Growth allocation for this iteration's grants (a decode slot
        // or a chunk's blocks each); preempt lowest-priority victims on
        // failure.
        let mut grow: Vec<(RequestId, usize)> = decode_set
            .iter()
            .map(|&id| {
                let r = self.reqs.get(id);
                let need = Request::blocks_for(r.tokens_in_cache + 1, self.block_size)
                    .saturating_sub(self.alloc.as_dyn_ref().table(id).len());
                (id, need)
            })
            .chain(prefill_take.iter().map(|&(id, take)| {
                let r = self.reqs.get(id);
                (id, self.prefill_blocks(r, take))
            }))
            .collect();
        grow.sort_by_key(|&(id, _)| std::cmp::Reverse(self.reqs.get(id).priority));
        for (id, need) in grow {
            // A victim preempted earlier in this very loop grows no more.
            let resident = matches!(
                self.reqs.get(id).state,
                ReqState::Running | ReqState::Prefilling
            );
            if need == 0 || !resident {
                continue;
            }
            loop {
                if let Some(b) = self.alloc.as_dyn().allocate(id, need) {
                    new_blocks.extend(b);
                    // Residency grew outside the request table: mark the
                    // grower dirty so the index re-reads `blocks_held`.
                    self.reqs.touch(id);
                    break;
                }
                // Pressure order: (0) reclaim a speculative prefetch —
                // demand growth outranks speculation; (1) KV-cache
                // conflict resolution — wait for an in-flight swap-out
                // to release its source blocks (Algorithm 1, step 3.1);
                // (2) evict the lowest-priority admitted victim (the
                // planner chooses whole swap, recompute, or a partial
                // tail of exactly `need` blocks); (3) preempt `id`
                // itself.
                if let Some(t) = self.cancel_one_prefetch_for_pressure(id) {
                    stall = stall.max(t.saturating_sub(self.now));
                    continue;
                }
                if let Some(t) = self.drain_one_swap_out(self.now) {
                    stall = stall.max(t.saturating_sub(self.now));
                    continue;
                }
                // (2.5) Reclaim speculative pool state before any live
                // victim: evict the deepest unreferenced prefix block —
                // shared blocks a request still pins are never touched.
                if self.cfg.prefix.enabled {
                    if let Some((group, depth, _)) =
                        self.prefix.evict_one(self.alloc.as_dyn())
                    {
                        self.rec.prefix_evicted_blocks += 1;
                        self.trace
                            .emit(self.now, TraceEvent::PrefixEvict { group, depth });
                        continue;
                    }
                }
                let ranks: Vec<VictimRank> = self
                    .reqs
                    .iter()
                    .filter(|r| {
                        r.id != id
                            && matches!(r.state, ReqState::Running | ReqState::Prefilling)
                    })
                    .map(|r| VictimRank {
                        id: r.id,
                        priority: r.priority,
                        turn_arrival: r.turn_arrival,
                    })
                    .collect();
                match ContextSwitchPlanner::select_victim(&ranks) {
                    Some(v) => stall += self.evict_for_pressure(v, need),
                    None => {
                        // Partially-resident heads (created only by the
                        // partial_tail policy) are reclaimed before the
                        // grower sacrifices itself.
                        let partial: Vec<VictimRank> = self
                            .reqs
                            .iter()
                            .filter(|r| {
                                r.id != id && r.state == ReqState::PartiallyResident
                            })
                            .map(|r| VictimRank {
                                id: r.id,
                                priority: r.priority,
                                turn_arrival: r.turn_arrival,
                            })
                            .collect();
                        if let Some(v) = ContextSwitchPlanner::select_victim(&partial) {
                            stall += self.preempt(v, false);
                        } else {
                            stall += self.preempt(id, false);
                            break;
                        }
                    }
                }
            }
        }
        let _ = &new_blocks; // retained for tests/metrics hooks

        // Drop grants whose request lost residency to pressure
        // preemption (their partial prefill progress is preserved for
        // re-admission).
        decode_set.retain(|&id| self.reqs.get(id).state == ReqState::Running);
        prefill_take.retain(|&(id, _)| self.reqs.get(id).state == ReqState::Prefilling);
        if let Some(t) = seg_t {
            self.rec
                .profiler
                .add(Stage::Preemption, t.elapsed().as_nanos() as u64);
            seg_t = Some(Instant::now());
        }

        // ---- execute: one mixed decode + chunked-prefill iteration ----
        let sched_ns = if self.charge_sched_overhead {
            wall0.elapsed().as_nanos() as Ns
        } else {
            0
        };

        let decode_batch = decode_set.len();
        let decode_ctx: u64 = decode_set
            .iter()
            .map(|&id| {
                let r = self.reqs.get(id);
                r.tokens_in_cache + r.prefix_tokens as u64
            })
            .sum();
        // Decode-ready requests the budget (or a monolithic prefill)
        // held back this iteration — the decode-interference population.
        let blocked_decodes = self
            .reqs
            .iter()
            .filter(|r| r.state == ReqState::Running)
            .count()
            .saturating_sub(decode_batch);

        // Requests that emit a token at the end of this iteration.
        let mut emitters: Vec<RequestId> = decode_set.clone();
        let mut prefill_new = 0u64;
        let mut prefill_ctx = 0u64;
        for &(id, take) in &prefill_take {
            let r = self.reqs.get_mut(id);
            let tenant = r.tenant();
            prefill_ctx += r.tokens_in_cache + r.prefix_tokens as u64;
            prefill_new += take as u64;
            if r.apply_prefill(take) {
                // The completing chunk emits the turn's next output token
                // (first token on a fresh turn; generation simply
                // continues after a recompute-preemption).
                emitters.push(id);
            }
            // Charge the prefill service to the tenant's virtual-token
            // account chunk-by-chunk: a long prompt accrues virtual
            // tokens as it progresses and cannot dodge the fairness
            // accounting by prefilling atomically. (The emitted token is
            // charged with the emitters below.)
            self.policy.on_tokens(tenant, take as u64, 0);
        }
        // Publish newly prefilled template blocks into the prefix pool
        // (opportunistic: one GPU block always stays in reserve, and a
        // refused allocation just means the chain stops short). A second
        // pass so the `get_mut` prefill loop above holds no borrows.
        if self.cfg.prefix.enabled {
            for &(id, _) in &prefill_take {
                let r = self.reqs.get(id);
                let Some(p) = (if r.turn == 0 { r.conv.prefix } else { None }) else {
                    continue;
                };
                // Absolute template position reached: pooled tokens plus
                // this request's own prefill progress, capped at the
                // template length.
                let abs = r.prefix_tokens as u64 + r.prefill_done as u64;
                let depth_target =
                    (abs.min(p.tokens as u64) / self.block_size as u64) as u32;
                if depth_target == 0 {
                    continue;
                }
                let inserted =
                    self.prefix
                        .publish(self.alloc.as_dyn(), p.group, depth_target, 1);
                if inserted > 0 {
                    self.rec.prefix_inserts += inserted as u64;
                    self.trace.emit(
                        self.now,
                        TraceEvent::PrefixInsert {
                            group: p.group,
                            blocks: inserted as usize,
                            depth: depth_target,
                        },
                    );
                }
            }
        }
        for &id in &decode_set {
            let r = self.reqs.get_mut(id);
            r.generated += 1;
            r.tokens_in_cache += 1;
        }
        let dur = self
            .perf
            .mixed_iter_ns(decode_batch, decode_ctx, prefill_new, prefill_ctx);
        // Decode-interference stall: the extra latency decodes suffer
        // from co-running chunks, or the full iteration when prefill
        // work ran while decode-ready requests sat idle.
        let decode_block_ns: Ns = if prefill_new == 0 {
            0
        } else if decode_batch > 0 {
            dur.saturating_sub(self.perf.decode_iter_ns(decode_batch, decode_ctx))
        } else if blocked_decodes > 0 {
            dur
        } else {
            0
        };
        let pure_prefill = prefill_new > 0 && decode_batch == 0;

        let tokens_made = emitters.len() as u32;
        let iter_end = self.now + stall + sched_ns + dur;
        self.now = iter_end;

        let mut turn_ends: Vec<RequestId> = Vec::new();
        for id in emitters {
            let (turn, tenant, arrival, first, gap) = {
                let r = self.reqs.get_mut(id);
                // `generated` was already incremented for this emission,
                // so 1 marks the turn's first token.
                let first = r.generated == 1;
                let gap = r.last_emit.map(|t| iter_end.saturating_sub(t));
                r.last_emit = Some(iter_end);
                (r.turn as u32, r.tenant(), r.turn_arrival, first, gap)
            };
            // One decode token of service; TTFT/TBT feedback for the
            // SLO-aware policy.
            self.policy.on_tokens(tenant, 0, 1);
            if first {
                self.policy
                    .on_ttft(tenant, to_secs(iter_end.saturating_sub(arrival)));
            } else if let Some(g) = gap {
                self.policy.on_tbt(tenant, to_secs(g));
            }
            self.rec.token(id, turn, iter_end);
            if self.reqs.get(id).turn_done() {
                turn_ends.push(id);
            }
        }
        // Turn-end swap-outs: synchronous engines stall here too (vLLM
        // blocks until the copy completes), after the tokens were emitted.
        let mut post_stall: Ns = 0;
        for id in turn_ends {
            post_stall += self.end_turn(id);
        }
        self.now += post_stall;
        let stall = stall + post_stall;
        if let Some(t) = seg_t {
            self.rec
                .profiler
                .add(Stage::Execution, t.elapsed().as_nanos() as u64);
            seg_t = Some(Instant::now());
        }

        // Track the working-iteration cadence (idle ticks excluded) —
        // the prefetcher's epoch-to-wall-clock conversion — then give
        // speculation its turn on whatever the iteration left idle.
        if dur > 0 {
            self.iter_span_ema =
                0.9 * self.iter_span_ema + 0.1 * (dur + stall + sched_ns) as f64;
        }
        self.prefetch_pass();
        if let Some(t) = seg_t {
            self.rec
                .profiler
                .add(Stage::Prefetch, t.elapsed().as_nanos() as u64);
        }

        let waiting_on_swap = self
            .reqs
            .iter()
            .filter(|r| r.state == ReqState::SwappingIn)
            .count() as u32;

        self.rec.iteration(IterationSample {
            at: self.now,
            inference_ns: dur,
            swap_stall_ns: stall,
            sched_overhead_ns: sched_ns,
            tokens: tokens_made,
            is_prefill: pure_prefill,
            prefill_tokens: prefill_new as u32,
            decode_block_ns,
            // Mixed/decode iterations: the actual decode set; pure
            // prefill: the scheduled running batch.
            batch: if pure_prefill {
                batch_now as u32
            } else {
                decode_batch as u32
            },
            waiting_on_swap,
            prefetch_inflight: self.mgr.prefetch_count() as u32,
        });
        self.iter += 1;

        // Idle fast-forward: nothing admitted and nothing running — jump
        // to the next event instead of spinning.
        if dur == 0 && stall == 0 {
            let next_arrival = self.future.last().map(|(t, _)| *t);
            // A pending turn only fires once its swap-out drains, so the
            // effective wake time is max(think-time due, event).
            let next_turn = self
                .pending_turns
                .iter()
                .map(|&(id, t)| {
                    let drain = self
                        .mgr
                        .swap_out_inflight(id)
                        .unwrap_or(self.now);
                    t.max(drain)
                })
                .min();
            let next_swap = self.mgr.next_event();
            // Prefetch lead time: an otherwise idle engine must wake
            // `horizon` *before* a pending turn is due (not at it), or
            // the speculative swap-in would never get to run during the
            // think time. Turns already prefetched or already inside the
            // horizon are excluded — no 1-ns spin.
            let depth = self.cfg.prefetch.depth;
            let prefetch_wake = if depth > 0 {
                let horizon = self.horizon_ns(depth);
                self.pending_turns
                    .iter()
                    .filter(|&&(id, _)| !self.mgr.prefetch_pending(id))
                    .map(|&(_, t)| t.saturating_sub(horizon))
                    .filter(|&w| w > self.now)
                    .min()
            } else {
                None
            };
            // A budget-starved prefetch wakes the engine at the refill
            // instant instead of sleeping until the turn is due.
            let budget_wake = self.prefetch_retry_at.filter(|&t| t > self.now);
            // More speculative work queued behind the prefetch that owns
            // the link right now (RejectedBusy): wake when it completes,
            // or turn 2's lead time is silently lost.
            let link_wake = if depth > 0 && !self.prefetch_queue.is_empty() {
                self.mgr.next_prefetch_completion(self.now)
            } else {
                None
            };
            let nxt = [
                next_arrival,
                next_turn,
                next_swap,
                prefetch_wake,
                budget_wake,
                link_wake,
            ]
            .into_iter()
            .flatten()
            .min();
            if let Some(t) = nxt {
                self.now = self.now.max(t);
            } else if self.reqs.all_finished() && self.future.is_empty() {
                return false;
            } else {
                self.now += 1_000_000; // 1 ms safety tick
            }
        }
        true
    }

    /// Run to completion (or `max_iters`). Returns the outcome summary.
    pub fn run(mut self, max_iters: u64) -> ServeOutcome {
        while self.iter < max_iters {
            if !self.step() {
                break;
            }
        }
        self.into_outcome()
    }

    /// Advance up to `max_steps` iterations for an actor-runtime driver
    /// ([`crate::runtime::actor`]). Counts every [`ServingEngine::step`]
    /// call taken (including a final no-progress one, matching the
    /// pre-actor router's step accounting) and stops early when the run
    /// finishes or — with `stop_on_release` — as soon as a held turn is
    /// released, so the router hears about it with minimal lag. Returns
    /// the number of steps taken.
    pub fn step_chunk(&mut self, max_steps: u64, stop_on_release: bool) -> u64 {
        let mut taken = 0u64;
        while taken < max_steps {
            let more = self.step();
            taken += 1;
            if !more {
                break;
            }
            if stop_on_release && !self.released_turns.is_empty() {
                break;
            }
        }
        taken
    }

    /// Finalize a router-driven engine: invariant checks + outcome
    /// summary (the tail of [`ServingEngine::run`]).
    pub fn into_outcome(self) -> ServeOutcome {
        let alloc = self.alloc.as_dyn_ref();
        alloc.space().check_invariants();
        self.cpu.check_invariants();
        let space = alloc.space();
        ServeOutcome {
            span: self.now,
            iterations: self.iter,
            swap_stats: self.mgr.stats.clone(),
            reuse_blocks_transferred: self.reuse.blocks_transferred_out,
            reuse_blocks_reused: self.reuse.blocks_reused,
            contaminated: self.cpu.total_contaminated,
            label: self.cfg.label.clone(),
            trace: self.trace.drain(),
            gpu_blocks_used_final: space.used_blocks(),
            gpu_blocks_free_final: space.free_blocks(),
            gpu_blocks_capacity: space.capacity(),
            cpu_blocks_used_final: self.cpu.used_slots(),
            cpu_blocks_capacity: self.cpu.capacity(),
            vtc_counters: self.policy.vtc_counters().unwrap_or_default(),
            block_size: self.block_size,
            prefix_blocks_final: self.prefix.live_blocks(),
            prefix_pinned_refs_final: self.prefix.pinned_refs(),
            recorder: self.rec,
        }
    }
}
