//! The preemption / promotion stage: evictions (whole-victim swap,
//! cost-aware recompute, partial tail), swap-ins, async-completion
//! harvesting, and turn-end context preservation.
//!
//! Per-victim eviction decisions are delegated to the
//! [`crate::coordinator::switch::ContextSwitchPlanner`]; this module
//! only *executes* the chosen [`EvictionAction`]. The one exception is
//! the `partial_tail` membership sweep — an inherently multi-victim
//! decision (how much to shave off whom, given the admitted set's
//! deficit) that is selected by planner kind rather than the per-victim
//! trait. With the default `swap_all` policy the execution paths are
//! exactly the pre-refactor behavior, bit-for-bit.

use super::ServingEngine;
use crate::block::KvAllocator;
use crate::config::SwapMode;
use crate::coordinator::request::{KvLocation, ReqState, Request};
use crate::coordinator::scheduler::Schedule;
use crate::coordinator::switch::{
    ContextSwitchPlanner, EvictionAction, VictimCtx, VictimRank,
};
use crate::memory::{BlockId, RequestId};
use crate::obs::TraceEvent;
use crate::sim::clock::Ns;
use crate::sim::link::Direction;
use crate::swap::engine::BlockMove;
use crate::swap::manager::{PrefetchClaim, SwapInDecision};
use crate::swap::op::SwapOp;

impl ServingEngine {
    /// After a swap-in finished reading the CPU copy: keep it as a
    /// backup (reuse on) or free it (vLLM semantics).
    pub(super) fn release_cpu_copy_after_swap_in(&mut self, id: RequestId) {
        if self.reuse.enabled() {
            self.cpu.set_required(id, false);
        } else {
            self.cpu.drop_request(id);
            self.reuse.forget(id);
        }
    }

    pub(super) fn harvest_async(&mut self) {
        for id in self.mgr.poll_completed(self.now) {
            let r = self.reqs.get_mut(id);
            debug_assert_eq!(r.state, ReqState::SwappingIn);
            r.state = if r.prefill_remaining() > 0 {
                ReqState::Prefilling
            } else {
                ReqState::Running
            };
            r.kv = KvLocation::Gpu;
            self.release_cpu_copy_after_swap_in(id);
        }
        let reaped = self.mgr.reap_swap_outs(self.now);
        self.release_reaped(reaped);
        let drained = self.mgr.reap_prefetch_drains(self.now);
        self.release_reaped(drained);
    }

    /// A swap-out drained: free its GPU source blocks and finish the
    /// turn-end transition. (Reuse state was committed at submit; readers
    /// are barriered on the event.) A partial-tail eviction frees only
    /// the evicted suffix — the resident head stays allocated.
    pub(super) fn release_reaped(&mut self, ids: Vec<RequestId>) {
        for id in ids {
            match self.partial_pending.remove(&id) {
                Some(n) => {
                    self.alloc.as_dyn().release_tail(id, n);
                }
                None => {
                    self.alloc.as_dyn().release(id);
                }
            }
            if !self.reqs.contains(id) {
                // Evicted mid-drain (cluster migration): the record is
                // gone; only the source blocks needed freeing.
                continue;
            }
            let r = self.reqs.get_mut(id);
            if r.state == ReqState::SwappingOutTurnEnd {
                r.state = ReqState::WaitingTurn;
            }
        }
    }

    /// Memory-pressure conflict resolution (§3.2): wait for the earliest
    /// in-flight swap-out, release its blocks, and charge the wait.
    /// Returns the synchronization point, or None if nothing is in
    /// flight.
    pub(super) fn drain_one_swap_out(&mut self, at_least: Ns) -> Option<Ns> {
        let t = self.mgr.next_out_event()?.max(at_least);
        let wait = t.saturating_sub(at_least);
        self.mgr.record_conflict(wait);
        let reaped = self.mgr.reap_swap_outs(t);
        self.release_reaped(reaped);
        Some(t)
    }

    /// Recompute-preemption: drop the KV entirely and re-prefill it at
    /// re-admission — vLLM's fallback when the CPU swap space is
    /// exhausted, and the `cost_aware` policy's choice when the model
    /// says compute is cheaper than the PCIe round trip.
    pub(super) fn recompute_preempt(&mut self, id: RequestId, turn_end: bool) -> Ns {
        self.trace.emit(
            self.now,
            TraceEvent::Recompute {
                req: id,
                blocks: self.alloc.as_dyn_ref().table(id).len(),
            },
        );
        self.alloc.as_dyn().release(id);
        self.cpu.drop_request(id);
        self.reuse.forget(id);
        let r = self.reqs.get_mut(id);
        r.drop_context();
        r.state = if turn_end {
            // Lost context at turn end: the next turn will recompute.
            ReqState::WaitingTurn
        } else {
            ReqState::Queued
        };
        self.rec.recompute_preemptions += 1;
        0
    }

    /// Whole-victim eviction decided by the planner (the scheduler
    /// removed the victim from the admitted set entirely): swap-all or
    /// cost-aware recompute. Partial tails never apply here — the
    /// scheduler's capacity math assumes the victim's blocks free up.
    pub(super) fn evict_unadmitted(&mut self, id: RequestId) -> Ns {
        let held = self.alloc.as_dyn_ref().table(id).len();
        let tokens = self.reqs.get(id).tokens_in_cache;
        if held == 0 || tokens == 0 {
            // Nothing to move and nothing to recompute: the "eviction"
            // is a pure state transition, not a swap-vs-recompute
            // decision point — take the baseline path uncounted.
            return self.preempt(id, false);
        }
        let ctx = VictimCtx {
            id,
            tokens_in_cache: tokens,
            blocks_held: held,
            blocks_wanted: held,
            full: true,
        };
        let action = self.planner.decide_eviction(&ctx);
        self.trace.emit(
            self.now,
            TraceEvent::Preempt {
                req: id,
                reason: "unadmitted",
                action: action.label(),
                blocks: held,
            },
        );
        match action {
            EvictionAction::Recompute => {
                self.rec.evict_recompute_decisions += 1;
                self.recompute_preempt(id, false)
            }
            _ => {
                self.rec.evict_swap_decisions += 1;
                self.preempt(id, false)
            }
        }
    }

    /// Pressure eviction of one victim during growth allocation: the
    /// planner sees exactly how many blocks the allocation needs and may
    /// answer with a partial tail of that size, a cost-aware recompute,
    /// or the whole-victim swap.
    pub(super) fn evict_for_pressure(&mut self, victim: RequestId, need: usize) -> Ns {
        let held = self.alloc.as_dyn_ref().table(victim).len();
        let tokens = self.reqs.get(victim).tokens_in_cache;
        if held == 0 || tokens == 0 {
            // Pure state transition (see `evict_unadmitted`).
            return self.preempt(victim, false);
        }
        let ctx = VictimCtx {
            id: victim,
            tokens_in_cache: tokens,
            blocks_held: held,
            blocks_wanted: need,
            full: false,
        };
        let action = self.planner.decide_eviction(&ctx);
        self.trace.emit(
            self.now,
            TraceEvent::Preempt {
                req: victim,
                reason: "pressure",
                action: action.label(),
                blocks: held,
            },
        );
        match action {
            EvictionAction::PartialTail { blocks } => self.preempt_tail(victim, blocks),
            EvictionAction::Recompute => {
                self.rec.evict_recompute_decisions += 1;
                self.recompute_preempt(victim, false)
            }
            EvictionAction::SwapAll => {
                self.rec.evict_swap_decisions += 1;
                self.preempt(victim, false)
            }
        }
    }

    /// The `partial_tail` membership sweep: instead of evicting every
    /// un-admitted victim whole, free only the admitted set's block
    /// *deficit* — shaving victims' tails lowest-priority-first, one
    /// partial [`crate::swap::op::SwapOp`] per shaved run. Victims whose
    /// blocks are not actually needed keep full residency (maximum KV
    /// locality, in the Deficit-LRU spirit): they simply receive no
    /// token grant this iteration and re-enter admission next time. Any
    /// shortfall the estimate misses is caught by the growth-allocation
    /// pressure path, exactly like a draining async swap-out.
    pub(super) fn partial_preemption_sweep(&mut self, sched: &Schedule) -> Ns {
        // Re-derive each admitted request's block ask from live state
        // (nothing has mutated since the schedule was built), so the
        // sweep is identical under both scheduler paths and O(admitted)
        // rather than a scan of the full candidate list.
        let needed: usize = sched
            .keep
            .iter()
            .chain(&sched.promote)
            .chain(&sched.start)
            .map(|&id| self.candidate_for(self.reqs.get(id)).blocks_needed)
            .sum();
        let mut deficit =
            needed.saturating_sub(self.alloc.as_dyn_ref().available_blocks());
        let mut stall: Ns = 0;
        // `sched.preempt` is in descending priority order; walk it in
        // reverse so the lowest-priority victims lose their tails first.
        for &id in sched.preempt.iter().rev() {
            if deficit == 0 {
                break;
            }
            let held = self.alloc.as_dyn_ref().table(id).len();
            if held == 0 {
                continue;
            }
            let wanted = deficit.min(held);
            deficit -= wanted;
            let tokens = self.reqs.get(id).tokens_in_cache;
            let partial = wanted < held && tokens > 0;
            self.trace.emit(
                self.now,
                TraceEvent::Preempt {
                    req: id,
                    reason: "sweep",
                    action: if partial { "partial_tail" } else { "swap_all" },
                    blocks: wanted,
                },
            );
            if partial {
                stall += self.preempt_tail(id, wanted);
            } else {
                // Whole-victim ask (or nothing materialized): baseline
                // swap eviction.
                if tokens > 0 {
                    self.rec.evict_swap_decisions += 1;
                }
                stall += self.preempt(id, false);
            }
        }
        stall
    }

    /// Swap out (or drop) one GPU-resident request whole. Returns
    /// main-thread stall charged to this iteration. For a partially
    /// resident victim only the resident head is transferred — the
    /// evicted tail already lives as valid CPU copies.
    pub(super) fn preempt(&mut self, id: RequestId, turn_end: bool) -> Ns {
        let r = self.reqs.get_mut(id);
        let tokens = r.tokens_in_cache;
        let prio = r.priority;
        let was_partial = r.state == ReqState::PartiallyResident;
        let plan = if was_partial {
            let held = self.alloc.as_dyn_ref().table(id).len() as u32;
            self.reuse
                .plan_swap_out_range(id, tokens, 0, held, &self.cpu)
        } else {
            self.reuse.plan_swap_out(id, tokens, &self.cpu)
        };
        // Re-transferred blocks that already own a CPU slot (the stale
        // partial tail) are overwritten in place; only genuinely new
        // logicals need fresh slots.
        let existing: std::collections::HashSet<u32> =
            self.cpu.valid_logical(id).into_iter().collect();
        let fresh: Vec<u32> = plan
            .transfer
            .iter()
            .copied()
            .filter(|l| !existing.contains(l))
            .collect();
        // Secure CPU slots for the blocks that must move.
        let copies = match self.cpu.add_copies(id, &fresh, prio) {
            Some(c) => Some(c),
            None => {
                self.cpu.contaminate_backups(fresh.len(), prio);
                self.cpu.add_copies(id, &fresh, prio)
            }
        };
        let Some(_) = copies else {
            // CPU swap space exhausted even after contamination →
            // recompute-preemption (vLLM's fallback).
            return self.recompute_preempt(id, turn_end);
        };
        // Build moves: logical → (gpu block, cpu slot).
        let slot_of: std::collections::HashMap<u32, u32> = self
            .cpu
            .copies_of(id)
            .map(|c| c.entries.iter().map(|e| (e.logical, e.slot)).collect())
            .unwrap_or_default();
        let table = self.alloc.as_dyn_ref().table(id).to_vec();
        let moves: Vec<BlockMove> = plan
            .transfer
            .iter()
            .map(|&l| BlockMove {
                logical: l,
                gpu: table[l as usize],
                cpu: slot_of[&l],
            })
            .collect();
        let op = self.seg.build(id, Direction::Out, &moves);
        let nothing_in_flight = op.segments.is_empty();
        let stall = self.mgr.submit_swap_out(op, self.now);
        // Synchronous engines free the source blocks now (the copy is
        // complete); asynchronous ones keep them allocated until the op
        // drains — reusing them earlier is exactly the KV-cache conflict
        // of §3.2, which the allocator-pressure path below resolves with
        // fine-grained synchronization.
        let async_out = !matches!(self.mgr.mode(), SwapMode::Sync) && !nothing_in_flight;
        if !async_out {
            self.alloc.as_dyn().release(id);
        }
        self.cpu.set_required(id, true);
        // The copy's content is fixed at submit; readers are barriered on
        // the completion event, so the reuse state can commit now.
        self.reuse.commit_swap_out(id, tokens);
        let sync_done = matches!(self.mgr.mode(), SwapMode::Sync) || nothing_in_flight;
        let r = self.reqs.get_mut(id);
        r.kv = KvLocation::Cpu;
        r.state = if turn_end {
            if sync_done {
                ReqState::WaitingTurn
            } else {
                ReqState::SwappingOutTurnEnd
            }
        } else {
            ReqState::SwappedOut
        };
        if !turn_end {
            self.rec.preemptions += 1;
        }
        stall
    }

    /// Partial-tail eviction (`partial_tail` policy): move only the last
    /// `wanted` blocks of `id`'s table to CPU and shrink the allocation
    /// in place; the head stays resident and the request re-admits with
    /// `needed = missing tail` only. Degenerates to a full eviction when
    /// the ask covers the whole table, and to recompute-preemption when
    /// the CPU swap space is exhausted.
    ///
    /// Mirrors [`ServingEngine::preempt`]'s swap-out pipeline rather
    /// than sharing a range-parameterized helper *on purpose*: the full
    /// eviction path is behavior-pinned bit-for-bit against the
    /// pre-refactor engine and must not change shape while that pin is
    /// load-bearing.
    pub(super) fn preempt_tail(&mut self, id: RequestId, wanted: usize) -> Ns {
        let held = self.alloc.as_dyn_ref().table(id).len();
        let r = self.reqs.get(id);
        let tokens = r.tokens_in_cache;
        let prio = r.priority;
        let total = Request::blocks_for(tokens, self.block_size);
        // Never leave an empty head; grown-but-still-empty blocks past
        // the KV end are dropped first (they hold no data to transfer).
        let n_tail = wanted
            .max(held.saturating_sub(total))
            .min(held.saturating_sub(1));
        if n_tail == 0 || n_tail >= held {
            return self.preempt(id, false);
        }
        self.trace.emit(
            self.now,
            TraceEvent::PartialShave {
                req: id,
                evicted: n_tail,
                retained: held - n_tail,
            },
        );
        // Logical tail blocks that actually hold KV and must move.
        let lo = (held - n_tail) as u32;
        let hi = held.min(total) as u32;
        let plan = if lo < hi {
            self.reuse
                .plan_swap_out_range(id, tokens, lo, hi, &self.cpu)
        } else {
            Default::default()
        };
        let existing: std::collections::HashSet<u32> =
            self.cpu.valid_logical(id).into_iter().collect();
        let fresh: Vec<u32> = plan
            .transfer
            .iter()
            .copied()
            .filter(|l| !existing.contains(l))
            .collect();
        let copies = match self.cpu.add_copies(id, &fresh, prio) {
            Some(c) => Some(c),
            None => {
                self.cpu.contaminate_backups(fresh.len(), prio);
                self.cpu.add_copies(id, &fresh, prio)
            }
        };
        if copies.is_none() {
            // CPU swap space exhausted: the tail cannot survive without
            // its copy — whole-victim recompute fallback.
            return self.recompute_preempt(id, false);
        }
        let slot_of: std::collections::HashMap<u32, u32> = self
            .cpu
            .copies_of(id)
            .map(|c| c.entries.iter().map(|e| (e.logical, e.slot)).collect())
            .unwrap_or_default();
        let table = self.alloc.as_dyn_ref().table(id).to_vec();
        let moves: Vec<BlockMove> = plan
            .transfer
            .iter()
            .map(|&l| BlockMove {
                logical: l,
                gpu: table[l as usize],
                cpu: slot_of[&l],
            })
            .collect();
        let op = self.seg.build(id, Direction::Out, &moves);
        let nothing_in_flight = op.segments.is_empty();
        let stall = self.mgr.submit_swap_out(op, self.now);
        let async_out = !matches!(self.mgr.mode(), SwapMode::Sync) && !nothing_in_flight;
        if async_out {
            // Source blocks stay allocated until the op drains;
            // `release_reaped` then shrinks exactly this tail.
            self.partial_pending.insert(id, n_tail);
        } else {
            self.alloc.as_dyn().release_tail(id, n_tail);
        }
        self.cpu.set_required(id, true);
        self.reuse.commit_swap_out(id, tokens);
        let r = self.reqs.get_mut(id);
        r.kv = KvLocation::Split;
        r.state = ReqState::PartiallyResident;
        self.rec.preemptions += 1;
        self.rec.partial_evictions += 1;
        self.rec.blocks_retained += (held - n_tail) as u64;
        stall
    }

    /// Build the CPU→GPU op materializing `id`'s missing suffix onto the
    /// freshly allocated `blocks` (shared by demand promotion and the
    /// speculative prefetch path). For fully swapped-out requests the
    /// suffix is the whole context; for partially resident ones it is
    /// exactly the evicted tail.
    pub(super) fn build_swap_in_op(&self, id: RequestId, blocks: &[BlockId]) -> SwapOp {
        let tokens = self.reqs.get(id).tokens_in_cache;
        let logicals = self.reuse.plan_swap_in(tokens);
        let skip = logicals.len() - blocks.len();
        let slot_of: std::collections::HashMap<u32, u32> = self
            .cpu
            .copies_of(id)
            .map(|c| c.entries.iter().map(|e| (e.logical, e.slot)).collect())
            .unwrap_or_default();
        let moves: Vec<BlockMove> = logicals[skip..]
            .iter()
            .map(|&l| BlockMove {
                logical: l,
                gpu: blocks[l as usize - skip],
                cpu: *slot_of.get(&l).expect("required CPU copy present"),
            })
            .collect();
        self.seg.build(id, Direction::In, &moves)
    }

    /// Swap a request back in. Returns (stall, newly allocated blocks);
    /// `None` if allocation failed (stays swapped out this iteration).
    pub(super) fn promote(
        &mut self,
        id: RequestId,
        iter_hint: Ns,
        batch: usize,
        avg_ctx: f64,
    ) -> Option<(Ns, Vec<BlockId>)> {
        // A prefetched request re-admits off its speculative transfer:
        // zero demand swap-in stall when it has landed, an asynchronous
        // remainder-wait when still on the wire. Either way the critical
        // path pays nothing synchronously — the point of the pipeline.
        match self.mgr.claim_prefetch(id, self.now) {
            Some(PrefetchClaim::Ready) => {
                debug_assert_eq!(
                    self.alloc.as_dyn_ref().table(id).len(),
                    Request::blocks_for(
                        self.reqs.get(id).tokens_in_cache,
                        self.block_size
                    ),
                    "prefetched residency must cover the whole context"
                );
                let r = self.reqs.get_mut(id);
                r.state = if r.prefill_remaining() > 0 {
                    ReqState::Prefilling
                } else {
                    ReqState::Running
                };
                r.kv = KvLocation::Gpu;
                self.release_cpu_copy_after_swap_in(id);
                self.trace
                    .emit(self.now, TraceEvent::Promote { req: id, stall_ns: 0 });
                return Some((0, Vec::new()));
            }
            Some(PrefetchClaim::Pending { .. }) => {
                self.reqs.get_mut(id).state = ReqState::SwappingIn;
                self.trace
                    .emit(self.now, TraceEvent::Promote { req: id, stall_ns: 0 });
                return Some((0, Vec::new()));
            }
            None => {}
        }
        // If this request's own swap-out is still writing the CPU copy,
        // synchronize on it first (its GPU blocks are also still held).
        let mut pre_stall: Ns = 0;
        if let Some(done) = self.mgr.swap_out_inflight(id) {
            pre_stall = done.saturating_sub(self.now);
            let reaped = self.mgr.reap_swap_outs(done);
            self.release_reaped(reaped);
        }
        let r = self.reqs.get(id);
        let tokens = r.tokens_in_cache;
        // Partially resident requests re-materialize only the missing
        // tail on top of their resident head (held == 0 for full
        // swap-outs — their draining source blocks were released by the
        // barrier above).
        let held = self.alloc.as_dyn_ref().table(id).len();
        let n = Request::blocks_for(tokens, self.block_size).saturating_sub(held);
        let blocks = loop {
            match self.alloc.as_dyn().allocate(id, n) {
                Some(b) => break b,
                None => {
                    // Pressure: (0) reclaim a speculative prefetch, (1)
                    // drain an in-flight swap-out (conflict) if one
                    // exists; otherwise give up this iteration.
                    if let Some(t) = self.cancel_one_prefetch_for_pressure(id) {
                        pre_stall = pre_stall.max(t.saturating_sub(self.now));
                        continue;
                    }
                    let at = self.now + pre_stall;
                    match self.drain_one_swap_out(at) {
                        Some(t) => pre_stall = t.saturating_sub(self.now),
                        None => {
                            // partial_tail only: non-admitted
                            // partially-resident heads are the last
                            // reclaimable blocks (the scheduler's
                            // capacity math cannot see them). Reclaim
                            // the lowest-priority one, then retry.
                            let partial: Vec<VictimRank> = self
                                .reqs
                                .iter()
                                .filter(|r| {
                                    r.id != id
                                        && r.state == ReqState::PartiallyResident
                                })
                                .map(|r| VictimRank {
                                    id: r.id,
                                    priority: r.priority,
                                    turn_arrival: r.turn_arrival,
                                })
                                .collect();
                            match ContextSwitchPlanner::select_victim(&partial) {
                                Some(v) => pre_stall += self.preempt(v, false),
                                None => return None,
                            }
                        }
                    }
                }
            }
        };
        let op = self.build_swap_in_op(id, &blocks);
        let mut stall = pre_stall;
        let start_at = self.now + pre_stall;
        match self.mgr.submit_swap_in(op, start_at, iter_hint, batch, avg_ctx) {
            SwapInDecision::Sync { done } => {
                stall = stall.max(done.saturating_sub(self.now));
                let r = self.reqs.get_mut(id);
                r.state = if r.prefill_remaining() > 0 {
                    ReqState::Prefilling
                } else {
                    ReqState::Running
                };
                r.kv = KvLocation::Gpu;
            }
            SwapInDecision::Async => {
                self.reqs.get_mut(id).state = ReqState::SwappingIn;
            }
        }
        // The CPU copy is demoted to a contaminable backup (reuse) or
        // freed (vLLM) only once the swap-in has finished reading it:
        // sync → now, async → at harvest.
        let sync_done = !matches!(self.reqs.get(id).state, ReqState::SwappingIn);
        if sync_done {
            self.release_cpu_copy_after_swap_in(id);
        }
        self.trace
            .emit(self.now, TraceEvent::Promote { req: id, stall_ns: stall });
        Some((stall, blocks))
    }

    /// End-of-turn handling after the last response token. Turn-end
    /// swap-outs are always whole-context (the next turn reuses the full
    /// CPU copy), so the planner is not consulted here.
    pub(super) fn end_turn(&mut self, id: RequestId) -> Ns {
        let r = self.reqs.get_mut(id);
        let turn = r.turn as u32;
        self.rec.turn_finished(id, turn);
        let r = self.reqs.get(id);
        self.trace.emit(
            self.now,
            TraceEvent::TurnFinish {
                req: id,
                turn,
                last: r.is_last_turn(),
            },
        );
        if r.is_last_turn() {
            self.alloc.as_dyn().release(id);
            self.cpu.drop_request(id);
            self.reuse.forget(id);
            self.prefix.release(id);
            let r = self.reqs.get_mut(id);
            r.state = ReqState::Finished;
            r.kv = KvLocation::None;
            self.rec.finished_conversations += 1;
            return 0;
        }
        // Schedule the next turn after think time, and move the KV cache
        // out of precious HBM (multi-turn context preservation — the
        // §3.3 workload). In cluster mode the next turn is instead held
        // for the router's placement decision.
        let think = r.conv.turns[r.turn + 1].think_time_s;
        let due = self.now + (think * 1e9) as Ns;
        if self.hold_turns {
            self.released_turns.push((id, due));
        } else {
            self.pending_turns.push((id, due));
        }
        self.trace.emit(
            self.now,
            TraceEvent::Preempt {
                req: id,
                reason: "turn_end",
                action: "swap_all",
                blocks: self.alloc.as_dyn_ref().table(id).len(),
            },
        );
        self.preempt(id, true)
    }
}
