//! Incremental candidate index: the sublinear scheduler epoch.
//!
//! [`crate::coordinator::scheduler::schedule`] re-collects every live
//! request into a fresh `Vec<Candidate>` and full-sorts it each
//! iteration — O(n log n) in total queue depth, with allocator churn on
//! top. At the ROADMAP's north-star scale (100k+ queued requests per
//! replica) that full re-sort, not PCIe, becomes the context-switch
//! bottleneck. [`CandidateIndex`] keeps the same candidates in priority
//! buckets (an ordered map of priority level → FCFS bucket, tie-broken
//! `(turn_arrival, id)` — exactly the sort key of the legacy path),
//! updated incrementally at the engine's state-change sites (arrival,
//! turn fire, promote, preempt, finish, priority re-score) so only
//! *dirty* entries are re-keyed per epoch.
//!
//! # Byte-identity with the sort-based oracle
//!
//! [`CandidateIndex::schedule_into`] must produce a [`Schedule`] equal
//! field-for-field to `schedule()` on the same candidate set — the
//! legacy path stays in the tree as the reference oracle, and
//! `rust/tests/sched_scale.rs` churns both paths in lockstep asserting
//! equality every epoch. The walk mirrors the oracle's three passes:
//!
//! 1. **Pinned swap-ins** (`pinned` buckets, highest priority first):
//!    admitted unconditionally, blocks accounted first.
//! 2. **Ranked admission** (`ranked` buckets): admit while the batch and
//!    block budgets hold. The oracle does *not* stop at the first
//!    non-fitting candidate — a later, smaller ask may still fit — so a
//!    naive "stop at first miss" diverges. The walk instead stops only
//!    when no unvisited candidate could possibly be admitted:
//!    `admitted == max_batch`, or `blocks + min_need > total_blocks`
//!    where `min_need` is the exact minimum `held + needed` over the
//!    *unvisited* candidates, maintained as a counting multiset
//!    (`need_counts`) that visited entries are deducted from during the
//!    walk and restored to afterwards. Either condition implies the
//!    oracle admits nothing further, so the walk is O(visited) with
//!    visited ≈ admitted in steady state.
//! 3. **Preempt sweep** (`resident` buckets): every on-GPU
//!    (Running/Prefilling) candidate not admitted is preempted, in
//!    bucket order — identical to the oracle's in-order preempt pushes
//!    because `preempt` is exactly the resident complement of the
//!    admitted set under the same total order.
//!
//! The grant pass then replays the oracle's decode-first / chunked-fill
//! logic over the admitted candidates in admission order (which *is*
//! sorted order). Epoch cost: O(admitted + dirty + preempted) instead
//! of O(total log total).
//!
//! [`EpochScratch`] is the companion arena: every per-epoch vector, the
//! membership set, and the prefetch-projection scratch are
//! cleared-not-dropped between iterations so the steady-state epoch
//! performs no heap allocation at all.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};

use crate::coordinator::request::ReqState;
use crate::coordinator::scheduler::{Candidate, IterBudget, Schedule, TokenGrant};
use crate::memory::RequestId;
use crate::sim::clock::Ns;

/// Bucket key within one priority level: FCFS, then id — the tail of
/// the oracle's `(priority desc, turn_arrival asc, id asc)` sort key.
type BucketKey = (Ns, RequestId);
/// Priority level → FCFS-ordered bucket; walked highest level first.
type Buckets = BTreeMap<i64, BTreeSet<BucketKey>>;

fn bucket_insert(map: &mut Buckets, priority: i64, key: BucketKey) {
    map.entry(priority).or_default().insert(key);
}

fn bucket_remove(map: &mut Buckets, priority: i64, key: &BucketKey) {
    if let Some(b) = map.get_mut(&priority) {
        b.remove(key);
        if b.is_empty() {
            map.remove(&priority);
        }
    }
}

fn multiset_insert(ms: &mut BTreeMap<usize, usize>, k: usize) {
    *ms.entry(k).or_insert(0) += 1;
}

fn multiset_remove(ms: &mut BTreeMap<usize, usize>, k: usize) {
    match ms.get_mut(&k) {
        Some(n) if *n > 1 => *n -= 1,
        Some(_) => {
            ms.remove(&k);
        }
        None => debug_assert!(false, "multiset underflow at key {k}"),
    }
}

/// Buffers one admission walk reads and writes: the admitted-candidate
/// sequence (grant pass input), the membership set (preempt sweep), and
/// the `need_counts` restore log. Grouped so a walk borrows them as one
/// unit alongside whichever [`Schedule`] it targets.
#[derive(Clone, Debug, Default)]
pub struct WalkScratch {
    /// Admitted (grantable) candidates in admission = sorted order.
    admit: Vec<Candidate>,
    /// Admitted-membership set for the preempt sweep.
    in_set: HashSet<RequestId>,
    /// `need_counts` deductions to restore after an early-exited walk.
    visited_needs: Vec<usize>,
}

impl WalkScratch {
    fn clear(&mut self) {
        self.admit.clear();
        self.in_set.clear();
        self.visited_needs.clear();
    }
}

/// Reusable per-epoch scratch (the arena half of the tentpole): owned by
/// the engine, `clear()`ed — never dropped — between iterations, so the
/// candidate vector, schedule vectors, membership set, and prefetch
/// projection buffers all retain their high-water capacity.
#[derive(Clone, Debug, Default)]
pub struct EpochScratch {
    /// Sort-path candidate list (the oracle's input), reused.
    pub cands: Vec<Candidate>,
    /// The iteration's schedule — both paths write here.
    pub sched: Schedule,
    /// Dirty request ids drained from the table each refresh.
    pub dirty: Vec<RequestId>,
    /// Admission-walk working set.
    pub walk: WalkScratch,
    /// `(id, previous priority, projected priority)` re-key log for
    /// projection application and rollback.
    pub moved: Vec<(RequestId, i64, i64)>,
    /// Scratch schedule for projection walks (`sched` may still be
    /// borrowed by the iteration when the prefetch pass runs).
    pub predict_sched: Schedule,
    /// Projected promotions accumulated across lookahead offsets.
    pub promote_out: Vec<RequestId>,
    /// Prefetch projection scratch: candidate ids, row-major with
    /// `proj[i * depth + (offset-1)]` the projected priority of
    /// `proj_ids[i]` at `offset` epochs ahead.
    pub proj_ids: Vec<RequestId>,
    pub proj: Vec<i64>,
}

impl EpochScratch {
    /// Clear every buffer, retaining capacity.
    pub fn clear(&mut self) {
        self.cands.clear();
        self.sched.clear();
        self.dirty.clear();
        self.walk.clear();
        self.moved.clear();
        self.predict_sched.clear();
        self.promote_out.clear();
        self.proj_ids.clear();
        self.proj.clear();
    }
}

/// The bucketed candidate index. See the module docs for the walk's
/// byte-identity argument; see [`CandidateIndex::upsert`] /
/// [`CandidateIndex::remove`] for the incremental-maintenance contract.
#[derive(Clone, Debug, Default)]
pub struct CandidateIndex {
    /// Current candidate snapshot per request — the removal/re-key
    /// handle and the `blocks_needed` lookup for the partial sweep.
    entries: HashMap<RequestId, Candidate>,
    /// Pass-2 population: every candidate except in-flight swap-ins.
    ranked: Buckets,
    /// Pass-1 population: pinned in-flight swap-ins.
    pinned: Buckets,
    /// Preempt-sweep population: Running / Prefilling candidates.
    resident: Buckets,
    /// Counting multiset of `held + needed` over the `ranked`
    /// population — the early-exit lower bound.
    need_counts: BTreeMap<usize, usize>,
    /// GPU KV capacity in blocks; [`CandidateIndex::upsert`] fails fast
    /// on a candidate that could never be admitted (the oracle's
    /// per-call capacity assert, moved to update time).
    capacity: usize,
}

impl CandidateIndex {
    pub fn new(capacity: usize) -> Self {
        CandidateIndex {
            capacity,
            ..CandidateIndex::default()
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Current candidate snapshot for `id`, if indexed.
    pub fn get(&self, id: RequestId) -> Option<&Candidate> {
        self.entries.get(&id)
    }

    /// Indexed request ids, in unspecified (hash-map) order — callers
    /// that need determinism sort the collected ids themselves.
    pub fn ids(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.entries.keys().copied()
    }

    fn attach(&mut self, c: &Candidate) {
        let key = (c.turn_arrival, c.id);
        if c.state == ReqState::SwappingIn {
            bucket_insert(&mut self.pinned, c.priority, key);
        } else {
            bucket_insert(&mut self.ranked, c.priority, key);
            multiset_insert(&mut self.need_counts, c.blocks_held + c.blocks_needed);
            if matches!(c.state, ReqState::Running | ReqState::Prefilling) {
                bucket_insert(&mut self.resident, c.priority, key);
            }
        }
    }

    fn detach(&mut self, c: &Candidate) {
        let key = (c.turn_arrival, c.id);
        if c.state == ReqState::SwappingIn {
            bucket_remove(&mut self.pinned, c.priority, &key);
        } else {
            bucket_remove(&mut self.ranked, c.priority, &key);
            multiset_remove(&mut self.need_counts, c.blocks_held + c.blocks_needed);
            if matches!(c.state, ReqState::Running | ReqState::Prefilling) {
                bucket_remove(&mut self.resident, c.priority, &key);
            }
        }
    }

    /// Insert or re-key one candidate. The engine calls this for every
    /// *dirty* request at the top of the iteration — any request whose
    /// state, priority, turn arrival, residency, or block demand may
    /// have changed since the last refresh.
    ///
    /// # Panics
    ///
    /// Panics if `blocks_needed` exceeds the GPU capacity — the same
    /// "could never be admitted, would starve forever" misconfiguration
    /// the sort-based `schedule()` fails fast on, caught at update time.
    pub fn upsert(&mut self, c: Candidate) {
        assert!(
            c.blocks_needed <= self.capacity,
            "capacity misconfiguration: request {} needs {} fresh GPU \
             blocks but the KV space has only {} in total — it could \
             never be admitted and would starve in the queue forever; \
             reject it at arrival (max-model-len) or provision more blocks",
            c.id,
            c.blocks_needed,
            self.capacity
        );
        if let Some(old) = self.entries.insert(c.id, c) {
            self.detach(&old);
        }
        self.attach(&c);
    }

    /// Drop a request from the index (finished, rejected, migrated, or
    /// parked in a non-schedulable state). Returns whether it was
    /// present.
    pub fn remove(&mut self, id: RequestId) -> bool {
        match self.entries.remove(&id) {
            Some(old) => {
                self.detach(&old);
                true
            }
            None => false,
        }
    }

    /// Re-key one entry to a projected priority (lookahead pass). The
    /// block-demand multiset round-trips through detach/attach, so only
    /// the bucket position moves.
    fn rekey(&mut self, id: RequestId, priority: i64) {
        if let Some(mut c) = self.entries.get(&id).copied() {
            self.detach(&c);
            c.priority = priority;
            self.entries.insert(id, c);
            self.attach(&c);
        }
    }

    /// Build this iteration's schedule into `scratch.sched` —
    /// byte-identical to `schedule()` over the same candidates, at
    /// O(admitted + preempted) instead of O(total log total).
    pub fn schedule_into(
        &mut self,
        total_blocks: usize,
        max_batch: usize,
        budget: IterBudget,
        scratch: &mut EpochScratch,
    ) {
        let EpochScratch { sched, walk, .. } = scratch;
        self.walk(total_blocks, max_batch, budget, sched, walk);
    }

    fn walk(
        &mut self,
        total_blocks: usize,
        max_batch: usize,
        budget: IterBudget,
        out: &mut Schedule,
        ws: &mut WalkScratch,
    ) {
        out.clear();
        ws.clear();
        let WalkScratch {
            admit,
            in_set,
            visited_needs,
        } = ws;
        let mut blocks = 0usize;
        let mut admitted = 0usize;

        // Pass 1: pinned in-flight swap-ins, highest priority first.
        for bucket in self.pinned.values().rev() {
            for &(_, id) in bucket {
                let c = &self.entries[&id];
                blocks += c.blocks_held + c.blocks_needed;
                admitted += 1;
                out.keep.push(id);
                in_set.insert(id);
            }
        }

        // Pass 2: ranked admission with the exact early exit. Visited
        // entries are deducted from `need_counts` so the bound is the
        // minimum over *unvisited* candidates only.
        'walk: for bucket in self.ranked.values().rev() {
            for &(_, id) in bucket {
                if admitted >= max_batch {
                    break 'walk;
                }
                match self.need_counts.keys().next() {
                    None => break 'walk,
                    Some(&min_need) if blocks + min_need > total_blocks => break 'walk,
                    Some(_) => {}
                }
                let c = self.entries[&id];
                let need = c.blocks_held + c.blocks_needed;
                multiset_remove(&mut self.need_counts, need);
                visited_needs.push(need);
                if blocks + need <= total_blocks {
                    blocks += need;
                    admitted += 1;
                    in_set.insert(id);
                    match c.state {
                        ReqState::Running | ReqState::Prefilling => out.keep.push(id),
                        ReqState::SwappedOut => out.promote.push(id),
                        ReqState::Queued => {
                            debug_assert_eq!(
                                c.blocks_held, 0,
                                "queued request holding GPU blocks"
                            );
                            out.start.push(id);
                        }
                        _ => {}
                    }
                    admit.push(c);
                }
                // Not admitted: if resident it falls out of the sweep
                // below, exactly like the oracle's in-pass preempt push.
            }
        }
        for &need in visited_needs.iter() {
            multiset_insert(&mut self.need_counts, need);
        }

        // Pass 2b: preempt sweep — resident complement of the admitted
        // set, in the same total order the oracle emits preempts in.
        for bucket in self.resident.values().rev() {
            for &(_, id) in bucket {
                if !in_set.contains(&id) {
                    out.preempt.push(id);
                }
            }
        }

        // Pass 3: token grants over the admitted (non-swap-in) set in
        // admission order — a verbatim replay of the oracle's grant
        // logic over the identical sequence.
        if budget.monolithic {
            let any_prefill = admit.iter().any(|c| c.prefill_remaining > 0);
            for c in admit.iter() {
                if any_prefill {
                    if c.prefill_remaining > 0 {
                        out.grants.push(TokenGrant {
                            id: c.id,
                            decode: 0,
                            prefill: c.prefill_remaining,
                        });
                    }
                } else {
                    out.grants.push(TokenGrant {
                        id: c.id,
                        decode: 1,
                        prefill: 0,
                    });
                }
            }
        } else {
            let decode_claims =
                admit.iter().filter(|c| c.prefill_remaining == 0).count() as u32;
            let mut left = budget.max_tokens.max(decode_claims);
            for c in admit.iter() {
                if left == 0 {
                    break;
                }
                if c.prefill_remaining == 0 {
                    out.grants.push(TokenGrant {
                        id: c.id,
                        decode: 1,
                        prefill: 0,
                    });
                    left -= 1;
                }
            }
            for c in admit.iter() {
                if left == 0 {
                    break;
                }
                if c.prefill_remaining > 0 {
                    let take = c.prefill_remaining.min(budget.chunk).min(left);
                    out.grants.push(TokenGrant {
                        id: c.id,
                        decode: 0,
                        prefill: take,
                    });
                    left -= take;
                }
            }
        }
    }

    /// Incremental lookahead projection into `scratch.promote_out` —
    /// the bucketed counterpart of `predict_admission()`, byte-identical
    /// output. Per offset only the entries whose projected priority
    /// *moved* are re-keyed (and rolled back afterwards), so an offset
    /// costs O(moved log n + walk) instead of a full O(n log n) re-sort.
    ///
    /// `future_priority` may be called in arbitrary per-offset order
    /// (the oracle calls it in candidate-vector order) — it must be a
    /// pure function of `(id, offset)`, which every live policy's
    /// projection is.
    pub fn predict_into(
        &mut self,
        total_blocks: usize,
        max_batch: usize,
        depth: u64,
        mut future_priority: impl FnMut(RequestId, u64) -> i64,
        scratch: &mut EpochScratch,
    ) {
        scratch.promote_out.clear();
        for offset in 1..=depth {
            // Snapshot the moved set first (the entries map cannot be
            // mutated mid-iteration), then apply, walk, and roll back.
            scratch.moved.clear();
            for (&id, c) in self.entries.iter() {
                let p = future_priority(id, offset);
                if p != c.priority {
                    scratch.moved.push((id, c.priority, p));
                }
            }
            for &(id, _, projected) in scratch.moved.iter() {
                self.rekey(id, projected);
            }
            let EpochScratch {
                predict_sched,
                walk,
                ..
            } = scratch;
            self.walk(
                total_blocks,
                max_batch,
                IterBudget::chunked(1, 1),
                predict_sched,
                walk,
            );
            for &id in &scratch.predict_sched.promote {
                if !scratch.promote_out.contains(&id) {
                    scratch.promote_out.push(id);
                }
            }
            for &(id, previous, _) in scratch.moved.iter() {
                self.rekey(id, previous);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::{predict_admission, schedule};

    fn cand(
        id: RequestId,
        priority: i64,
        state: ReqState,
        held: usize,
        needed: usize,
    ) -> Candidate {
        Candidate {
            id,
            priority,
            turn_arrival: id,
            state,
            blocks_held: held,
            blocks_needed: needed,
            prefill_remaining: match state {
                ReqState::Prefilling | ReqState::Queued => 64,
                _ => 0,
            },
        }
    }

    fn index_of(cands: &[Candidate], capacity: usize) -> CandidateIndex {
        let mut ix = CandidateIndex::new(capacity);
        for &c in cands {
            ix.upsert(c);
        }
        ix
    }

    fn assert_matches_oracle(
        cands: &[Candidate],
        total_blocks: usize,
        max_batch: usize,
        budget: IterBudget,
    ) {
        let oracle = schedule(cands, total_blocks, max_batch, budget);
        let mut ix = index_of(cands, total_blocks);
        let mut scratch = EpochScratch::default();
        ix.schedule_into(total_blocks, max_batch, budget, &mut scratch);
        assert_eq!(scratch.sched, oracle);
    }

    fn wide() -> IterBudget {
        IterBudget::chunked(4096, 512)
    }

    #[test]
    fn matches_oracle_on_the_pinned_scheduler_shapes() {
        // The exact candidate sets the scheduler unit tests pin.
        assert_matches_oracle(
            &[
                cand(1, 1, ReqState::Running, 10, 1),
                cand(2, 9, ReqState::SwappedOut, 0, 10),
                cand(3, 5, ReqState::Running, 10, 1),
            ],
            22,
            8,
            wide(),
        );
        let batch: Vec<Candidate> =
            (0..6).map(|i| cand(i, 5, ReqState::Running, 1, 0)).collect();
        assert_matches_oracle(&batch, 1000, 4, wide());
        assert_matches_oracle(
            &[
                cand(1, 0, ReqState::SwappingIn, 0, 10),
                cand(2, 9, ReqState::SwappedOut, 0, 10),
            ],
            10,
            8,
            wide(),
        );
        assert_matches_oracle(
            &[
                cand(1, 1, ReqState::SwappedOut, 0, 10),
                cand(2, 2, ReqState::Queued, 0, 10),
            ],
            10,
            8,
            wide(),
        );
        assert_matches_oracle(&[], 100, 8, wide());
    }

    #[test]
    fn matches_oracle_when_a_later_smaller_ask_still_fits() {
        // The shape a naive first-miss early exit gets wrong: the
        // priority-8 candidate does not fit, the priority-7 one does.
        assert_matches_oracle(
            &[
                cand(1, 9, ReqState::Running, 6, 0),
                cand(2, 8, ReqState::SwappedOut, 0, 8),
                cand(3, 7, ReqState::SwappedOut, 0, 4),
                cand(4, 6, ReqState::Queued, 0, 2),
            ],
            10,
            8,
            wide(),
        );
    }

    #[test]
    fn matches_oracle_on_grant_budgets() {
        let mut p = cand(1, 9, ReqState::Prefilling, 0, 4);
        p.prefill_remaining = 100;
        let cands = vec![
            p,
            cand(2, 1, ReqState::Running, 4, 1),
            cand(3, 2, ReqState::Running, 4, 1),
        ];
        assert_matches_oracle(&cands, 100, 8, IterBudget::chunked(10, 64));
        assert_matches_oracle(&cands, 100, 8, IterBudget::chunked(2, 64));
        assert_matches_oracle(&cands, 100, 8, IterBudget::monolithic());
        assert_matches_oracle(&cands, 100, 8, IterBudget::chunked(1, 1));
    }

    #[test]
    fn upsert_rekeys_and_remove_detaches() {
        let mut ix = CandidateIndex::new(100);
        let mut scratch = EpochScratch::default();
        let mut cands = vec![
            cand(1, 5, ReqState::Running, 4, 1),
            cand(2, 3, ReqState::SwappedOut, 0, 6),
        ];
        for &c in &cands {
            ix.upsert(c);
        }
        // Re-score request 2 above request 1 and re-check equivalence.
        cands[1].priority = 9;
        ix.upsert(cands[1]);
        let oracle = schedule(&cands, 100, 8, wide());
        ix.schedule_into(100, 8, wide(), &mut scratch);
        assert_eq!(scratch.sched, oracle);
        // Finish request 1: the index must forget it entirely.
        assert!(ix.remove(1));
        assert!(!ix.remove(1), "double remove");
        let oracle = schedule(&cands[1..], 100, 8, wide());
        ix.schedule_into(100, 8, wide(), &mut scratch);
        assert_eq!(scratch.sched, oracle);
        assert_eq!(ix.len(), 1);
    }

    #[test]
    fn early_exit_restores_the_need_multiset() {
        // A batch-limited walk visits only `max_batch` entries; the
        // deducted needs must be restored or the next walk diverges.
        let cands: Vec<Candidate> = (0..16)
            .map(|i| cand(i, 5, ReqState::SwappedOut, 0, 2))
            .collect();
        let mut ix = index_of(&cands, 1000);
        let mut scratch = EpochScratch::default();
        for _ in 0..3 {
            let oracle = schedule(&cands, 1000, 4, wide());
            ix.schedule_into(1000, 4, wide(), &mut scratch);
            assert_eq!(scratch.sched, oracle);
        }
    }

    #[test]
    fn predict_matches_oracle_including_order_and_dedup() {
        let cands = vec![
            cand(1, 9, ReqState::Running, 10, 0),
            cand(2, 1, ReqState::SwappedOut, 0, 10),
        ];
        let future = |id: RequestId, offset: u64| match (id, offset) {
            (1, 2) => 1,
            (2, 2) => 9,
            (1, _) => 9,
            (2, _) => 1,
            _ => unreachable!(),
        };
        let mut ix = index_of(&cands, 10);
        let mut scratch = EpochScratch::default();
        for depth in 0..=3 {
            let oracle = predict_admission(&cands, 10, 8, depth, future);
            ix.predict_into(10, 8, depth, future, &mut scratch);
            assert_eq!(scratch.promote_out, oracle, "depth {depth}");
        }
        // Projection must leave the index untouched: the live schedule
        // afterwards still matches the oracle on current priorities.
        let oracle = schedule(&cands, 10, 8, wide());
        ix.schedule_into(10, 8, wide(), &mut scratch);
        assert_eq!(scratch.sched, oracle);
    }

    #[test]
    fn predict_orders_by_first_projected_admission() {
        let cands = vec![
            cand(2, 0, ReqState::SwappedOut, 0, 10),
            cand(3, 0, ReqState::SwappedOut, 0, 10),
        ];
        let future = |id: RequestId, offset: u64| match (id, offset) {
            (3, 1) => 9,
            (2, 1) => 1,
            _ => 5,
        };
        let mut ix = index_of(&cands, 10);
        let mut scratch = EpochScratch::default();
        ix.predict_into(10, 8, 2, future, &mut scratch);
        assert_eq!(scratch.promote_out, vec![3, 2]);
        assert_eq!(
            predict_admission(&cands, 10, 8, 2, future),
            scratch.promote_out
        );
    }

    #[test]
    #[should_panic(expected = "capacity misconfiguration")]
    fn impossible_candidate_fails_fast_at_upsert() {
        let mut ix = CandidateIndex::new(100);
        ix.upsert(cand(7, 5, ReqState::Queued, 0, 101));
    }

    #[test]
    fn seeded_churn_stays_byte_identical_to_the_oracle() {
        // Miniature of the `tests/sched_scale.rs` suite, kept here so
        // the invariant is enforced at unit granularity too.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x5EED_10);
        let total_blocks = 64;
        let mut cands: Vec<Candidate> = Vec::new();
        let mut ix = CandidateIndex::new(total_blocks);
        let mut scratch = EpochScratch::default();
        let mut next_id = 0u64;
        for epoch in 0..400 {
            // One churn op per epoch: arrive / finish / re-score / flip.
            let op = rng.usize(0, 4);
            match op {
                0 => {
                    let states = [
                        ReqState::Queued,
                        ReqState::SwappedOut,
                        ReqState::Running,
                        ReqState::Prefilling,
                        ReqState::SwappingIn,
                    ];
                    let state = states[rng.usize(0, states.len())];
                    let held = match state {
                        ReqState::Running | ReqState::Prefilling => rng.usize(1, 6),
                        _ => 0,
                    };
                    let mut c = cand(
                        next_id,
                        rng.usize(0, 8) as i64,
                        state,
                        held,
                        rng.usize(0, 9),
                    );
                    c.turn_arrival = rng.usize(0, 1000) as Ns;
                    next_id += 1;
                    cands.push(c);
                    ix.upsert(c);
                }
                1 if !cands.is_empty() => {
                    let i = rng.usize(0, cands.len());
                    let gone = cands.swap_remove(i);
                    ix.remove(gone.id);
                }
                2 if !cands.is_empty() => {
                    let i = rng.usize(0, cands.len());
                    cands[i].priority = rng.usize(0, 8) as i64;
                    ix.upsert(cands[i]);
                }
                3 if !cands.is_empty() => {
                    // Promote/preempt-style flip: state + residency move.
                    let i = rng.usize(0, cands.len());
                    let c = &mut cands[i];
                    if c.state == ReqState::SwappedOut {
                        c.state = ReqState::Running;
                        c.blocks_held = c.blocks_needed.max(1);
                        c.blocks_needed = 0;
                    } else {
                        c.state = ReqState::SwappedOut;
                        c.blocks_needed =
                            (c.blocks_held + c.blocks_needed).clamp(1, total_blocks);
                        c.blocks_held = 0;
                    }
                    let c = cands[i];
                    ix.upsert(c);
                }
                _ => {}
            }
            let max_batch = 1 + rng.usize(0, 8);
            let budget = if epoch % 7 == 0 {
                IterBudget::monolithic()
            } else {
                IterBudget::chunked(1 + rng.usize(0, 64) as u32, 16)
            };
            let oracle = schedule(&cands, total_blocks, max_batch, budget);
            ix.schedule_into(total_blocks, max_batch, budget, &mut scratch);
            assert_eq!(scratch.sched, oracle, "diverged at epoch {epoch}");
        }
    }
}
