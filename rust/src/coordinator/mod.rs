//! L3 coordinator — the paper's system contribution.
//!
//! - [`request`] — per-request state machine across multi-turn
//!   conversations (prefill → decode → turn end → think time → next turn)
//!   and KV residency (GPU / CPU / dropped).
//! - [`priority`] — the paper's offline priority traces (Random, Markov,
//!   plus round-robin).
//! - [`scheduler`] — priority admission under a per-iteration token
//!   budget: who runs, who is preempted, who swaps in, and how many
//!   decode/prefill-chunk tokens each admitted request processes (pure,
//!   unit-testable).
//! - [`queue`] — the incremental bucketed candidate index and the
//!   epoch-scratch arena: the default sublinear scheduler path, kept
//!   byte-identical to [`scheduler::schedule`] (the retained oracle) and
//!   updated only at dirty entries per epoch.
//! - [`switch`] — the context-switch planner: every evict decision goes
//!   through a pluggable [`switch::PreemptionPolicy`] (`swap_all` |
//!   `cost_aware` | `partial_tail`) consulting a swap-vs-recompute cost
//!   model.
//! - [`engine`] — the staged per-iteration serving pipeline (admission →
//!   preemption → prefetch → execution → migration hooks) tying
//!   scheduler, allocators, reuse and the swap manager together over
//!   virtual time.

pub mod engine;
pub mod priority;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod switch;

pub use priority::{Pattern, PriorityTrace};
pub use request::{KvLocation, ReqState, Request, RequestTable};
