//! Context-switch planning: every evict/promote decision the serving
//! engine makes is owned here, behind a pluggable [`PreemptionPolicy`].
//!
//! The paper's block-group allocator gives context switching its
//! *mechanism* (cheap coalesced transfers); this module supplies the
//! *policy* layer on top — which victim to evict, and how:
//!
//! - [`SwapAllPolicy`] (`swap_all`, the default): evict the whole victim
//!   to CPU — the pre-refactor behavior, reproduced bit-for-bit.
//! - [`CostAwarePolicy`] (`cost_aware`): per-victim swap-vs-recompute
//!   chosen by the [`SwitchCostModel`] crossover — PCIe round-trip time
//!   for the context's bytes vs the roofline prefill time to recompute
//!   it (the trade-off vLLM hardcodes per sequence-group kind).
//! - [`PartialTailPolicy`] (`partial_tail`): under allocator pressure,
//!   evict only the minimal suffix of the victim's block runs needed to
//!   satisfy the allocation (Deficit-LRU spirit: preserve KV locality);
//!   the victim becomes
//!   [`crate::coordinator::request::ReqState::PartiallyResident`] and
//!   re-admits with `needed = missing tail` only.

use crate::config::{GpuSpec, PreemptionConfig, PreemptionPolicyKind};
use crate::memory::RequestId;
use crate::sim::clock::Ns;
use crate::sim::PerfModel;

/// Everything a policy may consult about one prospective victim.
#[derive(Clone, Copy, Debug)]
pub struct VictimCtx {
    pub id: RequestId,
    /// Context tokens materialized (GPU head + CPU tail for partially
    /// resident victims).
    pub tokens_in_cache: u64,
    /// GPU blocks the victim currently holds.
    pub blocks_held: usize,
    /// Blocks the evictor actually needs freed. Equals `blocks_held`
    /// for a whole-victim preemption (scheduler un-admission).
    pub blocks_wanted: usize,
    /// Whole-victim eviction: the scheduler removed the victim from the
    /// admitted set entirely, so a partial tail cannot apply.
    pub full: bool,
}

/// What the planner decided for one victim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionAction {
    /// Swap the whole resident context to CPU (baseline).
    SwapAll,
    /// Drop the KV and re-prefill it at re-admission (the cost model
    /// says compute is cheaper than the PCIe round trip).
    Recompute,
    /// Evict only the last `blocks` of the victim's table; the head
    /// stays resident.
    PartialTail { blocks: usize },
}

impl EvictionAction {
    /// Stable label for trace events and reports.
    pub fn label(&self) -> &'static str {
        match self {
            EvictionAction::SwapAll => "swap_all",
            EvictionAction::Recompute => "recompute",
            EvictionAction::PartialTail { .. } => "partial_tail",
        }
    }
}

/// Swap-vs-recompute cost model: the crossover between moving a context
/// over PCIe (out now, back in at re-admission) and recomputing it with
/// a fresh prefill. Pure and deterministic — the `cost_aware` e2e pins
/// the engine's decisions against exactly these numbers.
#[derive(Clone, Debug)]
pub struct SwitchCostModel {
    block_bytes: u64,
    gpu: GpuSpec,
    perf: PerfModel,
}

impl SwitchCostModel {
    pub fn new(block_bytes: u64, gpu: GpuSpec, perf: PerfModel) -> Self {
        SwitchCostModel {
            block_bytes,
            gpu,
            perf,
        }
    }

    /// PCIe time to move `blocks` out and back in (one coalesced
    /// transfer each way at the link's size-dependent efficiency). Uses
    /// the full block volume — the reuse mechanism may shave the
    /// outbound delta, but the decision must not depend on transient
    /// CPU-copy state or runs become schedule-dependent.
    pub fn swap_roundtrip_ns(&self, blocks: usize) -> Ns {
        let bytes = blocks as u64 * self.block_bytes;
        2 * self.gpu.pcie_exec_ns(bytes)
    }

    /// Roofline time to re-prefill `tokens` from scratch (dense GEMMs +
    /// the quadratic attention term, which grows recompute's deficit
    /// further for long contexts).
    pub fn recompute_ns(&self, tokens: u64) -> Ns {
        self.perf.prefill_ns(tokens, 0)
    }

    /// The crossover: is dropping-and-recomputing cheaper than the PCIe
    /// round trip for this context? The direction is hardware-driven: on
    /// the paper's A10 testbed the coalesced round trip (~16 µs/token)
    /// beats roofline recompute (~284 µs/token) at every servable
    /// context — exactly the premise that makes cheap swapping worth
    /// engineering — while a slow or contended link (or an
    /// abundant-compute accelerator) flips the verdict to recompute,
    /// vLLM's classic fallback.
    pub fn recompute_cheaper(&self, tokens: u64, blocks: usize) -> bool {
        self.recompute_ns(tokens) < self.swap_roundtrip_ns(blocks)
    }
}

/// A pluggable eviction policy: given a victim and the cost model,
/// decide how to free its blocks. `Send` because a replica actor
/// carries its engine — planner and policy included — onto an OS thread
/// under the threaded cluster executor
/// ([`crate::runtime::actor::threaded`]).
pub trait PreemptionPolicy: Send {
    fn label(&self) -> &'static str;
    fn decide(&self, v: &VictimCtx, cost: &SwitchCostModel) -> EvictionAction;
}

/// `swap_all` — today's behavior: every eviction swaps the whole victim.
pub struct SwapAllPolicy;

impl PreemptionPolicy for SwapAllPolicy {
    fn label(&self) -> &'static str {
        "swap_all"
    }

    fn decide(&self, _v: &VictimCtx, _cost: &SwitchCostModel) -> EvictionAction {
        EvictionAction::SwapAll
    }
}

/// `cost_aware` — swap or recompute, whichever the model says is
/// cheaper for this victim's context.
pub struct CostAwarePolicy;

impl PreemptionPolicy for CostAwarePolicy {
    fn label(&self) -> &'static str {
        "cost_aware"
    }

    fn decide(&self, v: &VictimCtx, cost: &SwitchCostModel) -> EvictionAction {
        if cost.recompute_cheaper(v.tokens_in_cache, v.blocks_held) {
            EvictionAction::Recompute
        } else {
            EvictionAction::SwapAll
        }
    }
}

/// `partial_tail` — free only what the allocation needs. Whole-victim
/// preemptions (and asks covering the whole table) fall back to the
/// full swap.
pub struct PartialTailPolicy;

impl PreemptionPolicy for PartialTailPolicy {
    fn label(&self) -> &'static str {
        "partial_tail"
    }

    fn decide(&self, v: &VictimCtx, _cost: &SwitchCostModel) -> EvictionAction {
        if !v.full && v.blocks_wanted > 0 && v.blocks_wanted < v.blocks_held {
            EvictionAction::PartialTail {
                blocks: v.blocks_wanted,
            }
        } else {
            EvictionAction::SwapAll
        }
    }
}

/// A victim candidate for [`ContextSwitchPlanner::select_victim`], in
/// the engine's request-table iteration order.
#[derive(Clone, Copy, Debug)]
pub struct VictimRank {
    pub id: RequestId,
    pub priority: i64,
    pub turn_arrival: Ns,
}

/// Owns all evict/promote decision making for one engine: the eviction
/// policy, the cost model it consults, and the victim ordering.
pub struct ContextSwitchPlanner {
    policy: Box<dyn PreemptionPolicy>,
    cost: SwitchCostModel,
    kind: PreemptionPolicyKind,
}

impl ContextSwitchPlanner {
    pub fn new(cfg: &PreemptionConfig, cost: SwitchCostModel) -> Self {
        let policy: Box<dyn PreemptionPolicy> = match cfg.policy {
            PreemptionPolicyKind::SwapAll => Box::new(SwapAllPolicy),
            PreemptionPolicyKind::CostAware => Box::new(CostAwarePolicy),
            PreemptionPolicyKind::PartialTail => Box::new(PartialTailPolicy),
        };
        ContextSwitchPlanner {
            policy,
            cost,
            kind: cfg.policy,
        }
    }

    pub fn kind(&self) -> PreemptionPolicyKind {
        self.kind
    }

    pub fn label(&self) -> &'static str {
        self.policy.label()
    }

    pub fn cost_model(&self) -> &SwitchCostModel {
        &self.cost
    }

    /// How to evict this victim.
    pub fn decide_eviction(&self, v: &VictimCtx) -> EvictionAction {
        self.policy.decide(v, &self.cost)
    }

    /// Victim ordering under allocator pressure: lowest priority first,
    /// latest turn arrival breaking ties (LIFO within a level — the
    /// newest arrival has the least sunk service), then input order.
    /// Exactly the pre-refactor engine ordering, now pinned by unit
    /// tests.
    pub fn select_victim(cands: &[VictimRank]) -> Option<RequestId> {
        cands
            .iter()
            .min_by_key(|v| (v.priority, std::cmp::Reverse(v.turn_arrival)))
            .map(|v| v.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    fn cost() -> SwitchCostModel {
        let model = ModelSpec::llama8b();
        let gpu = GpuSpec::a10();
        SwitchCostModel::new(
            model.block_bytes(),
            gpu.clone(),
            PerfModel::new(model, gpu),
        )
    }

    fn victim(tokens: u64, held: usize, wanted: usize, full: bool) -> VictimCtx {
        VictimCtx {
            id: 1,
            tokens_in_cache: tokens,
            blocks_held: held,
            blocks_wanted: wanted,
            full,
        }
    }

    #[test]
    fn swap_all_always_swaps() {
        let c = cost();
        for v in [victim(100, 7, 2, false), victim(50_000, 3200, 3200, true)] {
            assert_eq!(SwapAllPolicy.decide(&v, &c), EvictionAction::SwapAll);
        }
    }

    /// The same testbed with its PCIe link crippled 64× (0.5 GB/s): a
    /// round trip now costs ~1 ms/token while recompute stays ~284
    /// µs/token, so the crossover flips to recompute.
    fn slow_link_cost() -> SwitchCostModel {
        let model = ModelSpec::llama8b();
        let mut gpu = GpuSpec::a10();
        gpu.pcie_bw = 0.5e9;
        SwitchCostModel::new(
            model.block_bytes(),
            gpu.clone(),
            PerfModel::new(model, gpu),
        )
    }

    #[test]
    fn cost_model_fast_link_prefers_swap_at_every_context() {
        // LLaMA-8B on A10: the coalesced PCIe round trip (~16 µs/token)
        // beats roofline recompute (~284 µs/token) — the paper's premise
        // that swapping, done well, is the right preemption mechanism.
        let c = cost();
        for (tokens, blocks) in [(100u64, 7usize), (1_000, 63), (12_000, 750)] {
            assert!(
                !c.recompute_cheaper(tokens, blocks),
                "swap must win at {tokens} tokens on the fast link"
            );
            assert_eq!(
                CostAwarePolicy.decide(&victim(tokens, blocks, blocks, true), &c),
                EvictionAction::SwapAll
            );
        }
    }

    #[test]
    fn cost_model_slow_link_flips_the_crossover_to_recompute() {
        let c = slow_link_cost();
        let tokens = 1_000u64;
        let blocks = 63;
        assert!(c.recompute_cheaper(tokens, blocks));
        assert_eq!(
            CostAwarePolicy.decide(&victim(tokens, blocks, blocks, true), &c),
            EvictionAction::Recompute
        );
    }

    #[test]
    fn partial_tail_frees_only_what_is_wanted() {
        let c = cost();
        assert_eq!(
            PartialTailPolicy.decide(&victim(1_000, 63, 4, false), &c),
            EvictionAction::PartialTail { blocks: 4 }
        );
        // Whole-victim preemption or an ask covering the whole table
        // degrades to the full swap.
        assert_eq!(
            PartialTailPolicy.decide(&victim(1_000, 63, 63, false), &c),
            EvictionAction::SwapAll
        );
        assert_eq!(
            PartialTailPolicy.decide(&victim(1_000, 63, 4, true), &c),
            EvictionAction::SwapAll
        );
    }

    #[test]
    fn victim_ordering_is_priority_then_latest_arrival_then_input_order() {
        let rank = |id, priority, turn_arrival| VictimRank {
            id,
            priority,
            turn_arrival,
        };
        // Lowest priority loses first.
        assert_eq!(
            ContextSwitchPlanner::select_victim(&[
                rank(1, 5, 100),
                rank(2, 1, 0),
                rank(3, 9, 500),
            ]),
            Some(2)
        );
        // Tie on priority: the latest turn arrival (least sunk service)
        // is evicted.
        assert_eq!(
            ContextSwitchPlanner::select_victim(&[
                rank(1, 5, 100),
                rank(2, 5, 900),
                rank(3, 5, 400),
            ]),
            Some(2)
        );
        // Full tie: first in input (request-table) order wins — the
        // pre-refactor `min_by_key` semantics, kept for determinism.
        assert_eq!(
            ContextSwitchPlanner::select_victim(&[
                rank(7, 5, 100),
                rank(8, 5, 100),
            ]),
            Some(7)
        );
        assert_eq!(ContextSwitchPlanner::select_victim(&[]), None);
    }

    #[test]
    fn planner_dispatches_by_config() {
        let mk = |kind| {
            ContextSwitchPlanner::new(&PreemptionConfig { policy: kind }, cost())
        };
        let v = victim(1_000, 63, 4, false);
        assert_eq!(
            mk(PreemptionPolicyKind::SwapAll).decide_eviction(&v),
            EvictionAction::SwapAll
        );
        assert_eq!(
            mk(PreemptionPolicyKind::PartialTail).decide_eviction(&v),
            EvictionAction::PartialTail { blocks: 4 }
        );
        assert_eq!(
            mk(PreemptionPolicyKind::CostAware).decide_eviction(&v),
            EvictionAction::SwapAll,
            "on the fast A10 link the round trip beats recompute"
        );
        assert_eq!(mk(PreemptionPolicyKind::PartialTail).label(), "partial_tail");
    }
}
