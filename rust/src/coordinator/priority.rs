//! Offline priority traces (paper §4 "Context Switching Trace
//! Simulation").
//!
//! No public LLMaaS context-switching traces exist, so the paper (after
//! Yin et al., 2024) simulates two patterns, both precomputed offline:
//!
//! - **Random** — priorities redrawn arbitrarily at every update epoch;
//!   no temporal correlation (the harsher pattern: it disrupts block-group
//!   continuity and increases KV conflicts, §5.1.1).
//! - **Markov** — temporal locality: each conversation's priority does a
//!   sticky random walk, so recently favored requests tend to stay
//!   favored.
//! - **RoundRobin** (extra, after Andes) — deterministic rotation.
//!
//! The trace answers "priority of conversation c at epoch e" lazily but
//! deterministically: epoch values are memoized per conversation and
//! stepped forward as needed, so the whole trace never needs
//! materializing.

use std::collections::HashMap;

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    Random,
    Markov,
    RoundRobin,
}

impl Pattern {
    pub fn by_name(s: &str) -> Option<Pattern> {
        match s {
            "random" => Some(Pattern::Random),
            "markov" => Some(Pattern::Markov),
            "roundrobin" | "round-robin" => Some(Pattern::RoundRobin),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PriorityTrace {
    pattern: Pattern,
    levels: i64,
    seed: u64,
    /// Markov memo: conversation -> (last epoch computed, value at it).
    memo: HashMap<u64, (u64, i64)>,
    /// Markov stickiness: probability of staying at the current level.
    pub sticky: f64,
}

impl PriorityTrace {
    pub fn new(pattern: Pattern, levels: usize, seed: u64) -> Self {
        PriorityTrace {
            pattern,
            levels: levels.max(1) as i64,
            seed,
            memo: HashMap::new(),
            sticky: 0.8,
        }
    }

    pub fn pattern(&self) -> Pattern {
        self.pattern
    }

    /// Stateless per-(conv, epoch) uniform draw.
    fn draw(&self, conv: u64, epoch: u64) -> i64 {
        let mut r = Rng::new(
            self.seed
                ^ conv.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ epoch.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        );
        r.range(0, self.levels as u64) as i64
    }

    /// One seeded step of the Markov walk: the value at epoch `e` given
    /// the value `v` at epoch `e - 1`.
    fn markov_step(&self, conv: u64, e: u64, v: i64) -> i64 {
        let mut r = Rng::new(
            self.seed
                ^ 0xDEAD_BEEF
                ^ conv.wrapping_mul(0x0100_0000_01B3)
                ^ e.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let u = r.f64();
        if u > self.sticky {
            // Split the remainder between up and down moves.
            if u < self.sticky + (1.0 - self.sticky) / 2.0 {
                (v + 1).min(self.levels - 1)
            } else {
                (v - 1).max(0)
            }
        } else {
            v
        }
    }

    /// Priority of `conv` at update epoch `epoch` (higher = better).
    pub fn priority_of(&mut self, conv: u64, epoch: u64) -> i64 {
        match self.pattern {
            Pattern::Random => self.draw(conv, epoch),
            Pattern::RoundRobin => ((conv + epoch) % self.levels as u64) as i64,
            Pattern::Markov => {
                // Resume from the memo when stepping forward; recompute
                // from epoch 0 on random backwards access (each step is
                // seeded per-(conv, epoch), so recomputation is exact).
                let (mut e, mut v) = match self.memo.get(&conv) {
                    Some(&(e, v)) if e <= epoch => (e, v),
                    _ => (0, self.draw(conv, 0)),
                };
                while e < epoch {
                    e += 1;
                    v = self.markov_step(conv, e, v);
                }
                self.memo.insert(conv, (epoch, v));
                v
            }
        }
    }

    /// Priorities of `conv` for the `depth` epochs after `epoch`
    /// (index 0 = `epoch + 1`), computed by walking forward **without
    /// advancing the memo** past `epoch`. The lookahead prefetcher calls
    /// this instead of `priority_of(epoch + k)` — a memo parked in the
    /// future would force every later sequential query to replay the
    /// seeded walk from epoch 0 (O(epochs²) over a run).
    pub fn project(&mut self, conv: u64, epoch: u64, depth: u64) -> Vec<i64> {
        match self.pattern {
            Pattern::Random => (1..=depth).map(|j| self.draw(conv, epoch + j)).collect(),
            Pattern::RoundRobin => (1..=depth)
                .map(|j| ((conv + epoch + j) % self.levels as u64) as i64)
                .collect(),
            Pattern::Markov => {
                // Anchor the memo at `epoch`, then walk a local copy.
                let mut v = self.priority_of(conv, epoch);
                (1..=depth)
                    .map(|j| {
                        v = self.markov_step(conv, epoch + j, v);
                        v
                    })
                    .collect()
            }
        }
    }

    pub fn levels(&self) -> i64 {
        self.levels
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_is_deterministic_and_uncorrelated() {
        let mut a = PriorityTrace::new(Pattern::Random, 8, 1);
        let mut b = PriorityTrace::new(Pattern::Random, 8, 1);
        for c in 0..20 {
            for e in 0..20 {
                assert_eq!(a.priority_of(c, e), b.priority_of(c, e));
            }
        }
        // Temporal autocorrelation of the random pattern ≈ 0: count how
        // often consecutive epochs keep the same priority.
        let mut same = 0;
        let mut total = 0;
        for c in 0..200 {
            let mut prev = a.priority_of(c, 0);
            for e in 1..50 {
                let v = a.priority_of(c, e);
                same += (v == prev) as u32;
                prev = v;
                total += 1;
            }
        }
        let frac = same as f64 / total as f64;
        assert!(frac < 0.2, "random should rarely repeat: {frac}");
    }

    #[test]
    fn markov_has_temporal_locality() {
        let mut t = PriorityTrace::new(Pattern::Markov, 8, 2);
        let mut same = 0;
        let mut total = 0;
        for c in 0..200 {
            let mut prev = t.priority_of(c, 0);
            for e in 1..50 {
                let v = t.priority_of(c, e);
                assert!((v - prev).abs() <= 1, "walk moves one step");
                same += (v == prev) as u32;
                prev = v;
                total += 1;
            }
        }
        let frac = same as f64 / total as f64;
        assert!(frac > 0.7, "markov should be sticky: {frac}");
    }

    #[test]
    fn markov_random_access_consistent_with_sequential() {
        let mut seq = PriorityTrace::new(Pattern::Markov, 8, 3);
        let vals: Vec<i64> = (0..30).map(|e| seq.priority_of(7, e)).collect();
        let mut jump = PriorityTrace::new(Pattern::Markov, 8, 3);
        assert_eq!(jump.priority_of(7, 29), vals[29]);
    }

    #[test]
    fn projection_matches_sequential_future_and_preserves_the_memo() {
        // `project` must return exactly the values sequential access
        // will later produce, for every pattern — and leave the memo
        // anchored at the base epoch, so the subsequent live queries
        // stay O(1) forward steps (no O(epoch) replays from 0).
        for pat in [Pattern::Random, Pattern::Markov, Pattern::RoundRobin] {
            let mut t = PriorityTrace::new(pat, 8, 3);
            let mut seq = PriorityTrace::new(pat, 8, 3);
            let _ = t.priority_of(7, 10);
            let proj = t.project(7, 10, 5);
            let expect: Vec<i64> = (11..=15).map(|e| seq.priority_of(7, e)).collect();
            assert_eq!(proj, expect, "{pat:?} projection diverged");
            // Repeated projection is idempotent (memo undisturbed) ...
            assert_eq!(t.project(7, 10, 5), expect);
            // ... and the live walk continues exactly where it was.
            assert_eq!(t.priority_of(7, 11), expect[0]);
            assert_eq!(t.priority_of(7, 12), expect[1]);
        }
    }

    #[test]
    fn priorities_in_range() {
        for pat in [Pattern::Random, Pattern::Markov, Pattern::RoundRobin] {
            let mut t = PriorityTrace::new(pat, 5, 4);
            for c in 0..50 {
                for e in 0..50 {
                    let v = t.priority_of(c, e);
                    assert!((0..5).contains(&v), "{pat:?} gave {v}");
                }
            }
        }
    }

    #[test]
    fn roundrobin_rotates() {
        let mut t = PriorityTrace::new(Pattern::RoundRobin, 4, 0);
        assert_eq!(t.priority_of(0, 0), 0);
        assert_eq!(t.priority_of(0, 1), 1);
        assert_eq!(t.priority_of(1, 3), 0);
    }

    #[test]
    fn pattern_names() {
        assert_eq!(Pattern::by_name("markov"), Some(Pattern::Markov));
        assert_eq!(Pattern::by_name("random"), Some(Pattern::Random));
        assert_eq!(Pattern::by_name("x"), None);
    }
}
