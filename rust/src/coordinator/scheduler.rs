//! Priority scheduler: pure admission logic (who runs, who swaps).
//!
//! Each iteration the engine rebuilds the admitted set from the latest
//! priorities (paper: "the scheduler then reorders requests across
//! waiting, running, and swapped queues to meet the updated priority
//! requirements"). The scheduler itself is a pure function — it only
//! decides; the engine executes (swap-outs, swap-ins, prefills).

use crate::coordinator::request::ReqState;
use crate::memory::RequestId;
use crate::sim::clock::Ns;

/// Scheduler's view of one schedulable request.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub id: RequestId,
    pub priority: i64,
    pub turn_arrival: Ns,
    pub state: ReqState,
    /// GPU blocks currently held.
    pub blocks_held: usize,
    /// Additional GPU blocks needed to (re-)admit and run one iteration.
    pub blocks_needed: usize,
}

/// Admission outcome.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Schedule {
    /// On GPU and staying (Running / Prefilling / SwappingIn).
    pub keep: Vec<RequestId>,
    /// Off GPU, admitted: needs swap-in (KV on CPU).
    pub promote: Vec<RequestId>,
    /// Off GPU, admitted: fresh or recompute prefill (no KV anywhere).
    pub start: Vec<RequestId>,
    /// On GPU, not admitted: preempt (swap out or drop).
    pub preempt: Vec<RequestId>,
}

impl Schedule {
    pub fn admitted(&self) -> usize {
        self.keep.len() + self.promote.len() + self.start.len()
    }
}

fn on_gpu(state: ReqState) -> bool {
    matches!(
        state,
        ReqState::Running | ReqState::Prefilling | ReqState::SwappingIn
    )
}

/// Build the schedule.
///
/// `total_blocks` — GPU KV capacity in blocks; admission keeps the sum of
/// held+needed blocks within it. `max_batch` — max admitted requests.
pub fn schedule(cands: &[Candidate], total_blocks: usize, max_batch: usize) -> Schedule {
    let mut order: Vec<&Candidate> = cands.iter().collect();
    // Priority desc, then earlier turn arrival (FCFS within a level),
    // then id for determinism.
    order.sort_by(|a, b| {
        b.priority
            .cmp(&a.priority)
            .then(a.turn_arrival.cmp(&b.turn_arrival))
            .then(a.id.cmp(&b.id))
    });

    let mut out = Schedule::default();
    let mut blocks = 0usize;
    let mut admitted = 0usize;

    // Pass 1: in-flight swap-ins are pinned — un-admitting a request whose
    // KV transfer is mid-flight would require synchronizing the stream
    // (paper §3.2); keep them and account their blocks first.
    for c in &order {
        if c.state == ReqState::SwappingIn {
            blocks += c.blocks_held + c.blocks_needed;
            admitted += 1;
            out.keep.push(c.id);
        }
    }

    // Pass 2: everyone else by priority.
    for c in &order {
        if c.state == ReqState::SwappingIn {
            continue;
        }
        let need = c.blocks_held + c.blocks_needed;
        let fits = admitted < max_batch && blocks + need <= total_blocks;
        if fits {
            blocks += need;
            admitted += 1;
            match c.state {
                ReqState::Running | ReqState::Prefilling => out.keep.push(c.id),
                ReqState::SwappedOut => out.promote.push(c.id),
                ReqState::Queued => {
                    debug_assert_eq!(
                        c.blocks_held, 0,
                        "queued request holding GPU blocks"
                    );
                    out.start.push(c.id);
                }
                _ => {}
            }
        } else if on_gpu(c.state) {
            out.preempt.push(c.id);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(
        id: RequestId,
        priority: i64,
        state: ReqState,
        held: usize,
        needed: usize,
    ) -> Candidate {
        Candidate {
            id,
            priority,
            turn_arrival: id, // older id = earlier arrival
            state,
            blocks_held: held,
            blocks_needed: needed,
        }
    }

    #[test]
    fn admits_by_priority_within_capacity() {
        let cands = vec![
            cand(1, 1, ReqState::Running, 10, 1),
            cand(2, 9, ReqState::SwappedOut, 0, 10),
            cand(3, 5, ReqState::Running, 10, 1),
        ];
        // Capacity 22: request 2 (prio 9, 10) + request 3 (prio 5, 11) fit;
        // request 1 (prio 1) does not → preempt.
        let s = schedule(&cands, 22, 8);
        assert_eq!(s.promote, vec![2]);
        assert_eq!(s.keep, vec![3]);
        assert_eq!(s.preempt, vec![1]);
    }

    #[test]
    fn max_batch_enforced() {
        let cands: Vec<Candidate> = (0..6)
            .map(|i| cand(i, 5, ReqState::Running, 1, 0))
            .collect();
        let s = schedule(&cands, 1000, 4);
        assert_eq!(s.keep.len(), 4);
        assert_eq!(s.preempt.len(), 2);
    }

    #[test]
    fn swapping_in_requests_are_pinned() {
        let cands = vec![
            cand(1, 0, ReqState::SwappingIn, 0, 10),
            cand(2, 9, ReqState::SwappedOut, 0, 10),
        ];
        // Capacity only 10: the pinned swap-in wins even at priority 0.
        let s = schedule(&cands, 10, 8);
        assert_eq!(s.keep, vec![1]);
        assert!(s.promote.is_empty());
    }

    #[test]
    fn fcfs_within_priority_level() {
        let mut a = cand(1, 5, ReqState::Queued, 0, 5);
        let mut b = cand(2, 5, ReqState::Queued, 0, 5);
        a.turn_arrival = 100;
        b.turn_arrival = 50;
        let s = schedule(&[a, b], 5, 8);
        assert_eq!(s.start, vec![2], "earlier arrival wins the tie");
    }

    #[test]
    fn preempts_only_on_gpu_requests() {
        let cands = vec![
            cand(1, 1, ReqState::SwappedOut, 0, 10),
            cand(2, 2, ReqState::Queued, 0, 10),
        ];
        let s = schedule(&cands, 10, 8);
        // Capacity admits only request 2; request 1 is already off GPU →
        // NOT in preempt.
        assert_eq!(s.start, vec![2]);
        assert!(s.preempt.is_empty());
        assert!(s.promote.is_empty());
    }

    #[test]
    fn empty_input() {
        let s = schedule(&[], 100, 8);
        assert_eq!(s.admitted(), 0);
    }

    #[test]
    fn prefilling_counts_toward_batch() {
        let cands = vec![
            cand(1, 5, ReqState::Prefilling, 4, 4),
            cand(2, 4, ReqState::Running, 4, 1),
        ];
        let s = schedule(&cands, 13, 2);
        assert_eq!(s.keep, vec![1, 2]);
    }
}
