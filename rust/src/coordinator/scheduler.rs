//! Priority scheduler: token-budget admission (who runs, who swaps, and
//! how many tokens each admitted request may process this iteration).
//!
//! Each iteration the engine rebuilds the admitted set from the latest
//! priorities (paper: "the scheduler then reorders requests across
//! waiting, running, and swapped queues to meet the updated priority
//! requirements"). On top of membership, [`schedule`] hands out
//! per-request [`TokenGrant`]s under a per-iteration [`IterBudget`]:
//! decodes claim the budget first (one token each), and the remaining
//! capacity is filled with prefill *chunks*, so a long prompt advances
//! incrementally instead of stalling every co-resident decode — the
//! chunked-prefill discipline of arXiv 2401.00588 / 2606.09061 grafted
//! onto the paper's priority admission. The scheduler itself stays a
//! pure function — it only decides; the engine executes (swap-outs,
//! swap-ins, prefill chunks, decode steps).

use crate::coordinator::request::ReqState;
use crate::memory::RequestId;
use crate::sim::clock::Ns;

/// Scheduler's view of one schedulable request.
///
/// # Invariants
///
/// - `blocks_held` is the GPU blocks currently allocated to the request
///   (non-zero only for on-GPU states and draining swap-outs).
/// - `blocks_needed` is the *additional* blocks required to admit the
///   request and execute its largest possible grant this iteration; for
///   off-GPU candidates it includes re-materializing the whole context.
/// - `blocks_needed` must not exceed the GPU capacity passed to
///   [`schedule`]: such a candidate could never be admitted even with
///   every block free and would silently starve, so [`schedule`] panics
///   on it (the engine's max-model-len admission check rejects oversized
///   turns before they become candidates).
/// - `prefill_remaining == 0` means the request decodes when granted;
///   otherwise it still owes that many prompt tokens this turn.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub id: RequestId,
    pub priority: i64,
    pub turn_arrival: Ns,
    pub state: ReqState,
    /// GPU blocks currently held.
    pub blocks_held: usize,
    /// Additional GPU blocks needed to (re-)admit and run one iteration.
    pub blocks_needed: usize,
    /// Prompt tokens still to prefill this turn (0 = pure decode).
    pub prefill_remaining: u32,
}

/// Per-iteration token budget driving the grant pass of [`schedule`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IterBudget {
    /// Total new tokens (decode steps + prefill chunk tokens) one
    /// iteration may process. Clamped up to the admitted decode claim
    /// count during the grant pass: every decode-ready request always
    /// gets its one token, so an undersized budget throttles prefill
    /// fill, never decode progress.
    pub max_tokens: u32,
    /// Prompt tokens a single prefill may be granted per iteration.
    pub chunk: u32,
    /// Whole-prefill mode ([`crate::config::PrefillMode::Monolithic`]):
    /// an admitted prefill is granted its entire remaining prompt in one
    /// exclusive iteration and co-resident decodes receive no grant —
    /// the pre-chunking baseline the chunked experiments compare
    /// against. `max_tokens` is ignored for such grants (that is the
    /// all-or-nothing contract).
    pub monolithic: bool,
}

impl IterBudget {
    /// Budget for a chunked-prefill iteration.
    pub fn chunked(max_tokens: u32, chunk: u32) -> Self {
        IterBudget {
            max_tokens: max_tokens.max(1),
            chunk: chunk.max(1),
            monolithic: false,
        }
    }

    /// Whole-prefill (monolithic) admission.
    pub fn monolithic() -> Self {
        IterBudget {
            max_tokens: u32::MAX,
            chunk: u32::MAX,
            monolithic: true,
        }
    }
}

/// Tokens granted to one admitted request for this iteration.
///
/// At most one of `decode` / `prefill` is non-zero: a request either
/// decodes one token or advances its prefill by a chunk. Admitted
/// requests can legitimately carry *no* grant (mid swap-in, or the
/// budget ran dry) — they keep their residency and wait.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TokenGrant {
    pub id: RequestId,
    /// Decode tokens granted (0 or 1): one KV slot, one emitted token.
    pub decode: u32,
    /// Prompt tokens to prefill this iteration.
    pub prefill: u32,
}

/// Admission outcome: membership (who is on GPU) plus this iteration's
/// token grants (who makes progress, and by how much).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Schedule {
    /// On GPU and staying (Running / Prefilling / SwappingIn).
    pub keep: Vec<RequestId>,
    /// Off GPU, admitted: needs swap-in (KV on CPU).
    pub promote: Vec<RequestId>,
    /// Off GPU, admitted: fresh or recompute prefill (no KV anywhere).
    pub start: Vec<RequestId>,
    /// On GPU, not admitted: preempt (swap out or drop).
    pub preempt: Vec<RequestId>,
    /// Token grants for the admitted set, in grant order (decode claims
    /// first, then prefill chunks, each by descending priority). The
    /// engine voids a grant whose request is still mid swap-in or lost
    /// residency to allocator pressure after admission.
    pub grants: Vec<TokenGrant>,
}

impl Schedule {
    pub fn admitted(&self) -> usize {
        self.keep.len() + self.promote.len() + self.start.len()
    }

    /// This iteration's grant for `id`, if any.
    pub fn grant_for(&self, id: RequestId) -> Option<TokenGrant> {
        self.grants.iter().find(|g| g.id == id).copied()
    }

    /// Total tokens granted this iteration (decode + prefill).
    pub fn granted_tokens(&self) -> u64 {
        self.grants
            .iter()
            .map(|g| (g.decode + g.prefill) as u64)
            .sum()
    }

    /// Empty every membership and grant vector, retaining capacity —
    /// the epoch-scratch arena ([`crate::coordinator::queue`]) reuses
    /// one `Schedule` across iterations instead of reallocating five
    /// vectors per epoch.
    pub fn clear(&mut self) {
        self.keep.clear();
        self.promote.clear();
        self.start.clear();
        self.preempt.clear();
        self.grants.clear();
    }
}

fn on_gpu(state: ReqState) -> bool {
    matches!(
        state,
        ReqState::Running | ReqState::Prefilling | ReqState::SwappingIn
    )
}

/// Build the schedule.
///
/// `total_blocks` — GPU KV capacity in blocks; admission keeps the sum of
/// held+needed blocks within it. `max_batch` — max admitted requests.
/// `budget` — the per-iteration token budget for the grant pass.
///
/// # Panics
///
/// Panics if any candidate's `blocks_needed` exceeds `total_blocks`:
/// such a request can never be admitted and would starve in the queue
/// forever, so a capacity misconfiguration fails fast instead of
/// looping silently.
pub fn schedule(
    cands: &[Candidate],
    total_blocks: usize,
    max_batch: usize,
    budget: IterBudget,
) -> Schedule {
    // Fail fast on impossible candidates. `blocks_held` can transiently
    // inflate past capacity-minus-needed while an async swap-out drains
    // (the source blocks stay allocated until the DMA completes), so
    // only `blocks_needed` — the ask with every block free — decides
    // impossibility.
    for c in cands {
        assert!(
            c.blocks_needed <= total_blocks,
            "capacity misconfiguration: request {} needs {} fresh GPU \
             blocks but the KV space has only {} in total — it could \
             never be admitted and would starve in the queue forever; \
             reject it at arrival (max-model-len) or provision more blocks",
            c.id,
            c.blocks_needed,
            total_blocks
        );
    }

    let mut order: Vec<&Candidate> = cands.iter().collect();
    // Priority desc, then earlier turn arrival (FCFS within a level),
    // then id for determinism.
    order.sort_by(|a, b| {
        b.priority
            .cmp(&a.priority)
            .then(a.turn_arrival.cmp(&b.turn_arrival))
            .then(a.id.cmp(&b.id))
    });

    let mut out = Schedule::default();
    let mut blocks = 0usize;
    let mut admitted = 0usize;
    let mut in_set: std::collections::HashSet<RequestId> = std::collections::HashSet::new();

    // Pass 1: in-flight swap-ins are pinned — un-admitting a request whose
    // KV transfer is mid-flight would require synchronizing the stream
    // (paper §3.2); keep them and account their blocks first.
    for c in &order {
        if c.state == ReqState::SwappingIn {
            blocks += c.blocks_held + c.blocks_needed;
            admitted += 1;
            out.keep.push(c.id);
            in_set.insert(c.id);
        }
    }

    // Pass 2: everyone else by priority.
    for c in &order {
        if c.state == ReqState::SwappingIn {
            continue;
        }
        let need = c.blocks_held + c.blocks_needed;
        let fits = admitted < max_batch && blocks + need <= total_blocks;
        if fits {
            blocks += need;
            admitted += 1;
            in_set.insert(c.id);
            match c.state {
                ReqState::Running | ReqState::Prefilling => out.keep.push(c.id),
                ReqState::SwappedOut => out.promote.push(c.id),
                ReqState::Queued => {
                    debug_assert_eq!(
                        c.blocks_held, 0,
                        "queued request holding GPU blocks"
                    );
                    out.start.push(c.id);
                }
                _ => {}
            }
        } else if on_gpu(c.state) {
            out.preempt.push(c.id);
        }
    }

    // Pass 3: token grants over the admitted set. In-flight swap-ins get
    // none (their KV is still on the wire).
    let grantable = |c: &&Candidate| in_set.contains(&c.id) && c.state != ReqState::SwappingIn;
    if budget.monolithic {
        // Whole-prefill admission: any pending prefill claims the whole
        // iteration; decodes run only in prefill-free iterations.
        let any_prefill = order
            .iter()
            .copied()
            .filter(grantable)
            .any(|c| c.prefill_remaining > 0);
        for c in order.iter().copied().filter(grantable) {
            if any_prefill {
                if c.prefill_remaining > 0 {
                    out.grants.push(TokenGrant {
                        id: c.id,
                        decode: 0,
                        prefill: c.prefill_remaining,
                    });
                }
            } else {
                out.grants.push(TokenGrant {
                    id: c.id,
                    decode: 1,
                    prefill: 0,
                });
            }
        }
    } else {
        // Decodes claim first: one token each, highest priority first.
        // The budget never splits the decode population — an undersized
        // `max_tokens` must not pin the same low-ranked decodes at zero
        // progress while they hold GPU blocks (decode claims are cheap;
        // the budget chiefly bounds the prefill fill), so the effective
        // budget is clamped to at least the decode claim count.
        let decode_claims = order
            .iter()
            .copied()
            .filter(grantable)
            .filter(|c| c.prefill_remaining == 0)
            .count() as u32;
        let mut left = budget.max_tokens.max(decode_claims);
        for c in order.iter().copied().filter(grantable) {
            if left == 0 {
                break;
            }
            if c.prefill_remaining == 0 {
                out.grants.push(TokenGrant {
                    id: c.id,
                    decode: 1,
                    prefill: 0,
                });
                left -= 1;
            }
        }
        // Remaining capacity is filled with prefill chunks.
        for c in order.iter().copied().filter(grantable) {
            if left == 0 {
                break;
            }
            if c.prefill_remaining > 0 {
                let take = c.prefill_remaining.min(budget.chunk).min(left);
                out.grants.push(TokenGrant {
                    id: c.id,
                    decode: 0,
                    prefill: take,
                });
                left -= take;
            }
        }
    }
    out
}

/// Side-effect-free lookahead pass for the prefetch pipeline: project
/// which currently swapped-out candidates the admission logic will
/// *promote* within the next `depth` priority-update epochs, assuming
/// residency and block demands stay as they are now and only priorities
/// move.
///
/// `future_priority(id, offset)` supplies the live policy's priority of
/// `id` at `offset` epochs ahead (`1..=depth`) — the offline traces are
/// exact here, and the VTC/SLO policies return their current ranking
/// (mispredictions are the prefetcher's cancellation path, not ours).
/// For each offset the same membership passes as [`schedule`] run over
/// the re-prioritized candidates; the union of their `promote` sets, in
/// first-projected-admission order, is returned. Requests the *current*
/// schedule already admits will typically appear at offset 1 too — the
/// engine filters out anything already on GPU before acting.
///
/// Pure function: no engine, allocator, or policy state is touched
/// beyond what the caller's closure does.
pub fn predict_admission(
    cands: &[Candidate],
    total_blocks: usize,
    max_batch: usize,
    depth: u64,
    mut future_priority: impl FnMut(RequestId, u64) -> i64,
) -> Vec<RequestId> {
    let mut out: Vec<RequestId> = Vec::new();
    for offset in 1..=depth {
        let projected: Vec<Candidate> = cands
            .iter()
            .map(|c| Candidate {
                priority: future_priority(c.id, offset),
                ..*c
            })
            .collect();
        // Membership only — the grant pass is irrelevant to prefetch.
        let s = schedule(
            &projected,
            total_blocks,
            max_batch,
            IterBudget::chunked(1, 1),
        );
        for id in s.promote {
            if !out.contains(&id) {
                out.push(id);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(
        id: RequestId,
        priority: i64,
        state: ReqState,
        held: usize,
        needed: usize,
    ) -> Candidate {
        Candidate {
            id,
            priority,
            turn_arrival: id, // older id = earlier arrival
            state,
            blocks_held: held,
            blocks_needed: needed,
            prefill_remaining: match state {
                ReqState::Prefilling | ReqState::Queued => 64,
                _ => 0,
            },
        }
    }

    fn wide() -> IterBudget {
        IterBudget::chunked(4096, 512)
    }

    #[test]
    fn admits_by_priority_within_capacity() {
        let cands = vec![
            cand(1, 1, ReqState::Running, 10, 1),
            cand(2, 9, ReqState::SwappedOut, 0, 10),
            cand(3, 5, ReqState::Running, 10, 1),
        ];
        // Capacity 22: request 2 (prio 9, 10) + request 3 (prio 5, 11) fit;
        // request 1 (prio 1) does not → preempt.
        let s = schedule(&cands, 22, 8, wide());
        assert_eq!(s.promote, vec![2]);
        assert_eq!(s.keep, vec![3]);
        assert_eq!(s.preempt, vec![1]);
    }

    #[test]
    fn max_batch_enforced() {
        let cands: Vec<Candidate> = (0..6)
            .map(|i| cand(i, 5, ReqState::Running, 1, 0))
            .collect();
        let s = schedule(&cands, 1000, 4, wide());
        assert_eq!(s.keep.len(), 4);
        assert_eq!(s.preempt.len(), 2);
    }

    #[test]
    fn swapping_in_requests_are_pinned() {
        let cands = vec![
            cand(1, 0, ReqState::SwappingIn, 0, 10),
            cand(2, 9, ReqState::SwappedOut, 0, 10),
        ];
        // Capacity only 10: the pinned swap-in wins even at priority 0.
        let s = schedule(&cands, 10, 8, wide());
        assert_eq!(s.keep, vec![1]);
        assert!(s.promote.is_empty());
        // ... but carries no token grant while its KV is on the wire.
        assert!(s.grant_for(1).is_none());
    }

    #[test]
    fn fcfs_within_priority_level() {
        let mut a = cand(1, 5, ReqState::Queued, 0, 5);
        let mut b = cand(2, 5, ReqState::Queued, 0, 5);
        a.turn_arrival = 100;
        b.turn_arrival = 50;
        let s = schedule(&[a, b], 5, 8, wide());
        assert_eq!(s.start, vec![2], "earlier arrival wins the tie");
    }

    #[test]
    fn fcfs_breaks_grant_ties_at_equal_priority() {
        // Two prefills at the same priority competing for one chunk of
        // budget: the earlier arrival is granted, the later waits.
        let mut a = cand(1, 5, ReqState::Prefilling, 2, 2);
        let mut b = cand(2, 5, ReqState::Prefilling, 2, 2);
        a.turn_arrival = 200;
        b.turn_arrival = 100;
        let s = schedule(&[a, b], 100, 8, IterBudget::chunked(64, 64));
        assert_eq!(s.keep, vec![2, 1]);
        assert_eq!(s.grant_for(2), Some(TokenGrant { id: 2, decode: 0, prefill: 64 }));
        assert!(s.grant_for(1).is_none(), "budget exhausted for the later arrival");
    }

    #[test]
    fn preempts_only_on_gpu_requests() {
        let cands = vec![
            cand(1, 1, ReqState::SwappedOut, 0, 10),
            cand(2, 2, ReqState::Queued, 0, 10),
        ];
        let s = schedule(&cands, 10, 8, wide());
        // Capacity admits only request 2; request 1 is already off GPU →
        // NOT in preempt.
        assert_eq!(s.start, vec![2]);
        assert!(s.preempt.is_empty());
        assert!(s.promote.is_empty());
    }

    #[test]
    fn empty_input() {
        let s = schedule(&[], 100, 8, wide());
        assert_eq!(s.admitted(), 0);
        assert!(s.grants.is_empty());
    }

    #[test]
    fn prefilling_counts_toward_batch() {
        let cands = vec![
            cand(1, 5, ReqState::Prefilling, 4, 4),
            cand(2, 4, ReqState::Running, 4, 1),
        ];
        let s = schedule(&cands, 13, 2, wide());
        assert_eq!(s.keep, vec![1, 2]);
    }

    // ---- token-budget grants ---------------------------------------

    #[test]
    fn decodes_claim_budget_before_prefill_chunks() {
        let mut p = cand(1, 9, ReqState::Prefilling, 0, 4);
        p.prefill_remaining = 100;
        let cands = vec![
            p,
            cand(2, 1, ReqState::Running, 4, 1),
            cand(3, 2, ReqState::Running, 4, 1),
        ];
        // Budget 10: both decodes take 1 each even though the prefill
        // outranks them; the prefill gets the remaining 8.
        let s = schedule(&cands, 100, 8, IterBudget::chunked(10, 64));
        assert_eq!(s.grant_for(2), Some(TokenGrant { id: 2, decode: 1, prefill: 0 }));
        assert_eq!(s.grant_for(3), Some(TokenGrant { id: 3, decode: 1, prefill: 0 }));
        assert_eq!(s.grant_for(1), Some(TokenGrant { id: 1, decode: 0, prefill: 8 }));
        assert_eq!(s.granted_tokens(), 10);
    }

    #[test]
    fn chunk_caps_a_single_prefill_grant() {
        let mut p = cand(1, 5, ReqState::Prefilling, 0, 8);
        p.prefill_remaining = 1000;
        let s = schedule(&[p], 100, 8, IterBudget::chunked(4096, 256));
        assert_eq!(s.grant_for(1), Some(TokenGrant { id: 1, decode: 0, prefill: 256 }));
    }

    #[test]
    fn budget_spreads_across_multiple_prefills() {
        let mut a = cand(1, 5, ReqState::Prefilling, 0, 8);
        let mut b = cand(2, 4, ReqState::Prefilling, 0, 8);
        a.prefill_remaining = 100;
        b.prefill_remaining = 100;
        let s = schedule(&[a, b], 100, 8, IterBudget::chunked(150, 100));
        assert_eq!(s.grant_for(1).unwrap().prefill, 100);
        assert_eq!(s.grant_for(2).unwrap().prefill, 50, "tail of the budget");
    }

    #[test]
    fn preempted_prefill_resumes_with_its_remainder() {
        // A request that was preempted mid-prefill comes back as
        // SwappedOut with a partial remainder smaller than the chunk: it
        // is promoted (KV on CPU — not restarted) and granted exactly
        // what it still owes.
        let mut c = cand(1, 5, ReqState::SwappedOut, 0, 10);
        c.prefill_remaining = 40;
        let s = schedule(&[c], 100, 8, IterBudget::chunked(512, 64));
        assert_eq!(s.promote, vec![1], "partial prefill promotes, never restarts");
        assert!(s.start.is_empty());
        assert_eq!(s.grant_for(1), Some(TokenGrant { id: 1, decode: 0, prefill: 40 }));
    }

    #[test]
    fn admitted_without_grant_keeps_residency() {
        // Budget of 1 over two prefills: the lower-priority one stays
        // resident (keep) but makes no progress this iteration.
        let mut a = cand(1, 9, ReqState::Prefilling, 4, 1);
        let mut b = cand(2, 1, ReqState::Prefilling, 4, 1);
        a.prefill_remaining = 100;
        b.prefill_remaining = 100;
        let s = schedule(&[a, b], 100, 8, IterBudget::chunked(1, 64));
        assert_eq!(s.keep, vec![1, 2]);
        assert!(s.preempt.is_empty());
        assert_eq!(s.grant_for(1), Some(TokenGrant { id: 1, decode: 0, prefill: 1 }));
        assert!(s.grant_for(2).is_none());
    }

    #[test]
    fn undersized_budget_never_starves_decodes() {
        // An explicit budget below the decode population is clamped:
        // every decode-ready request still gets its token; only the
        // prefill fill is throttled (to zero here).
        let mut cands: Vec<Candidate> =
            (0..4).map(|i| cand(i, 5, ReqState::Running, 4, 1)).collect();
        let mut p = cand(9, 9, ReqState::Prefilling, 0, 4);
        p.prefill_remaining = 100;
        cands.push(p);
        let s = schedule(&cands, 100, 8, IterBudget::chunked(2, 64));
        for i in 0..4 {
            assert_eq!(s.grant_for(i).unwrap().decode, 1, "decode {i} starved");
        }
        assert!(s.grant_for(9).is_none(), "no budget left for prefill");
    }

    #[test]
    fn monolithic_grants_whole_prompt_and_stalls_decodes() {
        let mut p = cand(1, 1, ReqState::Prefilling, 0, 40);
        p.prefill_remaining = 600;
        let cands = vec![p, cand(2, 9, ReqState::Running, 4, 1)];
        let s = schedule(&cands, 100, 8, IterBudget::monolithic());
        assert_eq!(s.grant_for(1), Some(TokenGrant { id: 1, decode: 0, prefill: 600 }));
        assert!(
            s.grant_for(2).is_none(),
            "decodes stall behind a monolithic prefill"
        );
        // With no prefill pending, decodes run normally.
        let s = schedule(
            &[cand(2, 9, ReqState::Running, 4, 1)],
            100,
            8,
            IterBudget::monolithic(),
        );
        assert_eq!(s.grant_for(2).unwrap().decode, 1);
    }

    // ---- lookahead projection (prefetch pipeline) ------------------

    #[test]
    fn predicts_swapped_out_request_whose_priority_will_rise() {
        // Capacity for one: the running request owns the GPU now, but at
        // epoch offset 2 the trace flips the ranking — the swapped-out
        // request is projected for promotion exactly once.
        let cands = vec![
            cand(1, 9, ReqState::Running, 10, 0),
            cand(2, 1, ReqState::SwappedOut, 0, 10),
        ];
        let future = |id: RequestId, offset: u64| match (id, offset) {
            (1, 2) => 1,
            (2, 2) => 9,
            (1, _) => 9,
            (2, _) => 1,
            _ => unreachable!(),
        };
        assert!(predict_admission(&cands, 10, 8, 1, future).is_empty());
        assert_eq!(predict_admission(&cands, 10, 8, 2, future), vec![2]);
        // Depth 3 repeats the offset-2 ranking: still deduplicated.
        let future3 = |id: RequestId, offset: u64| match (id, offset >= 2) {
            (1, true) => 1,
            (2, true) => 9,
            (1, false) => 9,
            _ => 1,
        };
        assert_eq!(predict_admission(&cands, 10, 8, 3, future3), vec![2]);
    }

    #[test]
    fn depth_zero_predicts_nothing() {
        let cands = vec![cand(2, 5, ReqState::SwappedOut, 0, 4)];
        assert!(predict_admission(&cands, 100, 8, 0, |_, _| 5).is_empty());
    }

    #[test]
    fn prediction_respects_capacity_and_batch_limits() {
        // Three swapped-out requests, room for only the best two under
        // the projected priorities.
        let cands = vec![
            cand(1, 0, ReqState::SwappedOut, 0, 5),
            cand(2, 0, ReqState::SwappedOut, 0, 5),
            cand(3, 0, ReqState::SwappedOut, 0, 5),
        ];
        let future = |id: RequestId, _| 10 - id as i64; // 1 > 2 > 3
        assert_eq!(predict_admission(&cands, 10, 8, 1, future), vec![1, 2]);
        assert_eq!(predict_admission(&cands, 100, 2, 1, future), vec![1, 2]);
    }

    #[test]
    fn prediction_orders_by_first_projected_admission() {
        // Request 3 wins at offset 1, request 2 only at offset 2: the
        // returned order is the projected admission order, not id order.
        let cands = vec![
            cand(2, 0, ReqState::SwappedOut, 0, 10),
            cand(3, 0, ReqState::SwappedOut, 0, 10),
        ];
        let future = |id: RequestId, offset: u64| match (id, offset) {
            (3, 1) => 9,
            (2, 1) => 1,
            _ => 5,
        };
        assert_eq!(predict_admission(&cands, 10, 8, 2, future), vec![3, 2]);
    }

    #[test]
    #[should_panic(expected = "capacity misconfiguration")]
    fn impossible_candidate_fails_fast_instead_of_starving() {
        // A queued request needing more blocks than the GPU has could
        // never be admitted: schedule() must fail fast, not loop.
        let c = cand(7, 5, ReqState::Queued, 0, 101);
        schedule(&[c], 100, 8, wide());
    }
}
