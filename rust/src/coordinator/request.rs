//! Request state machine over multi-turn conversations.

use crate::memory::RequestId;
use crate::sim::clock::Ns;
use crate::workload::Conversation;

/// Where the request's KV cache currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLocation {
    /// No KV materialized (fresh, or dropped by recompute-preemption).
    None,
    Gpu,
    Cpu,
    /// Partial-tail eviction: the head blocks stay GPU-resident while
    /// the evicted suffix lives as CPU copies (state
    /// [`ReqState::PartiallyResident`]).
    Split,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    /// Next turn hasn't arrived yet (user think time).
    WaitingTurn,
    /// Turn arrived; waiting for admission.
    Queued,
    /// Admitted; asynchronous swap-in in flight.
    SwappingIn,
    /// Admitted; prompt (or recompute) prefill in progress.
    Prefilling,
    /// Admitted; decoding.
    Running,
    /// Preempted; KV on CPU, waiting for re-admission.
    SwappedOut,
    /// Partially preempted (`partial_tail` policy): the KV head is still
    /// GPU-resident, only the evicted tail is on CPU. Re-admission needs
    /// `missing tail` blocks only; the scheduler sees it as
    /// [`ReqState::SwappedOut`] with its held head accounted.
    PartiallyResident,
    /// Turn-end swap-out still draining; then → WaitingTurn/Finished.
    SwappingOutTurnEnd,
    /// Conversation complete.
    Finished,
}

/// A live conversation being served.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub conv: Conversation,
    pub turn: usize,
    pub state: ReqState,
    pub kv: KvLocation,
    pub priority: i64,
    /// KV tokens materialized (valid both on GPU and as the CPU copy
    /// baseline — the context length).
    pub tokens_in_cache: u64,
    /// Prompt tokens of the current turn already prefilled.
    pub prefill_done: u32,
    /// Tokens that must be prefilled this turn (prompt, plus the whole
    /// lost context after a recompute-preemption).
    pub prefill_target: u32,
    /// Leading tokens of the first turn's prompt served from the global
    /// prefix cache ([`crate::block::prefix`]) instead of being
    /// prefilled. Excluded from `tokens_in_cache` and from every
    /// prefill/recompute target: the shared pool blocks stay pinned (and
    /// valid) for the request's whole lifetime, even across
    /// recompute-preemptions. 0 when no prefix matched.
    pub prefix_tokens: u32,
    /// Output tokens generated this turn.
    pub generated: u32,
    /// When the current turn arrived (TTFT reference point).
    pub turn_arrival: Ns,
    /// First arrival of the conversation.
    pub arrival: Ns,
    /// When this turn last emitted a token (drives the online policies'
    /// TBT observations); reset each turn.
    pub last_emit: Option<Ns>,
}

impl Request {
    pub fn new(id: RequestId, conv: Conversation, arrival: Ns) -> Self {
        let prompt = conv.turns[0].prompt_tokens;
        Request {
            id,
            conv,
            turn: 0,
            state: ReqState::Queued,
            kv: KvLocation::None,
            priority: 0,
            tokens_in_cache: 0,
            prefill_done: 0,
            prefill_target: prompt,
            prefix_tokens: 0,
            generated: 0,
            turn_arrival: arrival,
            arrival,
            last_emit: None,
        }
    }

    /// Owning tenant (the fairness accounting unit).
    pub fn tenant(&self) -> u32 {
        self.conv.tenant
    }

    pub fn cur_turn(&self) -> &crate::workload::Turn {
        &self.conv.turns[self.turn]
    }

    /// Total context tokens once this turn completes.
    pub fn turn_total_tokens(&self) -> u64 {
        self.conv.turns[..=self.turn]
            .iter()
            .map(|t| (t.prompt_tokens + t.response_tokens) as u64)
            .sum()
    }

    /// Context tokens accumulated before this turn.
    pub fn history_tokens(&self) -> u64 {
        self.conv.turns[..self.turn]
            .iter()
            .map(|t| (t.prompt_tokens + t.response_tokens) as u64)
            .sum()
    }

    /// Remaining prompt tokens to prefill this turn.
    pub fn prefill_remaining(&self) -> u32 {
        self.prefill_target.saturating_sub(self.prefill_done)
    }

    /// Apply one granted prefill chunk of `take` tokens (the resumable
    /// prefill state machine: `prefill_done` advances toward
    /// `prefill_target`, and the partial progress survives swap-out —
    /// only `drop_context` resets it). The chunk that completes the
    /// prompt also emits the turn's next output token (first token on a
    /// fresh turn; generation simply continues after a
    /// recompute-preemption) and moves the request to [`ReqState::Running`].
    /// Returns `true` on that completing chunk.
    pub fn apply_prefill(&mut self, take: u32) -> bool {
        debug_assert!(self.state == ReqState::Prefilling);
        debug_assert!(take > 0 && take <= self.prefill_remaining());
        self.prefill_done += take;
        self.tokens_in_cache += take as u64;
        if self.prefill_remaining() > 0 {
            return false;
        }
        self.state = ReqState::Running;
        self.generated += 1;
        self.tokens_in_cache += 1;
        true
    }

    /// Is the current turn's generation complete?
    pub fn turn_done(&self) -> bool {
        self.generated >= self.cur_turn().response_tokens
    }

    pub fn is_last_turn(&self) -> bool {
        self.turn + 1 == self.conv.turns.len()
    }

    /// Blocks needed to hold `tokens` at the given block size.
    pub fn blocks_for(tokens: u64, block_size: usize) -> usize {
        tokens.div_ceil(block_size as u64) as usize
    }

    /// Clamp a u64 token count into the u32 `prefill_target` domain
    /// without wrapping. A context anywhere near `u32::MAX` tokens is far
    /// beyond any servable max-model-len, so the saturated target keeps
    /// the request oversized and guarantees the engine's admission check
    /// rejects it — a wrapped value would instead look like a small,
    /// perfectly servable prompt and silently truncate the conversation.
    fn prefill_target_from(tokens: u64) -> u32 {
        u32::try_from(tokens).unwrap_or(u32::MAX)
    }

    /// Begin the next turn (state → Queued). Must not be on the last turn.
    /// If the context was dropped (recompute-preemption at turn end), the
    /// new turn must re-prefill the whole history as well.
    pub fn advance_turn(&mut self, now: Ns) {
        assert!(!self.is_last_turn());
        self.turn += 1;
        self.state = ReqState::Queued;
        self.prefill_done = 0;
        self.generated = 0;
        self.last_emit = None;
        self.prefill_target = if self.kv == KvLocation::None {
            // Prefix-cache tokens never need recomputing: the shared
            // pool blocks are still pinned and valid.
            Self::prefill_target_from(
                (self.history_tokens() + self.cur_turn().prompt_tokens as u64)
                    .saturating_sub(self.prefix_tokens as u64),
            )
        } else {
            self.cur_turn().prompt_tokens
        };
        self.turn_arrival = now;
    }

    /// Drop the KV context entirely (recompute-preemption): the whole
    /// history plus this turn's prompt must be prefilled again.
    pub fn drop_context(&mut self) {
        self.kv = KvLocation::None;
        self.tokens_in_cache = 0;
        // Everything materialized so far must be recomputed: history +
        // this turn's prompt + already-generated output.
        self.prefill_target = Self::prefill_target_from(
            (self.history_tokens()
                + self.cur_turn().prompt_tokens as u64
                + self.generated as u64)
                .saturating_sub(self.prefix_tokens as u64),
        );
        self.prefill_done = 0;
    }
}

/// All live requests, indexed by id.
///
/// The table doubles as the scheduler's dirty-tracking choke point
/// (see [`crate::coordinator::queue`]): every mutable access marks the
/// request dirty, and the engine drains the dirty set each iteration to
/// re-key only changed entries in the incremental candidate index.
/// External events that change a request's scheduler view without
/// touching the record itself (block allocation, prefetch submission)
/// are reported via [`RequestTable::touch`].
#[derive(Clone, Debug, Default)]
pub struct RequestTable {
    reqs: Vec<Request>,
    index: std::collections::HashMap<RequestId, usize>,
    dirty: std::collections::HashSet<RequestId>,
}

impl RequestTable {
    pub fn insert(&mut self, r: Request) {
        self.dirty.insert(r.id);
        self.index.insert(r.id, self.reqs.len());
        self.reqs.push(r);
    }

    pub fn get(&self, id: RequestId) -> &Request {
        &self.reqs[self.index[&id]]
    }

    pub fn try_get(&self, id: RequestId) -> Option<&Request> {
        self.index.get(&id).map(|&i| &self.reqs[i])
    }

    pub fn get_mut(&mut self, id: RequestId) -> &mut Request {
        self.dirty.insert(id);
        &mut self.reqs[self.index[&id]]
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.index.contains_key(&id)
    }

    /// Mark a request's scheduler view dirty without mutating the
    /// record — for residency/prefetch changes tracked outside the
    /// table (allocator grants and releases, swap-manager transitions).
    pub fn touch(&mut self, id: RequestId) {
        self.dirty.insert(id);
    }

    /// Drain the accumulated dirty set into `out` (cleared first). The
    /// order is unspecified; per-id index refreshes are
    /// order-independent.
    pub fn drain_dirty_into(&mut self, out: &mut Vec<RequestId>) {
        out.clear();
        out.extend(self.dirty.drain());
    }

    /// Remove a request entirely (cluster migration: the conversation
    /// leaves this replica and may later return under the same id, so a
    /// stale record must not linger). Swap-remove keeps the index dense.
    pub fn remove(&mut self, id: RequestId) -> Option<Request> {
        let idx = self.index.remove(&id)?;
        self.dirty.insert(id);
        let r = self.reqs.swap_remove(idx);
        if idx < self.reqs.len() {
            let moved = self.reqs[idx].id;
            self.index.insert(moved, idx);
        }
        Some(r)
    }

    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.reqs.iter()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Request> {
        self.dirty.extend(self.reqs.iter().map(|r| r.id));
        self.reqs.iter_mut()
    }

    pub fn ids_in_state(&self, s: ReqState) -> Vec<RequestId> {
        self.reqs
            .iter()
            .filter(|r| r.state == s)
            .map(|r| r.id)
            .collect()
    }

    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    pub fn all_finished(&self) -> bool {
        self.reqs.iter().all(|r| r.state == ReqState::Finished)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Conversation, Turn};

    fn conv(turns: &[(u32, u32)]) -> Conversation {
        Conversation {
            id: 0,
            tenant: 0,
            prefix: None,
            turns: turns
                .iter()
                .map(|&(p, r)| Turn {
                    prompt_tokens: p,
                    response_tokens: r,
                    think_time_s: 1.0,
                })
                .collect(),
        }
    }

    #[test]
    fn fresh_request_targets_first_prompt() {
        let r = Request::new(1, conv(&[(100, 50), (30, 40)]), 0);
        assert_eq!(r.prefill_target, 100);
        assert_eq!(r.state, ReqState::Queued);
        assert_eq!(r.kv, KvLocation::None);
        assert_eq!(r.turn_total_tokens(), 150);
    }

    #[test]
    fn advance_turn_resets_counters() {
        let mut r = Request::new(1, conv(&[(100, 50), (30, 40)]), 0);
        r.generated = 50;
        r.tokens_in_cache = 150;
        r.kv = KvLocation::Cpu; // context preserved
        r.advance_turn(1_000);
        assert_eq!(r.turn, 1);
        assert_eq!(r.prefill_target, 30);
        assert_eq!(r.generated, 0);
        assert_eq!(r.history_tokens(), 150);
        assert_eq!(r.turn_arrival, 1_000);
    }

    #[test]
    fn advance_turn_after_dropped_context_recomputes_history() {
        let mut r = Request::new(1, conv(&[(100, 50), (30, 40)]), 0);
        r.generated = 50;
        r.kv = KvLocation::None; // context lost (recompute-preemption)
        r.tokens_in_cache = 0;
        r.advance_turn(1_000);
        // history (100+50) + new prompt 30
        assert_eq!(r.prefill_target, 180);
    }

    #[test]
    #[should_panic]
    fn advance_past_last_turn_panics() {
        let mut r = Request::new(1, conv(&[(10, 10)]), 0);
        r.advance_turn(0);
    }

    #[test]
    fn drop_context_forces_full_recompute() {
        let mut r = Request::new(1, conv(&[(100, 50), (30, 40)]), 0);
        r.advance_turn(0);
        r.prefill_done = 30;
        r.generated = 10;
        r.tokens_in_cache = 190;
        r.kv = KvLocation::Gpu;
        r.drop_context();
        assert_eq!(r.kv, KvLocation::None);
        assert_eq!(r.tokens_in_cache, 0);
        // history 150 + prompt 30 + generated 10
        assert_eq!(r.prefill_target, 190);
        assert_eq!(r.prefill_done, 0);
    }

    #[test]
    fn huge_history_saturates_prefill_target_instead_of_wrapping() {
        // Regression: `history_tokens()` (u64) used to be cast to u32
        // with `as`, so a conversation whose history exceeded u32::MAX
        // tokens wrapped to a small, plausible-looking prefill target and
        // would have been silently served truncated. The conversion must
        // saturate so the engine's max-model-len admission check fires.
        let mut r = Request::new(1, conv(&[(3_000_000_000, 3_000_000_000), (30, 40)]), 0);
        r.kv = KvLocation::None; // context lost: next turn recomputes history
        r.advance_turn(0);
        // history 6e9 + prompt 30 wraps to ~1.7e9 under `as u32`.
        assert_eq!(r.prefill_target, u32::MAX, "must saturate, not wrap");
    }

    #[test]
    fn drop_context_saturates_on_huge_history() {
        let mut r = Request::new(1, conv(&[(3_000_000_000, 3_000_000_000), (30, 40)]), 0);
        r.kv = KvLocation::Cpu; // context preserved across the turn switch
        r.advance_turn(0);
        assert_eq!(r.prefill_target, 30, "preserved context needs only the prompt");
        r.generated = 10;
        r.drop_context();
        // history 6e9 + prompt 30 + generated 10: saturates.
        assert_eq!(r.prefill_target, u32::MAX, "must saturate, not wrap");
    }

    #[test]
    fn prefix_tokens_stay_out_of_every_recompute_target() {
        let mut r = Request::new(1, conv(&[(100, 50), (30, 40)]), 0);
        r.prefix_tokens = 64; // leading 64 prompt tokens served from the pool
        r.prefill_target = 100 - 64;
        r.generated = 50;
        r.kv = KvLocation::None; // context lost at turn end
        r.tokens_in_cache = 0;
        r.advance_turn(1_000);
        // history (100+50) + prompt 30 − pooled 64
        assert_eq!(r.prefill_target, 180 - 64);
        r.prefill_done = r.prefill_target;
        r.generated = 10;
        r.kv = KvLocation::Gpu;
        r.drop_context();
        // history 150 + prompt 30 + generated 10 − pooled 64
        assert_eq!(r.prefill_target, 190 - 64);
    }

    #[test]
    fn apply_prefill_resumes_across_chunks() {
        let mut r = Request::new(1, conv(&[(100, 50)]), 0);
        r.state = ReqState::Prefilling;
        assert!(!r.apply_prefill(64), "partial chunk does not complete");
        assert_eq!(r.prefill_remaining(), 36);
        assert_eq!(r.tokens_in_cache, 64);
        assert_eq!(r.state, ReqState::Prefilling);
        // The completing chunk emits the first token (+1 KV slot).
        assert!(r.apply_prefill(36));
        assert_eq!(r.state, ReqState::Running);
        assert_eq!(r.generated, 1);
        assert_eq!(r.tokens_in_cache, 101);
    }

    #[test]
    fn blocks_for_rounds_up() {
        assert_eq!(Request::blocks_for(0, 16), 0);
        assert_eq!(Request::blocks_for(1, 16), 1);
        assert_eq!(Request::blocks_for(16, 16), 1);
        assert_eq!(Request::blocks_for(17, 16), 2);
    }

    #[test]
    fn table_state_queries() {
        let mut t = RequestTable::default();
        t.insert(Request::new(1, conv(&[(10, 10)]), 0));
        t.insert(Request::new(2, conv(&[(10, 10)]), 0));
        t.get_mut(2).state = ReqState::Running;
        assert_eq!(t.ids_in_state(ReqState::Queued), vec![1]);
        assert_eq!(t.ids_in_state(ReqState::Running), vec![2]);
        assert!(!t.all_finished());
    }

    #[test]
    fn table_tracks_dirty_ids_across_mutation_paths() {
        let mut t = RequestTable::default();
        let mut dirty = Vec::new();
        t.insert(Request::new(1, conv(&[(10, 10)]), 0));
        t.insert(Request::new(2, conv(&[(10, 10)]), 0));
        t.drain_dirty_into(&mut dirty);
        dirty.sort_unstable();
        assert_eq!(dirty, vec![1, 2], "insert marks dirty");
        t.drain_dirty_into(&mut dirty);
        assert!(dirty.is_empty(), "drain clears the set");
        t.get_mut(2).state = ReqState::Running;
        t.touch(1);
        t.drain_dirty_into(&mut dirty);
        dirty.sort_unstable();
        assert_eq!(dirty, vec![1, 2], "get_mut and touch mark dirty");
        t.remove(1);
        t.drain_dirty_into(&mut dirty);
        assert_eq!(dirty, vec![1], "remove marks dirty");
        assert!(t.try_get(1).is_none());
        assert_eq!(t.try_get(2).map(|r| r.id), Some(2));
    }

    #[test]
    fn table_remove_keeps_index_dense_and_allows_reinsert() {
        let mut t = RequestTable::default();
        t.insert(Request::new(1, conv(&[(10, 10)]), 0));
        t.insert(Request::new(2, conv(&[(20, 10)]), 0));
        t.insert(Request::new(3, conv(&[(30, 10)]), 0));
        let r = t.remove(2).expect("present");
        assert_eq!(r.id, 2);
        assert_eq!(t.len(), 2);
        assert!(!t.contains(2));
        // Swap-remove moved request 3 into the vacated slot: lookups
        // must still resolve.
        assert_eq!(t.get(3).conv.turns[0].prompt_tokens, 30);
        assert_eq!(t.get(1).conv.turns[0].prompt_tokens, 10);
        assert!(t.remove(2).is_none(), "double remove");
        // The migrated conversation can come back under the same id.
        t.insert(Request::new(2, conv(&[(40, 10)]), 5));
        assert_eq!(t.get(2).conv.turns[0].prompt_tokens, 40);
        assert_eq!(t.len(), 3);
    }
}
