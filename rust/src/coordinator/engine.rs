//! The per-iteration serving loop (virtual time).
//!
//! Ties everything together, per Fig. 5 of the paper: the priority
//! scheduler decides admission; the Dynamic Block Group Manager (or the
//! fixed-block baseline) allocates KV; the Multithreading Swap Manager
//! executes context switches (Algorithm 1); the KV Cache Reuse Mechanism
//! minimizes swap-out volume; the roofline perf model advances the clock.
//!
//! One deliberately *real* measurement: the scheduler's own call-stack
//! time (steps 1–8) is measured in wall-clock and charged to the virtual
//! clock — that is exactly the paper's Fig. 9 "call stack overhead", and
//! it keeps us honest about L3 hot-path cost (<1 % of end-to-end time).

use std::time::Instant;

use crate::block::{buddy::BlockGroupAllocator, fixed::FixedBlockAllocator};
use crate::block::{reuse::KvCacheReuse, KvAllocator};
use crate::config::{EngineConfig, Granularity, PrefillMode, Preset, SwapMode};
use crate::coordinator::priority::Pattern;
use crate::coordinator::request::{KvLocation, ReqState, Request, RequestTable};
use crate::coordinator::scheduler::{predict_admission, schedule, Candidate, IterBudget};
use crate::fairness::policy::{build_policy, PriorityPolicy};
use crate::fairness::TenantId;
use crate::memory::{BlockId, CpuSwapSpace, RequestId};
use crate::metrics::{IterationSample, Recorder};
use crate::sim::clock::{to_secs, Ns};
use crate::sim::link::{Direction, PcieLink};
use crate::sim::PerfModel;
use crate::swap::engine::{BlockMove, SegmentBuilder};
use crate::swap::manager::{
    PrefetchCancel, PrefetchClaim, PrefetchSubmit, SwapInDecision, SwapManager,
};
use crate::swap::op::SwapOp;
use crate::workload::{ArrivalTrace, Conversation, Turn};

/// Everything a finished simulation reports.
#[derive(Debug)]
pub struct ServeOutcome {
    pub recorder: Recorder,
    pub span: Ns,
    pub iterations: u64,
    pub swap_stats: crate::swap::manager::SwapStats,
    pub reuse_blocks_transferred: u64,
    pub reuse_blocks_reused: u64,
    pub contaminated: u64,
    pub label: String,
}

impl ServeOutcome {
    pub fn throughput(&self) -> f64 {
        self.recorder.throughput(self.span)
    }
}

/// What [`ServingEngine::evict_for_migration`] hands the cluster router
/// when a conversation's next turn is placed on a different replica: the
/// unserved remainder plus the context the target replica must rebuild.
#[derive(Clone, Debug)]
pub struct MigratedConv {
    pub conv_id: RequestId,
    pub tenant: u32,
    /// Turns not yet served (the next turn first).
    pub remaining: Vec<Turn>,
    /// Context tokens accumulated on the source replica — the target must
    /// re-prefill all of them (its CPU holds no copy).
    pub history_tokens: u64,
    /// Valid CPU-copy blocks dropped on the source replica — the reuse
    /// the migration destroys (the router's
    /// `retransferred_blocks_on_migration` counter).
    pub cpu_copy_blocks: usize,
}

enum Alloc {
    Fixed(FixedBlockAllocator),
    Group(BlockGroupAllocator),
}

impl Alloc {
    fn as_dyn(&mut self) -> &mut dyn KvAllocator {
        match self {
            Alloc::Fixed(a) => a,
            Alloc::Group(a) => a,
        }
    }
    fn as_dyn_ref(&self) -> &dyn KvAllocator {
        match self {
            Alloc::Fixed(a) => a,
            Alloc::Group(a) => a,
        }
    }
}

pub struct ServingEngine {
    cfg: EngineConfig,
    preset: Preset,
    perf: PerfModel,
    alloc: Alloc,
    cpu: CpuSwapSpace,
    reuse: KvCacheReuse,
    seg: SegmentBuilder,
    pub mgr: SwapManager,
    /// Source of scheduling priorities: the offline trace or an online
    /// fairness policy (VTC / SLO-aware), per `cfg.fairness`.
    policy: Box<dyn PriorityPolicy>,
    reqs: RequestTable,
    /// Conversations not yet arrived: (arrival, conversation), sorted desc
    /// so we pop from the back.
    future: Vec<(Ns, Conversation)>,
    /// (request, due-time) for turns waiting out think time.
    pending_turns: Vec<(RequestId, Ns)>,
    pub rec: Recorder,
    now: Ns,
    iter: u64,
    epoch_iters: u64,
    last_epoch: u64,
    gpu_blocks: usize,
    block_size: usize,
    /// Per-iteration token budget (decode claims + prefill chunks);
    /// roofline-sized at init when the config says 0.
    iter_budget: u32,
    /// Wall-clock → virtual charging of scheduler overhead (Fig. 9).
    pub charge_sched_overhead: bool,
    /// Cluster mode: turn transitions are *held* for the front-end router
    /// instead of self-scheduled — `end_turn` reports the next turn via
    /// [`ServingEngine::take_released_turns`] and the router decides
    /// placement ([`ServingEngine::fire_turn`] to keep it here,
    /// [`ServingEngine::evict_for_migration`] to move it).
    pub hold_turns: bool,
    /// Next turns awaiting a router placement decision: (request, due).
    released_turns: Vec<(RequestId, Ns)>,
    /// Lookahead prefetcher: predicted re-admissions not yet submitted
    /// (drained across iterations as budget and free blocks allow).
    prefetch_queue: Vec<RequestId>,
    /// Epoch the policy projection was last rebuilt at.
    prefetch_epoch: u64,
    /// When a budget-rejected prefetch becomes submittable again — an
    /// idle engine wakes for the refill instead of sleeping past it.
    prefetch_retry_at: Option<Ns>,
    /// Requests whose context can never fit the prefetch burst budget
    /// (contexts only grow): permanently excluded, so the per-iteration
    /// due-turn scan cannot churn them through allocate/reject cycles.
    prefetch_never_fits: std::collections::HashSet<RequestId>,
    /// EMA of recent working-iteration spans (ns) — converts the epoch
    /// lookahead depth into the wall-clock horizon for pending turns.
    iter_span_ema: f64,
}

impl ServingEngine {
    pub fn new(
        cfg: EngineConfig,
        preset: Preset,
        pattern: Pattern,
        convs: Vec<Conversation>,
        arrivals: ArrivalTrace,
        seed: u64,
    ) -> Self {
        let gpu_blocks = preset.gpu_blocks();
        let cpu_blocks = preset.cpu_blocks();
        let block_size = preset.model.block_size;
        let alloc = match cfg.granularity {
            Granularity::FixedBlock => Alloc::Fixed(FixedBlockAllocator::new(gpu_blocks)),
            Granularity::BlockGroup { init_group_blocks } => Alloc::Group(
                BlockGroupAllocator::new(gpu_blocks, init_group_blocks, seed),
            ),
        };
        let perf = PerfModel::new(preset.model.clone(), preset.gpu.clone());
        let link = PcieLink::new(preset.gpu.clone());
        let mut mgr = SwapManager::new(cfg.swap_mode, cfg.dispatch, &cfg.swap_cost, link);
        mgr.configure_prefetch(cfg.prefetch.io_budget * preset.gpu.pcie_bw);
        let seg = SegmentBuilder::new(preset.model.clone(), cfg.granularity);
        let reuse = KvCacheReuse::new(cfg.reuse, block_size);
        let policy = build_policy(
            &cfg.fairness,
            pattern,
            cfg.scheduler.priority_levels,
            seed,
        );
        let epoch_iters = (1.0 / cfg.scheduler.priority_update_freq).round().max(1.0) as u64;
        let iter_budget = if cfg.scheduler.max_tokens_per_iter == 0 {
            perf.suggest_token_budget(cfg.scheduler.max_batch)
        } else {
            cfg.scheduler.max_tokens_per_iter as u32
        };

        // Seeded with a one-request decode iteration; converges onto the
        // real cadence within a few working iterations.
        let iter_span_seed = perf.decode_iter_ns(1, 0) as f64;
        let mut future: Vec<(Ns, Conversation)> = arrivals
            .entries
            .iter()
            .map(|e| (e.arrival, convs[e.conversation as usize].clone()))
            .collect();
        future.sort_by(|a, b| b.0.cmp(&a.0)); // pop() yields earliest

        ServingEngine {
            cfg,
            preset,
            perf,
            alloc,
            cpu: CpuSwapSpace::new(cpu_blocks),
            reuse,
            seg,
            mgr,
            policy,
            reqs: RequestTable::default(),
            future,
            pending_turns: Vec::new(),
            rec: Recorder::default(),
            now: 0,
            iter: 0,
            epoch_iters,
            last_epoch: u64::MAX,
            gpu_blocks,
            block_size,
            iter_budget,
            charge_sched_overhead: true,
            hold_turns: false,
            released_turns: Vec::new(),
            prefetch_queue: Vec::new(),
            prefetch_epoch: u64::MAX,
            prefetch_retry_at: None,
            prefetch_never_fits: std::collections::HashSet::new(),
            iter_span_ema: iter_span_seed,
        }
    }

    pub fn now(&self) -> Ns {
        self.now
    }

    /// The resolved per-iteration token budget (after roofline
    /// auto-sizing).
    pub fn token_budget(&self) -> u32 {
        self.iter_budget
    }

    fn budget(&self) -> IterBudget {
        match self.cfg.scheduler.prefill_mode {
            PrefillMode::Monolithic => IterBudget::monolithic(),
            PrefillMode::Chunked => IterBudget::chunked(
                self.iter_budget,
                self.cfg.scheduler.prefill_chunk as u32,
            ),
        }
    }

    pub fn iterations(&self) -> u64 {
        self.iter
    }

    // ------------------------------------------------------------------
    // Step phases
    // ------------------------------------------------------------------

    /// Admission rule: a turn whose full context (plus the first-token
    /// slot) cannot fit the whole GPU KV space can never be served —
    /// reject the conversation (vLLM's max-model-len check).
    fn reject_if_oversized(&mut self, id: RequestId) -> bool {
        let r = self.reqs.get(id);
        let worst = r.turn_total_tokens() + 1;
        if Request::blocks_for(worst, self.block_size) <= self.gpu_blocks {
            return false;
        }
        // A rejected conversation may hold speculatively prefetched GPU
        // blocks: free them now (or let an in-flight transfer drain —
        // `reap_prefetch_drains` frees the blocks then).
        match self.mgr.cancel_prefetch(id, self.now) {
            Some(PrefetchCancel::Draining { .. }) => {}
            _ => {
                self.alloc.as_dyn().release(id);
            }
        }
        self.cpu.drop_request(id);
        self.reuse.forget(id);
        let r = self.reqs.get_mut(id);
        r.state = ReqState::Finished;
        r.kv = KvLocation::None;
        self.rec.rejected_conversations += 1;
        true
    }

    fn admit_arrivals(&mut self) {
        while self.future.last().is_some_and(|(t, _)| *t <= self.now) {
            let (t, conv) = self.future.pop().unwrap();
            let id = conv.id;
            let tenant = conv.tenant;
            let r = Request::new(id, conv, t);
            self.rec.turn_arrival(id, 0, t, tenant);
            self.reqs.insert(r);
            self.reject_if_oversized(id);
        }
        // Turns whose think time elapsed AND whose turn-end swap-out has
        // drained (requests still in SwappingOutTurnEnd stay pending and
        // fire right after harvest transitions them).
        let mut due = Vec::new();
        let reqs = &self.reqs;
        self.pending_turns.retain(|&(id, t)| {
            if t <= self.now && reqs.get(id).state == ReqState::WaitingTurn {
                due.push((id, t));
                false
            } else {
                true
            }
        });
        for (id, t) in due {
            let r = self.reqs.get_mut(id);
            r.advance_turn(t.max(r.turn_arrival));
            let turn = r.turn as u32;
            let arr = r.turn_arrival;
            let tenant = r.tenant();
            self.rec.turn_arrival(id, turn, arr, tenant);
            // A later turn may have grown past the servable context.
            self.reject_if_oversized(id);
        }
    }

    /// After a swap-in finished reading the CPU copy: keep it as a
    /// backup (reuse on) or free it (vLLM semantics).
    fn release_cpu_copy_after_swap_in(&mut self, id: RequestId) {
        if self.reuse.enabled() {
            self.cpu.set_required(id, false);
        } else {
            self.cpu.drop_request(id);
            self.reuse.forget(id);
        }
    }

    fn harvest_async(&mut self) {
        for id in self.mgr.poll_completed(self.now) {
            let r = self.reqs.get_mut(id);
            debug_assert_eq!(r.state, ReqState::SwappingIn);
            r.state = if r.prefill_remaining() > 0 {
                ReqState::Prefilling
            } else {
                ReqState::Running
            };
            r.kv = KvLocation::Gpu;
            self.release_cpu_copy_after_swap_in(id);
        }
        let reaped = self.mgr.reap_swap_outs(self.now);
        self.release_reaped(reaped);
        let drained = self.mgr.reap_prefetch_drains(self.now);
        self.release_reaped(drained);
    }

    /// A swap-out drained: free its GPU source blocks and finish the
    /// turn-end transition. (Reuse state was committed at submit; readers
    /// are barriered on the event.)
    fn release_reaped(&mut self, ids: Vec<RequestId>) {
        for id in ids {
            self.alloc.as_dyn().release(id);
            if !self.reqs.contains(id) {
                // Evicted mid-drain (cluster migration): the record is
                // gone; only the source blocks needed freeing.
                continue;
            }
            let r = self.reqs.get_mut(id);
            if r.state == ReqState::SwappingOutTurnEnd {
                r.state = ReqState::WaitingTurn;
            }
        }
    }

    /// Memory-pressure conflict resolution (§3.2): wait for the earliest
    /// in-flight swap-out, release its blocks, and charge the wait.
    /// Returns the synchronization point, or None if nothing is in
    /// flight.
    fn drain_one_swap_out(&mut self, at_least: Ns) -> Option<Ns> {
        let t = self.mgr.next_out_event()?.max(at_least);
        let wait = t.saturating_sub(at_least);
        self.mgr.record_conflict(wait);
        let reaped = self.mgr.reap_swap_outs(t);
        self.release_reaped(reaped);
        Some(t)
    }

    fn update_priorities(&mut self) {
        let epoch = self.iter / self.epoch_iters;
        if epoch == self.last_epoch {
            return;
        }
        self.last_epoch = epoch;
        // Live (unfinished) requests and the distinct tenants backing
        // them; finished requests hold no GPU/CPU state, so their stale
        // priorities are irrelevant.
        let live: Vec<(RequestId, TenantId)> = self
            .reqs
            .iter()
            .filter(|r| r.state != ReqState::Finished)
            .map(|r| (r.id, r.tenant()))
            .collect();
        let mut active: Vec<TenantId> = live.iter().map(|&(_, t)| t).collect();
        active.sort_unstable();
        active.dedup();
        self.policy.on_schedule(epoch, &active);
        for (id, tenant) in live {
            let p = self.policy.priority_of(id, tenant, epoch);
            self.reqs.get_mut(id).priority = p;
            self.cpu.set_priority(id, p);
        }
    }

    // ------------------------------------------------------------------
    // Lookahead swap-in prefetch (speculative context switching)
    // ------------------------------------------------------------------

    /// Rebuild the prediction of upcoming re-admissions, once per
    /// policy epoch: (a) currently swapped-out requests the live
    /// priority policy is projected to promote within `depth` epochs
    /// ([`predict_admission`] — side-effect-free), and (b) stale landed
    /// prefetches the new projection no longer wants are canceled, their
    /// blocks returned (the CPU copy stays the valid version under the
    /// contamination rules).
    fn rebuild_prefetch_predictions(&mut self, epoch: u64, depth: u64) {
        let cands = self.candidates();
        // One projection per candidate via `project_priorities`, which
        // leaves the policy's sequential state (the trace memo) parked
        // at the live epoch — querying `priority_of(epoch + k)` directly
        // would force every later live refresh to replay the walk from
        // epoch 0.
        let projections: std::collections::HashMap<RequestId, Vec<i64>> = cands
            .iter()
            .map(|c| {
                let tenant = self.reqs.get(c.id).tenant();
                (
                    c.id,
                    self.policy.project_priorities(c.id, tenant, epoch, depth),
                )
            })
            .collect();
        let predicted = predict_admission(
            &cands,
            self.gpu_blocks,
            self.cfg.scheduler.max_batch,
            depth,
            |id, offset| projections[&id][(offset - 1) as usize],
        );
        self.prefetch_queue = predicted;
        // Misprediction cleanup: a landed prefetch for a request that is
        // still parked off-GPU and no longer projected (priority flip,
        // pending turn migrated away) is canceled.
        for id in self.mgr.prefetched_ids() {
            if self.prefetch_queue.contains(&id) || !self.reqs.contains(id) {
                continue;
            }
            let r = self.reqs.get(id);
            let parked = matches!(r.state, ReqState::SwappedOut | ReqState::WaitingTurn);
            let due_soon = self
                .pending_turns
                .iter()
                .any(|&(p, t)| p == id && t <= self.now.saturating_add(self.horizon_ns(depth)));
            if !parked || due_soon {
                continue;
            }
            if self.mgr.prefetch_ready(id, self.now) {
                if let Some(PrefetchCancel::Freed { .. }) =
                    self.mgr.cancel_prefetch(id, self.now)
                {
                    self.alloc.as_dyn().release(id);
                }
            }
        }
    }

    /// The epoch lookahead depth expressed in wall-clock nanoseconds
    /// (drives the pending-turn horizon).
    fn horizon_ns(&self, depth: u64) -> Ns {
        (depth as f64 * self.epoch_iters as f64 * self.iter_span_ema) as Ns
    }

    /// The per-iteration prefetch pass: refresh the I/O budget, fold
    /// pending turns whose think time expires within the lookahead
    /// horizon into the prediction (their re-admission is a
    /// near-certainty — the §3.3 multi-turn workload), and submit as
    /// many speculative swap-ins as free blocks, link idleness, and the
    /// byte budget allow. Speculation never preempts and never waits:
    /// anything it cannot do right now is retried next iteration.
    fn prefetch_pass(&mut self) {
        let depth = self.cfg.prefetch.depth;
        if depth == 0 {
            return;
        }
        self.prefetch_retry_at = None; // recomputed below if still starved
        self.mgr.refill_prefetch_budget(self.now);
        let epoch = self.iter / self.epoch_iters;
        if epoch != self.prefetch_epoch {
            self.prefetch_epoch = epoch;
            self.rebuild_prefetch_predictions(epoch, depth);
        }
        // Pending turns are re-scanned every iteration (they appear
        // mid-epoch at turn ends). The submission order is rebuilt so
        // every within-horizon due turn runs first, earliest due time
        // first, with the policy projection behind them.
        let horizon = self.horizon_ns(depth);
        let mut due: Vec<(Ns, RequestId)> = self
            .pending_turns
            .iter()
            .filter(|&&(_, t)| t <= self.now.saturating_add(horizon))
            .map(|&(id, t)| (t, id))
            .collect();
        due.sort_unstable();
        let mut ordered: Vec<RequestId> = due.into_iter().map(|(_, id)| id).collect();
        for &id in &self.prefetch_queue {
            if !ordered.contains(&id) {
                ordered.push(id);
            }
        }
        self.prefetch_queue = ordered;
        // Headroom: leave at least one growth block per admitted
        // request, so speculation never forces the grow pass into
        // preempting a real victim next iteration.
        let headroom = self
            .reqs
            .iter()
            .filter(|q| matches!(q.state, ReqState::Running | ReqState::Prefilling))
            .count();
        let mut i = 0;
        while i < self.prefetch_queue.len() {
            let id = self.prefetch_queue[i];
            if !self.reqs.contains(id)
                || self.mgr.prefetch_pending(id)
                || self.prefetch_never_fits.contains(&id)
            {
                self.prefetch_queue.remove(i);
                continue;
            }
            let r = self.reqs.get(id);
            let eligible = r.kv == KvLocation::Cpu
                && r.tokens_in_cache > 0
                && matches!(r.state, ReqState::SwappedOut | ReqState::WaitingTurn);
            if !eligible {
                self.prefetch_queue.remove(i);
                continue;
            }
            if self.mgr.swap_out_inflight(id).is_some() {
                // The CPU copy is still being written: retry after drain.
                i += 1;
                continue;
            }
            // Cheap pre-flight before touching the allocator: the op
            // moves every context block, so its bytes are exactly
            // n × block_bytes.
            let n = Request::blocks_for(r.tokens_in_cache, self.block_size);
            let bytes = n as u64 * self.preset.model.block_bytes();
            match self.mgr.prefetch_admissible(bytes, self.now) {
                PrefetchSubmit::Started => {}
                PrefetchSubmit::RejectedTooLarge => {
                    // Can never fit the burst budget (contexts only
                    // grow): exclude the request permanently so the
                    // due-turn scan cannot churn it back in.
                    self.prefetch_never_fits.insert(id);
                    self.prefetch_queue.remove(i);
                    continue;
                }
                PrefetchSubmit::RejectedBudget => {
                    // Bucket dry: wake exactly when the refill covers it.
                    self.prefetch_retry_at =
                        self.mgr.prefetch_budget_eta(bytes, self.now);
                    break;
                }
                PrefetchSubmit::RejectedBusy => {
                    break; // demand traffic owns the link: back off
                }
            }
            if self.alloc.as_dyn_ref().available_blocks() < n + headroom {
                break; // no free blocks — prefetch never preempts for space
            }
            let Some(blocks) = self.alloc.as_dyn().allocate(id, n) else {
                break;
            };
            let op = self.build_swap_in_op(id, &blocks);
            match self.mgr.submit_prefetch(op, self.now) {
                PrefetchSubmit::Started => {
                    self.prefetch_queue.remove(i);
                }
                _ => {
                    // Pre-flight said yes, submit said no — can only be
                    // a racing state change; give the blocks back.
                    self.alloc.as_dyn().release(id);
                    break;
                }
            }
        }
    }

    /// Blocks to grow `r` by a prefill grant of `take` tokens. The grant
    /// that completes the prompt also emits the turn's first output
    /// token, whose KV occupies a slot too; with `take == rem == 0`
    /// (a decode-ready request) that degenerates to the next decode
    /// slot — exactly what re-admission must reserve.
    fn prefill_blocks(&self, r: &Request, take: u32) -> usize {
        let rem = r.prefill_remaining();
        let extra = u64::from(take == rem);
        let after = r.tokens_in_cache + take as u64 + extra;
        Request::blocks_for(after, self.block_size)
            .saturating_sub(Request::blocks_for(r.tokens_in_cache, self.block_size))
    }

    /// The largest prefill grant admission must budget blocks for: one
    /// chunk (chunked mode) or the whole remaining prompt (monolithic
    /// all-or-nothing admission).
    fn admit_take(&self, r: &Request) -> u32 {
        let rem = r.prefill_remaining();
        match self.cfg.scheduler.prefill_mode {
            PrefillMode::Monolithic => rem,
            PrefillMode::Chunked => (self.cfg.scheduler.prefill_chunk as u32).min(rem),
        }
    }

    fn chunk_blocks(&self, r: &Request) -> usize {
        self.prefill_blocks(r, self.admit_take(r))
    }

    fn candidates(&self) -> Vec<Candidate> {
        self.reqs
            .iter()
            .filter(|r| {
                matches!(
                    r.state,
                    ReqState::Running
                        | ReqState::Prefilling
                        | ReqState::SwappingIn
                        | ReqState::Queued
                        | ReqState::SwappedOut
                )
            })
            .map(|r| {
                let held = self.alloc.as_dyn_ref().table(r.id).len();
                // Off-GPU candidates normally hold no blocks (a draining
                // async swap-out's source blocks are counted conservatively
                // on top of the full re-admission ask — see `schedule`'s
                // transient-inflation note). A *prefetched* candidate is
                // the exception: its context blocks are already resident,
                // so only the remainder of the ask is fresh demand.
                let full_swap_in = |r: &Request| {
                    let full = Request::blocks_for(r.tokens_in_cache, self.block_size)
                        + self.chunk_blocks(r);
                    if self.mgr.prefetch_pending(r.id) {
                        full.saturating_sub(held)
                    } else {
                        full
                    }
                };
                let needed = match r.state {
                    ReqState::Running => {
                        Request::blocks_for(r.tokens_in_cache + 1, self.block_size)
                            .saturating_sub(held)
                    }
                    ReqState::Prefilling => self.chunk_blocks(r),
                    ReqState::SwappingIn => 0,
                    ReqState::SwappedOut => full_swap_in(r),
                    ReqState::Queued => {
                        if r.kv == KvLocation::Cpu {
                            full_swap_in(r)
                        } else {
                            self.chunk_blocks(r)
                        }
                    }
                    _ => 0,
                };
                Candidate {
                    id: r.id,
                    priority: r.priority,
                    turn_arrival: r.turn_arrival,
                    // Queued-with-CPU-KV behaves like SwappedOut for the
                    // scheduler (needs promotion, not a fresh start).
                    state: if r.state == ReqState::Queued && r.kv == KvLocation::Cpu {
                        ReqState::SwappedOut
                    } else {
                        r.state
                    },
                    blocks_held: held,
                    blocks_needed: needed,
                    prefill_remaining: r.prefill_remaining(),
                }
            })
            .collect()
    }

    /// Swap out (or drop) one GPU-resident request. Returns main-thread
    /// stall charged to this iteration.
    fn preempt(&mut self, id: RequestId, turn_end: bool) -> Ns {
        let r = self.reqs.get_mut(id);
        let tokens = r.tokens_in_cache;
        let prio = r.priority;
        let plan = self.reuse.plan_swap_out(id, tokens, &self.cpu);
        // Re-transferred blocks that already own a CPU slot (the stale
        // partial tail) are overwritten in place; only genuinely new
        // logicals need fresh slots.
        let existing: std::collections::HashSet<u32> =
            self.cpu.valid_logical(id).into_iter().collect();
        let fresh: Vec<u32> = plan
            .transfer
            .iter()
            .copied()
            .filter(|l| !existing.contains(l))
            .collect();
        // Secure CPU slots for the blocks that must move.
        let copies = match self.cpu.add_copies(id, &fresh, prio) {
            Some(c) => Some(c),
            None => {
                self.cpu.contaminate_backups(fresh.len(), prio);
                self.cpu.add_copies(id, &fresh, prio)
            }
        };
        let Some(_) = copies else {
            // CPU swap space exhausted even after contamination →
            // recompute-preemption (vLLM's fallback).
            self.alloc.as_dyn().release(id);
            self.cpu.drop_request(id);
            self.reuse.forget(id);
            let r = self.reqs.get_mut(id);
            r.drop_context();
            r.state = if turn_end {
                // Lost context at turn end: the next turn will recompute.
                ReqState::WaitingTurn
            } else {
                ReqState::Queued
            };
            self.rec.recompute_preemptions += 1;
            return 0;
        };
        // Build moves: logical → (gpu block, cpu slot).
        let slot_of: std::collections::HashMap<u32, u32> = self
            .cpu
            .copies_of(id)
            .map(|c| c.entries.iter().map(|e| (e.logical, e.slot)).collect())
            .unwrap_or_default();
        let table = self.alloc.as_dyn_ref().table(id).to_vec();
        let moves: Vec<BlockMove> = plan
            .transfer
            .iter()
            .map(|&l| BlockMove {
                logical: l,
                gpu: table[l as usize],
                cpu: slot_of[&l],
            })
            .collect();
        let op = self.seg.build(id, Direction::Out, &moves);
        let nothing_in_flight = op.segments.is_empty();
        let stall = self.mgr.submit_swap_out(op, self.now);
        // Synchronous engines free the source blocks now (the copy is
        // complete); asynchronous ones keep them allocated until the op
        // drains — reusing them earlier is exactly the KV-cache conflict
        // of §3.2, which the allocator-pressure path below resolves with
        // fine-grained synchronization.
        let async_out = !matches!(self.mgr.mode(), SwapMode::Sync) && !nothing_in_flight;
        if !async_out {
            self.alloc.as_dyn().release(id);
        }
        self.cpu.set_required(id, true);
        // The copy's content is fixed at submit; readers are barriered on
        // the completion event, so the reuse state can commit now.
        self.reuse.commit_swap_out(id, tokens);
        let sync_done = matches!(self.mgr.mode(), SwapMode::Sync) || nothing_in_flight;
        let r = self.reqs.get_mut(id);
        r.kv = KvLocation::Cpu;
        r.state = if turn_end {
            if sync_done {
                ReqState::WaitingTurn
            } else {
                ReqState::SwappingOutTurnEnd
            }
        } else {
            ReqState::SwappedOut
        };
        if !turn_end {
            self.rec.preemptions += 1;
        }
        stall
    }

    /// Build the CPU→GPU op materializing `id`'s whole context onto the
    /// freshly allocated `blocks` (shared by demand promotion and the
    /// speculative prefetch path).
    fn build_swap_in_op(&self, id: RequestId, blocks: &[BlockId]) -> SwapOp {
        let tokens = self.reqs.get(id).tokens_in_cache;
        let logicals = self.reuse.plan_swap_in(tokens);
        let slot_of: std::collections::HashMap<u32, u32> = self
            .cpu
            .copies_of(id)
            .map(|c| c.entries.iter().map(|e| (e.logical, e.slot)).collect())
            .unwrap_or_default();
        let moves: Vec<BlockMove> = logicals
            .iter()
            .map(|&l| BlockMove {
                logical: l,
                gpu: blocks[l as usize],
                cpu: *slot_of.get(&l).expect("required CPU copy present"),
            })
            .collect();
        self.seg.build(id, Direction::In, &moves)
    }

    /// Pressure valve: reclaim the GPU blocks of one unclaimed prefetch
    /// — demand allocation always outranks speculation, so a
    /// (mis)predicted prefetch is evicted before any real victim is
    /// preempted. Landed prefetches free immediately; an in-flight one
    /// is canceled and its short drain is waited out (still far cheaper
    /// than a preemption round-trip). Victims are picked landed-first,
    /// then lowest priority. The victim's CPU copy stays its valid KV
    /// version. Returns the time the blocks are free (≥ `now` when a
    /// drain was waited on), or `None` if there was nothing to reclaim.
    fn cancel_one_prefetch_for_pressure(&mut self, keep: RequestId) -> Option<Ns> {
        let mut victims: Vec<(bool, i64, RequestId)> = self
            .mgr
            .prefetched_ids()
            .into_iter()
            .filter(|&v| v != keep && self.reqs.contains(v))
            .map(|v| {
                (
                    // false sorts first: landed (freeable now) preferred.
                    !self.mgr.prefetch_ready(v, self.now),
                    self.reqs.get(v).priority,
                    v,
                )
            })
            .collect();
        victims.sort_unstable();
        let &(_, _, victim) = victims.first()?;
        match self.mgr.cancel_prefetch(victim, self.now)? {
            PrefetchCancel::Freed { .. } => {
                self.alloc.as_dyn().release(victim);
                Some(self.now)
            }
            PrefetchCancel::Draining { done } => {
                // Account the wait like any other pressure drain so the
                // conflict bucket still explains all recorded swap stall.
                self.mgr.record_conflict(done.saturating_sub(self.now));
                let drained = self.mgr.reap_prefetch_drains(done);
                self.release_reaped(drained);
                Some(done)
            }
        }
    }

    /// Swap a request back in. Returns (stall, newly allocated blocks);
    /// `None` if allocation failed (stays swapped out this iteration).
    fn promote(
        &mut self,
        id: RequestId,
        iter_hint: Ns,
        batch: usize,
        avg_ctx: f64,
    ) -> Option<(Ns, Vec<BlockId>)> {
        // A prefetched request re-admits off its speculative transfer:
        // zero demand swap-in stall when it has landed, an asynchronous
        // remainder-wait when still on the wire. Either way the critical
        // path pays nothing synchronously — the point of the pipeline.
        match self.mgr.claim_prefetch(id, self.now) {
            Some(PrefetchClaim::Ready) => {
                debug_assert_eq!(
                    self.alloc.as_dyn_ref().table(id).len(),
                    Request::blocks_for(
                        self.reqs.get(id).tokens_in_cache,
                        self.block_size
                    ),
                    "prefetched residency must cover the whole context"
                );
                let r = self.reqs.get_mut(id);
                r.state = if r.prefill_remaining() > 0 {
                    ReqState::Prefilling
                } else {
                    ReqState::Running
                };
                r.kv = KvLocation::Gpu;
                self.release_cpu_copy_after_swap_in(id);
                return Some((0, Vec::new()));
            }
            Some(PrefetchClaim::Pending { .. }) => {
                self.reqs.get_mut(id).state = ReqState::SwappingIn;
                return Some((0, Vec::new()));
            }
            None => {}
        }
        // If this request's own swap-out is still writing the CPU copy,
        // synchronize on it first (its GPU blocks are also still held).
        let mut pre_stall: Ns = 0;
        if let Some(done) = self.mgr.swap_out_inflight(id) {
            pre_stall = done.saturating_sub(self.now);
            let reaped = self.mgr.reap_swap_outs(done);
            self.release_reaped(reaped);
        }
        let r = self.reqs.get(id);
        let tokens = r.tokens_in_cache;
        let n = Request::blocks_for(tokens, self.block_size);
        let blocks = loop {
            match self.alloc.as_dyn().allocate(id, n) {
                Some(b) => break b,
                None => {
                    // Pressure: (0) reclaim a speculative prefetch, (1)
                    // drain an in-flight swap-out (conflict) if one
                    // exists; otherwise give up this iteration.
                    if let Some(t) = self.cancel_one_prefetch_for_pressure(id) {
                        pre_stall = pre_stall.max(t.saturating_sub(self.now));
                        continue;
                    }
                    let at = self.now + pre_stall;
                    match self.drain_one_swap_out(at) {
                        Some(t) => pre_stall = t.saturating_sub(self.now),
                        None => return None,
                    }
                }
            }
        };
        let op = self.build_swap_in_op(id, &blocks);
        let mut stall = pre_stall;
        let start_at = self.now + pre_stall;
        match self.mgr.submit_swap_in(op, start_at, iter_hint, batch, avg_ctx) {
            SwapInDecision::Sync { done } => {
                stall = stall.max(done.saturating_sub(self.now));
                let r = self.reqs.get_mut(id);
                r.state = if r.prefill_remaining() > 0 {
                    ReqState::Prefilling
                } else {
                    ReqState::Running
                };
                r.kv = KvLocation::Gpu;
            }
            SwapInDecision::Async => {
                self.reqs.get_mut(id).state = ReqState::SwappingIn;
            }
        }
        // The CPU copy is demoted to a contaminable backup (reuse) or
        // freed (vLLM) only once the swap-in has finished reading it:
        // sync → now, async → at harvest.
        let sync_done = !matches!(
            self.reqs.get(id).state,
            ReqState::SwappingIn
        );
        if sync_done {
            self.release_cpu_copy_after_swap_in(id);
        }
        Some((stall, blocks))
    }

    /// End-of-turn handling after the last response token.
    fn end_turn(&mut self, id: RequestId) -> Ns {
        let r = self.reqs.get_mut(id);
        let turn = r.turn as u32;
        self.rec.turn_finished(id, turn);
        let r = self.reqs.get(id);
        if r.is_last_turn() {
            self.alloc.as_dyn().release(id);
            self.cpu.drop_request(id);
            self.reuse.forget(id);
            let r = self.reqs.get_mut(id);
            r.state = ReqState::Finished;
            r.kv = KvLocation::None;
            self.rec.finished_conversations += 1;
            return 0;
        }
        // Schedule the next turn after think time, and move the KV cache
        // out of precious HBM (multi-turn context preservation — the
        // §3.3 workload). In cluster mode the next turn is instead held
        // for the router's placement decision.
        let think = r.conv.turns[r.turn + 1].think_time_s;
        let due = self.now + (think * 1e9) as Ns;
        if self.hold_turns {
            self.released_turns.push((id, due));
        } else {
            self.pending_turns.push((id, due));
        }
        self.preempt(id, true)
    }

    // ------------------------------------------------------------------
    // One iteration
    // ------------------------------------------------------------------

    /// Advance one scheduler iteration. Returns false when all work is
    /// done.
    pub fn step(&mut self) -> bool {
        // In-flight ops gate the exit too: an evicted conversation's
        // draining swap-out (cluster migration) still holds GPU blocks
        // after its record is gone; a step must reap it. Single-engine
        // serving never hits this — live ops imply a live request.
        if self.reqs.all_finished()
            && self.future.is_empty()
            && self.mgr.next_event().is_none()
        {
            return false;
        }
        let wall0 = Instant::now();
        self.admit_arrivals();
        self.harvest_async();
        self.update_priorities();

        let cands = self.candidates();
        let sched = schedule(
            &cands,
            self.gpu_blocks,
            self.cfg.scheduler.max_batch,
            self.budget(),
        );

        let mut stall: Ns = 0;

        // Preemptions first (frees blocks for promotions).
        for &id in &sched.preempt {
            stall += self.preempt(id, false);
        }

        // Estimate the iteration for the adaptive strategy.
        let running_ids: Vec<RequestId> = sched
            .keep
            .iter()
            .copied()
            .filter(|&id| self.reqs.get(id).state == ReqState::Running)
            .collect();
        let ctx_total: u64 = running_ids
            .iter()
            .map(|&id| self.reqs.get(id).tokens_in_cache)
            .sum();
        let batch_now = running_ids.len();
        let avg_ctx = if batch_now > 0 {
            ctx_total as f64 / batch_now as f64
        } else {
            0.0
        };
        let iter_hint = self.perf.decode_iter_ns(batch_now.max(1), ctx_total);

        let mut new_blocks: Vec<BlockId> = Vec::new();

        // Promotions (swap-ins).
        for &id in &sched.promote {
            if let Some((s, blocks)) = self.promote(id, iter_hint, batch_now, avg_ctx) {
                stall = stall.max(s);
                new_blocks.extend(blocks);
            }
        }

        // Fresh starts (first prefill or recompute).
        for &id in &sched.start {
            self.reqs.get_mut(id).state = ReqState::Prefilling;
        }

        // Resolve the token grants against post-admission reality: a
        // grant is void if its request is mid swap-in (async promote) or
        // failed to promote; allocator pressure below can still preempt
        // a granted request, so the sets are re-filtered afterwards.
        let mut decode_set: Vec<RequestId> = Vec::new();
        let mut prefill_take: Vec<(RequestId, u32)> = Vec::new();
        for g in &sched.grants {
            let r = self.reqs.get(g.id);
            match r.state {
                ReqState::Running if g.decode > 0 => decode_set.push(g.id),
                ReqState::Prefilling if g.prefill > 0 => {
                    let take = g.prefill.min(r.prefill_remaining());
                    if take > 0 {
                        prefill_take.push((g.id, take));
                    }
                }
                _ => {}
            }
        }

        // Growth allocation for this iteration's grants (a decode slot
        // or a chunk's blocks each); preempt lowest-priority victims on
        // failure.
        let mut grow: Vec<(RequestId, usize)> = decode_set
            .iter()
            .map(|&id| {
                let r = self.reqs.get(id);
                let need = Request::blocks_for(r.tokens_in_cache + 1, self.block_size)
                    .saturating_sub(self.alloc.as_dyn_ref().table(id).len());
                (id, need)
            })
            .chain(prefill_take.iter().map(|&(id, take)| {
                let r = self.reqs.get(id);
                (id, self.prefill_blocks(r, take))
            }))
            .collect();
        grow.sort_by_key(|&(id, _)| std::cmp::Reverse(self.reqs.get(id).priority));
        for (id, need) in grow {
            // A victim preempted earlier in this very loop grows no more.
            let resident = matches!(
                self.reqs.get(id).state,
                ReqState::Running | ReqState::Prefilling
            );
            if need == 0 || !resident {
                continue;
            }
            loop {
                if let Some(b) = self.alloc.as_dyn().allocate(id, need) {
                    new_blocks.extend(b);
                    break;
                }
                // Pressure order: (0) reclaim a speculative prefetch —
                // demand growth outranks speculation; (1) KV-cache
                // conflict resolution — wait for an in-flight swap-out
                // to release its source blocks (Algorithm 1, step 3.1);
                // (2) preempt the lowest-priority admitted victim; (3)
                // preempt `id` itself.
                if let Some(t) = self.cancel_one_prefetch_for_pressure(id) {
                    stall = stall.max(t.saturating_sub(self.now));
                    continue;
                }
                if let Some(t) = self.drain_one_swap_out(self.now) {
                    stall = stall.max(t.saturating_sub(self.now));
                    continue;
                }
                let victim = self
                    .reqs
                    .iter()
                    .filter(|r| {
                        r.id != id
                            && matches!(r.state, ReqState::Running | ReqState::Prefilling)
                    })
                    .min_by_key(|r| (r.priority, std::cmp::Reverse(r.turn_arrival)))
                    .map(|r| r.id);
                match victim {
                    Some(v) => stall += self.preempt(v, false),
                    None => {
                        stall += self.preempt(id, false);
                        break;
                    }
                }
            }
        }
        let _ = &new_blocks; // retained for tests/metrics hooks

        // Drop grants whose request lost residency to pressure
        // preemption (their partial prefill progress is preserved for
        // re-admission).
        decode_set.retain(|&id| self.reqs.get(id).state == ReqState::Running);
        prefill_take.retain(|&(id, _)| self.reqs.get(id).state == ReqState::Prefilling);

        // ---- execute: one mixed decode + chunked-prefill iteration ----
        let sched_ns = if self.charge_sched_overhead {
            wall0.elapsed().as_nanos() as Ns
        } else {
            0
        };

        let decode_batch = decode_set.len();
        let decode_ctx: u64 = decode_set
            .iter()
            .map(|&id| self.reqs.get(id).tokens_in_cache)
            .sum();
        // Decode-ready requests the budget (or a monolithic prefill)
        // held back this iteration — the decode-interference population.
        let blocked_decodes = self
            .reqs
            .iter()
            .filter(|r| r.state == ReqState::Running)
            .count()
            .saturating_sub(decode_batch);

        // Requests that emit a token at the end of this iteration.
        let mut emitters: Vec<RequestId> = decode_set.clone();
        let mut prefill_new = 0u64;
        let mut prefill_ctx = 0u64;
        for &(id, take) in &prefill_take {
            let r = self.reqs.get_mut(id);
            let tenant = r.tenant();
            prefill_ctx += r.tokens_in_cache;
            prefill_new += take as u64;
            if r.apply_prefill(take) {
                // The completing chunk emits the turn's next output token
                // (first token on a fresh turn; generation simply
                // continues after a recompute-preemption).
                emitters.push(id);
            }
            // Charge the prefill service to the tenant's virtual-token
            // account chunk-by-chunk: a long prompt accrues virtual
            // tokens as it progresses and cannot dodge the fairness
            // accounting by prefilling atomically. (The emitted token is
            // charged with the emitters below.)
            self.policy.on_tokens(tenant, take as u64, 0);
        }
        for &id in &decode_set {
            let r = self.reqs.get_mut(id);
            r.generated += 1;
            r.tokens_in_cache += 1;
        }
        let dur = self
            .perf
            .mixed_iter_ns(decode_batch, decode_ctx, prefill_new, prefill_ctx);
        // Decode-interference stall: the extra latency decodes suffer
        // from co-running chunks, or the full iteration when prefill
        // work ran while decode-ready requests sat idle.
        let decode_block_ns: Ns = if prefill_new == 0 {
            0
        } else if decode_batch > 0 {
            dur.saturating_sub(self.perf.decode_iter_ns(decode_batch, decode_ctx))
        } else if blocked_decodes > 0 {
            dur
        } else {
            0
        };
        let pure_prefill = prefill_new > 0 && decode_batch == 0;

        let tokens_made = emitters.len() as u32;
        let iter_end = self.now + stall + sched_ns + dur;
        self.now = iter_end;

        let mut turn_ends: Vec<RequestId> = Vec::new();
        for id in emitters {
            let (turn, tenant, arrival, first, gap) = {
                let r = self.reqs.get_mut(id);
                // `generated` was already incremented for this emission,
                // so 1 marks the turn's first token.
                let first = r.generated == 1;
                let gap = r.last_emit.map(|t| iter_end.saturating_sub(t));
                r.last_emit = Some(iter_end);
                (r.turn as u32, r.tenant(), r.turn_arrival, first, gap)
            };
            // One decode token of service; TTFT/TBT feedback for the
            // SLO-aware policy.
            self.policy.on_tokens(tenant, 0, 1);
            if first {
                self.policy
                    .on_ttft(tenant, to_secs(iter_end.saturating_sub(arrival)));
            } else if let Some(g) = gap {
                self.policy.on_tbt(tenant, to_secs(g));
            }
            self.rec.token(id, turn, iter_end);
            if self.reqs.get(id).turn_done() {
                turn_ends.push(id);
            }
        }
        // Turn-end swap-outs: synchronous engines stall here too (vLLM
        // blocks until the copy completes), after the tokens were emitted.
        let mut post_stall: Ns = 0;
        for id in turn_ends {
            post_stall += self.end_turn(id);
        }
        self.now += post_stall;
        let stall = stall + post_stall;

        // Track the working-iteration cadence (idle ticks excluded) —
        // the prefetcher's epoch-to-wall-clock conversion — then give
        // speculation its turn on whatever the iteration left idle.
        if dur > 0 {
            self.iter_span_ema =
                0.9 * self.iter_span_ema + 0.1 * (dur + stall + sched_ns) as f64;
        }
        self.prefetch_pass();

        let waiting_on_swap = self
            .reqs
            .iter()
            .filter(|r| r.state == ReqState::SwappingIn)
            .count() as u32;

        self.rec.iteration(IterationSample {
            at: self.now,
            inference_ns: dur,
            swap_stall_ns: stall,
            sched_overhead_ns: sched_ns,
            tokens: tokens_made,
            is_prefill: pure_prefill,
            prefill_tokens: prefill_new as u32,
            decode_block_ns,
            // Mixed/decode iterations: the actual decode set; pure
            // prefill: the scheduled running batch.
            batch: if pure_prefill {
                batch_now as u32
            } else {
                decode_batch as u32
            },
            waiting_on_swap,
            prefetch_inflight: self.mgr.prefetch_count() as u32,
        });
        self.iter += 1;

        // Idle fast-forward: nothing admitted and nothing running — jump
        // to the next event instead of spinning.
        if dur == 0 && stall == 0 {
            let next_arrival = self.future.last().map(|(t, _)| *t);
            // A pending turn only fires once its swap-out drains, so the
            // effective wake time is max(think-time due, event).
            let next_turn = self
                .pending_turns
                .iter()
                .map(|&(id, t)| {
                    let drain = self
                        .mgr
                        .swap_out_inflight(id)
                        .unwrap_or(self.now);
                    t.max(drain)
                })
                .min();
            let next_swap = self.mgr.next_event();
            // Prefetch lead time: an otherwise idle engine must wake
            // `horizon` *before* a pending turn is due (not at it), or
            // the speculative swap-in would never get to run during the
            // think time. Turns already prefetched or already inside the
            // horizon are excluded — no 1-ns spin.
            let depth = self.cfg.prefetch.depth;
            let prefetch_wake = if depth > 0 {
                let horizon = self.horizon_ns(depth);
                self.pending_turns
                    .iter()
                    .filter(|&&(id, _)| !self.mgr.prefetch_pending(id))
                    .map(|&(_, t)| t.saturating_sub(horizon))
                    .filter(|&w| w > self.now)
                    .min()
            } else {
                None
            };
            // A budget-starved prefetch wakes the engine at the refill
            // instant instead of sleeping until the turn is due.
            let budget_wake = self.prefetch_retry_at.filter(|&t| t > self.now);
            // More speculative work queued behind the prefetch that owns
            // the link right now (RejectedBusy): wake when it completes,
            // or turn 2's lead time is silently lost.
            let link_wake = if depth > 0 && !self.prefetch_queue.is_empty() {
                self.mgr.next_prefetch_completion(self.now)
            } else {
                None
            };
            let nxt = [
                next_arrival,
                next_turn,
                next_swap,
                prefetch_wake,
                budget_wake,
                link_wake,
            ]
            .into_iter()
            .flatten()
            .min();
            if let Some(t) = nxt {
                self.now = self.now.max(t);
            } else if self.reqs.all_finished() && self.future.is_empty() {
                return false;
            } else {
                self.now += 1_000_000; // 1 ms safety tick
            }
        }
        true
    }

    /// Run to completion (or `max_iters`). Returns the outcome summary.
    pub fn run(mut self, max_iters: u64) -> ServeOutcome {
        while self.iter < max_iters {
            if !self.step() {
                break;
            }
        }
        self.into_outcome()
    }

    /// Finalize a router-driven engine: invariant checks + outcome
    /// summary (the tail of [`ServingEngine::run`]).
    pub fn into_outcome(self) -> ServeOutcome {
        let alloc = self.alloc.as_dyn_ref();
        alloc.space().check_invariants();
        self.cpu.check_invariants();
        ServeOutcome {
            span: self.now,
            iterations: self.iter,
            swap_stats: self.mgr.stats.clone(),
            reuse_blocks_transferred: self.reuse.blocks_transferred_out,
            reuse_blocks_reused: self.reuse.blocks_reused,
            contaminated: self.cpu.total_contaminated,
            label: self.cfg.label.clone(),
            recorder: self.rec,
        }
    }

    // ------------------------------------------------------------------
    // Cluster front-end hooks (see crate::cluster)
    // ------------------------------------------------------------------

    /// Enqueue a conversation arriving at virtual time `at` (the cluster
    /// router's dispatch path; `future` stays sorted descending so
    /// `pop()` still yields the earliest arrival).
    pub fn push_arrival(&mut self, conv: Conversation, at: Ns) {
        let idx = self.future.partition_point(|&(t, _)| t > at);
        self.future.insert(idx, (at, conv));
    }

    /// Drain the next-turn events held back by `hold_turns`: (request,
    /// due time after think time). The router must answer each with
    /// [`ServingEngine::fire_turn`] or
    /// [`ServingEngine::evict_for_migration`].
    pub fn take_released_turns(&mut self) -> Vec<(RequestId, Ns)> {
        std::mem::take(&mut self.released_turns)
    }

    /// Router kept the conversation on this replica: schedule its held
    /// next turn at `due` through the normal pending-turn path (the
    /// turn's KV context is still on this replica's CPU).
    pub fn fire_turn(&mut self, id: RequestId, due: Ns) {
        debug_assert!(self.reqs.contains(id));
        self.pending_turns.push((id, due));
    }

    /// Router moved the conversation to another replica: drop every local
    /// trace of it (GPU blocks, CPU copies, reuse state) and hand back
    /// the unserved remainder. Only valid for a conversation whose held
    /// turn has not been fired — i.e. it is waiting out think time with
    /// more turns to go. Returns `None` if the conversation meanwhile
    /// terminated here (e.g. oversize rejection).
    pub fn evict_for_migration(&mut self, id: RequestId) -> Option<MigratedConv> {
        if !self.reqs.contains(id) {
            return None;
        }
        let r = self.reqs.get(id);
        // A turn-end swap-out may still be on the wire
        // (SwappingOutTurnEnd): its content was fixed at submit, so the
        // remainder can migrate now, but the op itself keeps draining —
        // the source blocks stay allocated and visible to the conflict /
        // pressure paths until its completion event, exactly like any
        // other in-flight swap-out ([`Self::release_reaped`] tolerates
        // the record being gone by then).
        if !matches!(
            r.state,
            ReqState::WaitingTurn | ReqState::SwappingOutTurnEnd
        ) || r.is_last_turn()
        {
            return None;
        }
        let history_tokens = r.turn_total_tokens();
        let remaining: Vec<Turn> = r.conv.turns[r.turn + 1..].to_vec();
        let tenant = r.tenant();
        let cpu_copy_blocks = self.cpu.valid_logical(id).len();
        let draining = self.mgr.swap_out_inflight(id).is_some();
        // A speculative prefetch may hold GPU blocks for this
        // conversation: cancel it. A landed one frees with the release
        // below; an in-flight one keeps draining and frees at reap
        // (same tolerance as the draining swap-out).
        let prefetch_draining = matches!(
            self.mgr.cancel_prefetch(id, self.now),
            Some(PrefetchCancel::Draining { .. })
        );
        if !draining && !prefetch_draining {
            self.alloc.as_dyn().release(id);
        }
        self.cpu.drop_request(id);
        self.reuse.forget(id);
        // Remove the record entirely: the conversation may return to this
        // replica later and re-insert under the same id; a stale Finished
        // entry would leak and be rescanned every iteration.
        let _ = self.reqs.remove(id);
        Some(MigratedConv {
            conv_id: id,
            tenant,
            remaining,
            history_tokens,
            cpu_copy_blocks,
        })
    }

    /// Does this replica still have internally schedulable work? A
    /// request parked in `WaitingTurn` whose next turn the router holds
    /// does NOT count — only the router can make it progress. In-flight
    /// swap operations DO count: an evicted conversation's draining
    /// swap-out still holds GPU source blocks that only a step can reap.
    pub fn has_pending_work(&self) -> bool {
        if !self.future.is_empty() || !self.pending_turns.is_empty() {
            return true;
        }
        if self.mgr.ongoing_in_count() > 0 || self.mgr.ongoing_out_count() > 0 {
            return true;
        }
        // A canceled prefetch still draining holds GPU blocks only a
        // step can reap. (Live unclaimed prefetches belong to requests
        // already counted below.)
        if self.mgr.prefetch_draining_count() > 0 {
            return true;
        }
        self.reqs
            .iter()
            .any(|r| !matches!(r.state, ReqState::Finished | ReqState::WaitingTurn))
    }

    /// GPU KV blocks currently allocated (placement load signal).
    pub fn gpu_blocks_in_use(&self) -> usize {
        self.alloc.as_dyn_ref().space().used_blocks()
    }

    /// Admission backlog: dispatched-but-unserved arrivals, scheduled
    /// pending turns, and requests waiting for GPU residency (placement
    /// load signal).
    pub fn backlog(&self) -> usize {
        self.future.len()
            + self.pending_turns.len()
            + self
                .reqs
                .iter()
                .filter(|r| matches!(r.state, ReqState::Queued | ReqState::SwappedOut))
                .count()
    }

    /// Max decode batch (normalizes the backlog in load scores).
    pub fn max_batch(&self) -> usize {
        self.cfg.scheduler.max_batch
    }

    /// Testing/experiment access.
    pub fn request_state(&self, id: RequestId) -> Option<ReqState> {
        if self.reqs.contains(id) {
            Some(self.reqs.get(id).state)
        } else {
            None
        }
    }

    pub fn gpu_capacity_blocks(&self) -> usize {
        self.gpu_blocks
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::workload::sharegpt::{generate, ShareGptConfig};

    /// Small contended testbed: LLaMA-8B timing constants but only a few
    /// hundred KV blocks, so preemption pressure appears with ~10
    /// conversations.
    fn test_preset(gpu_blocks_target: usize) -> Preset {
        let model = crate::config::ModelSpec::llama8b();
        let mut gpu = GpuSpec::a10();
        // Shrink HBM so preset.gpu_blocks() == gpu_blocks_target.
        gpu.hbm_bytes =
            ((model.weight_bytes() + gpu_blocks_target as u64 * model.block_bytes())
                as f64
                / gpu.mem_util) as u64
                + (1 << 20);
        Preset {
            model,
            gpu,
            cpu_swap_bytes: 4096 * 4 * 1024 * 1024, // plenty
        }
    }

    fn small_workload(n: usize, seed: u64) -> (Vec<Conversation>, ArrivalTrace) {
        let mut cfg = ShareGptConfig::default();
        cfg.mean_turns = 3.0;
        cfg.max_prompt = 256;
        cfg.max_response = 128;
        cfg.mean_think_s = 2.0;
        let convs = generate(&cfg, n, seed);
        let tr = ArrivalTrace::poisson(&convs, 2.0, seed ^ 1);
        (convs, tr)
    }

    fn run_with(cfg: EngineConfig, blocks: usize, n_conv: usize, seed: u64) -> ServeOutcome {
        let (convs, tr) = small_workload(n_conv, seed);
        let mut e = ServingEngine::new(
            cfg,
            test_preset(blocks),
            Pattern::Markov,
            convs,
            tr,
            seed,
        );
        e.charge_sched_overhead = false; // determinism for tests
        e.run(200_000)
    }

    #[test]
    fn completes_all_conversations_fastswitch() {
        let out = run_with(EngineConfig::fastswitch(), 400, 12, 1);
        assert_eq!(out.recorder.finished_conversations, 12);
        assert!(out.recorder.total_tokens > 0);
        assert!(!out.recorder.ttft().is_empty());
        assert!(!out.recorder.tbt().is_empty());
    }

    #[test]
    fn completes_all_conversations_vllm_baseline() {
        let out = run_with(EngineConfig::vllm_baseline(), 400, 12, 1);
        assert_eq!(out.recorder.finished_conversations, 12);
    }

    #[test]
    fn online_policies_complete_all_conversations() {
        use crate::fairness::PolicyKind;
        for kind in [PolicyKind::Vtc, PolicyKind::SloAware] {
            let mut cfg = EngineConfig::fastswitch();
            cfg.fairness.policy = kind;
            let out = run_with(cfg, 400, 12, 1);
            assert_eq!(
                out.recorder.finished_conversations, 12,
                "{kind:?} lost conversations"
            );
            assert!(out.recorder.total_tokens > 0);
        }
    }

    #[test]
    fn contended_memory_causes_preemptions() {
        let mut cfg = EngineConfig::vllm_baseline();
        cfg.scheduler.priority_update_freq = 0.25; // churn priorities hard
        let out = run_with(cfg, 96, 16, 2);
        assert_eq!(out.recorder.finished_conversations, 16);
        assert!(
            out.recorder.preemptions + out.recorder.recompute_preemptions > 0,
            "expected preemption under contention"
        );
        assert!(out.swap_stats.swap_out_ops > 0);
    }

    #[test]
    fn fastswitch_beats_baseline_on_stall_time() {
        let mut base = EngineConfig::vllm_baseline();
        base.scheduler.priority_update_freq = 0.25;
        let mut fast = EngineConfig::fastswitch();
        fast.scheduler.priority_update_freq = 0.25;
        let ob = run_with(base, 96, 16, 3);
        let of = run_with(fast, 96, 16, 3);
        let (_, swap_b, _) = ob.recorder.stall_breakdown();
        let (_, swap_f, _) = of.recorder.stall_breakdown();
        assert!(
            swap_f < swap_b,
            "fastswitch stall {swap_f} !< baseline {swap_b}"
        );
    }

    #[test]
    fn reuse_reduces_swap_out_blocks() {
        let mut base = EngineConfig::with_dbg();
        base.scheduler.priority_update_freq = 0.25;
        let mut reuse = EngineConfig::with_dbg_reuse();
        reuse.scheduler.priority_update_freq = 0.25;
        let ob = run_with(base, 96, 16, 4);
        let orr = run_with(reuse, 96, 16, 4);
        assert!(orr.reuse_blocks_reused > 0, "reuse must trigger");
        assert!(
            orr.reuse_blocks_transferred < ob.reuse_blocks_transferred,
            "reuse {} !< baseline {}",
            orr.reuse_blocks_transferred,
            ob.reuse_blocks_transferred
        );
    }

    #[test]
    fn dbg_coarser_granularity_than_fixed() {
        let mut base = EngineConfig::vllm_baseline();
        base.scheduler.priority_update_freq = 0.25;
        let mut dbg = EngineConfig::with_dbg();
        dbg.scheduler.priority_update_freq = 0.25;
        let ob = run_with(base, 96, 16, 5);
        let od = run_with(dbg, 96, 16, 5);
        assert!(ob.swap_stats.avg_granularity() < 1.5);
        assert!(
            od.swap_stats.avg_granularity() > 2.0 * ob.swap_stats.avg_granularity(),
            "dbg granularity {} vs fixed {}",
            od.swap_stats.avg_granularity(),
            ob.swap_stats.avg_granularity()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_with(EngineConfig::fastswitch(), 128, 8, 7);
        let b = run_with(EngineConfig::fastswitch(), 128, 8, 7);
        assert_eq!(a.span, b.span);
        assert_eq!(a.recorder.total_tokens, b.recorder.total_tokens);
        assert_eq!(a.swap_stats.total_calls, b.swap_stats.total_calls);
    }

    #[test]
    fn chunked_mode_mixes_decodes_with_prefill_chunks() {
        // Under the default chunked scheduler, prompt chunks co-run with
        // decode steps: some iterations must carry both prefill tokens
        // and a non-empty decode batch, and the decode-interference
        // bucket must be charged for them.
        let out = run_with(EngineConfig::fastswitch(), 400, 12, 1);
        let mixed = out
            .recorder
            .iterations
            .iter()
            .any(|s| s.prefill_tokens > 0 && !s.is_prefill && s.batch > 0);
        assert!(mixed, "no mixed decode+prefill iteration observed");
        assert!(out.recorder.decode_interference_ns() > 0);
        assert!(out.recorder.prefill_tokens() > 0);
    }

    #[test]
    fn monolithic_mode_completes_and_stalls_decodes() {
        let mut cfg = EngineConfig::fastswitch();
        cfg.scheduler.prefill_mode = PrefillMode::Monolithic;
        let out = run_with(cfg, 400, 12, 1);
        assert_eq!(out.recorder.finished_conversations, 12);
        // Whole prompts run in exclusive iterations: no mixed ones.
        assert!(out
            .recorder
            .iterations
            .iter()
            .all(|s| s.prefill_tokens == 0 || s.batch == 0 || s.is_prefill));
    }

    #[test]
    fn chunked_caps_prefill_per_iteration() {
        let mut cfg = EngineConfig::fastswitch();
        cfg.scheduler.prefill_chunk = 64;
        cfg.scheduler.max_tokens_per_iter = 96;
        let out = run_with(cfg, 400, 12, 1);
        assert_eq!(out.recorder.finished_conversations, 12);
        assert!(out
            .recorder
            .iterations
            .iter()
            .all(|s| s.prefill_tokens <= 96));
    }

    #[test]
    fn token_budget_auto_sizes_from_roofline() {
        let (convs, tr) = small_workload(4, 1);
        let e = ServingEngine::new(
            EngineConfig::fastswitch(),
            test_preset(400),
            Pattern::Markov,
            convs,
            tr,
            1,
        );
        let b = e.token_budget();
        // max_batch (32) decode claims plus a roofline-sized chunk term.
        assert!(b > 32 && b < 4096, "budget = {b}");
    }

    #[test]
    fn prefetch_enabled_run_completes_and_lands_hits() {
        // Multi-turn think times make pending-turn re-admissions the
        // prefetcher's bread and butter: with lookahead on, speculative
        // swap-ins must land and be claimed, and the workload must drain
        // to exactly the same token totals as the demand-only run.
        let mut cfg = EngineConfig::fastswitch();
        cfg.prefetch.depth = 2;
        let out = run_with(cfg, 400, 12, 1);
        assert_eq!(out.recorder.finished_conversations, 12);
        assert!(out.swap_stats.prefetch_ops > 0, "no speculation issued");
        assert!(out.swap_stats.prefetch_hits > 0, "no prefetch ever claimed");
        assert!(out.swap_stats.prefetch_hit_rate() > 0.0);
        assert!(out
            .recorder
            .iterations
            .iter()
            .any(|s| s.prefetch_inflight > 0));
        let base = run_with(EngineConfig::fastswitch(), 400, 12, 1);
        assert_eq!(base.swap_stats.prefetch_ops, 0, "default stays demand-only");
        assert_eq!(out.recorder.total_tokens, base.recorder.total_tokens);
    }

    #[test]
    fn prefetch_under_contention_completes_and_cancels_safely() {
        // Hard priority churn on a tiny pool: predictions flip, landed
        // prefetches get canceled for pressure/staleness, and the final
        // allocator/CPU-space invariant checks (run by `into_outcome`)
        // must still hold with every conversation served.
        let mut cfg = EngineConfig::fastswitch();
        cfg.scheduler.priority_update_freq = 0.25;
        cfg.prefetch.depth = 2;
        let out = run_with(cfg, 96, 16, 2);
        assert_eq!(out.recorder.finished_conversations, 16);
        assert!(out.swap_stats.prefetch_ops > 0);
    }

    #[test]
    fn prefetch_runs_are_deterministic() {
        let mk = || {
            let mut cfg = EngineConfig::fastswitch();
            cfg.prefetch.depth = 2;
            run_with(cfg, 128, 8, 7)
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.span, b.span);
        assert_eq!(a.recorder.total_tokens, b.recorder.total_tokens);
        assert_eq!(a.swap_stats.prefetch_ops, b.swap_stats.prefetch_ops);
        assert_eq!(a.swap_stats.prefetch_hits, b.swap_stats.prefetch_hits);
        assert_eq!(
            a.swap_stats.prefetch_wasted_bytes,
            b.swap_stats.prefetch_wasted_bytes
        );
    }

    #[test]
    fn ttft_includes_queueing_and_swap_delays() {
        let out = run_with(EngineConfig::vllm_baseline(), 96, 16, 8);
        let ttft = out.recorder.ttft();
        // Tail must exceed median under contention.
        assert!(ttft.p(99.0) > ttft.p(50.0));
    }
}
