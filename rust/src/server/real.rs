//! Real serving engine over PJRT (wall-clock latencies, physical swaps).

use std::time::Instant;

use crate::block::{buddy::BlockGroupAllocator, fixed::FixedBlockAllocator, KvAllocator};
use crate::config::Granularity;
use crate::memory::{CpuSwapSpace, RequestId};
use crate::runtime::{PjrtModel, RuntimeError};
use crate::swap::pool::{CopyPool, CopyTask};
use crate::util::stats::Percentiles;

/// One request to serve: a prompt plus a generation budget.
#[derive(Clone, Debug)]
pub struct RealRequestSpec {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub priority: i64,
}

#[derive(Clone, Debug)]
pub struct RealEngineConfig {
    pub granularity: Granularity,
    /// Copy-pool workers (0 → inline copies, the GIL-path analogue).
    pub copy_workers: usize,
    /// CPU swap slots (blocks).
    pub cpu_slots: usize,
    pub max_batch: usize,
}

impl Default for RealEngineConfig {
    fn default() -> Self {
        RealEngineConfig {
            granularity: Granularity::BlockGroup {
                init_group_blocks: 8,
            },
            copy_workers: 4,
            cpu_slots: 512,
            max_batch: 8,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum St {
    Queued,
    Running,
    SwappedOut,
    Done,
}

struct Slot {
    id: RequestId,
    spec: RealRequestSpec,
    state: St,
    /// Tokens whose KV is materialized (prompt prefilled + decoded).
    context: usize,
    prefilled: usize,
    generated: Vec<i32>,
    started: Instant,
    first_token: Option<f64>,
    token_times: Vec<f64>,
}

/// Serving results with wall-clock latencies.
#[derive(Debug)]
pub struct RealOutcome {
    pub completions: Vec<(RequestId, Vec<i32>)>,
    pub ttft_s: Percentiles,
    pub tbt_s: Percentiles,
    pub tokens: u64,
    pub wall_s: f64,
    pub preemptions: u64,
    pub swapped_blocks: u64,
    pub decode_iters: u64,
    pub throughput_tok_s: f64,
}

enum Alloc {
    Fixed(FixedBlockAllocator),
    Group(BlockGroupAllocator),
}

impl Alloc {
    fn a(&mut self) -> &mut dyn KvAllocator {
        match self {
            Alloc::Fixed(x) => x,
            Alloc::Group(x) => x,
        }
    }
    fn ar(&self) -> &dyn KvAllocator {
        match self {
            Alloc::Fixed(x) => x,
            Alloc::Group(x) => x,
        }
    }
}

pub struct RealEngine {
    model: PjrtModel,
    cfg: RealEngineConfig,
    alloc: Alloc,
    cpu_space: CpuSwapSpace,
    /// CPU swap pool: slot-major, per slot `n_layers · 2 · block_layer`
    /// f32 (all layers of one block, K then V per layer).
    cpu_pool: Vec<f32>,
    pool: Option<CopyPool>,
    slots: Vec<Slot>,
    preemptions: u64,
    swapped_blocks: u64,
    decode_iters: u64,
}

impl RealEngine {
    pub fn new(model: PjrtModel, cfg: RealEngineConfig) -> Self {
        // Block 0 is the model's reserved null block; allocator ids start
        // at 1, so hand it num_blocks-1 usable blocks.
        let usable = model.meta.num_blocks - 1;
        let alloc = match cfg.granularity {
            Granularity::FixedBlock => Alloc::Fixed(FixedBlockAllocator::new(usable)),
            Granularity::BlockGroup { init_group_blocks } => {
                Alloc::Group(BlockGroupAllocator::new(usable, init_group_blocks, 7))
            }
        };
        let slot_elems = model.meta.n_layers * 2 * model.meta.block_layer_elements();
        let cpu_pool = vec![0f32; cfg.cpu_slots * slot_elems];
        let pool = (cfg.copy_workers > 0).then(|| CopyPool::new(cfg.copy_workers));
        RealEngine {
            model,
            cpu_space: CpuSwapSpace::new(cfg.cpu_slots),
            cpu_pool,
            pool,
            cfg,
            alloc,
            slots: Vec::new(),
            preemptions: 0,
            swapped_blocks: 0,
            decode_iters: 0,
        }
    }

    pub fn submit(&mut self, spec: RealRequestSpec) -> RequestId {
        let id = self.slots.len() as RequestId;
        self.slots.push(Slot {
            id,
            spec,
            state: St::Queued,
            context: 0,
            prefilled: 0,
            generated: Vec::new(),
            started: Instant::now(),
            first_token: None,
            token_times: Vec::new(),
        });
        id
    }

    fn slot_elems(&self) -> usize {
        self.model.meta.n_layers * 2 * self.model.meta.block_layer_elements()
    }

    /// Build the copy tasks for one (gpu block, cpu slot) pair.
    fn block_copy_tasks(&mut self, gpu_block: usize, cpu_slot: usize, to_cpu: bool)
        -> Vec<CopyTask>
    {
        let bl = self.model.meta.block_layer_elements();
        let layers = self.model.meta.n_layers;
        let slot_base = cpu_slot * self.slot_elems();
        let mut tasks = Vec::with_capacity(layers * 2);
        for l in 0..layers {
            let goff = self.model.kv.offset(l, gpu_block);
            let coff_k = slot_base + l * 2 * bl;
            let coff_v = coff_k + bl;
            let (ksrc, kdst, vsrc, vdst): (*const f32, *mut f32, *const f32, *mut f32) =
                if to_cpu {
                    (
                        self.model.kv.k[goff..].as_ptr(),
                        self.cpu_pool[coff_k..].as_mut_ptr(),
                        self.model.kv.v[goff..].as_ptr(),
                        self.cpu_pool[coff_v..].as_mut_ptr(),
                    )
                } else {
                    (
                        self.cpu_pool[coff_k..].as_ptr(),
                        self.model.kv.k[goff..].as_mut_ptr(),
                        self.cpu_pool[coff_v..].as_ptr(),
                        self.model.kv.v[goff..].as_mut_ptr(),
                    )
                };
            tasks.push(CopyTask { src: ksrc, dst: kdst, len: bl });
            tasks.push(CopyTask { src: vsrc, dst: vdst, len: bl });
        }
        tasks
    }

    fn run_copies(&self, tasks: Vec<CopyTask>) {
        match &self.pool {
            Some(p) => p.submit(tasks).wait(),
            None => CopyPool::run_inline(tasks),
        }
    }

    /// Preempt the lowest-priority running slot: physically move its KV
    /// to the CPU pool and free the GPU blocks.
    fn preempt_one(&mut self, exclude: Option<usize>) -> bool {
        let victim = self
            .slots
            .iter()
            .enumerate()
            .filter(|(i, s)| s.state == St::Running && Some(*i) != exclude)
            .min_by_key(|(_, s)| (s.spec.priority, std::cmp::Reverse(s.context)))
            .map(|(i, _)| i);
        let Some(vi) = victim else { return false };
        let id = self.slots[vi].id;
        let table = self.alloc.ar().table(id).to_vec();
        let n = table.len();
        let logicals: Vec<u32> = (0..n as u32).collect();
        let Some(copies) =
            self.cpu_space
                .add_copies(id, &logicals, self.slots[vi].spec.priority)
        else {
            return false; // CPU swap space full — caller handles
        };
        let mut tasks = Vec::new();
        for e in &copies {
            tasks.extend(self.block_copy_tasks(
                table[e.logical as usize] as usize,
                e.slot as usize,
                true,
            ));
        }
        self.run_copies(tasks);
        self.alloc.a().release(id);
        self.cpu_space.set_required(id, true);
        self.slots[vi].state = St::SwappedOut;
        self.preemptions += 1;
        self.swapped_blocks += n as u64;
        true
    }

    /// Swap a request back in (physical CPU→GPU copies).
    fn swap_in(&mut self, si: usize) -> bool {
        let id = self.slots[si].id;
        let n = self.slots[si].context.div_ceil(self.model.meta.block_size);
        let Some(blocks) = self.alloc.a().allocate(id, n) else {
            return false;
        };
        let entries: Vec<(u32, u32)> = self
            .cpu_space
            .copies_of(id)
            .map(|c| c.entries.iter().map(|e| (e.logical, e.slot)).collect())
            .unwrap_or_default();
        let mut tasks = Vec::new();
        for (logical, slot) in entries {
            tasks.extend(self.block_copy_tasks(
                blocks[logical as usize] as usize,
                slot as usize,
                false,
            ));
        }
        self.run_copies(tasks);
        self.cpu_space.drop_request(id);
        self.slots[si].state = St::Running;
        self.swapped_blocks += n as u64;
        true
    }

    fn ensure_blocks(&mut self, si: usize, tokens_after: usize) -> bool {
        let id = self.slots[si].id;
        let have = self.alloc.ar().table(id).len();
        let need = tokens_after
            .div_ceil(self.model.meta.block_size)
            .saturating_sub(have);
        if need == 0 {
            return true;
        }
        loop {
            if self.alloc.a().allocate(id, need).is_some() {
                return true;
            }
            if !self.preempt_one(Some(si)) {
                return false;
            }
        }
    }

    fn block_table_i32(&self, id: RequestId) -> Vec<i32> {
        self.alloc.ar().table(id).iter().map(|&b| b as i32).collect()
    }

    /// Serve everything to completion; returns wall-clock metrics.
    pub fn run(mut self) -> Result<RealOutcome, RuntimeError> {
        let t0 = Instant::now();
        loop {
            // Admission by priority: top max_batch among non-done.
            let mut active: Vec<usize> = (0..self.slots.len())
                .filter(|&i| self.slots[i].state != St::Done)
                .collect();
            if active.is_empty() {
                break;
            }
            active.sort_by_key(|&i| {
                (
                    std::cmp::Reverse(self.slots[i].spec.priority),
                    self.slots[i].id,
                )
            });
            let admitted: Vec<usize> =
                active.iter().copied().take(self.cfg.max_batch).collect();

            // Demote running requests that fell out of the admitted set.
            let over: Vec<usize> = (0..self.slots.len())
                .filter(|&i| {
                    self.slots[i].state == St::Running && !admitted.contains(&i)
                })
                .collect();
            for _ in over {
                self.preempt_one(None);
            }

            // Promote: swap in / start prefill.
            for &i in &admitted {
                match self.slots[i].state {
                    St::SwappedOut => {
                        if !self.swap_in(i) && !self.preempt_one(Some(i)) {
                            // Cannot make room now; retry next round.
                        }
                    }
                    St::Queued => {
                        self.slots[i].state = St::Running;
                        self.slots[i].started = Instant::now();
                    }
                    _ => {}
                }
            }

            // Prefill phase: one chunk for the highest-priority request
            // with prompt remaining (vLLM-style prefill priority).
            let prefill_target = admitted.iter().copied().find(|&i| {
                self.slots[i].state == St::Running
                    && self.slots[i].prefilled < self.slots[i].spec.prompt.len()
            });
            if let Some(i) = prefill_target {
                let chunk_sz = self.model.meta.prefill_chunk;
                let (start, end, prompt_len) = {
                    let s = &self.slots[i];
                    let start = s.prefilled;
                    (
                        start,
                        (start + chunk_sz).min(s.spec.prompt.len()),
                        s.spec.prompt.len(),
                    )
                };
                // The completing chunk also writes the first output token's
                // KV on the next decode — reserve its block now.
                let after = if end == prompt_len { end + 1 } else { end };
                if !self.ensure_blocks(i, after) {
                    continue; // couldn't fit; retry
                }
                let chunk: Vec<i32> = self.slots[i].spec.prompt[start..end].to_vec();
                let bt = self.block_table_i32(self.slots[i].id);
                let next =
                    self.model
                        .prefill(&chunk, start as i32, chunk.len() as i32, &bt)?;
                let s = &mut self.slots[i];
                s.prefilled = end;
                s.context = end;
                if end == prompt_len {
                    // First token of the response.
                    s.context += 1;
                    s.generated.push(next);
                    let dt = s.started.elapsed().as_secs_f64();
                    s.first_token = Some(dt);
                    s.token_times.push(dt);
                    if s.generated.len() >= s.spec.max_new_tokens {
                        s.state = St::Done;
                        self.alloc.a().release(s.id);
                    }
                }
                continue;
            }

            // Decode phase: batch every running, fully prefilled request.
            let batch: Vec<usize> = admitted
                .iter()
                .copied()
                .filter(|&i| {
                    self.slots[i].state == St::Running
                        && self.slots[i].prefilled >= self.slots[i].spec.prompt.len()
                        && !self.slots[i].generated.is_empty()
                })
                .take(self.model.max_batch())
                .collect();
            if batch.is_empty() {
                // Nothing runnable (e.g., everything queued couldn't fit).
                if !admitted.iter().any(|&i| self.slots[i].state == St::Running) {
                    break;
                }
                continue;
            }
            // Grow each by one token slot.
            let mut ok_batch = Vec::new();
            for &i in &batch {
                let after = self.slots[i].context + 1;
                if self.ensure_blocks(i, after) {
                    ok_batch.push(i);
                }
            }
            if ok_batch.is_empty() {
                continue;
            }
            let toks: Vec<i32> = ok_batch
                .iter()
                .map(|&i| *self.slots[i].generated.last().unwrap())
                .collect();
            let poss: Vec<i32> = ok_batch
                .iter()
                .map(|&i| (self.slots[i].context - 1) as i32)
                .collect();
            let bts: Vec<Vec<i32>> = ok_batch
                .iter()
                .map(|&i| self.block_table_i32(self.slots[i].id))
                .collect();
            let cls: Vec<i32> = ok_batch
                .iter()
                .map(|&i| self.slots[i].context as i32)
                .collect();
            let next = self.model.decode(&toks, &poss, &bts, &cls)?;
            self.decode_iters += 1;
            for (bi, &i) in ok_batch.iter().enumerate() {
                let s = &mut self.slots[i];
                s.context += 1;
                s.generated.push(next[bi]);
                s.token_times.push(s.started.elapsed().as_secs_f64());
                if s.generated.len() >= s.spec.max_new_tokens {
                    s.state = St::Done;
                    self.alloc.a().release(s.id);
                    self.cpu_space.drop_request(s.id);
                }
            }
        }

        let wall_s = t0.elapsed().as_secs_f64();
        let mut ttft = Vec::new();
        let mut tbt = Vec::new();
        let mut tokens = 0u64;
        let mut completions = Vec::new();
        for s in &self.slots {
            if let Some(f) = s.first_token {
                ttft.push(f);
            }
            for w in s.token_times.windows(2) {
                tbt.push(w[1] - w[0]);
            }
            tokens += s.generated.len() as u64;
            completions.push((s.id, s.generated.clone()));
        }
        Ok(RealOutcome {
            completions,
            ttft_s: Percentiles::from(ttft),
            tbt_s: Percentiles::from(tbt),
            tokens,
            wall_s,
            preemptions: self.preemptions,
            swapped_blocks: self.swapped_blocks,
            decode_iters: self.decode_iters,
            throughput_tok_s: tokens as f64 / wall_s,
        })
    }
}
