//! Real-execution serving backend: the FastSwitch policies driving the
//! AOT-compiled model through PJRT, with *physical* KV block movement.
//!
//! This is the end-to-end proof that the three layers compose into a
//! server: continuous batching + priority preemption + paged KV over
//! [`crate::runtime::PjrtModel`], with swaps performed as real memcpys
//! between the GPU-pool and CPU-pool buffers via
//! [`crate::swap::pool::CopyPool`] worker threads (the paper's C++
//! offload). Latencies here are wall-clock, not simulated.

pub mod real;

pub use real::{RealEngine, RealEngineConfig, RealOutcome, RealRequestSpec};
