//! `fastswitch` CLI — launcher for simulations, experiments, and the
//! real-model server.
//!
//! ```text
//! fastswitch exp <id|all> [--conversations N] [--seed S] [--out FILE]
//!     Regenerate a paper figure/table (fig1..fig13, table1), the
//!     fairness-policy showdown (`exp fairness`), the chunked-prefill
//!     showdown (`exp chunked`), the multi-replica placement showdown
//!     (`exp cluster`), the lookahead swap-in prefetch showdown
//!     (`exp prefetch`), the preemption-policy showdown
//!     (`exp preemption`), or the prefix-locality showdown
//!     (`exp locality`: shared-template fleets vs disjoint chat x
//!     round_robin/kv_affinity/prefix_aware with the prefix cache on).
//!
//! fastswitch exp ledger [--ledger-out FILE] [--conversations N] [--seed S]
//!     Measure the per-PR perf ledger matrix (hotpath ns/op, scheduler
//!     epoch cost, throughput at 1/3 replicas, deterministic-vs-threaded
//!     executor wall-clock, per-policy tail latency, scheduler-scale
//!     depth sweep) and write the schema-stable JSON (default
//!     BENCH_PR10.json).
//!
//! fastswitch exp gauntlet [--gauntlet-out FILE] [--conversations N] [--seed S]
//!     [--herd-spike F] [--think-floor F]
//!     Run the scenario gauntlet: every preemption policy x every
//!     adversarial scenario (agentic, mega_context, thundering_herd,
//!     diurnal) on the 3-replica cluster path, invariant-checked per
//!     cell, writing the schema-stable scorecard (default
//!     GAUNTLET_PR10.json). --herd-spike scales the thundering-herd
//!     within-wave arrival spike; --think-floor raises the agentic
//!     think-time floor (seconds).
//!
//! fastswitch simulate [--preset llama8b_a10|qwen32b_a100]
//!     [--policy vllm|vllm+dbg|vllm+dbg+reuse|fastswitch]
//!     [--pattern markov|random|roundrobin] [--freq F]
//!     [--fairness trace|vtc|slo] [--tenants N] [--heavy-share F]
//!     [--arrivals poisson|bursty] [--burst B]
//!     [--prefill-mode chunked|monolithic] [--chunk-tokens N]
//!     [--iter-budget N (0 = roofline auto)] [--sort-scheduler]
//!     [--prefetch-depth K (0 = off)] [--prefetch-io-budget F]
//!     [--preemption-policy swap_all|cost_aware|partial_tail]
//!     [--replicas N]
//!     [--placement round_robin|least_loaded|kv_affinity|prefix_aware]
//!     [--spill-threshold F] [--parallel] [--prefix-cache]
//!     [--scenario agentic|mega_context|thundering_herd|diurnal]
//!     [--conversations N] [--rate R] [--seed S] [--config FILE]
//!     [--trace] [--trace-out FILE] [--obs-profile]
//!     [--telemetry exact|reservoir]
//!     One simulation run; prints the SLO summary (a per-tenant
//!     breakdown when --tenants > 1, and cluster aggregates when
//!     --replicas > 1). --scenario swaps the ShareGPT workload for a
//!     seeded gauntlet scenario (4 tenants; the thundering-herd
//!     drain + rejoin fires only with --replicas >= 2). --parallel
//!     runs the cluster on the threaded actor executor (one OS thread
//!     per replica) instead of the seeded deterministic one.
//!
//! fastswitch serve [--artifacts DIR] [--requests N] [--policy ...]
//!     Serve batched requests on the real AOT-compiled model via PJRT.
//!
//! fastswitch workload [--conversations N] [--seed S]
//!     Print workload statistics (Fig. 4).
//! ```

use fastswitch::cluster::{ClusterConfig, ClusterOutcome, PlacementKind};
use fastswitch::config::{
    file::ConfigFile, EngineConfig, Granularity, PrefillMode, PreemptionPolicyKind, Preset,
};
use fastswitch::coordinator::priority::Pattern;
use fastswitch::exp;
use fastswitch::exp::runner::{
    run_cluster_scenario, run_cluster_with, run_sim_scenario, run_sim_with, Scale, WorkloadSpec,
};
use fastswitch::fairness::PolicyKind;
use fastswitch::obs::{chrome, Stage, TelemetryMode, TraceRecord};
use fastswitch::runtime::PjrtModel;
use fastswitch::server::{RealEngine, RealEngineConfig, RealRequestSpec};
use fastswitch::util::cli::Args;
use fastswitch::workload::{ScenarioParams, ScenarioSpec};
use fastswitch::util::rng::Rng;
use fastswitch::util::stats::Percentiles;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "exp" => cmd_exp(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "workload" => cmd_workload(&args),
        _ => {
            println!("{}", include_str!("main.rs").lines()
                .skip(3)
                .take_while(|l| l.starts_with("//!"))
                .map(|l| l.trim_start_matches("//! ").trim_start_matches("//!"))
                .collect::<Vec<_>>()
                .join("\n"));
        }
    }
}

fn scale_from(args: &Args) -> Scale {
    Scale {
        conversations: args.get_usize("conversations", 300),
        request_rate: args.get_f64("rate", 1.0),
        seed: args.get_u64("seed", 42),
        max_iters: args.get_u64("max-iters", 2_000_000),
        charge_sched_overhead: false,
    }
}

fn cmd_exp(args: &Args) {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let scale = scale_from(args);
    let mut reports = Vec::new();
    let freqs = args.get_f64_list("freqs", &[0.01, 0.02, 0.04, 0.08]);
    let run_one = |id: &str, reports: &mut Vec<exp::Report>| match id {
        "fig1" => reports.push(exp::fig1::run(&scale)),
        "fig2" => reports.push(exp::fig2::run(&scale)),
        "fig3" => reports.push(exp::fig3::run()),
        "fig4" => reports.push(exp::fig4::run(&scale)),
        "fig6" => reports.push(exp::fig6::run()),
        "fig8" => {
            for testbed in ["llama8b", "qwen32b"] {
                for pat in [Pattern::Markov, Pattern::Random] {
                    reports.push(exp::fig8::run_latency(testbed, pat, &scale));
                }
            }
            for testbed in ["llama8b", "qwen32b"] {
                reports.push(exp::fig8::run_throughput(
                    testbed,
                    Pattern::Markov,
                    &freqs,
                    &scale,
                ));
            }
        }
        "fig9" => reports.push(exp::fig9::run(&freqs, &scale)),
        "fig10" => reports.push(exp::fig10::run(&freqs, &scale)),
        "fig11" => reports.push(exp::fig11::run(
            &[64, 256, 1000, 2000, 3000],
            &[0.02, 0.04],
            &scale,
        )),
        "fig12" => reports.push(exp::fig12::run(&scale)),
        "fig13" => reports.push(exp::fig13::run(&[2, 8, 20, 40, 60, 80], &scale)),
        "table1" => reports.push(exp::table1::run(&scale)),
        "fairness" => reports.push(exp::fairness_showdown::run(&scale)),
        "chunked" => reports.push(exp::chunked_prefill::run(&scale)),
        "cluster" => reports.push(exp::cluster::run(&scale)),
        "prefetch" => reports.push(exp::prefetch::run(&scale)),
        "preemption" => reports.push(exp::preemption::run(&scale)),
        "locality" => reports.push(exp::locality::run(&scale)),
        "ledger" => reports.push(exp::ledger::run(
            &scale,
            args.get_or("ledger-out", "BENCH_PR10.json"),
        )),
        "gauntlet" => {
            let canon = ScenarioParams::default();
            let params = ScenarioParams {
                herd_spike: args.get_f64("herd-spike", canon.herd_spike),
                agentic_think_floor_s: args
                    .get_f64("think-floor", canon.agentic_think_floor_s),
            };
            reports.push(exp::gauntlet::run(
                &scale,
                &params,
                args.get_or("gauntlet-out", "GAUNTLET_PR10.json"),
            ));
        }
        other => eprintln!("unknown experiment {other:?}"),
    };
    if id == "all" {
        for e in [
            "fig1", "fig2", "fig3", "fig4", "fig6", "fig8", "fig9", "fig10", "fig11",
            "fig12", "fig13", "table1", "fairness", "chunked", "cluster", "prefetch",
            "preemption", "locality", "gauntlet", "ledger",
        ] {
            eprintln!("[exp] running {e} ...");
            run_one(e, &mut reports);
        }
    } else {
        run_one(id, &mut reports);
    }
    let mut md = String::new();
    for r in &reports {
        println!("{}", r.render());
        md.push_str(&r.markdown());
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, md).expect("write report");
        eprintln!("wrote {path}");
    }
}

fn cmd_simulate(args: &Args) {
    let mut pattern_name = args.get_or("pattern", "markov").to_string();
    let mut scale = scale_from(args);
    let mut spec = WorkloadSpec::default();
    let mut ccfg = ClusterConfig::default();
    let (mut cfg, preset) = if let Some(path) = args.get("config") {
        let f = ConfigFile::load(path).expect("config file");
        ccfg = f.cluster().expect("cluster config");
        if let Some(n) = f.get_usize("workload", "conversations") {
            scale.conversations = n;
        }
        if let Some(r) = f.get_f64("workload", "request_rate") {
            scale.request_rate = r;
        }
        if let Some(s) = f.get_u64("workload", "seed") {
            scale.seed = s;
        }
        if let Some(p) = f.get("workload", "pattern") {
            pattern_name = p.to_string();
        }
        if let Some(n) = f.get_usize("workload", "tenants") {
            spec.tenants = n;
        }
        if let Some(h) = f.get_f64("workload", "heavy_share") {
            spec.heavy_share = h;
        }
        if f.get("workload", "arrivals") == Some("bursty") {
            spec.burst = Some(f.get_f64("workload", "burst").unwrap_or(4.0));
        }
        (f.engine().expect("engine config"), f.preset().expect("preset"))
    } else {
        let cfg = match args.get_or("policy", "fastswitch") {
            "vllm" => EngineConfig::vllm_baseline(),
            "vllm+dbg" => EngineConfig::with_dbg(),
            "vllm+dbg+reuse" => EngineConfig::with_dbg_reuse(),
            _ => EngineConfig::fastswitch(),
        };
        let preset = Preset::by_name(args.get_or("preset", "llama8b_a10"))
            .expect("unknown preset");
        (cfg, preset)
    };
    if let Some(f) = args.get("freq") {
        cfg.scheduler.priority_update_freq = f.parse().expect("freq");
    }
    if let Some(p) = args.get("fairness") {
        cfg.fairness.policy = PolicyKind::by_name(p).expect("unknown fairness policy");
    }
    if let Some(m) = args.get("prefill-mode") {
        cfg.scheduler.prefill_mode =
            PrefillMode::by_name(m).expect("unknown prefill mode (chunked|monolithic)");
    }
    if let Some(c) = args.get("chunk-tokens") {
        cfg.scheduler.prefill_chunk = c.parse().expect("chunk-tokens");
    }
    if let Some(b) = args.get("iter-budget") {
        cfg.scheduler.max_tokens_per_iter = b.parse().expect("iter-budget");
    }
    if args.flag("sort-scheduler") {
        // Escape hatch to the sort-based reference scheduler (the
        // incremental index is the default; both are byte-identical).
        cfg.scheduler.incremental = false;
    }
    if let Some(d) = args.get("prefetch-depth") {
        cfg.prefetch.depth = d.parse().expect("prefetch-depth");
    }
    if let Some(b) = args.get("prefetch-io-budget") {
        cfg.prefetch.io_budget = b.parse::<f64>().expect("prefetch-io-budget").clamp(0.0, 1.0);
    }
    if let Some(p) = args.get("preemption-policy") {
        cfg.preemption.policy = PreemptionPolicyKind::by_name(p)
            .expect("unknown preemption policy (swap_all|cost_aware|partial_tail)");
    }
    if let Some(n) = args.get("tenants") {
        spec.tenants = n.parse().expect("tenants");
    }
    if let Some(h) = args.get("heavy-share") {
        spec.heavy_share = h.parse().expect("heavy-share");
    }
    if let Some(a) = args.get("arrivals") {
        // Explicit CLI choice overrides the config file in both
        // directions (bursty → poisson too).
        spec.burst = (a == "bursty").then(|| args.get_f64("burst", 4.0));
    }
    if let Some(n) = args.get("replicas") {
        ccfg.replicas = n.parse::<usize>().expect("replicas").max(1);
    }
    if let Some(p) = args.get("placement") {
        ccfg.placement = PlacementKind::by_name(p)
            .expect("unknown placement (round_robin|least_loaded|kv_affinity|prefix_aware)");
    }
    if let Some(s) = args.get("spill-threshold") {
        let spill_threshold = s.parse().expect("spill-threshold");
        match ccfg.placement {
            PlacementKind::KvAffinity { .. } => {
                ccfg.placement = PlacementKind::KvAffinity { spill_threshold };
            }
            PlacementKind::PrefixAware { .. } => {
                ccfg.placement = PlacementKind::PrefixAware { spill_threshold };
            }
            _ => {}
        }
    }
    if args.flag("parallel") {
        ccfg.parallel = true;
    }
    if args.flag("prefix-cache") {
        cfg.prefix.enabled = true;
    }
    if args.flag("trace") {
        cfg.obs.trace = true;
    }
    if args.flag("obs-profile") {
        cfg.obs.profile = true;
    }
    if let Some(m) = args.get("telemetry") {
        cfg.obs.telemetry =
            TelemetryMode::by_name(m).expect("unknown telemetry mode (exact|reservoir)");
    }
    let trace_on = cfg.obs.trace;
    let trace_out = args.get_or("trace-out", "trace.json").to_string();
    let pattern = Pattern::by_name(&pattern_name).expect("unknown pattern");
    let scenario = args.get("scenario").map(|name| {
        ScenarioSpec::by_name(name, cfg.scheduler.max_seq_len)
            .expect("unknown scenario (agentic|mega_context|thundering_herd|diurnal)")
    });
    if let Some(sc) = &scenario {
        eprintln!(
            "[simulate] scenario {} ({} tenants{})",
            sc.label(),
            fastswitch::workload::scenario::SCENARIO_TENANTS,
            if matches!(sc, ScenarioSpec::ThunderingHerd) {
                ", mid-run replica drain"
            } else {
                ""
            }
        );
    }

    if ccfg.replicas > 1 {
        eprintln!(
            "[simulate] cluster: {} on {}, {} replicas, {} placement, {} executor, \
             {} conversations, {} tenant(s)",
            cfg.label,
            preset.model.name,
            ccfg.replicas,
            ccfg.placement.label(),
            if ccfg.parallel { "threaded" } else { "deterministic" },
            scale.conversations,
            spec.tenants
        );
        let multi_tenant = scenario.is_some() || spec.tenants > 1;
        let out = if let Some(sc) = &scenario {
            let wl = sc.build(scale.conversations, scale.request_rate, scale.seed);
            run_cluster_scenario(cfg, preset, pattern, ccfg, &scale, &wl)
        } else {
            run_cluster_with(cfg, preset, pattern, ccfg, &scale, &spec)
        };
        print_cluster_summary(&out, multi_tenant);
        if trace_on {
            // One lane per replica, plus the router's own stream (its
            // events sit on the arrival clock, not any replica clock).
            let mut lanes: Vec<(u32, &[TraceRecord])> = out
                .replicas
                .iter()
                .enumerate()
                .map(|(i, o)| (i as u32, o.trace.as_slice()))
                .collect();
            lanes.push((out.replicas.len() as u32, out.router_trace.as_slice()));
            write_trace(&trace_out, &lanes);
        }
        return;
    }

    eprintln!(
        "[simulate] {} on {}, pattern {:?}, freq {}, priorities {}, prefill {} \
         (chunk {}, budget {}), {} conversations, {} tenant(s)",
        cfg.label,
        preset.model.name,
        pattern,
        cfg.scheduler.priority_update_freq,
        cfg.fairness.policy.label(),
        cfg.scheduler.prefill_mode.label(),
        cfg.scheduler.prefill_chunk,
        if cfg.scheduler.max_tokens_per_iter == 0 {
            "auto".to_string()
        } else {
            cfg.scheduler.max_tokens_per_iter.to_string()
        },
        scale.conversations,
        spec.tenants
    );
    let multi_tenant = scenario.is_some() || spec.tenants > 1;
    let prefetch_depth = cfg.prefetch.depth;
    let preemption_policy = cfg.preemption.policy;
    let profile_on = cfg.obs.profile;
    let out = if let Some(sc) = &scenario {
        let wl = sc.build(scale.conversations, scale.request_rate, scale.seed);
        run_sim_scenario(cfg, preset, pattern, &scale, &wl)
    } else {
        run_sim_with(cfg, preset, pattern, &scale, &spec)
    };
    let ttft = out.recorder.ttft();
    let tbt = out.recorder.tbt();
    let (inf, swap, sched) = out.recorder.stall_breakdown();
    println!("== simulation summary ({}) ==", out.label);
    println!("conversations finished : {}", out.recorder.finished_conversations);
    println!("turns finished         : {}", out.recorder.finished_turns);
    println!("tokens generated       : {}", out.recorder.total_tokens);
    println!("span                   : {:.1}s", out.span as f64 / 1e9);
    println!("throughput             : {:.1} tok/s", out.throughput());
    println!(
        "TTFT   P50/P95/P99/P99.9 : {:.3}/{:.3}/{:.3}/{:.3} s",
        ttft.p(50.0), ttft.p(95.0), ttft.p(99.0), ttft.p(99.9)
    );
    println!(
        "TBT    P50/P95/P99/P99.9 : {:.3}/{:.3}/{:.3}/{:.3} s",
        tbt.p(50.0), tbt.p(95.0), tbt.p(99.0), tbt.p(99.9)
    );
    println!(
        "time: inference {:.1}s, swap stall {:.2}s, scheduler {:.3}s",
        inf as f64 / 1e9, swap as f64 / 1e9, sched as f64 / 1e9
    );
    println!(
        "preemptions {} (recompute {}), swap ops {}/{} in/out, avg granularity {:.1} blocks/call",
        out.recorder.preemptions,
        out.recorder.recompute_preemptions,
        out.swap_stats.swap_in_ops,
        out.swap_stats.swap_out_ops,
        out.swap_stats.avg_granularity()
    );
    if prefetch_depth > 0 {
        println!(
            "prefetch (depth {}): {} issued, hit rate {:.2} ({} hits / {} partial), \
             {:.1} ms stall recovered, {:.1} MB wasted, {} canceled",
            prefetch_depth,
            out.swap_stats.prefetch_ops,
            out.swap_stats.prefetch_hit_rate(),
            out.swap_stats.prefetch_hits,
            out.swap_stats.prefetch_partial_hits,
            out.swap_stats.prefetch_recovered_ns as f64 / 1e6,
            out.swap_stats.prefetch_wasted_bytes as f64 / 1e6,
            out.swap_stats.prefetch_canceled
        );
    }
    if profile_on {
        let p = &out.recorder.profiler;
        println!(
            "epoch cost (wall)      : {:.0} ns mean over {} epochs \
             (admission {:.0} / preemption {:.0} / prefetch {:.0} / execution {:.0})",
            p.total_mean_ns(),
            p.epochs(),
            p.mean_ns(Stage::Admission),
            p.mean_ns(Stage::Preemption),
            p.mean_ns(Stage::Prefetch),
            p.mean_ns(Stage::Execution)
        );
    }
    if preemption_policy != PreemptionPolicyKind::SwapAll {
        println!(
            "preemption ({}): {} partial evictions ({} blocks retained), \
             swap/recompute decisions {}/{}",
            preemption_policy.label(),
            out.recorder.partial_evictions,
            out.recorder.blocks_retained,
            out.recorder.evict_swap_decisions,
            out.recorder.evict_recompute_decisions
        );
    }
    if multi_tenant {
        println!("== per-tenant breakdown ==");
        print_tenant_rows(
            &out.recorder.ttft_by_tenant(),
            &out.recorder.tbt_by_tenant(),
            &out.recorder.token_shares(),
        );
        println!(
            "max/min token share : {:.2}   Jain index : {:.3}",
            out.recorder.max_min_share_ratio(),
            out.recorder.jain_fairness()
        );
    }
    if trace_on {
        write_trace(&trace_out, &[(0, out.trace.as_slice())]);
    }
}

/// Write trace lanes as Chrome trace-event JSON.
fn write_trace(path: &str, lanes: &[(u32, &[TraceRecord])]) {
    let events: usize = lanes.iter().map(|(_, r)| r.len()).sum();
    std::fs::write(path, chrome::export(lanes)).expect("write trace");
    eprintln!(
        "[simulate] wrote Chrome trace {path} ({events} events; open in \
         chrome://tracing or ui.perfetto.dev)"
    );
}

/// Shared per-tenant breakdown rows (single-engine and cluster
/// summaries must not drift apart).
fn print_tenant_rows(
    ttft: &[(u32, Percentiles)],
    tbt: &[(u32, Percentiles)],
    shares: &[(u32, f64)],
) {
    for &(tenant, share) in shares {
        let tt = ttft.iter().find(|&&(t, _)| t == tenant).map(|(_, p)| p);
        let tb = tbt.iter().find(|&&(t, _)| t == tenant).map(|(_, p)| p);
        println!(
            "tenant {tenant:>3}{} : share {:.3}  TTFT P50/P99 {:.3}/{:.3} s  TBT P99 {:.3} s",
            if tenant == 0 { " (heavy)" } else { "        " },
            share,
            tt.map(|p| p.p(50.0)).unwrap_or(f64::NAN),
            tt.map(|p| p.p(99.0)).unwrap_or(f64::NAN),
            tb.map(|p| p.p(99.0)).unwrap_or(f64::NAN),
        );
    }
}

fn print_cluster_summary(out: &ClusterOutcome, multi_tenant: bool) {
    let ttft = out.ttft();
    let tbt = out.tbt();
    println!("== cluster summary ({}) ==", out.label);
    println!("replicas               : {}", out.replicas.len());
    println!("conversations finished : {}", out.finished_conversations());
    println!("tokens generated       : {}", out.total_tokens());
    println!("span (makespan)        : {:.1}s", out.span() as f64 / 1e9);
    println!("throughput             : {:.1} tok/s", out.throughput());
    println!(
        "TTFT   P50/P95/P99/P99.9 : {:.3}/{:.3}/{:.3}/{:.3} s",
        ttft.p(50.0), ttft.p(95.0), ttft.p(99.0), ttft.p(99.9)
    );
    println!(
        "TBT    P50/P95/P99/P99.9 : {:.3}/{:.3}/{:.3}/{:.3} s",
        tbt.p(50.0), tbt.p(95.0), tbt.p(99.0), tbt.p(99.9)
    );
    println!(
        "placements {} (turn decisions {}), affinity hit rate {:.3}, migrations {} \
         ({} context blocks re-prefilled)",
        out.placements,
        out.affinity_decisions,
        out.affinity_hit_rate(),
        out.migrations,
        out.retransferred_blocks_on_migration
    );
    if let Some((replica, at)) = out.drain {
        match out.rejoin {
            Some((_, back)) => println!(
                "drain/rejoin           : replica {replica} drained at {:.1}s, \
                 rejoined at {:.1}s",
                at as f64 / 1e9,
                back as f64 / 1e9
            ),
            None => println!(
                "drain                  : replica {replica} drained at {:.1}s",
                at as f64 / 1e9
            ),
        }
    }
    println!(
        "swap volume            : {} blocks / {:.2} GB across replicas",
        out.swap_blocks_total(),
        out.swap_bytes_total() as f64 / 1e9
    );
    if out.prefix_hits_total() > 0 {
        println!(
            "prefix cache           : {} hits, {} prompt tokens never prefilled",
            out.prefix_hits_total(),
            out.prefix_saved_tokens_total()
        );
    }
    println!("== per-replica breakdown ==");
    for (i, o) in out.replicas.iter().enumerate() {
        println!(
            "replica {i} : finished {:>4}  tokens {:>8}  preemptions {:>5}  \
             swap blocks {:>8}  span {:.1}s",
            o.recorder.finished_conversations,
            o.recorder.total_tokens,
            o.recorder.preemptions,
            o.swap_stats.total_blocks,
            o.span as f64 / 1e9
        );
    }
    if multi_tenant {
        println!("== per-tenant breakdown (aggregated over replicas) ==");
        print_tenant_rows(
            &out.ttft_by_tenant(),
            &out.tbt_by_tenant(),
            &out.token_shares(),
        );
        println!(
            "cluster Jain index     : {:.3}",
            out.jain_fairness()
        );
    }
}

fn cmd_serve(args: &Args) {
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let model = PjrtModel::load(&dir).expect("load artifacts (run `make artifacts`)");
    let vocab = model.meta.vocab;
    println!(
        "[serve] model loaded on {}: {} layers, {} blocks x {} tokens",
        model.platform(),
        model.meta.n_layers,
        model.meta.num_blocks,
        model.meta.block_size
    );
    let granularity = match args.get_or("policy", "fastswitch") {
        "vllm" => Granularity::FixedBlock,
        _ => Granularity::BlockGroup { init_group_blocks: 8 },
    };
    let mut eng = RealEngine::new(
        model,
        RealEngineConfig {
            granularity,
            copy_workers: args.get_usize("copy-workers", 4),
            cpu_slots: args.get_usize("cpu-slots", 512),
            max_batch: args.get_usize("max-batch", 8),
        },
    );
    let n = args.get_usize("requests", 8);
    let mut rng = Rng::new(args.get_u64("seed", 42));
    for i in 0..n {
        let plen = rng.usize(16, 96);
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.usize(1, vocab) as i32).collect();
        eng.submit(RealRequestSpec {
            prompt,
            max_new_tokens: rng.usize(8, 32),
            priority: (i % 4) as i64,
        });
    }
    let out = eng.run().expect("serve");
    println!("== real serving summary ==");
    println!("requests        : {}", out.completions.len());
    println!("tokens          : {}", out.tokens);
    println!("wall time       : {:.2}s", out.wall_s);
    println!("throughput      : {:.1} tok/s", out.throughput_tok_s);
    println!(
        "TTFT P50/P99    : {:.3}/{:.3} s",
        out.ttft_s.p(50.0),
        out.ttft_s.p(99.0)
    );
    println!(
        "TBT  P50/P99    : {:.4}/{:.4} s",
        out.tbt_s.p(50.0),
        out.tbt_s.p(99.0)
    );
    println!(
        "preemptions     : {} ({} blocks swapped)",
        out.preemptions, out.swapped_blocks
    );
}

fn cmd_workload(args: &Args) {
    let scale = scale_from(args);
    let rep = exp::fig4::run(&scale);
    println!("{}", rep.render());
}
