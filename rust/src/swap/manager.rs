//! Multithreading Swap Manager (paper §3.2, Algorithm 1).
//!
//! Owns the dispatch lanes (GIL vs thread pool) and the PCIe link, tracks
//! in-flight operations with an event pool, and implements:
//!
//! - **Adaptive swapping strategy** — per-iteration choice between
//!   asynchronous swap-in (overlapped with inference) and synchronous
//!   swap-in (stall once), driven by a profiler window of recent swap
//!   metrics. The paper observes async is *not* always better: with many
//!   short requests, holding GPU blocks for several iterations while a
//!   swap-in completes costs more tokens than a short stall.
//! - **Conflict detection** — newly allocated GPU blocks may still be the
//!   source of an in-flight swap-out; writing them would corrupt the copy,
//!   so the manager synchronizes on exactly the conflicting operations.
//! - **Ordered dispatch** — the dispatch model inserts fine-grained
//!   synchronizations every N calls so inference-stream copies can
//!   preempt a long swap burst (modeled in [`crate::sim::dispatch`]).

use std::collections::VecDeque;

use super::op::{InflightOp, SwapOp};
use crate::config::{DispatchMode, SwapCostConfig, SwapMode};
use crate::memory::{BlockId, RequestId};
use crate::sim::clock::Ns;
use crate::sim::dispatch::DispatchLanes;
use crate::sim::link::PcieLink;

/// CUDA-event pool analogue: recycled completion-tracking handles.
#[derive(Clone, Debug, Default)]
pub struct EventPool {
    free: Vec<u32>,
    next: u32,
    pub high_water: u32,
}

impl EventPool {
    pub fn acquire(&mut self) -> u32 {
        if let Some(e) = self.free.pop() {
            e
        } else {
            let e = self.next;
            self.next += 1;
            self.high_water = self.high_water.max(self.next);
            e
        }
    }

    pub fn release(&mut self, e: u32) {
        self.free.push(e);
    }
}

/// Profiler sample over one recent swap (the paper's `r_info` queue).
#[derive(Clone, Copy, Debug)]
pub struct RecentSwap {
    pub bytes: u64,
    pub calls: u32,
    pub duration: Ns,
}

/// Cumulative statistics (feeds Figs. 9/10/12 and Table 1).
#[derive(Clone, Debug, Default)]
pub struct SwapStats {
    pub swap_out_ops: u64,
    pub swap_in_ops: u64,
    pub async_swap_ins: u64,
    pub sync_swap_ins: u64,
    pub total_calls: u64,
    pub total_bytes: u64,
    pub total_blocks: u64,
    pub conflicts: u64,
    pub conflict_wait_ns: Ns,
    /// Main-thread time consumed by dispatch (the GIL tax). Disjoint
    /// from `sync_stall_ns`: summing the two reconstructs the total
    /// main-thread stall without double-counting (Figs. 1/10).
    pub main_thread_dispatch_ns: Ns,
    /// Execution-wait stall from synchronous swap-ins / swap-outs,
    /// *excluding* the dispatch share already counted in
    /// `main_thread_dispatch_ns`.
    pub sync_stall_ns: Ns,
    /// Sum over ops of avg blocks/call (divide by op count for the
    /// Fig. 11 granularity metric).
    pub granularity_sum: f64,
}

impl SwapStats {
    pub fn avg_granularity(&self) -> f64 {
        let ops = (self.swap_out_ops + self.swap_in_ops) as f64;
        if ops == 0.0 {
            0.0
        } else {
            self.granularity_sum / ops
        }
    }
}

/// How a submitted swap-in is being executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapInDecision {
    /// Stall the iteration until `done`.
    Sync { done: Ns },
    /// Overlapped; the request returns via `poll_completed`.
    Async,
}

#[derive(Clone, Debug)]
pub struct SwapManager {
    pub dispatch: DispatchLanes,
    pub link: PcieLink,
    mode: SwapMode,
    dispatch_mode: DispatchMode,
    ongoing_in: Vec<(InflightOp, u32)>,
    ongoing_out: Vec<(InflightOp, u32)>,
    events: EventPool,
    r_info: VecDeque<RecentSwap>,
    r_info_cap: usize,
    pub stats: SwapStats,
    adaptive_overlap_threshold: f64,
}

impl SwapManager {
    pub fn new(
        mode: SwapMode,
        dispatch_mode: DispatchMode,
        cost: &SwapCostConfig,
        link: PcieLink,
    ) -> Self {
        SwapManager {
            dispatch: DispatchLanes::new(dispatch_mode, cost),
            link,
            mode,
            dispatch_mode,
            ongoing_in: Vec::new(),
            ongoing_out: Vec::new(),
            events: EventPool::default(),
            r_info: VecDeque::new(),
            r_info_cap: 32,
            stats: SwapStats::default(),
            adaptive_overlap_threshold: cost.adaptive_overlap_threshold,
        }
    }

    pub fn mode(&self) -> SwapMode {
        self.mode
    }

    /// Step 1 of Algorithm 1: harvest asynchronous swap-ins whose event
    /// has fired; the engine returns them to the running queue.
    pub fn poll_completed(&mut self, now: Ns) -> Vec<RequestId> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.ongoing_in.len() {
            if self.ongoing_in[i].0.exec_done <= now {
                let (inflight, ev) = self.ongoing_in.swap_remove(i);
                self.events.release(ev);
                done.push(inflight.op.req);
            } else {
                i += 1;
            }
        }
        done
    }

    /// Drop drained swap-outs (their CPU copies are now complete) and
    /// return the finished request ids (the engine commits reuse state).
    pub fn reap_swap_outs(&mut self, now: Ns) -> Vec<RequestId> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.ongoing_out.len() {
            if self.ongoing_out[i].0.exec_done <= now {
                let (inflight, ev) = self.ongoing_out.swap_remove(i);
                self.events.release(ev);
                done.push(inflight.op.req);
            } else {
                i += 1;
            }
        }
        done
    }

    // Perf note (§Perf L3): takes the op by value — the segment vector
    // (up to blocks×layers entries at vLLM granularity) is moved into the
    // inflight record instead of cloned.
    fn run_op(&mut self, op: SwapOp, now: Ns) -> InflightOp {
        let dir = op.dir;
        let mut dispatch_done = now;
        let mut exec_done = now;
        for seg in &op.segments {
            let d = self.dispatch.dispatch_one(now);
            dispatch_done = dispatch_done.max(d);
            let t = self.link.enqueue(dir, seg.bytes, d);
            exec_done = exec_done.max(t.end);
        }
        self.stats.total_calls += op.n_calls() as u64;
        self.stats.total_bytes += op.total_bytes();
        self.stats.total_blocks += op.blocks as u64;
        self.stats.granularity_sum += op.avg_granularity();
        self.push_r_info(RecentSwap {
            bytes: op.total_bytes(),
            calls: op.n_calls() as u32,
            duration: exec_done.saturating_sub(now),
        });
        InflightOp {
            op,
            dispatch_done,
            exec_done,
        }
    }

    fn push_r_info(&mut self, r: RecentSwap) {
        if self.r_info.len() == self.r_info_cap {
            self.r_info.pop_front();
        }
        self.r_info.push_back(r);
    }

    /// Step 3 of Algorithm 1: swap-out. Returns the main-thread stall
    /// this costs the current iteration:
    /// - GIL dispatch serializes on the main thread (dispatch time);
    /// - `SwapMode::Sync` additionally waits for execution (vLLM
    ///   semantics: the swap must finish before the iteration proceeds).
    pub fn submit_swap_out(&mut self, op: SwapOp, now: Ns) -> Ns {
        if op.segments.is_empty() {
            return 0;
        }
        let inflight = self.run_op(op, now);
        self.stats.swap_out_ops += 1;
        let main_thread = match self.dispatch_mode {
            DispatchMode::Gil => inflight.dispatch_done.saturating_sub(now),
            DispatchMode::ThreadPool { .. } => 0,
        };
        self.stats.main_thread_dispatch_ns += main_thread;
        let stall = match self.mode {
            SwapMode::Sync => inflight.exec_done.saturating_sub(now),
            _ => main_thread,
        };
        if matches!(self.mode, SwapMode::Sync) {
            // The dispatch share of the stall is already counted in
            // `main_thread_dispatch_ns`; record only the execution wait so
            // the Fig-1/Fig-10 breakdown buckets stay disjoint.
            self.stats.sync_stall_ns += stall.saturating_sub(main_thread);
            // Synchronous: nothing left in flight.
        } else {
            // Asynchronous: the only main-thread cost is the dispatch,
            // which `main_thread_dispatch_ns` above already recorded.
            let ev = self.events.acquire();
            self.ongoing_out.push((inflight, ev));
        }
        stall
    }

    /// Step 4 of Algorithm 1: swap-in with the adaptive strategy.
    /// `iter_ns_hint` — engine's estimate of the next iteration time;
    /// `batch` / `avg_ctx_tokens` — running-batch profile.
    pub fn submit_swap_in(
        &mut self,
        op: SwapOp,
        now: Ns,
        iter_ns_hint: Ns,
        batch: usize,
        avg_ctx_tokens: f64,
    ) -> SwapInDecision {
        if op.segments.is_empty() {
            return SwapInDecision::Sync { done: now };
        }
        let inflight = self.run_op(op, now);
        self.stats.swap_in_ops += 1;
        let main_thread = match self.dispatch_mode {
            DispatchMode::Gil => inflight.dispatch_done.saturating_sub(now),
            DispatchMode::ThreadPool { .. } => 0,
        };
        self.stats.main_thread_dispatch_ns += main_thread;

        let go_async = match self.mode {
            SwapMode::Sync => false,
            SwapMode::Async => true,
            SwapMode::Adaptive => {
                let dur = inflight.exec_done.saturating_sub(now);
                // Tiny swaps: stalling once is cheaper than holding blocks
                // idle for dur/iter iterations.
                let worth_overlapping =
                    dur as f64 > self.adaptive_overlap_threshold * iter_ns_hint as f64;
                // Many short requests: token throughput dominates — prefer
                // the short sync stall (paper §3.2).
                let many_short = batch >= 24 && avg_ctx_tokens < 512.0;
                worth_overlapping && !many_short
            }
        };
        if go_async {
            self.stats.async_swap_ins += 1;
            let ev = self.events.acquire();
            self.ongoing_in.push((inflight, ev));
            SwapInDecision::Async
        } else {
            self.stats.sync_swap_ins += 1;
            let stall = inflight.exec_done.saturating_sub(now);
            // Dispatch already landed in `main_thread_dispatch_ns`.
            self.stats.sync_stall_ns += stall.saturating_sub(main_thread);
            SwapInDecision::Sync {
                done: inflight.exec_done,
            }
        }
    }

    /// Step 3.1 of Algorithm 1: conflict detection. If any freshly
    /// allocated GPU block is still the source/target of an in-flight op,
    /// return the synchronization point (latest conflicting event).
    pub fn detect_conflict(&mut self, new_blocks: &[BlockId], now: Ns) -> Option<Ns> {
        if new_blocks.is_empty()
            || (self.ongoing_out.is_empty() && self.ongoing_in.is_empty())
        {
            return None;
        }
        // Per-iteration admission hot path: hash the new blocks once so
        // each in-flight segment costs O(1) instead of a linear scan of
        // `new_blocks` (O(inflight × blocks + new) vs
        // O(inflight × blocks × new)).
        let fresh: std::collections::HashSet<BlockId> =
            new_blocks.iter().copied().collect();
        let mut sync_until: Option<Ns> = None;
        for (inflight, _) in self.ongoing_out.iter().chain(self.ongoing_in.iter()) {
            if inflight.exec_done <= now {
                continue;
            }
            if inflight.op.gpu_blocks.iter().any(|b| fresh.contains(b)) {
                sync_until = Some(sync_until.map_or(inflight.exec_done, |s: Ns| {
                    s.max(inflight.exec_done)
                }));
            }
        }
        if let Some(s) = sync_until {
            self.stats.conflicts += 1;
            self.stats.conflict_wait_ns += s.saturating_sub(now);
        }
        sync_until
    }

    /// Earliest completion among all in-flight operations (both
    /// directions) — the engine's idle fast-forward target.
    pub fn next_event(&self) -> Option<Ns> {
        self.ongoing_in
            .iter()
            .chain(self.ongoing_out.iter())
            .map(|(i, _)| i.exec_done)
            .min()
    }

    /// Earliest completion among in-flight swap-outs.
    pub fn next_out_event(&self) -> Option<Ns> {
        self.ongoing_out.iter().map(|(i, _)| i.exec_done).min()
    }

    /// Record a memory-pressure conflict: an allocation had to wait
    /// `wait_ns` for an in-flight swap-out to release its source blocks
    /// (paper §3.2 KV-cache conflict resolution).
    pub fn record_conflict(&mut self, wait_ns: Ns) {
        self.stats.conflicts += 1;
        self.stats.conflict_wait_ns += wait_ns;
    }

    /// `SwapInStreamSynchronize()` — drain every ongoing swap-in.
    pub fn sync_all_in(&self, now: Ns) -> Ns {
        self.ongoing_in
            .iter()
            .map(|(i, _)| i.exec_done)
            .fold(now, Ns::max)
    }

    /// If `req` has a swap-out still executing, when it completes. Used
    /// by the engine to barrier a swap-in that would read the CPU copy
    /// before it is fully written.
    pub fn swap_out_inflight(&self, req: RequestId) -> Option<Ns> {
        self.ongoing_out
            .iter()
            .find(|(i, _)| i.op.req == req)
            .map(|(i, _)| i.exec_done)
    }

    pub fn ongoing_in_count(&self) -> usize {
        self.ongoing_in.len()
    }

    pub fn ongoing_out_count(&self) -> usize {
        self.ongoing_out.len()
    }

    pub fn event_high_water(&self) -> u32 {
        self.events.high_water
    }

    pub fn recent(&self) -> impl Iterator<Item = &RecentSwap> {
        self.r_info.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, Granularity, ModelSpec};
    use crate::sim::link::Direction;
    use crate::swap::engine::{BlockMove, SegmentBuilder};

    fn op(dir: Direction, nblocks: u32, coalesced: bool) -> SwapOp {
        let g = if coalesced {
            Granularity::BlockGroup { init_group_blocks: 60 }
        } else {
            Granularity::FixedBlock
        };
        let b = SegmentBuilder::new(ModelSpec::llama8b(), g);
        let moves: Vec<BlockMove> = (0..nblocks)
            .map(|i| BlockMove {
                logical: i,
                gpu: 10 + i,
                cpu: 100 + i,
            })
            .collect();
        b.build(1, dir, &moves)
    }

    fn mgr(mode: SwapMode, dm: DispatchMode) -> SwapManager {
        SwapManager::new(
            mode,
            dm,
            &SwapCostConfig::default(),
            PcieLink::new(GpuSpec::a10()),
        )
    }

    #[test]
    fn sync_swap_out_stalls_full_duration() {
        let mut m = mgr(SwapMode::Sync, DispatchMode::Gil);
        let stall = m.submit_swap_out(op(Direction::Out, 20, false), 0);
        assert!(stall > 0);
        assert_eq!(m.ongoing_out_count(), 0);
        assert_eq!(m.stats.swap_out_ops, 1);
    }

    #[test]
    fn async_swap_out_with_threadpool_is_free_for_main_thread() {
        let mut m = mgr(
            SwapMode::Adaptive,
            DispatchMode::ThreadPool { workers: 4 },
        );
        let stall = m.submit_swap_out(op(Direction::Out, 20, true), 0);
        assert_eq!(stall, 0);
        assert_eq!(m.ongoing_out_count(), 1);
        assert_eq!(m.stats.main_thread_dispatch_ns, 0);
    }

    #[test]
    fn coalesced_op_finishes_much_earlier() {
        let mut ma = mgr(SwapMode::Sync, DispatchMode::Gil);
        let mut mb = mgr(SwapMode::Sync, DispatchMode::Gil);
        let sa = ma.submit_swap_out(op(Direction::Out, 32, false), 0);
        let sb = mb.submit_swap_out(op(Direction::Out, 32, true), 0);
        assert!(
            (sb as f64) < sa as f64 / 4.0,
            "coalesced {sb} vs fixed {sa}"
        );
    }

    #[test]
    fn adaptive_small_swap_goes_sync() {
        let mut m = mgr(
            SwapMode::Adaptive,
            DispatchMode::ThreadPool { workers: 4 },
        );
        // 1-block swap vs a 30 ms iteration hint: not worth overlapping.
        let d = m.submit_swap_in(op(Direction::In, 1, true), 0, 30_000_000, 8, 2000.0);
        assert!(matches!(d, SwapInDecision::Sync { .. }));
        assert_eq!(m.stats.sync_swap_ins, 1);
    }

    #[test]
    fn adaptive_large_swap_goes_async() {
        let mut m = mgr(
            SwapMode::Adaptive,
            DispatchMode::ThreadPool { workers: 4 },
        );
        let d = m.submit_swap_in(op(Direction::In, 200, true), 0, 5_000_000, 8, 2000.0);
        assert_eq!(d, SwapInDecision::Async);
        assert_eq!(m.ongoing_in_count(), 1);
    }

    #[test]
    fn adaptive_many_short_requests_prefers_sync() {
        let mut m = mgr(
            SwapMode::Adaptive,
            DispatchMode::ThreadPool { workers: 4 },
        );
        let d = m.submit_swap_in(op(Direction::In, 200, true), 0, 5_000_000, 32, 100.0);
        assert!(matches!(d, SwapInDecision::Sync { .. }));
    }

    #[test]
    fn poll_completed_returns_after_event_fires() {
        let mut m = mgr(SwapMode::Async, DispatchMode::ThreadPool { workers: 4 });
        m.submit_swap_in(op(Direction::In, 50, true), 0, 1_000_000, 4, 4000.0);
        assert!(m.poll_completed(1).is_empty());
        let done_at = m.sync_all_in(0);
        let done = m.poll_completed(done_at);
        assert_eq!(done, vec![1]);
        assert_eq!(m.ongoing_in_count(), 0);
    }

    #[test]
    fn async_swap_out_dispatch_counted_once() {
        // Regression: the async path used to add the GIL dispatch stall
        // to `sync_stall_ns` even though it was already recorded in
        // `main_thread_dispatch_ns`, double-counting dispatch time in the
        // Fig-1/Fig-10 stall breakdown. The stall returned to the engine
        // is pure dispatch, and it must land in exactly one counter.
        let mut m = mgr(SwapMode::Adaptive, DispatchMode::Gil);
        let stall = m.submit_swap_out(op(Direction::Out, 20, true), 0);
        assert!(stall > 0, "GIL dispatch must stall the main thread");
        assert_eq!(m.stats.main_thread_dispatch_ns, stall);
        assert_eq!(
            m.stats.sync_stall_ns, 0,
            "dispatch time double-counted as sync stall"
        );
    }

    #[test]
    fn stall_counters_are_disjoint_under_sync_gil() {
        // Sync swap-out: the full stall splits exactly into the dispatch
        // share (main_thread_dispatch_ns) and the execution wait
        // (sync_stall_ns) — summing the breakdown reconstructs the stall
        // with no overlap.
        let mut m = mgr(SwapMode::Sync, DispatchMode::Gil);
        let stall = m.submit_swap_out(op(Direction::Out, 20, false), 0);
        assert!(m.stats.main_thread_dispatch_ns > 0);
        assert!(m.stats.sync_stall_ns > 0);
        assert_eq!(
            m.stats.main_thread_dispatch_ns + m.stats.sync_stall_ns,
            stall,
            "breakdown buckets must partition the stall"
        );
        // Same disjointness on the sync swap-in path.
        let mut m = mgr(SwapMode::Sync, DispatchMode::Gil);
        let d = m.submit_swap_in(op(Direction::In, 20, false), 0, 1_000_000, 4, 4000.0);
        let done = match d {
            SwapInDecision::Sync { done } => done,
            SwapInDecision::Async => panic!("sync mode must not go async"),
        };
        assert_eq!(
            m.stats.main_thread_dispatch_ns + m.stats.sync_stall_ns,
            done,
            "swap-in breakdown buckets must partition the stall"
        );
    }

    #[test]
    fn conflict_detected_on_overlapping_blocks() {
        let mut m = mgr(SwapMode::Adaptive, DispatchMode::ThreadPool { workers: 4 });
        m.submit_swap_out(op(Direction::Out, 20, true), 0); // blocks 10..30
        let sync = m.detect_conflict(&[12, 99], 0);
        assert!(sync.is_some());
        assert_eq!(m.stats.conflicts, 1);
        let none = m.detect_conflict(&[99, 200], 0);
        assert!(none.is_none());
    }

    #[test]
    fn conflict_ignored_once_drained() {
        let mut m = mgr(SwapMode::Adaptive, DispatchMode::ThreadPool { workers: 4 });
        m.submit_swap_out(op(Direction::Out, 20, true), 0);
        let end = m.ongoing_out[0].0.exec_done;
        assert!(m.detect_conflict(&[12], end).is_none());
    }

    #[test]
    fn event_pool_recycles() {
        let mut p = EventPool::default();
        let a = p.acquire();
        let b = p.acquire();
        p.release(a);
        let c = p.acquire();
        assert_eq!(c, a);
        assert_ne!(b, c);
        assert_eq!(p.high_water, 2);
    }

    #[test]
    fn in_and_out_directions_overlap() {
        // Full-duplex: an outgoing op must not delay an incoming one.
        let mut m = mgr(SwapMode::Async, DispatchMode::ThreadPool { workers: 8 });
        m.submit_swap_out(op(Direction::Out, 100, true), 0);
        let before = m.sync_all_in(0);
        m.submit_swap_in(op(Direction::In, 100, true), 0, 1_000_000, 4, 4000.0);
        let after = m.sync_all_in(0);
        let out_done = m.ongoing_out[0].0.exec_done;
        assert!(after < out_done + (out_done - before) / 4, "directions serialized?");
    }
}
