//! Multithreading Swap Manager (paper §3.2, Algorithm 1).
//!
//! Owns the dispatch lanes (GIL vs thread pool) and the PCIe link, tracks
//! in-flight operations with an event pool, and implements:
//!
//! - **Adaptive swapping strategy** — per-iteration choice between
//!   asynchronous swap-in (overlapped with inference) and synchronous
//!   swap-in (stall once), driven by a profiler window of recent swap
//!   metrics. The paper observes async is *not* always better: with many
//!   short requests, holding GPU blocks for several iterations while a
//!   swap-in completes costs more tokens than a short stall.
//! - **Conflict detection** — newly allocated GPU blocks may still be the
//!   source of an in-flight swap-out; writing them would corrupt the copy,
//!   so the manager synchronizes on exactly the conflicting operations.
//! - **Ordered dispatch** — the dispatch model inserts fine-grained
//!   synchronizations every N calls so inference-stream copies can
//!   preempt a long swap burst (modeled in [`crate::sim::dispatch`]).

use std::collections::VecDeque;

use super::op::{InflightOp, SwapOp};
use crate::config::{DispatchMode, SwapCostConfig, SwapMode};
use crate::memory::{BlockId, RequestId};
use crate::obs::{TraceEvent, TraceSink};
use crate::sim::clock::Ns;
use crate::sim::dispatch::DispatchLanes;
use crate::sim::link::{Direction, PcieLink};

/// CUDA-event pool analogue: recycled completion-tracking handles.
#[derive(Clone, Debug, Default)]
pub struct EventPool {
    free: Vec<u32>,
    next: u32,
    pub high_water: u32,
}

impl EventPool {
    pub fn acquire(&mut self) -> u32 {
        if let Some(e) = self.free.pop() {
            e
        } else {
            let e = self.next;
            self.next += 1;
            self.high_water = self.high_water.max(self.next);
            e
        }
    }

    pub fn release(&mut self, e: u32) {
        self.free.push(e);
    }
}

/// Profiler sample over one recent swap (the paper's `r_info` queue).
#[derive(Clone, Copy, Debug)]
pub struct RecentSwap {
    pub bytes: u64,
    pub calls: u32,
    pub duration: Ns,
}

/// Cumulative statistics (feeds Figs. 9/10/12 and Table 1).
#[derive(Clone, Debug, Default)]
pub struct SwapStats {
    pub swap_out_ops: u64,
    pub swap_in_ops: u64,
    pub async_swap_ins: u64,
    pub sync_swap_ins: u64,
    pub total_calls: u64,
    pub total_bytes: u64,
    pub total_blocks: u64,
    pub conflicts: u64,
    pub conflict_wait_ns: Ns,
    /// Main-thread time consumed by dispatch (the GIL tax). Disjoint
    /// from `sync_stall_ns`: summing the two reconstructs the total
    /// main-thread stall without double-counting (Figs. 1/10).
    pub main_thread_dispatch_ns: Ns,
    /// Execution-wait stall from synchronous swap-ins / swap-outs,
    /// *excluding* the dispatch share already counted in
    /// `main_thread_dispatch_ns`.
    pub sync_stall_ns: Ns,
    /// Sum over ops of avg blocks/call (divide by op count for the
    /// Fig. 11 granularity metric).
    pub granularity_sum: f64,
    // ---- lookahead prefetcher (speculative swap-ins) ----
    /// Speculative swap-ins issued. Kept out of `swap_in_ops` /
    /// `total_*` so demand swap volume and the stall-breakdown buckets
    /// stay exactly what they were without prefetching.
    pub prefetch_ops: u64,
    /// Bytes moved by speculative swap-ins (background PCIe traffic).
    pub prefetch_bytes: u64,
    /// Distinct logical blocks moved speculatively.
    pub prefetch_blocks: u64,
    /// Re-admissions whose prefetch had fully landed: zero swap-in stall.
    pub prefetch_hits: u64,
    /// Re-admissions that found their prefetch still on the wire and
    /// continued it asynchronously (only the remainder is waited on).
    pub prefetch_partial_hits: u64,
    /// Prefetches canceled on misprediction (priority flip, block-pool
    /// pressure, migration/rejection).
    pub prefetch_canceled: u64,
    /// PCIe bytes spent on canceled prefetches — pure speculation waste.
    pub prefetch_wasted_bytes: u64,
    /// Demand-stall nanoseconds the prefetcher recovered: for a hit, the
    /// whole transfer ran off the critical path; for a partial hit, the
    /// already-elapsed share did.
    pub prefetch_recovered_ns: Ns,
}

impl SwapStats {
    pub fn avg_granularity(&self) -> f64 {
        let ops = (self.swap_out_ops + self.swap_in_ops) as f64;
        if ops == 0.0 {
            0.0
        } else {
            self.granularity_sum / ops
        }
    }

    /// Fraction of KV re-materializations served (at least partly) by a
    /// prefetch instead of a demand swap-in. `0.0` when nothing swapped
    /// in at all.
    pub fn prefetch_hit_rate(&self) -> f64 {
        let served = self.prefetch_hits + self.prefetch_partial_hits;
        let total = served + self.swap_in_ops;
        if total == 0 {
            0.0
        } else {
            served as f64 / total as f64
        }
    }
}

/// How a submitted swap-in is being executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapInDecision {
    /// Stall the iteration until `done`.
    Sync { done: Ns },
    /// Overlapped; the request returns via `poll_completed`.
    Async,
}

/// Outcome of [`SwapManager::submit_prefetch`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefetchSubmit {
    /// Issued onto the idle inbound DMA engine under the I/O budget.
    Started,
    /// The token bucket cannot cover the op right now — retry after a
    /// refill ([`SwapManager::prefetch_budget_eta`] says when).
    RejectedBudget,
    /// The inbound direction is busy (demand traffic or an earlier
    /// prefetch): speculation never queues ahead of anything.
    RejectedBusy,
    /// The op exceeds the bucket's burst capacity (or is empty): it can
    /// *never* be issued under this budget — drop it, don't retry.
    RejectedTooLarge,
}

/// Outcome of [`SwapManager::claim_prefetch`] at re-admission time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefetchClaim {
    /// The KV fully landed: re-admit with zero swap-in stall.
    Ready,
    /// Still on the wire: the op continues as an ordinary asynchronous
    /// swap-in (harvested via `poll_completed` at `done`).
    Pending { done: Ns },
}

/// Outcome of [`SwapManager::cancel_prefetch`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefetchCancel {
    /// The transfer had completed: the caller may free the GPU blocks
    /// immediately.
    Freed { wasted_bytes: u64 },
    /// Still on the wire: the op keeps draining (its GPU blocks stay
    /// allocated and conflict-visible); `reap_prefetch_drains` returns
    /// the request id once it is safe to free them.
    Draining { done: Ns },
}

/// One speculative swap-in: in flight until `inflight.exec_done`, then
/// parked (still holding its event) until claimed or canceled.
#[derive(Clone, Debug)]
struct PrefetchEntry {
    inflight: InflightOp,
    ev: u32,
    submitted: Ns,
}

/// Token-bucket window for the prefetch I/O budget: the bucket holds at
/// most this many seconds of budgeted bandwidth, bounding burst size.
const PREFETCH_BUDGET_WINDOW_S: f64 = 0.25;

#[derive(Clone, Debug)]
pub struct SwapManager {
    pub dispatch: DispatchLanes,
    pub link: PcieLink,
    mode: SwapMode,
    dispatch_mode: DispatchMode,
    ongoing_in: Vec<(InflightOp, u32)>,
    ongoing_out: Vec<(InflightOp, u32)>,
    /// Speculative swap-ins: in flight or landed-but-unclaimed.
    prefetches: Vec<PrefetchEntry>,
    /// Canceled-while-in-flight prefetches still draining on the link.
    prefetch_drains: Vec<PrefetchEntry>,
    /// Prefetch I/O token bucket: refill rate (bytes/s), burst cap, and
    /// current level. Rate 0 (unconfigured) rejects every prefetch.
    prefetch_rate: f64,
    prefetch_cap: f64,
    prefetch_budget: f64,
    prefetch_last_refill: Ns,
    events: EventPool,
    r_info: VecDeque<RecentSwap>,
    r_info_cap: usize,
    pub stats: SwapStats,
    adaptive_overlap_threshold: f64,
    /// Lifecycle trace sink, shared with the engine's — I/O events
    /// interleave with scheduling events in one ordered stream. Off (a
    /// no-op) unless the engine enables tracing.
    trace: TraceSink,
}

impl SwapManager {
    pub fn new(
        mode: SwapMode,
        dispatch_mode: DispatchMode,
        cost: &SwapCostConfig,
        link: PcieLink,
    ) -> Self {
        SwapManager {
            dispatch: DispatchLanes::new(dispatch_mode, cost),
            link,
            mode,
            dispatch_mode,
            ongoing_in: Vec::new(),
            ongoing_out: Vec::new(),
            prefetches: Vec::new(),
            prefetch_drains: Vec::new(),
            prefetch_rate: 0.0,
            prefetch_cap: 0.0,
            prefetch_budget: 0.0,
            prefetch_last_refill: 0,
            events: EventPool::default(),
            r_info: VecDeque::new(),
            r_info_cap: 32,
            stats: SwapStats::default(),
            adaptive_overlap_threshold: cost.adaptive_overlap_threshold,
            trace: TraceSink::default(),
        }
    }

    pub fn mode(&self) -> SwapMode {
        self.mode
    }

    /// Share the engine's trace sink (clones write into one buffer).
    pub fn set_trace(&mut self, trace: TraceSink) {
        self.trace = trace;
    }

    /// Step 1 of Algorithm 1: harvest asynchronous swap-ins whose event
    /// has fired; the engine returns them to the running queue.
    pub fn poll_completed(&mut self, now: Ns) -> Vec<RequestId> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.ongoing_in.len() {
            if self.ongoing_in[i].0.exec_done <= now {
                let (inflight, ev) = self.ongoing_in.swap_remove(i);
                self.events.release(ev);
                done.push(inflight.op.req);
            } else {
                i += 1;
            }
        }
        done
    }

    /// Drop drained swap-outs (their CPU copies are now complete) and
    /// return the finished request ids (the engine commits reuse state).
    pub fn reap_swap_outs(&mut self, now: Ns) -> Vec<RequestId> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.ongoing_out.len() {
            if self.ongoing_out[i].0.exec_done <= now {
                let (inflight, ev) = self.ongoing_out.swap_remove(i);
                self.events.release(ev);
                done.push(inflight.op.req);
            } else {
                i += 1;
            }
        }
        done
    }

    // Perf note (§Perf L3): takes the op by value — the segment vector
    // (up to blocks×layers entries at vLLM granularity) is moved into the
    // inflight record instead of cloned.
    fn run_op(&mut self, op: SwapOp, now: Ns) -> InflightOp {
        let dir = op.dir;
        let mut dispatch_done = now;
        let mut exec_done = now;
        for seg in &op.segments {
            let d = self.dispatch.dispatch_one(now);
            dispatch_done = dispatch_done.max(d);
            let t = self.link.enqueue(dir, seg.bytes, d);
            exec_done = exec_done.max(t.end);
        }
        self.stats.total_calls += op.n_calls() as u64;
        self.stats.total_bytes += op.total_bytes();
        self.stats.total_blocks += op.blocks as u64;
        self.stats.granularity_sum += op.avg_granularity();
        self.push_r_info(RecentSwap {
            bytes: op.total_bytes(),
            calls: op.n_calls() as u32,
            duration: exec_done.saturating_sub(now),
        });
        InflightOp {
            op,
            dispatch_done,
            exec_done,
        }
    }

    fn push_r_info(&mut self, r: RecentSwap) {
        if self.r_info.len() == self.r_info_cap {
            self.r_info.pop_front();
        }
        self.r_info.push_back(r);
    }

    /// Step 3 of Algorithm 1: swap-out. Returns the main-thread stall
    /// this costs the current iteration:
    /// - GIL dispatch serializes on the main thread (dispatch time);
    /// - `SwapMode::Sync` additionally waits for execution (vLLM
    ///   semantics: the swap must finish before the iteration proceeds).
    pub fn submit_swap_out(&mut self, op: SwapOp, now: Ns) -> Ns {
        if op.segments.is_empty() {
            return 0;
        }
        let (req, blocks, bytes) = (op.req, op.blocks as usize, op.total_bytes());
        let inflight = self.run_op(op, now);
        self.trace.emit(
            now,
            TraceEvent::SwapOut {
                req,
                blocks,
                bytes,
                sync: matches!(self.mode, SwapMode::Sync),
                done: inflight.exec_done,
            },
        );
        self.stats.swap_out_ops += 1;
        let main_thread = match self.dispatch_mode {
            DispatchMode::Gil => inflight.dispatch_done.saturating_sub(now),
            DispatchMode::ThreadPool { .. } => 0,
        };
        self.stats.main_thread_dispatch_ns += main_thread;
        let stall = match self.mode {
            SwapMode::Sync => inflight.exec_done.saturating_sub(now),
            _ => main_thread,
        };
        if matches!(self.mode, SwapMode::Sync) {
            // The dispatch share of the stall is already counted in
            // `main_thread_dispatch_ns`; record only the execution wait so
            // the Fig-1/Fig-10 breakdown buckets stay disjoint.
            self.stats.sync_stall_ns += stall.saturating_sub(main_thread);
            // Synchronous: nothing left in flight.
        } else {
            // Asynchronous: the only main-thread cost is the dispatch,
            // which `main_thread_dispatch_ns` above already recorded.
            let ev = self.events.acquire();
            self.ongoing_out.push((inflight, ev));
        }
        stall
    }

    /// Step 4 of Algorithm 1: swap-in with the adaptive strategy.
    /// `iter_ns_hint` — engine's estimate of the next iteration time;
    /// `batch` / `avg_ctx_tokens` — running-batch profile.
    pub fn submit_swap_in(
        &mut self,
        op: SwapOp,
        now: Ns,
        iter_ns_hint: Ns,
        batch: usize,
        avg_ctx_tokens: f64,
    ) -> SwapInDecision {
        if op.segments.is_empty() {
            return SwapInDecision::Sync { done: now };
        }
        let (req, blocks, bytes) = (op.req, op.blocks as usize, op.total_bytes());
        let inflight = self.run_op(op, now);
        self.stats.swap_in_ops += 1;
        let main_thread = match self.dispatch_mode {
            DispatchMode::Gil => inflight.dispatch_done.saturating_sub(now),
            DispatchMode::ThreadPool { .. } => 0,
        };
        self.stats.main_thread_dispatch_ns += main_thread;

        let go_async = match self.mode {
            SwapMode::Sync => false,
            SwapMode::Async => true,
            SwapMode::Adaptive => {
                let dur = inflight.exec_done.saturating_sub(now);
                // Tiny swaps: stalling once is cheaper than holding blocks
                // idle for dur/iter iterations.
                let worth_overlapping =
                    dur as f64 > self.adaptive_overlap_threshold * iter_ns_hint as f64;
                // Many short requests: token throughput dominates — prefer
                // the short sync stall (paper §3.2).
                let many_short = batch >= 24 && avg_ctx_tokens < 512.0;
                worth_overlapping && !many_short
            }
        };
        self.trace.emit(
            now,
            TraceEvent::SwapIn {
                req,
                blocks,
                bytes,
                sync: !go_async,
                done: inflight.exec_done,
            },
        );
        if go_async {
            self.stats.async_swap_ins += 1;
            let ev = self.events.acquire();
            self.ongoing_in.push((inflight, ev));
            SwapInDecision::Async
        } else {
            self.stats.sync_swap_ins += 1;
            let stall = inflight.exec_done.saturating_sub(now);
            // Dispatch already landed in `main_thread_dispatch_ns`.
            self.stats.sync_stall_ns += stall.saturating_sub(main_thread);
            SwapInDecision::Sync {
                done: inflight.exec_done,
            }
        }
    }

    // ------------------------------------------------------------------
    // Lookahead prefetch (speculative swap-ins below demand traffic)
    // ------------------------------------------------------------------

    /// Arm the prefetch I/O token bucket at `rate_bytes_per_s` (the
    /// engine passes `io_budget × pcie_bw`). The bucket starts full so a
    /// freshly idle link can prefetch immediately.
    pub fn configure_prefetch(&mut self, rate_bytes_per_s: f64) {
        self.prefetch_rate = rate_bytes_per_s.max(0.0);
        self.prefetch_cap = self.prefetch_rate * PREFETCH_BUDGET_WINDOW_S;
        self.prefetch_budget = self.prefetch_cap;
    }

    /// Refill the token bucket for the virtual time elapsed since the
    /// last refill (capped at the burst window).
    pub fn refill_prefetch_budget(&mut self, now: Ns) {
        let dt = now.saturating_sub(self.prefetch_last_refill);
        self.prefetch_last_refill = now;
        self.prefetch_budget = (self.prefetch_budget
            + self.prefetch_rate * dt as f64 / 1e9)
            .min(self.prefetch_cap);
    }

    /// When the token bucket will have refilled enough to cover `bytes`
    /// (assuming no spending in between): the engine's idle-wake target
    /// after a [`PrefetchSubmit::RejectedBudget`]. `None` if the budget
    /// can never cover it (rate 0 or beyond the burst cap).
    pub fn prefetch_budget_eta(&self, bytes: u64, now: Ns) -> Option<Ns> {
        let bytes = bytes as f64;
        if self.prefetch_rate <= 0.0 || bytes > self.prefetch_cap {
            return None;
        }
        if bytes <= self.prefetch_budget {
            return Some(now);
        }
        let wait_s = (bytes - self.prefetch_budget) / self.prefetch_rate;
        Some(now + (wait_s * 1e9).ceil() as Ns)
    }

    /// Would a speculative op of `bytes` be accepted right now? The same
    /// checks as [`SwapManager::submit_prefetch`] without building or
    /// issuing anything — the engine's cheap pre-flight before spending
    /// an allocation + op build on a doomed submission.
    pub fn prefetch_admissible(&self, bytes: u64, now: Ns) -> PrefetchSubmit {
        if bytes == 0 || bytes as f64 > self.prefetch_cap {
            PrefetchSubmit::RejectedTooLarge
        } else if self.link.idle_at(Direction::In) > now {
            PrefetchSubmit::RejectedBusy
        } else if bytes as f64 > self.prefetch_budget {
            PrefetchSubmit::RejectedBudget
        } else {
            PrefetchSubmit::Started
        }
    }

    /// Issue a speculative swap-in. Unlike demand ops it bypasses the
    /// dispatch lanes (the paper's §3.2 thread pool absorbs background
    /// dispatch off the main thread), only runs when the inbound DMA
    /// engine is idle, and must fit the I/O token bucket — so it can
    /// never push demand traffic off the critical path's schedule by
    /// more than the configured link fraction.
    pub fn submit_prefetch(&mut self, op: SwapOp, now: Ns) -> PrefetchSubmit {
        let bytes = op.total_bytes();
        // Single source of truth for admission — an empty op has 0 bytes
        // and lands in RejectedTooLarge (drop, don't retry).
        match self.prefetch_admissible(bytes, now) {
            PrefetchSubmit::Started => {}
            reject => return reject,
        }
        self.prefetch_budget -= bytes as f64;
        let mut exec_done = now;
        for seg in &op.segments {
            let t = self.link.enqueue_background(Direction::In, seg.bytes, now);
            exec_done = exec_done.max(t.end);
        }
        self.stats.prefetch_ops += 1;
        self.stats.prefetch_bytes += bytes;
        self.stats.prefetch_blocks += op.blocks as u64;
        self.trace.emit(
            now,
            TraceEvent::PrefetchIssue {
                req: op.req,
                blocks: op.blocks as usize,
                bytes,
                done: exec_done,
            },
        );
        let ev = self.events.acquire();
        self.prefetches.push(PrefetchEntry {
            inflight: InflightOp {
                op,
                dispatch_done: now,
                exec_done,
            },
            ev,
            submitted: now,
        });
        PrefetchSubmit::Started
    }

    /// Consume `req`'s prefetch at re-admission time. `Ready` means the
    /// KV is resident (zero swap-in stall); `Pending` converts the op
    /// into an ordinary asynchronous swap-in the engine harvests via
    /// [`SwapManager::poll_completed`].
    pub fn claim_prefetch(&mut self, req: RequestId, now: Ns) -> Option<PrefetchClaim> {
        let i = self
            .prefetches
            .iter()
            .position(|e| e.inflight.op.req == req)?;
        let e = self.prefetches.swap_remove(i);
        self.trace.emit(
            now,
            TraceEvent::PrefetchClaim {
                req,
                ready: e.inflight.exec_done <= now,
            },
        );
        if e.inflight.exec_done <= now {
            self.events.release(e.ev);
            self.stats.prefetch_hits += 1;
            self.stats.prefetch_recovered_ns +=
                e.inflight.exec_done.saturating_sub(e.submitted);
            Some(PrefetchClaim::Ready)
        } else {
            self.stats.prefetch_partial_hits += 1;
            self.stats.prefetch_recovered_ns += now.saturating_sub(e.submitted);
            let done = e.inflight.exec_done;
            self.ongoing_in.push((e.inflight, e.ev));
            Some(PrefetchClaim::Pending { done })
        }
    }

    /// Abort `req`'s prefetch (misprediction / pressure / migration).
    /// A completed transfer frees immediately; an in-flight one keeps
    /// draining (blocks stay allocated and conflict-visible) and its id
    /// is returned by [`SwapManager::reap_prefetch_drains`] once done.
    /// Either way the bytes already spent are charged as waste; the CPU
    /// copy is untouched and stays the valid version.
    pub fn cancel_prefetch(&mut self, req: RequestId, now: Ns) -> Option<PrefetchCancel> {
        let i = self
            .prefetches
            .iter()
            .position(|e| e.inflight.op.req == req)?;
        let e = self.prefetches.swap_remove(i);
        self.trace.emit(
            now,
            TraceEvent::PrefetchCancel {
                req,
                landed: e.inflight.exec_done <= now,
            },
        );
        self.stats.prefetch_canceled += 1;
        self.stats.prefetch_wasted_bytes += e.inflight.op.total_bytes();
        if e.inflight.exec_done <= now {
            self.events.release(e.ev);
            Some(PrefetchCancel::Freed {
                wasted_bytes: e.inflight.op.total_bytes(),
            })
        } else {
            let done = e.inflight.exec_done;
            self.prefetch_drains.push(e);
            Some(PrefetchCancel::Draining { done })
        }
    }

    /// Drained canceled prefetches: their GPU blocks may now be freed by
    /// the engine (mirrors `reap_swap_outs`).
    pub fn reap_prefetch_drains(&mut self, now: Ns) -> Vec<RequestId> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.prefetch_drains.len() {
            if self.prefetch_drains[i].inflight.exec_done <= now {
                let e = self.prefetch_drains.swap_remove(i);
                self.events.release(e.ev);
                done.push(e.inflight.op.req);
            } else {
                i += 1;
            }
        }
        done
    }

    /// Does `req` have an unclaimed prefetch (in flight or landed)?
    pub fn prefetch_pending(&self, req: RequestId) -> bool {
        self.prefetches.iter().any(|e| e.inflight.op.req == req)
    }

    /// Has `req`'s prefetch fully landed (cancelable without a drain)?
    pub fn prefetch_ready(&self, req: RequestId, now: Ns) -> bool {
        self.prefetches
            .iter()
            .any(|e| e.inflight.op.req == req && e.inflight.exec_done <= now)
    }

    /// Requests with an unclaimed prefetch, sorted for determinism.
    pub fn prefetched_ids(&self) -> Vec<RequestId> {
        let mut ids: Vec<RequestId> =
            self.prefetches.iter().map(|e| e.inflight.op.req).collect();
        ids.sort_unstable();
        ids
    }

    /// Unclaimed prefetches (in flight + landed); drains excluded.
    pub fn prefetch_count(&self) -> usize {
        self.prefetches.len()
    }

    /// Earliest completion among live (unclaimed) prefetches strictly
    /// after `now` — the engine's idle-wake target when further
    /// speculative work is queued behind the one occupying the link.
    pub fn next_prefetch_completion(&self, now: Ns) -> Option<Ns> {
        self.prefetches
            .iter()
            .map(|e| e.inflight.exec_done)
            .filter(|&t| t > now)
            .min()
    }

    /// Canceled prefetches still draining on the link.
    pub fn prefetch_draining_count(&self) -> usize {
        self.prefetch_drains.len()
    }

    /// Step 3.1 of Algorithm 1: conflict detection. If any freshly
    /// allocated GPU block is still the source/target of an in-flight op,
    /// return the synchronization point (latest conflicting event).
    /// Speculative swap-ins (and their canceled drains) are writers too:
    /// their destination blocks conflict exactly like demand traffic.
    pub fn detect_conflict(&mut self, new_blocks: &[BlockId], now: Ns) -> Option<Ns> {
        if new_blocks.is_empty()
            || (self.ongoing_out.is_empty()
                && self.ongoing_in.is_empty()
                && self.prefetches.is_empty()
                && self.prefetch_drains.is_empty())
        {
            return None;
        }
        // Per-iteration admission hot path: hash the new blocks once so
        // each in-flight segment costs O(1) instead of a linear scan of
        // `new_blocks` (O(inflight × blocks + new) vs
        // O(inflight × blocks × new)).
        let fresh: std::collections::HashSet<BlockId> =
            new_blocks.iter().copied().collect();
        let mut sync_until: Option<Ns> = None;
        let demand = self
            .ongoing_out
            .iter()
            .chain(self.ongoing_in.iter())
            .map(|(i, _)| i);
        let speculative = self
            .prefetches
            .iter()
            .chain(self.prefetch_drains.iter())
            .map(|e| &e.inflight);
        for inflight in demand.chain(speculative) {
            if inflight.exec_done <= now {
                continue;
            }
            if inflight.op.gpu_blocks.iter().any(|b| fresh.contains(b)) {
                sync_until = Some(sync_until.map_or(inflight.exec_done, |s: Ns| {
                    s.max(inflight.exec_done)
                }));
            }
        }
        if let Some(s) = sync_until {
            self.stats.conflicts += 1;
            self.stats.conflict_wait_ns += s.saturating_sub(now);
        }
        sync_until
    }

    /// Earliest completion among all in-flight operations (both
    /// directions) — the engine's idle fast-forward target. Canceled
    /// prefetch drains count (their blocks free at that instant); live
    /// unclaimed prefetches do NOT — they park until claimed, and must
    /// not keep an otherwise finished engine spinning.
    pub fn next_event(&self) -> Option<Ns> {
        self.ongoing_in
            .iter()
            .chain(self.ongoing_out.iter())
            .map(|(i, _)| i.exec_done)
            .chain(self.prefetch_drains.iter().map(|e| e.inflight.exec_done))
            .min()
    }

    /// Earliest completion among in-flight swap-outs.
    pub fn next_out_event(&self) -> Option<Ns> {
        self.ongoing_out.iter().map(|(i, _)| i.exec_done).min()
    }

    /// Record a memory-pressure conflict: an allocation had to wait
    /// `wait_ns` for an in-flight swap-out to release its source blocks
    /// (paper §3.2 KV-cache conflict resolution).
    pub fn record_conflict(&mut self, wait_ns: Ns) {
        self.stats.conflicts += 1;
        self.stats.conflict_wait_ns += wait_ns;
    }

    /// `SwapInStreamSynchronize()` — drain every ongoing swap-in.
    pub fn sync_all_in(&self, now: Ns) -> Ns {
        self.ongoing_in
            .iter()
            .map(|(i, _)| i.exec_done)
            .fold(now, Ns::max)
    }

    /// If `req` has a swap-out still executing, when it completes. Used
    /// by the engine to barrier a swap-in that would read the CPU copy
    /// before it is fully written.
    pub fn swap_out_inflight(&self, req: RequestId) -> Option<Ns> {
        self.ongoing_out
            .iter()
            .find(|(i, _)| i.op.req == req)
            .map(|(i, _)| i.exec_done)
    }

    pub fn ongoing_in_count(&self) -> usize {
        self.ongoing_in.len()
    }

    pub fn ongoing_out_count(&self) -> usize {
        self.ongoing_out.len()
    }

    pub fn event_high_water(&self) -> u32 {
        self.events.high_water
    }

    pub fn recent(&self) -> impl Iterator<Item = &RecentSwap> {
        self.r_info.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, Granularity, ModelSpec};
    use crate::sim::link::Direction;
    use crate::swap::engine::{BlockMove, SegmentBuilder};

    fn op(dir: Direction, nblocks: u32, coalesced: bool) -> SwapOp {
        let g = if coalesced {
            Granularity::BlockGroup { init_group_blocks: 60 }
        } else {
            Granularity::FixedBlock
        };
        let b = SegmentBuilder::new(ModelSpec::llama8b(), g);
        let moves: Vec<BlockMove> = (0..nblocks)
            .map(|i| BlockMove {
                logical: i,
                gpu: 10 + i,
                cpu: 100 + i,
            })
            .collect();
        b.build(1, dir, &moves)
    }

    fn mgr(mode: SwapMode, dm: DispatchMode) -> SwapManager {
        SwapManager::new(
            mode,
            dm,
            &SwapCostConfig::default(),
            PcieLink::new(GpuSpec::a10()),
        )
    }

    #[test]
    fn sync_swap_out_stalls_full_duration() {
        let mut m = mgr(SwapMode::Sync, DispatchMode::Gil);
        let stall = m.submit_swap_out(op(Direction::Out, 20, false), 0);
        assert!(stall > 0);
        assert_eq!(m.ongoing_out_count(), 0);
        assert_eq!(m.stats.swap_out_ops, 1);
    }

    #[test]
    fn async_swap_out_with_threadpool_is_free_for_main_thread() {
        let mut m = mgr(
            SwapMode::Adaptive,
            DispatchMode::ThreadPool { workers: 4 },
        );
        let stall = m.submit_swap_out(op(Direction::Out, 20, true), 0);
        assert_eq!(stall, 0);
        assert_eq!(m.ongoing_out_count(), 1);
        assert_eq!(m.stats.main_thread_dispatch_ns, 0);
    }

    #[test]
    fn coalesced_op_finishes_much_earlier() {
        let mut ma = mgr(SwapMode::Sync, DispatchMode::Gil);
        let mut mb = mgr(SwapMode::Sync, DispatchMode::Gil);
        let sa = ma.submit_swap_out(op(Direction::Out, 32, false), 0);
        let sb = mb.submit_swap_out(op(Direction::Out, 32, true), 0);
        assert!(
            (sb as f64) < sa as f64 / 4.0,
            "coalesced {sb} vs fixed {sa}"
        );
    }

    #[test]
    fn adaptive_small_swap_goes_sync() {
        let mut m = mgr(
            SwapMode::Adaptive,
            DispatchMode::ThreadPool { workers: 4 },
        );
        // 1-block swap vs a 30 ms iteration hint: not worth overlapping.
        let d = m.submit_swap_in(op(Direction::In, 1, true), 0, 30_000_000, 8, 2000.0);
        assert!(matches!(d, SwapInDecision::Sync { .. }));
        assert_eq!(m.stats.sync_swap_ins, 1);
    }

    #[test]
    fn adaptive_large_swap_goes_async() {
        let mut m = mgr(
            SwapMode::Adaptive,
            DispatchMode::ThreadPool { workers: 4 },
        );
        let d = m.submit_swap_in(op(Direction::In, 200, true), 0, 5_000_000, 8, 2000.0);
        assert_eq!(d, SwapInDecision::Async);
        assert_eq!(m.ongoing_in_count(), 1);
    }

    #[test]
    fn adaptive_many_short_requests_prefers_sync() {
        let mut m = mgr(
            SwapMode::Adaptive,
            DispatchMode::ThreadPool { workers: 4 },
        );
        let d = m.submit_swap_in(op(Direction::In, 200, true), 0, 5_000_000, 32, 100.0);
        assert!(matches!(d, SwapInDecision::Sync { .. }));
    }

    #[test]
    fn poll_completed_returns_after_event_fires() {
        let mut m = mgr(SwapMode::Async, DispatchMode::ThreadPool { workers: 4 });
        m.submit_swap_in(op(Direction::In, 50, true), 0, 1_000_000, 4, 4000.0);
        assert!(m.poll_completed(1).is_empty());
        let done_at = m.sync_all_in(0);
        let done = m.poll_completed(done_at);
        assert_eq!(done, vec![1]);
        assert_eq!(m.ongoing_in_count(), 0);
    }

    #[test]
    fn async_swap_out_dispatch_counted_once() {
        // Regression: the async path used to add the GIL dispatch stall
        // to `sync_stall_ns` even though it was already recorded in
        // `main_thread_dispatch_ns`, double-counting dispatch time in the
        // Fig-1/Fig-10 stall breakdown. The stall returned to the engine
        // is pure dispatch, and it must land in exactly one counter.
        let mut m = mgr(SwapMode::Adaptive, DispatchMode::Gil);
        let stall = m.submit_swap_out(op(Direction::Out, 20, true), 0);
        assert!(stall > 0, "GIL dispatch must stall the main thread");
        assert_eq!(m.stats.main_thread_dispatch_ns, stall);
        assert_eq!(
            m.stats.sync_stall_ns, 0,
            "dispatch time double-counted as sync stall"
        );
    }

    #[test]
    fn stall_counters_are_disjoint_under_sync_gil() {
        // Sync swap-out: the full stall splits exactly into the dispatch
        // share (main_thread_dispatch_ns) and the execution wait
        // (sync_stall_ns) — summing the breakdown reconstructs the stall
        // with no overlap.
        let mut m = mgr(SwapMode::Sync, DispatchMode::Gil);
        let stall = m.submit_swap_out(op(Direction::Out, 20, false), 0);
        assert!(m.stats.main_thread_dispatch_ns > 0);
        assert!(m.stats.sync_stall_ns > 0);
        assert_eq!(
            m.stats.main_thread_dispatch_ns + m.stats.sync_stall_ns,
            stall,
            "breakdown buckets must partition the stall"
        );
        // Same disjointness on the sync swap-in path.
        let mut m = mgr(SwapMode::Sync, DispatchMode::Gil);
        let d = m.submit_swap_in(op(Direction::In, 20, false), 0, 1_000_000, 4, 4000.0);
        let done = match d {
            SwapInDecision::Sync { done } => done,
            SwapInDecision::Async => panic!("sync mode must not go async"),
        };
        assert_eq!(
            m.stats.main_thread_dispatch_ns + m.stats.sync_stall_ns,
            done,
            "swap-in breakdown buckets must partition the stall"
        );
    }

    #[test]
    fn conflict_detected_on_overlapping_blocks() {
        let mut m = mgr(SwapMode::Adaptive, DispatchMode::ThreadPool { workers: 4 });
        m.submit_swap_out(op(Direction::Out, 20, true), 0); // blocks 10..30
        let sync = m.detect_conflict(&[12, 99], 0);
        assert!(sync.is_some());
        assert_eq!(m.stats.conflicts, 1);
        let none = m.detect_conflict(&[99, 200], 0);
        assert!(none.is_none());
    }

    #[test]
    fn conflict_ignored_once_drained() {
        let mut m = mgr(SwapMode::Adaptive, DispatchMode::ThreadPool { workers: 4 });
        m.submit_swap_out(op(Direction::Out, 20, true), 0);
        let end = m.ongoing_out[0].0.exec_done;
        assert!(m.detect_conflict(&[12], end).is_none());
    }

    #[test]
    fn event_pool_recycles() {
        let mut p = EventPool::default();
        let a = p.acquire();
        let b = p.acquire();
        p.release(a);
        let c = p.acquire();
        assert_eq!(c, a);
        assert_ne!(b, c);
        assert_eq!(p.high_water, 2);
    }

    // ---- lookahead prefetch ----------------------------------------

    /// Build an op for an arbitrary request id (the shared `op` helper
    /// pins req 1).
    fn op_req(req: u64, dir: Direction, nblocks: u32) -> SwapOp {
        let b = SegmentBuilder::new(
            ModelSpec::llama8b(),
            Granularity::BlockGroup { init_group_blocks: 60 },
        );
        let moves: Vec<BlockMove> = (0..nblocks)
            .map(|i| BlockMove {
                logical: i,
                gpu: 500 + i,
                cpu: 700 + i,
            })
            .collect();
        b.build(req, dir, &moves)
    }

    fn prefetch_mgr() -> SwapManager {
        let mut m = mgr(SwapMode::Adaptive, DispatchMode::ThreadPool { workers: 4 });
        m.configure_prefetch(8e9); // 25% of a 32 GB/s link
        m
    }

    #[test]
    fn prefetch_claim_after_landing_is_a_zero_stall_hit() {
        let mut m = prefetch_mgr();
        assert_eq!(
            m.submit_prefetch(op(Direction::In, 6, true), 0),
            PrefetchSubmit::Started
        );
        assert!(m.prefetch_pending(1));
        assert_eq!(m.prefetch_count(), 1);
        let landed = m.link.idle_at(Direction::In);
        assert!(m.prefetch_ready(1, landed));
        assert_eq!(m.claim_prefetch(1, landed), Some(PrefetchClaim::Ready));
        assert_eq!(m.stats.prefetch_hits, 1);
        assert_eq!(m.stats.prefetch_recovered_ns, landed, "whole transfer off-path");
        // Demand counters untouched: hit rate is 1.0 with zero swap-ins.
        assert_eq!(m.stats.swap_in_ops, 0);
        assert!((m.stats.prefetch_hit_rate() - 1.0).abs() < 1e-12);
        assert!(m.claim_prefetch(1, landed).is_none(), "claimed once");
    }

    #[test]
    fn prefetch_claimed_early_continues_as_async_swap_in() {
        let mut m = prefetch_mgr();
        m.submit_prefetch(op(Direction::In, 50, true), 0);
        let claim = m.claim_prefetch(1, 1).expect("pending prefetch");
        let done = match claim {
            PrefetchClaim::Pending { done } => done,
            PrefetchClaim::Ready => panic!("cannot be ready at t=1"),
        };
        assert_eq!(m.stats.prefetch_partial_hits, 1);
        assert_eq!(m.ongoing_in_count(), 1, "continues as a demand async op");
        assert_eq!(m.poll_completed(done), vec![1]);
        assert!(m.poll_completed(done).is_empty(), "returned exactly once");
    }

    #[test]
    fn prefetch_rejected_while_link_busy() {
        let mut m = mgr(SwapMode::Async, DispatchMode::ThreadPool { workers: 4 });
        m.configure_prefetch(8e9);
        m.submit_swap_in(op(Direction::In, 50, true), 0, 1_000_000, 4, 4000.0);
        assert_eq!(
            m.submit_prefetch(op_req(2, Direction::In, 4), 0),
            PrefetchSubmit::RejectedBusy,
            "speculation must not queue behind (or ahead of) demand"
        );
        let idle = m.link.idle_at(Direction::In);
        assert_eq!(
            m.submit_prefetch(op_req(2, Direction::In, 4), idle),
            PrefetchSubmit::Started
        );
    }

    #[test]
    fn prefetch_budget_throttles_and_refills() {
        let mut m = mgr(SwapMode::Adaptive, DispatchMode::ThreadPool { workers: 4 });
        // 20 MB/s budget: bucket caps at 5 MB — one 4 MB block fits.
        m.configure_prefetch(20e6);
        assert_eq!(
            m.submit_prefetch(op(Direction::In, 1, true), 0),
            PrefetchSubmit::Started
        );
        let idle = m.link.idle_at(Direction::In);
        assert_eq!(
            m.submit_prefetch(op_req(2, Direction::In, 1), idle),
            PrefetchSubmit::RejectedBudget,
            "bucket spent"
        );
        // The ETA names the exact refill instant; by then the submit
        // succeeds.
        let bytes = op_req(2, Direction::In, 1).total_bytes();
        let eta = m.prefetch_budget_eta(bytes, idle).expect("refillable");
        assert!(eta > idle, "bucket was dry: the ETA must be in the future");
        m.refill_prefetch_budget(eta);
        assert_eq!(
            m.submit_prefetch(op_req(2, Direction::In, 1), eta),
            PrefetchSubmit::Started
        );
    }

    #[test]
    fn prefetch_larger_than_burst_cap_is_rejected_permanently() {
        let mut m = mgr(SwapMode::Adaptive, DispatchMode::ThreadPool { workers: 4 });
        // 1 MB/s budget: bucket caps at 250 KB — a 4 MB block can never
        // fit, no matter how long the refill runs.
        m.configure_prefetch(1e6);
        assert_eq!(
            m.submit_prefetch(op(Direction::In, 1, true), 0),
            PrefetchSubmit::RejectedTooLarge
        );
        let bytes = op(Direction::In, 1, true).total_bytes();
        assert_eq!(m.prefetch_budget_eta(bytes, 0), None, "no ETA for the unfittable");
        assert_eq!(m.prefetch_count(), 0, "nothing tracked, nothing charged");
        assert_eq!(m.stats.prefetch_ops, 0);
    }

    #[test]
    fn prefetch_cancel_frees_or_drains_and_counts_waste() {
        let mut m = prefetch_mgr();
        let bytes = op(Direction::In, 6, true).total_bytes();
        m.submit_prefetch(op(Direction::In, 6, true), 0);
        // Canceled mid-flight: drains, blocks not freeable yet.
        let c = m.cancel_prefetch(1, 1).expect("in flight");
        let done = match c {
            PrefetchCancel::Draining { done } => done,
            PrefetchCancel::Freed { .. } => panic!("cannot be done at t=1"),
        };
        assert_eq!(m.prefetch_draining_count(), 1);
        assert_eq!(m.reap_prefetch_drains(done), vec![1]);
        assert_eq!(m.prefetch_draining_count(), 0);
        // Canceled after landing: freeable immediately.
        let t0 = done;
        m.submit_prefetch(op_req(2, Direction::In, 6), t0);
        let landed = m.link.idle_at(Direction::In);
        assert_eq!(
            m.cancel_prefetch(2, landed),
            Some(PrefetchCancel::Freed { wasted_bytes: bytes })
        );
        assert_eq!(m.stats.prefetch_canceled, 2);
        assert_eq!(m.stats.prefetch_wasted_bytes, 2 * bytes);
    }

    #[test]
    fn prefetch_destination_blocks_are_conflict_visible() {
        let mut m = prefetch_mgr();
        m.submit_prefetch(op(Direction::In, 20, true), 0); // gpu 10..30
        assert!(m.detect_conflict(&[12], 0).is_some());
        assert!(m.detect_conflict(&[99], 0).is_none());
        // Once landed, the write is complete: no conflict.
        let landed = m.link.idle_at(Direction::In);
        assert!(m.detect_conflict(&[12], landed).is_none());
    }

    #[test]
    fn stall_partition_holds_with_prefetch_traffic_in_flight() {
        // Regression guard on the PR-3 invariant: with speculative
        // traffic on the wire, `main_thread_dispatch_ns` + `sync_stall_ns`
        // still exactly partition a demand op's stall, and prefetch
        // traffic lands in neither bucket (nor in demand volume).
        let mut m = mgr(SwapMode::Sync, DispatchMode::Gil);
        m.configure_prefetch(8e9);
        m.submit_prefetch(op_req(9, Direction::In, 8), 0);
        let spec_bytes = m.stats.prefetch_bytes;
        assert!(spec_bytes > 0);
        assert_eq!(m.stats.main_thread_dispatch_ns, 0);
        assert_eq!(m.stats.sync_stall_ns, 0);
        let d = m.submit_swap_in(op(Direction::In, 20, false), 0, 1_000_000, 4, 4000.0);
        let done = match d {
            SwapInDecision::Sync { done } => done,
            SwapInDecision::Async => panic!("sync mode must not go async"),
        };
        assert_eq!(
            m.stats.main_thread_dispatch_ns + m.stats.sync_stall_ns,
            done,
            "breakdown buckets must partition the demand stall"
        );
        assert_eq!(m.stats.prefetch_bytes, spec_bytes, "no double count");
        assert_eq!(
            m.stats.total_bytes,
            op(Direction::In, 20, false).total_bytes(),
            "demand volume excludes speculative bytes"
        );
    }

    #[test]
    fn event_pool_high_water_stays_bounded_under_pinned_churn() {
        // Satellite regression: 50 rounds of out + in + prefetch churn
        // recycle events instead of growing the pool.
        let mut m = mgr(SwapMode::Async, DispatchMode::ThreadPool { workers: 4 });
        m.configure_prefetch(32e9);
        let mut t: Ns = 0;
        for round in 0..50u64 {
            // Prefetch first (the link is idle at the top of each round),
            // then demand traffic queues behind it.
            let started = m.submit_prefetch(op_req(2, Direction::In, 4), t);
            assert_eq!(started, PrefetchSubmit::Started, "round {round}");
            m.submit_swap_out(op(Direction::Out, 8, true), t);
            m.submit_swap_in(op(Direction::In, 8, true), t, 1_000_000, 4, 4000.0);
            // Fast-forward past every in-flight op, then drain all three
            // tracking lists.
            t = t.max(m.sync_all_in(t)).max(m.next_out_event().unwrap_or(t)) + 1;
            m.refill_prefetch_budget(t);
            let polled = m.poll_completed(t);
            assert!(polled.len() <= 1);
            let reaped = m.reap_swap_outs(t);
            assert!(reaped.len() <= 1);
            // The demand swap-in queued behind the prefetch, so by `t`
            // the prefetch has certainly landed.
            assert_eq!(m.claim_prefetch(2, t), Some(PrefetchClaim::Ready));
        }
        assert_eq!(m.ongoing_in_count(), 0);
        assert_eq!(m.ongoing_out_count(), 0);
        assert!(
            m.event_high_water() <= 4,
            "event pool leaked: high water {}",
            m.event_high_water()
        );
    }

    #[test]
    fn poll_and_reap_never_return_a_request_twice() {
        let mut m = mgr(SwapMode::Async, DispatchMode::ThreadPool { workers: 8 });
        m.submit_swap_in(op(Direction::In, 30, true), 0, 1_000_000, 4, 4000.0);
        m.submit_swap_out(op_req(2, Direction::Out, 30), 0);
        let done = m.sync_all_in(0).max(m.next_out_event().unwrap());
        // Harvest incrementally across time: each id appears exactly once
        // over the whole sequence of polls/reaps.
        let mut seen_in = Vec::new();
        let mut seen_out = Vec::new();
        for t in [0, 1, done / 2, done, done, done + 1_000_000] {
            seen_in.extend(m.poll_completed(t));
            seen_out.extend(m.reap_swap_outs(t));
        }
        assert_eq!(seen_in, vec![1]);
        assert_eq!(seen_out, vec![2]);
    }

    #[test]
    fn in_and_out_directions_overlap() {
        // Full-duplex: an outgoing op must not delay an incoming one.
        let mut m = mgr(SwapMode::Async, DispatchMode::ThreadPool { workers: 8 });
        m.submit_swap_out(op(Direction::Out, 100, true), 0);
        let before = m.sync_all_in(0);
        m.submit_swap_in(op(Direction::In, 100, true), 0, 1_000_000, 4, 4000.0);
        let after = m.sync_all_in(0);
        let out_done = m.ongoing_out[0].0.exec_done;
        assert!(after < out_done + (out_done - before) / 4, "directions serialized?");
    }
}
