//! Swap operations and DMA segments.

use crate::memory::{BlockId, RequestId, SlotId};
use crate::sim::clock::Ns;
use crate::sim::link::Direction;

/// One DMA copy call (`cudaMemcpyAsync` equivalent): a physically
/// contiguous span on both ends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    /// First GPU block of the span.
    pub gpu_start: BlockId,
    /// First CPU slot of the span.
    pub cpu_start: SlotId,
    /// Blocks covered.
    pub blocks: u32,
    /// Layer index (each layer's cache is a separate tensor, so a block
    /// run yields one segment per layer).
    pub layer: u32,
    /// Bytes moved by this call.
    pub bytes: u64,
}

/// A request's context switch in one direction.
#[derive(Clone, Debug)]
pub struct SwapOp {
    pub req: RequestId,
    pub dir: Direction,
    pub segments: Vec<Segment>,
    /// Distinct logical blocks moved (all layers counted once).
    pub blocks: u32,
    /// GPU blocks touched — used for conflict detection against newly
    /// allocated blocks (paper §3.2).
    pub gpu_blocks: Vec<BlockId>,
}

impl SwapOp {
    pub fn total_bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.bytes).sum()
    }

    pub fn n_calls(&self) -> usize {
        self.segments.len()
    }

    /// Average granularity in blocks per call (the paper's Fig. 11
    /// metric; ~1 for vLLM, ~20 for FastSwitch on the A10 testbed).
    pub fn avg_granularity(&self) -> f64 {
        if self.segments.is_empty() {
            return 0.0;
        }
        self.segments.iter().map(|s| s.blocks as f64).sum::<f64>()
            / self.segments.len() as f64
    }
}

/// An in-flight asynchronous operation tracked by the swap manager.
#[derive(Clone, Debug)]
pub struct InflightOp {
    pub op: SwapOp,
    /// When the last segment's dispatch completes.
    pub dispatch_done: Ns,
    /// When the last segment's DMA execution completes (the CUDA event
    /// the manager polls).
    pub exec_done: Ns,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(blocks: u32, bytes: u64) -> Segment {
        Segment {
            gpu_start: 1,
            cpu_start: 0,
            blocks,
            layer: 0,
            bytes,
        }
    }

    #[test]
    fn totals() {
        let op = SwapOp {
            req: 1,
            dir: Direction::Out,
            segments: vec![seg(4, 400), seg(2, 200)],
            blocks: 6,
            gpu_blocks: vec![1, 2, 3, 4, 7, 8],
        };
        assert_eq!(op.total_bytes(), 600);
        assert_eq!(op.n_calls(), 2);
        assert!((op.avg_granularity() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_granularity_zero() {
        let op = SwapOp {
            req: 1,
            dir: Direction::In,
            segments: vec![],
            blocks: 0,
            gpu_blocks: vec![],
        };
        assert_eq!(op.avg_granularity(), 0.0);
    }
}
