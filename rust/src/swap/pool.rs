//! Real worker thread pool for copy dispatch — the Rust analogue of the
//! paper's C++ offload (§3.2 "Overcoming Python GIL Limitation").
//!
//! Used by the real-execution backend: KV block data physically moves
//! between the GPU-pool and CPU-pool buffers on worker threads, off the
//! serving hot path, with completion tracked by event handles (the CUDA
//! event analogue). Safety: the block allocators guarantee every
//! submitted copy touches disjoint regions (each block has exactly one
//! owner; swap sources/targets are never concurrently written — enforced
//! by the swap manager's conflict detection).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// One copy task: `len` f32 elements from `src` to `dst`.
pub struct CopyTask {
    pub src: *const f32,
    pub dst: *mut f32,
    pub len: usize,
}

// Safety: tasks are only constructed over regions proven disjoint by the
// allocator (asserted by callers); the pool itself never aliases them.
unsafe impl Send for CopyTask {}

/// Completion handle (CUDA-event analogue): fires when its batch drains.
#[derive(Clone)]
pub struct CopyEvent {
    remaining: Arc<AtomicUsize>,
}

impl CopyEvent {
    pub fn is_done(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }

    /// Spin-then-yield wait (batches are short; used by sync swap paths
    /// and shutdown).
    pub fn wait(&self) {
        let mut spins = 0u32;
        while !self.is_done() {
            spins += 1;
            if spins > 100 {
                thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }
}

enum Msg {
    Run(CopyTask, Arc<AtomicUsize>),
    Stop,
}

/// Fixed-size worker pool executing copy tasks.
pub struct CopyPool {
    tx: mpsc::Sender<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
    pub n_workers: usize,
}

impl CopyPool {
    pub fn new(n_workers: usize) -> Self {
        let n_workers = n_workers.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n_workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Msg::Run(task, remaining)) => {
                            // The memcpy itself — the "execution stage".
                            unsafe {
                                std::ptr::copy_nonoverlapping(task.src, task.dst, task.len);
                            }
                            remaining.fetch_sub(1, Ordering::AcqRel);
                        }
                        Ok(Msg::Stop) | Err(_) => break,
                    }
                })
            })
            .collect();
        CopyPool {
            tx,
            workers,
            n_workers,
        }
    }

    /// Dispatch a batch of copies; returns the completion event.
    /// Dispatch cost on the caller is one channel send per task — the
    /// cheap "thread-pool dispatch" the paper contrasts with the GIL path.
    pub fn submit(&self, tasks: Vec<CopyTask>) -> CopyEvent {
        let remaining = Arc::new(AtomicUsize::new(tasks.len()));
        for t in tasks {
            self.tx
                .send(Msg::Run(t, Arc::clone(&remaining)))
                .expect("pool alive");
        }
        CopyEvent { remaining }
    }

    /// Execute a batch synchronously on the caller thread (the GIL-path
    /// analogue, used by the baseline config in real mode).
    pub fn run_inline(tasks: Vec<CopyTask>) {
        for t in tasks {
            unsafe {
                std::ptr::copy_nonoverlapping(t.src, t.dst, t.len);
            }
        }
    }
}

impl Drop for CopyPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tasks_between(src: &[f32], dst: &mut [f32], chunks: usize) -> Vec<CopyTask> {
        let n = src.len() / chunks;
        (0..chunks)
            .map(|i| CopyTask {
                src: src[i * n..].as_ptr(),
                dst: dst[i * n..].as_mut_ptr(),
                len: n,
            })
            .collect()
    }

    #[test]
    fn copies_all_chunks() {
        let src: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let mut dst = vec![0.0f32; 4096];
        let pool = CopyPool::new(4);
        let ev = pool.submit(tasks_between(&src, &mut dst, 8));
        ev.wait();
        assert_eq!(src, dst);
    }

    #[test]
    fn event_not_done_before_wait() {
        let src = vec![1.0f32; 1 << 20];
        let mut dst = vec![0.0f32; 1 << 20];
        let pool = CopyPool::new(2);
        let ev = pool.submit(tasks_between(&src, &mut dst, 16));
        ev.wait();
        assert!(ev.is_done());
        assert_eq!(dst[0], 1.0);
        assert_eq!(dst[(1 << 20) - 1], 1.0);
    }

    #[test]
    fn inline_path_matches() {
        let src: Vec<f32> = (0..1024).map(|i| (i * 3) as f32).collect();
        let mut dst = vec![0.0f32; 1024];
        CopyPool::run_inline(tasks_between(&src, &mut dst, 4));
        assert_eq!(src, dst);
    }

    #[test]
    fn multiple_batches_independent_events() {
        let src = vec![2.0f32; 8192];
        let mut dst1 = vec![0.0f32; 8192];
        let mut dst2 = vec![0.0f32; 8192];
        let pool = CopyPool::new(3);
        let e1 = pool.submit(tasks_between(&src, &mut dst1, 4));
        let e2 = pool.submit(tasks_between(&src, &mut dst2, 4));
        e1.wait();
        e2.wait();
        assert!(dst1.iter().all(|&x| x == 2.0));
        assert!(dst2.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn empty_batch_immediately_done() {
        let pool = CopyPool::new(1);
        let ev = pool.submit(vec![]);
        assert!(ev.is_done());
    }
}
