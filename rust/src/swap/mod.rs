//! Context-switch machinery: swap operations, segment coalescing, and the
//! Multithreading Swap Manager (paper §3.2).
//!
//! - [`op`] — swap operations and their DMA segment decomposition.
//! - [`engine`] — builds segments from block tables + CPU slot maps,
//!   honoring the allocator's granularity (the paper's Fig. 3 contrast).
//! - [`manager`] — Algorithm 1: adaptive async/sync swap-in, event pool,
//!   conflict detection, ordered dispatch.
//! - [`pool`] — a real worker thread pool used by the real-execution
//!   backend for genuinely parallel copy dispatch (the C++-offload
//!   analogue).

pub mod engine;
pub mod manager;
pub mod op;
pub mod pool;

pub use engine::SegmentBuilder;
pub use manager::{SwapManager, SwapStats};
pub use op::{Segment, SwapOp};
