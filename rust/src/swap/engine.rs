//! Segment builder: turns "move these logical blocks of this request"
//! into the DMA call list, honoring the allocator's granularity.
//!
//! This is where the paper's Fig. 3 contrast materializes:
//! - `FixedBlock` (vLLM): one call per block per layer — for LLaMA-8B a
//!   1 000-token preemption is 63 blocks × 32 layers ≈ 2 000 dispatches
//!   of 128 KB each, dispatch-bound.
//! - `BlockGroup` (FastSwitch): calls coalesce over spans that are
//!   contiguous on BOTH ends (GPU block run AND CPU slot run) — tens of
//!   blocks per call, few calls per layer.

use super::op::{Segment, SwapOp};
use crate::config::{Granularity, ModelSpec};
use crate::memory::{BlockId, RequestId, SlotId};
use crate::sim::link::Direction;

/// A (logical, gpu block, cpu slot) mapping entry for one moved block.
#[derive(Clone, Copy, Debug)]
pub struct BlockMove {
    pub logical: u32,
    pub gpu: BlockId,
    pub cpu: SlotId,
}

#[derive(Clone, Debug)]
pub struct SegmentBuilder {
    model: ModelSpec,
    granularity: Granularity,
}

impl SegmentBuilder {
    pub fn new(model: ModelSpec, granularity: Granularity) -> Self {
        SegmentBuilder { model, granularity }
    }

    /// Build the swap op for `moves` (sorted by logical index).
    pub fn build(&self, req: RequestId, dir: Direction, moves: &[BlockMove]) -> SwapOp {
        let per_layer = self.model.block_bytes_per_layer();
        let n_layers = self.model.n_layers as u32;
        let mut spans: Vec<(BlockId, SlotId, u32)> = Vec::new();
        match self.granularity {
            Granularity::FixedBlock => {
                // vLLM: no coalescing — one span per block.
                for m in moves {
                    spans.push((m.gpu, m.cpu, 1));
                }
            }
            Granularity::BlockGroup { .. } => {
                // Coalesce spans contiguous on both GPU and CPU ends.
                let mut i = 0;
                while i < moves.len() {
                    let (g0, c0) = (moves[i].gpu, moves[i].cpu);
                    let mut len = 1u32;
                    while i + (len as usize) < moves.len() {
                        let m = moves[i + len as usize];
                        if m.gpu == g0 + len && m.cpu == c0 + len {
                            len += 1;
                        } else {
                            break;
                        }
                    }
                    spans.push((g0, c0, len));
                    i += len as usize;
                }
            }
        }

        let mut segments = Vec::with_capacity(spans.len() * n_layers as usize);
        for layer in 0..n_layers {
            for &(gpu_start, cpu_start, blocks) in &spans {
                segments.push(Segment {
                    gpu_start,
                    cpu_start,
                    blocks,
                    layer,
                    bytes: per_layer * blocks as u64,
                });
            }
        }
        SwapOp {
            req,
            dir,
            segments,
            blocks: moves.len() as u32,
            gpu_blocks: moves.iter().map(|m| m.gpu).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moves_contig(n: u32) -> Vec<BlockMove> {
        (0..n)
            .map(|i| BlockMove {
                logical: i,
                gpu: 10 + i,
                cpu: 100 + i,
            })
            .collect()
    }

    fn spec() -> ModelSpec {
        ModelSpec::llama8b()
    }

    #[test]
    fn fixed_block_one_call_per_block_per_layer() {
        let b = SegmentBuilder::new(spec(), Granularity::FixedBlock);
        let op = b.build(1, Direction::Out, &moves_contig(10));
        assert_eq!(op.n_calls(), 10 * 32);
        assert_eq!(op.segments[0].bytes, 128 * 1024); // the paper's 128 KB
        assert!((op.avg_granularity() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn block_group_coalesces_contiguous() {
        let b = SegmentBuilder::new(
            spec(),
            Granularity::BlockGroup { init_group_blocks: 60 },
        );
        let op = b.build(1, Direction::Out, &moves_contig(10));
        assert_eq!(op.n_calls(), 32); // one span × 32 layers
        assert_eq!(op.segments[0].bytes, 10 * 128 * 1024);
        assert!((op.avg_granularity() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn coalescing_breaks_on_gpu_discontinuity() {
        let b = SegmentBuilder::new(
            spec(),
            Granularity::BlockGroup { init_group_blocks: 60 },
        );
        let mut m = moves_contig(6);
        m[3].gpu += 5; // gap on GPU side
        m[4].gpu += 5;
        m[5].gpu += 5;
        let op = b.build(1, Direction::Out, &m);
        assert_eq!(op.n_calls(), 2 * 32);
    }

    #[test]
    fn coalescing_breaks_on_cpu_discontinuity() {
        let b = SegmentBuilder::new(
            spec(),
            Granularity::BlockGroup { init_group_blocks: 60 },
        );
        let mut m = moves_contig(4);
        m[2].cpu += 9;
        m[3].cpu += 9;
        let op = b.build(1, Direction::In, &m);
        assert_eq!(op.n_calls(), 2 * 32);
    }

    #[test]
    fn same_bytes_both_granularities() {
        let fixed = SegmentBuilder::new(spec(), Granularity::FixedBlock);
        let group = SegmentBuilder::new(
            spec(),
            Granularity::BlockGroup { init_group_blocks: 60 },
        );
        let m = moves_contig(17);
        assert_eq!(
            fixed.build(1, Direction::Out, &m).total_bytes(),
            group.build(1, Direction::Out, &m).total_bytes()
        );
    }

    #[test]
    fn empty_moves() {
        let b = SegmentBuilder::new(spec(), Granularity::FixedBlock);
        let op = b.build(1, Direction::Out, &[]);
        assert_eq!(op.n_calls(), 0);
        assert_eq!(op.total_bytes(), 0);
    }
}
