//! CPU swap space: range-allocated slots holding KV-cache copies, with
//! priority-based *contamination* (paper §3.3, Challenge #3).
//!
//! Every request may hold a *copy map*: logical block index → CPU slot.
//! A copy is either **required** (the request is swapped out; the CPU copy
//! is the only version) or a **backup** (the request's KV also lives on
//! GPU; the copy exists so a future swap-out transfers only the delta).
//! When space runs out, backups of lower-priority requests are reclaimed
//! ("contaminated"), always from the *tail* of the victim's copy — the
//! prefix stays valid, preserving prefix reuse for the victim's next turn.
//!
//! Slots are range-allocated (best-fit with coalescing on free) so a
//! coalesced GPU block run can land in a contiguous CPU region and remain
//! one DMA segment; `add_copies` also honors §3.3's *preallocation*: new
//! copies try to extend the request's existing slot run so successive
//! turns stay adjacent.

use std::collections::{BTreeMap, HashMap};

use super::{RequestId, SlotId};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CopyEntry {
    /// Logical block index within the request's sequence.
    pub logical: u32,
    pub slot: SlotId,
}

#[derive(Clone, Debug, Default)]
pub struct RequestCopies {
    /// Valid copies, sorted by logical index.
    pub entries: Vec<CopyEntry>,
    pub priority: i64,
    /// True while the request's only KV version is this CPU copy.
    pub required: bool,
    /// Number of tail entries contaminated over this copy's lifetime
    /// (metrics for Fig. 13).
    pub contaminated: u64,
}

#[derive(Clone, Debug)]
pub struct CpuSwapSpace {
    capacity: usize,
    /// Free ranges: start -> len, coalesced.
    free: BTreeMap<SlotId, u32>,
    copies: HashMap<RequestId, RequestCopies>,
    /// Total contaminations (evicted backup blocks).
    pub total_contaminated: u64,
}

impl CpuSwapSpace {
    pub fn new(capacity: usize) -> Self {
        let mut free = BTreeMap::new();
        if capacity > 0 {
            free.insert(0, capacity as u32);
        }
        CpuSwapSpace {
            capacity,
            free,
            copies: HashMap::new(),
            total_contaminated: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn free_slots(&self) -> usize {
        self.free.values().map(|&l| l as usize).sum()
    }

    pub fn used_slots(&self) -> usize {
        self.capacity - self.free_slots()
    }

    pub fn copies_of(&self, req: RequestId) -> Option<&RequestCopies> {
        self.copies.get(&req)
    }

    /// Logical indices with a valid CPU copy, sorted.
    pub fn valid_logical(&self, req: RequestId) -> Vec<u32> {
        self.copies
            .get(&req)
            .map(|c| c.entries.iter().map(|e| e.logical).collect())
            .unwrap_or_default()
    }

    pub fn set_priority(&mut self, req: RequestId, priority: i64) {
        if let Some(c) = self.copies.get_mut(&req) {
            c.priority = priority;
        }
    }

    pub fn set_required(&mut self, req: RequestId, required: bool) {
        if let Some(c) = self.copies.get_mut(&req) {
            c.required = required;
        }
    }

    // ---- range allocation ------------------------------------------------

    fn take_range(&mut self, start: SlotId, len: u32) {
        let (&rs, &rl) = self
            .free
            .range(..=start)
            .next_back()
            .expect("range not free");
        assert!(start >= rs && start + len <= rs + rl, "range not free");
        self.free.remove(&rs);
        if start > rs {
            self.free.insert(rs, start - rs);
        }
        if rs + rl > start + len {
            self.free.insert(start + len, rs + rl - (start + len));
        }
    }

    fn release_range(&mut self, start: SlotId, len: u32) {
        if len == 0 {
            return;
        }
        // Coalesce with neighbors.
        let mut start = start;
        let mut len = len;
        if let Some((&ps, &pl)) = self.free.range(..start).next_back() {
            assert!(ps + pl <= start, "double free");
            if ps + pl == start {
                self.free.remove(&ps);
                start = ps;
                len += pl;
            }
        }
        if let Some((&ns, &nl)) = self.free.range(start + len..).next() {
            if start + len == ns {
                self.free.remove(&ns);
                len += nl;
            }
        }
        self.free.insert(start, len);
    }

    /// Best-fit allocation of one run of exactly `len` slots; prefers the
    /// run starting at `prefer` if it is free (§3.3 preallocation
    /// adjacency). Returns the start, or None.
    fn alloc_run(&mut self, len: u32, prefer: Option<SlotId>) -> Option<SlotId> {
        if len == 0 {
            return None;
        }
        if let Some(p) = prefer {
            if let Some((&rs, &rl)) = self.free.range(..=p).next_back() {
                if p >= rs && p + len <= rs + rl {
                    self.take_range(p, len);
                    return Some(p);
                }
            }
        }
        // Best fit: smallest free range that holds `len`.
        let cand = self
            .free
            .iter()
            .filter(|(_, &l)| l >= len)
            .min_by_key(|(_, &l)| l)
            .map(|(&s, _)| s)?;
        self.take_range(cand, len);
        Some(cand)
    }

    // ---- copy management ---------------------------------------------------

    /// Add copies for `logicals` (sorted, no duplicates with existing
    /// entries). Slots are allocated contiguously where possible, adjacent
    /// to the request's last existing slot. Returns the new (logical, slot)
    /// pairs, or None if free space is insufficient (caller should
    /// `contaminate_backups` and retry, or give up).
    pub fn add_copies(
        &mut self,
        req: RequestId,
        logicals: &[u32],
        priority: i64,
    ) -> Option<Vec<CopyEntry>> {
        if logicals.is_empty() {
            return Some(vec![]);
        }
        if self.free_slots() < logicals.len() {
            return None;
        }
        let prefer = self
            .copies
            .get(&req)
            .and_then(|c| c.entries.last())
            .map(|e| e.slot + 1);

        let mut out = Vec::with_capacity(logicals.len());
        let mut remaining = logicals;
        let mut prefer = prefer;
        while !remaining.is_empty() {
            // Try to place the whole remainder as one run; if no single
            // free range fits, take the largest range (the copy spans
            // multiple runs).
            let want = remaining.len() as u32;
            let (start, n) = match self.alloc_run(want, prefer) {
                Some(s) => (s, want),
                None => {
                    let (&s, &l) = self
                        .free
                        .iter()
                        .max_by_key(|(_, &l)| l)
                        .expect("free_slots >= len but no free range");
                    let take = l.min(want);
                    self.take_range(s, take);
                    (s, take)
                }
            };
            let (head, tail) = remaining.split_at(n as usize);
            for (i, &logical) in head.iter().enumerate() {
                out.push(CopyEntry {
                    logical,
                    slot: start + i as u32,
                });
            }
            remaining = tail;
            prefer = Some(start + n);
        }

        let c = self.copies.entry(req).or_default();
        c.priority = priority;
        c.entries.extend(out.iter().copied());
        c.entries.sort_by_key(|e| e.logical);
        Some(out)
    }

    fn slot_in_free(&self, slot: SlotId) -> bool {
        self.free
            .range(..=slot)
            .next_back()
            .map(|(&s, &l)| slot >= s && slot < s + l)
            .unwrap_or(false)
    }

    /// Contaminate (reclaim) backup copies until `needed` slots are free,
    /// starting with the lowest-priority victims (strictly below
    /// `requesting_priority`), always from the tail of each victim's copy.
    /// Returns the number of slots actually freed.
    pub fn contaminate_backups(&mut self, needed: usize, requesting_priority: i64) -> usize {
        let mut freed = 0usize;
        while self.free_slots() < needed {
            // Lowest-priority victim with a non-required, non-empty copy
            // (request-id tiebreak keeps runs deterministic).
            let victim = self
                .copies
                .iter()
                .filter(|(_, c)| !c.required && !c.entries.is_empty())
                .filter(|(_, c)| c.priority < requesting_priority)
                .min_by_key(|(&r, c)| (c.priority, r))
                .map(|(&r, _)| r);
            let Some(victim) = victim else { break };
            let c = self.copies.get_mut(&victim).unwrap();
            let e = c.entries.pop().unwrap();
            c.contaminated += 1;
            self.total_contaminated += 1;
            self.release_range(e.slot, 1);
            freed += 1;
        }
        freed
    }

    /// Drop all copies of `req` (conversation finished or copy abandoned).
    pub fn drop_request(&mut self, req: RequestId) {
        if let Some(c) = self.copies.remove(&req) {
            for e in c.entries {
                self.release_range(e.slot, 1);
            }
        }
    }

    /// Invariant check: no slot is both free and referenced; totals add up.
    pub fn check_invariants(&self) {
        let mut seen = std::collections::HashSet::new();
        for c in self.copies.values() {
            for e in &c.entries {
                assert!(seen.insert(e.slot), "slot {} referenced twice", e.slot);
                assert!(!self.slot_in_free(e.slot), "slot {} free+used", e.slot);
                assert!((e.slot as usize) < self.capacity);
            }
        }
        assert_eq!(self.free_slots() + seen.len(), self.capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_drop_roundtrip() {
        let mut s = CpuSwapSpace::new(16);
        let added = s.add_copies(1, &[0, 1, 2], 5).unwrap();
        assert_eq!(added.len(), 3);
        // Contiguous run.
        assert_eq!(added[1].slot, added[0].slot + 1);
        assert_eq!(s.used_slots(), 3);
        s.drop_request(1);
        assert_eq!(s.used_slots(), 0);
        s.check_invariants();
    }

    #[test]
    fn extension_stays_adjacent() {
        let mut s = CpuSwapSpace::new(16);
        let a = s.add_copies(1, &[0, 1], 5).unwrap();
        let b = s.add_copies(1, &[2, 3], 5).unwrap();
        assert_eq!(b[0].slot, a[1].slot + 1, "next turn's copies adjacent");
        s.check_invariants();
    }

    #[test]
    fn insufficient_space_returns_none() {
        let mut s = CpuSwapSpace::new(4);
        assert!(s.add_copies(1, &[0, 1, 2, 3], 5).is_some());
        assert!(s.add_copies(2, &[0], 5).is_none());
    }

    #[test]
    fn contamination_evicts_lowest_priority_tail_first() {
        let mut s = CpuSwapSpace::new(8);
        s.add_copies(1, &[0, 1, 2], 1).unwrap(); // low priority backup
        s.add_copies(2, &[0, 1, 2], 9).unwrap(); // high priority backup
        assert_eq!(s.free_slots(), 2);
        let freed = s.contaminate_backups(4, 10);
        assert_eq!(freed, 2);
        // Victim is request 1 (lowest priority), tail-first.
        assert_eq!(s.valid_logical(1), vec![0]);
        assert_eq!(s.valid_logical(2), vec![0, 1, 2]);
        assert_eq!(s.total_contaminated, 2);
        s.check_invariants();
    }

    #[test]
    fn required_copies_never_contaminated() {
        let mut s = CpuSwapSpace::new(4);
        s.add_copies(1, &[0, 1, 2, 3], 1).unwrap();
        s.set_required(1, true);
        let freed = s.contaminate_backups(1, 100);
        assert_eq!(freed, 0);
        assert_eq!(s.valid_logical(1).len(), 4);
    }

    #[test]
    fn equal_priority_not_contaminated() {
        let mut s = CpuSwapSpace::new(4);
        s.add_copies(1, &[0, 1, 2, 3], 5).unwrap();
        assert_eq!(s.contaminate_backups(1, 5), 0, "only strictly lower prio");
    }

    #[test]
    fn fragmented_allocation_spans_runs() {
        let mut s = CpuSwapSpace::new(8);
        s.add_copies(1, &[0, 1, 2], 1).unwrap(); // slots 0..3
        s.add_copies(2, &[0], 1).unwrap(); // slot 3
        s.drop_request(1); // free 0..3
        // 4 slots free: 0..3 and 4..8 → a 5-block copy must span two runs.
        let added = s.add_copies(3, &[0, 1, 2, 3, 4], 1).unwrap();
        assert_eq!(added.len(), 5);
        s.check_invariants();
    }

    #[test]
    fn free_coalescing() {
        let mut s = CpuSwapSpace::new(8);
        s.add_copies(1, &[0, 1], 1).unwrap();
        s.add_copies(2, &[0, 1], 1).unwrap();
        s.add_copies(3, &[0, 1], 1).unwrap();
        s.drop_request(1);
        s.drop_request(3);
        s.drop_request(2);
        // All free again as one range.
        assert_eq!(s.free.len(), 1);
        assert_eq!(s.free_slots(), 8);
    }
}
