//! GPU KV block space: ownership + free accounting over block ids
//! `1..n_blocks` (block 0 is the reserved null block).

use super::{BlockId, RequestId, NULL_BLOCK};

#[derive(Clone, Debug)]
pub struct GpuBlockSpace {
    /// owner[b] — `None` if free. Index 0 unused.
    owner: Vec<Option<RequestId>>,
    free: usize,
}

impl GpuBlockSpace {
    /// `n_blocks` *usable* blocks (ids 1..=n_blocks).
    pub fn new(n_blocks: usize) -> Self {
        GpuBlockSpace {
            owner: vec![None; n_blocks + 1],
            free: n_blocks,
        }
    }

    pub fn capacity(&self) -> usize {
        self.owner.len() - 1
    }

    pub fn free_blocks(&self) -> usize {
        self.free
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity() - self.free
    }

    pub fn owner_of(&self, b: BlockId) -> Option<RequestId> {
        self.owner.get(b as usize).copied().flatten()
    }

    pub fn is_free(&self, b: BlockId) -> bool {
        b != NULL_BLOCK && (b as usize) < self.owner.len() && self.owner[b as usize].is_none()
    }

    /// Mark `b` owned by `req`. Panics on double-allocation (an allocator
    /// bug — the property tests rely on this tripping).
    pub fn claim(&mut self, b: BlockId, req: RequestId) {
        assert_ne!(b, NULL_BLOCK, "null block is not allocatable");
        let slot = &mut self.owner[b as usize];
        assert!(slot.is_none(), "double allocation of block {b}");
        *slot = Some(req);
        self.free -= 1;
    }

    /// Release `b`. Panics if not owned by `req` (ownership violation).
    pub fn reclaim(&mut self, b: BlockId, req: RequestId) {
        let slot = &mut self.owner[b as usize];
        assert_eq!(*slot, Some(req), "block {b} not owned by request {req}");
        *slot = None;
        self.free += 1;
    }

    /// Integrity check: free-count consistent with the ownership map.
    pub fn check_invariants(&self) {
        let counted = self.owner[1..].iter().filter(|o| o.is_none()).count();
        assert_eq!(counted, self.free, "free-count drift");
        assert!(self.owner[0].is_none(), "null block must stay unowned");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claim_reclaim_roundtrip() {
        let mut s = GpuBlockSpace::new(8);
        assert_eq!(s.free_blocks(), 8);
        s.claim(3, 7);
        assert_eq!(s.owner_of(3), Some(7));
        assert_eq!(s.free_blocks(), 7);
        s.reclaim(3, 7);
        assert_eq!(s.free_blocks(), 8);
        s.check_invariants();
    }

    #[test]
    #[should_panic(expected = "double allocation")]
    fn double_claim_panics() {
        let mut s = GpuBlockSpace::new(4);
        s.claim(1, 1);
        s.claim(1, 2);
    }

    #[test]
    #[should_panic(expected = "not owned")]
    fn reclaim_wrong_owner_panics() {
        let mut s = GpuBlockSpace::new(4);
        s.claim(1, 1);
        s.reclaim(1, 2);
    }

    #[test]
    #[should_panic(expected = "null block")]
    fn null_block_unallocatable() {
        let mut s = GpuBlockSpace::new(4);
        s.claim(NULL_BLOCK, 1);
    }
}
