//! KV-cache memory substrates: the GPU block space and the CPU swap space.
//!
//! These are *bookkeeping* layers shared by both allocators
//! ([`crate::block::fixed`], [`crate::block::buddy`]) and the KV Cache
//! Reuse Mechanism ([`crate::block::reuse`]): ownership, free accounting,
//! and integrity invariants. In real-execution mode the same ids index
//! physical KV storage held by [`crate::runtime`].

pub mod cpu;
pub mod gpu;

pub use cpu::CpuSwapSpace;
pub use gpu::GpuBlockSpace;

/// Physical GPU block id. Block 0 is reserved (the null block padded
/// batch slots scatter into — see python/compile/model.py) and is never
/// allocated.
pub type BlockId = u32;

/// CPU swap-slot id.
pub type SlotId = u32;

/// Request identifier (assigned by the workload/frontend).
pub type RequestId = u64;

pub const NULL_BLOCK: BlockId = 0;
