//! Config-file loading: a TOML-subset parser (offline build — no `toml`
//! crate) covering the needs of launcher configs: `[section]` headers,
//! `key = value` with string / number / bool values, comments.
//!
//! Example (`examples/configs/fastswitch.toml`):
//! ```toml
//! [preset]
//! name = "llama8b_a10"
//!
//! [engine]
//! policy = "fastswitch"        # vllm | vllm+dbg | vllm+dbg+reuse | fastswitch
//! priority_update_freq = 0.04
//! max_batch = 32
//!
//! [workload]
//! conversations = 1000
//! request_rate = 1.0
//! pattern = "markov"           # markov | random
//! seed = 42
//! ```

use std::collections::HashMap;

use crate::config::{EngineConfig, Preset};

#[derive(Clone, Debug, Default)]
pub struct ConfigFile {
    /// section -> key -> raw value
    sections: HashMap<String, HashMap<String, String>>,
}

#[derive(Debug)]
pub enum ConfigError {
    Io(std::io::Error),
    Parse(usize, String),
    UnknownPreset(String),
    UnknownPolicy(String),
    UnknownFairnessPolicy(String),
    UnknownPrefillMode(String),
    UnknownPlacement(String),
    UnknownPreemptionPolicy(String),
    UnknownTelemetryMode(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(e) => write!(f, "io: {e}"),
            ConfigError::Parse(line, msg) => write!(f, "parse error at line {line}: {msg}"),
            ConfigError::UnknownPreset(p) => write!(f, "unknown preset {p:?}"),
            ConfigError::UnknownPolicy(p) => write!(f, "unknown engine policy {p:?}"),
            ConfigError::UnknownFairnessPolicy(p) => {
                write!(f, "unknown fairness policy {p:?} (trace|vtc|slo)")
            }
            ConfigError::UnknownPrefillMode(p) => {
                write!(f, "unknown prefill mode {p:?} (chunked|monolithic)")
            }
            ConfigError::UnknownPlacement(p) => {
                write!(
                    f,
                    "unknown placement policy {p:?} \
                     (round_robin|least_loaded|kv_affinity|prefix_aware)"
                )
            }
            ConfigError::UnknownPreemptionPolicy(p) => {
                write!(
                    f,
                    "unknown preemption policy {p:?} (swap_all|cost_aware|partial_tail)"
                )
            }
            ConfigError::UnknownTelemetryMode(m) => {
                write!(f, "unknown telemetry mode {m:?} (exact|reservoir)")
            }
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut out = ConfigFile::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| ConfigError::Parse(lineno + 1, "unclosed [section".into()))?;
                section = name.trim().to_string();
                out.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                let val = unquote(v.trim()).to_string();
                out.sections
                    .entry(section.clone())
                    .or_default()
                    .insert(key, val);
            } else {
                return Err(ConfigError::Parse(
                    lineno + 1,
                    format!("expected `key = value`, got {line:?}"),
                ));
            }
        }
        Ok(out)
    }

    pub fn load(path: &str) -> Result<Self, ConfigError> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Option<u64> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            "true" | "yes" | "1" => Some(true),
            "false" | "no" | "0" => Some(false),
            _ => None,
        }
    }

    /// Resolve the testbed preset named in `[preset] name`.
    pub fn preset(&self) -> Result<Preset, ConfigError> {
        let name = self.get("preset", "name").unwrap_or("llama8b_a10");
        Preset::by_name(name).ok_or_else(|| ConfigError::UnknownPreset(name.into()))
    }

    /// Build the engine config from `[engine]`, starting from the named
    /// policy and applying overrides.
    pub fn engine(&self) -> Result<EngineConfig, ConfigError> {
        let policy = self.get("engine", "policy").unwrap_or("fastswitch");
        let mut cfg = match policy {
            "vllm" => EngineConfig::vllm_baseline(),
            "vllm+dbg" => EngineConfig::with_dbg(),
            "vllm+dbg+reuse" => EngineConfig::with_dbg_reuse(),
            "fastswitch" => EngineConfig::fastswitch(),
            other => return Err(ConfigError::UnknownPolicy(other.into())),
        };
        if let Some(f) = self.get_f64("engine", "priority_update_freq") {
            cfg.scheduler.priority_update_freq = f;
        }
        if let Some(b) = self.get_usize("engine", "max_batch") {
            cfg.scheduler.max_batch = b;
        }
        if let Some(c) = self.get_usize("engine", "prefill_chunk") {
            cfg.scheduler.prefill_chunk = c;
        }
        if let Some(r) = self.get_bool("engine", "reuse") {
            cfg.reuse = r;
        }
        // `[scheduler]` — the chunked-prefill token-budget knobs.
        if let Some(c) = self.get_usize("scheduler", "chunk_tokens") {
            cfg.scheduler.prefill_chunk = c;
        }
        if let Some(b) = self.get_usize("scheduler", "max_tokens_per_iter") {
            cfg.scheduler.max_tokens_per_iter = b;
        }
        if let Some(m) = self.get("scheduler", "prefill_mode") {
            cfg.scheduler.prefill_mode = crate::config::PrefillMode::by_name(m)
                .ok_or_else(|| ConfigError::UnknownPrefillMode(m.into()))?;
        }
        if let Some(i) = self.get_bool("scheduler", "incremental") {
            cfg.scheduler.incremental = i;
        }
        // `[preemption]` — the pluggable context-switch eviction policy.
        if let Some(p) = self.get("preemption", "policy") {
            cfg.preemption.policy = crate::config::PreemptionPolicyKind::by_name(p)
                .ok_or_else(|| ConfigError::UnknownPreemptionPolicy(p.into()))?;
        }
        // `[prefetch]` — the lookahead swap-in prefetcher.
        if let Some(d) = self.get_u64("prefetch", "depth") {
            cfg.prefetch.depth = d;
        }
        if let Some(b) = self.get_f64("prefetch", "io_budget") {
            cfg.prefetch.io_budget = b.clamp(0.0, 1.0);
        }
        // `[prefix]` — the cross-request global prefix cache.
        if let Some(e) = self.get_bool("prefix", "enabled") {
            cfg.prefix.enabled = e;
        }
        // `[obs]` — observability (tracing / profiling / telemetry).
        if let Some(t) = self.get_bool("obs", "trace") {
            cfg.obs.trace = t;
        }
        if let Some(p) = self.get_bool("obs", "profile") {
            cfg.obs.profile = p;
        }
        if let Some(m) = self.get("obs", "telemetry") {
            cfg.obs.telemetry = crate::obs::TelemetryMode::by_name(m)
                .ok_or_else(|| ConfigError::UnknownTelemetryMode(m.into()))?;
        }
        if let Some(p) = self.get("fairness", "policy") {
            cfg.fairness.policy = crate::fairness::PolicyKind::by_name(p)
                .ok_or_else(|| ConfigError::UnknownFairnessPolicy(p.into()))?;
        }
        if let Some(w) = self.get_f64("fairness", "prefill_weight") {
            cfg.fairness.vtc.prefill_weight = w;
        }
        if let Some(w) = self.get_f64("fairness", "decode_weight") {
            cfg.fairness.vtc.decode_weight = w;
        }
        if let Some(g) = self.get_f64("fairness", "max_service_gap") {
            cfg.fairness.vtc.max_service_gap = g;
        }
        if let Some(t) = self.get_f64("fairness", "ttft_target_s") {
            cfg.fairness.slo.ttft_target_s = t;
        }
        if let Some(t) = self.get_f64("fairness", "tbt_target_s") {
            cfg.fairness.slo.tbt_target_s = t;
        }
        Ok(cfg)
    }

    /// Build the cluster front-end config from `[cluster]` (defaults:
    /// one replica, `kv_affinity` placement).
    pub fn cluster(&self) -> Result<crate::cluster::ClusterConfig, ConfigError> {
        use crate::cluster::{ClusterConfig, PlacementKind};
        let mut c = ClusterConfig::default();
        if let Some(n) = self.get_usize("cluster", "replicas") {
            c.replicas = n.max(1);
        }
        if let Some(p) = self.get("cluster", "placement") {
            c.placement = PlacementKind::by_name(p)
                .ok_or_else(|| ConfigError::UnknownPlacement(p.into()))?;
        }
        if let Some(s) = self.get_f64("cluster", "spill_threshold") {
            match c.placement {
                PlacementKind::KvAffinity { .. } => {
                    c.placement = PlacementKind::KvAffinity { spill_threshold: s };
                }
                PlacementKind::PrefixAware { .. } => {
                    c.placement = PlacementKind::PrefixAware { spill_threshold: s };
                }
                _ => {}
            }
        }
        if let Some(p) = self.get_bool("cluster", "parallel") {
            c.parallel = p;
        }
        Ok(c)
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quotes.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> &str {
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DispatchMode, SwapMode};

    const SAMPLE: &str = r#"
# comment
[preset]
name = "llama8b_a10"

[engine]
policy = "fastswitch"
priority_update_freq = 0.04   # paper LLaMA-8B setting
max_batch = 16

[workload]
conversations = 1000
pattern = "markov"
"#;

    #[test]
    fn parse_sections_and_values() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(c.get("preset", "name"), Some("llama8b_a10"));
        assert_eq!(c.get_f64("engine", "priority_update_freq"), Some(0.04));
        assert_eq!(c.get_usize("workload", "conversations"), Some(1000));
        assert_eq!(c.get("workload", "pattern"), Some("markov"));
    }

    #[test]
    fn engine_policy_with_overrides() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        let e = c.engine().unwrap();
        assert_eq!(e.label, "fastswitch");
        assert_eq!(e.scheduler.priority_update_freq, 0.04);
        assert_eq!(e.scheduler.max_batch, 16);
        assert!(matches!(e.dispatch, DispatchMode::ThreadPool { .. }));
        assert_eq!(e.swap_mode, SwapMode::Adaptive);
    }

    #[test]
    fn preset_resolution() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(c.preset().unwrap().model.name, "llama-8b");
    }

    #[test]
    fn bad_policy_rejected() {
        let c = ConfigFile::parse("[engine]\npolicy = \"nope\"").unwrap();
        assert!(matches!(c.engine(), Err(ConfigError::UnknownPolicy(_))));
    }

    #[test]
    fn fairness_section_selects_online_policy() {
        use crate::fairness::PolicyKind;
        let c = ConfigFile::parse(
            "[fairness]\npolicy = \"vtc\"\ndecode_weight = 3.5\nmax_service_gap = 500",
        )
        .unwrap();
        let e = c.engine().unwrap();
        assert_eq!(e.fairness.policy, PolicyKind::Vtc);
        assert_eq!(e.fairness.vtc.decode_weight, 3.5);
        assert_eq!(e.fairness.vtc.max_service_gap, 500.0);
    }

    #[test]
    fn scheduler_section_sets_chunking_knobs() {
        use crate::config::PrefillMode;
        let c = ConfigFile::parse(
            "[scheduler]\nchunk_tokens = 128\nmax_tokens_per_iter = 256\n\
             prefill_mode = \"monolithic\"",
        )
        .unwrap();
        let e = c.engine().unwrap();
        assert_eq!(e.scheduler.prefill_chunk, 128);
        assert_eq!(e.scheduler.max_tokens_per_iter, 256);
        assert_eq!(e.scheduler.prefill_mode, PrefillMode::Monolithic);
    }

    #[test]
    fn scheduler_section_selects_the_scheduler_path() {
        let c = ConfigFile::parse("[scheduler]\nincremental = false").unwrap();
        assert!(!c.engine().unwrap().scheduler.incremental);
        let c = ConfigFile::parse("[scheduler]\nchunk_tokens = 128").unwrap();
        assert!(c.engine().unwrap().scheduler.incremental, "default is on");
    }

    #[test]
    fn prefetch_section_sets_depth_and_budget() {
        let c = ConfigFile::parse("[prefetch]\ndepth = 2\nio_budget = 0.4").unwrap();
        let e = c.engine().unwrap();
        assert_eq!(e.prefetch.depth, 2);
        assert_eq!(e.prefetch.io_budget, 0.4);
        // Out-of-range budgets are clamped, absent section keeps the
        // demand-only default.
        let c = ConfigFile::parse("[prefetch]\nio_budget = 7.5").unwrap();
        assert_eq!(c.engine().unwrap().prefetch.io_budget, 1.0);
        let d = ConfigFile::parse("").unwrap().engine().unwrap();
        assert_eq!(d.prefetch.depth, 0);
    }

    #[test]
    fn prefix_section_enables_the_global_prefix_cache() {
        let c = ConfigFile::parse("[prefix]\nenabled = true").unwrap();
        assert!(c.engine().unwrap().prefix.enabled);
        // Absent section keeps the cache off (seed behavior).
        let d = ConfigFile::parse("").unwrap().engine().unwrap();
        assert!(!d.prefix.enabled);
    }

    #[test]
    fn prefix_aware_placement_and_spill_threshold() {
        use crate::cluster::PlacementKind;
        let c = ConfigFile::parse(
            "[cluster]\nplacement = \"prefix_aware\"\nspill_threshold = 0.75",
        )
        .unwrap();
        assert_eq!(
            c.cluster().unwrap().placement,
            PlacementKind::PrefixAware { spill_threshold: 0.75 }
        );
    }

    #[test]
    fn preemption_section_selects_the_eviction_policy() {
        use crate::config::PreemptionPolicyKind;
        let c = ConfigFile::parse("[preemption]\npolicy = \"partial_tail\"").unwrap();
        assert_eq!(
            c.engine().unwrap().preemption.policy,
            PreemptionPolicyKind::PartialTail
        );
        // Absent section keeps the pinned swap_all default.
        let d = ConfigFile::parse("").unwrap().engine().unwrap();
        assert_eq!(d.preemption.policy, PreemptionPolicyKind::SwapAll);
        let bad = ConfigFile::parse("[preemption]\npolicy = \"nope\"").unwrap();
        assert!(matches!(
            bad.engine(),
            Err(ConfigError::UnknownPreemptionPolicy(_))
        ));
    }

    #[test]
    fn obs_section_sets_tracing_and_telemetry() {
        use crate::obs::TelemetryMode;
        let c = ConfigFile::parse(
            "[obs]\ntrace = true\nprofile = yes\ntelemetry = \"reservoir\"",
        )
        .unwrap();
        let e = c.engine().unwrap();
        assert!(e.obs.trace);
        assert!(e.obs.profile);
        assert_eq!(e.obs.telemetry, TelemetryMode::Reservoir);
        // Absent section keeps everything off/exact (seed behavior).
        let d = ConfigFile::parse("").unwrap().engine().unwrap();
        assert!(!d.obs.trace && !d.obs.profile);
        assert_eq!(d.obs.telemetry, TelemetryMode::Exact);
        let bad = ConfigFile::parse("[obs]\ntelemetry = \"nope\"").unwrap();
        assert!(matches!(
            bad.engine(),
            Err(ConfigError::UnknownTelemetryMode(_))
        ));
    }

    #[test]
    fn bad_prefill_mode_rejected() {
        let c = ConfigFile::parse("[scheduler]\nprefill_mode = \"nope\"").unwrap();
        assert!(matches!(c.engine(), Err(ConfigError::UnknownPrefillMode(_))));
    }

    #[test]
    fn bad_fairness_policy_rejected() {
        let c = ConfigFile::parse("[fairness]\npolicy = \"nope\"").unwrap();
        assert!(matches!(
            c.engine(),
            Err(ConfigError::UnknownFairnessPolicy(_))
        ));
    }

    #[test]
    fn cluster_section_configures_the_front_end() {
        use crate::cluster::PlacementKind;
        let c = ConfigFile::parse(
            "[cluster]\nreplicas = 4\nplacement = \"kv_affinity\"\nspill_threshold = 1.25\n\
             parallel = true",
        )
        .unwrap();
        let cl = c.cluster().unwrap();
        assert_eq!(cl.replicas, 4);
        assert_eq!(
            cl.placement,
            PlacementKind::KvAffinity { spill_threshold: 1.25 }
        );
        assert!(cl.parallel);
        // Absent section → single-replica default on the deterministic
        // executor.
        let d = ConfigFile::parse("").unwrap().cluster().unwrap();
        assert_eq!(d.replicas, 1);
        assert!(!d.parallel);
    }

    #[test]
    fn bad_placement_rejected() {
        let c = ConfigFile::parse("[cluster]\nplacement = \"nope\"").unwrap();
        assert!(matches!(c.cluster(), Err(ConfigError::UnknownPlacement(_))));
    }

    #[test]
    fn parse_error_line_number() {
        let err = ConfigFile::parse("[a]\njunk line").unwrap_err();
        match err {
            ConfigError::Parse(2, _) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn comment_inside_string_preserved() {
        let c = ConfigFile::parse("[s]\nk = \"a # b\"").unwrap();
        assert_eq!(c.get("s", "k"), Some("a # b"));
    }
}
