//! Configuration system: model/GPU specs, scheduler + swap policies, and
//! the presets reproducing the paper's two testbeds.
//!
//! All timing constants are calibrated to the paper's own measurements
//! (§2.2): 128 KB per-block-per-layer swap granularity for LLaMA-8B-class
//! models, `cudaMemcpyAsync` dispatch overhead exceeding its ~10 µs
//! execution, dispatch = 90–95 % of total transmission at vLLM granularity,
//! PCIe 4.0 x16 with 32 GB/s per direction and optimal efficiency ≥ 320 KB.

pub mod file;

use crate::fairness::FairnessConfig;
use crate::obs::ObsConfig;

/// Served-model characteristics that drive KV-cache geometry and the
/// roofline inference model. Mirrors the paper's LLaMA-8B / Qwen-32B.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// Bytes per element of the KV cache and weights (2 = fp16/bf16).
    pub dtype_bytes: usize,
    /// Tokens per KV block (vLLM default 16).
    pub block_size: usize,
    /// Total parameter count (drives weight-read time and HBM footprint).
    pub n_params: u64,
}

impl ModelSpec {
    /// K+V bytes of ONE block in ONE layer — the vLLM swap granularity
    /// (paper: 128 KB for LLaMA-8B).
    pub fn block_bytes_per_layer(&self) -> u64 {
        (2 * self.block_size * self.n_kv_heads * self.head_dim * self.dtype_bytes) as u64
    }

    /// K+V bytes of one block across ALL layers (the allocator unit).
    pub fn block_bytes(&self) -> u64 {
        self.block_bytes_per_layer() * self.n_layers as u64
    }

    pub fn weight_bytes(&self) -> u64 {
        self.n_params * self.dtype_bytes as u64
    }

    /// Paper testbed model 1: LLaMA-8B, 32 layers. `n_kv_heads` is set so
    /// the per-block-per-layer K+V segment is exactly the 128 KB swap
    /// granularity the paper measures (§2.2) — i.e. kv_dim = 2048.
    pub fn llama8b() -> Self {
        ModelSpec {
            name: "llama-8b".into(),
            n_layers: 32,
            n_kv_heads: 16,
            head_dim: 128,
            dtype_bytes: 2,
            block_size: 16,
            n_params: 8_000_000_000,
        }
    }

    /// Paper testbed model 2: Qwen-32B, 64 layers, same 128 KB
    /// per-block-per-layer calibration.
    pub fn qwen32b() -> Self {
        ModelSpec {
            name: "qwen-32b".into(),
            n_layers: 64,
            n_kv_heads: 16,
            head_dim: 128,
            dtype_bytes: 2,
            block_size: 16,
            n_params: 32_000_000_000,
        }
    }

    /// Small spec for unit tests (fast, readable numbers).
    pub fn tiny() -> Self {
        ModelSpec {
            name: "tiny".into(),
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 16,
            dtype_bytes: 2,
            block_size: 4,
            n_params: 1_000_000,
        }
    }
}

/// Accelerator + host-link characteristics (simulated hardware).
#[derive(Clone, Debug, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    pub hbm_bytes: u64,
    /// HBM bandwidth, bytes/s (decode is memory-bound).
    pub hbm_bw: f64,
    /// Peak dense fp16/bf16 FLOP/s (prefill is compute-bound).
    pub peak_flops: f64,
    /// PCIe bandwidth per direction, bytes/s (paper: PCIe 4.0 x16 = 32 GB/s).
    pub pcie_bw: f64,
    /// Transfer size at which PCIe efficiency reaches 50 % (models the
    /// per-transfer setup cost; paper: optimal ≥ 320 KB).
    pub pcie_half_size: u64,
    /// Fraction of HBM usable (rest: activations, fragmentation, runtime).
    pub mem_util: f64,
}

impl GpuSpec {
    /// Effective PCIe bandwidth for one transfer of `size` bytes.
    pub fn pcie_eff_bw(&self, size: u64) -> f64 {
        self.pcie_bw * size as f64 / (size + self.pcie_half_size) as f64
    }

    /// Execution time (ns) of one DMA transfer of `size` bytes.
    pub fn pcie_exec_ns(&self, size: u64) -> u64 {
        (size as f64 / self.pcie_eff_bw(size) * 1e9) as u64
    }

    pub fn a10() -> Self {
        GpuSpec {
            name: "a10-24g".into(),
            hbm_bytes: 24 * (1 << 30),
            hbm_bw: 600e9,
            peak_flops: 125e12,
            pcie_bw: 32e9,
            pcie_half_size: 64 * 1024,
            mem_util: 0.92,
        }
    }

    pub fn a100_80g() -> Self {
        GpuSpec {
            name: "a100-80g".into(),
            hbm_bytes: 80 * (1 << 30),
            hbm_bw: 2039e9,
            peak_flops: 312e12,
            pcie_bw: 32e9,
            pcie_half_size: 64 * 1024,
            mem_util: 0.92,
        }
    }

    pub fn tiny() -> Self {
        GpuSpec {
            name: "tiny-gpu".into(),
            hbm_bytes: 1 << 20,
            hbm_bw: 1e9,
            peak_flops: 1e12,
            pcie_bw: 1e9,
            pcie_half_size: 1024,
            mem_util: 1.0,
        }
    }
}

/// KV-cache allocator granularity policy (the paper's core ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// vLLM baseline: individual fixed-size blocks; swap segments are one
    /// block per layer.
    FixedBlock,
    /// FastSwitch §3.1: buddy-style dynamic block groups; swap segments
    /// coalesce contiguous block runs per layer.
    BlockGroup {
        /// Initial group size in blocks (paper default ≈ 60–70 blocks
        /// ≈ 1 000 tokens at block_size 16).
        init_group_blocks: usize,
    },
}

/// How swap copies are dispatched to the (simulated) DMA engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// Python call stack under the GIL: one serialized dispatch lane with
    /// high per-call cost (the baseline the paper measures at 90–95 % of
    /// transmission time).
    Gil,
    /// FastSwitch §3.2: C++ thread-pool offload — parallel lanes, low
    /// per-call cost.
    ThreadPool { workers: usize },
}

/// Swap-in scheduling policy (paper §3.2 "Adaptive Swapping Strategy").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapMode {
    /// Baseline: swap-ins stall the iteration until complete.
    Sync,
    /// Always overlap swap-ins with inference.
    Async,
    /// Profiler-driven choice between the two per iteration.
    Adaptive,
}

/// How prompt prefills are admitted and executed each iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillMode {
    /// Whole-prefill admission: blocks for the entire remaining prompt
    /// are claimed up front and the prompt runs in exclusive iterations
    /// that stall every co-resident decode — the pre-chunking baseline
    /// the `chunked` experiment measures against.
    Monolithic,
    /// Chunked prefill under the per-iteration token budget: decodes
    /// claim the budget first, prefill chunks fill the remainder, held
    /// blocks grow chunk-by-chunk, and partial prefill progress survives
    /// preemption.
    Chunked,
}

impl PrefillMode {
    pub fn by_name(s: &str) -> Option<PrefillMode> {
        match s {
            "monolithic" | "mono" => Some(PrefillMode::Monolithic),
            "chunked" | "chunk" => Some(PrefillMode::Chunked),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PrefillMode::Monolithic => "monolithic",
            PrefillMode::Chunked => "chunked",
        }
    }
}

/// Scheduler parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerConfig {
    /// Max requests decoding in one iteration.
    pub max_batch: usize,
    /// Max sequence length (tokens) per request.
    pub max_seq_len: usize,
    /// Priority-update frequency: updates per iteration (paper: 0.01 =
    /// every 100 iterations).
    pub priority_update_freq: f64,
    /// Prefill chunk size in tokens: the most prompt tokens one request
    /// may prefill per iteration in [`PrefillMode::Chunked`] (CLI
    /// `--chunk-tokens`, config `[scheduler] chunk_tokens`).
    pub prefill_chunk: usize,
    /// Per-iteration token budget shared by decode steps and prefill
    /// chunks. `0` = auto-size from the roofline model at engine init
    /// ([`crate::sim::PerfModel::suggest_token_budget`]): the batch's
    /// decode claims plus the chunk tokens whose compute time matches
    /// one weight read.
    pub max_tokens_per_iter: usize,
    /// Prefill admission/execution mode.
    pub prefill_mode: PrefillMode,
    /// Number of distinct priority levels in the traces.
    pub priority_levels: usize,
    /// Scheduler path: `true` (default) walks the incremental bucketed
    /// candidate index ([`crate::coordinator::queue`], O(admitted +
    /// dirty) per epoch); `false` re-sorts every candidate per
    /// iteration (the reference oracle — CLI `--sort-scheduler`,
    /// config `[scheduler] incremental`). Both produce byte-identical
    /// schedules.
    pub incremental: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 32,
            max_seq_len: 4096,
            priority_update_freq: 0.02,
            prefill_chunk: 512,
            max_tokens_per_iter: 0, // auto (roofline-sized)
            prefill_mode: PrefillMode::Chunked,
            priority_levels: 8,
            incremental: true,
        }
    }
}

/// Lookahead swap-in prefetcher (the speculative context-switch
/// pipeline): the scheduler projects which swapped-out requests the next
/// few priority-update epochs will re-admit, and the engine issues their
/// swap-ins early — strictly below demand traffic — so a predicted
/// re-admission lands with zero synchronous swap-in stall.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefetchConfig {
    /// Lookahead depth in priority-update epochs. `0` disables
    /// prefetching entirely (the demand-only baseline — seed behavior,
    /// bit-for-bit).
    pub depth: u64,
    /// Fraction of per-direction PCIe capacity the prefetcher may
    /// consume: a token bucket refilled at `io_budget × pcie_bw` bytes/s
    /// caps speculative traffic, and prefetches are only issued onto an
    /// idle inbound DMA engine.
    pub io_budget: f64,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig {
            depth: 0,
            io_budget: 0.25,
        }
    }
}

/// `[prefix]` section: the cross-request global prefix cache
/// ([`crate::block::prefix::PrefixIndex`]). Off by default — every
/// existing seeded e2e pin depends on the engine never touching the
/// index, so enabling it is an explicit opt-in (config `[prefix]
/// enabled = true` or CLI `--prefix-cache`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixConfig {
    /// Match shared prompt templates against the per-replica prefix
    /// index at admission and publish their full blocks as prefilled.
    pub enabled: bool,
}

/// Which eviction mechanism the [`crate::coordinator::switch`] planner
/// uses when the scheduler (or allocator pressure) preempts a victim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreemptionPolicyKind {
    /// Swap the victim's whole context to CPU — today's behavior and the
    /// default (seed runs reproduce bit-for-bit).
    SwapAll,
    /// Per-victim swap-vs-recompute choice by the
    /// [`crate::coordinator::switch::SwitchCostModel`] crossover
    /// (PCIe round-trip bytes vs recompute FLOPs) — the trade-off vLLM
    /// hardcodes per sequence-group kind.
    CostAware,
    /// Evict only the minimal suffix of the victim's block runs needed
    /// to satisfy the allocation, leaving the head GPU-resident
    /// ([`crate::coordinator::request::ReqState::PartiallyResident`]).
    PartialTail,
}

impl PreemptionPolicyKind {
    pub fn by_name(s: &str) -> Option<PreemptionPolicyKind> {
        match s {
            "swap_all" | "swap-all" | "swap" => Some(PreemptionPolicyKind::SwapAll),
            "cost_aware" | "cost-aware" | "cost" => Some(PreemptionPolicyKind::CostAware),
            "partial_tail" | "partial-tail" | "partial" => {
                Some(PreemptionPolicyKind::PartialTail)
            }
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PreemptionPolicyKind::SwapAll => "swap_all",
            PreemptionPolicyKind::CostAware => "cost_aware",
            PreemptionPolicyKind::PartialTail => "partial_tail",
        }
    }
}

/// `[preemption]` section: the pluggable context-switch eviction policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PreemptionConfig {
    pub policy: PreemptionPolicyKind,
}

impl Default for PreemptionConfig {
    fn default() -> Self {
        PreemptionConfig {
            policy: PreemptionPolicyKind::SwapAll,
        }
    }
}

/// Dispatch-cost constants (per `cudaMemcpyAsync`-equivalent call).
#[derive(Clone, Debug, PartialEq)]
pub struct SwapCostConfig {
    /// Per-call dispatch cost under the GIL path, ns.
    pub gil_dispatch_ns: u64,
    /// Per-call dispatch cost via the C++ thread pool, ns.
    pub threadpool_dispatch_ns: u64,
    /// Dispatches between forced fine-grained synchronizations (paper
    /// §3.2: ordered multi-stream dispatch).
    pub dispatch_sync_interval: usize,
    /// Cost of one fine-grained synchronization, ns.
    pub sync_cost_ns: u64,
    /// Adaptive policy: swap-in is made synchronous when the running
    /// batch's predicted iteration time is below this fraction of the
    /// predicted swap duration AND the batch is large (see
    /// swap::manager::AdaptivePolicy).
    pub adaptive_overlap_threshold: f64,
}

impl Default for SwapCostConfig {
    fn default() -> Self {
        SwapCostConfig {
            // Paper §2.2: dispatch exceeds the ~10 µs execution of a 128 KB
            // copy and is 90–95 % of total transmission time.
            gil_dispatch_ns: 18_000,
            // C++ offload: dominated by the driver call itself.
            threadpool_dispatch_ns: 2_500,
            dispatch_sync_interval: 64,
            sync_cost_ns: 8_000,
            adaptive_overlap_threshold: 0.5,
        }
    }
}

/// The full engine policy — spans vLLM baseline → full FastSwitch.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    pub granularity: Granularity,
    pub dispatch: DispatchMode,
    pub swap_mode: SwapMode,
    /// KV Cache Reuse Mechanism (§3.3) on/off.
    pub reuse: bool,
    pub scheduler: SchedulerConfig,
    pub swap_cost: SwapCostConfig,
    /// Priority source: offline trace (seed behavior) or an online
    /// per-tenant fairness policy (VTC / SLO-aware).
    pub fairness: FairnessConfig,
    /// Lookahead swap-in prefetcher (off by default).
    pub prefetch: PrefetchConfig,
    /// Cross-request global prefix cache (off by default).
    pub prefix: PrefixConfig,
    /// Pluggable eviction policy (`swap_all` default — seed behavior).
    pub preemption: PreemptionConfig,
    /// Observability: lifecycle tracing, epoch profiling, telemetry
    /// mode (everything off/exact by default — seed behavior).
    pub obs: ObsConfig,
    pub label: String,
}

impl EngineConfig {
    /// vLLM 0.3.3 baseline: fixed blocks, GIL dispatch, synchronous swap,
    /// no CPU-copy reuse.
    pub fn vllm_baseline() -> Self {
        EngineConfig {
            granularity: Granularity::FixedBlock,
            dispatch: DispatchMode::Gil,
            swap_mode: SwapMode::Sync,
            reuse: false,
            scheduler: SchedulerConfig::default(),
            swap_cost: SwapCostConfig::default(),
            fairness: FairnessConfig::default(),
            prefetch: PrefetchConfig::default(),
            prefix: PrefixConfig::default(),
            preemption: PreemptionConfig::default(),
            obs: ObsConfig::default(),
            label: "vllm".into(),
        }
    }

    /// Ablation step 1: + Dynamic Block Group Manager.
    pub fn with_dbg() -> Self {
        EngineConfig {
            granularity: Granularity::BlockGroup {
                init_group_blocks: 60,
            },
            label: "vllm+dbg".into(),
            ..Self::vllm_baseline()
        }
    }

    /// Ablation step 2: + KV Cache Reuse Mechanism.
    pub fn with_dbg_reuse() -> Self {
        EngineConfig {
            reuse: true,
            label: "vllm+dbg+reuse".into(),
            ..Self::with_dbg()
        }
    }

    /// Full FastSwitch: + Multithreading Swap Manager.
    pub fn fastswitch() -> Self {
        EngineConfig {
            dispatch: DispatchMode::ThreadPool { workers: 4 },
            swap_mode: SwapMode::Adaptive,
            label: "fastswitch".into(),
            ..Self::with_dbg_reuse()
        }
    }

    /// The paper's Fig. 8 ablation ladder, in order.
    pub fn ablation_ladder() -> Vec<EngineConfig> {
        vec![
            Self::vllm_baseline(),
            Self::with_dbg(),
            Self::with_dbg_reuse(),
            Self::fastswitch(),
        ]
    }
}

/// A complete testbed: model + GPU + capacities.
#[derive(Clone, Debug)]
pub struct Preset {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    /// CPU swap space for KV copies, bytes (paper: 60 GB).
    pub cpu_swap_bytes: u64,
}

impl Preset {
    /// Number of GPU KV blocks available after weights.
    pub fn gpu_blocks(&self) -> usize {
        let usable = (self.gpu.hbm_bytes as f64 * self.gpu.mem_util) as u64;
        let free = usable.saturating_sub(self.model.weight_bytes());
        (free / self.model.block_bytes()) as usize
    }

    /// Number of CPU KV block slots.
    pub fn cpu_blocks(&self) -> usize {
        (self.cpu_swap_bytes / self.model.block_bytes()) as usize
    }

    /// Paper testbed 1: LLaMA-8B on A10 24 GB, 60 GB CPU swap.
    pub fn llama8b_a10() -> Self {
        Preset {
            model: ModelSpec::llama8b(),
            gpu: GpuSpec::a10(),
            cpu_swap_bytes: 60 * (1 << 30),
        }
    }

    /// Paper testbed 2: Qwen-32B on A100 80 GB, 60 GB CPU swap.
    pub fn qwen32b_a100() -> Self {
        Preset {
            model: ModelSpec::qwen32b(),
            gpu: GpuSpec::a100_80g(),
            cpu_swap_bytes: 60 * (1 << 30),
        }
    }

    /// Small deterministic testbed for unit/integration tests.
    pub fn tiny() -> Self {
        Preset {
            model: ModelSpec::tiny(),
            gpu: GpuSpec::tiny(),
            cpu_swap_bytes: 1 << 20,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama8b_a10" | "llama8b" => Some(Self::llama8b_a10()),
            "qwen32b_a100" | "qwen32b" => Some(Self::qwen32b_a100()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama8b_block_granularity_matches_paper() {
        // Paper §2.2: 128 KB swap granularity for LLaMA-8B.
        let m = ModelSpec::llama8b();
        assert_eq!(m.block_bytes_per_layer(), 128 * 1024);
        assert_eq!(m.block_bytes(), 4 * 1024 * 1024); // 4 MB across 32 layers
    }

    #[test]
    fn qwen32b_block_bytes() {
        let m = ModelSpec::qwen32b();
        assert_eq!(m.block_bytes_per_layer(), 128 * 1024);
        assert_eq!(m.block_bytes(), 8 * 1024 * 1024);
    }

    #[test]
    fn a10_preset_capacity_is_contended() {
        // The A10 testbed must be memory-contended (that's the regime the
        // paper studies): a few GB of KV space after 16 GB of weights.
        let p = Preset::llama8b_a10();
        let blocks = p.gpu_blocks();
        assert!(blocks > 500 && blocks < 3000, "blocks = {blocks}");
        // 60 GB CPU swap at 4 MB/block.
        assert_eq!(p.cpu_blocks(), 15 * 1024);
    }

    #[test]
    fn pcie_efficiency_curve() {
        // Paper: small 128 KB transfers under-utilize PCIe; ≥ 320 KB is
        // near-optimal.
        let g = GpuSpec::a10();
        let small = g.pcie_eff_bw(128 * 1024);
        let good = g.pcie_eff_bw(320 * 1024);
        let big = g.pcie_eff_bw(4 * 1024 * 1024);
        assert!(small < 0.7 * g.pcie_bw);
        assert!(good > 0.8 * g.pcie_bw);
        assert!(big > 0.95 * g.pcie_bw);
    }

    #[test]
    fn dispatch_dominates_at_vllm_granularity() {
        // Paper §2.2: at 128 KB granularity, dispatch = 90–95 % of total
        // transmission time. With execution overlapped behind serialized
        // dispatch, many-copy total ≈ N·dispatch, so the per-copy ratio
        // dispatch/(dispatch+exec_tail) must be large.
        let g = GpuSpec::a10();
        let c = SwapCostConfig::default();
        let exec = g.pcie_exec_ns(128 * 1024);
        // execution of one 128 KB copy ≈ 6 µs < dispatch 18 µs — dispatch
        // exceeds execution, as measured in the paper.
        assert!(c.gil_dispatch_ns > exec, "exec = {exec}");
        // aggregate fraction for a long burst (N = 100):
        let n = 100u64;
        let frac =
            (n * c.gil_dispatch_ns) as f64 / ((n * c.gil_dispatch_ns) + exec) as f64;
        assert!(frac > 0.9);
    }

    #[test]
    fn ablation_ladder_is_monotone_in_features() {
        let l = EngineConfig::ablation_ladder();
        assert_eq!(l.len(), 4);
        assert_eq!(l[0].granularity, Granularity::FixedBlock);
        assert!(matches!(l[1].granularity, Granularity::BlockGroup { .. }));
        assert!(!l[1].reuse && l[2].reuse);
        assert!(matches!(l[3].dispatch, DispatchMode::ThreadPool { .. }));
        assert_eq!(l[3].swap_mode, SwapMode::Adaptive);
    }

    #[test]
    fn default_priority_source_is_the_offline_trace() {
        use crate::fairness::PolicyKind;
        // The seed behavior must be the default: online policies are
        // opt-in via config/CLI.
        for cfg in EngineConfig::ablation_ladder() {
            assert_eq!(cfg.fairness.policy, PolicyKind::Trace);
        }
    }

    #[test]
    fn chunked_prefill_is_the_default_with_auto_budget() {
        let s = SchedulerConfig::default();
        assert_eq!(s.prefill_mode, PrefillMode::Chunked);
        assert_eq!(s.max_tokens_per_iter, 0, "0 = roofline auto-sizing");
        assert!(s.prefill_chunk > 0);
    }

    #[test]
    fn prefill_mode_names() {
        assert_eq!(PrefillMode::by_name("chunked"), Some(PrefillMode::Chunked));
        assert_eq!(
            PrefillMode::by_name("monolithic"),
            Some(PrefillMode::Monolithic)
        );
        assert_eq!(PrefillMode::by_name("nope"), None);
        assert_eq!(PrefillMode::Chunked.label(), "chunked");
    }

    #[test]
    fn prefetch_defaults_off_everywhere() {
        // Depth 0 must be the default on every ladder rung: the
        // prefetcher is opt-in and the seed behavior stays bit-for-bit.
        for cfg in EngineConfig::ablation_ladder() {
            assert_eq!(cfg.prefetch.depth, 0, "{} prefetches by default", cfg.label);
            assert!(cfg.prefetch.io_budget > 0.0 && cfg.prefetch.io_budget <= 1.0);
        }
    }

    #[test]
    fn prefix_cache_defaults_off_everywhere() {
        // The global prefix cache is opt-in on every ladder rung: with
        // it off the engine never touches the index and every seeded
        // e2e pin stays byte-identical.
        for cfg in EngineConfig::ablation_ladder() {
            assert!(!cfg.prefix.enabled, "{} prefix-caches by default", cfg.label);
        }
    }

    #[test]
    fn preemption_defaults_to_swap_all_everywhere() {
        // The refactor is behavior-pinned: every ladder rung must keep
        // the whole-victim swap eviction unless explicitly overridden.
        for cfg in EngineConfig::ablation_ladder() {
            assert_eq!(
                cfg.preemption.policy,
                PreemptionPolicyKind::SwapAll,
                "{} must default to swap_all",
                cfg.label
            );
        }
    }

    #[test]
    fn preemption_policy_names() {
        assert_eq!(
            PreemptionPolicyKind::by_name("swap_all"),
            Some(PreemptionPolicyKind::SwapAll)
        );
        assert_eq!(
            PreemptionPolicyKind::by_name("cost_aware"),
            Some(PreemptionPolicyKind::CostAware)
        );
        assert_eq!(
            PreemptionPolicyKind::by_name("partial_tail"),
            Some(PreemptionPolicyKind::PartialTail)
        );
        assert_eq!(PreemptionPolicyKind::by_name("nope"), None);
        assert_eq!(PreemptionPolicyKind::PartialTail.label(), "partial_tail");
    }

    #[test]
    fn obs_defaults_off_everywhere() {
        use crate::obs::TelemetryMode;
        // Observability is opt-in on every ladder rung: no trace buffer,
        // no profiler, exact telemetry — the e2e pins depend on it.
        for cfg in EngineConfig::ablation_ladder() {
            assert!(!cfg.obs.trace, "{} traces by default", cfg.label);
            assert!(!cfg.obs.profile, "{} profiles by default", cfg.label);
            assert_eq!(cfg.obs.telemetry, TelemetryMode::Exact);
        }
    }

    #[test]
    fn preset_lookup() {
        assert!(Preset::by_name("llama8b_a10").is_some());
        assert!(Preset::by_name("qwen32b").is_some());
        assert!(Preset::by_name("nope").is_none());
    }
}
