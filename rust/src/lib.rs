//! # FastSwitch
//!
//! Reproduction of *"FastSwitch: Optimizing Context Switching Efficiency in
//! Fairness-aware Large Language Model Serving"* (Shen, Li, Gao, 2024).
//!
//! FastSwitch is a fairness-aware LLM serving system that makes
//! preemption-induced context switching (KV-cache swapping between GPU and
//! CPU memory) cheap, so frequent priority adjustments do not destroy tail
//! TTFT/TBT. Three optimizations on top of a vLLM-style paged-KV engine:
//!
//! 1. [`block::buddy`] — **Dynamic Block Group Manager**: buddy-style
//!    allocation of contiguous block groups so swap traffic coalesces into
//!    few large transfers (paper §3.1, Challenge #1).
//! 2. [`swap::manager`] — **Multithreading Swap Manager**: asynchronous,
//!    conflict-checked swap dispatch overlapping inference (paper §3.2,
//!    Challenge #2, Algorithm 1).
//! 3. [`block::reuse`] — **KV Cache Reuse Mechanism**: CPU-side KV copies
//!    with contamination tracking, cutting multi-turn swap-out volume
//!    (paper §3.3, Challenge #3).
//!
//! On top of the reproduction, two extensions push toward a production
//! serving system:
//!
//! - the [`fairness`] subsystem supplies the *online* policies the paper
//!   presupposes but replays from offline traces: per-tenant
//!   virtual-token accounting (VTC) and SLO-deficit boosting compute
//!   live scheduler priorities from observed service, so the
//!   cheap-context-switch machinery is exercised by realistic
//!   multi-tenant contention (`exp fairness`);
//! - the [`coordinator::scheduler`] admits work under a per-iteration
//!   **token budget**: decodes claim the budget first and prefill
//!   *chunks* fill the remainder ([`coordinator::scheduler::IterBudget`]
//!   / [`coordinator::scheduler::TokenGrant`]), so a long prompt no
//!   longer stalls co-resident decodes the way whole-prefill admission
//!   does (`exp chunked` measures the tail-TBT / TTFT trade-off;
//!   [`config::PrefillMode`] selects the mode);
//! - the [`cluster`] front-end dispatches conversations across N
//!   independent engine replicas with pluggable placement —
//!   round-robin, least-loaded, or KV-affinity (pin a conversation's
//!   later turns to the replica holding its CPU KV copy, with a spill
//!   threshold trading locality for balance) — and aggregates per-tenant
//!   latency, fairness, and swap-volume metrics across replicas
//!   (`exp cluster` runs the placement showdown);
//! - the **lookahead swap-in prefetcher**
//!   ([`coordinator::scheduler::predict_admission`] +
//!   [`swap::manager::SwapManager::submit_prefetch`], configured by
//!   [`config::PrefetchConfig`]) projects which swapped-out requests
//!   the next priority epochs will re-admit and issues their swap-ins
//!   early as background PCIe traffic under an I/O budget, so a
//!   predicted re-admission lands with zero synchronous swap-in stall
//!   (`exp prefetch` sweeps the lookahead depth);
//! - the [`obs`] observability layer: zero-cost-when-off request
//!   lifecycle tracing with a `chrome://tracing` exporter, bounded
//!   reservoir telemetry + a per-stage scheduler-epoch profiler, and
//!   the per-PR perf ledger (`exp ledger` regenerates
//!   `BENCH_PR<N>.json` at the repo root);
//! - the [`workload::scenario`] fleet: four seeded adversarial workload
//!   generators (agentic tool-call loops, mega-context summarization,
//!   a thundering herd with a mid-run replica drain, a diurnal load
//!   wave) behind one [`workload::ScenarioSpec`], driven by
//!   `exp gauntlet` — every preemption policy × every scenario on the
//!   3-replica cluster path, audited per cell by
//!   [`metrics::invariants`] and scored into the schema-stable
//!   `GAUNTLET_PR<N>.json` regression scorecard;
//! - the [`runtime::actor`] cluster runtime: each replica is an actor
//!   owning its [`ServingEngine`] behind a typed mailbox
//!   ([`runtime::actor::ReplicaMsg`]), the router is a message-driven
//!   work-queue core ([`cluster::RouterCore`]), and one
//!   [`runtime::actor::Executor`] trait hosts two interchangeable
//!   schedulers — the seeded single-threaded deterministic executor
//!   (default; every e2e pin reproduces byte-for-byte) and the threaded
//!   executor (`--parallel`: one OS thread per replica, real channels,
//!   wall-clock speedup reported in the perf ledger's `parallel`
//!   section);
//! - the **global prefix cache** ([`block::prefix::PrefixIndex`],
//!   [`config::PrefixConfig`], off by default): a per-replica
//!   refcounted radix index of content-hashed shared-template blocks —
//!   admission matches a templated request's longest cached chain and
//!   prefills only the uncached suffix, VTC charges only uncached
//!   tokens, and [`cluster::PlacementKind::PrefixAware`] routes fresh
//!   templated conversations at the replica holding the deepest
//!   published chain (`exp locality` runs the shared-fleet vs
//!   disjoint-chat showdown).
//!
//! ## Architecture (three layers, Python never on the request path)
//!
//! - **L3** (this crate): coordinator — scheduler, allocators, swap
//!   managers, the [`fairness`] priority policies, metrics, CLI. Two
//!   backends: a virtual-time simulation of the paper's A10/A100+PCIe
//!   testbed ([`sim`]) and real execution of an AOT-compiled paged-KV
//!   transformer via PJRT ([`runtime`], behind the `xla` feature).
//! - **L2**: JAX paged transformer (`python/compile/model.py`), lowered
//!   once to HLO text artifacts.
//! - **L1**: Pallas kernels (`python/compile/kernels/`): decode paged
//!   attention + prefill-with-prefix.
//!
//! The priority flow: [`workload`] assigns every conversation a tenant;
//! each iteration the engine reports per-tenant service and latency to
//! the configured [`fairness::PriorityPolicy`]; each update epoch the
//! policy maps accrued (weighted) virtual service and SLO deficits onto
//! priority levels; [`coordinator::scheduler`] consumes those priorities
//! unchanged.
//!
//! See `README.md` for the quickstart and `DESIGN.md` for the full
//! system inventory and the experiment index mapping every paper
//! figure/table to a module and bench.

pub mod block;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod fairness;
pub mod memory;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod swap;
pub mod util;
pub mod workload;

pub use cluster::{ClusterConfig, ClusterOutcome, ClusterRouter, PlacementKind};
pub use config::{EngineConfig, GpuSpec, ModelSpec, Preset, SchedulerConfig};
pub use coordinator::engine::{ServeOutcome, ServingEngine};
pub use fairness::{FairnessConfig, PolicyKind, PriorityPolicy};
