//! Statistical clone of the Multi-Round ShareGPT dataset (paper Fig. 4).
//!
//! The paper's workload facts: ~100 K conversations, 78 % multi-turn,
//! average 5.5 turns per conversation; prompt/response lengths follow the
//! familiar heavy-tailed ShareGPT distribution (most turns are a few
//! hundred tokens; responses longer than prompts on average). We model:
//!
//! - turns per conversation: shifted geometric calibrated to
//!   P(multi-turn) ≈ 0.78 and mean ≈ 5.5;
//! - prompt length: log-normal, median ≈ 70 tokens (first turns longer —
//!   they carry instructions/context);
//! - response length: log-normal, median ≈ 200 tokens;
//! - think time between turns: exponential (user reading/typing).
//!
//! All draws are seeded — a given (config, seed) pair reproduces the same
//! workload on every run.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct Turn {
    pub prompt_tokens: u32,
    pub response_tokens: u32,
    /// Gap between the previous turn's completion and this turn's
    /// arrival, seconds.
    pub think_time_s: f64,
}

/// A shared prompt template: the leading `tokens` of the first turn's
/// prompt are byte-identical across every conversation carrying the
/// same `group` (a system prompt / few-shot preamble). Turns carry only
/// token counts, so the group id *is* the template identity — the
/// global prefix cache ([`crate::block::prefix`]) hashes template
/// blocks as `(group, block index)` chains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SharedPrefix {
    pub group: u64,
    /// Shared leading length in tokens (≤ the first turn's prompt).
    pub tokens: u32,
}

#[derive(Clone, Debug)]
pub struct Conversation {
    pub id: u64,
    /// Owning tenant (client account) — the fairness accounting unit.
    /// 0 by default; see [`crate::workload::tenants::assign_tenants`].
    pub tenant: u32,
    /// Shared prompt template, if the first prompt opens with one
    /// (`None` = fully distinct prompt — the default everywhere).
    pub prefix: Option<SharedPrefix>,
    pub turns: Vec<Turn>,
}

impl Conversation {
    pub fn total_tokens(&self) -> u64 {
        self.turns
            .iter()
            .map(|t| (t.prompt_tokens + t.response_tokens) as u64)
            .sum()
    }
}

#[derive(Clone, Debug)]
pub struct ShareGptConfig {
    /// Mean turns per conversation (paper: 5.5).
    pub mean_turns: f64,
    /// First-turn prompt log-normal (mu, sigma) in log-tokens.
    pub first_prompt_mu: f64,
    pub first_prompt_sigma: f64,
    /// Follow-up prompt log-normal.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// Response log-normal.
    pub response_mu: f64,
    pub response_sigma: f64,
    /// Mean think time between turns, seconds.
    pub mean_think_s: f64,
    /// Hard caps (tokens) to fit the serving context window.
    pub max_prompt: u32,
    pub max_response: u32,
}

impl Default for ShareGptConfig {
    fn default() -> Self {
        ShareGptConfig {
            mean_turns: 5.5,
            first_prompt_mu: 5.1,      // median ≈ 164 tokens
            first_prompt_sigma: 0.9,
            prompt_mu: 4.2,            // median ≈ 67 tokens
            prompt_sigma: 0.8,
            response_mu: 5.3,          // median ≈ 200 tokens
            response_sigma: 0.7,
            mean_think_s: 20.0,
            max_prompt: 1536,
            max_response: 1024,
        }
    }
}

/// Generate `n` conversations.
pub fn generate(cfg: &ShareGptConfig, n: usize, seed: u64) -> Vec<Conversation> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|id| {
            // Shifted geometric: support {1, 2, ...}, mean = 1/p.
            let p = 1.0 / cfg.mean_turns;
            let n_turns = rng.geometric(p) as usize;
            let turns = (0..n_turns)
                .map(|t| {
                    let (mu, sigma) = if t == 0 {
                        (cfg.first_prompt_mu, cfg.first_prompt_sigma)
                    } else {
                        (cfg.prompt_mu, cfg.prompt_sigma)
                    };
                    let prompt =
                        (rng.lognormal(mu, sigma).round() as u32).clamp(4, cfg.max_prompt);
                    let response = (rng.lognormal(cfg.response_mu, cfg.response_sigma)
                        .round() as u32)
                        .clamp(4, cfg.max_response);
                    let think = if t == 0 {
                        0.0
                    } else {
                        rng.exp(1.0 / cfg.mean_think_s)
                    };
                    Turn {
                        prompt_tokens: prompt,
                        response_tokens: response,
                        think_time_s: think,
                    }
                })
                .collect();
            Conversation {
                id: id as u64,
                tenant: 0,
                prefix: None,
                turns,
            }
        })
        .collect()
}

/// Summary statistics (regenerates the paper's Fig. 4 panels).
#[derive(Debug, Clone)]
pub struct WorkloadStats {
    pub n_conversations: usize,
    pub mean_turns: f64,
    pub multi_turn_fraction: f64,
    pub mean_prompt: f64,
    pub mean_response: f64,
    pub p95_conv_tokens: f64,
}

pub fn stats(convs: &[Conversation]) -> WorkloadStats {
    let n = convs.len();
    let total_turns: usize = convs.iter().map(|c| c.turns.len()).sum();
    let multi = convs.iter().filter(|c| c.turns.len() > 1).count();
    let mut prompts = 0u64;
    let mut resps = 0u64;
    for c in convs {
        for t in &c.turns {
            prompts += t.prompt_tokens as u64;
            resps += t.response_tokens as u64;
        }
    }
    let conv_tokens = crate::util::stats::Percentiles::from(
        convs.iter().map(|c| c.total_tokens() as f64).collect(),
    );
    WorkloadStats {
        n_conversations: n,
        mean_turns: total_turns as f64 / n as f64,
        multi_turn_fraction: multi as f64 / n as f64,
        mean_prompt: prompts as f64 / total_turns as f64,
        mean_response: resps as f64 / total_turns as f64,
        p95_conv_tokens: conv_tokens.p(95.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_fig4_statistics() {
        let convs = generate(&ShareGptConfig::default(), 4000, 1);
        let s = stats(&convs);
        // Paper: avg 5.5 turns, 78 % multi-turn.
        assert!((s.mean_turns - 5.5).abs() < 0.4, "{}", s.mean_turns);
        assert!(
            (s.multi_turn_fraction - 0.78).abs() < 0.06,
            "{}",
            s.multi_turn_fraction
        );
        assert!(s.mean_response > s.mean_prompt * 0.9);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&ShareGptConfig::default(), 50, 7);
        let b = generate(&ShareGptConfig::default(), 50, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.turns.len(), y.turns.len());
            for (t, u) in x.turns.iter().zip(&y.turns) {
                assert_eq!(t.prompt_tokens, u.prompt_tokens);
                assert_eq!(t.response_tokens, u.response_tokens);
            }
        }
        let c = generate(&ShareGptConfig::default(), 50, 8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.turns.len() != y.turns.len()));
    }

    #[test]
    fn lengths_respect_caps() {
        let cfg = ShareGptConfig::default();
        for c in generate(&cfg, 500, 3) {
            for t in &c.turns {
                assert!(t.prompt_tokens >= 4 && t.prompt_tokens <= cfg.max_prompt);
                assert!(t.response_tokens >= 4 && t.response_tokens <= cfg.max_response);
            }
        }
    }

    #[test]
    fn first_turn_has_no_think_time() {
        for c in generate(&ShareGptConfig::default(), 100, 4) {
            assert_eq!(c.turns[0].think_time_s, 0.0);
            for t in &c.turns[1..] {
                assert!(t.think_time_s >= 0.0);
            }
        }
    }
}
