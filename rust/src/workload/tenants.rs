//! Multi-tenant workload shaping: assign conversations to N tenants with
//! a skewed request mix (one "heavy" abuser vs many light users).
//!
//! Tenant 0 is by convention the heavy tenant; it issues
//! [`TenantMix::heavy_share`] of all conversations and the remainder is
//! spread uniformly across tenants `1..n_tenants`. With
//! `heavy_share = 1/n_tenants` the mix degenerates to uniform.

use super::sharegpt::Conversation;
use crate::util::rng::Rng;

/// How conversations are split across tenants.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantMix {
    pub n_tenants: usize,
    /// Fraction of conversations issued by tenant 0 (the heavy tenant).
    pub heavy_share: f64,
}

impl TenantMix {
    pub fn uniform(n_tenants: usize) -> Self {
        let n = n_tenants.max(1);
        TenantMix {
            n_tenants: n,
            heavy_share: 1.0 / n as f64,
        }
    }

    /// One heavy tenant issuing `heavy_share` of the traffic.
    pub fn skewed(n_tenants: usize, heavy_share: f64) -> Self {
        TenantMix {
            n_tenants: n_tenants.max(1),
            heavy_share: heavy_share.clamp(0.0, 1.0),
        }
    }
}

/// Assign a tenant to every conversation (deterministic per seed).
pub fn assign_tenants(convs: &mut [Conversation], mix: &TenantMix, seed: u64) {
    let mut rng = Rng::new(seed ^ 0x7E4A_4717);
    for c in convs.iter_mut() {
        c.tenant = if mix.n_tenants == 1 || rng.chance(mix.heavy_share) {
            0
        } else {
            rng.usize(1, mix.n_tenants) as u32
        };
    }
}

/// (tenant, conversation count) pairs, sorted by tenant.
pub fn conversations_per_tenant(convs: &[Conversation]) -> Vec<(u32, usize)> {
    let mut counts: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
    for c in convs {
        *counts.entry(c.tenant).or_insert(0) += 1;
    }
    let mut v: Vec<(u32, usize)> = counts.into_iter().collect();
    v.sort_by_key(|&(t, _)| t);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::sharegpt::{generate, ShareGptConfig};

    #[test]
    fn skewed_mix_concentrates_on_tenant_zero() {
        let mut convs = generate(&ShareGptConfig::default(), 4000, 1);
        assign_tenants(&mut convs, &TenantMix::skewed(8, 0.5), 2);
        let counts = conversations_per_tenant(&convs);
        assert_eq!(counts.len(), 8, "all tenants appear");
        let total: usize = counts.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 4000);
        let heavy = counts.iter().find(|&&(t, _)| t == 0).unwrap().1;
        let frac = heavy as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.05, "heavy share {frac}");
        // Light tenants split the rest roughly evenly.
        for &(t, n) in &counts {
            if t != 0 {
                let f = n as f64 / total as f64;
                assert!((f - 0.5 / 7.0).abs() < 0.03, "tenant {t} share {f}");
            }
        }
    }

    #[test]
    fn uniform_mix_is_balanced() {
        let mut convs = generate(&ShareGptConfig::default(), 4000, 3);
        assign_tenants(&mut convs, &TenantMix::uniform(4), 4);
        for (t, n) in conversations_per_tenant(&convs) {
            let f = n as f64 / 4000.0;
            assert!((f - 0.25).abs() < 0.04, "tenant {t} share {f}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = generate(&ShareGptConfig::default(), 200, 5);
        let mut b = generate(&ShareGptConfig::default(), 200, 5);
        assign_tenants(&mut a, &TenantMix::skewed(4, 0.6), 9);
        assign_tenants(&mut b, &TenantMix::skewed(4, 0.6), 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tenant, y.tenant);
        }
    }

    #[test]
    fn single_tenant_everything_is_tenant_zero() {
        let mut convs = generate(&ShareGptConfig::default(), 50, 6);
        assign_tenants(&mut convs, &TenantMix::uniform(1), 7);
        assert!(convs.iter().all(|c| c.tenant == 0));
    }
}
