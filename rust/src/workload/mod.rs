//! Workload generation: ShareGPT-like multi-turn conversations with
//! Poisson or bursty (on/off MMPP) arrivals, optionally split across
//! tenants with a skewed request mix (paper §4 "System and Workload
//! Configuration", extended for the online fairness policies), plus the
//! [`scenario`] fleet of adversarial shapes behind the `exp gauntlet`.

pub mod scenario;
pub mod sharegpt;
pub mod tenants;
pub mod trace;

pub use scenario::{DrainPlan, ScenarioParams, ScenarioSpec, ScenarioWorkload};
pub use sharegpt::{Conversation, ShareGptConfig, SharedPrefix, Turn};
pub use tenants::{assign_tenants, conversations_per_tenant, TenantMix};
pub use trace::{ArrivalTrace, TraceEntry};
