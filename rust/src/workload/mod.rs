//! Workload generation: ShareGPT-like multi-turn conversations with
//! Poisson arrivals (paper §4 "System and Workload Configuration").

pub mod sharegpt;
pub mod trace;

pub use sharegpt::{Conversation, ShareGptConfig, Turn};
pub use trace::{ArrivalTrace, TraceEntry};
