//! Arrival traces: Poisson request arrivals over a conversation set
//! (paper §4: 1 000 conversations, Poisson, average 1 req/s).

use super::sharegpt::Conversation;
use crate::sim::clock::{Ns, SEC};
use crate::util::rng::Rng;

/// One conversation's first-turn arrival.
#[derive(Clone, Copy, Debug)]
pub struct TraceEntry {
    pub conversation: u64,
    pub arrival: Ns,
}

#[derive(Clone, Debug)]
pub struct ArrivalTrace {
    pub entries: Vec<TraceEntry>,
}

impl ArrivalTrace {
    /// Poisson arrivals at `rate` conversations/second.
    pub fn poisson(convs: &[Conversation], rate: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xA221);
        let mut t = 0.0f64;
        let entries = convs
            .iter()
            .map(|c| {
                t += rng.exp(rate);
                TraceEntry {
                    conversation: c.id,
                    arrival: (t * SEC as f64) as Ns,
                }
            })
            .collect();
        ArrivalTrace { entries }
    }

    pub fn span(&self) -> Ns {
        self.entries.last().map(|e| e.arrival).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::sharegpt::{generate, ShareGptConfig};

    #[test]
    fn poisson_rate_approximately_honored() {
        let convs = generate(&ShareGptConfig::default(), 2000, 1);
        let tr = ArrivalTrace::poisson(&convs, 1.0, 2);
        let span_s = tr.span() as f64 / SEC as f64;
        let rate = 2000.0 / span_s;
        assert!((rate - 1.0).abs() < 0.1, "rate {rate}");
    }

    #[test]
    fn arrivals_monotone() {
        let convs = generate(&ShareGptConfig::default(), 100, 1);
        let tr = ArrivalTrace::poisson(&convs, 2.0, 3);
        for w in tr.entries.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn deterministic() {
        let convs = generate(&ShareGptConfig::default(), 100, 1);
        let a = ArrivalTrace::poisson(&convs, 1.0, 9);
        let b = ArrivalTrace::poisson(&convs, 1.0, 9);
        assert_eq!(a.entries.len(), b.entries.len());
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.arrival, y.arrival);
        }
    }
}
