//! Arrival traces: Poisson request arrivals over a conversation set
//! (paper §4: 1 000 conversations, Poisson, average 1 req/s), plus an
//! on/off Markov-modulated Poisson pattern ([`ArrivalTrace::mmpp`] /
//! [`ArrivalTrace::bursty`]) for bursty multi-tenant workloads.

use super::sharegpt::Conversation;
use crate::sim::clock::{Ns, SEC};
use crate::util::rng::Rng;

/// One conversation's first-turn arrival.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEntry {
    pub conversation: u64,
    pub arrival: Ns,
}

#[derive(Clone, Debug)]
pub struct ArrivalTrace {
    pub entries: Vec<TraceEntry>,
}

impl ArrivalTrace {
    /// Poisson arrivals at `rate` conversations/second.
    pub fn poisson(convs: &[Conversation], rate: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xA221);
        let mut t = 0.0f64;
        let entries = convs
            .iter()
            .map(|c| {
                t += rng.exp(rate);
                TraceEntry {
                    conversation: c.id,
                    arrival: (t * SEC as f64) as Ns,
                }
            })
            .collect();
        ArrivalTrace { entries }
    }

    /// On/off Markov-modulated Poisson process: while ON, arrivals come
    /// at `rate_on`/s; while OFF at `rate_off`/s (0 allowed — a silent
    /// gap). State holding times are exponential with means `mean_on_s`
    /// / `mean_off_s`. The long-run average rate is
    /// `(rate_on·mean_on + rate_off·mean_off) / (mean_on + mean_off)`.
    pub fn mmpp(
        convs: &[Conversation],
        rate_on: f64,
        rate_off: f64,
        mean_on_s: f64,
        mean_off_s: f64,
        seed: u64,
    ) -> Self {
        assert!(rate_on > 0.0 && rate_off >= 0.0);
        assert!(mean_on_s > 0.0 && mean_off_s > 0.0);
        let mut rng = Rng::new(seed ^ 0xB0B5);
        let mut t = 0.0f64;
        let mut on = true;
        let mut state_end = rng.exp(1.0 / mean_on_s);
        let entries = convs
            .iter()
            .map(|c| {
                loop {
                    let rate = if on { rate_on } else { rate_off };
                    // In a zero-rate state the next arrival is beyond the
                    // state's end with probability 1.
                    let dt = if rate > 0.0 { rng.exp(rate) } else { f64::INFINITY };
                    if t + dt <= state_end {
                        t += dt;
                        break;
                    }
                    // The exponential's memorylessness lets us discard the
                    // partial draw and restart the clock in the new state.
                    t = state_end;
                    on = !on;
                    let mean = if on { mean_on_s } else { mean_off_s };
                    state_end = t + rng.exp(1.0 / mean);
                }
                TraceEntry {
                    conversation: c.id,
                    arrival: (t * SEC as f64) as Ns,
                }
            })
            .collect();
        ArrivalTrace { entries }
    }

    /// Convenience bursty pattern averaging ≈ `mean_rate` req/s: ON
    /// bursts at `burst × mean_rate` (mean 5 s long) separated by silent
    /// OFF gaps sized so the long-run rate stays `mean_rate`. `burst`
    /// must exceed 1.
    pub fn bursty(convs: &[Conversation], mean_rate: f64, burst: f64, seed: u64) -> Self {
        assert!(burst > 1.0, "burst factor must exceed 1");
        let mean_on_s = 5.0;
        // duty cycle 1/burst → average = rate_on / burst = mean_rate.
        let mean_off_s = mean_on_s * (burst - 1.0);
        Self::mmpp(convs, mean_rate * burst, 0.0, mean_on_s, mean_off_s, seed)
    }

    pub fn span(&self) -> Ns {
        self.entries.last().map(|e| e.arrival).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::sharegpt::{generate, ShareGptConfig};

    #[test]
    fn poisson_rate_approximately_honored() {
        let convs = generate(&ShareGptConfig::default(), 2000, 1);
        let tr = ArrivalTrace::poisson(&convs, 1.0, 2);
        let span_s = tr.span() as f64 / SEC as f64;
        let rate = 2000.0 / span_s;
        assert!((rate - 1.0).abs() < 0.1, "rate {rate}");
    }

    #[test]
    fn arrivals_monotone() {
        let convs = generate(&ShareGptConfig::default(), 100, 1);
        let tr = ArrivalTrace::poisson(&convs, 2.0, 3);
        for w in tr.entries.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn deterministic() {
        let convs = generate(&ShareGptConfig::default(), 100, 1);
        let a = ArrivalTrace::poisson(&convs, 1.0, 9);
        let b = ArrivalTrace::poisson(&convs, 1.0, 9);
        assert_eq!(a.entries.len(), b.entries.len());
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn bursty_rate_approximately_honored() {
        let convs = generate(&ShareGptConfig::default(), 4000, 1);
        let tr = ArrivalTrace::bursty(&convs, 1.0, 4.0, 2);
        let span_s = tr.span() as f64 / SEC as f64;
        let rate = 4000.0 / span_s;
        assert!((rate - 1.0).abs() < 0.2, "long-run rate {rate}");
    }

    #[test]
    fn bursty_arrivals_monotone_and_deterministic() {
        let convs = generate(&ShareGptConfig::default(), 300, 1);
        let a = ArrivalTrace::bursty(&convs, 2.0, 5.0, 11);
        let b = ArrivalTrace::bursty(&convs, 2.0, 5.0, 11);
        for w in a.entries.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // Squared coefficient of variation of inter-arrival times:
        // exactly 1 for Poisson, well above 1 for an on/off MMPP with
        // silent gaps.
        fn cv2(tr: &ArrivalTrace) -> f64 {
            let gaps: Vec<f64> = tr
                .entries
                .windows(2)
                .map(|w| (w[1].arrival - w[0].arrival) as f64 / SEC as f64)
                .collect();
            let n = gaps.len() as f64;
            let mean = gaps.iter().sum::<f64>() / n;
            let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n;
            var / (mean * mean)
        }
        let convs = generate(&ShareGptConfig::default(), 3000, 1);
        let poisson = ArrivalTrace::poisson(&convs, 1.0, 3);
        let bursty = ArrivalTrace::bursty(&convs, 1.0, 6.0, 3);
        let (cp, cb) = (cv2(&poisson), cv2(&bursty));
        assert!((cp - 1.0).abs() < 0.25, "poisson cv² {cp}");
        assert!(cb > 1.5 * cp, "bursty cv² {cb} !>> poisson {cp}");
    }

    #[test]
    fn mmpp_with_nonzero_off_rate_still_arrives_everywhere() {
        let convs = generate(&ShareGptConfig::default(), 500, 1);
        let tr = ArrivalTrace::mmpp(&convs, 3.0, 0.5, 4.0, 8.0, 7);
        assert_eq!(tr.entries.len(), 500);
        for w in tr.entries.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }
}
