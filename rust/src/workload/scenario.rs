//! Scenario fleet: seeded adversarial workload generators behind one
//! [`ScenarioSpec`] abstraction (the `exp gauntlet` input matrix).
//!
//! Four shapes, each stressing a different subsystem at its design
//! limit — the traffic the paper's preemption-heavy claims rest on and
//! the skewed-tenant/MMPP mixes cannot produce:
//!
//! - [`ScenarioSpec::Agentic`] — tool-call loops: many short turns with
//!   sub-second think times, stressing prefetch lead time and
//!   claim/cancel churn (the lookahead pipeline barely has one epoch of
//!   warning before a turn fires).
//! - [`ScenarioSpec::MegaContext`] — single-turn summarization requests
//!   near `max_model_len`, stressing `partial_tail` and the capacity
//!   backstops. Rejection-free *by construction*: every prompt+response
//!   fits the configured `max_model_len`, which on the testbed presets
//!   is far below the GPU-capacity admission bound, so
//!   `rejected_conversations == 0` is an invariant, not a hope.
//! - [`ScenarioSpec::ThunderingHerd`] — synchronized arrival waves plus
//!   a mid-run replica drain event (injected through the cluster
//!   router) that forces live migrations, stressing migration costing.
//! - [`ScenarioSpec::Diurnal`] — a long-run sinusoidal load wave
//!   (non-homogeneous Poisson via thinning) for steady-state drift.
//!
//! Every generator is pure over `(spec, n, rate, seed)`: same inputs,
//! byte-identical workload (the determinism pins and the gauntlet's
//! same-seed scorecard test rely on it).

use super::sharegpt::{generate, Conversation, ShareGptConfig, Turn};
use super::tenants::{assign_tenants, TenantMix};
use super::trace::{ArrivalTrace, TraceEntry};
use crate::sim::clock::{Ns, SEC};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------
// Spec bounds (public so property tests assert generators stay in-spec)
// ---------------------------------------------------------------------

/// Tenants every scenario splits across (Jain index needs > 1).
pub const SCENARIO_TENANTS: usize = 4;
/// Share of conversations owned by tenant 0 (mild skew).
pub const SCENARIO_HEAVY_SHARE: f64 = 0.4;

/// Agentic: turns per conversation, inclusive bounds.
pub const AGENTIC_TURNS_MIN: usize = 8;
pub const AGENTIC_TURNS_MAX: usize = 16;
/// Agentic: sub-second think times (tool execution latency), seconds.
pub const AGENTIC_THINK_MIN_S: f64 = 0.05;
pub const AGENTIC_THINK_MAX_S: f64 = 0.9;
/// Agentic: token bounds — first prompt carries the task, follow-ups
/// are tool results, responses are short tool calls. Inclusive.
pub const AGENTIC_FIRST_PROMPT: (u32, u32) = (96, 256);
pub const AGENTIC_TOOL_PROMPT: (u32, u32) = (24, 96);
pub const AGENTIC_RESPONSE: (u32, u32) = (16, 64);

/// Mega-context: response token bounds, inclusive.
pub const MEGA_RESPONSE: (u32, u32) = (64, 256);
/// Mega-context: prompts start at this fraction of the remaining
/// context budget (`max_model_len - response`) — "near the cap".
pub const MEGA_PROMPT_FLOOR_FRAC: f64 = 0.70;

/// Thundering herd: arrival waves and their spacing.
pub const HERD_WAVES: usize = 3;
pub const HERD_WAVE_GAP_S: f64 = 30.0;
/// Within-wave arrival rate multiplier over the base request rate.
pub const HERD_SPIKE: f64 = 20.0;
/// Herd conversations: turns (inclusive), think times, token bounds.
pub const HERD_TURNS_MIN: usize = 2;
pub const HERD_TURNS_MAX: usize = 6;
pub const HERD_THINK_MIN_S: f64 = 0.5;
pub const HERD_THINK_MAX_S: f64 = 3.0;
pub const HERD_PROMPT: (u32, u32) = (32, 256);
pub const HERD_RESPONSE: (u32, u32) = (32, 192);
/// Mid-run drain: which replica fails, and how long after the second
/// wave's first arrival. Anchoring to the wave (not a span fraction)
/// guarantees the drained replica holds live multi-turn conversations
/// at the event — a fraction could land in the silent gap between
/// waves, where a drain would migrate nothing.
pub const HERD_DRAIN_REPLICA: usize = 1;
pub const HERD_DRAIN_DELAY_S: f64 = 1.0;
/// How long after the drain the replica re-joins the placement
/// rotation. Sized to land inside the inter-wave gap (well before the
/// third wave at 2·[`HERD_WAVE_GAP_S`]), so the rejoined replica
/// provably receives wave-3 placements — the drain→rejoin cycle is
/// exercised, not just scheduled.
pub const HERD_REJOIN_DELAY_S: f64 = 20.0;

/// Diurnal: full load-wave periods the arrival span covers, and the
/// modulation depth (`rate · (1 ± amplitude)` at peak/trough).
pub const DIURNAL_PERIODS: f64 = 2.0;
pub const DIURNAL_AMPLITUDE: f64 = 0.8;

/// Mid-run replica drain/failure request: the cluster router stops
/// placing work on `replica` once its clock passes `at`, and every held
/// conversation migrates off on its next turn. An optional `rejoin_at`
/// returns the replica to the placement rotation later (recovery after
/// a rolling restart rather than a permanent loss).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DrainPlan {
    pub replica: usize,
    pub at: Ns,
    /// Re-join time (must be after `at`); `None` = drained for good.
    pub rejoin_at: Option<Ns>,
}

/// Generator knobs the gauntlet exposes as CLI flags
/// (`--herd-spike`, `--think-floor`); defaults reproduce the canonical
/// scenarios byte-for-byte.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScenarioParams {
    /// Within-wave arrival rate multiplier for the thundering herd
    /// (default [`HERD_SPIKE`]): higher = tighter, more adversarial
    /// bursts.
    pub herd_spike: f64,
    /// Lower bound on agentic think times, seconds (default
    /// [`AGENTIC_THINK_MIN_S`]): the floor the prefetch lookahead gets
    /// to work with.
    pub agentic_think_floor_s: f64,
}

impl Default for ScenarioParams {
    fn default() -> Self {
        ScenarioParams {
            herd_spike: HERD_SPIKE,
            agentic_think_floor_s: AGENTIC_THINK_MIN_S,
        }
    }
}

/// One scenario's full deterministic workload.
#[derive(Clone, Debug)]
pub struct ScenarioWorkload {
    pub conversations: Vec<Conversation>,
    pub arrivals: ArrivalTrace,
    /// Replica drain event (thundering herd only).
    pub drain: Option<DrainPlan>,
}

/// One scenario of the fleet (see module docs for what each stresses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioSpec {
    Agentic,
    MegaContext {
        /// Context cap every prompt+response stays within (the
        /// `[scheduler] max_seq_len` of the run's config).
        max_model_len: usize,
    },
    ThunderingHerd,
    Diurnal,
}

impl ScenarioSpec {
    /// The whole fleet in canonical (gauntlet row) order.
    pub fn all(max_model_len: usize) -> Vec<ScenarioSpec> {
        vec![
            ScenarioSpec::Agentic,
            ScenarioSpec::MegaContext { max_model_len },
            ScenarioSpec::ThunderingHerd,
            ScenarioSpec::Diurnal,
        ]
    }

    pub fn by_name(s: &str, max_model_len: usize) -> Option<ScenarioSpec> {
        match s {
            "agentic" => Some(ScenarioSpec::Agentic),
            "mega_context" | "mega-context" | "mega" => {
                Some(ScenarioSpec::MegaContext { max_model_len })
            }
            "thundering_herd" | "thundering-herd" | "herd" => {
                Some(ScenarioSpec::ThunderingHerd)
            }
            "diurnal" => Some(ScenarioSpec::Diurnal),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ScenarioSpec::Agentic => "agentic",
            ScenarioSpec::MegaContext { .. } => "mega_context",
            ScenarioSpec::ThunderingHerd => "thundering_herd",
            ScenarioSpec::Diurnal => "diurnal",
        }
    }

    /// Whether the generator guarantees zero max-model-len rejections
    /// by construction (the gauntlet asserts it as an invariant).
    pub fn expect_rejection_free(&self) -> bool {
        matches!(self, ScenarioSpec::MegaContext { .. })
    }

    /// Generate the scenario's workload: `conversations` conversations,
    /// base arrival rate `request_rate`/s, everything derived from
    /// `seed` via tagged sub-streams (conversation shapes, tenant
    /// assignment, and arrivals never share draws). Canonical
    /// [`ScenarioParams::default`] knobs.
    pub fn build(
        &self,
        conversations: usize,
        request_rate: f64,
        seed: u64,
    ) -> ScenarioWorkload {
        self.build_with(conversations, request_rate, seed, &ScenarioParams::default())
    }

    /// [`ScenarioSpec::build`] with explicit generator knobs. Default
    /// params are byte-identical to `build` — the knobs multiply into
    /// the same RNG draws, they never add or skip any.
    pub fn build_with(
        &self,
        conversations: usize,
        request_rate: f64,
        seed: u64,
        params: &ScenarioParams,
    ) -> ScenarioWorkload {
        match *self {
            ScenarioSpec::Agentic => agentic(conversations, request_rate, seed, params),
            ScenarioSpec::MegaContext { max_model_len } => {
                mega_context(conversations, request_rate, seed, max_model_len)
            }
            ScenarioSpec::ThunderingHerd => herd(conversations, request_rate, seed, params),
            ScenarioSpec::Diurnal => diurnal(conversations, request_rate, seed),
        }
    }
}

fn split_tenants(convs: &mut [Conversation], seed: u64) {
    assign_tenants(
        convs,
        &TenantMix::skewed(SCENARIO_TENANTS, SCENARIO_HEAVY_SHARE),
        seed ^ 0x7E,
    );
}

/// Inclusive uniform draw over a `(lo, hi)` token-bound pair.
fn tokens(rng: &mut Rng, bounds: (u32, u32)) -> u32 {
    rng.range(bounds.0 as u64, bounds.1 as u64 + 1) as u32
}

fn uniform_s(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    lo + rng.f64() * (hi - lo)
}

fn agentic(n: usize, rate: f64, seed: u64, params: &ScenarioParams) -> ScenarioWorkload {
    // The think floor shifts the uniform draw's bounds, never its RNG
    // consumption — raising the ceiling alongside keeps lo ≤ hi for
    // floors past AGENTIC_THINK_MAX_S.
    let think_lo = params.agentic_think_floor_s;
    let think_hi = AGENTIC_THINK_MAX_S.max(think_lo);
    let mut rng = Rng::new(seed ^ 0xA9E7_71C0);
    let mut convs: Vec<Conversation> = (0..n)
        .map(|id| {
            let n_turns = rng.usize(AGENTIC_TURNS_MIN, AGENTIC_TURNS_MAX + 1);
            let turns = (0..n_turns)
                .map(|t| Turn {
                    prompt_tokens: if t == 0 {
                        tokens(&mut rng, AGENTIC_FIRST_PROMPT)
                    } else {
                        tokens(&mut rng, AGENTIC_TOOL_PROMPT)
                    },
                    response_tokens: tokens(&mut rng, AGENTIC_RESPONSE),
                    think_time_s: if t == 0 {
                        0.0
                    } else {
                        uniform_s(&mut rng, think_lo, think_hi)
                    },
                })
                .collect();
            Conversation { id: id as u64, tenant: 0, prefix: None, turns }
        })
        .collect();
    split_tenants(&mut convs, seed);
    let arrivals = ArrivalTrace::poisson(&convs, rate, seed ^ 0x5EED);
    ScenarioWorkload { conversations: convs, arrivals, drain: None }
}

fn mega_context(n: usize, rate: f64, seed: u64, max_model_len: usize) -> ScenarioWorkload {
    let mut rng = Rng::new(seed ^ 0x3E6A_C027);
    let mut convs: Vec<Conversation> = (0..n)
        .map(|id| {
            let response = tokens(&mut rng, MEGA_RESPONSE);
            // Rejection-free by construction: prompt + response never
            // exceeds the context cap (and the cap itself sits far
            // below the GPU-capacity admission bound on the testbed
            // presets, so the max-model-len rule can never fire).
            let budget = (max_model_len as u64).saturating_sub(response as u64).max(8);
            let floor = ((budget as f64) * MEGA_PROMPT_FLOOR_FRAC) as u64;
            let prompt = rng.range(floor.max(8), budget + 1) as u32;
            Conversation {
                id: id as u64,
                tenant: 0,
                prefix: None,
                turns: vec![Turn {
                    prompt_tokens: prompt,
                    response_tokens: response,
                    think_time_s: 0.0,
                }],
            }
        })
        .collect();
    split_tenants(&mut convs, seed);
    let arrivals = ArrivalTrace::poisson(&convs, rate, seed ^ 0x5EED);
    ScenarioWorkload { conversations: convs, arrivals, drain: None }
}

fn herd(n: usize, rate: f64, seed: u64, params: &ScenarioParams) -> ScenarioWorkload {
    let mut rng = Rng::new(seed ^ 0x4E8D_11B2);
    let mut convs: Vec<Conversation> = (0..n)
        .map(|id| {
            let n_turns = rng.usize(HERD_TURNS_MIN, HERD_TURNS_MAX + 1);
            let turns = (0..n_turns)
                .map(|t| Turn {
                    prompt_tokens: tokens(&mut rng, HERD_PROMPT),
                    response_tokens: tokens(&mut rng, HERD_RESPONSE),
                    think_time_s: if t == 0 {
                        0.0
                    } else {
                        uniform_s(&mut rng, HERD_THINK_MIN_S, HERD_THINK_MAX_S)
                    },
                })
                .collect();
            Conversation { id: id as u64, tenant: 0, prefix: None, turns }
        })
        .collect();
    split_tenants(&mut convs, seed);

    // Synchronized waves: conversations split into HERD_WAVES contiguous
    // chunks, each arriving in a tight burst at `params.herd_spike`
    // (canonically HERD_SPIKE) times the base rate; waves start
    // HERD_WAVE_GAP_S apart. `t.max(wave_start)` keeps arrivals monotone
    // even if a wave overruns its gap.
    let mut arr_rng = Rng::new(seed ^ 0x5EED ^ 0x4E8D_11B2);
    let mut entries = Vec::with_capacity(n);
    let base = n / HERD_WAVES;
    let extra = n % HERD_WAVES;
    let mut t = 0.0f64;
    let mut next = 0usize;
    let mut second_wave_start: Option<f64> = None;
    for wave in 0..HERD_WAVES {
        let count = base + usize::from(wave < extra);
        t = t.max(wave as f64 * HERD_WAVE_GAP_S);
        for _ in 0..count {
            t += arr_rng.exp(rate * params.herd_spike);
            if wave == 1 && second_wave_start.is_none() {
                second_wave_start = Some(t);
            }
            entries.push(TraceEntry {
                conversation: convs[next].id,
                arrival: (t * SEC as f64) as Ns,
            });
            next += 1;
        }
    }
    let arrivals = ArrivalTrace { entries };
    // Drain while the second wave is live: its conversations all have
    // ≥ HERD_TURNS_MIN turns and ≥ HERD_THINK_MIN_S think times, so the
    // drained replica provably holds work whose next turns must migrate
    // off. (Degenerate single-wave workloads fall back to mid-span.)
    // The replica re-joins in the inter-wave gap, before the third
    // wave — the router must route wave-3 placements back onto it.
    let drain_at_s = second_wave_start
        .map(|w| w + HERD_DRAIN_DELAY_S)
        .unwrap_or_else(|| arrivals.span() as f64 * 0.45 / SEC as f64);
    let drain = DrainPlan {
        replica: HERD_DRAIN_REPLICA,
        at: (drain_at_s * SEC as f64) as Ns,
        rejoin_at: Some(((drain_at_s + HERD_REJOIN_DELAY_S) * SEC as f64) as Ns),
    };
    ScenarioWorkload { conversations: convs, arrivals, drain: Some(drain) }
}

fn diurnal(n: usize, rate: f64, seed: u64) -> ScenarioWorkload {
    // Conversation shapes are the calibrated ShareGPT clone — the
    // scenario's stress is the load wave, not the per-request shape.
    let mut convs = generate(&ShareGptConfig::default(), n, seed ^ 0xD1);
    split_tenants(&mut convs, seed);

    // Non-homogeneous Poisson via thinning: candidates at the peak rate
    // λmax, accepted with probability λ(t)/λmax where
    // λ(t) = rate · (1 + A·sin(2πt/period)). The period is sized so the
    // expected span (n/rate seconds) covers DIURNAL_PERIODS full waves.
    let mut rng = Rng::new(seed ^ 0x5EED ^ 0xD1FF_A301);
    let period_s = (n as f64 / (rate * DIURNAL_PERIODS)).max(1.0);
    let lmax = rate * (1.0 + DIURNAL_AMPLITUDE);
    let mut t = 0.0f64;
    let entries = convs
        .iter()
        .map(|c| {
            loop {
                t += rng.exp(lmax);
                let phase = 2.0 * std::f64::consts::PI * t / period_s;
                let lam = rate * (1.0 + DIURNAL_AMPLITUDE * phase.sin());
                if rng.f64() * lmax <= lam {
                    break;
                }
            }
            TraceEntry {
                conversation: c.id,
                arrival: (t * SEC as f64) as Ns,
            }
        })
        .collect();
    ScenarioWorkload {
        conversations: convs,
        arrivals: ArrivalTrace { entries },
        drain: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LEN: usize = 4096;

    #[test]
    fn fleet_has_four_scenarios_and_names_round_trip() {
        let fleet = ScenarioSpec::all(LEN);
        assert_eq!(fleet.len(), 4);
        for s in &fleet {
            assert_eq!(ScenarioSpec::by_name(s.label(), LEN), Some(*s));
        }
        assert_eq!(ScenarioSpec::by_name("mega", LEN), ScenarioSpec::by_name("mega_context", LEN));
        assert_eq!(ScenarioSpec::by_name("herd", LEN), ScenarioSpec::by_name("thundering_herd", LEN));
        assert_eq!(ScenarioSpec::by_name("bogus", LEN), None);
    }

    #[test]
    fn every_scenario_is_deterministic_per_seed() {
        for spec in ScenarioSpec::all(LEN) {
            let a = spec.build(40, 2.0, 7);
            let b = spec.build(40, 2.0, 7);
            assert_eq!(a.conversations.len(), 40);
            assert_eq!(a.drain, b.drain);
            for (x, y) in a.conversations.iter().zip(&b.conversations) {
                assert_eq!(x.tenant, y.tenant);
                assert_eq!(x.turns.len(), y.turns.len());
                for (t, u) in x.turns.iter().zip(&y.turns) {
                    assert_eq!(t.prompt_tokens, u.prompt_tokens);
                    assert_eq!(t.response_tokens, u.response_tokens);
                    assert_eq!(t.think_time_s, u.think_time_s);
                }
            }
            for (x, y) in a.arrivals.entries.iter().zip(&b.arrivals.entries) {
                assert_eq!(x.arrival, y.arrival);
            }
            let c = spec.build(40, 2.0, 8);
            assert!(
                a.arrivals.entries.iter().zip(&c.arrivals.entries).any(|(x, y)| x.arrival != y.arrival),
                "{}: seed change must change the workload",
                spec.label()
            );
        }
    }

    #[test]
    fn agentic_stays_within_spec_bounds() {
        let wl = ScenarioSpec::Agentic.build(60, 2.0, 3);
        for c in &wl.conversations {
            assert!((AGENTIC_TURNS_MIN..=AGENTIC_TURNS_MAX).contains(&c.turns.len()));
            assert_eq!(c.turns[0].think_time_s, 0.0);
            for (i, t) in c.turns.iter().enumerate() {
                if i > 0 {
                    assert!(t.think_time_s >= AGENTIC_THINK_MIN_S);
                    assert!(t.think_time_s < AGENTIC_THINK_MAX_S);
                    assert!((AGENTIC_TOOL_PROMPT.0..=AGENTIC_TOOL_PROMPT.1).contains(&t.prompt_tokens));
                } else {
                    assert!((AGENTIC_FIRST_PROMPT.0..=AGENTIC_FIRST_PROMPT.1).contains(&t.prompt_tokens));
                }
                assert!((AGENTIC_RESPONSE.0..=AGENTIC_RESPONSE.1).contains(&t.response_tokens));
            }
        }
    }

    #[test]
    fn mega_context_is_single_turn_near_but_under_the_cap() {
        let wl = ScenarioSpec::MegaContext { max_model_len: LEN }.build(60, 1.0, 5);
        for c in &wl.conversations {
            assert_eq!(c.turns.len(), 1);
            let total = c.turns[0].prompt_tokens as usize + c.turns[0].response_tokens as usize;
            assert!(total <= LEN, "conv {} context {total} > {LEN}", c.id);
            assert!(
                c.turns[0].prompt_tokens as f64 >= MEGA_PROMPT_FLOOR_FRAC * 0.9 * LEN as f64,
                "prompt {} not near the cap",
                c.turns[0].prompt_tokens
            );
        }
    }

    #[test]
    fn herd_waves_are_separated_and_drain_lands_mid_run() {
        let wl = ScenarioSpec::ThunderingHerd.build(90, 1.0, 11);
        for w in wl.arrivals.entries.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "arrivals must be monotone");
        }
        // Inter-wave silence: with ~30-conv waves at 20 req/s the wave
        // spread is ~1.5 s against a 30 s gap — at least HERD_WAVES-1
        // gaps far exceed any in-wave spacing.
        let big_gaps = wl
            .arrivals
            .entries
            .windows(2)
            .filter(|w| w[1].arrival - w[0].arrival > 10 * SEC)
            .count();
        assert!(big_gaps >= HERD_WAVES - 1, "{big_gaps} inter-wave gaps");
        let d = wl.drain.expect("herd must carry a drain event");
        assert_eq!(d.replica, HERD_DRAIN_REPLICA);
        assert!(d.at > 0 && d.at < wl.arrivals.span());
        // The drain is anchored inside the second wave (first wave-2
        // arrival + delay), never in the silent inter-wave gap: with 90
        // conversations the waves are thirds of the entry list.
        let wave2_first = wl.arrivals.entries[30].arrival;
        let wave3_first = wl.arrivals.entries[60].arrival;
        assert!(
            d.at > wave2_first && d.at < wave3_first,
            "drain {} outside wave 2 [{wave2_first}, {wave3_first})",
            d.at
        );
        // The rejoin lands in the gap before wave 3, so the recovered
        // replica is back in rotation when the third wave hits.
        let rejoin = d.rejoin_at.expect("herd drain must schedule a rejoin");
        assert!(
            rejoin > d.at && rejoin < wave3_first,
            "rejoin {rejoin} outside (drain {}, wave 3 {wave3_first})",
            d.at
        );
    }

    #[test]
    fn params_knobs_shift_generators_without_new_rng_draws() {
        // Default params reproduce build() byte-for-byte.
        let canon = ScenarioSpec::ThunderingHerd.build(60, 1.0, 11);
        let explicit = ScenarioSpec::ThunderingHerd.build_with(
            60,
            1.0,
            11,
            &ScenarioParams::default(),
        );
        assert_eq!(canon.drain, explicit.drain);
        assert_eq!(canon.arrivals.entries, explicit.arrivals.entries);
        // A hotter spike compresses in-wave spacing (same exp() draws,
        // scaled) — the first wave's arrivals come strictly earlier.
        let hot = ScenarioSpec::ThunderingHerd.build_with(
            60,
            1.0,
            11,
            &ScenarioParams { herd_spike: 2.0 * HERD_SPIKE, ..Default::default() },
        );
        assert!(hot.arrivals.entries[1].arrival < canon.arrivals.entries[1].arrival);
        // A raised think floor bounds every agentic follow-up turn.
        let floor = 0.4;
        let slow = ScenarioSpec::Agentic.build_with(
            40,
            2.0,
            7,
            &ScenarioParams { agentic_think_floor_s: floor, ..Default::default() },
        );
        for c in &slow.conversations {
            for t in &c.turns[1..] {
                assert!(t.think_time_s >= floor, "think {} under floor", t.think_time_s);
            }
        }
    }

    #[test]
    fn diurnal_span_covers_the_configured_wave_count() {
        let n = 400;
        let rate = 2.0;
        let wl = ScenarioSpec::Diurnal.build(n, rate, 13);
        for w in wl.arrivals.entries.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        // Thinning keeps the long-run average at `rate`, so the span
        // should sit near n/rate seconds (= DIURNAL_PERIODS periods).
        let span_s = wl.arrivals.span() as f64 / SEC as f64;
        let expect = n as f64 / rate;
        assert!(
            span_s > 0.5 * expect && span_s < 2.0 * expect,
            "span {span_s:.1}s vs expected ≈{expect:.1}s"
        );
    }

    #[test]
    fn every_scenario_spans_all_tenants() {
        for spec in ScenarioSpec::all(LEN) {
            let wl = spec.build(80, 2.0, 17);
            let mut seen: Vec<u32> = wl.conversations.iter().map(|c| c.tenant).collect();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(
                seen.len(),
                SCENARIO_TENANTS,
                "{}: tenants {seen:?}",
                spec.label()
            );
        }
    }
}
