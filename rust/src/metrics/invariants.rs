//! End-to-end invariant checks shared by the scenario gauntlet
//! (`exp gauntlet`) and the e2e test suite.
//!
//! Every gauntlet cell — each preemption policy × scenario pair — is
//! audited with the same checks after its run, so a regression that
//! keeps the summary numbers plausible but corrupts the underlying
//! accounting (a leaked block, a stall bucket double-count, a lost
//! conversation during a drain) still fails loudly:
//!
//! - **block conservation** — GPU used + free equals capacity at end of
//!   run, and the CPU swap space never exceeds its slot capacity;
//! - **stall-bucket partition** — per iteration, decode-interference
//!   stall is bounded by inference time, sample timestamps are
//!   monotone, and the summed critical-path buckets (inference + swap
//!   stall + scheduler overhead) fit inside the run's span;
//! - **served-token accounting** — the per-tenant token split sums back
//!   to the total, and (cluster runs) every dispatched conversation is
//!   either finished or rejected — nothing is lost or served twice
//!   across migrations and drains;
//! - **monotone VTC** — when an online VTC-family policy ran, every
//!   final virtual-time counter is finite, non-negative, and at least
//!   the tenant's served tokens (charges are weighted ≥ 1 per token and
//!   counters are only ever lifted, never decreased);
//! - **prefix-pool accounting** — the global prefix cache's counters
//!   close: saved tokens equal hit blocks × block size, live pool
//!   blocks equal inserts − evictions and are a subset of the used GPU
//!   blocks (pool blocks are allocated from the same space, so GPU
//!   conservation above already covers them), and no request pin
//!   dangles once the run has drained.
//!
//! Checks return violations as strings rather than panicking so the
//! gauntlet can finish writing its scorecard (with the violation count
//! per cell) before failing the run.

use crate::cluster::ClusterOutcome;
use crate::coordinator::engine::ServeOutcome;

/// Audit one engine outcome. Returns one message per violated
/// invariant; empty means clean.
pub fn check_engine(out: &ServeOutcome) -> Vec<String> {
    let mut v = Vec::new();
    let label = &out.label;

    // Block conservation.
    if out.gpu_blocks_used_final + out.gpu_blocks_free_final != out.gpu_blocks_capacity {
        v.push(format!(
            "[{label}] gpu block conservation: used {} + free {} != capacity {}",
            out.gpu_blocks_used_final, out.gpu_blocks_free_final, out.gpu_blocks_capacity
        ));
    }
    if out.cpu_blocks_used_final > out.cpu_blocks_capacity {
        v.push(format!(
            "[{label}] cpu slots over capacity: {} > {}",
            out.cpu_blocks_used_final, out.cpu_blocks_capacity
        ));
    }

    // Stall-bucket partition.
    let mut prev_at = 0;
    let (mut inf, mut swap, mut sched) = (0u128, 0u128, 0u128);
    for (i, s) in out.recorder.iterations.iter().enumerate() {
        if s.at < prev_at {
            v.push(format!(
                "[{label}] iteration {i}: timestamp {} before predecessor {prev_at}",
                s.at
            ));
        }
        prev_at = s.at;
        if s.decode_block_ns > s.inference_ns {
            v.push(format!(
                "[{label}] iteration {i}: decode-interference {} exceeds inference {}",
                s.decode_block_ns, s.inference_ns
            ));
        }
        inf += s.inference_ns as u128;
        swap += s.swap_stall_ns as u128;
        sched += s.sched_overhead_ns as u128;
    }
    if inf + swap + sched > out.span as u128 {
        v.push(format!(
            "[{label}] critical-path buckets exceed span: {inf} + {swap} + {sched} > {}",
            out.span
        ));
    }

    // Served-token accounting (per-tenant split vs total).
    let by_tenant: u64 = out.recorder.tokens_by_tenant().iter().map(|&(_, n)| n).sum();
    if by_tenant != out.recorder.total_tokens {
        v.push(format!(
            "[{label}] token split {} != total {}",
            by_tenant, out.recorder.total_tokens
        ));
    }
    if out.recorder.finished_conversations > out.recorder.finished_turns {
        v.push(format!(
            "[{label}] finished conversations {} exceed finished turns {}",
            out.recorder.finished_conversations, out.recorder.finished_turns
        ));
    }

    // Monotone VTC: counters are lifted-only, so the final value must
    // cover at least the tenant's served tokens (every token charges a
    // weight ≥ 1; mid-prompt prefill chunks only add more).
    if !out.vtc_counters.is_empty() {
        for &(tenant, counter) in &out.vtc_counters {
            if !counter.is_finite() || counter < 0.0 {
                v.push(format!(
                    "[{label}] vtc counter for tenant {tenant} not finite/non-negative: {counter}"
                ));
            }
        }
        for &(tenant, tokens) in &out.recorder.tokens_by_tenant() {
            if tokens == 0 {
                continue;
            }
            let counter = out
                .vtc_counters
                .iter()
                .find(|&&(t, _)| t == tenant)
                .map(|&(_, c)| c);
            match counter {
                None => v.push(format!(
                    "[{label}] tenant {tenant} served {tokens} tokens but has no vtc counter"
                )),
                Some(c) if c + 1e-9 < tokens as f64 => v.push(format!(
                    "[{label}] vtc counter for tenant {tenant} below served tokens: {c} < {tokens}"
                )),
                _ => {}
            }
        }
    }

    // Prefix-pool accounting.
    let rec = &out.recorder;
    if rec.prefix_saved_tokens != rec.prefix_hit_blocks * out.block_size as u64 {
        v.push(format!(
            "[{label}] prefix saved tokens {} != hit blocks {} x block size {}",
            rec.prefix_saved_tokens, rec.prefix_hit_blocks, out.block_size
        ));
    }
    if rec.prefix_hits > 0 && rec.prefix_hit_blocks < rec.prefix_hits {
        v.push(format!(
            "[{label}] prefix hit blocks {} below hit count {} (every hit pins >= 1 block)",
            rec.prefix_hit_blocks, rec.prefix_hits
        ));
    }
    if rec.prefix_inserts < rec.prefix_evicted_blocks
        || out.prefix_blocks_final as u64 != rec.prefix_inserts - rec.prefix_evicted_blocks
    {
        v.push(format!(
            "[{label}] prefix pool conservation: live {} != inserts {} - evictions {}",
            out.prefix_blocks_final, rec.prefix_inserts, rec.prefix_evicted_blocks
        ));
    }
    if out.prefix_blocks_final > out.gpu_blocks_used_final {
        v.push(format!(
            "[{label}] prefix pool blocks {} exceed used gpu blocks {}",
            out.prefix_blocks_final, out.gpu_blocks_used_final
        ));
    }
    if out.prefix_pinned_refs_final != 0 {
        v.push(format!(
            "[{label}] {} prefix pins dangle after the run drained \
             (a finished/rejected/migrated request failed to release its path)",
            out.prefix_pinned_refs_final
        ));
    }
    v
}

/// Audit a cluster outcome: every replica's engine invariants, plus the
/// router-level accounting. `total_conversations` is the dispatched
/// workload size; `expect_rejection_free` asserts the scenario's
/// by-construction guarantee (mega-context sizes every request under
/// the admission bound).
pub fn check_cluster(
    out: &ClusterOutcome,
    total_conversations: u64,
    expect_rejection_free: bool,
) -> Vec<String> {
    let mut v = Vec::new();
    for r in &out.replicas {
        v.extend(check_engine(r));
    }
    let finished = out.finished_conversations();
    let rejected = out.rejected_conversations();
    if finished + rejected != total_conversations {
        v.push(format!(
            "[{}] conversation accounting: finished {finished} + rejected {rejected} != dispatched {total_conversations}",
            out.label
        ));
    }
    if expect_rejection_free && rejected > 0 {
        v.push(format!(
            "[{}] scenario is rejection-free by construction but {rejected} conversations were rejected",
            out.label
        ));
    }
    if out.affinity_hits > out.affinity_decisions {
        v.push(format!(
            "[{}] affinity hits {} exceed decisions {}",
            out.label, out.affinity_hits, out.affinity_decisions
        ));
    }
    if out.migrations > out.affinity_decisions {
        v.push(format!(
            "[{}] migrations {} exceed later-turn placements {}",
            out.label, out.migrations, out.affinity_decisions
        ));
    }
    if out.affinity_decisions > out.placements {
        v.push(format!(
            "[{}] later-turn placements {} exceed total placements {}",
            out.label, out.affinity_decisions, out.placements
        ));
    }
    if let Some((replica, _)) = out.drain {
        if replica >= out.replicas.len() {
            v.push(format!(
                "[{}] drain target {replica} out of range ({} replicas)",
                out.label,
                out.replicas.len()
            ));
        }
    }
    if let Some((replica, at)) = out.rejoin {
        match out.drain {
            None => v.push(format!(
                "[{}] rejoin without a drain event",
                out.label
            )),
            Some((drained, drain_at)) => {
                if replica != drained {
                    v.push(format!(
                        "[{}] rejoin replica {replica} != drained replica {drained}",
                        out.label
                    ));
                }
                if at <= drain_at {
                    v.push(format!(
                        "[{}] rejoin at {at} not after drain at {drain_at}",
                        out.label
                    ));
                }
            }
        }
    }
    let split: u64 = out.tokens_by_tenant().iter().map(|&(_, n)| n).sum();
    if split != out.total_tokens() {
        v.push(format!(
            "[{}] cluster token split {split} != total {}",
            out.label,
            out.total_tokens()
        ));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{IterationSample, Recorder};

    fn clean_outcome() -> ServeOutcome {
        let mut rec = Recorder::default();
        rec.turn_arrival(1, 0, 0, 0);
        rec.token(1, 0, 1_000);
        rec.token(1, 0, 2_000);
        rec.turn_finished(1, 0);
        rec.finished_conversations = 1;
        rec.iteration(IterationSample {
            at: 1_000,
            inference_ns: 800,
            swap_stall_ns: 100,
            sched_overhead_ns: 50,
            decode_block_ns: 200,
            tokens: 1,
            batch: 1,
            ..Default::default()
        });
        rec.iteration(IterationSample {
            at: 2_000,
            inference_ns: 700,
            decode_block_ns: 0,
            tokens: 1,
            batch: 1,
            ..Default::default()
        });
        ServeOutcome {
            recorder: rec,
            span: 2_000,
            iterations: 2,
            swap_stats: Default::default(),
            reuse_blocks_transferred: 0,
            reuse_blocks_reused: 0,
            contaminated: 0,
            label: "test".into(),
            trace: Vec::new(),
            gpu_blocks_used_final: 0,
            gpu_blocks_free_final: 100,
            gpu_blocks_capacity: 100,
            cpu_blocks_used_final: 3,
            cpu_blocks_capacity: 50,
            vtc_counters: vec![(0, 4.0)],
            block_size: 4,
            prefix_blocks_final: 0,
            prefix_pinned_refs_final: 0,
        }
    }

    #[test]
    fn clean_outcome_passes() {
        assert_eq!(check_engine(&clean_outcome()), Vec::<String>::new());
    }

    #[test]
    fn block_leak_is_caught() {
        let mut o = clean_outcome();
        o.gpu_blocks_free_final = 98; // two blocks vanished
        let v = check_engine(&o);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("gpu block conservation"), "{v:?}");
    }

    #[test]
    fn cpu_overflow_is_caught() {
        let mut o = clean_outcome();
        o.cpu_blocks_used_final = 51;
        assert!(check_engine(&o)[0].contains("cpu slots over capacity"));
    }

    #[test]
    fn stall_partition_violations_are_caught() {
        // Decode interference larger than the iteration's inference.
        let mut o = clean_outcome();
        o.recorder.iterations[0].decode_block_ns = 900;
        assert!(check_engine(&o)[0].contains("decode-interference"));
        // Non-monotone timestamps.
        let mut o = clean_outcome();
        o.recorder.iterations[1].at = 500;
        assert!(check_engine(&o)[0].contains("before predecessor"));
        // Buckets summing past the span.
        let mut o = clean_outcome();
        o.span = 1_000;
        assert!(check_engine(&o)
            .iter()
            .any(|m| m.contains("exceed span")));
    }

    #[test]
    fn vtc_violations_are_caught() {
        // Counter below served tokens (2 tokens, counter 1.0).
        let mut o = clean_outcome();
        o.vtc_counters = vec![(0, 1.0)];
        assert!(check_engine(&o)[0].contains("below served tokens"));
        // Served tenant missing from the counters.
        let mut o = clean_outcome();
        o.vtc_counters = vec![(7, 10.0)];
        assert!(check_engine(&o)
            .iter()
            .any(|m| m.contains("no vtc counter")));
        // NaN counter.
        let mut o = clean_outcome();
        o.vtc_counters = vec![(0, f64::NAN)];
        assert!(check_engine(&o)
            .iter()
            .any(|m| m.contains("not finite")));
        // Empty counters (trace policy): VTC checks are skipped.
        let mut o = clean_outcome();
        o.vtc_counters = Vec::new();
        assert!(check_engine(&o).is_empty());
    }

    #[test]
    fn prefix_pool_violations_are_caught() {
        // Saved-token identity: 2 hit blocks at block size 4 must save 8.
        let mut o = clean_outcome();
        o.recorder.prefix_hits = 1;
        o.recorder.prefix_hit_blocks = 2;
        o.recorder.prefix_saved_tokens = 7;
        assert!(check_engine(&o)[0].contains("prefix saved tokens"));
        // Hit without a block.
        let mut o = clean_outcome();
        o.recorder.prefix_hits = 1;
        assert!(check_engine(&o)
            .iter()
            .any(|m| m.contains("below hit count")));
        // Pool conservation: live != inserts − evictions.
        let mut o = clean_outcome();
        o.recorder.prefix_inserts = 3;
        o.recorder.prefix_evicted_blocks = 1;
        assert!(check_engine(&o)
            .iter()
            .any(|m| m.contains("prefix pool conservation")));
        // Pool blocks exceeding the used-GPU footprint.
        let mut o = clean_outcome();
        o.recorder.prefix_inserts = 2;
        o.prefix_blocks_final = 2; // gpu_blocks_used_final is 0
        assert!(check_engine(&o)
            .iter()
            .any(|m| m.contains("exceed used gpu blocks")));
        // Dangling pin after drain (the migration regression's surface).
        let mut o = clean_outcome();
        o.prefix_pinned_refs_final = 1;
        assert!(check_engine(&o).iter().any(|m| m.contains("dangle")));
        // A consistent prefix run is clean.
        let mut o = clean_outcome();
        o.recorder.prefix_hits = 1;
        o.recorder.prefix_hit_blocks = 2;
        o.recorder.prefix_saved_tokens = 8;
        o.recorder.prefix_inserts = 3;
        o.recorder.prefix_evicted_blocks = 1;
        o.prefix_blocks_final = 2;
        o.gpu_blocks_used_final = 2;
        o.gpu_blocks_free_final = 98;
        assert_eq!(check_engine(&o), Vec::<String>::new());
    }

    fn clean_cluster() -> ClusterOutcome {
        use crate::cluster::PlacementKind;
        ClusterOutcome {
            replicas: vec![clean_outcome()],
            placement: PlacementKind::LeastLoaded,
            label: "cluster".into(),
            placements: 5,
            drain: Some((0, 1_000)),
            rejoin: None,
            affinity_decisions: 4,
            affinity_hits: 2,
            migrations: 2,
            retransferred_blocks_on_migration: 0,
            router_trace: Vec::new(),
        }
    }

    #[test]
    fn cluster_accounting_is_checked() {
        assert_eq!(check_cluster(&clean_cluster(), 1, true), Vec::<String>::new());
        // One conversation lost.
        assert!(check_cluster(&clean_cluster(), 2, false)[0].contains("conversation accounting"));
        // Rejection-free scenario that rejected.
        let mut rej = clean_cluster();
        rej.replicas[0].recorder.rejected_conversations = 1;
        assert!(check_cluster(&rej, 2, true)
            .iter()
            .any(|m| m.contains("rejection-free")));
        // Router counter inversions.
        let mut inv = clean_cluster();
        inv.affinity_hits = 9;
        assert!(check_cluster(&inv, 1, false)
            .iter()
            .any(|m| m.contains("affinity hits")));
        let mut oob = clean_cluster();
        oob.drain = Some((3, 1_000));
        assert!(check_cluster(&oob, 1, false)
            .iter()
            .any(|m| m.contains("out of range")));
    }

    #[test]
    fn rejoin_consistency_is_checked() {
        // A matching drain → rejoin pair is clean.
        let mut ok = clean_cluster();
        ok.rejoin = Some((0, 2_000));
        assert_eq!(check_cluster(&ok, 1, true), Vec::<String>::new());
        // Rejoin with no drain at all.
        let mut orphan = clean_cluster();
        orphan.drain = None;
        orphan.rejoin = Some((0, 2_000));
        assert!(check_cluster(&orphan, 1, false)
            .iter()
            .any(|m| m.contains("rejoin without a drain")));
        // Rejoin of a different replica than the drained one.
        let mut wrong = clean_cluster();
        wrong.rejoin = Some((1, 2_000));
        assert!(check_cluster(&wrong, 1, false)
            .iter()
            .any(|m| m.contains("!= drained replica")));
        // Rejoin not after the drain.
        let mut early = clean_cluster();
        early.rejoin = Some((0, 1_000));
        assert!(check_cluster(&early, 1, false)
            .iter()
            .any(|m| m.contains("not after drain")));
    }
}
