//! Serving metrics: TTFT / TBT / throughput, stall accounting, and the
//! token-generation-efficiency windows of Fig. 12.
//!
//! TTFT is measured **per turn** (paper §4: "latency experienced ...
//! before the first token of each turn is generated"); TBT is the gap
//! between consecutive generated tokens of the same turn.

use crate::memory::RequestId;
use crate::sim::clock::{to_secs, Ns};
use crate::util::stats::Percentiles;
use std::collections::HashMap;

/// Per-iteration engine telemetry (Figs. 1, 2, 9, 12).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterationSample {
    pub at: Ns,
    /// Pure model execution time.
    pub inference_ns: Ns,
    /// Swap-induced stall on the critical path.
    pub swap_stall_ns: Ns,
    /// Scheduler/bookkeeping time on the critical path (call-stack
    /// overhead, Fig. 9).
    pub sched_overhead_ns: Ns,
    /// Decode tokens produced this iteration.
    pub tokens: u32,
    /// Prefill iteration (prompt chunks) rather than a decode step.
    pub is_prefill: bool,
    /// Requests in the running batch.
    pub batch: u32,
    /// Requests currently waiting on a KV transfer (Fig. 2).
    pub waiting_on_swap: u32,
}

#[derive(Clone, Debug, Default)]
struct TurnRecord {
    arrival: Ns,
    first_token: Option<Ns>,
    token_times: Vec<Ns>,
}

/// Collects everything the experiment harness needs.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    turns: Vec<TurnRecord>,
    open: HashMap<(RequestId, u32), usize>,
    pub iterations: Vec<IterationSample>,
    pub total_tokens: u64,
    pub finished_turns: u64,
    pub finished_conversations: u64,
    pub preemptions: u64,
    pub recompute_preemptions: u64,
    /// Conversations rejected because their context can never fit the
    /// GPU KV space (the max-model-len admission rule).
    pub rejected_conversations: u64,
}

impl Recorder {
    /// A turn became servable (its request arrived / think time elapsed).
    pub fn turn_arrival(&mut self, req: RequestId, turn: u32, at: Ns) {
        let idx = self.turns.len();
        self.turns.push(TurnRecord {
            arrival: at,
            ..Default::default()
        });
        self.open.insert((req, turn), idx);
    }

    /// A decode/prefill step produced a token for (req, turn).
    pub fn token(&mut self, req: RequestId, turn: u32, at: Ns) {
        if let Some(&idx) = self.open.get(&(req, turn)) {
            let rec = &mut self.turns[idx];
            if rec.first_token.is_none() {
                rec.first_token = Some(at);
            }
            rec.token_times.push(at);
            self.total_tokens += 1;
        }
    }

    pub fn turn_finished(&mut self, req: RequestId, turn: u32) {
        self.open.remove(&(req, turn));
        self.finished_turns += 1;
    }

    pub fn iteration(&mut self, s: IterationSample) {
        self.iterations.push(s);
    }

    // ---- summaries -------------------------------------------------------

    /// TTFT samples in seconds (finished or in-flight turns that produced
    /// a first token).
    pub fn ttft(&self) -> Percentiles {
        Percentiles::from(
            self.turns
                .iter()
                .filter_map(|t| t.first_token.map(|f| to_secs(f - t.arrival)))
                .collect(),
        )
    }

    /// TBT samples in seconds (all inter-token gaps).
    pub fn tbt(&self) -> Percentiles {
        let mut gaps = Vec::new();
        for t in &self.turns {
            for w in t.token_times.windows(2) {
                gaps.push(to_secs(w[1] - w[0]));
            }
        }
        Percentiles::from(gaps)
    }

    /// End-to-end token throughput, tokens/s over `span`.
    pub fn throughput(&self, span: Ns) -> f64 {
        if span == 0 {
            return 0.0;
        }
        self.total_tokens as f64 / to_secs(span)
    }

    /// Fig. 12: token-generation efficiency per fixed-size iteration
    /// window, as percentiles. Efficiency is tokens per second **per
    /// running request**, over *decode* iterations only: prefill
    /// iterations are long/low-token by design, and raw batch-size
    /// variation would otherwise mask the swap stalls the figure is
    /// about.
    pub fn token_gen_efficiency(&self, window: usize) -> Percentiles {
        let decode: Vec<&IterationSample> = self
            .iterations
            .iter()
            .filter(|s| !s.is_prefill && s.batch > 0)
            .collect();
        let mut samples = Vec::new();
        for chunk in decode.chunks(window) {
            if chunk.len() < window {
                break;
            }
            // Per-request tokens (≡ iterations completed) over wall time.
            let per_req_tokens: f64 = chunk
                .iter()
                .map(|s| s.tokens as f64 / s.batch as f64)
                .sum();
            let dur: Ns = chunk
                .iter()
                .map(|s| s.inference_ns + s.swap_stall_ns + s.sched_overhead_ns)
                .sum();
            if dur > 0 {
                samples.push(per_req_tokens / to_secs(dur));
            }
        }
        Percentiles::from(samples)
    }

    /// Fig. 1 / Fig. 10: total stall vs inference on the critical path.
    pub fn stall_breakdown(&self) -> (Ns, Ns, Ns) {
        let inf = self.iterations.iter().map(|s| s.inference_ns).sum();
        let swap = self.iterations.iter().map(|s| s.swap_stall_ns).sum();
        let sched = self.iterations.iter().map(|s| s.sched_overhead_ns).sum();
        (inf, swap, sched)
    }

    /// Fig. 1: per-iteration total latency percentiles with their swap
    /// share — (total_ns, swap_ns) pairs sorted by total.
    pub fn iteration_latency_samples(&self) -> Vec<(f64, f64)> {
        self.iterations
            .iter()
            .map(|s| {
                (
                    (s.inference_ns + s.swap_stall_ns + s.sched_overhead_ns) as f64,
                    s.swap_stall_ns as f64,
                )
            })
            .collect()
    }

    /// Fig. 2: per-iteration fraction of scheduled requests waiting on a
    /// KV transfer (waiters / (batch + waiters)).
    pub fn waiting_on_swap_fractions(&self) -> Vec<f64> {
        self.iterations
            .iter()
            .filter(|s| s.batch + s.waiting_on_swap > 0)
            .map(|s| s.waiting_on_swap as f64 / (s.batch + s.waiting_on_swap) as f64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::{MS, SEC};

    #[test]
    fn ttft_per_turn() {
        let mut r = Recorder::default();
        r.turn_arrival(1, 0, 0);
        r.token(1, 0, 2 * SEC);
        r.token(1, 0, 2 * SEC + 100 * MS);
        r.turn_finished(1, 0);
        r.turn_arrival(1, 1, 10 * SEC);
        r.token(1, 1, 10 * SEC + 500 * MS);
        let ttft = r.ttft();
        assert_eq!(ttft.len(), 2);
        assert!((ttft.min() - 0.5).abs() < 1e-9);
        assert!((ttft.max() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tbt_gaps() {
        let mut r = Recorder::default();
        r.turn_arrival(1, 0, 0);
        r.token(1, 0, 0);
        r.token(1, 0, 100 * MS);
        r.token(1, 0, 400 * MS);
        let tbt = r.tbt();
        assert_eq!(tbt.len(), 2);
        assert!((tbt.max() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn throughput() {
        let mut r = Recorder::default();
        r.turn_arrival(1, 0, 0);
        for i in 0..100 {
            r.token(1, 0, i * MS);
        }
        assert!((r.throughput(10 * SEC) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_windows() {
        let mut r = Recorder::default();
        for i in 0..10 {
            r.iteration(IterationSample {
                at: i * 10 * MS,
                inference_ns: 10 * MS,
                swap_stall_ns: if i >= 5 { 10 * MS } else { 0 },
                tokens: 8,
                batch: 8,
                ..Default::default()
            });
        }
        let eff = r.token_gen_efficiency(5);
        assert_eq!(eff.len(), 2);
        // Second window has stalls → half the efficiency.
        assert!((eff.max() / eff.min() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn tokens_for_unknown_turn_ignored() {
        let mut r = Recorder::default();
        r.token(9, 0, 0);
        assert_eq!(r.total_tokens, 0);
        assert!(r.ttft().is_empty());
    }
}
