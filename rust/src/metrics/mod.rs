//! Serving metrics: TTFT / TBT / throughput, stall accounting, the
//! token-generation-efficiency windows of Fig. 12, and per-tenant
//! breakdowns (tail latency and token shares) for the fairness policies.
//!
//! TTFT is measured **per turn** (paper §4: "latency experienced ...
//! before the first token of each turn is generated"); TBT is the gap
//! between consecutive generated tokens of the same turn. Every turn is
//! tagged with its owning tenant so fairness experiments can split all
//! of the above by tenant.

pub mod invariants;

use crate::memory::RequestId;
use crate::obs::{EpochProfiler, Reservoir, TelemetryMode};
use crate::sim::clock::{to_secs, Ns};
use crate::util::stats::Percentiles;
use std::collections::HashMap;

/// Per-iteration engine telemetry (Figs. 1, 2, 9, 12).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterationSample {
    pub at: Ns,
    /// Pure model execution time.
    pub inference_ns: Ns,
    /// Swap-induced stall on the critical path.
    pub swap_stall_ns: Ns,
    /// Scheduler/bookkeeping time on the critical path (call-stack
    /// overhead, Fig. 9).
    pub sched_overhead_ns: Ns,
    /// Tokens emitted this iteration (decode steps + prompt-completing
    /// chunks).
    pub tokens: u32,
    /// Pure prefill iteration: prompt chunks ran with no co-scheduled
    /// decode (monolithic prefill, or nothing was decodable). Mixed
    /// chunked iterations count as decode iterations.
    pub is_prefill: bool,
    /// Prompt tokens prefilled this iteration (0 = pure decode).
    pub prefill_tokens: u32,
    /// Decode-interference stall: virtual time decode-ready requests
    /// spent blocked behind (monolithic) or inflated by (co-run chunks)
    /// prefill work this iteration.
    pub decode_block_ns: Ns,
    /// Requests in the running batch.
    pub batch: u32,
    /// Requests currently waiting on a KV transfer (Fig. 2).
    pub waiting_on_swap: u32,
    /// Speculative swap-ins outstanding at iteration end (in flight or
    /// landed-but-unclaimed) — the lookahead prefetcher's pipeline depth
    /// as actually achieved.
    pub prefetch_inflight: u32,
}

#[derive(Clone, Debug, Default)]
struct TurnRecord {
    arrival: Ns,
    tenant: u32,
    first_token: Option<Ns>,
    token_times: Vec<Ns>,
}

/// Collects everything the experiment harness needs.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    turns: Vec<TurnRecord>,
    open: HashMap<(RequestId, u32), usize>,
    /// Distinct tenants observed, kept sorted as turns arrive — the
    /// compute-once backing for [`Recorder::tenants`], which the
    /// fairness summaries call per report row (previously a full
    /// sort+dedup scan of every turn each time).
    seen_tenants: Vec<u32>,
    pub iterations: Vec<IterationSample>,
    pub total_tokens: u64,
    pub finished_turns: u64,
    pub finished_conversations: u64,
    pub preemptions: u64,
    pub recompute_preemptions: u64,
    /// Conversations rejected because their context can never fit the
    /// GPU KV space (the max-model-len admission rule).
    pub rejected_conversations: u64,
    // ---- context-switch planner (preemption policies) ----
    /// Partial-tail evictions: preemptions that moved only the victim's
    /// tail blocks and left the head GPU-resident (`partial_tail`).
    pub partial_evictions: u64,
    /// Blocks partial evictions kept resident (the KV locality the
    /// policy preserved — these never crossed PCIe).
    pub blocks_retained: u64,
    /// Planner decisions that chose the swap eviction at a
    /// swap-vs-recompute choice point.
    pub evict_swap_decisions: u64,
    /// Planner decisions that chose recompute (`cost_aware` crossover).
    pub evict_recompute_decisions: u64,
    // ---- global prefix cache (block::prefix) ----------------------------
    /// Fresh requests whose template matched a cached prefix chain.
    pub prefix_hits: u64,
    /// Pool blocks matched (and pinned) across all prefix hits.
    pub prefix_hit_blocks: u64,
    /// Prompt tokens never prefilled thanks to prefix hits — always
    /// `prefix_hit_blocks × block_size` (an invariant-audit identity).
    pub prefix_saved_tokens: u64,
    /// Template blocks published into the prefix pool.
    pub prefix_inserts: u64,
    /// Prefix-pool blocks reclaimed under memory pressure.
    pub prefix_evicted_blocks: u64,
    // ---- observability (obs) --------------------------------------------
    /// Latency summary mode. [`TelemetryMode::Exact`] (the default)
    /// keeps every sample and is what the e2e pins measure;
    /// [`TelemetryMode::Reservoir`] additionally feeds the bounded
    /// reservoirs online and serves percentiles from them.
    pub telemetry: TelemetryMode,
    /// Per-stage scheduler-epoch wall-time profiler (off by default).
    pub profiler: EpochProfiler,
    ttft_res: Reservoir,
    tbt_res: Reservoir,
}

impl Recorder {
    /// Recorder with observability knobs applied (the engine's
    /// constructor path; `Recorder::default()` keeps everything off).
    pub fn with_obs(telemetry: TelemetryMode, profile: bool) -> Self {
        Recorder {
            telemetry,
            profiler: EpochProfiler::new(profile),
            ..Recorder::default()
        }
    }
    /// A turn became servable (its request arrived / think time elapsed).
    pub fn turn_arrival(&mut self, req: RequestId, turn: u32, at: Ns, tenant: u32) {
        let idx = self.turns.len();
        self.turns.push(TurnRecord {
            arrival: at,
            tenant,
            ..Default::default()
        });
        self.open.insert((req, turn), idx);
        if let Err(pos) = self.seen_tenants.binary_search(&tenant) {
            self.seen_tenants.insert(pos, tenant);
        }
    }

    /// A decode/prefill step produced a token for (req, turn).
    pub fn token(&mut self, req: RequestId, turn: u32, at: Ns) {
        if let Some(&idx) = self.open.get(&(req, turn)) {
            let rec = &mut self.turns[idx];
            if rec.first_token.is_none() {
                rec.first_token = Some(at);
                if self.telemetry == TelemetryMode::Reservoir {
                    self.ttft_res.add(to_secs(at - rec.arrival));
                }
            } else if self.telemetry == TelemetryMode::Reservoir {
                self.tbt_res.add(to_secs(at - *rec.token_times.last().unwrap()));
            }
            rec.token_times.push(at);
            self.total_tokens += 1;
        }
    }

    pub fn turn_finished(&mut self, req: RequestId, turn: u32) {
        self.open.remove(&(req, turn));
        self.finished_turns += 1;
    }

    pub fn iteration(&mut self, s: IterationSample) {
        self.iterations.push(s);
    }

    // ---- summaries -------------------------------------------------------

    /// TTFT summary in the configured [`TelemetryMode`]: exact over all
    /// samples, or the bounded reservoir's retained subset.
    pub fn ttft(&self) -> Percentiles {
        match self.telemetry {
            TelemetryMode::Exact => self.ttft_exact(),
            TelemetryMode::Reservoir => self.ttft_res.percentiles(),
        }
    }

    /// TBT summary in the configured [`TelemetryMode`].
    pub fn tbt(&self) -> Percentiles {
        match self.telemetry {
            TelemetryMode::Exact => self.tbt_exact(),
            TelemetryMode::Reservoir => self.tbt_res.percentiles(),
        }
    }

    /// Exact TTFT samples in seconds (finished or in-flight turns that
    /// produced a first token) — always available; the reservoir
    /// accuracy tests compare against this.
    pub fn ttft_exact(&self) -> Percentiles {
        Percentiles::from(
            self.turns
                .iter()
                .filter_map(|t| t.first_token.map(|f| to_secs(f - t.arrival)))
                .collect(),
        )
    }

    /// Exact TBT samples in seconds (all inter-token gaps).
    pub fn tbt_exact(&self) -> Percentiles {
        let mut gaps = Vec::new();
        for t in &self.turns {
            for w in t.token_times.windows(2) {
                gaps.push(to_secs(w[1] - w[0]));
            }
        }
        Percentiles::from(gaps)
    }

    /// End-to-end token throughput, tokens/s over `span`.
    pub fn throughput(&self, span: Ns) -> f64 {
        if span == 0 {
            return 0.0;
        }
        self.total_tokens as f64 / to_secs(span)
    }

    /// Fig. 12: token-generation efficiency per fixed-size iteration
    /// window, as percentiles. Efficiency is tokens per second **per
    /// running request**, over *decode* iterations only: prefill
    /// iterations are long/low-token by design, and raw batch-size
    /// variation would otherwise mask the swap stalls the figure is
    /// about.
    pub fn token_gen_efficiency(&self, window: usize) -> Percentiles {
        let decode: Vec<&IterationSample> = self
            .iterations
            .iter()
            .filter(|s| !s.is_prefill && s.batch > 0)
            .collect();
        let mut samples = Vec::new();
        for chunk in decode.chunks(window) {
            if chunk.len() < window {
                break;
            }
            // Per-request tokens (≡ iterations completed) over wall time.
            // Mixed chunked iterations also emit prompt-completing tokens
            // from requests outside the decode batch; clamp so the ratio
            // stays "iterations completed per running request" (≤ 1).
            let per_req_tokens: f64 = chunk
                .iter()
                .map(|s| s.tokens.min(s.batch) as f64 / s.batch as f64)
                .sum();
            let dur: Ns = chunk
                .iter()
                .map(|s| s.inference_ns + s.swap_stall_ns + s.sched_overhead_ns)
                .sum();
            if dur > 0 {
                samples.push(per_req_tokens / to_secs(dur));
            }
        }
        Percentiles::from(samples)
    }

    // ---- per-tenant summaries (fairness policies) -----------------------

    /// Distinct tenants observed, sorted. O(1) per call: the set is
    /// maintained incrementally at [`Recorder::turn_arrival`], not
    /// rescanned from the turn log.
    pub fn tenants(&self) -> Vec<u32> {
        self.seen_tenants.clone()
    }

    /// Both per-tenant latency breakdowns from ONE tenant-indexed pass
    /// over the turns — `(ttft, tbt)`, each sorted by tenant. TTFT
    /// includes only tenants with a first token; TBT includes every
    /// tenant with a recorded turn (possibly with an empty sample set),
    /// matching the historical per-metric scans exactly.
    pub fn latency_by_tenant(&self) -> (Vec<(u32, Percentiles)>, Vec<(u32, Percentiles)>) {
        let mut ttft: HashMap<u32, Vec<f64>> = HashMap::new();
        let mut tbt: HashMap<u32, Vec<f64>> = HashMap::new();
        for t in &self.turns {
            if let Some(f) = t.first_token {
                ttft.entry(t.tenant)
                    .or_default()
                    .push(to_secs(f - t.arrival));
            }
            let s = tbt.entry(t.tenant).or_default();
            for w in t.token_times.windows(2) {
                s.push(to_secs(w[1] - w[0]));
            }
        }
        let finish = |m: HashMap<u32, Vec<f64>>| {
            let mut v: Vec<(u32, Percentiles)> = m
                .into_iter()
                .map(|(t, s)| (t, Percentiles::from(s)))
                .collect();
            v.sort_by_key(|&(t, _)| t);
            v
        };
        (finish(ttft), finish(tbt))
    }

    /// Per-tenant TTFT percentiles, sorted by tenant.
    pub fn ttft_by_tenant(&self) -> Vec<(u32, Percentiles)> {
        self.latency_by_tenant().0
    }

    /// Per-tenant TBT percentiles, sorted by tenant.
    pub fn tbt_by_tenant(&self) -> Vec<(u32, Percentiles)> {
        self.latency_by_tenant().1
    }

    /// Tokens generated per tenant (every tenant with a recorded turn
    /// appears, even at 0 tokens — starvation must be visible).
    pub fn tokens_by_tenant(&self) -> Vec<(u32, u64)> {
        self.tokens_by_tenant_until(Ns::MAX)
    }

    /// Tokens generated per tenant up to virtual time `cutoff`
    /// (inclusive) — the mid-flight share snapshot fairness bounds are
    /// asserted on.
    pub fn tokens_by_tenant_until(&self, cutoff: Ns) -> Vec<(u32, u64)> {
        let mut counts: HashMap<u32, u64> = HashMap::new();
        for t in &self.turns {
            let n = t.token_times.iter().filter(|&&at| at <= cutoff).count() as u64;
            *counts.entry(t.tenant).or_insert(0) += n;
        }
        let mut v: Vec<(u32, u64)> = counts.into_iter().collect();
        v.sort_by_key(|&(t, _)| t);
        v
    }

    /// Per-tenant fraction of all generated tokens, sorted by tenant.
    pub fn token_shares(&self) -> Vec<(u32, f64)> {
        let counts = self.tokens_by_tenant();
        let total: u64 = counts.iter().map(|&(_, n)| n).sum();
        if total == 0 {
            return counts.iter().map(|&(t, _)| (t, 0.0)).collect();
        }
        counts
            .iter()
            .map(|&(t, n)| (t, n as f64 / total as f64))
            .collect()
    }

    /// Max-min token-share ratio across tenants (1.0 = perfectly even;
    /// `INFINITY` when some tenant is fully starved; `NAN` with no data).
    pub fn max_min_share_ratio(&self) -> f64 {
        let shares = self.token_shares();
        if shares.is_empty() {
            return f64::NAN;
        }
        let max = shares.iter().map(|&(_, s)| s).fold(0.0f64, f64::max);
        let min = shares.iter().map(|&(_, s)| s).fold(f64::INFINITY, f64::min);
        if min == 0.0 {
            f64::INFINITY
        } else {
            max / min
        }
    }

    /// Jain's fairness index over per-tenant token counts:
    /// `(Σx)² / (n·Σx²)` — 1.0 when perfectly even, → 1/n under full
    /// capture by one tenant.
    pub fn jain_fairness(&self) -> f64 {
        let counts = self.tokens_by_tenant();
        if counts.is_empty() {
            return f64::NAN;
        }
        let n = counts.len() as f64;
        let sum: f64 = counts.iter().map(|&(_, c)| c as f64).sum();
        let sq: f64 = counts.iter().map(|&(_, c)| (c as f64) * (c as f64)).sum();
        if sq == 0.0 {
            return f64::NAN;
        }
        sum * sum / (n * sq)
    }

    /// Total decode-interference stall: virtual time decode-ready
    /// requests spent held back by prefill work — the tail-TBT tax the
    /// chunked-prefill scheduler exists to shrink (compare monolithic vs
    /// chunked in `exp chunked`).
    pub fn decode_interference_ns(&self) -> Ns {
        self.iterations.iter().map(|s| s.decode_block_ns).sum()
    }

    /// Total prompt tokens prefilled across all iterations.
    pub fn prefill_tokens(&self) -> u64 {
        self.iterations.iter().map(|s| s.prefill_tokens as u64).sum()
    }

    /// Fig. 1 / Fig. 10: total stall vs inference on the critical path.
    pub fn stall_breakdown(&self) -> (Ns, Ns, Ns) {
        let inf = self.iterations.iter().map(|s| s.inference_ns).sum();
        let swap = self.iterations.iter().map(|s| s.swap_stall_ns).sum();
        let sched = self.iterations.iter().map(|s| s.sched_overhead_ns).sum();
        (inf, swap, sched)
    }

    /// Fig. 1: per-iteration total latency percentiles with their swap
    /// share — (total_ns, swap_ns) pairs sorted by total.
    pub fn iteration_latency_samples(&self) -> Vec<(f64, f64)> {
        self.iterations
            .iter()
            .map(|s| {
                (
                    (s.inference_ns + s.swap_stall_ns + s.sched_overhead_ns) as f64,
                    s.swap_stall_ns as f64,
                )
            })
            .collect()
    }

    /// Fig. 2: per-iteration fraction of scheduled requests waiting on a
    /// KV transfer (waiters / (batch + waiters)).
    pub fn waiting_on_swap_fractions(&self) -> Vec<f64> {
        self.iterations
            .iter()
            .filter(|s| s.batch + s.waiting_on_swap > 0)
            .map(|s| s.waiting_on_swap as f64 / (s.batch + s.waiting_on_swap) as f64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::{MS, SEC};

    #[test]
    fn ttft_per_turn() {
        let mut r = Recorder::default();
        r.turn_arrival(1, 0, 0, 0);
        r.token(1, 0, 2 * SEC);
        r.token(1, 0, 2 * SEC + 100 * MS);
        r.turn_finished(1, 0);
        r.turn_arrival(1, 1, 10 * SEC, 0);
        r.token(1, 1, 10 * SEC + 500 * MS);
        let ttft = r.ttft();
        assert_eq!(ttft.len(), 2);
        assert!((ttft.min() - 0.5).abs() < 1e-9);
        assert!((ttft.max() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tenants_match_the_turn_log_scan() {
        // The incremental sorted set must equal what the old
        // sort+dedup scan over every recorded turn produced.
        let mut r = Recorder::default();
        for (req, tenant) in [(1, 7), (2, 0), (3, 7), (4, 3), (5, 0), (6, 9)] {
            r.turn_arrival(req, 0, 0, tenant);
        }
        let mut scanned: Vec<u32> = r.turns.iter().map(|t| t.tenant).collect();
        scanned.sort_unstable();
        scanned.dedup();
        assert_eq!(r.tenants(), scanned);
        assert_eq!(r.tenants(), vec![0, 3, 7, 9]);
    }

    #[test]
    fn tbt_gaps() {
        let mut r = Recorder::default();
        r.turn_arrival(1, 0, 0, 0);
        r.token(1, 0, 0);
        r.token(1, 0, 100 * MS);
        r.token(1, 0, 400 * MS);
        let tbt = r.tbt();
        assert_eq!(tbt.len(), 2);
        assert!((tbt.max() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn throughput() {
        let mut r = Recorder::default();
        r.turn_arrival(1, 0, 0, 0);
        for i in 0..100 {
            r.token(1, 0, i * MS);
        }
        assert!((r.throughput(10 * SEC) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_windows() {
        let mut r = Recorder::default();
        for i in 0..10 {
            r.iteration(IterationSample {
                at: i * 10 * MS,
                inference_ns: 10 * MS,
                swap_stall_ns: if i >= 5 { 10 * MS } else { 0 },
                tokens: 8,
                batch: 8,
                ..Default::default()
            });
        }
        let eff = r.token_gen_efficiency(5);
        assert_eq!(eff.len(), 2);
        // Second window has stalls → half the efficiency.
        assert!((eff.max() / eff.min() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn decode_interference_and_prefill_totals() {
        let mut r = Recorder::default();
        r.iteration(IterationSample {
            inference_ns: 10 * MS,
            prefill_tokens: 256,
            decode_block_ns: 10 * MS, // monolithic: decodes fully blocked
            is_prefill: true,
            ..Default::default()
        });
        r.iteration(IterationSample {
            inference_ns: 12 * MS,
            prefill_tokens: 64,
            decode_block_ns: 2 * MS, // mixed: chunk inflated the decode
            tokens: 8,
            batch: 8,
            ..Default::default()
        });
        r.iteration(IterationSample {
            inference_ns: 10 * MS,
            tokens: 8,
            batch: 8,
            ..Default::default()
        });
        assert_eq!(r.decode_interference_ns(), 12 * MS);
        assert_eq!(r.prefill_tokens(), 320);
    }

    #[test]
    fn tokens_for_unknown_turn_ignored() {
        let mut r = Recorder::default();
        r.token(9, 0, 0);
        assert_eq!(r.total_tokens, 0);
        assert!(r.ttft().is_empty());
    }

    #[test]
    fn per_tenant_breakdown() {
        let mut r = Recorder::default();
        // Tenant 0: one turn, fast first token, 3 tokens.
        r.turn_arrival(1, 0, 0, 0);
        r.token(1, 0, SEC);
        r.token(1, 0, SEC + 100 * MS);
        r.token(1, 0, SEC + 200 * MS);
        // Tenant 5: one turn, slow first token, 1 token.
        r.turn_arrival(2, 0, 0, 5);
        r.token(2, 0, 4 * SEC);
        assert_eq!(r.tenants(), vec![0, 5]);
        let ttft = r.ttft_by_tenant();
        assert_eq!(ttft.len(), 2);
        assert!((ttft[0].1.p(50.0) - 1.0).abs() < 1e-9);
        assert!((ttft[1].1.p(50.0) - 4.0).abs() < 1e-9);
        let tbt = r.tbt_by_tenant();
        assert_eq!(tbt[0].0, 0);
        assert_eq!(tbt[0].1.len(), 2);
        assert_eq!(r.tokens_by_tenant(), vec![(0, 3), (5, 1)]);
        let shares = r.token_shares();
        assert!((shares[0].1 - 0.75).abs() < 1e-9);
        assert!((shares[1].1 - 0.25).abs() < 1e-9);
        assert!((r.max_min_share_ratio() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn reservoir_mode_matches_exact_below_capacity() {
        let mut r = Recorder::with_obs(TelemetryMode::Reservoir, false);
        r.turn_arrival(1, 0, 0, 0);
        r.token(1, 0, SEC);
        r.token(1, 0, SEC + 100 * MS);
        r.token(1, 0, SEC + 400 * MS);
        // Below reservoir capacity the retained set IS the sample set.
        assert_eq!(r.ttft().samples(), r.ttft_exact().samples());
        assert_eq!(r.tbt().samples(), r.tbt_exact().samples());
        // Exact mode serves the exact pipeline (the pinned default).
        let d = Recorder::default();
        assert_eq!(d.telemetry, TelemetryMode::Exact);
        assert!(!d.profiler.enabled);
    }

    #[test]
    fn single_pass_by_tenant_matches_per_metric_views() {
        let mut r = Recorder::default();
        r.turn_arrival(1, 0, 0, 0);
        r.token(1, 0, SEC);
        r.token(1, 0, 2 * SEC);
        r.turn_arrival(2, 0, 0, 3);
        // Tenant 3 has a turn but no tokens: present in TBT (empty),
        // absent from TTFT — the historical shape.
        let (ttft, tbt) = r.latency_by_tenant();
        assert_eq!(ttft.len(), 1);
        assert_eq!(tbt.len(), 2);
        assert!(tbt[1].1.is_empty());
        assert_eq!(ttft[0].0, 0);
        assert_eq!(r.ttft_by_tenant().len(), ttft.len());
        assert_eq!(r.tbt_by_tenant().len(), tbt.len());
    }

    #[test]
    fn tokens_until_cutoff() {
        let mut r = Recorder::default();
        r.turn_arrival(1, 0, 0, 0);
        r.token(1, 0, SEC);
        r.token(1, 0, 2 * SEC);
        r.token(1, 0, 3 * SEC);
        assert_eq!(r.tokens_by_tenant_until(2 * SEC), vec![(0, 2)]);
        assert_eq!(r.tokens_by_tenant(), vec![(0, 3)]);
    }

    #[test]
    fn jain_index_bounds() {
        let mut even = Recorder::default();
        even.turn_arrival(1, 0, 0, 0);
        even.turn_arrival(2, 0, 0, 1);
        for i in 0..4 {
            even.token(1, 0, i * MS);
            even.token(2, 0, i * MS);
        }
        assert!((even.jain_fairness() - 1.0).abs() < 1e-9);

        let mut skew = Recorder::default();
        skew.turn_arrival(1, 0, 0, 0);
        skew.turn_arrival(2, 0, 0, 1);
        for i in 0..8 {
            skew.token(1, 0, i * MS);
        }
        // One tenant captured everything: index → 1/n = 0.5.
        assert!((skew.jain_fairness() - 0.5).abs() < 1e-9);
        assert!(skew.max_min_share_ratio().is_infinite());
    }
}
