//! Actor runtime for the cluster: replicas as message-driven tasks
//! behind a pluggable executor.
//!
//! The pre-actor cluster was N engine replicas stepped sequentially
//! inside one loop that called engine hooks directly; router dispatch,
//! swap/prefetch I/O, and replica compute could never actually be
//! concurrent. This layer restructures that loop into actors:
//!
//! - every replica is a [`ReplicaActor`] owning its
//!   [`ServingEngine`], with a typed [`Mailbox`] of [`ReplicaMsg`]
//!   deliveries (arrivals, turn firings, migrations, drain/rejoin,
//!   clock ticks, shutdown);
//! - the router ([`crate::cluster::router::RouterCore`]) owns only the
//!   placement state and its own stamped work mailbox; everything it
//!   learns about replicas arrives as [`RouterMsg`] reports (released
//!   turns, migration results, status/load snapshots, final outcomes);
//! - an [`Executor`] decides *how* messages flow.
//!
//! Two executors ship behind the one trait:
//!
//! - [`deterministic::DeterministicExecutor`] — the default. A
//!   single-threaded virtual-clock scheduler delivering messages in
//!   [`Stamp`] `(due, seq)` order, replicating the pre-actor router
//!   loop decision-for-decision so every seeded e2e pin stays
//!   byte-identical. As the virtual clock itself, it may inspect actor
//!   clocks and loads synchronously — the inspection *is* the
//!   simulated "message" and costs nothing in virtual time.
//! - [`threaded::ThreadedExecutor`] — `--parallel`. One OS thread per
//!   replica plus the router thread, real mpsc channels, replicas
//!   free-running their virtual clocks concurrently. Placement uses
//!   the latest *reported* (slightly stale) clocks and loads, so
//!   placement counters and latency percentiles may differ run-to-run;
//!   the workload outcome — which conversations finish, which are
//!   rejected, how many tokens are served — is placement-invariant and
//!   must match the deterministic executor exactly
//!   (`rust/tests/actor_e2e.rs` pins this).
//!
//! The determinism contract, in one line: **messages are totally
//! ordered by `(due, seq)` and the deterministic executor delivers them
//! in exactly that order**; the threaded executor preserves per-sender
//! FIFO order only, and every aggregate it reports must be an invariant
//! of that relaxation.

pub mod deterministic;
pub mod mailbox;
pub mod threaded;

pub use mailbox::Mailbox;

use crate::cluster::placement::ReplicaLoad;
use crate::cluster::router::{ClusterOutcome, RouterCore};
use crate::coordinator::engine::{MigratedConv, ServeOutcome, ServingEngine};
use crate::memory::RequestId;
use crate::sim::clock::Ns;
use crate::workload::Conversation;

/// Messages a replica actor can receive (router → replica).
#[derive(Debug)]
pub enum ReplicaMsg {
    /// Place a conversation on this replica; it enters the engine's
    /// arrival queue at the stamp's due time.
    Arrive { conv: Conversation },
    /// Fire a held turn of a conversation homed here (affinity hit).
    FireTurn { id: RequestId },
    /// Evict a conversation for migration to replica `to`; the actor
    /// answers with [`RouterMsg::Migrated`] carrying the unserved
    /// remainder (or `None` if the conversation already terminated).
    Migrate { id: RequestId, to: usize },
    /// The router drained this replica: no further placements will
    /// arrive until a [`ReplicaMsg::Rejoin`]. In-flight work finishes.
    Drain,
    /// The drained replica re-enters the placement rotation.
    Rejoin,
    /// Advance the engine's virtual clock by at most `max_steps`
    /// iterations (deterministic executor only — the threaded executor
    /// free-runs instead).
    Tick { max_steps: u64 },
    /// Finish up: after this the actor reports its outcome and stops.
    Shutdown,
}

/// Messages a replica actor sends back (replica → router).
#[derive(Debug)]
pub enum RouterMsg {
    /// A held conversation finished a turn; its next turn is due for a
    /// placement decision at `due`.
    Released { replica: usize, id: RequestId, due: Ns },
    /// Answer to [`ReplicaMsg::Migrate`]: the evicted remainder headed
    /// for replica `to` (`None` when the conversation terminated on the
    /// home replica in the meantime — nothing to move).
    Migrated { replica: usize, to: usize, at: Ns, conv: Option<MigratedConv> },
    /// Liveness/load report, appended after every processed batch:
    /// the actor's virtual clock, whether it still has runnable work
    /// (within its step budget), its current placement load snapshot,
    /// and how many router→replica messages it has processed so far
    /// (the threaded executor's quiescence handshake compares this
    /// against its send count).
    Status { replica: usize, now: Ns, runnable: bool, load: ReplicaLoad, acked: u64 },
    /// Terminal report after [`ReplicaMsg::Shutdown`].
    Finished { replica: usize, outcome: Box<ServeOutcome> },
}

/// A replica as an actor: the engine, its mailbox, and the local step
/// budget. All engine access from the cluster layer flows through
/// [`ReplicaActor::post`] + [`ReplicaActor::process`] (message
/// delivery) or the read-only snapshot accessors the deterministic
/// executor uses as its virtual-clock view.
pub struct ReplicaActor {
    id: usize,
    engine: ServingEngine,
    mailbox: Mailbox<ReplicaMsg>,
    /// Router→replica messages processed (Status handshake).
    handled: u64,
    /// Engine iterations this actor may still take (backstop against
    /// runaway runs; mirrors the pre-actor global step budget).
    budget: u64,
    steps: u64,
    alive: bool,
}

impl ReplicaActor {
    /// Wrap an engine as an actor with a step budget.
    pub fn new(id: usize, engine: ServingEngine, budget: u64) -> Self {
        ReplicaActor {
            id,
            engine,
            mailbox: Mailbox::new(),
            handled: 0,
            budget,
            steps: 0,
            alive: true,
        }
    }

    /// Replica index (also its trace lane).
    pub fn id(&self) -> usize {
        self.id
    }

    /// Re-arm the step budget (the executor owns the budget policy:
    /// the deterministic executor enforces a global budget itself and
    /// leaves actors unbounded; the threaded executor caps each actor).
    pub fn set_budget(&mut self, budget: u64) {
        self.budget = budget;
    }

    /// Enqueue a message for delivery at `due`.
    pub fn post(&mut self, due: Ns, msg: ReplicaMsg) {
        self.mailbox.push(due, msg);
    }

    /// Deliver every queued message in `(due, seq)` order, then report:
    /// released turns first (per-sender FIFO guarantees the router sees
    /// them before the Status that acknowledges this batch), then one
    /// [`RouterMsg::Status`]. Returns `false` once a
    /// [`ReplicaMsg::Shutdown`] was delivered.
    pub fn process(&mut self, out: &mut Vec<RouterMsg>) -> bool {
        while let Some((stamp, msg)) = self.mailbox.pop_min() {
            self.handled += 1;
            match msg {
                ReplicaMsg::Arrive { conv } => self.engine.push_arrival(conv, stamp.due),
                ReplicaMsg::FireTurn { id } => self.engine.fire_turn(id, stamp.due),
                ReplicaMsg::Migrate { id, to } => {
                    let conv = self.engine.evict_for_migration(id);
                    out.push(RouterMsg::Migrated {
                        replica: self.id,
                        to,
                        at: stamp.due,
                        conv,
                    });
                }
                // Drain/rejoin only move the replica in and out of the
                // router's placement rotation; the engine itself keeps
                // serving whatever it already holds.
                ReplicaMsg::Drain | ReplicaMsg::Rejoin => {}
                ReplicaMsg::Tick { max_steps } => self.step_chunk(max_steps, false),
                ReplicaMsg::Shutdown => self.alive = false,
            }
        }
        self.report(out);
        self.alive
    }

    /// Free-run a chunk of engine iterations (threaded executor),
    /// early-stopping as soon as a turn is released so the router hears
    /// about it with minimal lag, then report.
    pub fn tick(&mut self, max_steps: u64, out: &mut Vec<RouterMsg>) {
        self.step_chunk(max_steps, true);
        self.report(out);
    }

    fn step_chunk(&mut self, max_steps: u64, stop_on_release: bool) {
        let taken = self
            .engine
            .step_chunk(max_steps.min(self.budget.saturating_sub(self.steps)), stop_on_release);
        self.steps += taken;
    }

    fn report(&mut self, out: &mut Vec<RouterMsg>) {
        for (id, due) in self.engine.take_released_turns() {
            out.push(RouterMsg::Released { replica: self.id, id, due });
        }
        out.push(RouterMsg::Status {
            replica: self.id,
            now: self.engine.now(),
            runnable: self.runnable(),
            load: self.engine.load_snapshot(),
            acked: self.handled,
        });
    }

    /// Virtual clock (deterministic executor's synchronous view).
    pub fn now(&self) -> Ns {
        self.engine.now()
    }

    /// Runnable = has pending work and step budget left.
    pub fn runnable(&self) -> bool {
        self.engine.has_pending_work() && self.steps < self.budget
    }

    /// Current placement load (deterministic executor's synchronous
    /// view; the threaded executor gets this via [`RouterMsg::Status`]).
    pub fn load(&self) -> ReplicaLoad {
        self.engine.load_snapshot()
    }

    /// Engine iterations taken so far under this actor.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Direct engine access for pre-run configuration (e.g. the
    /// Fig-9 wall-clock charging flag). Not used while an executor is
    /// driving the actor.
    pub fn engine_mut(&mut self) -> &mut ServingEngine {
        &mut self.engine
    }

    /// Undelivered mailbox depth (observability).
    pub fn mailbox_depth(&self) -> usize {
        self.mailbox.depth()
    }

    /// Finish the actor and extract its engine outcome.
    pub fn into_outcome(self) -> ServeOutcome {
        self.engine.into_outcome()
    }
}

/// One strategy for driving the router + replica actors to completion.
/// Implementations consume the router core and actors and return the
/// aggregated outcome.
pub trait Executor {
    /// Short name for banners and the ledger.
    fn label(&self) -> &'static str;
    /// Drive the message flow until the workload completes (or the step
    /// budget derived from `max_iters` runs out).
    fn run(&mut self, core: RouterCore, actors: Vec<ReplicaActor>, max_iters: u64)
        -> ClusterOutcome;
}

/// Re-exported for executor implementations and tests.
pub use crate::sim::clock::Stamp as MessageStamp;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_messages_are_send() {
        // The threaded executor moves actors and both message enums
        // across OS threads; keep that property pinned at compile time.
        fn assert_send<T: Send>() {}
        assert_send::<ReplicaMsg>();
        assert_send::<RouterMsg>();
        assert_send::<ReplicaActor>();
    }

    #[test]
    fn stamp_reexport_matches_clock_stamp() {
        let s = MessageStamp { due: 1, seq: 2 };
        assert_eq!(s, crate::sim::clock::Stamp { due: 1, seq: 2 });
    }
}
