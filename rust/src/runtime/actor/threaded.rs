//! The `--parallel` executor: one OS thread per replica plus the router
//! on the calling thread, real mpsc channels in both directions.
//!
//! Replicas free-run their virtual clocks in [`CHUNK`]-iteration
//! bursts (early-stopping on turn release so the router hears about
//! due placements with minimal lag) and block on their inbox when
//! idle. The router dispatches a placement decision once no replica it
//! *believes* runnable is still behind the decision's due time — the
//! belief comes from the latest [`RouterMsg::Status`] reports, so it
//! is slightly stale and placements can differ from the deterministic
//! executor's. That staleness is the whole relaxation: which replica
//! serves a conversation affects latency percentiles and migration
//! counts, but never whether the conversation finishes, gets rejected,
//! or how many tokens it is served — those depend only on the
//! conversation's own content (migration folds served history into the
//! next prompt, so the max-model-len check sees the same cumulative
//! length on any replica). `rust/tests/actor_e2e.rs` pins exactly that
//! agreement against the deterministic run.
//!
//! Termination is a two-sided handshake. A replica is *settled* when
//! its last report says it is not runnable and has acknowledged every
//! message the router sent it (`acked == sent`). Per-sender channel
//! FIFO means that final [`RouterMsg::Status`] arrives after anything
//! else the replica sent, so once every replica is settled and the
//! report channel drains empty, nothing can be in flight: the router
//! sends [`ReplicaMsg::Shutdown`] and collects one
//! [`RouterMsg::Finished`] per replica. Step budgets are per-actor
//! (`max_iters` each); a budget-exhausted replica reports itself not
//! runnable, so exhaustion ends the run instead of deadlocking it.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread;

use crate::cluster::placement::ReplicaLoad;
use crate::cluster::router::{ClusterOutcome, RouterCore};
use crate::coordinator::engine::ServeOutcome;
use crate::sim::clock::Ns;

use super::{Executor, ReplicaActor, ReplicaMsg, RouterMsg};

/// Iterations per free-run burst between inbox polls. Small enough to
/// keep status reports fresh, large enough to amortize channel traffic.
const CHUNK: u64 = 256;

/// One OS thread per replica; placement on stale reported state. See
/// the module docs for the invariants this preserves.
pub struct ThreadedExecutor;

/// The router's latest belief about one replica, rebuilt from every
/// [`RouterMsg::Status`] it receives.
struct ReplicaView {
    now: Ns,
    runnable: bool,
    load: ReplicaLoad,
    sent: u64,
    acked: u64,
}

impl Executor for ThreadedExecutor {
    fn label(&self) -> &'static str {
        "threaded"
    }

    fn run(
        &mut self,
        mut core: RouterCore,
        actors: Vec<ReplicaActor>,
        max_iters: u64,
    ) -> ClusterOutcome {
        let n = actors.len();
        let (report_tx, report_rx) = mpsc::channel::<RouterMsg>();
        let mut inboxes: Vec<Sender<(Ns, ReplicaMsg)>> = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for mut actor in actors {
            actor.set_budget(max_iters);
            let (tx, rx) = mpsc::channel::<(Ns, ReplicaMsg)>();
            inboxes.push(tx);
            let out = report_tx.clone();
            handles.push(thread::spawn(move || replica_main(actor, rx, out)));
        }
        drop(report_tx);

        let mut views: Vec<ReplicaView> = (0..n)
            .map(|_| ReplicaView {
                now: 0,
                runnable: false,
                load: ReplicaLoad::default(),
                sent: 0,
                acked: 0,
            })
            .collect();

        let mut send = |views: &mut [ReplicaView], replica: usize, due: Ns, msg: ReplicaMsg| {
            // A send can only fail if the replica thread panicked; the
            // panic surfaces at join below, so losing the message here
            // is moot.
            let _ = inboxes[replica].send((due, msg));
            views[replica].sent += 1;
            // Optimistic: assume the delivery wakes the replica until
            // its next status report says otherwise. This paces
            // dispatch (later-due decisions wait for the report) and
            // keeps the settled-check honest.
            views[replica].runnable = true;
        };

        loop {
            // Dispatch every decision already reached by all replicas
            // believed runnable; their clocks only move forward, so
            // waiting on stale reports is conservative, never wrong.
            while let Some(stamp) = core.peek_due() {
                let due = stamp.due;
                if views.iter().any(|v| v.runnable && v.now < due) {
                    break;
                }
                let loads: Vec<ReplicaLoad> = views.iter().map(|v| v.load.clone()).collect();
                let deliveries = core.route(&loads).expect("peeked work vanished");
                for (replica, msg_due, msg) in deliveries {
                    send(&mut views, replica, msg_due, msg);
                }
            }
            let settled = core.queue_is_empty()
                && views.iter().all(|v| !v.runnable && v.acked == v.sent);
            if settled {
                // Per-sender FIFO: a settled replica's final status is
                // the last thing it sent, so an empty channel here is a
                // true fixpoint, not a race window.
                match report_rx.try_recv() {
                    Ok(msg) => {
                        handle_report(&mut core, &mut views, &mut send, msg);
                        continue;
                    }
                    Err(_) => break,
                }
            }
            match report_rx.recv() {
                Ok(msg) => handle_report(&mut core, &mut views, &mut send, msg),
                Err(_) => break, // every replica hung up (all panicked)
            }
        }

        for (replica, inbox) in inboxes.iter().enumerate() {
            let _ = inbox.send((views[replica].now, ReplicaMsg::Shutdown));
        }
        let mut outcomes: Vec<Option<ServeOutcome>> = (0..n).map(|_| None).collect();
        let mut finished = 0usize;
        while finished < n {
            match report_rx.recv() {
                // Only trailing status reports can interleave here: a
                // shutting-down replica drains an idle engine, so no
                // releases or migration replies are possible.
                Ok(RouterMsg::Finished { replica, outcome }) => {
                    outcomes[replica] = Some(*outcome);
                    finished += 1;
                }
                Ok(_) => {}
                Err(_) => break,
            }
        }
        for h in handles {
            h.join().expect("replica thread panicked");
        }
        let outcomes = outcomes
            .into_iter()
            .map(|o| o.expect("replica exited without a final report"))
            .collect();
        core.into_outcome(outcomes)
    }
}

fn handle_report(
    core: &mut RouterCore,
    views: &mut [ReplicaView],
    send: &mut impl FnMut(&mut [ReplicaView], usize, Ns, ReplicaMsg),
    msg: RouterMsg,
) {
    match msg {
        RouterMsg::Released { replica, id, due } => core.on_released(replica, id, due),
        RouterMsg::Migrated { replica, to, at, conv } => {
            if let Some((target, due, m)) = core.on_migrated(replica, to, at, conv) {
                send(views, target, due, m);
            }
        }
        RouterMsg::Status { replica, now, runnable, load, acked } => {
            let v = &mut views[replica];
            v.now = now;
            v.load = load;
            v.acked = acked;
            // Trust a status only once it acknowledges everything we
            // sent — an older report must not flip a woken replica back
            // to idle.
            if acked == v.sent {
                v.runnable = runnable;
            }
        }
        RouterMsg::Finished { .. } => {}
    }
}

/// Replica thread body: block when idle, drain the inbox, process, then
/// free-run a burst if there is runnable work. Every loop iteration
/// flushes its reports, so the router's view lags by at most one burst.
fn replica_main(
    mut actor: ReplicaActor,
    inbox: Receiver<(Ns, ReplicaMsg)>,
    out: Sender<RouterMsg>,
) {
    let mut reports: Vec<RouterMsg> = Vec::new();
    loop {
        if !actor.runnable() && actor.mailbox_depth() == 0 {
            match inbox.recv() {
                Ok((due, msg)) => actor.post(due, msg),
                Err(_) => return, // router dropped us without shutdown
            }
        }
        while let Ok((due, msg)) = inbox.try_recv() {
            actor.post(due, msg);
        }
        let alive = actor.process(&mut reports);
        if !alive {
            for m in reports.drain(..) {
                let _ = out.send(m);
            }
            let id = actor.id();
            let _ = out.send(RouterMsg::Finished {
                replica: id,
                outcome: Box::new(actor.into_outcome()),
            });
            return;
        }
        if actor.runnable() {
            actor.tick(CHUNK, &mut reports);
        }
        for m in reports.drain(..) {
            let _ = out.send(m);
        }
    }
}
