//! Stamped mailbox: the delivery queue behind every actor.
//!
//! A [`Mailbox`] holds `(Stamp, T)` pairs and always delivers the
//! minimum [`Stamp`] first — due time, then enqueue order. The seq
//! counter lives *inside* the mailbox, so the tie-break is a pure
//! function of enqueue order and a seeded run replays identically on the
//! deterministic executor. The router's placement queue and every
//! replica inbox are instances of this one type, which is what makes the
//! "no message loss" invariant checkable in one place: whatever is
//! pushed is popped exactly once, in `(due, seq)` order.
//!
//! Implementation note: storage is a binary min-heap on the stamp —
//! O(log n) push/pop instead of the previous Vec min-scan's O(n) pop.
//! Every stamp is unique (the seq counter increments on each push), so
//! `(due, seq)` is a *strict* total order and the heap delivers exactly
//! the sequence the min-scan did — the byte-stable e2e pins that were
//! recorded against the Vec implementation hold unchanged.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::sim::clock::{Ns, Stamp};

/// One queued message. The ordering is on the stamp alone (reversed, so
/// the max-heap pops the minimum) — `T` needs no `Ord`.
#[derive(Debug)]
struct Entry<T> {
    stamp: Stamp,
    msg: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.stamp == other.stamp
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: `BinaryHeap` is a max-heap and delivery wants the
        // minimum `(due, seq)` first.
        other.stamp.cmp(&self.stamp)
    }
}

/// A `(due, seq)`-ordered delivery queue. See the module docs for the
/// ordering contract.
#[derive(Debug)]
pub struct Mailbox<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for Mailbox<T> {
    fn default() -> Self {
        Mailbox { heap: BinaryHeap::new(), seq: 0 }
    }
}

impl<T> Mailbox<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a message due at `due`; returns the assigned stamp.
    pub fn push(&mut self, due: Ns, msg: T) -> Stamp {
        let stamp = Stamp { due, seq: self.seq };
        self.seq += 1;
        self.heap.push(Entry { stamp, msg });
        stamp
    }

    /// The stamp that [`Mailbox::pop_min`] would deliver next.
    pub fn peek_min(&self) -> Option<Stamp> {
        self.heap.peek().map(|e| e.stamp)
    }

    /// Deliver the minimum-stamped message, removing it from the queue.
    pub fn pop_min(&mut self) -> Option<(Stamp, T)> {
        self.heap.pop().map(|e| (e.stamp, e.msg))
    }

    /// Current queue depth (undelivered messages).
    pub fn depth(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total messages ever enqueued (the next stamp's seq).
    pub fn enqueued(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_in_due_then_seq_order() {
        let mut mb = Mailbox::new();
        mb.push(30, "c");
        mb.push(10, "a1");
        mb.push(10, "a2");
        mb.push(20, "b");
        let order: Vec<&str> = std::iter::from_fn(|| mb.pop_min().map(|(_, m)| m)).collect();
        assert_eq!(order, ["a1", "a2", "b", "c"]);
        assert!(mb.is_empty());
    }

    #[test]
    fn same_due_ties_break_by_enqueue_order() {
        let mut mb = Mailbox::new();
        for i in 0..16u32 {
            mb.push(5, i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| mb.pop_min().map(|(_, m)| m)).collect();
        assert_eq!(order, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn no_message_loss_under_interleaved_push_pop() {
        // Interleave pushes and pops with colliding due times; every
        // pushed message must come out exactly once.
        let mut mb = Mailbox::new();
        let mut delivered = Vec::new();
        let mut pushed = 0u64;
        for round in 0..8u64 {
            for k in 0..5u64 {
                mb.push((round / 2) * 10, pushed);
                pushed += 1;
                let _ = k;
            }
            if round % 2 == 1 {
                for _ in 0..3 {
                    if let Some((_, m)) = mb.pop_min() {
                        delivered.push(m);
                    }
                }
            }
        }
        while let Some((_, m)) = mb.pop_min() {
            delivered.push(m);
        }
        assert_eq!(delivered.len() as u64, pushed);
        let mut sorted = delivered.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len() as u64, pushed, "duplicate or lost delivery");
        assert_eq!(mb.enqueued(), pushed);
    }

    #[test]
    fn stamps_are_monotone_in_seq() {
        let mut mb = Mailbox::new();
        let a = mb.push(100, ());
        let b = mb.push(1, ());
        assert_eq!(a.seq, 0);
        assert_eq!(b.seq, 1);
        assert_eq!(mb.depth(), 2);
        // Despite later seq, the earlier due delivers first.
        assert_eq!(mb.pop_min().unwrap().0, b);
    }

    #[test]
    fn heap_matches_the_min_scan_model_under_seeded_interleaving() {
        // Property pin for the heap rewrite: against a reference model
        // (the old Vec min-scan, reproduced inline), a seeded interleave
        // of pushes and pops with heavy due collisions must deliver the
        // byte-identical sequence — `(due, seq)` is a strict total
        // order, so there is exactly one correct delivery order.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xB0B_CA7);
        let mut mb = Mailbox::new();
        let mut model: Vec<(Stamp, u64)> = Vec::new();
        let mut model_seq = 0u64;
        let mut payload = 0u64;
        for _ in 0..500 {
            if rng.chance(0.6) || mb.is_empty() {
                // Few distinct due values → constant tie-breaking.
                let due = rng.usize(0, 8) as Ns * 100;
                mb.push(due, payload);
                model.push((Stamp { due, seq: model_seq }, payload));
                model_seq += 1;
                payload += 1;
            } else {
                let min = model.iter().map(|&(s, _)| s).min().unwrap();
                let idx = model.iter().position(|&(s, _)| s == min).unwrap();
                let expect = model.swap_remove(idx);
                assert_eq!(mb.peek_min(), Some(expect.0));
                assert_eq!(mb.pop_min(), Some(expect));
            }
        }
        while let Some(got) = mb.pop_min() {
            let min = model.iter().map(|&(s, _)| s).min().unwrap();
            let idx = model.iter().position(|&(s, _)| s == min).unwrap();
            assert_eq!(got, model.swap_remove(idx));
        }
        assert!(model.is_empty());
    }
}
