//! Stamped mailbox: the delivery queue behind every actor.
//!
//! A [`Mailbox`] holds `(Stamp, T)` pairs and always delivers the
//! minimum [`Stamp`] first — due time, then enqueue order. The seq
//! counter lives *inside* the mailbox, so the tie-break is a pure
//! function of enqueue order and a seeded run replays identically on the
//! deterministic executor. The router's placement queue and every
//! replica inbox are instances of this one type, which is what makes the
//! "no message loss" invariant checkable in one place: whatever is
//! pushed is popped exactly once, in `(due, seq)` order.
//!
//! Implementation note: storage is a plain `Vec` with a linear min-scan
//! and `swap_remove`, not a binary heap. Mailboxes on this path hold at
//! most a few hundred entries (the router's backlog of undispatched
//! arrivals), and the Vec scan preserves the exact pop semantics the
//! pre-actor router used — byte-stable e2e pins depend on it.

use crate::sim::clock::{Ns, Stamp};

/// A `(due, seq)`-ordered delivery queue. See the module docs for the
/// ordering contract.
#[derive(Debug)]
pub struct Mailbox<T> {
    items: Vec<(Stamp, T)>,
    seq: u64,
}

impl<T> Default for Mailbox<T> {
    fn default() -> Self {
        Mailbox { items: Vec::new(), seq: 0 }
    }
}

impl<T> Mailbox<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a message due at `due`; returns the assigned stamp.
    pub fn push(&mut self, due: Ns, msg: T) -> Stamp {
        let stamp = Stamp { due, seq: self.seq };
        self.seq += 1;
        self.items.push((stamp, msg));
        stamp
    }

    /// The stamp that [`Mailbox::pop_min`] would deliver next.
    pub fn peek_min(&self) -> Option<Stamp> {
        self.items.iter().map(|&(s, _)| s).min()
    }

    /// Deliver the minimum-stamped message, removing it from the queue.
    pub fn pop_min(&mut self) -> Option<(Stamp, T)> {
        let min = self.peek_min()?;
        let idx = self
            .items
            .iter()
            .position(|&(s, _)| s == min)
            .expect("peeked stamp vanished");
        Some(self.items.swap_remove(idx))
    }

    /// Current queue depth (undelivered messages).
    pub fn depth(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Total messages ever enqueued (the next stamp's seq).
    pub fn enqueued(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_in_due_then_seq_order() {
        let mut mb = Mailbox::new();
        mb.push(30, "c");
        mb.push(10, "a1");
        mb.push(10, "a2");
        mb.push(20, "b");
        let order: Vec<&str> = std::iter::from_fn(|| mb.pop_min().map(|(_, m)| m)).collect();
        assert_eq!(order, ["a1", "a2", "b", "c"]);
        assert!(mb.is_empty());
    }

    #[test]
    fn same_due_ties_break_by_enqueue_order() {
        let mut mb = Mailbox::new();
        for i in 0..16u32 {
            mb.push(5, i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| mb.pop_min().map(|(_, m)| m)).collect();
        assert_eq!(order, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn no_message_loss_under_interleaved_push_pop() {
        // Interleave pushes and pops with colliding due times; every
        // pushed message must come out exactly once.
        let mut mb = Mailbox::new();
        let mut delivered = Vec::new();
        let mut pushed = 0u64;
        for round in 0..8u64 {
            for k in 0..5u64 {
                mb.push((round / 2) * 10, pushed);
                pushed += 1;
                let _ = k;
            }
            if round % 2 == 1 {
                for _ in 0..3 {
                    if let Some((_, m)) = mb.pop_min() {
                        delivered.push(m);
                    }
                }
            }
        }
        while let Some((_, m)) = mb.pop_min() {
            delivered.push(m);
        }
        assert_eq!(delivered.len() as u64, pushed);
        let mut sorted = delivered.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len() as u64, pushed, "duplicate or lost delivery");
        assert_eq!(mb.enqueued(), pushed);
    }

    #[test]
    fn stamps_are_monotone_in_seq() {
        let mut mb = Mailbox::new();
        let a = mb.push(100, ());
        let b = mb.push(1, ());
        assert_eq!(a.seq, 0);
        assert_eq!(b.seq, 1);
        assert_eq!(mb.depth(), 2);
        // Despite later seq, the earlier due delivers first.
        assert_eq!(mb.pop_min().unwrap().0, b);
    }
}
