//! The default executor: a single-threaded virtual-clock scheduler
//! delivering messages in `(due, seq)` order.
//!
//! This is a behaviour-preserving restructuring of the pre-actor
//! router loop, and every seeded e2e pin depends on the equivalence:
//!
//! 1. **Decision gate** — the next work item (minimum stamp in the
//!    router mailbox) is decided only once no replica with runnable
//!    work is still behind its due time; until then the *first* such
//!    straggler (by replica index) gets a one-iteration
//!    [`ReplicaMsg::Tick`].
//! 2. **Eager report drain** — exactly one replica advances between
//!    decisions, so draining its [`RouterMsg::Released`] reports
//!    immediately after each interaction assigns the same mailbox
//!    `seq` numbers the old loop-top scan produced.
//! 3. **Idle tail** — with the router mailbox empty, the replica with
//!    the smallest virtual clock (first on ties) ticks until no replica
//!    has pending work.
//!
//! The global step budget is `max_iters × replicas`, counted per tick
//! exactly like the old loop; actors themselves run unbounded here
//! (their per-actor budget is the *threaded* executor's tool).

use crate::cluster::placement::ReplicaLoad;
use crate::cluster::router::{ClusterOutcome, RouterCore};
use crate::sim::clock::Ns;

use super::{Executor, ReplicaActor, ReplicaMsg, RouterMsg};

/// Seeded, single-threaded, byte-reproducible. See the module docs.
pub struct DeterministicExecutor;

impl DeterministicExecutor {
    fn tick_one(actor: &mut ReplicaActor, reports: &mut Vec<RouterMsg>) {
        let at = actor.now();
        actor.post(at, ReplicaMsg::Tick { max_steps: 1 });
        actor.process(reports);
    }
}

impl Executor for DeterministicExecutor {
    fn label(&self) -> &'static str {
        "deterministic"
    }

    fn run(
        &mut self,
        mut core: RouterCore,
        mut actors: Vec<ReplicaActor>,
        max_iters: u64,
    ) -> ClusterOutcome {
        // Global backstop against runaway runs, pro-rated per replica.
        let max_steps = max_iters.saturating_mul(actors.len() as u64);
        let mut steps = 0u64;
        let mut reports: Vec<RouterMsg> = Vec::new();
        loop {
            match core.peek_due() {
                Some(stamp) => {
                    let due = stamp.due;
                    // Let every replica that still has runnable work
                    // catch up to the decision time first, so the load
                    // snapshot the placement sees is causal.
                    if let Some(a) = actors
                        .iter_mut()
                        .find(|a| a.runnable() && a.now() < due)
                    {
                        Self::tick_one(a, &mut reports);
                        steps += 1;
                        if steps >= max_steps {
                            break;
                        }
                        drain_reports(&mut core, &mut actors, &mut reports);
                        continue;
                    }
                    let loads: Vec<ReplicaLoad> = actors.iter().map(|a| a.load()).collect();
                    let deliveries = core.route(&loads).expect("peeked work vanished");
                    for (replica, msg_due, msg) in deliveries {
                        deliver(&mut actors, replica, msg_due, msg, &mut reports);
                    }
                    drain_reports(&mut core, &mut actors, &mut reports);
                }
                None => {
                    // No undispatched work: advance the laggard (its
                    // next turn release is the only thing that can
                    // refill the mailbox), first-by-index on clock ties.
                    if let Some(a) = actors
                        .iter_mut()
                        .filter(|a| a.runnable())
                        .min_by_key(|a| a.now())
                    {
                        Self::tick_one(a, &mut reports);
                        steps += 1;
                        if steps >= max_steps {
                            break;
                        }
                        drain_reports(&mut core, &mut actors, &mut reports);
                    } else {
                        break;
                    }
                }
            }
        }
        let outcomes = actors.into_iter().map(|a| a.into_outcome()).collect();
        core.into_outcome(outcomes)
    }
}

fn deliver(
    actors: &mut [ReplicaActor],
    replica: usize,
    due: Ns,
    msg: ReplicaMsg,
    reports: &mut Vec<RouterMsg>,
) {
    actors[replica].post(due, msg);
    actors[replica].process(reports);
}

/// Feed replica reports back into the router until the worklist
/// settles. A [`RouterMsg::Migrated`] reply produces the target's
/// [`ReplicaMsg::Arrive`] delivery, whose own report lands on the same
/// worklist; status reports are the threaded executor's handshake and
/// carry nothing here (the deterministic executor reads actor state
/// synchronously).
fn drain_reports(
    core: &mut RouterCore,
    actors: &mut [ReplicaActor],
    reports: &mut Vec<RouterMsg>,
) {
    while !reports.is_empty() {
        let batch: Vec<RouterMsg> = std::mem::take(reports);
        for msg in batch {
            match msg {
                RouterMsg::Released { replica, id, due } => core.on_released(replica, id, due),
                RouterMsg::Migrated { replica, to, at, conv } => {
                    if let Some((target, due, m)) = core.on_migrated(replica, to, at, conv) {
                        deliver(actors, target, due, m, reports);
                    }
                }
                RouterMsg::Status { .. } | RouterMsg::Finished { .. } => {}
            }
        }
    }
}
