//! The compiled model: PJRT executables + weights + Rust-owned KV state.
//!
//! Real PJRT execution is gated behind the `xla` cargo feature (the
//! crate's dependency closure is only available when vendored — see
//! Cargo.toml). The default build substitutes a stub whose `load`
//! reports [`RuntimeError::XlaUnavailable`], so every simulation path,
//! experiment, and test compiles and runs fully offline.

use std::path::Path;

#[cfg(feature = "xla")]
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::meta::ModelMeta;

#[derive(Debug)]
pub enum RuntimeError {
    #[cfg(feature = "xla")]
    Xla(xla::Error),
    Io(std::io::Error),
    Meta(super::meta::MetaError),
    ParamsSize { got: usize, want: usize },
    BatchTooLarge(usize),
    ArtifactMissing(String),
    /// Real execution requested but the crate was built without the
    /// `xla` feature.
    XlaUnavailable,
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            #[cfg(feature = "xla")]
            RuntimeError::Xla(e) => write!(f, "xla: {e}"),
            RuntimeError::Io(e) => write!(f, "io: {e}"),
            RuntimeError::Meta(e) => write!(f, "meta: {e}"),
            RuntimeError::ParamsSize { got, want } => {
                write!(f, "params.bin size mismatch: got {got} bytes, want {want}")
            }
            RuntimeError::BatchTooLarge(n) => {
                write!(f, "batch {n} exceeds the largest compiled decode variant")
            }
            RuntimeError::ArtifactMissing(p) => write!(f, "artifact missing: {p}"),
            RuntimeError::XlaUnavailable => write!(
                f,
                "real PJRT execution requires building with `--features xla` \
                 (and a vendored xla crate)"
            ),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            RuntimeError::Meta(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

impl From<super::meta::MetaError> for RuntimeError {
    fn from(e: super::meta::MetaError) -> Self {
        RuntimeError::Meta(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e)
    }
}

/// Rust-owned paged KV caches (the "GPU memory" of the real backend).
/// Layout matches the python side: `[L, NB, BS, KH, D]`, row-major f32.
pub struct KvState {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Elements of one (layer, block): BS·KH·D.
    pub block_layer: usize,
    pub num_blocks: usize,
    pub n_layers: usize,
}

impl KvState {
    pub fn new(meta: &ModelMeta) -> Self {
        KvState {
            k: vec![0.0; meta.cache_elements()],
            v: vec![0.0; meta.cache_elements()],
            block_layer: meta.block_layer_elements(),
            num_blocks: meta.num_blocks,
            n_layers: meta.n_layers,
        }
    }

    /// Flat offset of (layer, block).
    pub fn offset(&self, layer: usize, block: usize) -> usize {
        debug_assert!(layer < self.n_layers && block < self.num_blocks);
        (layer * self.num_blocks + block) * self.block_layer
    }
}

/// Loaded model: executables, weights, caches.
///
/// Perf (§Perf runtime): weights are uploaded to the PJRT device ONCE as
/// `xla::PjRtBuffer`s and every call uses `execute_b`, so the ~22 MB of
/// parameters are not re-transferred per decode step (they were with the
/// `execute(&[Literal])` path). KV caches still round-trip per call:
/// the crate returns multi-output results as a single tuple buffer whose
/// elements cannot be re-fed as inputs, so device-resident caches are
/// blocked at the binding layer (documented in EXPERIMENTS.md §Perf).
#[cfg(feature = "xla")]
pub struct PjrtModel {
    pub meta: ModelMeta,
    client: PjRtClient,
    /// (batch size, executable), ascending.
    decode: Vec<(usize, PjRtLoadedExecutable)>,
    prefill: PjRtLoadedExecutable,
    /// Device-resident weights, in param_spec order.
    param_bufs: Vec<xla::PjRtBuffer>,
    pub kv: KvState,
}

#[cfg(feature = "xla")]
fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable, RuntimeError> {
    if !path.exists() {
        return Err(RuntimeError::ArtifactMissing(path.display().to_string()));
    }
    let proto = HloModuleProto::from_text_file(path.to_str().unwrap())?;
    let comp = XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

#[cfg(feature = "xla")]
impl PjrtModel {
    /// Load everything from `artifacts/`.
    pub fn load(dir: &Path) -> Result<Self, RuntimeError> {
        let meta = ModelMeta::load(&dir.join("model_meta.txt"))?;
        let client = PjRtClient::cpu()?;

        let mut decode = Vec::new();
        for &b in &meta.decode_batch_sizes {
            let exe = compile(&client, &dir.join(format!("decode_b{b}.hlo.txt")))?;
            decode.push((b, exe));
        }
        decode.sort_by_key(|(b, _)| *b);
        let prefill = compile(
            &client,
            &dir.join(format!("prefill_t{}.hlo.txt", meta.prefill_chunk)),
        )?;

        // Stream weights.
        let raw = std::fs::read(dir.join("params.bin"))?;
        let want = meta.total_param_elements() * 4;
        if raw.len() != want {
            return Err(RuntimeError::ParamsSize {
                got: raw.len(),
                want,
            });
        }
        let mut param_bufs = Vec::with_capacity(meta.tensors.len());
        let mut off = 0usize;
        for t in &meta.tensors {
            let n = t.elements();
            let mut buf = vec![0f32; n];
            for (i, chunk) in raw[off..off + n * 4].chunks_exact(4).enumerate() {
                buf[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            off += n * 4;
            // Upload once; device-resident for the process lifetime.
            param_bufs.push(client.buffer_from_host_buffer(&buf, &t.shape, None)?);
        }

        let kv = KvState::new(&meta);
        Ok(PjrtModel {
            meta,
            client,
            decode,
            prefill,
            param_bufs,
            kv,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn cache_buffers(&self) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer), RuntimeError> {
        let m = &self.meta;
        let dims = [
            m.n_layers,
            m.num_blocks,
            m.block_size,
            m.n_kv_heads,
            m.head_dim,
        ];
        Ok((
            self.client.buffer_from_host_buffer(&self.kv.k, &dims, None)?,
            self.client.buffer_from_host_buffer(&self.kv.v, &dims, None)?,
        ))
    }

    fn i32_buffer(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer, RuntimeError> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn write_back_caches(&mut self, k: &Literal, v: &Literal) -> Result<(), RuntimeError> {
        k.copy_raw_to(&mut self.kv.k)?;
        v.copy_raw_to(&mut self.kv.v)?;
        Ok(())
    }

    /// One decode iteration. Slices must all have the same length
    /// `n <= max compiled batch`; inactive behavior follows the L2
    /// contract (token 0 / context_len 0 rows are padding).
    /// Returns next token ids (same length as the input batch).
    pub fn decode(
        &mut self,
        token_ids: &[i32],
        positions: &[i32],
        block_tables: &[Vec<i32>],
        context_lens: &[i32],
    ) -> Result<Vec<i32>, RuntimeError> {
        let n = token_ids.len();
        let (bsz, _) = *self
            .decode
            .iter()
            .find(|(b, _)| *b >= n)
            .ok_or(RuntimeError::BatchTooLarge(n))?;
        let maxb = self.meta.max_blocks_per_seq;

        // Pad to the variant's batch size.
        let pad = |xs: &[i32]| -> Vec<i32> {
            let mut v = xs.to_vec();
            v.resize(bsz, 0);
            v
        };
        let mut bt = vec![0i32; bsz * maxb];
        for (i, row) in block_tables.iter().enumerate() {
            for (j, &b) in row.iter().take(maxb).enumerate() {
                bt[i * maxb + j] = b;
            }
        }

        let (kc, vc) = self.cache_buffers()?;
        let toks = self.i32_buffer(&pad(token_ids), &[bsz])?;
        let pos = self.i32_buffer(&pad(positions), &[bsz])?;
        let btl = self.i32_buffer(&bt, &[bsz, maxb])?;
        let cl = self.i32_buffer(&pad(context_lens), &[bsz])?;

        let exe = &self
            .decode
            .iter()
            .find(|(b, _)| *b == bsz)
            .unwrap()
            .1;
        let mut inputs: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        inputs.push(&kc);
        inputs.push(&vc);
        inputs.push(&toks);
        inputs.push(&pos);
        inputs.push(&btl);
        inputs.push(&cl);

        let result = exe.execute_b::<&xla::PjRtBuffer>(&inputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let (next, k_new, v_new) = (&parts[0], &parts[1], &parts[2]);
        self.write_back_caches(k_new, v_new)?;
        let all: Vec<i32> = next.to_vec()?;
        Ok(all[..n].to_vec())
    }

    /// Prefill one chunk of one request (prefix reuse). Returns the
    /// greedy next token (meaningful on the final chunk).
    pub fn prefill(
        &mut self,
        token_ids: &[i32],
        prefix_len: i32,
        t_actual: i32,
        block_table: &[i32],
    ) -> Result<i32, RuntimeError> {
        let t = self.meta.prefill_chunk;
        let maxb = self.meta.max_blocks_per_seq;
        let mut toks = token_ids.to_vec();
        toks.resize(t, 0);
        let mut btv = block_table.to_vec();
        btv.resize(maxb, 0);

        let (kc, vc) = self.cache_buffers()?;
        let toks = self.i32_buffer(&toks, &[t])?;
        let pfx = self.i32_buffer(&[prefix_len], &[])?;
        let ta = self.i32_buffer(&[t_actual], &[])?;
        let btl = self.i32_buffer(&btv, &[maxb])?;

        let mut inputs: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        inputs.push(&kc);
        inputs.push(&vc);
        inputs.push(&toks);
        inputs.push(&pfx);
        inputs.push(&ta);
        inputs.push(&btl);

        let result =
            self.prefill.execute_b::<&xla::PjRtBuffer>(&inputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        self.write_back_caches(&parts[1], &parts[2])?;
        let next: i32 = parts[0].get_first_element()?;
        Ok(next)
    }

    /// Largest compiled decode batch.
    pub fn max_batch(&self) -> usize {
        self.decode.last().map(|(b, _)| *b).unwrap_or(0)
    }
}

/// Offline stub: same surface as the real model so the server layer and
/// CLI compile without the `xla` feature; `load` always fails with
/// [`RuntimeError::XlaUnavailable`], so no instance can exist and the
/// method bodies are unreachable in practice.
#[cfg(not(feature = "xla"))]
pub struct PjrtModel {
    pub meta: ModelMeta,
    pub kv: KvState,
}

#[cfg(not(feature = "xla"))]
impl PjrtModel {
    pub fn load(_dir: &Path) -> Result<Self, RuntimeError> {
        Err(RuntimeError::XlaUnavailable)
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn decode(
        &mut self,
        _token_ids: &[i32],
        _positions: &[i32],
        _block_tables: &[Vec<i32>],
        _context_lens: &[i32],
    ) -> Result<Vec<i32>, RuntimeError> {
        Err(RuntimeError::XlaUnavailable)
    }

    pub fn prefill(
        &mut self,
        _token_ids: &[i32],
        _prefix_len: i32,
        _t_actual: i32,
        _block_table: &[i32],
    ) -> Result<i32, RuntimeError> {
        Err(RuntimeError::XlaUnavailable)
    }

    pub fn max_batch(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_state_offsets() {
        let meta = ModelMeta::parse(
            "fastswitch-model-meta v1\n\
             vocab 64\nd_model 32\nn_layers 2\nn_heads 2\nn_kv_heads 2\n\
             head_dim 16\nd_ff 64\nmax_seq 32\nnum_blocks 8\nblock_size 8\n\
             max_blocks_per_seq 4\nprefill_chunk 8\ndecode_batch_sizes 1,2\n",
        )
        .unwrap();
        let kv = KvState::new(&meta);
        let bl = meta.block_layer_elements();
        assert_eq!(kv.offset(0, 0), 0);
        assert_eq!(kv.offset(0, 1), bl);
        assert_eq!(kv.offset(1, 0), 8 * bl);
        assert_eq!(kv.k.len(), meta.cache_elements());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_reports_unavailable() {
        let err = PjrtModel::load(Path::new("/nonexistent")).unwrap_err();
        assert!(matches!(err, RuntimeError::XlaUnavailable));
        assert!(err.to_string().contains("xla"));
    }
}
