//! Parser for `artifacts/model_meta.txt` — the contract emitted by
//! `python/compile/aot.py` describing the AOT-compiled model: geometry,
//! shape variants, and the `params.bin` tensor manifest.

use std::collections::HashMap;

#[derive(Debug)]
pub enum MetaError {
    Io(std::io::Error),
    BadHeader(String),
    MissingKey(&'static str),
    Malformed(usize, String),
}

impl std::fmt::Display for MetaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetaError::Io(e) => write!(f, "io: {e}"),
            MetaError::BadHeader(h) => write!(f, "bad header: {h:?}"),
            MetaError::MissingKey(k) => write!(f, "missing key {k}"),
            MetaError::Malformed(line, text) => write!(f, "malformed line {line}: {text:?}"),
        }
    }
}

impl std::error::Error for MetaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MetaError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for MetaError {
    fn from(e: std::io::Error) -> Self {
        MetaError::Io(e)
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed model metadata.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub num_blocks: usize,
    pub block_size: usize,
    pub max_blocks_per_seq: usize,
    pub prefill_chunk: usize,
    pub decode_batch_sizes: Vec<usize>,
    pub tensors: Vec<TensorSpec>,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<Self, MetaError> {
        let mut lines = text.lines().enumerate();
        let (_, header) = lines
            .next()
            .ok_or_else(|| MetaError::BadHeader(String::new()))?;
        if header.trim() != "fastswitch-model-meta v1" {
            return Err(MetaError::BadHeader(header.to_string()));
        }
        let mut kv: HashMap<&str, &str> = HashMap::new();
        let mut tensors = Vec::new();
        for (i, line) in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("tensor ") {
                let mut parts = rest.split_whitespace();
                let name = parts
                    .next()
                    .ok_or_else(|| MetaError::Malformed(i + 1, line.into()))?;
                let dims = parts
                    .next()
                    .ok_or_else(|| MetaError::Malformed(i + 1, line.into()))?;
                let shape: Result<Vec<usize>, _> =
                    dims.split('x').map(|d| d.parse::<usize>()).collect();
                tensors.push(TensorSpec {
                    name: name.to_string(),
                    shape: shape.map_err(|_| MetaError::Malformed(i + 1, line.into()))?,
                });
            } else if let Some((k, v)) = line.split_once(' ') {
                kv.insert(k, v);
            } else {
                return Err(MetaError::Malformed(i + 1, line.into()));
            }
        }
        fn get(kv: &HashMap<&str, &str>, k: &'static str) -> Result<usize, MetaError> {
            kv.get(k)
                .and_then(|v| v.parse().ok())
                .ok_or(MetaError::MissingKey(k))
        }
        let decode_batch_sizes = kv
            .get("decode_batch_sizes")
            .ok_or(MetaError::MissingKey("decode_batch_sizes"))?
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect();
        Ok(ModelMeta {
            vocab: get(&kv, "vocab")?,
            d_model: get(&kv, "d_model")?,
            n_layers: get(&kv, "n_layers")?,
            n_heads: get(&kv, "n_heads")?,
            n_kv_heads: get(&kv, "n_kv_heads")?,
            head_dim: get(&kv, "head_dim")?,
            d_ff: get(&kv, "d_ff")?,
            max_seq: get(&kv, "max_seq")?,
            num_blocks: get(&kv, "num_blocks")?,
            block_size: get(&kv, "block_size")?,
            max_blocks_per_seq: get(&kv, "max_blocks_per_seq")?,
            prefill_chunk: get(&kv, "prefill_chunk")?,
            decode_batch_sizes,
            tensors,
        })
    }

    pub fn load(path: &std::path::Path) -> Result<Self, MetaError> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Total f32 elements in params.bin.
    pub fn total_param_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.elements()).sum()
    }

    /// Elements of one full KV cache tensor [L, NB, BS, KH, D].
    pub fn cache_elements(&self) -> usize {
        self.n_layers * self.num_blocks * self.block_size * self.n_kv_heads * self.head_dim
    }

    /// Elements of one block in one layer (the copy granularity).
    pub fn block_layer_elements(&self) -> usize {
        self.block_size * self.n_kv_heads * self.head_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
fastswitch-model-meta v1
vocab 64
d_model 32
n_layers 1
n_heads 2
n_kv_heads 2
head_dim 16
d_ff 64
max_seq 32
num_blocks 8
block_size 8
max_blocks_per_seq 4
prefill_chunk 8
decode_batch_sizes 1,2
tensor embed 64x32
tensor pos_embed 32x32
tensor ln_f 32
";

    #[test]
    fn parse_roundtrip() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.vocab, 64);
        assert_eq!(m.decode_batch_sizes, vec![1, 2]);
        assert_eq!(m.tensors.len(), 3);
        assert_eq!(m.tensors[0].shape, vec![64, 32]);
        assert_eq!(m.tensors[2].shape, vec![32]);
        assert_eq!(m.total_param_elements(), 64 * 32 + 32 * 32 + 32);
        assert_eq!(m.cache_elements(), 8 * 8 * 2 * 16);
        assert_eq!(m.block_layer_elements(), 8 * 2 * 16);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(matches!(
            ModelMeta::parse("nope v9"),
            Err(MetaError::BadHeader(_))
        ));
    }

    #[test]
    fn rejects_missing_key() {
        let text = SAMPLE.replace("vocab 64\n", "");
        assert!(matches!(
            ModelMeta::parse(&text),
            Err(MetaError::MissingKey("vocab"))
        ));
    }

    #[test]
    fn rejects_malformed_tensor() {
        let text = format!("{SAMPLE}tensor bad\n");
        assert!(matches!(
            ModelMeta::parse(&text),
            Err(MetaError::Malformed(..))
        ));
    }
}
