//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them on
//! the serving hot path — Python is never involved at runtime.
//!
//! Pipeline (see /opt/xla-example/README.md for the interchange gotchas):
//! `python -m compile.aot` lowers the paged-KV transformer to **HLO
//! text**; here `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` produces one loaded executable per shape variant
//! (decode at batch 1/4/8, prefill at one chunk size). Weights stream
//! from `params.bin` once at startup.
//!
//! KV caches live in Rust-owned buffers ([`model::KvState`]); each
//! executable call passes them in and receives the updated caches back.
//! Swap in real mode = physical `memcpy` between the GPU-pool and
//! CPU-pool buffers, dispatched through [`crate::swap::pool::CopyPool`].
//!
//! The [`actor`] submodule is the *cluster* runtime: replica engines as
//! message-driven actors behind a pluggable executor (deterministic
//! virtual-clock or threaded `--parallel`).

pub mod actor;
pub mod meta;
pub mod model;

pub use meta::{MetaError, ModelMeta};
pub use model::{PjrtModel, RuntimeError};
