//! KV Cache Reuse Mechanism (paper §3.3).
//!
//! Keeps CPU-side copies of swapped KV cache across preemptions and
//! conversation turns, and tracks which copies are still *valid* so a
//! swap-out transfers only the delta:
//!
//! - KV blocks are append-only: once a block is full, its content never
//!   changes, so a CPU copy of a full block stays valid until the CPU
//!   slot is reclaimed by a higher-priority request (*contamination*,
//!   handled by [`crate::memory::CpuSwapSpace`]).
//! - The partially filled tail block is volatile: it must be
//!   re-transferred whenever the sequence has grown since the copy.
//!
//! The planner returns the exact logical block set to move; the engine
//! turns that into DMA segments. With reuse off (vLLM baseline), every
//! swap-out moves the full table and swap-in drops the CPU copy.

use std::collections::HashMap;

use crate::memory::{CpuSwapSpace, RequestId};

/// Outcome of planning one swap-out.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SwapOutPlan {
    /// Logical block indices that must be transferred GPU→CPU.
    pub transfer: Vec<u32>,
    /// Logical blocks skipped thanks to valid CPU copies (metrics,
    /// Table 1).
    pub reused: u32,
}

#[derive(Clone, Copy, Debug, Default)]
struct ReuseState {
    /// Tokens covered by the newest complete CPU copy.
    copied_tokens: u64,
}

#[derive(Clone, Debug)]
pub struct KvCacheReuse {
    enabled: bool,
    block_size: usize,
    state: HashMap<RequestId, ReuseState>,
    // ---- statistics (Table 1) ----
    pub blocks_transferred_out: u64,
    pub blocks_reused: u64,
}

impl KvCacheReuse {
    pub fn new(enabled: bool, block_size: usize) -> Self {
        KvCacheReuse {
            enabled,
            block_size: block_size.max(1),
            state: HashMap::new(),
            blocks_transferred_out: 0,
            blocks_reused: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    fn n_blocks(&self, tokens: u64) -> u32 {
        tokens.div_ceil(self.block_size as u64) as u32
    }

    /// Plan a swap-out of `req` currently holding `tokens` tokens.
    /// `cpu` is consulted for currently valid copies.
    pub fn plan_swap_out(
        &mut self,
        req: RequestId,
        tokens: u64,
        cpu: &CpuSwapSpace,
    ) -> SwapOutPlan {
        let total = self.n_blocks(tokens);
        self.plan_swap_out_range(req, tokens, 0, total, cpu)
    }

    /// Plan a swap-out restricted to logical blocks `lo..hi` of a
    /// request holding `tokens` tokens — the partial-eviction planner:
    /// a `partial_tail` preemption moves only the evicted suffix, and a
    /// later full eviction of a partially-resident request moves only
    /// its resident head (`0..held`). Blocks outside the range are
    /// neither transferred nor counted as reused.
    pub fn plan_swap_out_range(
        &mut self,
        req: RequestId,
        tokens: u64,
        lo: u32,
        hi: u32,
        cpu: &CpuSwapSpace,
    ) -> SwapOutPlan {
        debug_assert!(hi <= self.n_blocks(tokens) && lo <= hi);
        if !self.enabled {
            self.blocks_transferred_out += (hi - lo) as u64;
            return SwapOutPlan {
                transfer: (lo..hi).collect(),
                reused: 0,
            };
        }
        let st = self.state.get(&req).copied().unwrap_or_default();
        // Blocks < durable are full AND covered by the last copy; they
        // changed only if contaminated (absent from the valid set).
        let durable = if tokens > st.copied_tokens {
            // Sequence grew: the previous copy's tail block (if partial)
            // is stale.
            (st.copied_tokens / self.block_size as u64) as u32
        } else {
            // No growth since the copy: everything copied is still exact.
            self.n_blocks(st.copied_tokens)
        };
        let valid = cpu.valid_logical(req);
        let mut valid_iter = valid.iter().peekable();
        let mut transfer = Vec::new();
        for i in lo..hi {
            while valid_iter.peek().is_some_and(|&&v| v < i) {
                valid_iter.next();
            }
            let has_copy = valid_iter.peek().is_some_and(|&&v| v == i);
            if i < durable && has_copy {
                self.blocks_reused += 1;
            } else {
                transfer.push(i);
            }
        }
        self.blocks_transferred_out += transfer.len() as u64;
        SwapOutPlan {
            reused: (hi - lo) - transfer.len() as u32,
            transfer,
        }
    }

    /// Record that the swap-out completed and the CPU copy now covers
    /// `tokens` tokens.
    pub fn commit_swap_out(&mut self, req: RequestId, tokens: u64) {
        self.state.insert(req, ReuseState { copied_tokens: tokens });
    }

    /// Plan a swap-in: all blocks of the sequence move CPU→GPU.
    pub fn plan_swap_in(&self, tokens: u64) -> Vec<u32> {
        (0..self.n_blocks(tokens)).collect()
    }

    /// The request finished (or its copy is being abandoned): forget it.
    pub fn forget(&mut self, req: RequestId) {
        self.state.remove(&req);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BS: usize = 16;

    fn setup(enabled: bool, cpu_slots: usize) -> (KvCacheReuse, CpuSwapSpace) {
        (KvCacheReuse::new(enabled, BS), CpuSwapSpace::new(cpu_slots))
    }

    /// Simulate a committed swap-out: copies registered in CPU space.
    fn do_swap_out(
        r: &mut KvCacheReuse,
        cpu: &mut CpuSwapSpace,
        req: RequestId,
        tokens: u64,
        prio: i64,
    ) -> SwapOutPlan {
        let plan = r.plan_swap_out(req, tokens, cpu);
        cpu.add_copies(req, &plan.transfer, prio).unwrap();
        r.commit_swap_out(req, tokens);
        plan
    }

    #[test]
    fn baseline_transfers_everything_every_time() {
        let (mut r, mut cpu) = setup(false, 64);
        let p1 = do_swap_out(&mut r, &mut cpu, 1, 100, 5);
        assert_eq!(p1.transfer.len(), 7); // ceil(100/16)
        // Re-swap-out after growth: again everything.
        let p2 = r.plan_swap_out(1, 120, &cpu);
        assert_eq!(p2.transfer.len(), 8);
        assert_eq!(p2.reused, 0);
    }

    #[test]
    fn reuse_skips_full_copied_blocks() {
        let (mut r, mut cpu) = setup(true, 64);
        // First swap-out at 100 tokens: all 7 blocks move.
        let p1 = do_swap_out(&mut r, &mut cpu, 1, 100, 5);
        assert_eq!(p1.transfer.len(), 7);
        // Resume, grow to 120 tokens, swap out again: blocks 0..5 are full
        // + copied (durable); block 6 was partial at copy time (stale) and
        // block 7 is new.
        let p2 = r.plan_swap_out(1, 120, &cpu);
        assert_eq!(p2.transfer, vec![6, 7]);
        assert_eq!(p2.reused, 6);
    }

    #[test]
    fn no_growth_means_no_transfer() {
        let (mut r, mut cpu) = setup(true, 64);
        do_swap_out(&mut r, &mut cpu, 1, 100, 5);
        // Swapped in but preempted again before generating anything.
        let p = r.plan_swap_out(1, 100, &cpu);
        assert!(p.transfer.is_empty());
        assert_eq!(p.reused, 7);
    }

    #[test]
    fn contaminated_blocks_retransferred() {
        let (mut r, mut cpu) = setup(true, 16);
        do_swap_out(&mut r, &mut cpu, 1, 100, 1); // 7 blocks, low prio
        cpu.set_required(1, false); // request back on GPU; copy is a backup
        // Higher-priority request floods the CPU space.
        cpu.contaminate_backups(12, 9);
        let remaining = cpu.valid_logical(1);
        assert!(remaining.len() < 7);
        let p = r.plan_swap_out(1, 100, &cpu);
        // Exactly the contaminated blocks must move again.
        assert_eq!(p.transfer.len(), 7 - remaining.len());
        for l in &remaining {
            assert!(!p.transfer.contains(l));
        }
    }

    #[test]
    fn exact_block_boundary_tail_is_durable() {
        let (mut r, mut cpu) = setup(true, 64);
        do_swap_out(&mut r, &mut cpu, 1, 64, 5); // 4 full blocks, no partial
        let p = r.plan_swap_out(1, 80, &cpu); // grew one block
        assert_eq!(p.transfer, vec![4]);
        assert_eq!(p.reused, 4);
    }

    #[test]
    fn multi_turn_accumulates_reuse() {
        // Table 1 shape: across turns, transferred blocks ≈ increments
        // only → large total reduction vs baseline.
        let (mut r, mut cpu) = setup(true, 256);
        let (mut rb, mut cpub) = setup(false, 256);
        let mut tokens = 0u64;
        let mut reuse_moved = 0usize;
        let mut base_moved = 0usize;
        for turn in 0..6 {
            tokens += 96; // each turn adds 6 blocks
            reuse_moved += do_swap_out(&mut r, &mut cpu, 1, tokens, 5).transfer.len();
            base_moved += do_swap_out(&mut rb, &mut cpub, 1, tokens, 5)
                .transfer
                .len();
            let _ = turn;
        }
        assert!(reuse_moved * 2 < base_moved, "{reuse_moved} vs {base_moved}");
        assert_eq!(r.blocks_transferred_out as usize, reuse_moved);
        assert!(r.blocks_reused > 0);
    }

    #[test]
    fn range_plan_covers_only_the_tail() {
        let (mut r, mut cpu) = setup(true, 64);
        // 100 tokens = 7 blocks; evict only the last 2 (logical 5..7):
        // nothing is copied yet, so both must move.
        let p = r.plan_swap_out_range(1, 100, 5, 7, &cpu);
        assert_eq!(p.transfer, vec![5, 6]);
        assert_eq!(p.reused, 0);
        cpu.add_copies(1, &p.transfer, 5).unwrap();
        r.commit_swap_out(1, 100);
        // A later full-context plan re-uses the tail copies (durable —
        // no growth since the commit) and moves only the head.
        let full = r.plan_swap_out(1, 100, &cpu);
        assert_eq!(full.transfer, vec![0, 1, 2, 3, 4]);
        assert_eq!(full.reused, 2);
        // A head-restricted plan (partially-resident eviction) never
        // touches the tail logicals.
        let head = r.plan_swap_out_range(1, 100, 0, 5, &cpu);
        assert_eq!(head.transfer, vec![0, 1, 2, 3, 4]);
        assert_eq!(head.reused, 0);
    }

    #[test]
    fn range_plan_disabled_transfers_whole_range() {
        let (mut r, cpu) = setup(false, 64);
        let p = r.plan_swap_out_range(1, 100, 3, 7, &cpu);
        assert_eq!(p.transfer, vec![3, 4, 5, 6]);
        assert_eq!(p.reused, 0);
        assert_eq!(r.blocks_transferred_out, 4);
    }

    #[test]
    fn forget_resets_state() {
        let (mut r, mut cpu) = setup(true, 64);
        do_swap_out(&mut r, &mut cpu, 1, 100, 5);
        r.forget(1);
        cpu.drop_request(1);
        let p = r.plan_swap_out(1, 100, &cpu);
        assert_eq!(p.transfer.len(), 7, "fresh request transfers all");
    }
}
