//! Cross-request radix prefix index over KV block hashes — the global
//! prefix cache (shared system prompts, few-shot templates).
//!
//! Full blocks of a shared prompt template are content-hashed as they
//! are prefilled and registered in a per-replica [`PrefixIndex`] as a
//! radix tree of refcounted nodes. Every node owns exactly one GPU KV
//! block, allocated from the engine's own [`KvAllocator`] under a
//! reserved pseudo request id — so GPU block conservation holds with no
//! special cases: pool blocks are "used" blocks like any other.
//!
//! On admission the scheduler matches a fresh request's template
//! against the index and grants only the uncached suffix: the matched
//! path is pinned (+1 refcount per node) for the request's lifetime,
//! its `prefill_target` shrinks by the matched depth, and VTC charges
//! only the uncached tokens (prefill charges are per applied chunk, so
//! this falls out for free).
//!
//! Eviction is deepest-leaf-first and only ever frees a node at
//! refcount 1 (the index's own reference) — a shared block is never
//! preempted out from under a live request.
//!
//! Conversations carry only token *counts*, so block content is
//! identified by the template's `(group, block index)` pair: two
//! conversations share KV iff they share a
//! [`crate::workload::SharedPrefix`] group, and the per-block hash is a
//! deterministic chain over the group and position.

use std::collections::HashMap;

use crate::memory::{BlockId, RequestId};

use super::KvAllocator;

/// Base of the reserved pseudo request-id range the pool allocates
/// under. Real request ids are dense small integers; anything at or
/// above this base belongs to the prefix pool.
pub const PREFIX_POOL_ID_BASE: RequestId = 0xFFFF_FFFF_0000_0000;

/// Deterministic per-block content hash of a shared template: a
/// splitmix-style chain over `(group, block index)` — two requests with
/// the same template group produce identical chains, which is exactly
/// the "identical token content hashes identically" property a real
/// token-level hasher provides.
pub fn block_hash(group: u64, index: u32) -> u64 {
    let mut h = group
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(index as u64 + 1);
    h ^= h >> 30;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^ (h >> 31)
}

/// One radix node: a published full block of some template.
#[derive(Clone, Debug)]
struct Node {
    hash: u64,
    /// Parent node slot (`None` = depth-1 root child).
    parent: Option<usize>,
    /// Template group this chain belongs to.
    group: u64,
    /// 1-based depth: number of blocks from the template start.
    depth: u32,
    /// Shared-ownership count. The index's own reference counts as 1;
    /// every request that matched through this node adds 1. Evictable
    /// only at exactly 1.
    refcount: u32,
    /// Number of child nodes (leaf ⇔ 0); eviction is leaf-only so the
    /// tree never dangles.
    children: u32,
    /// The GPU KV block this node owns (under its pseudo request id).
    block: BlockId,
}

/// Per-replica refcounted radix index of published template blocks.
///
/// The engine is single-threaded per replica, so the index is plain
/// data; it is `Send` because every field is.
#[derive(Debug, Default)]
pub struct PrefixIndex {
    /// Slab of nodes; freed slots are recycled via `free`.
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    /// Radix edges: (parent slot or None, child hash) → child slot.
    edges: HashMap<(Option<usize>, u64), usize>,
    /// Matched paths pinned per live request (deepest node last).
    pinned: HashMap<RequestId, Vec<usize>>,
    /// Published nodes alive right now.
    live: usize,
    /// Total nodes ever published (monotone).
    pub inserts: u64,
    /// Total nodes ever evicted (monotone).
    pub evictions: u64,
}

impl PrefixIndex {
    pub fn new() -> Self {
        PrefixIndex::default()
    }

    /// Published blocks currently alive in the pool.
    pub fn live_blocks(&self) -> usize {
        self.live
    }

    /// Sum over all nodes of (refcount − 1): outstanding request pins.
    /// Zero once every matched request has released — the dangling-ref
    /// invariant the migration regression pins.
    pub fn pinned_refs(&self) -> u64 {
        self.nodes
            .iter()
            .flatten()
            .map(|n| (n.refcount - 1) as u64)
            .sum()
    }

    /// Every published `(group, depth)` pair — the brute-force oracle
    /// surface for the property suite.
    pub fn published(&self) -> Vec<(u64, u32)> {
        let mut v: Vec<(u64, u32)> = self
            .nodes
            .iter()
            .flatten()
            .map(|n| (n.group, n.depth))
            .collect();
        v.sort_unstable();
        v
    }

    /// Deepest published depth per group, sorted by group — the load
    /// snapshot the prefix-aware placer routes on.
    pub fn group_depths(&self) -> Vec<(u64, u32)> {
        let mut best: HashMap<u64, u32> = HashMap::new();
        for n in self.nodes.iter().flatten() {
            let d = best.entry(n.group).or_insert(0);
            if n.depth > *d {
                *d = n.depth;
            }
        }
        let mut v: Vec<(u64, u32)> = best.into_iter().collect();
        v.sort_unstable_by_key(|&(g, _)| g);
        v
    }

    fn pseudo_id(slot: usize) -> RequestId {
        PREFIX_POOL_ID_BASE + slot as RequestId
    }

    /// Walk the longest cached chain of `group`, up to `max_blocks`.
    /// Returns the node path, shallowest first.
    fn match_path(&self, group: u64, max_blocks: u32) -> Vec<usize> {
        let mut path = Vec::new();
        let mut parent = None;
        for i in 0..max_blocks {
            match self.edges.get(&(parent, block_hash(group, i))) {
                Some(&slot) => {
                    path.push(slot);
                    parent = Some(slot);
                }
                None => break,
            }
        }
        path
    }

    /// Longest cached prefix depth for `group` (blocks), read-only.
    pub fn match_depth(&self, group: u64, max_blocks: u32) -> u32 {
        self.match_path(group, max_blocks).len() as u32
    }

    /// Match and pin: the longest cached chain of `group` (≤
    /// `max_blocks`) gains one reference per node, held until
    /// [`PrefixIndex::release`]. Returns the matched depth in blocks
    /// (0 = miss). A request may hold at most one pinned path.
    pub fn acquire(&mut self, req: RequestId, group: u64, max_blocks: u32) -> u32 {
        debug_assert!(!self.pinned.contains_key(&req), "double acquire for {req}");
        let path = self.match_path(group, max_blocks);
        if path.is_empty() {
            return 0;
        }
        for &slot in &path {
            self.nodes[slot].as_mut().unwrap().refcount += 1;
        }
        let depth = path.len() as u32;
        self.pinned.insert(req, path);
        depth
    }

    /// Drop the request's pinned path (no-op if it holds none).
    pub fn release(&mut self, req: RequestId) {
        if let Some(path) = self.pinned.remove(&req) {
            for slot in path {
                let n = self.nodes[slot].as_mut().unwrap();
                debug_assert!(n.refcount > 1, "release underflow at slot {slot}");
                n.refcount -= 1;
            }
        }
    }

    /// Whether `req` currently pins a matched path.
    pub fn is_pinned(&self, req: RequestId) -> bool {
        self.pinned.contains_key(&req)
    }

    /// Publish the chain of `group` up to `depth_target` blocks,
    /// allocating one pool block per new node (born at refcount 1, the
    /// index's own reference). Publication is opportunistic: it stops —
    /// without error — as soon as the allocator cannot hand out a block
    /// while keeping `reserve` blocks free. Returns the number of nodes
    /// inserted.
    pub fn publish(
        &mut self,
        alloc: &mut dyn KvAllocator,
        group: u64,
        depth_target: u32,
        reserve: usize,
    ) -> u32 {
        let mut parent = None;
        let mut inserted = 0u32;
        for i in 0..depth_target {
            let hash = block_hash(group, i);
            if let Some(&slot) = self.edges.get(&(parent, hash)) {
                parent = Some(slot);
                continue;
            }
            if alloc.available_blocks() <= reserve {
                break;
            }
            let slot = self.free.pop().unwrap_or_else(|| {
                self.nodes.push(None);
                self.nodes.len() - 1
            });
            let block = match alloc.allocate(Self::pseudo_id(slot), 1) {
                Some(blocks) => blocks[0],
                None => {
                    self.free.push(slot);
                    break;
                }
            };
            self.nodes[slot] = Some(Node {
                hash,
                parent,
                group,
                depth: i + 1,
                refcount: 1,
                children: 0,
                block,
            });
            self.edges.insert((parent, hash), slot);
            if let Some(p) = parent {
                self.nodes[p].as_mut().unwrap().children += 1;
            }
            self.live += 1;
            self.inserts += 1;
            parent = Some(slot);
            inserted += 1;
        }
        inserted
    }

    /// Evict the deepest unreferenced leaf (ties → lowest slot),
    /// releasing its pool block back to the allocator. Returns the
    /// freed `(group, depth, block)` or `None` when nothing is
    /// evictable. Never frees a node with refcount > 1 or with
    /// children.
    pub fn evict_one(&mut self, alloc: &mut dyn KvAllocator) -> Option<(u64, u32, BlockId)> {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(slot, n)| n.as_ref().map(|n| (slot, n)))
            .filter(|(_, n)| n.refcount == 1 && n.children == 0)
            .max_by(|a, b| a.1.depth.cmp(&b.1.depth).then(b.0.cmp(&a.0)))?
            .0;
        let n = self.nodes[victim].take().unwrap();
        self.edges.remove(&(n.parent, n.hash));
        if let Some(p) = n.parent {
            self.nodes[p].as_mut().unwrap().children -= 1;
        }
        let freed = alloc.release(Self::pseudo_id(victim));
        debug_assert_eq!(freed, vec![n.block]);
        self.free.push(victim);
        self.live -= 1;
        self.evictions += 1;
        Some((n.group, n.depth, n.block))
    }

    /// Tear the whole pool down, releasing every pool block. Requires
    /// that no request still pins a path (all refcounts are 1).
    pub fn clear(&mut self, alloc: &mut dyn KvAllocator) -> usize {
        assert!(
            self.pinned.is_empty(),
            "clear with {} pinned paths outstanding",
            self.pinned.len()
        );
        let mut freed = 0;
        while self.evict_one(alloc).is_some() {
            freed += 1;
        }
        debug_assert_eq!(self.live, 0);
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::block::fixed::FixedBlockAllocator;

    fn pool(n: usize) -> FixedBlockAllocator {
        FixedBlockAllocator::new(n)
    }

    #[test]
    fn hash_chain_is_deterministic_and_group_distinct() {
        assert_eq!(block_hash(7, 0), block_hash(7, 0));
        assert_ne!(block_hash(7, 0), block_hash(7, 1));
        assert_ne!(block_hash(7, 0), block_hash(8, 0));
    }

    #[test]
    fn publish_then_match_pins_the_path() {
        let mut a = pool(16);
        let mut ix = PrefixIndex::new();
        assert_eq!(ix.publish(&mut a, 5, 3, 0), 3);
        assert_eq!(ix.live_blocks(), 3);
        assert_eq!(a.available_blocks(), 13);
        // Full match, capped match, and miss.
        assert_eq!(ix.match_depth(5, 8), 3);
        assert_eq!(ix.match_depth(5, 2), 2);
        assert_eq!(ix.match_depth(6, 8), 0);
        // Acquire pins every node on the path.
        assert_eq!(ix.acquire(100, 5, 8), 3);
        assert_eq!(ix.pinned_refs(), 3);
        // Pinned nodes are not evictable.
        assert!(ix.evict_one(&mut a).is_none());
        ix.release(100);
        assert_eq!(ix.pinned_refs(), 0);
    }

    #[test]
    fn republish_is_idempotent() {
        let mut a = pool(16);
        let mut ix = PrefixIndex::new();
        ix.publish(&mut a, 1, 2, 0);
        assert_eq!(ix.publish(&mut a, 1, 2, 0), 0, "already published");
        assert_eq!(ix.publish(&mut a, 1, 4, 0), 2, "extends the chain");
        assert_eq!(ix.inserts, 4);
    }

    #[test]
    fn eviction_is_deepest_leaf_first_and_refcount_guarded() {
        let mut a = pool(16);
        let mut ix = PrefixIndex::new();
        ix.publish(&mut a, 1, 3, 0);
        ix.publish(&mut a, 2, 2, 0);
        // Deepest leaf overall is group 1 depth 3.
        let (g, d, _) = ix.evict_one(&mut a).unwrap();
        assert_eq!((g, d), (1, 3));
        // Pin group 1; next evictions must come from group 2 only.
        ix.acquire(7, 1, 8);
        let (g, d, _) = ix.evict_one(&mut a).unwrap();
        assert_eq!((g, d), (2, 2));
        let (g, d, _) = ix.evict_one(&mut a).unwrap();
        assert_eq!((g, d), (2, 1));
        assert!(ix.evict_one(&mut a).is_none(), "group 1 is pinned");
        ix.release(7);
        assert!(ix.evict_one(&mut a).is_some());
    }

    #[test]
    fn publish_respects_the_reserve_and_allocator_capacity() {
        let mut a = pool(4);
        let mut ix = PrefixIndex::new();
        // Keep 2 blocks free: only 2 of 5 requested nodes land.
        assert_eq!(ix.publish(&mut a, 9, 5, 2), 2);
        assert_eq!(a.available_blocks(), 2);
        // Reserve 0 drains the rest.
        assert_eq!(ix.publish(&mut a, 9, 5, 0), 2);
        assert_eq!(a.available_blocks(), 0);
        assert_eq!(ix.live_blocks(), 4);
    }

    #[test]
    fn clear_returns_the_allocator_to_initial_capacity() {
        let mut a = pool(8);
        let before = a.available_blocks();
        let mut ix = PrefixIndex::new();
        ix.publish(&mut a, 1, 3, 0);
        ix.publish(&mut a, 2, 4, 0);
        assert_eq!(ix.clear(&mut a), 7);
        assert_eq!(a.available_blocks(), before);
        assert_eq!(ix.live_blocks(), 0);
        assert_eq!(ix.inserts, 7);
        assert_eq!(ix.evictions, 7);
    }

    #[test]
    fn group_depths_reports_the_deepest_published_block() {
        let mut a = pool(16);
        let mut ix = PrefixIndex::new();
        ix.publish(&mut a, 3, 4, 0);
        ix.publish(&mut a, 1, 2, 0);
        assert_eq!(ix.group_depths(), vec![(1, 2), (3, 4)]);
    }
}
