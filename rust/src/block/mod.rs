//! KV-cache block allocation — the heart of the paper's contribution.
//!
//! Two allocators over the same [`crate::memory::GpuBlockSpace`]:
//!
//! - [`fixed::FixedBlockAllocator`] — the vLLM baseline: individual
//!   blocks from a LIFO free list. Near-zero waste, but after churn a
//!   request's blocks are physically scattered, so preemption swaps one
//!   128 KB segment per block per layer (paper Challenge #1).
//! - [`buddy::BlockGroupAllocator`] — FastSwitch §3.1's Dynamic Block
//!   Group Manager: buddy-style contiguous *block groups* with
//!   split/merge and reserved-tail stealing, so swap traffic coalesces
//!   into few large segments.
//!
//! [`reuse::KvCacheReuse`] adds §3.3's CPU-copy reuse on top of either,
//! and [`prefix::PrefixIndex`] the cross-request global prefix cache.

pub mod buddy;
pub mod fixed;
pub mod prefix;
pub mod reuse;

use crate::memory::{BlockId, GpuBlockSpace, RequestId};

/// A physically contiguous run of blocks, in logical order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockRun {
    pub start: BlockId,
    pub len: u32,
    /// Logical block index of `start` within the request's sequence.
    pub logical_start: u32,
}

/// Common interface of both allocators.
pub trait KvAllocator {
    /// Append `n` blocks to `req`'s block table. Returns the new blocks
    /// (in logical order) or `None` if space is insufficient — the caller
    /// must preempt and retry.
    fn allocate(&mut self, req: RequestId, n: usize) -> Option<Vec<BlockId>>;

    /// Release every block of `req` and forget it. Returns the freed
    /// block table (logical order).
    fn release(&mut self, req: RequestId) -> Vec<BlockId>;

    /// Release only the last `n` blocks of `req`'s table (the logical
    /// tail), keeping the head resident — the partial-eviction primitive
    /// of the `partial_tail` preemption policy. Returns the freed blocks
    /// in logical order. `n >= held` degenerates to a full
    /// [`KvAllocator::release`]. The buddy allocator shrinks the
    /// affected groups in place and re-coalesces the freed ranges (and
    /// any reserved tail beyond them) into the free manager.
    fn release_tail(&mut self, req: RequestId, n: usize) -> Vec<BlockId>;

    /// The request's block table (logical order).
    fn table(&self, req: RequestId) -> &[BlockId];

    /// Blocks that could be handed out right now without preemption
    /// (includes reclaimable reserved tails for the buddy allocator).
    fn available_blocks(&self) -> usize;

    /// The underlying ownership space (for invariant checks).
    fn space(&self) -> &GpuBlockSpace;

    /// Decompose `req`'s table into physically contiguous runs — the
    /// swap engine's coalescing units.
    fn runs(&self, req: RequestId) -> Vec<BlockRun> {
        runs_of_table(self.table(req))
    }
}

/// Merge a logical block table into contiguous physical runs.
pub fn runs_of_table(table: &[BlockId]) -> Vec<BlockRun> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < table.len() {
        let start = table[i];
        let logical_start = i as u32;
        let mut len = 1u32;
        while i + (len as usize) < table.len()
            && table[i + len as usize] == start + len
        {
            len += 1;
        }
        out.push(BlockRun {
            start,
            len,
            logical_start,
        });
        i += len as usize;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_merge_contiguous() {
        let runs = runs_of_table(&[5, 6, 7, 10, 11, 3]);
        assert_eq!(
            runs,
            vec![
                BlockRun { start: 5, len: 3, logical_start: 0 },
                BlockRun { start: 10, len: 2, logical_start: 3 },
                BlockRun { start: 3, len: 1, logical_start: 5 },
            ]
        );
    }

    #[test]
    fn runs_empty() {
        assert!(runs_of_table(&[]).is_empty());
    }

    #[test]
    fn runs_single() {
        assert_eq!(
            runs_of_table(&[42]),
            vec![BlockRun { start: 42, len: 1, logical_start: 0 }]
        );
    }
}
