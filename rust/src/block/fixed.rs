//! vLLM-baseline allocator: individual fixed-size blocks from a LIFO
//! free list.
//!
//! This is deliberately faithful to vLLM 0.3.3's BlockAllocator: freed
//! blocks are pushed on a stack and reused most-recent-first, so after
//! scheduling churn a request's table is physically scattered — exactly
//! the fragmentation that makes its swap granularity one block per layer
//! (128 KB for LLaMA-8B, paper §2.2).

use std::collections::HashMap;

use super::KvAllocator;
use crate::memory::{BlockId, GpuBlockSpace, RequestId};

#[derive(Clone, Debug)]
pub struct FixedBlockAllocator {
    space: GpuBlockSpace,
    free_list: Vec<BlockId>,
    tables: HashMap<RequestId, Vec<BlockId>>,
}

impl FixedBlockAllocator {
    pub fn new(n_blocks: usize) -> Self {
        FixedBlockAllocator {
            space: GpuBlockSpace::new(n_blocks),
            // Pop from the back → ascending ids first allocation.
            free_list: (1..=n_blocks as BlockId).rev().collect(),
            tables: HashMap::new(),
        }
    }

    pub fn n_requests(&self) -> usize {
        self.tables.len()
    }
}

impl KvAllocator for FixedBlockAllocator {
    fn allocate(&mut self, req: RequestId, n: usize) -> Option<Vec<BlockId>> {
        if self.free_list.len() < n {
            return None;
        }
        let mut got = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.free_list.pop().unwrap();
            self.space.claim(b, req);
            got.push(b);
        }
        self.tables.entry(req).or_default().extend(&got);
        Some(got)
    }

    fn release(&mut self, req: RequestId) -> Vec<BlockId> {
        let table = self.tables.remove(&req).unwrap_or_default();
        for &b in &table {
            self.space.reclaim(b, req);
            self.free_list.push(b);
        }
        table
    }

    fn release_tail(&mut self, req: RequestId, n: usize) -> Vec<BlockId> {
        let held = self.table(req).len();
        if n == 0 {
            return Vec::new();
        }
        if n >= held {
            return self.release(req);
        }
        let table = self.tables.get_mut(&req).expect("held > 0");
        let freed = table.split_off(held - n);
        for &b in &freed {
            self.space.reclaim(b, req);
            self.free_list.push(b);
        }
        freed
    }

    fn table(&self, req: RequestId) -> &[BlockId] {
        self.tables.get(&req).map(|t| t.as_slice()).unwrap_or(&[])
    }

    fn available_blocks(&self) -> usize {
        self.free_list.len()
    }

    fn space(&self) -> &GpuBlockSpace {
        &self.space
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::block::runs_of_table;
    use crate::util::rng::Rng;

    #[test]
    fn allocate_release_roundtrip() {
        let mut a = FixedBlockAllocator::new(8);
        let got = a.allocate(1, 3).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(a.table(1), got.as_slice());
        assert_eq!(a.available_blocks(), 5);
        let freed = a.release(1);
        assert_eq!(freed, got);
        assert_eq!(a.available_blocks(), 8);
        a.space().check_invariants();
    }

    #[test]
    fn refuses_over_allocation() {
        let mut a = FixedBlockAllocator::new(4);
        assert!(a.allocate(1, 5).is_none());
        assert!(a.allocate(1, 4).is_some());
        assert!(a.allocate(2, 1).is_none());
    }

    #[test]
    fn release_unknown_request_is_empty() {
        let mut a = FixedBlockAllocator::new(4);
        assert!(a.release(99).is_empty());
    }

    #[test]
    fn churn_fragments_tables() {
        // The defining property of the baseline: after alloc/free churn, a
        // new request's table is scattered → runs of length ~1. This is
        // what Fig. 3(a) depicts.
        let mut a = FixedBlockAllocator::new(256);
        let mut rng = Rng::new(1);
        let mut live: Vec<RequestId> = Vec::new();
        let mut next_id: RequestId = 0;
        for _ in 0..400 {
            if !live.is_empty() && rng.chance(0.5) {
                let idx = rng.usize(0, live.len());
                let r = live.swap_remove(idx);
                a.release(r);
            } else {
                let n = rng.usize(1, 9);
                if a.allocate(next_id, n).is_some() {
                    live.push(next_id);
                    next_id += 1;
                }
            }
        }
        // Allocate one sizeable request post-churn and measure granularity.
        let n = 32.min(a.available_blocks());
        a.allocate(next_id, n).unwrap();
        let runs = runs_of_table(a.table(next_id));
        let avg = n as f64 / runs.len() as f64;
        assert!(avg < 3.0, "baseline should fragment, avg run = {avg}");
        a.space().check_invariants();
    }

    #[test]
    fn release_tail_keeps_the_head_resident() {
        let mut a = FixedBlockAllocator::new(16);
        let got = a.allocate(1, 6).unwrap();
        let freed = a.release_tail(1, 2);
        assert_eq!(freed, got[4..].to_vec(), "logical tail, in order");
        assert_eq!(a.table(1), &got[..4]);
        assert_eq!(a.available_blocks(), 12);
        // Edge cases: zero is a no-op, >= held is a full release.
        assert!(a.release_tail(1, 0).is_empty());
        assert_eq!(a.release_tail(1, 99).len(), 4);
        assert!(a.table(1).is_empty());
        assert_eq!(a.available_blocks(), 16);
        a.space().check_invariants();
    }

    #[test]
    fn incremental_growth_appends() {
        let mut a = FixedBlockAllocator::new(16);
        a.allocate(1, 2).unwrap();
        a.allocate(1, 2).unwrap();
        assert_eq!(a.table(1).len(), 4);
    }
}
