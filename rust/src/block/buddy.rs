//! Dynamic Block Group Manager (paper §3.1) — FastSwitch's I/O-aware
//! KV-cache allocator.
//!
//! Analogous to an OS buddy allocator: KV memory is handed out as *block
//! groups* — contiguous runs of vLLM blocks — kept in a Free Block Group
//! Manager (the `free` range map, with split on allocation and merge on
//! release) and a Used Block Group Manager (`groups`, per request). The
//! most recently allocated group of a request is *active*: it holds a
//! reserved tail (`len - used`) that absorbs the request's future growth
//! in place. When the free manager runs dry, the reserved tail of a
//! randomly selected request's active group is *stolen* (split off and
//! reallocated) — so, like vLLM, the allocator wastes no memory under
//! pressure, yet under normal operation swap traffic coalesces into
//! few large segments.
//!
//! Granularity outcome (paper: ≈ 20 blocks/group average on the A10
//! testbed): see `exp::fig11` and the churn tests below.

use std::collections::{BTreeMap, HashMap};

use super::KvAllocator;
use crate::memory::{BlockId, GpuBlockSpace, RequestId};
use crate::util::rng::Rng;

/// One contiguous block group owned by a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Group {
    pub start: BlockId,
    /// Total blocks (used + reserved tail).
    pub len: u32,
    /// Blocks actually holding KV (a prefix of the group).
    pub used: u32,
}

#[derive(Clone, Debug)]
pub struct BlockGroupAllocator {
    space: GpuBlockSpace,
    /// Free Block Group Manager: start -> len, coalesced.
    free: BTreeMap<BlockId, u32>,
    /// Used Block Group Manager: request -> groups in logical order.
    groups: HashMap<RequestId, Vec<Group>>,
    tables: HashMap<RequestId, Vec<BlockId>>,
    init_group_blocks: u32,
    rng: Rng,
    // ---- statistics (Fig. 10/11) ----
    pub splits: u64,
    pub steals: u64,
    pub groups_created: u64,
}

impl BlockGroupAllocator {
    pub fn new(n_blocks: usize, init_group_blocks: usize, seed: u64) -> Self {
        let mut free = BTreeMap::new();
        if n_blocks > 0 {
            free.insert(1, n_blocks as u32);
        }
        BlockGroupAllocator {
            space: GpuBlockSpace::new(n_blocks),
            free,
            groups: HashMap::new(),
            tables: HashMap::new(),
            init_group_blocks: init_group_blocks.max(1) as u32,
            rng: Rng::new(seed ^ 0xD8B6),
            splits: 0,
            steals: 0,
            groups_created: 0,
        }
    }

    pub fn groups_of(&self, req: RequestId) -> &[Group] {
        self.groups.get(&req).map(|g| g.as_slice()).unwrap_or(&[])
    }

    fn free_total(&self) -> u32 {
        self.free.values().sum()
    }

    /// Total reserved (stealable) tail blocks across all used groups.
    fn reserved_tails(&self) -> u32 {
        self.groups
            .values()
            .flat_map(|gs| gs.iter())
            .map(|g| g.len - g.used)
            .sum()
    }

    fn take_range(&mut self, start: BlockId, len: u32) {
        let (&rs, &rl) = self.free.range(..=start).next_back().expect("not free");
        assert!(start >= rs && start + len <= rs + rl, "range not free");
        self.free.remove(&rs);
        if start > rs {
            self.free.insert(rs, start - rs);
            self.splits += 1;
        }
        if rs + rl > start + len {
            self.free.insert(start + len, rs + rl - (start + len));
            self.splits += 1;
        }
    }

    fn release_range(&mut self, start: BlockId, len: u32) {
        if len == 0 {
            return;
        }
        let mut start = start;
        let mut len = len;
        if let Some((&ps, &pl)) = self.free.range(..start).next_back() {
            assert!(ps + pl <= start, "double free of block range");
            if ps + pl == start {
                self.free.remove(&ps);
                start = ps;
                len += pl;
            }
        }
        if let Some((&ns, &nl)) = self.free.range(start + len..).next() {
            if start + len == ns {
                self.free.remove(&ns);
                len += nl;
            }
        }
        self.free.insert(start, len);
    }

    /// Best-fit free range of length >= want; returns (start, len of range).
    fn best_fit(&self, want: u32) -> Option<(BlockId, u32)> {
        self.free
            .iter()
            .filter(|(_, &l)| l >= want)
            .min_by_key(|(_, &l)| l)
            .map(|(&s, &l)| (s, l))
    }

    fn largest(&self) -> Option<(BlockId, u32)> {
        self.free
            .iter()
            .max_by_key(|(_, &l)| l)
            .map(|(&s, &l)| (s, l))
    }

    /// Steal the reserved tail of a randomly selected request's group
    /// (paper: "the active block group currently being used by a randomly
    /// selected request can be taken"). Returns blocks freed.
    fn steal_one_tail(&mut self) -> u32 {
        let mut candidates: Vec<(RequestId, usize)> = self
            .groups
            .iter()
            .flat_map(|(&r, gs)| {
                gs.iter()
                    .enumerate()
                    .filter(|(_, g)| g.len > g.used)
                    .map(move |(i, _)| (r, i))
            })
            .collect();
        if candidates.is_empty() {
            return 0;
        }
        // HashMap iteration order is nondeterministic — sort so the
        // "random victim" draw is reproducible per seed.
        candidates.sort_unstable();
        let (req, gi) = candidates[self.rng.usize(0, candidates.len())];
        let g = &mut self.groups.get_mut(&req).unwrap()[gi];
        let tail = g.len - g.used;
        let tail_start = g.start + g.used;
        g.len = g.used;
        for b in tail_start..tail_start + tail {
            self.space.reclaim(b, req);
        }
        self.release_range(tail_start, tail);
        self.steals += 1;
        tail
    }

    /// How much reserve to add on top of `need` for a new group: the
    /// paper's "expected size" (init_group_blocks ≈ 1 000 tokens),
    /// dynamically shrunk when free memory is scarce.
    fn reserve_for(&self, need: u32) -> u32 {
        let free = self.free_total();
        let headroom = free.saturating_sub(need) / 4;
        self.init_group_blocks.saturating_sub(need).min(headroom)
    }
}

impl KvAllocator for BlockGroupAllocator {
    fn allocate(&mut self, req: RequestId, n: usize) -> Option<Vec<BlockId>> {
        let mut need = n as u32;
        // Atomicity precheck: free + reserved tails (the requester's own
        // tail is consumed in step 1; others are stealable) must cover it.
        if (self.free_total() + self.reserved_tails()) < need {
            return None;
        }
        let mut got: Vec<BlockId> = Vec::with_capacity(n);

        // 1) Fill the active group's reserved tail in place.
        if let Some(gs) = self.groups.get_mut(&req) {
            if let Some(g) = gs.last_mut() {
                let take = (g.len - g.used).min(need);
                for i in 0..take {
                    got.push(g.start + g.used + i);
                }
                g.used += take;
                need -= take;
            }
        }

        // 2) New groups from the free manager (stealing tails on demand).
        while need > 0 {
            if self.free_total() == 0 && self.steal_one_tail() == 0 {
                unreachable!("precheck guaranteed space");
            }
            if self.free_total() == 0 {
                continue; // steal again
            }
            let reserve = self.reserve_for(need);
            let want = need + reserve;
            let (start, take_len) = match self.best_fit(want) {
                Some((s, _)) => (s, want),
                None => {
                    let (s, l) = self.largest().unwrap();
                    (s, l.min(want))
                }
            };
            self.take_range(start, take_len);
            for b in start..start + take_len {
                self.space.claim(b, req);
            }
            let used = take_len.min(need);
            for i in 0..used {
                got.push(start + i);
            }
            self.groups.entry(req).or_default().push(Group {
                start,
                len: take_len,
                used,
            });
            self.groups_created += 1;
            need -= used;
        }

        self.tables.entry(req).or_default().extend(&got);
        Some(got)
    }

    fn release(&mut self, req: RequestId) -> Vec<BlockId> {
        let table = self.tables.remove(&req).unwrap_or_default();
        for g in self.groups.remove(&req).unwrap_or_default() {
            for b in g.start..g.start + g.len {
                self.space.reclaim(b, req);
            }
            self.release_range(g.start, g.len);
        }
        table
    }

    fn release_tail(&mut self, req: RequestId, n: usize) -> Vec<BlockId> {
        let held = self.table(req).len();
        if n == 0 {
            return Vec::new();
        }
        if n >= held {
            return self.release(req);
        }
        let table = self.tables.get_mut(&req).expect("held > 0");
        let freed = table.split_off(held - n);
        let mut left = n as u32;
        while left > 0 {
            let g = *self
                .groups
                .get(&req)
                .and_then(|gs| gs.last())
                .expect("groups cover the table");
            if g.used <= left {
                // The whole group goes (its reserved tail with it).
                self.groups.get_mut(&req).unwrap().pop();
                left -= g.used;
                for b in g.start..g.start + g.len {
                    self.space.reclaim(b, req);
                }
                self.release_range(g.start, g.len);
            } else {
                // Shrink in place: free the used suffix plus the
                // reserved tail beyond it as one contiguous range, which
                // `release_range` re-coalesces with any free neighbor.
                let keep = g.used - left;
                let free_start = g.start + keep;
                let free_len = g.len - keep;
                let gm = self.groups.get_mut(&req).unwrap().last_mut().unwrap();
                gm.used = keep;
                gm.len = keep;
                for b in free_start..free_start + free_len {
                    self.space.reclaim(b, req);
                }
                self.release_range(free_start, free_len);
                left = 0;
            }
        }
        freed
    }

    fn table(&self, req: RequestId) -> &[BlockId] {
        self.tables.get(&req).map(|t| t.as_slice()).unwrap_or(&[])
    }

    fn available_blocks(&self) -> usize {
        (self.free_total() + self.reserved_tails()) as usize
    }

    fn space(&self) -> &GpuBlockSpace {
        &self.space
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::block::runs_of_table;

    fn alloc(n: usize, init: usize) -> BlockGroupAllocator {
        BlockGroupAllocator::new(n, init, 42)
    }

    #[test]
    fn first_allocation_is_one_contiguous_group() {
        let mut a = alloc(256, 60);
        let got = a.allocate(1, 10).unwrap();
        assert_eq!(runs_of_table(&got).len(), 1, "contiguous");
        let gs = a.groups_of(1);
        assert_eq!(gs.len(), 1);
        assert_eq!(gs[0].used, 10);
        assert!(gs[0].len >= 10, "reserved tail allowed");
        a.space().check_invariants();
    }

    #[test]
    fn growth_fills_reserved_tail_in_place() {
        let mut a = alloc(256, 60);
        let first = a.allocate(1, 10).unwrap();
        let more = a.allocate(1, 5).unwrap();
        // Growth continues physically after the first allocation.
        assert_eq!(more[0], *first.last().unwrap() + 1);
        assert_eq!(runs_of_table(a.table(1)).len(), 1);
    }

    #[test]
    fn release_merges_back_to_one_range() {
        let mut a = alloc(128, 16);
        a.allocate(1, 20).unwrap();
        a.allocate(2, 20).unwrap();
        a.allocate(3, 20).unwrap();
        a.release(2);
        a.release(1);
        a.release(3);
        assert_eq!(a.free.len(), 1);
        assert_eq!(a.free_total(), 128);
        a.space().check_invariants();
    }

    #[test]
    fn steals_reserved_tail_under_pressure() {
        let mut a = alloc(64, 60);
        // Request 1 takes 10 used but reserves a big tail.
        a.allocate(1, 10).unwrap();
        let tail_before: u32 = a.groups_of(1).iter().map(|g| g.len - g.used).sum();
        assert!(tail_before > 0);
        // Request 2 wants more than what's in the free manager.
        let free_now = a.free_total() as usize;
        let got = a.allocate(2, free_now + 4).unwrap();
        assert_eq!(got.len(), free_now + 4);
        assert!(a.steals > 0, "tail must have been stolen");
        a.space().check_invariants();
    }

    #[test]
    fn refuses_when_even_steal_insufficient() {
        let mut a = alloc(32, 8);
        a.allocate(1, 30).unwrap();
        assert!(a.allocate(2, 10).is_none());
        // No partial mutation.
        assert!(a.table(2).is_empty());
        a.space().check_invariants();
    }

    #[test]
    fn coarser_granularity_than_fixed_after_churn() {
        // The headline §3.1 property: after identical churn, block-group
        // tables have far fewer, larger runs than the fixed allocator
        // (Fig. 3). Mirrors fixed.rs::churn_fragments_tables.
        use crate::block::fixed::FixedBlockAllocator;
        use crate::util::rng::Rng;

        let n_blocks = 1024;
        let mut bg = alloc(n_blocks, 60);
        let mut fx = FixedBlockAllocator::new(n_blocks);
        for (label, a) in [
            ("bg", &mut bg as &mut dyn KvAllocator),
            ("fx", &mut fx as &mut dyn KvAllocator),
        ] {
            let mut rng = Rng::new(7);
            let mut live: Vec<RequestId> = Vec::new();
            let mut next: RequestId = 0;
            for _ in 0..600 {
                if !live.is_empty() && rng.chance(0.45) {
                    let idx = rng.usize(0, live.len());
                    a.release(live.swap_remove(idx));
                } else {
                    // Mixed growth: new request or grow an existing one.
                    if !live.is_empty() && rng.chance(0.5) {
                        let r = live[rng.usize(0, live.len())];
                        let _ = a.allocate(r, rng.usize(1, 5));
                    } else {
                        let nb = rng.usize(4, 40);
                        if a.allocate(next, nb).is_some() {
                            live.push(next);
                        }
                        next += 1;
                    }
                }
            }
            let mut total_blocks = 0usize;
            let mut total_runs = 0usize;
            for &r in &live {
                let t = a.table(r);
                total_blocks += t.len();
                total_runs += runs_of_table(t).len();
            }
            let avg = total_blocks as f64 / total_runs.max(1) as f64;
            println!("{label}: avg run length {avg:.2}");
            if label == "bg" {
                assert!(avg > 6.0, "block groups stay coarse, got {avg}");
            } else {
                assert!(avg < 4.0, "fixed fragments, got {avg}");
            }
            a.space().check_invariants();
        }
    }

    #[test]
    fn release_tail_shrinks_in_place_and_recoalesces() {
        let mut a = alloc(64, 60);
        a.allocate(1, 40).unwrap();
        let freed = a.release_tail(1, 10);
        assert_eq!(freed.len(), 10);
        assert_eq!(a.table(1).len(), 30);
        // The freed suffix (and the group's reserved tail) went back to
        // the free manager as allocatable space...
        assert_eq!(a.available_blocks(), 34);
        let gs = a.groups_of(1);
        assert_eq!(gs.len(), 1);
        assert_eq!((gs[0].used, gs[0].len), (30, 30), "shrunk in place");
        // ... coalesced into ONE range, so a contiguous 34-block
        // allocation succeeds.
        let got = a.allocate(2, 34).unwrap();
        assert_eq!(runs_of_table(&got).len(), 1, "freed tail must coalesce");
        a.space().check_invariants();
        a.release(1);
        a.release(2);
        assert_eq!(a.free_total(), 64);
        assert_eq!(a.free.len(), 1, "full free restores one range");
    }

    #[test]
    fn release_tail_spans_groups() {
        let mut a = alloc(64, 8);
        a.allocate(1, 20).unwrap();
        a.allocate(2, 20).unwrap();
        a.release(1); // hole at the front
        a.allocate(3, 30).unwrap(); // spans the hole + tail space
        assert!(a.groups_of(3).len() >= 2);
        // Drop a tail crossing the last group boundary.
        let freed = a.release_tail(3, 25);
        assert_eq!(freed.len(), 25);
        assert_eq!(a.table(3).len(), 5);
        let used: u32 = a.groups_of(3).iter().map(|g| g.used).sum();
        assert_eq!(used, 5, "groups must cover exactly the table");
        a.space().check_invariants();
    }

    #[test]
    fn release_tail_of_everything_is_a_full_release() {
        let mut a = alloc(64, 8);
        a.allocate(1, 12).unwrap();
        let freed = a.release_tail(1, 12);
        assert_eq!(freed.len(), 12);
        assert!(a.table(1).is_empty());
        assert!(a.groups_of(1).is_empty());
        assert_eq!(a.free_total(), 64);
        a.space().check_invariants();
    }

    #[test]
    fn reserve_shrinks_when_memory_scarce() {
        let mut a = alloc(64, 60);
        a.allocate(1, 40).unwrap();
        // Only ~24 blocks left; a new request must not hoard them all.
        a.allocate(2, 4).unwrap();
        let g2 = a.groups_of(2)[0];
        assert!(g2.len < 16, "reserve must shrink under pressure: {g2:?}");
    }

    #[test]
    fn multi_group_requests() {
        let mut a = alloc(64, 8);
        a.allocate(1, 20).unwrap();
        a.allocate(2, 20).unwrap();
        a.release(1); // free hole of >= 20 at the front
        a.allocate(3, 30).unwrap(); // must span the hole + tail space
        assert!(a.groups_of(3).len() >= 2);
        assert_eq!(a.table(3).len(), 30);
        a.space().check_invariants();
    }
}
