//! Deterministic PRNG (splitmix64 seeding + xoshiro256++) and the
//! distributions the workload/priority generators need.
//!
//! Every simulation component takes an explicit seed so all experiments
//! are exactly reproducible run-to-run.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-subsystem seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let res = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — hi exclusive, hi > lo.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given rate (mean 1/rate) — Poisson inter-arrivals.
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given log-space mean and sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Geometric: number of Bernoulli(p) failures before the first success,
    /// plus one (support {1, 2, ...}).
    pub fn geometric(&mut self, p: f64) -> u64 {
        debug_assert!(p > 0.0 && p <= 1.0);
        let u = 1.0 - self.f64();
        (u.ln() / (1.0 - p).ln()).floor() as u64 + 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.range(5, 17);
            assert!((5..17).contains(&x));
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn geometric_mean_close() {
        let mut r = Rng::new(7);
        let p = 0.25;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.geometric(p) as f64).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / p).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "frac {frac}");
    }
}
