//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional args.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.options.contains_key(key)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of f64 (for sweep grids).
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.get(key) {
            Some(v) => v
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["serve", "--rate", "1.5", "--mode=sim", "--verbose"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("rate"), Some("1.5"));
        assert_eq!(a.get("mode"), Some("sim"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--rate", "2.5", "--iters", "100"]);
        assert_eq!(a.get_f64("rate", 0.0), 2.5);
        assert_eq!(a.get_u64("iters", 0), 100);
        assert_eq!(a.get_u64("missing", 7), 7);
    }

    #[test]
    fn lists() {
        let a = parse(&["--freqs", "0.01,0.02, 0.04"]);
        assert_eq!(a.get_f64_list("freqs", &[]), vec![0.01, 0.02, 0.04]);
        assert_eq!(a.get_f64_list("absent", &[1.0]), vec![1.0]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "x"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("x"));
    }
}
