//! Percentile / summary statistics for latency metrics.
//!
//! The paper reports P50/P95/P99/P99.9 TTFT and TBT; this module provides
//! exact (sort-based) percentiles over collected samples plus simple
//! histogram utilities for the distribution figures (Fig. 4).

/// Exact percentile summary over a sample set.
///
/// An **empty** sample set is well-defined: every summary statistic
/// (`p`, `mean`, `min`, `max`, `sum`) returns the `0.0` sentinel instead
/// of `NaN` or panicking. Cluster aggregation relies on this — a
/// zero-traffic replica contributes empty percentile sets, and a `NaN`
/// would silently poison every downstream comparison and report cell.
/// Use [`Percentiles::is_empty`] when "no data" must be distinguished
/// from "all samples are zero".
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    sorted: Vec<f64>,
}

impl Percentiles {
    pub fn from(mut samples: Vec<f64>) -> Self {
        samples.retain(|x| x.is_finite());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles { sorted: samples }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Linear-interpolated percentile, `p` in [0, 100]. Empty set → 0.0.
    pub fn p(&self, p: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let n = self.sorted.len();
        if n == 1 {
            return self.sorted[0];
        }
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.sorted[lo] * (1.0 - frac) + self.sorted[hi.min(n - 1)] * frac
    }

    /// Arithmetic mean. Empty set → 0.0.
    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        self.sorted.iter().sum::<f64>() / self.sorted.len() as f64
    }

    /// Largest sample. Empty set → 0.0.
    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap_or(&0.0)
    }

    /// Smallest sample. Empty set → 0.0.
    pub fn min(&self) -> f64 {
        *self.sorted.first().unwrap_or(&0.0)
    }

    pub fn sum(&self) -> f64 {
        self.sorted.iter().sum()
    }

    /// The raw (sorted) samples — cross-replica aggregation re-merges
    /// these so cluster percentiles stay exact.
    pub fn samples(&self) -> &[f64] {
        &self.sorted
    }

    /// Exact union of several percentile sets. The merged buffer is
    /// preallocated at the exact summed length — cluster aggregation
    /// merges hundreds of thousands of samples per metric, and repeated
    /// doubling grows were measurable there.
    pub fn merged(parts: impl IntoIterator<Item = Percentiles>) -> Percentiles {
        let parts: Vec<Percentiles> = parts.into_iter().collect();
        let total = parts.iter().map(|p| p.sorted.len()).sum();
        let mut all = Vec::with_capacity(total);
        for p in parts {
            all.extend_from_slice(&p.sorted);
        }
        Percentiles::from(all)
    }
}

/// Fixed-bin histogram (used for the Fig. 4 workload distributions).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.counts.len();
            let bin = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.counts[bin.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// (bin center, fraction) pairs.
    pub fn normalized(&self) -> Vec<(f64, f64)> {
        let total = self.total().max(1) as f64;
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + (i as f64 + 0.5) * w, c as f64 / total))
            .collect()
    }
}

/// Welford online mean/variance — used by the swap manager's profiler.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basic() {
        let p = Percentiles::from((1..=100).map(|x| x as f64).collect());
        assert!((p.p(50.0) - 50.5).abs() < 1e-9);
        assert_eq!(p.p(0.0), 1.0);
        assert_eq!(p.p(100.0), 100.0);
        assert!((p.p(99.0) - 99.01).abs() < 0.1);
    }

    #[test]
    fn percentile_single() {
        let p = Percentiles::from(vec![7.0]);
        assert_eq!(p.p(50.0), 7.0);
        assert_eq!(p.p(99.9), 7.0);
    }

    #[test]
    fn empty_sample_set_returns_the_zero_sentinel() {
        // Regression: empty sets used to return NaN, which a zero-traffic
        // replica in cluster aggregation propagated into every comparison
        // and report cell. All summaries must be well-defined (0.0) and
        // emptiness must stay queryable.
        let p = Percentiles::from(vec![]);
        assert_eq!(p.p(50.0), 0.0);
        assert_eq!(p.p(99.9), 0.0);
        assert_eq!(p.mean(), 0.0);
        assert_eq!(p.min(), 0.0);
        assert_eq!(p.max(), 0.0);
        assert_eq!(p.sum(), 0.0);
        assert!(p.is_empty(), "emptiness still distinguishable from zeros");
        // The merged-empty path of cluster aggregation is equally safe.
        let m = Percentiles::merged([Percentiles::from(vec![]), Percentiles::from(vec![])]);
        assert_eq!(m.p(99.0), 0.0);
        assert!(m.is_empty());
        // NaN *samples* are still filtered out, never returned.
        let f = Percentiles::from(vec![f64::NAN]);
        assert!(f.is_empty());
        assert_eq!(f.p(50.0), 0.0);
    }

    #[test]
    fn percentile_unsorted_input() {
        let p = Percentiles::from(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(p.min(), 1.0);
        assert_eq!(p.max(), 5.0);
        assert_eq!(p.p(50.0), 3.0);
    }

    #[test]
    fn percentile_filters_nan() {
        let p = Percentiles::from(vec![1.0, f64::NAN, 2.0]);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn merged_is_exact_union() {
        let a = Percentiles::from(vec![1.0, 3.0]);
        let b = Percentiles::from(vec![2.0, 4.0]);
        let m = Percentiles::merged([a, b]);
        assert_eq!(m.samples(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.p(50.0), 2.5);
        assert!(Percentiles::merged([]).is_empty());
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert!(h.counts.iter().all(|&c| c == 1));
        h.add(-1.0);
        h.add(42.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((w.variance() - var).abs() < 1e-12);
    }
}
