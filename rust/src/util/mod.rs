//! Small self-contained utilities (PRNG, statistics, CLI parsing).
//!
//! This repository builds fully offline; only the `xla` crate's dependency
//! closure is available, so the usual ecosystem crates (rand, serde, clap,
//! criterion, proptest) are replaced by the minimal implementations here.

pub mod bench;
pub mod cli;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Percentiles;
