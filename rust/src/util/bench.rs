//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `cargo bench` targets in `rust/benches/`. Measures
//! wall-clock over repeated runs with warmup, reports mean / p50 / p95 /
//! min, and supports throughput annotation. Deliberately simple: the
//! paper-figure "benches" are simulation experiments whose primary output
//! is the metric table itself; this harness times the end-to-end runs and
//! the hot paths.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<4} mean={:>12} p50={:>12} p95={:>12} min={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` repeatedly: `warmup` discarded iterations then `iters` timed.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: samples[samples.len() / 2],
        p95_ns: samples[(samples.len() as f64 * 0.95) as usize % samples.len()],
        min_ns: samples[0],
    };
    res.report();
    res
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_numbers() {
        let r = bench("noop-ish", 2, 10, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
