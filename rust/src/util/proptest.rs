//! Property-testing helper (proptest is unavailable offline).
//!
//! `for_cases(seed, n, |rng| ...)` runs a closure over `n` independently
//! seeded PRNGs; on failure it reports the failing case seed so the case
//! can be replayed deterministically with `replay(seed, ...)`. Shrinking is
//! replaced by deterministic replay — good enough for allocator/scheduler
//! invariant testing, where cases are cheap and seeds printable.

use super::rng::Rng;

/// Run `n` randomized cases. Panics (propagating the inner panic) with the
/// failing case's seed in the message.
pub fn for_cases<F: Fn(&mut Rng)>(base_seed: u64, n: u64, f: F) {
    for i in 0..n {
        let case_seed = base_seed
            .wrapping_mul(0x0100_0000_01B3)
            .wrapping_add(i);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property case {i}/{n} FAILED — replay with seed {case_seed:#x}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single failing case.
pub fn replay<F: Fn(&mut Rng)>(case_seed: u64, f: F) {
    let mut rng = Rng::new(case_seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let counter = std::sync::atomic::AtomicU64::new(0);
        for_cases(1, 25, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 25);
    }

    #[test]
    #[should_panic]
    fn propagates_failure() {
        for_cases(2, 50, |rng| {
            assert!(rng.f64() < 0.9, "intentional failure");
        });
    }
}
