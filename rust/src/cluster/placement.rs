//! Placement policies: which replica serves the next unit of work.
//!
//! A unit of work is either a fresh conversation (no KV anywhere) or a
//! live conversation's next turn (its CPU KV copy lives on the *home*
//! replica). Policies are pure over a per-replica load snapshot, so they
//! are unit-testable without engines and deterministic across runs.

/// Which placement policy the router runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlacementKind {
    /// Rotate every placement across replicas, ignoring both load and KV
    /// locality (the baseline that destroys multi-turn reuse on ≥ 2
    /// replicas).
    RoundRobin,
    /// Lowest load score (KV occupancy + normalized admission backlog),
    /// ignoring KV locality.
    LeastLoaded,
    /// Pin a conversation's later turns to the replica holding its CPU
    /// KV copy; spill to the least-loaded replica only when the home
    /// replica's load score exceeds the least-loaded score by more than
    /// `spill_threshold` (0 = spill on any imbalance ≈ least-loaded with
    /// an affinity tiebreak; `f64::INFINITY` = never spill).
    KvAffinity { spill_threshold: f64 },
    /// KvAffinity for later turns, plus longest-shared-prefix routing
    /// for fresh conversations carrying a shared template: route to the
    /// replica whose prefix pool holds the deepest published chain of
    /// the template's group (ties → lowest index), under the same
    /// `spill_threshold` against the least-loaded score. Fresh
    /// conversations without a template fall back to least-loaded.
    PrefixAware { spill_threshold: f64 },
}

/// Default affinity/balance trade-off: tolerate the home replica being
/// up to half a load unit (≈ half its KV space, or half a batch of
/// backlog) busier than the least-loaded one before giving up locality.
pub const DEFAULT_SPILL_THRESHOLD: f64 = 0.5;

impl PlacementKind {
    pub fn by_name(s: &str) -> Option<PlacementKind> {
        match s {
            "round_robin" | "round-robin" | "rr" => Some(PlacementKind::RoundRobin),
            "least_loaded" | "least-loaded" | "ll" => Some(PlacementKind::LeastLoaded),
            "kv_affinity" | "kv-affinity" | "affinity" => Some(PlacementKind::KvAffinity {
                spill_threshold: DEFAULT_SPILL_THRESHOLD,
            }),
            "prefix_aware" | "prefix-aware" | "prefix" => Some(PlacementKind::PrefixAware {
                spill_threshold: DEFAULT_SPILL_THRESHOLD,
            }),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PlacementKind::RoundRobin => "round_robin",
            PlacementKind::LeastLoaded => "least_loaded",
            PlacementKind::KvAffinity { .. } => "kv_affinity",
            PlacementKind::PrefixAware { .. } => "prefix_aware",
        }
    }
}

/// One replica's load snapshot at placement time. In actor runs this
/// travels inside [`crate::runtime::actor::RouterMsg::Status`] reports:
/// the deterministic executor reads it synchronously at decision time,
/// the threaded executor places on the latest reported (slightly stale)
/// snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplicaLoad {
    /// GPU KV blocks currently allocated.
    pub blocks_in_use: usize,
    /// GPU KV capacity in blocks.
    pub gpu_blocks: usize,
    /// Admission backlog: dispatched-but-unserved arrivals, pending
    /// turns, and requests waiting for GPU residency.
    pub backlog: usize,
    /// Max decode batch (normalizes the backlog).
    pub max_batch: usize,
    /// Deepest published prefix-pool chain per template group, sorted by
    /// group (empty when the prefix cache is off) — what
    /// [`PlacementKind::PrefixAware`] routes fresh templated
    /// conversations on.
    pub prefix_groups: Vec<(u64, u32)>,
}

impl ReplicaLoad {
    /// Scalar load score: KV occupancy plus batch-normalized backlog.
    /// Both terms are ~1.0 at saturation, so a score difference of 0.5
    /// means "half a GPU's worth busier".
    pub fn score(&self) -> f64 {
        self.blocks_in_use as f64 / self.gpu_blocks.max(1) as f64
            + self.backlog as f64 / self.max_batch.max(1) as f64
    }

    /// Deepest published chain of `group` in this replica's prefix pool
    /// (0 = nothing cached).
    pub fn prefix_depth(&self, group: u64) -> u32 {
        self.prefix_groups
            .iter()
            .find(|&&(g, _)| g == group)
            .map_or(0, |&(_, d)| d)
    }
}

/// Lowest-score replica among those `up`; ties break to the lowest
/// index (deterministic). At least one replica must be up.
fn least_loaded_up(loads: &[ReplicaLoad], up: &impl Fn(usize) -> bool) -> usize {
    let mut best: Option<usize> = None;
    for (i, l) in loads.iter().enumerate() {
        if !up(i) {
            continue;
        }
        match best {
            Some(b) if l.score() >= loads[b].score() => {}
            _ => best = Some(i),
        }
    }
    best.expect("no up replica")
}

/// Stateful placement driver (round-robin needs a rotation cursor).
#[derive(Clone, Debug)]
pub struct Placer {
    kind: PlacementKind,
    rr_next: usize,
}

impl Placer {
    pub fn new(kind: PlacementKind) -> Self {
        Placer { kind, rr_next: 0 }
    }

    pub fn kind(&self) -> PlacementKind {
        self.kind
    }

    /// Choose a replica for one unit of work. `home` is the replica
    /// holding the conversation's CPU KV copy (`None` for fresh
    /// conversations).
    pub fn place(&mut self, loads: &[ReplicaLoad], home: Option<usize>) -> usize {
        self.place_filtered(loads, home, None)
    }

    /// [`Placer::place`] with an availability mask: `down[i] == true`
    /// excludes replica `i` from every candidate set (a drained/failed
    /// replica). A drained home forces a migration; round-robin skips
    /// drained slots without disturbing its rotation over the rest. At
    /// least one replica must remain up.
    pub fn place_filtered(
        &mut self,
        loads: &[ReplicaLoad],
        home: Option<usize>,
        down: Option<&[bool]>,
    ) -> usize {
        self.place_with_group(loads, home, down, None)
    }

    /// [`Placer::place_filtered`] with the work unit's shared-template
    /// group (`None` = no template, or the prefix cache is off). Only
    /// [`PlacementKind::PrefixAware`] reads it, and only for fresh
    /// conversations (`home == None`): route to the up replica with the
    /// deepest published chain of the group — locality worth a real
    /// prefill saving — unless that replica is more than
    /// `spill_threshold` busier than the least-loaded one.
    pub fn place_with_group(
        &mut self,
        loads: &[ReplicaLoad],
        home: Option<usize>,
        down: Option<&[bool]>,
        group: Option<u64>,
    ) -> usize {
        assert!(!loads.is_empty(), "placement over an empty cluster");
        let up = |i: usize| down.is_none_or(|d| !d[i]);
        assert!(
            (0..loads.len()).any(up),
            "placement over a fully drained cluster"
        );
        // Home-or-spill under a score threshold — shared by KvAffinity's
        // later-turn pinning and PrefixAware's deepest-chain routing.
        let sticky = |target: Option<usize>, best: usize, threshold: f64| match target {
            Some(t) if up(t) && loads[t].score() <= loads[best].score() + threshold => t,
            _ => best,
        };
        match self.kind {
            PlacementKind::RoundRobin => loop {
                let r = self.rr_next % loads.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                if up(r) {
                    return r;
                }
            },
            PlacementKind::LeastLoaded => least_loaded_up(loads, &up),
            PlacementKind::KvAffinity { spill_threshold } => {
                sticky(home, least_loaded_up(loads, &up), spill_threshold)
            }
            PlacementKind::PrefixAware { spill_threshold } => {
                let best = least_loaded_up(loads, &up);
                if home.is_some() {
                    // Later turns: exactly KvAffinity (the CPU KV copy
                    // outweighs any template prefix).
                    return sticky(home, best, spill_threshold);
                }
                let deepest = group.and_then(|g| {
                    loads
                        .iter()
                        .enumerate()
                        .filter(|&(i, _)| up(i))
                        .map(|(i, l)| (i, l.prefix_depth(g)))
                        .filter(|&(_, d)| d > 0)
                        // Deepest chain wins; ties → lowest index.
                        .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
                        .map(|(i, _)| i)
                });
                sticky(deepest, best, spill_threshold)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(blocks: usize, backlog: usize) -> ReplicaLoad {
        ReplicaLoad {
            blocks_in_use: blocks,
            gpu_blocks: 100,
            backlog,
            max_batch: 10,
            prefix_groups: Vec::new(),
        }
    }

    fn load_with_prefix(blocks: usize, groups: &[(u64, u32)]) -> ReplicaLoad {
        ReplicaLoad {
            prefix_groups: groups.to_vec(),
            ..load(blocks, 0)
        }
    }

    #[test]
    fn names_and_labels() {
        assert_eq!(
            PlacementKind::by_name("round_robin"),
            Some(PlacementKind::RoundRobin)
        );
        assert_eq!(
            PlacementKind::by_name("least_loaded"),
            Some(PlacementKind::LeastLoaded)
        );
        assert!(matches!(
            PlacementKind::by_name("kv_affinity"),
            Some(PlacementKind::KvAffinity { .. })
        ));
        assert!(matches!(
            PlacementKind::by_name("prefix_aware"),
            Some(PlacementKind::PrefixAware { .. })
        ));
        assert_eq!(
            PlacementKind::by_name("prefix"),
            PlacementKind::by_name("prefix-aware")
        );
        assert_eq!(PlacementKind::by_name("nope"), None);
        assert_eq!(PlacementKind::RoundRobin.label(), "round_robin");
        assert_eq!(
            PlacementKind::KvAffinity { spill_threshold: 1.0 }.label(),
            "kv_affinity"
        );
        assert_eq!(
            PlacementKind::PrefixAware { spill_threshold: 1.0 }.label(),
            "prefix_aware"
        );
    }

    #[test]
    fn round_robin_cycles_regardless_of_load() {
        let mut p = Placer::new(PlacementKind::RoundRobin);
        let loads = vec![load(90, 9), load(0, 0), load(50, 5)];
        let seq: Vec<usize> = (0..6).map(|_| p.place(&loads, Some(0))).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_minimum_score_ties_to_lowest_index() {
        let mut p = Placer::new(PlacementKind::LeastLoaded);
        assert_eq!(p.place(&[load(90, 0), load(10, 0), load(10, 8)], None), 1);
        // Exact tie: lowest index wins (determinism).
        assert_eq!(p.place(&[load(10, 2), load(10, 2)], None), 0);
        // Backlog counts too: fewer blocks but a deep queue loses.
        assert_eq!(p.place(&[load(0, 9), load(30, 0)], None), 1);
    }

    #[test]
    fn affinity_sticks_to_home_within_threshold() {
        let mut p = Placer::new(PlacementKind::KvAffinity { spill_threshold: 0.5 });
        // Home is busier, but within half a load unit: stay.
        assert_eq!(p.place(&[load(40, 0), load(10, 0)], Some(0)), 0);
        // Home exceeds the threshold: spill to the least loaded.
        assert_eq!(p.place(&[load(80, 5), load(10, 0)], Some(0)), 1);
        // No home (fresh conversation): least loaded.
        assert_eq!(p.place(&[load(40, 0), load(10, 0)], None), 1);
    }

    #[test]
    fn affinity_never_spills_at_infinite_threshold() {
        let mut p = Placer::new(PlacementKind::KvAffinity {
            spill_threshold: f64::INFINITY,
        });
        assert_eq!(p.place(&[load(100, 10), load(0, 0)], Some(0)), 0);
    }

    #[test]
    fn affinity_at_zero_threshold_still_prefers_home_on_ties() {
        let mut p = Placer::new(PlacementKind::KvAffinity { spill_threshold: 0.0 });
        // Equal scores: home wins (free locality).
        assert_eq!(p.place(&[load(10, 0), load(10, 0)], Some(1)), 1);
        // Any imbalance: spill.
        assert_eq!(p.place(&[load(10, 0), load(11, 0)], Some(1)), 0);
    }

    #[test]
    fn prefix_aware_routes_to_the_deepest_published_chain() {
        let mut p = Placer::new(PlacementKind::PrefixAware { spill_threshold: 0.5 });
        let loads = vec![
            load_with_prefix(10, &[(7, 2)]),
            load_with_prefix(20, &[(7, 5), (9, 1)]),
            load_with_prefix(0, &[]),
        ];
        // Fresh templated conversation: replica 1 holds the deepest
        // chain of group 7 and is within the threshold of replica 2.
        assert_eq!(p.place_with_group(&loads, None, None, Some(7)), 1);
        // Group nobody cached: least loaded.
        assert_eq!(p.place_with_group(&loads, None, None, Some(42)), 2);
        // No template at all: least loaded.
        assert_eq!(p.place_with_group(&loads, None, None, None), 2);
        // Later turns ignore the template and behave like KvAffinity.
        assert_eq!(p.place_with_group(&loads, Some(0), None, Some(7)), 0);
    }

    #[test]
    fn prefix_aware_spills_past_the_threshold_and_breaks_ties_low() {
        let mut p = Placer::new(PlacementKind::PrefixAware { spill_threshold: 0.3 });
        // The deepest-chain replica is 0.8 busier than least-loaded:
        // locality loses.
        let hot = vec![load_with_prefix(80, &[(7, 6)]), load_with_prefix(0, &[])];
        assert_eq!(p.place_with_group(&hot, None, None, Some(7)), 1);
        // Equal depths tie to the lowest index (determinism).
        let tied = vec![load_with_prefix(0, &[(7, 3)]), load_with_prefix(0, &[(7, 3)])];
        assert_eq!(p.place_with_group(&tied, None, None, Some(7)), 0);
        // A drained deepest-chain replica is skipped.
        let mut q = Placer::new(PlacementKind::PrefixAware { spill_threshold: 5.0 });
        let loads = vec![load_with_prefix(0, &[(7, 6)]), load_with_prefix(10, &[(7, 2)])];
        assert_eq!(
            q.place_with_group(&loads, None, Some(&[true, false]), Some(7)),
            1
        );
    }

    #[test]
    fn filtered_placement_skips_drained_replicas() {
        let down = [false, true, false];
        // Round-robin rotation skips the drained middle replica.
        let mut rr = Placer::new(PlacementKind::RoundRobin);
        let even = vec![load(0, 0), load(0, 0), load(0, 0)];
        let seq: Vec<usize> = (0..4)
            .map(|_| rr.place_filtered(&even, None, Some(&down)))
            .collect();
        assert_eq!(seq, vec![0, 2, 0, 2]);
        // Least-loaded ignores a drained minimum.
        let mut ll = Placer::new(PlacementKind::LeastLoaded);
        assert_eq!(
            ll.place_filtered(&[load(90, 0), load(0, 0), load(40, 0)], None, Some(&down)),
            2
        );
        // A drained home forces the spill even inside the threshold.
        let mut aff = Placer::new(PlacementKind::KvAffinity { spill_threshold: 10.0 });
        assert_eq!(
            aff.place_filtered(&[load(0, 0), load(0, 0), load(40, 0)], Some(1), Some(&down)),
            0
        );
        // No mask degenerates to plain place().
        let mut p = Placer::new(PlacementKind::LeastLoaded);
        assert_eq!(p.place_filtered(&[load(5, 0), load(0, 0)], None, None), 1);
    }

    #[test]
    #[should_panic(expected = "fully drained")]
    fn fully_drained_cluster_is_rejected() {
        let mut p = Placer::new(PlacementKind::LeastLoaded);
        p.place_filtered(&[load(0, 0)], None, Some(&[true]));
    }

    #[test]
    fn load_score_saturates_at_about_one_per_axis() {
        let l = load(100, 10);
        assert!((l.score() - 2.0).abs() < 1e-12);
        assert_eq!(ReplicaLoad::default().score(), 0.0);
    }
}
