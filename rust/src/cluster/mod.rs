//! Multi-replica cluster serving: a front-end router dispatching
//! multi-turn conversations across N independent engine replicas.
//!
//! The paper's §3.3 insight — multi-turn KV reuse only pays off when a
//! conversation's later turns land where its CPU-side KV copy lives —
//! acquires a *scale* dimension the moment serving spans more than one
//! engine. Each replica is a full [`crate::coordinator::engine::ServingEngine`]
//! (own scheduler, block pool, swap manager, CPU swap space, and fairness
//! policy); the router owns placement:
//!
//! - [`placement::PlacementKind::RoundRobin`] — rotate every placement,
//!   turn-blind. On ≥ 2 replicas a conversation's later turns land on a
//!   different replica, so the whole accumulated context is re-prefilled
//!   from scratch — the §3.3 reuse win is destroyed (cf. Locality-aware
//!   Fair Scheduling, arXiv 2501.14312).
//! - [`placement::PlacementKind::LeastLoaded`] — lowest load score (held
//!   GPU blocks + admission backlog), locality-blind.
//! - [`placement::PlacementKind::KvAffinity`] — pin later turns to the
//!   replica holding the conversation's CPU KV copy, spilling to the
//!   least-loaded replica only when the home replica's load exceeds the
//!   spill threshold — the tunable reuse-vs-balance trade-off.
//! - [`placement::PlacementKind::PrefixAware`] — KvAffinity plus
//!   template locality for *fresh* conversations: route a templated
//!   arrival at the replica whose global prefix cache
//!   ([`crate::block::prefix`]) holds the deepest published chain for
//!   its group, under the same spill guard.
//!
//! The router measures exactly that trade-off: `affinity_hit_rate`
//! (later-turn placements that kept their KV locality) and
//! `retransferred_blocks_on_migration` (context blocks a migration forces
//! the target replica to rebuild), next to cross-replica aggregates of
//! the per-tenant TTFT/TBT percentiles, token shares, Jain fairness
//! index, and swap volume ([`router::ClusterOutcome`]).
//!
//! `fastswitch exp cluster` runs the placement showdown;
//! `cargo bench --bench cluster_scaling` measures router cost as the
//! replica count grows; `rust/tests/cluster_e2e.rs` pins the reuse
//! semantics deterministically.
//!
//! Execution is actor-shaped ([`crate::runtime::actor`]): the router
//! and every replica communicate through typed messages, and
//! [`ClusterConfig::parallel`] picks the executor — the seeded
//! deterministic scheduler (default, byte-reproducible) or one OS
//! thread per replica over real channels.

pub mod placement;
pub mod router;

pub use placement::{PlacementKind, Placer, ReplicaLoad, DEFAULT_SPILL_THRESHOLD};
pub use router::{ClusterOutcome, ClusterRouter};

/// Front-end configuration: replica fan-out + placement policy
/// (`[cluster]` config section / `--replicas` / `--placement`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Number of independent engine replicas (1 = classic single-engine
    /// serving; the router is bypassed).
    pub replicas: usize,
    pub placement: PlacementKind,
    /// Run replicas on real OS threads (`--parallel` /
    /// `[cluster] parallel`). Placement decisions then use slightly
    /// stale load reports, so per-replica metrics may differ from the
    /// default deterministic executor; workload totals do not.
    pub parallel: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            placement: PlacementKind::KvAffinity {
                spill_threshold: DEFAULT_SPILL_THRESHOLD,
            },
            parallel: false,
        }
    }
}
